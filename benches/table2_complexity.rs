//! Table 2: analytical time complexities of MPR / MRR / HAR, cross-checked
//! against the executable LGR engine's routing costs.
//!
//! The analytical forms (paper Table 2) and the engine's physical model
//! (contended PCIe lanes, shared NVLink fabric, slow CPU reduce) must agree
//! on ORDERING for every layout, even where absolute constants differ.

mod common;

use gmi_drl::cluster::{Topology, HOST_BW, NVLINK_BW};
use gmi_drl::comm::lgr::analytical;
use gmi_drl::comm::{LgrEngine, ReduceStrategy};
use gmi_drl::metrics::Table;

fn engine(g: usize, t: usize) -> LgrEngine {
    let mpl: Vec<Vec<usize>> =
        (0..g).map(|i| (0..t).map(|j| i * t + j).collect()).collect();
    LgrEngine::new(Topology::dgx_a100(g), mpl).unwrap()
}

fn main() {
    common::header(
        "Table 2: MPR / MRR / HAR time complexity",
        "paper Table 2; expectation: HAR <= MRR << MPR for multi-GPU multi-GMI",
    );
    let mp_params = [("AT", 1.1e5), ("HM", 2.9e5), ("SH", 1.5e6)];
    let mut t = Table::new(&[
        "Bench", "g", "t", "MPR ms (analytic)", "MRR ms (analytic)", "HAR ms (analytic)",
        "MPR ms (engine)", "MRR ms (engine)", "HAR ms (engine)",
    ]);
    for (abbr, params) in mp_params {
        for (g, tt) in [(2usize, 2usize), (4, 2), (4, 4), (8, 4)] {
            let mp = params * 4.0;
            let a_mpr = analytical::mpr(g, tt, mp, HOST_BW) * 1e3;
            let a_mrr = analytical::mrr(g, tt, mp, NVLINK_BW) * 1e3;
            let a_har = analytical::har(g, tt, mp, HOST_BW, NVLINK_BW) * 1e3;
            let eng = engine(g, tt);
            let grads: Vec<Vec<f32>> =
                (0..g * tt).map(|_| vec![0.1f32; params as usize]).collect();
            let (_, e_mpr) = eng.allreduce(&grads, ReduceStrategy::MultiProcess).unwrap();
            let (_, e_mrr) = eng
                .allreduce(&grads, ReduceStrategy::MultiRing)
                .map(|(_, s)| ((), s))
                .unwrap_or(((), f64::NAN));
            let (_, e_har) = eng.allreduce(&grads, ReduceStrategy::Hierarchical).unwrap();
            t.row(vec![
                abbr.to_string(),
                g.to_string(),
                tt.to_string(),
                format!("{a_mpr:.3}"),
                format!("{a_mrr:.3}"),
                format!("{a_har:.3}"),
                format!("{:.3}", e_mpr * 1e3),
                format!("{:.3}", e_mrr * 1e3),
                format!("{:.3}", e_har * 1e3),
            ]);
        }
    }
    t.print();
    println!("\n(engine MPR includes the CPU-reduce term the analytic form folds into B1)");
}
