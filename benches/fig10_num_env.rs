//! Figure 10: sync training throughput and GPU memory vs num_env for AT
//! and HM (1 GMI on 1 GPU).
//!
//! Expected shape: throughput rises with num_env with diminishing returns;
//! memory grows steadily and sharply at the top end — the observation that
//! drives the saturation metric of Algorithm 2.

mod common;

use gmi_drl::cluster::Topology;
use gmi_drl::drl::sync::{run_sync, SyncConfig};
use gmi_drl::mapping::{build_sync_layout, MappingTemplate};
use gmi_drl::metrics::{fmt_rate, Table};

fn main() {
    common::header(
        "Fig 10: throughput and memory vs num_env (1 GMI / 1 GPU)",
        "paper Fig 10; expectation: saturating throughput, growing memory",
    );
    let (_guard, compute) = common::compute();
    for abbr in ["AT", "HM"] {
        let (b, cost) = common::bench(abbr);
        println!("--- {} ---", b.name);
        let mut t = Table::new(&["num_env", "steps/s", "gain vs prev", "mem GiB"]);
        let mut prev = 0.0f64;
        for num_env in [512usize, 1024, 2048, 4096, 8192] {
            let topo = Topology::dgx_a100(1);
            let layout = build_sync_layout(
                &topo,
                MappingTemplate::TaskColocated,
                1,
                num_env,
                &cost,
                None,
            )
            .unwrap();
            let cfg = SyncConfig { iterations: 10, ..Default::default() };
            let r = run_sync(&layout, &b, &cost, &compute, &cfg).unwrap();
            let gain = if prev > 0.0 {
                format!("{:+.1}%", 100.0 * (r.metrics.steps_per_sec / prev - 1.0))
            } else {
                "-".to_string()
            };
            prev = r.metrics.steps_per_sec;
            t.row(vec![
                num_env.to_string(),
                fmt_rate(r.metrics.steps_per_sec),
                gain,
                format!("{:.1}", r.metrics.peak_mem_gib),
            ]);
        }
        t.print();
        println!();
    }
}
