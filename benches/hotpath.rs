//! L3 hot-path micro-benchmarks (the §Perf deliverable): wall-clock timing
//! of the coordinator's inner loops — LGR reduction arithmetic, the channel
//! pipeline, and the sync orchestrator — independent of virtual time.
//!
//! Used by the performance pass to find and verify hot-path optimizations;
//! before/after numbers are recorded in EXPERIMENTS.md §Perf.

mod common;

use std::time::Instant;

use gmi_drl::channels::{Compressor, Dispenser, RolloutSegment, ShareMode};
use gmi_drl::cluster::Topology;
use gmi_drl::comm::{LgrEngine, ReduceStrategy};
use gmi_drl::drl::sync::{run_sync, SyncConfig};
use gmi_drl::drl::Compute;
use gmi_drl::mapping::{build_sync_layout, MappingTemplate};
use gmi_drl::metrics::Table;
use gmi_drl::vtime::Clock;

fn time<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    common::header("hotpath: coordinator wall-clock micro-benchmarks", "EXPERIMENTS.md §Perf");
    let mut t = Table::new(&["path", "work", "wall-clock", "rate"]);

    // 1. LGR reduction arithmetic (16 x 1.5M-float gradients, SH scale).
    let mpl: Vec<Vec<usize>> = (0..4).map(|i| (0..4).map(|j| i * 4 + j).collect()).collect();
    let engine = LgrEngine::new(Topology::dgx_a100(4), mpl).unwrap();
    let grads: Vec<Vec<f32>> = (0..16).map(|_| vec![0.5f32; 1_500_000]).collect();
    let s = time(5, || {
        let _ = engine.allreduce(&grads, ReduceStrategy::Hierarchical).unwrap();
    });
    let gb = (16.0 * 1.5e6 * 4.0) / 1e9;
    t.row(vec![
        "LGR allreduce (real sum)".into(),
        "16 x 1.5M f32".into(),
        format!("{:.1} ms", s * 1e3),
        format!("{:.1} GB/s", gb / s),
    ]);

    // 2. Channel pipeline: dispense + compress one SH-scale segment.
    let seg = RolloutSegment::synthetic(16, 2048, 211, 20);
    let mut dp = Dispenser::new(0, 211, 20);
    let mut cp = Compressor::with_default_threshold(ShareMode::MultiChannel);
    let s = time(10, || {
        let chunks = dp.dispense(&seg, Clock(1.0), ShareMode::MultiChannel);
        let _ = cp.push(chunks);
    });
    let seg_bytes = (16 * 2048 * (211 + 20 + 4) * 4) as f64 / 1e9;
    t.row(vec![
        "channel DP+CP".into(),
        "16x2048 SH segment".into(),
        format!("{:.2} ms", s * 1e3),
        format!("{:.1} GB/s", seg_bytes / s),
    ]);

    // 3. Whole sync orchestrator iteration (Null compute, 4G4T).
    let (b, cost) = common::bench("AT");
    let topo = Topology::dgx_a100(4);
    let layout =
        build_sync_layout(&topo, MappingTemplate::TaskColocated, 4, 2048, &cost, None).unwrap();
    let cfg = SyncConfig { iterations: 10, ..Default::default() };
    let s = time(3, || {
        let _ = run_sync(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
    });
    t.row(vec![
        "sync orchestrator".into(),
        "10 iters, 16 GMIs".into(),
        format!("{:.1} ms", s * 1e3),
        format!("{:.2} ms/iter", s * 1e3 / 10.0),
    ]);

    t.print();
}
