//! L3 hot-path micro-benchmarks (the §Perf deliverable): wall-clock timing
//! of the coordinator's inner loops — LGR reduction arithmetic, the channel
//! pipeline, the sync orchestrator, the engine's clock-frontier queries
//! (incremental vs the kept reference scans), and the gateway dispatch
//! loop end to end — independent of virtual time.
//!
//! Used by the performance pass to find and verify hot-path optimizations;
//! before/after numbers are recorded in EXPERIMENTS.md §Perf and in
//! `BENCH_hotpath.json` (written with `--bless`, compared with
//! `--check <baseline.json>` — the CI perf gate).

mod common;

use std::time::Instant;

use common::Json;
use gmi_drl::channels::{Compressor, Dispenser, RolloutSegment, ShareMode};
use gmi_drl::cluster::Topology;
use gmi_drl::comm::{LgrEngine, ReduceStrategy};
use gmi_drl::drl::sync::{run_sync, SyncConfig};
use gmi_drl::drl::Compute;
use gmi_drl::engine::{Engine, OpCharge};
use gmi_drl::mapping::{build_gateway_fleet, build_sync_layout, MappingTemplate};
use gmi_drl::gmi::GmiBackend;
use gmi_drl::metrics::Table;
use gmi_drl::serve::{batch_seconds, generate_trace, run_gateway, GatewayConfig, TrafficPattern};
use gmi_drl::tune::{tune_sync, SyncSpace, TuneConfig};
use gmi_drl::vtime::{Clock, OpKind};

fn time<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    common::header("hotpath: coordinator wall-clock micro-benchmarks", "EXPERIMENTS.md §Perf");
    let mut t = Table::new(&["path", "work", "wall-clock", "rate"]);

    // 1. LGR reduction arithmetic (16 x 1.5M-float gradients, SH scale).
    let mpl: Vec<Vec<usize>> = (0..4).map(|i| (0..4).map(|j| i * 4 + j).collect()).collect();
    let engine = LgrEngine::new(Topology::dgx_a100(4), mpl).unwrap();
    let grads: Vec<Vec<f32>> = (0..16).map(|_| vec![0.5f32; 1_500_000]).collect();
    let s = time(5, || {
        let _ = engine.allreduce(&grads, ReduceStrategy::Hierarchical).unwrap();
    });
    let gb = (16.0 * 1.5e6 * 4.0) / 1e9;
    t.row(vec![
        "LGR allreduce (real sum)".into(),
        "16 x 1.5M f32".into(),
        format!("{:.1} ms", s * 1e3),
        format!("{:.1} GB/s", gb / s),
    ]);

    // 2. Channel pipeline: dispense + compress one SH-scale segment.
    let seg = RolloutSegment::synthetic(16, 2048, 211, 20);
    let mut dp = Dispenser::new(0, 211, 20);
    let mut cp = Compressor::with_default_threshold(ShareMode::MultiChannel);
    let s = time(10, || {
        let chunks = dp.dispense(&seg, Clock(1.0), ShareMode::MultiChannel);
        let _ = cp.push(chunks);
    });
    let seg_bytes = (16 * 2048 * (211 + 20 + 4) * 4) as f64 / 1e9;
    t.row(vec![
        "channel DP+CP".into(),
        "16x2048 SH segment".into(),
        format!("{:.2} ms", s * 1e3),
        format!("{:.1} GB/s", seg_bytes / s),
    ]);

    // 3. Whole sync orchestrator iteration (Null compute, 4G4T).
    let (b, cost) = common::bench("AT");
    let topo = Topology::dgx_a100(4);
    let layout =
        build_sync_layout(&topo, MappingTemplate::TaskColocated, 4, 2048, &cost, None).unwrap();
    let cfg = SyncConfig { iterations: 10, ..Default::default() };
    let s = time(3, || {
        let _ = run_sync(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
    });
    t.row(vec![
        "sync orchestrator".into(),
        "10 iters, 16 GMIs".into(),
        format!("{:.1} ms", s * 1e3),
        format!("{:.2} ms/iter", s * 1e3 / 10.0),
    ]);

    // 4. Engine clock-frontier round: one charge + span + all per-GPU
    //    frontiers, the query mix every scheduler/gateway round performs.
    //    Run once through the incremental fields and once through the kept
    //    `*_scan` reference implementations — the speedup between them is
    //    the PR's machine-independent headline and the in-binary half of
    //    the regression gate (both halves run on the same host in the same
    //    process, so the ratio survives any hardware).
    let (b4, cost4) = common::bench("AT");
    let topo8 = Topology::dgx_a100(8);
    let gpus = topo8.num_gpus();
    let fleet8 = build_gateway_fleet(&topo8, 4, 4, 32, &cost4, None).unwrap();
    let mut engine = Engine::new(&fleet8.manager, &cost4);
    let execs = engine.add_group(&fleet8.rollout_gmis).unwrap();
    let fwd = [OpCharge::recorded(OpKind::PolicyFwd { num_env: 32 })];
    let rounds = 100_000usize;
    let mut next = 0usize;
    let mut run_rounds = |engine: &mut Engine, scan: bool| -> f64 {
        let mut acc = 0.0;
        for _ in 0..rounds {
            let ex = execs[next % execs.len()];
            next += 1;
            engine.charge_steps(&cost4, ex, 1.0, &fwd, 0.0);
            if scan {
                acc += engine.span_scan();
                for g in 0..gpus {
                    acc += engine.gpu_time_scan(g);
                }
            } else {
                acc += engine.span();
                for g in 0..gpus {
                    acc += engine.gpu_time(g);
                }
            }
        }
        acc
    };
    // Interleave so clock growth (charges accumulate across calls) hits
    // both variants evenly; the warmup call inside `time` covers the rest.
    let s_scan = time(3, || {
        assert!(run_rounds(&mut engine, true).is_finite());
    }) / rounds as f64;
    let s_inc = time(3, || {
        assert!(run_rounds(&mut engine, false).is_finite());
    }) / rounds as f64;
    let speedup = s_scan / s_inc;
    t.row(vec![
        "engine round (scan ref)".into(),
        format!("{} execs, {gpus} GPUs", execs.len()),
        format!("{:.0} ns", s_scan * 1e9),
        format!("{:.2} Mrounds/s", 1e-6 / s_scan),
    ]);
    t.row(vec![
        "engine round (incremental)".into(),
        format!("{} execs, {gpus} GPUs", execs.len()),
        format!("{:.0} ns", s_inc * 1e9),
        format!("{:.2} Mrounds/s ({speedup:.1}x)", 1e-6 / s_inc),
    ]);

    // 5. Gateway dispatch loop end to end: a constant-rate open-loop trace
    //    through `run_gateway` (pooled plans, Arc trace, Fabric-free
    //    capacity math). Requests/wall-second is the events/s headline.
    let topo2 = Topology::dgx_a100(2);
    let batch = 32;
    let serial = batch_seconds(&b4, &cost4, &topo2, 0.25, batch);
    let rate = 0.7 * 4.0 * batch as f64 / serial; // 70% of the 4-member fleet
    let n_requests = 200_000usize;
    let duration = n_requests as f64 / rate;
    let trace = generate_trace(&TrafficPattern::Constant { rate }, duration, 17, 8);
    let fleet2 = build_gateway_fleet(&topo2, 2, 4, batch, &cost4, None).unwrap();
    let cfg = GatewayConfig {
        max_batch: batch,
        max_wait_s: 1e-3,
        admission_cap: None,
        slo_s: 20e-3,
        autoscale: None,
        ..GatewayConfig::default()
    };
    let t0 = Instant::now();
    let r = run_gateway(&fleet2, &b4, &cost4, &trace, &cfg).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    let req_per_s = r.latency.served as f64 / wall;
    let sim_per_wall = r.metrics.span_s / wall;
    t.row(vec![
        "gateway dispatch loop".into(),
        format!("{} requests", trace.len()),
        format!("{:.0} ms", wall * 1e3),
        format!("{:.2} Mreq/s", req_per_s / 1e6),
    ]);

    // 6. Auto-tuner probe overhead: one full tune_sync pass over the default
    //    joint space (saturation pruning + successive halving + final lock).
    //    Two numbers matter: the wall-clock cost of making the decision, and
    //    the *virtual* probe time charged against the 1% budget — the latter
    //    is the machine-independent half of the gate below.
    let tune_topo = Topology::dgx_a100(2);
    let tune_base = SyncConfig { iterations: 40_000, ..Default::default() };
    let tcfg = TuneConfig::default();
    let mut last_rep = None;
    let s_tune = time(3, || {
        let rep = tune_sync(
            &tune_topo,
            MappingTemplate::TaskColocated,
            Some(GmiBackend::Mps),
            &b4,
            &cost4,
            &tune_base,
            (2, 512),
            &SyncSpace::default(),
            &tcfg,
        )
        .unwrap();
        last_rep = Some(rep);
    });
    let rep = last_rep.expect("tuner ran");
    let probe_frac = if rep.run_horizon_s > 0.0 { rep.probe_cost_s / rep.run_horizon_s } else { 0.0 };
    t.row(vec![
        "tuner decision (sync)".into(),
        format!("{} probes / {} cands", rep.probes.len(), rep.candidates),
        format!("{:.1} ms", s_tune * 1e3),
        format!("{:.3}% of run", probe_frac * 100.0),
    ]);

    t.print();

    // BENCH_hotpath.json + regression gate.
    let (check, bless) = common::perf_args();
    let fields = [
        ("bench", Json::Str("hotpath".into())),
        ("status", Json::Str("measured".into())),
        ("engine_round_ns_incremental", Json::Num(s_inc * 1e9)),
        ("engine_round_ns_scan", Json::Num(s_scan * 1e9)),
        ("incremental_vs_scan_speedup", Json::Num(speedup)),
        ("gateway_requests", Json::Int(r.latency.served as u64)),
        ("gateway_wall_s", Json::Num(wall)),
        ("events_per_s", Json::Num(req_per_s)),
        ("sim_s_per_wall_s", Json::Num(sim_per_wall)),
        ("tune_wall_s", Json::Num(s_tune)),
        ("tune_probes", Json::Int(rep.probes.len() as u64)),
        ("tune_probe_cost_s", Json::Num(rep.probe_cost_s)),
        ("tune_budget_s", Json::Num(rep.budget_s)),
        ("tune_probe_frac_of_run", Json::Num(probe_frac)),
        (
            "peak_rss_kib",
            common::peak_rss_kib().map_or(Json::Null, Json::Int),
        ),
    ];
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_hotpath.json");
    // Gate BEFORE bless: with both pointed at the same path, blessing
    // first would make the check compare the run against itself.
    if let Some(baseline) = check {
        // Machine-independent half: the incremental path must actually be
        // faster than the reference scans it replaced.
        if speedup < 1.0 {
            eprintln!(
                "gate FAILED: incremental frontier queries slower than the \
                 reference scans ({speedup:.2}x)"
            );
            std::process::exit(1);
        }
        println!("gate: incremental vs scan speedup {speedup:.1}x (>= 1.0 required)");
        // Tuner half of the machine-independent gate: the probes charged
        // must fit the budget the tuner reserved, and the budget itself
        // must stay within the configured fraction of the run horizon.
        if rep.probe_cost_s > rep.budget_s + 1e-9 {
            eprintln!(
                "gate FAILED: tuner probe cost {:.4}s exceeds its budget {:.4}s",
                rep.probe_cost_s, rep.budget_s
            );
            std::process::exit(1);
        }
        println!(
            "gate: tuner probes {:.4}s within {:.4}s budget ({:.3}% of run)",
            rep.probe_cost_s,
            rep.budget_s,
            probe_frac * 100.0
        );
        // Host-dependent half: only binding once the committed baseline
        // carries real numbers.
        common::gate_throughput(&baseline, "events_per_s", req_per_s);
    }
    if bless {
        common::write_json(out, &fields).unwrap();
        println!("blessed {out}");
    }
}
