//! Tables 4 & 5: TDG vs TCG analytical comparison (resource size,
//! communication size, projected throughput), cross-checked against the
//! executable orchestrators.
//!
//! Expected shape: TCG ~2.5x TDG for serving (Eq. 2), TCG_EX ~5x TDG_EX
//! for sync training (Eq. 3); the run-level orchestrators must agree on
//! ordering.

mod common;

use gmi_drl::cluster::Topology;
use gmi_drl::drl::serving::{run_serving, ServingConfig};
use gmi_drl::drl::sync::{run_sync, SyncConfig};
use gmi_drl::mapping::cost::{serving_cost, sync_cost, TaskProfile};
use gmi_drl::mapping::{
    build_serving_layout, build_sync_layout, MappingTemplate,
};
use gmi_drl::metrics::Table;

fn main() {
    common::header(
        "Tables 4+5: task-colocated vs task-dedicated GMI mapping",
        "paper §5.1; expectation: TCG ~2.5x (serving), TCG_EX ~5x (sync)",
    );
    let (_guard, compute) = common::compute();

    // ---- analytical (Tables 4/5 with the paper's measured constants) ----
    let mut t = Table::new(&[
        "Bench", "workload", "R(TDG)", "R(TCG)", "COM(TDG) B", "COM(TCG) B", "TOP ratio TCG/TDG",
    ]);
    for abbr in ["AT", "HM", "SH"] {
        let (b, _) = common::bench(abbr);
        let p = TaskProfile::paper_defaults(b.obs_dim, b.act_dim, b.param_bytes() as f64, 32, 8);
        let s_tdg = serving_cost(&p, MappingTemplate::TaskDedicated);
        let s_tcg = serving_cost(&p, MappingTemplate::TaskColocated);
        t.row(vec![
            abbr.to_string(),
            "serving".to_string(),
            format!("{:.2}", s_tdg.resource_size),
            format!("{:.2}", s_tcg.resource_size),
            format!("{:.0}", s_tdg.comm_bytes),
            format!("{:.0}", s_tcg.comm_bytes),
            format!("{:.2}x", s_tcg.throughput / s_tdg.throughput),
        ]);
        let x_tdg = sync_cost(&p, MappingTemplate::TaskDedicated);
        let x_tcg = sync_cost(&p, MappingTemplate::TaskColocated);
        t.row(vec![
            abbr.to_string(),
            "sync train".to_string(),
            format!("{:.2}", x_tdg.resource_size),
            format!("{:.2}", x_tcg.resource_size),
            format!("{:.2e}", x_tdg.comm_bytes),
            format!("{:.2e}", x_tcg.comm_bytes),
            format!("{:.2}x", x_tcg.throughput / x_tdg.throughput),
        ]);
    }
    t.print();

    // ---- executable cross-check ----
    println!("\nrun-level cross-check (steps/s, 2 GPUs, 3 GMIs/GPU):");
    let mut t = Table::new(&["Bench", "serving TDG", "serving TCG", "sync TDG_EX", "sync TCG_EX"]);
    for abbr in ["AT", "HM"] {
        let (b, cost) = common::bench(abbr);
        let topo = Topology::dgx_a100(2);
        let scfg = ServingConfig { rounds: 8, ..Default::default() };
        let run_serve = |tpl| {
            let l = build_serving_layout(&topo, tpl, 3, 2048, &cost, None).unwrap();
            run_serving(&l, &b, &cost, &compute, &scfg).unwrap().steps_per_sec
        };
        let ycfg = SyncConfig { iterations: 8, ..Default::default() };
        let run_train = |tpl| {
            let l = build_sync_layout(&topo, tpl, 3, 2048, &cost, None).unwrap();
            run_sync(&l, &b, &cost, &compute, &ycfg)
                .unwrap()
                .metrics
                .steps_per_sec
        };
        t.row(vec![
            abbr.to_string(),
            format!("{:.0}", run_serve(MappingTemplate::TaskDedicated)),
            format!("{:.0}", run_serve(MappingTemplate::TaskColocated)),
            format!("{:.0}", run_train(MappingTemplate::TaskDedicated)),
            format!("{:.0}", run_train(MappingTemplate::TaskColocated)),
        ]);
    }
    t.print();
}
