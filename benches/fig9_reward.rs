//! Figure 9: reward accumulation over training time for AT, AY, HM —
//! GMI-DRL vs single-GPU Isaac Gym and its multi-GPU NCCL variant.
//!
//! With real numerics (GMI_DRL_BENCH_REAL=1 + `make artifacts`) the reward
//! samples come from genuine PPO training through the artifacts; the time
//! axis is virtual seconds in both modes, so the *curves' ordering* — GMI-
//! DRL accumulates reward fastest per unit time — is the reproduced claim.

mod common;

use gmi_drl::baselines::{self, CommBackend};
use gmi_drl::cluster::Topology;
use gmi_drl::drl::sync::{run_sync, SyncConfig};
use gmi_drl::gmi::GmiBackend;
use gmi_drl::mapping::{build_sync_layout, MappingTemplate};
use gmi_drl::metrics::Table;
use gmi_drl::selection;

fn main() {
    common::header(
        "Fig 9: reward accumulation over (virtual) training time, 20 epochs",
        "paper Fig 9; expectation: GMI-DRL reaches any reward level sooner",
    );
    let (_guard, compute) = common::compute();
    let epochs = 20;
    for abbr in ["AT", "AY", "HM"] {
        let (b, cost) = common::bench(abbr);
        println!("--- {} ---", b.name);
        let cfg = SyncConfig { iterations: epochs, real_replicas: 1, ..Default::default() };
        let topo4 = Topology::dgx_a100(4);
        let topo1 = Topology::dgx_a100(1);

        // GMI-DRL on 4 GPUs.
        let (sel, _) = selection::explore(&b, &cost, GmiBackend::Mps, 4, b.horizon);
        let sel = sel.unwrap();
        let layout = build_sync_layout(
            &topo4,
            MappingTemplate::TaskColocated,
            sel.gmi_per_gpu,
            sel.num_env,
            &cost,
            None,
        )
        .unwrap();
        let ours = run_sync(&layout, &b, &cost, &compute, &cfg).unwrap();
        // Baselines.
        let single =
            baselines::isaac_sync(&topo1, &b, &cost, &compute, CommBackend::Nccl, 8192, &cfg)
                .unwrap();
        let nccl4 =
            baselines::isaac_sync(&topo4, &b, &cost, &compute, CommBackend::Nccl, 8192, &cfg)
                .unwrap();

        // Sample the three curves on a common virtual-time grid.
        let t_max = ours
            .metrics
            .span_s
            .max(single.metrics.span_s)
            .max(nccl4.metrics.span_s);
        let mut t = Table::new(&["t (s)", "Isaac 1GPU", "Isaac+NCCL 4GPU", "GMI-DRL 4GPU"]);
        let at = |curve: &[(f64, f64)], tt: f64| -> f64 {
            let mut last = 0.0;
            for &(ts, r) in curve {
                if ts > tt {
                    break;
                }
                last = r;
            }
            last
        };
        for i in 1..=8 {
            let tt = t_max * i as f64 / 8.0;
            t.row(vec![
                format!("{tt:.2}"),
                format!("{:.3}", at(&single.metrics.reward_curve, tt)),
                format!("{:.3}", at(&nccl4.metrics.reward_curve, tt)),
                format!("{:.3}", at(&ours.metrics.reward_curve, tt)),
            ]);
        }
        t.print();
        println!(
            "time to finish {epochs} epochs: GMI-DRL {:.2}s | NCCL-4GPU {:.2}s | 1GPU {:.2}s\n",
            ours.metrics.span_s, nccl4.metrics.span_s, single.metrics.span_s
        );
    }
}
