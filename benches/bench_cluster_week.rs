//! Cluster-week replay (the week-scale fast-path tentpole bench): the
//! seeded week scenario — an early-finishing training tenant, a diurnal
//! serving fleet cycling through seven day/night swings, and a bursty
//! gateway with a mid-week spike — run twice on the same topology:
//!
//! * **fast**: streaming traces, macro-request aggregation, capped
//!   seeded-reservoir latency windows, and idle-round fast-forward;
//! * **naive**: every optimization disabled — materialized traces, no
//!   coalescing, exact latency logs, every quantum stepped.
//!
//! Both runs report simulated seconds per wall second, retired events per
//! second, and the process's peak-RSS watermark. The fast run executes
//! FIRST because `VmHWM` is monotonic: its watermark is read before the
//! naive run can raise it, so the RSS ratio is a true fast-vs-naive
//! comparison inside one process.
//!
//! Default mode shrinks the week 8x so CI stays quick (the naive loop is
//! the cost; a full naive week is ~30 M quanta). `--full` runs the real
//! 604 800-second week and enforces the tentpole gates in-binary:
//! >= 10x sim-s/wall-s and >= 5x lower peak RSS than the naive week.
//!
//! `--bless` writes `BENCH_cluster_week.json`; `--check <baseline.json>`
//! compares the fast configuration's sim-s-per-wall-s against the
//! committed baseline (bootstrap/null baselines warn and pass).

mod common;

use std::time::Instant;

use common::Json;
use gmi_drl::cluster::Topology;
use gmi_drl::metrics::Table;
use gmi_drl::sched::{run_cluster, week_scenario, FastForward, SchedConfig, WeekOpts};

const WEEK_S: f64 = 604_800.0;

struct Run {
    label: &'static str,
    wall_s: f64,
    sim_per_wall: f64,
    events_per_s: f64,
    served: usize,
    rss_kib: Option<u64>,
}

fn one_run(
    topo: &Topology,
    b: &gmi_drl::BenchInfo,
    cost: &gmi_drl::vtime::CostModel,
    week_s: f64,
    opts: &WeekOpts,
    ff: FastForward,
    label: &'static str,
) -> Run {
    let cfg = SchedConfig { fast_forward: ff, ..SchedConfig::default() };
    let jobs = week_scenario(topo, week_s, 11, opts);
    let t0 = Instant::now();
    let r = run_cluster(topo, b, cost, &jobs, &cfg).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    let rounds = (r.makespan_s / cfg.quantum_s).ceil() as u64;
    let served: usize = r
        .jobs
        .iter()
        .filter_map(|j| j.metrics.latency.as_ref())
        .map(|l| l.served)
        .sum();
    let events = served as u64 + r.events.len() as u64 + rounds;
    Run {
        label,
        wall_s: wall,
        sim_per_wall: r.makespan_s / wall,
        events_per_s: events as f64 / wall,
        served,
        // Monotonic high watermark: meaningful only in fast-then-naive order.
        rss_kib: common::peak_rss_kib(),
    }
}

fn main() {
    common::header(
        "cluster week: streaming + aggregation + fast-forward vs the naive loop",
        "EXPERIMENTS.md §Scale protocol",
    );
    let (b, cost) = common::bench("AT");
    let topo = Topology::dgx_a100(2);

    let full = std::env::args().any(|a| a == "--full");
    let week_s = if full { WEEK_S } else { WEEK_S / 8.0 };

    // Fast FIRST (see the module docs: VmHWM only goes up).
    let fast = one_run(
        &topo,
        &b,
        &cost,
        week_s,
        &WeekOpts::fast(),
        FastForward::On,
        "fast",
    );
    let naive = one_run(
        &topo,
        &b,
        &cost,
        week_s,
        &WeekOpts::disabled(),
        FastForward::Off,
        "naive",
    );

    let mut t = Table::new(&[
        "config",
        "served",
        "wall (s)",
        "sim-s/wall-s",
        "events/s",
        "peak RSS (KiB)",
    ]);
    for r in [&fast, &naive] {
        t.row(vec![
            r.label.to_string(),
            r.served.to_string(),
            format!("{:.2}", r.wall_s),
            format!("{:.0}", r.sim_per_wall),
            format!("{:.0}", r.events_per_s),
            r.rss_kib.map(|k| k.to_string()).unwrap_or_else(|| "-".into()),
        ]);
    }
    t.print();

    let speed_ratio = fast.sim_per_wall / naive.sim_per_wall.max(1e-12);
    let rss_ratio = match (fast.rss_kib, naive.rss_kib) {
        (Some(f), Some(n)) if f > 0 => Some(n as f64 / f as f64),
        _ => None,
    };
    println!(
        "\n{} week ({week_s:.0}s sim): fast path {speed_ratio:.1}x the naive loop{}",
        if full { "full" } else { "1/8-scale" },
        rss_ratio
            .map(|r| format!(", {r:.1}x lower peak RSS"))
            .unwrap_or_default(),
    );
    if !full {
        println!("(pass --full for the real 604800s week and the tentpole gates)");
    }

    // The tentpole gates bind on the full week; the shrunken CI week still
    // sanity-checks that fast-forward is actually engaged.
    if full {
        assert!(
            speed_ratio >= 10.0,
            "week-scale gate: fast path only {speed_ratio:.1}x the naive loop (need >= 10x)"
        );
        if let Some(r) = rss_ratio {
            assert!(
                r >= 5.0,
                "week-scale gate: peak RSS only {r:.1}x lower than naive (need >= 5x)"
            );
        }
    } else {
        assert!(
            speed_ratio >= 2.0,
            "shrunken week: fast path only {speed_ratio:.1}x the naive loop (need >= 2x)"
        );
    }

    let (check, bless) = common::perf_args();
    let fields = [
        ("bench", Json::Str("cluster_week".into())),
        ("status", Json::Str("measured".into())),
        ("week_s", Json::Num(week_s)),
        ("full", Json::Str(full.to_string())),
        ("sim_s_per_wall_s", Json::Num(fast.sim_per_wall)),
        ("events_per_s", Json::Num(fast.events_per_s)),
        ("naive_sim_s_per_wall_s", Json::Num(naive.sim_per_wall)),
        ("speed_ratio", Json::Num(speed_ratio)),
        (
            "fast_peak_rss_kib",
            fast.rss_kib.map_or(Json::Null, Json::Int),
        ),
        (
            "naive_peak_rss_kib",
            naive.rss_kib.map_or(Json::Null, Json::Int),
        ),
        (
            "rss_ratio",
            rss_ratio.map_or(Json::Null, Json::Num),
        ),
    ];
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_cluster_week.json");
    // Gate BEFORE bless (same-path self-comparison hazard).
    if let Some(baseline) = check {
        common::gate_throughput(&baseline, "sim_s_per_wall_s", fast.sim_per_wall);
    }
    if bless {
        common::write_json(out, &fields).unwrap();
        println!("blessed {out}");
    }
}
