//! Table 8: uni-channel (UCC) vs multi-channel (MCC) experience sharing in
//! A3C training — PPS and TTOP for AY and FC on 2 and 4 GPUs.
//!
//! Expected shape: MCC > UCC on both metrics everywhere; the mechanism is
//! fewer, larger transfers (higher effective bandwidth utilization).

mod common;

use gmi_drl::channels::ShareMode;
use gmi_drl::cluster::Topology;
use gmi_drl::drl::a3c::{run_async, AsyncConfig};
use gmi_drl::mapping::build_async_layout;
use gmi_drl::metrics::{fmt_rate, Table};

fn main() {
    common::header(
        "Table 8: uni-channel vs multi-channel experience sharing",
        "paper Table 8; expectation: MCC beats UCC in PPS and TTOP",
    );
    let (_guard, compute) = common::compute();
    for gpus in [2usize, 4] {
        println!("--- {gpus} GPUs ---");
        let mut t = Table::new(&[
            "Bench", "UCC_PPS", "MCC_PPS", "UCC_TTOP", "MCC_TTOP", "UCC pkts", "MCC pkts",
        ]);
        for abbr in ["AY", "FC"] {
            let (b, cost) = common::bench(abbr);
            let topo = Topology::dgx_a100(gpus);
            let layout = build_async_layout(&topo, gpus / 2, 3, 2, 2048, &cost).unwrap();
            let run = |mode| {
                let cfg = AsyncConfig {
                    rounds: 16,
                    share_mode: mode,
                    batch_samples: 8192,
                    ..Default::default()
                };
                run_async(&layout, &b, &cost, &compute, &cfg).unwrap()
            };
            let ucc = run(ShareMode::UniChannel);
            let mcc = run(ShareMode::MultiChannel);
            t.row(vec![
                abbr.to_string(),
                fmt_rate(ucc.metrics.pps),
                fmt_rate(mcc.metrics.pps),
                fmt_rate(ucc.metrics.ttop),
                fmt_rate(mcc.metrics.ttop),
                ucc.channel_stats.packets_out.to_string(),
                mcc.channel_stats.packets_out.to_string(),
            ]);
        }
        t.print();
        println!();
    }
    println!("paper reference (2 GPUs, AY): UCC 169,451/108,536 -> MCC 180,001/122,676 (PPS/TTOP)");
}
