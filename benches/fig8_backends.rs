//! Figure 8: GMI backend comparison — MPS and MIG vs Direct-Share, for
//! 2-serving and 3-serving layouts on one A100.
//!
//! Expected shape: MPS and MIG consistently beat Direct-Share; on the
//! heavier benchmarks MIG's hardware isolation wins over MPS; on the light
//! ones the difference is minor.

mod common;

use gmi_drl::baselines::backend_serving;
use gmi_drl::config::PAPER_BENCHMARKS;
use gmi_drl::gmi::GmiBackend;
use gmi_drl::metrics::Table;

fn main() {
    common::header(
        "Fig 8: backend comparison (normalized to Direct-Share)",
        "paper Fig 8; expectation: MIG >= MPS > Direct-Share (1.0)",
    );
    let (_guard, compute) = common::compute();
    for k in [2usize, 3] {
        println!("--- {k}-serving on 1x A100 ---");
        let mut t = Table::new(&["Bench", "Direct-Share", "MPS", "MIG"]);
        for abbr in PAPER_BENCHMARKS {
            let (b, cost) = common::bench(abbr);
            let num_env = 2048;
            let run = |be| {
                backend_serving(&b, &cost, &compute, be, k, num_env, 10)
                    .unwrap()
                    .steps_per_sec
            };
            let ds = run(GmiBackend::DirectShare);
            let mps = run(GmiBackend::Mps);
            let mig = run(GmiBackend::Mig);
            t.row(vec![
                abbr.to_string(),
                "1.00".to_string(),
                format!("{:.2}", mps / ds),
                format!("{:.2}", mig / ds),
            ]);
        }
        t.print();
        println!();
    }
}
