//! Figure 7: end-to-end computation throughput, normalized to Isaac Gym on
//! a single GPU — the paper's headline result.
//!
//!   (a) DRL serving            — GMI-DRL vs Isaac Gym multi-GPU serving
//!   (b) sync training vs NCCL  — GMI-DRL vs Isaac Gym (PPO) + NCCL
//!   (c) sync training vs Horovod
//!
//! Expected shape: GMI-DRL wins up to ~2.6x serving (avg ~2.1x), up to
//! ~2.8x vs NCCL (avg ~1.9x), up to ~2.3x vs Horovod (avg ~1.75x); gains
//! grow with benchmark complexity.
//!
//! Usage: cargo bench --bench fig7_end_to_end [-- serving|sync-nccl|sync-horovod]

mod common;

use gmi_drl::baselines::{self, CommBackend};
use gmi_drl::cluster::Topology;
use gmi_drl::config::PAPER_BENCHMARKS;
use gmi_drl::drl::serving::{run_serving, ServingConfig};
use gmi_drl::drl::sync::{run_sync, SyncConfig};
use gmi_drl::drl::Compute;
use gmi_drl::gmi::GmiBackend;
use gmi_drl::mapping::{build_serving_layout, build_sync_layout, MappingTemplate};
use gmi_drl::metrics::Table;
use gmi_drl::selection;

const GPU_COUNTS: [usize; 4] = [1, 2, 4, 8];

fn serving(compute: &Compute) {
    common::header(
        "Fig 7(a): DRL serving throughput (normalized to 1-GPU Isaac Gym)",
        "paper Fig 7(a); expectation: up to ~2.6x, ~2.1x average",
    );
    let mut t = Table::new(&["Bench", "1 GPU", "2 GPUs", "4 GPUs", "8 GPUs"]);
    let mut gains = Vec::new();
    for abbr in PAPER_BENCHMARKS {
        let (b, cost) = common::bench(abbr);
        // 1-GPU Isaac Gym reference for normalization.
        let topo1 = Topology::dgx_a100(1);
        let ref_m = baselines::isaac_serving(&topo1, &b, &cost, compute, 8192, 10).unwrap();
        let mut row = vec![abbr.to_string()];
        for gpus in GPU_COUNTS {
            let topo = Topology::dgx_a100(gpus);
            let (sel, _) =
                selection::explore(&b, &cost, GmiBackend::Mig, gpus, b.horizon);
            let sel = sel.unwrap();
            let layout = build_serving_layout(
                &topo,
                MappingTemplate::TaskColocated,
                sel.gmi_per_gpu,
                sel.num_env,
                &cost,
                None,
            )
            .unwrap();
            let ours = run_serving(&layout, &b, &cost, compute, &ServingConfig {
                rounds: 10,
                seed: 1,
                real_replicas: 0,
            })
            .unwrap();
            let base =
                baselines::isaac_serving(&topo, &b, &cost, compute, 8192, 10).unwrap();
            gains.push(ours.steps_per_sec / base.steps_per_sec);
            row.push(format!(
                "{:.2} vs {:.2} ({:.2}x)",
                ours.steps_per_sec / ref_m.steps_per_sec,
                base.steps_per_sec / ref_m.steps_per_sec,
                ours.steps_per_sec / base.steps_per_sec
            ));
        }
        t.row(row);
    }
    t.print();
    summary(&gains, "2.62x max / 2.08x avg");
}

fn sync(compute: &Compute, backend: CommBackend, label: &str, expect: &str) {
    common::header(
        &format!("Fig 7({label}): sync DRL training throughput vs {backend:?}"),
        &format!("paper Fig 7({label}); expectation: {expect}"),
    );
    let cfg = SyncConfig { iterations: 10, ..Default::default() };
    let mut t = Table::new(&["Bench", "2 GPUs", "4 GPUs", "8 GPUs"]);
    let mut gains = Vec::new();
    for abbr in PAPER_BENCHMARKS {
        let (b, cost) = common::bench(abbr);
        let topo1 = Topology::dgx_a100(1);
        let ref_r = baselines::isaac_sync(&topo1, &b, &cost, compute, backend, 8192, &cfg)
            .unwrap();
        let mut row = vec![abbr.to_string()];
        for gpus in [2usize, 4, 8] {
            let topo = Topology::dgx_a100(gpus);
            let (sel, _) =
                selection::explore(&b, &cost, GmiBackend::Mps, gpus, b.horizon);
            let sel = sel.unwrap();
            let layout = build_sync_layout(
                &topo,
                MappingTemplate::TaskColocated,
                sel.gmi_per_gpu,
                sel.num_env,
                &cost,
                None,
            )
            .unwrap();
            let ours = run_sync(&layout, &b, &cost, compute, &cfg).unwrap();
            let base =
                baselines::isaac_sync(&topo, &b, &cost, compute, backend, 8192, &cfg)
                    .unwrap();
            gains.push(ours.metrics.steps_per_sec / base.metrics.steps_per_sec);
            row.push(format!(
                "{:.2} vs {:.2} ({:.2}x)",
                ours.metrics.steps_per_sec / ref_r.metrics.steps_per_sec,
                base.metrics.steps_per_sec / ref_r.metrics.steps_per_sec,
                ours.metrics.steps_per_sec / base.metrics.steps_per_sec
            ));
        }
        t.row(row);
    }
    t.print();
    summary(&gains, expect);
}

fn summary(gains: &[f64], paper: &str) {
    let max = gains.iter().cloned().fold(0.0f64, f64::max);
    let avg = gains.iter().sum::<f64>() / gains.len() as f64;
    println!("\nGMI-DRL speedup: max {max:.2}x, avg {avg:.2}x (paper: {paper})");
}

fn main() {
    // cargo bench passes a `--bench` flag to the binary; ignore flags.
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with("--"))
        .unwrap_or_default();
    let (_guard, compute) = common::compute();
    if filter.is_empty() || filter == "serving" {
        serving(&compute);
    }
    if filter.is_empty() || filter == "sync-nccl" {
        sync(&compute, CommBackend::Nccl, "b", "2.81x max / 1.86x avg");
    }
    if filter.is_empty() || filter == "sync-horovod" {
        sync(&compute, CommBackend::Horovod, "c", "2.34x max / 1.75x avg");
    }
}
