//! Figure 11: asynchronized DRL training throughput — GMI-DRL vs the
//! non-GMI baseline on 2 and 4 GPUs, measuring predictions/s (PPS) and
//! training-sample throughput (TTOP).
//!
//! Expected shape: GMI-DRL ~1.9x PPS and ~1.65x TTOP on average.

mod common;

use gmi_drl::baselines::non_gmi_async_layout;
use gmi_drl::channels::ShareMode;
use gmi_drl::cluster::Topology;
use gmi_drl::drl::a3c::{run_async, AsyncConfig};
use gmi_drl::mapping::build_async_layout;
use gmi_drl::metrics::{fmt_rate, Table};

fn main() {
    common::header(
        "Fig 11: async DRL training (A3C) — PPS and TTOP vs non-GMI",
        "paper Fig 11; expectation: ~1.88x PPS, ~1.65x TTOP average",
    );
    let (_guard, compute) = common::compute();
    let mut pps_gains = Vec::new();
    let mut ttop_gains = Vec::new();
    for gpus in [2usize, 4] {
        println!("--- {gpus} GPUs (half serving, half training) ---");
        let mut t = Table::new(&[
            "Bench", "non-GMI PPS", "GMI PPS", "PPS gain", "non-GMI TTOP", "GMI TTOP",
            "TTOP gain",
        ]);
        for abbr in ["AY", "FC", "AT", "HM"] {
            let (b, cost) = common::bench(abbr);
            let topo = Topology::dgx_a100(gpus);
            let serving_gpus = gpus / 2;
            let cfg = AsyncConfig {
                rounds: 16,
                share_mode: ShareMode::MultiChannel,
                batch_samples: 8192,
                ..Default::default()
            };
            // GMI-DRL: 3 serving GMIs and 2 trainer GMIs per GPU.
            let ours_layout =
                build_async_layout(&topo, serving_gpus, 3, 2, 2048, &cost).unwrap();
            let ours = run_async(&ours_layout, &b, &cost, &compute, &cfg).unwrap();
            // non-GMI: one process per GPU, uni-channel experience path.
            let base_layout = non_gmi_async_layout(&topo, serving_gpus, 6144, &cost).unwrap();
            let base_cfg = AsyncConfig { share_mode: ShareMode::UniChannel, ..cfg.clone() };
            let base = run_async(&base_layout, &b, &cost, &compute, &base_cfg).unwrap();

            let gp = ours.metrics.pps / base.metrics.pps;
            let gt = ours.metrics.ttop / base.metrics.ttop.max(1e-9);
            pps_gains.push(gp);
            ttop_gains.push(gt);
            t.row(vec![
                abbr.to_string(),
                fmt_rate(base.metrics.pps),
                fmt_rate(ours.metrics.pps),
                format!("{gp:.2}x"),
                fmt_rate(base.metrics.ttop),
                fmt_rate(ours.metrics.ttop),
                format!("{gt:.2}x"),
            ]);
        }
        t.print();
        println!();
    }
    let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    println!(
        "average: {:.2}x PPS (paper 1.88x), {:.2}x TTOP (paper 1.65x)",
        avg(&pps_gains),
        avg(&ttop_gains)
    );
}
