//! Cluster-day replay at ramping volumes (the §Perf tentpole bench): the
//! seeded diurnal co-run scenario — an elastic serving tenant against a
//! preemptible training tenant on one shared cluster — replayed at
//! geometrically growing simulated durations. Each scale reports simulated
//! seconds per wall second, request + scheduling-event throughput, and the
//! process's peak-RSS proxy, so the trajectory shows whether per-round cost
//! stays flat as the day grows (the pooled/incremental hot paths) or
//! degrades (an accidental O(N) scan or per-round allocation creeping back).
//!
//! `--bless` writes `BENCH_cluster_day.json`; `--check <baseline.json>`
//! compares the largest scale's sim-s-per-wall-s against the committed
//! baseline and fails on a >20% regression (bootstrap/null baselines warn
//! and pass) — the CI perf gate's second half.

mod common;

use std::time::Instant;

use common::Json;
use gmi_drl::cluster::Topology;
use gmi_drl::fault::{FaultPlan, FaultTrace};
use gmi_drl::metrics::Table;
use gmi_drl::sched::{corun_scenario, run_cluster, SchedConfig};

fn main() {
    common::header(
        "cluster day: shared-cluster replay at ramping volumes",
        "EXPERIMENTS.md §Perf (wall-clock trajectory)",
    );
    let (b, cost) = common::bench("AT");
    let topo = Topology::dgx_a100(2);
    let cfg = SchedConfig::default();

    let full = std::env::args().any(|a| a == "--full");
    let mut scales = vec![1.0f64, 4.0, 16.0];
    if full {
        scales.push(64.0);
    }

    let mut t = Table::new(&[
        "sim day (s)",
        "rounds",
        "requests",
        "wall (ms)",
        "sim-s/wall-s",
        "events/s",
    ]);
    let mut rows_json = Vec::new();
    let mut last_sim_per_wall = 0.0;
    let mut last_events_per_s = 0.0;
    for &day_s in &scales {
        // Fresh seeded scenario per scale: the diurnal period stretches
        // with the day, so every scale exercises the same grow/shrink
        // cycle shape at proportionally more rounds and requests.
        let jobs = corun_scenario(&topo, &b, &cost, day_s, 11, false);
        let requests = jobs
            .iter()
            .map(|j| match &j.kind {
                gmi_drl::sched::JobKind::Serving { trace, .. } => {
                    trace.len_hint().unwrap_or_else(|| trace.count_and_last().0)
                }
                _ => 0,
            })
            .sum::<usize>();
        let t0 = Instant::now();
        let r = run_cluster(&topo, &b, &cost, &jobs, &cfg).unwrap();
        let wall = t0.elapsed().as_secs_f64();

        let rounds = (r.makespan_s / cfg.quantum_s).ceil() as u64;
        let served: usize = r
            .jobs
            .iter()
            .filter_map(|j| j.metrics.latency.as_ref())
            .map(|l| l.served)
            .sum();
        // "Events" = everything the engine retired: served requests plus
        // scheduling decisions plus round boundaries.
        let events = served as u64 + r.events.len() as u64 + rounds;
        let sim_per_wall = r.makespan_s / wall;
        let events_per_s = events as f64 / wall;
        last_sim_per_wall = sim_per_wall;
        last_events_per_s = events_per_s;
        t.row(vec![
            format!("{day_s:.0}"),
            rounds.to_string(),
            served.to_string(),
            format!("{:.1}", wall * 1e3),
            format!("{sim_per_wall:.1}"),
            format!("{events_per_s:.0}"),
        ]);
        rows_json.push(format!(
            "{{\"sim_day_s\": {day_s}, \"rounds\": {rounds}, \"requests_served\": {served}, \
             \"wall_s\": {wall}, \"sim_s_per_wall_s\": {sim_per_wall}, \
             \"events_per_s\": {events_per_s}}}"
        ));
    }
    t.print();
    if !full {
        println!("(pass --full for the 64-simulated-second scale)");
    }

    // `--faulted`: replay one day under failure injection + charged
    // checkpoints (a GPU loss and an NVSwitch outage, both repaired, on
    // the same seeded scenario) so the fault passes' wall-clock cost is
    // tracked next to the clean day's. Deterministic like everything
    // else: the kills, re-admissions, and goodput-lost figure replay
    // bit-for-bit for a given seed.
    let faulted = std::env::args().any(|a| a == "--faulted");
    let mut faulted_sim_per_wall = None;
    let mut faulted_lost = None;
    if faulted {
        let day_s = 4.0;
        let trace_text = format!(
            "{} fail gpu 1\n{} fail nvswitch\n{} repair gpu 1\n{} repair nvswitch\n",
            0.15 * day_s,
            0.25 * day_s,
            0.40 * day_s,
            0.45 * day_s,
        );
        let trace = FaultTrace::parse(&trace_text, 1).unwrap();
        let fcfg = SchedConfig {
            faults: Some(FaultPlan::new(trace).with_checkpoint_interval(day_s / 40.0)),
            ..SchedConfig::default()
        };
        let jobs = corun_scenario(&topo, &b, &cost, day_s, 11, false);
        let t0 = Instant::now();
        let r = run_cluster(&topo, &b, &cost, &jobs, &fcfg).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let sim_per_wall = r.makespan_s / wall;
        let kills: usize = r.jobs.iter().map(|j| j.kills).sum();
        faulted_sim_per_wall = Some(sim_per_wall);
        faulted_lost = Some(r.goodput_lost_s);
        println!(
            "\nfaulted day ({day_s:.0}s sim): {:.1} sim-s/wall-s | {} hardware events | \
             {kills} kill(s) | goodput lost {:.3} GPU-s | clean day {last_sim_per_wall:.1} \
             sim-s/wall-s",
            sim_per_wall, r.fault_events, r.goodput_lost_s,
        );
        assert!(kills > 0, "the faulted bench day must exercise the kill path");
        assert!(
            r.jobs.iter().all(|j| j.completed_s > 0.0),
            "a killed tenant was never re-admitted in the faulted bench day"
        );
    }

    let (check, bless) = common::perf_args();
    let fields = [
        ("bench", Json::Str("cluster_day".into())),
        ("status", Json::Str("measured".into())),
        ("sim_s_per_wall_s", Json::Num(last_sim_per_wall)),
        ("events_per_s", Json::Num(last_events_per_s)),
        (
            "faulted_sim_s_per_wall_s",
            faulted_sim_per_wall.map_or(Json::Null, Json::Num),
        ),
        (
            "faulted_goodput_lost_s",
            faulted_lost.map_or(Json::Null, Json::Num),
        ),
        (
            "peak_rss_kib",
            common::peak_rss_kib().map_or(Json::Null, Json::Int),
        ),
        (
            "scales",
            Json::Raw(format!("[\n    {}\n  ]", rows_json.join(",\n    "))),
        ),
    ];
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_cluster_day.json");
    // Gate BEFORE bless: with both pointed at the same path, blessing
    // first would make the check compare the run against itself.
    if let Some(baseline) = check {
        common::gate_throughput(&baseline, "sim_s_per_wall_s", last_sim_per_wall);
    }
    if bless {
        common::write_json(out, &fields).unwrap();
        println!("blessed {out}");
    }
}
