//! Table 7: layout-aware gradient reduction (LGR) vs the MPR-only baseline
//! on sync DRL training, for 2G2T / 2G3T / 4G4T layouts.
//!
//! Expected shape: LGR wins on every (bench, layout); the gain grows with
//! the number of GPUs and with model size (SH > HM > AT).

mod common;

use gmi_drl::cluster::Topology;
use gmi_drl::comm::ReduceStrategy;
use gmi_drl::drl::sync::{run_sync, SyncConfig};
use gmi_drl::mapping::{build_sync_layout, MappingTemplate};
use gmi_drl::metrics::{fmt_rate, Table};

fn main() {
    common::header(
        "Table 7: LGR vs MPR baseline throughput (steps/s)",
        "paper Table 7; expectation: LGR > baseline everywhere, larger gains at 4G4T and for bigger models",
    );
    let (_guard, compute) = common::compute();
    let layouts = [("2G2T", 2usize, 2usize), ("2G3T", 2, 3), ("4G4T", 4, 4)];

    let mut t = Table::new(&[
        "Bench", "Params", "2G2T base", "2G2T LGR", "2G3T base", "2G3T LGR", "4G4T base",
        "4G4T LGR",
    ]);
    for abbr in ["AT", "HM", "SH"] {
        let (b, cost) = common::bench(abbr);
        let mut row = vec![abbr.to_string(), format!("{:.1e}", b.num_params as f64)];
        for (_, gpus, tpg) in layouts {
            let topo = Topology::dgx_a100(gpus);
            let layout = build_sync_layout(
                &topo,
                MappingTemplate::TaskColocated,
                tpg,
                2048,
                &cost,
                None,
            )
            .unwrap();
            let mut cfg = SyncConfig { iterations: 10, ..Default::default() };
            cfg.strategy_override = Some(ReduceStrategy::MultiProcess);
            let base = run_sync(&layout, &b, &cost, &compute, &cfg).unwrap();
            cfg.strategy_override = None; // Algorithm 1 (the LGR design)
            let lgr = run_sync(&layout, &b, &cost, &compute, &cfg).unwrap();
            row.push(fmt_rate(base.metrics.steps_per_sec));
            row.push(format!(
                "{} [{}]",
                fmt_rate(lgr.metrics.steps_per_sec),
                lgr.strategy
            ));
        }
        t.row(row);
    }
    t.print();
    println!("\npaper reference rows (DGX-A100): AT 168,619->207,834 | HM 308,873->336,591 | SH 133,044->166,722 at 4G4T");
}
