//! Shared helpers for the bench harnesses.
//!
//! The offline build has no criterion; every bench is a plain `main` that
//! regenerates one of the paper's tables/figures on the deterministic
//! virtual timeline (real numerics optional via GMI_DRL_BENCH_REAL=1 where
//! supported) and prints the same rows/series the paper reports.

#![allow(dead_code)]

use gmi_drl::config::{static_registry, BenchInfo};
use gmi_drl::drl::Compute;
use gmi_drl::vtime::CostModel;

pub fn bench(abbr: &str) -> (BenchInfo, CostModel) {
    let b = static_registry()[abbr].clone();
    let c = CostModel::new(&b);
    (b, c)
}

/// Use real numerics if requested AND artifacts exist; otherwise Null.
/// Returns the server guard (keep alive) and the compute handle.
pub fn compute() -> (Option<gmi_drl::runtime::ExecServer>, Compute) {
    let want_real = std::env::var("GMI_DRL_BENCH_REAL").map(|v| v == "1").unwrap_or(false);
    if want_real {
        if let Ok(server) = gmi_drl::runtime::ExecServer::start(gmi_drl::config::artifacts_dir()) {
            let h = server.handle();
            return (Some(server), Compute::Real { handle: h });
        }
        eprintln!("(GMI_DRL_BENCH_REAL=1 but artifacts unavailable; using Null compute)");
    }
    (None, Compute::Null)
}

pub fn header(title: &str, paper_ref: &str) {
    println!("\n=== {title} ===");
    println!("regenerates: {paper_ref}\n");
}

// ---------------------------------------------------------------------------
// Perf-trajectory support (BENCH_*.json emission and the CI regression gate).
// The offline build has no serde either, so the JSON is written and probed by
// hand: flat objects of numbers / strings / nulls plus pre-rendered nested
// values are all the BENCH files need.
// ---------------------------------------------------------------------------

/// One JSON value in a [`write_json`] object.
pub enum Json {
    Num(f64),
    Int(u64),
    Str(String),
    /// Pre-rendered JSON (nested arrays/objects the caller formats).
    Raw(String),
    Null,
}

fn fmt_json(v: &Json) -> String {
    match v {
        Json::Num(x) if x.is_finite() => format!("{x}"),
        Json::Num(_) => "null".into(),
        Json::Int(x) => format!("{x}"),
        Json::Str(s) => format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\"")),
        Json::Raw(s) => s.clone(),
        Json::Null => "null".into(),
    }
}

/// Write `fields` as a pretty-printed JSON object at `path`.
pub fn write_json(path: &str, fields: &[(&str, Json)]) -> std::io::Result<()> {
    let mut out = String::from("{\n");
    for (i, (k, v)) in fields.iter().enumerate() {
        let comma = if i + 1 == fields.len() { "" } else { "," };
        out.push_str(&format!("  \"{k}\": {}{comma}\n", fmt_json(v)));
    }
    out.push_str("}\n");
    std::fs::write(path, out)
}

/// Extract a top-level numeric field from a (flat-ish) JSON text. Returns
/// `None` when the key is absent or its value is `null` / non-numeric — the
/// bootstrap-baseline case the gate treats as "no baseline yet".
pub fn json_num_field(text: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\"");
    let at = text.find(&pat)? + pat.len();
    let rest = text[at..].trim_start().strip_prefix(':')?.trim_start();
    let end = rest.find(|c: char| c == ',' || c == '}' || c == '\n').unwrap_or(rest.len());
    rest[..end].trim().parse::<f64>().ok()
}

/// Loose CLI parsing shared by the perf benches. Returns
/// `(check_baseline_path, bless)`; every unrecognized argument (e.g. the
/// `--bench` flag cargo injects) is ignored.
pub fn perf_args() -> (Option<String>, bool) {
    let mut check = None;
    let mut bless = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--check" => check = args.next(),
            "--bless" => bless = true,
            _ => {}
        }
    }
    (check, bless)
}

/// Peak resident set size of this process in KiB (`VmHWM` from
/// `/proc/self/status`) — the bench's memory-footprint proxy. `None` off
/// Linux or when procfs is unavailable.
pub fn peak_rss_kib() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

/// The shared regression gate: compare a freshly measured throughput
/// against the committed baseline's same-named field. Exits non-zero on a
/// >20% regression; a missing/null baseline (bootstrap) warns and passes.
pub fn gate_throughput(baseline_path: &str, field: &str, measured: f64) {
    let text = match std::fs::read_to_string(baseline_path) {
        Ok(t) => t,
        Err(e) => {
            println!("gate: baseline {baseline_path} unreadable ({e}); bootstrap pass");
            return;
        }
    };
    match json_num_field(&text, field) {
        Some(base) if base > 0.0 => {
            let floor = 0.8 * base;
            println!(
                "gate: {field} measured {measured:.3} vs baseline {base:.3} (floor {floor:.3})"
            );
            if measured < floor {
                eprintln!(
                    "gate FAILED: {field} regressed more than 20% \
                     ({measured:.3} < 0.8 x {base:.3})"
                );
                std::process::exit(1);
            }
            println!("gate: OK");
        }
        _ => {
            println!(
                "gate: baseline field {field} is null/absent in {baseline_path}; \
                 bootstrap pass (run with --bless to record one)"
            );
        }
    }
}
