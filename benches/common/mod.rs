//! Shared helpers for the bench harnesses.
//!
//! The offline build has no criterion; every bench is a plain `main` that
//! regenerates one of the paper's tables/figures on the deterministic
//! virtual timeline (real numerics optional via GMI_DRL_BENCH_REAL=1 where
//! supported) and prints the same rows/series the paper reports.

#![allow(dead_code)]

use gmi_drl::config::{static_registry, BenchInfo};
use gmi_drl::drl::Compute;
use gmi_drl::vtime::CostModel;

pub fn bench(abbr: &str) -> (BenchInfo, CostModel) {
    let b = static_registry()[abbr].clone();
    let c = CostModel::new(&b);
    (b, c)
}

/// Use real numerics if requested AND artifacts exist; otherwise Null.
/// Returns the server guard (keep alive) and the compute handle.
pub fn compute() -> (Option<gmi_drl::runtime::ExecServer>, Compute) {
    let want_real = std::env::var("GMI_DRL_BENCH_REAL").map(|v| v == "1").unwrap_or(false);
    if want_real {
        if let Ok(server) = gmi_drl::runtime::ExecServer::start(gmi_drl::config::artifacts_dir()) {
            let h = server.handle();
            return (Some(server), Compute::Real { handle: h });
        }
        eprintln!("(GMI_DRL_BENCH_REAL=1 but artifacts unavailable; using Null compute)");
    }
    (None, Compute::Null)
}

pub fn header(title: &str, paper_ref: &str) {
    println!("\n=== {title} ===");
    println!("regenerates: {paper_ref}\n");
}
