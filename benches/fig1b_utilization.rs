//! Figure 1(b): GPU utilization of Isaac Gym PPO training on one A100.
//!
//! The paper profiles AT/HM/SH for 10 epochs and finds utilization
//! consistently under 50% (32% average). We reproduce the measurement on
//! the virtual timeline, and add the GMI-DRL utilization for contrast
//! (the §6.1 claim: +31.8% utilization on average).

mod common;

use gmi_drl::baselines;
use gmi_drl::cluster::Topology;
use gmi_drl::drl::sync::{run_sync, SyncConfig};
use gmi_drl::gmi::GmiBackend;
use gmi_drl::mapping::{build_sync_layout, MappingTemplate};
use gmi_drl::metrics::Table;
use gmi_drl::selection;

fn main() {
    common::header(
        "Fig 1(b): GPU utilization, PPO on 1x A100, 10 epochs",
        "paper Fig 1(b); expectation: baseline < 50% (avg ~32%), GMI-DRL much higher",
    );
    let (_guard, compute) = common::compute();
    let topo = Topology::dgx_a100(1);
    let cfg = SyncConfig { iterations: 10, ..Default::default() };

    let mut t = Table::new(&["Bench", "Isaac Gym util", "GMI-DRL util", "delta"]);
    let mut base_sum = 0.0;
    let mut ours_sum = 0.0;
    for abbr in ["AT", "HM", "SH"] {
        let (b, cost) = common::bench(abbr);
        // Baseline: one exclusive process, peak-tuned num_env.
        let base = baselines::isaac_sync(
            &topo,
            &b,
            &cost,
            &compute,
            baselines::CommBackend::Nccl,
            8192,
            &cfg,
        )
        .unwrap();
        // GMI-DRL: Algorithm 2 configuration.
        let (sel, _) = selection::explore(&b, &cost, GmiBackend::Mps, 1, b.horizon);
        let sel = sel.unwrap();
        let layout = build_sync_layout(
            &topo,
            MappingTemplate::TaskColocated,
            sel.gmi_per_gpu,
            sel.num_env,
            &cost,
            None,
        )
        .unwrap();
        let ours = run_sync(&layout, &b, &cost, &compute, &cfg).unwrap();
        base_sum += base.metrics.utilization;
        ours_sum += ours.metrics.utilization;
        t.row(vec![
            abbr.to_string(),
            format!("{:.1}%", 100.0 * base.metrics.utilization),
            format!("{:.1}%", 100.0 * ours.metrics.utilization),
            format!("+{:.1}pp", 100.0 * (ours.metrics.utilization - base.metrics.utilization)),
        ]);
    }
    t.print();
    println!(
        "\nbaseline avg {:.1}% (paper: ~32%, <50%) | GMI-DRL avg {:.1}%",
        100.0 * base_sum / 3.0,
        100.0 * ours_sum / 3.0
    );
}
