//! Fabric allreduce crossover sweep: payload size x GMI layout, priced by
//! the collective planner (paper Table 2's MPR / MRR / HAR crossover).
//!
//! The offline build has no criterion crate; like every bench here this is
//! a plain deterministic `main` (`cargo bench --bench fabric_allreduce`)
//! that prints the per-strategy plan costs, the planner's cheapest valid
//! pick, and the Algorithm 1 heuristic pick for each point of the sweep —
//! the crossover plot is the cheapest-strategy column flipping as payload
//! grows.

mod common;

use gmi_drl::cluster::Topology;
use gmi_drl::comm::select_strategy;
use gmi_drl::fabric::{Fabric, ReduceStrategy};
use gmi_drl::metrics::Table;

fn mpl(g: usize, t: usize) -> Vec<Vec<usize>> {
    (0..g).map(|i| (0..t).map(|j| i * t + j).collect()).collect()
}

fn main() {
    common::header(
        "fabric_allreduce: MPR / MRR / HAR plan-cost crossover",
        "paper Table 2 / Fig 4; planner pick vs Algorithm 1 heuristic",
    );
    let payloads: [(&str, usize); 5] = [
        ("64 KiB", 64 << 10),
        ("256 KiB", 256 << 10),
        ("1 MiB", 1 << 20),
        ("6 MiB", 6 << 20),
        ("24 MiB", 24 << 20),
    ];
    let layouts: [(usize, usize); 6] = [(1, 3), (2, 2), (2, 3), (4, 2), (4, 4), (8, 4)];
    let mut t = Table::new(&[
        "payload", "g", "t", "MPR ms", "MRR ms", "HAR ms", "planner", "Alg 1",
    ]);
    for (label, bytes) in payloads {
        for (g, tt) in layouts {
            let fabric = Fabric::single_node(Topology::dgx_a100(g));
            let layout = mpl(g, tt);
            let cost_ms = |s: ReduceStrategy| -> String {
                match fabric.plan_allreduce(&layout, bytes, s) {
                    Ok(p) => format!("{:.3}", p.total_s() * 1e3),
                    Err(_) => "invalid".to_string(),
                }
            };
            let (cheapest, plan) = fabric.cheapest_allreduce(&layout, bytes);
            let heuristic = select_strategy(&layout);
            // The planner must never be costlier than the heuristic pick.
            let h_cost = fabric
                .plan_allreduce(&layout, bytes, heuristic)
                .expect("Algorithm 1 only picks valid strategies")
                .total_s();
            assert!(plan.total_s() <= h_cost + 1e-15, "planner worse than Alg 1 at {label} {g}G{tt}T");
            t.row(vec![
                label.to_string(),
                g.to_string(),
                tt.to_string(),
                cost_ms(ReduceStrategy::MultiProcess),
                cost_ms(ReduceStrategy::MultiRing),
                cost_ms(ReduceStrategy::Hierarchical),
                cheapest.to_string(),
                heuristic.to_string(),
            ]);
        }
    }
    t.print();
    println!("\n(planner == cheapest valid plan; asserted <= the Algorithm 1 pick everywhere)");
}
