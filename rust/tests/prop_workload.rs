//! The Workload-program equivalence suite: ONE implementation per
//! workload means a single-tenant cluster run must be *bit-identical* to
//! the standalone run of the same program — same engine charges, same
//! fabric plans, same metrics fold. Each equivalence test mirrors the
//! scheduler's deterministic placement (most-free-share GPU, ties to the
//! lowest index, GMI ids in placement order) with a hand-built layout and
//! compares every `RunMetrics` field bit-for-bit.
//!
//! Also locks in the resumability contract: a preempted (shrunk) and
//! later restored program charges every round exactly once — no work is
//! re-charged across membership/provisioning changes.

use gmi_drl::cluster::Topology;
use gmi_drl::config::static_registry;
use gmi_drl::drl::a3c::{run_async, AsyncConfig};
use gmi_drl::drl::serving::{run_serving, ServingConfig};
use gmi_drl::drl::sync::{run_sync, SyncConfig};
use gmi_drl::drl::Compute;
use gmi_drl::gmi::{GmiBackend, GmiManager, GmiSpec, Role};
use gmi_drl::mapping::{build_gateway_fleet, Layout};
use gmi_drl::metrics::RunMetrics;
use gmi_drl::sched::{run_cluster, JobKind, JobSpec, SchedAction, SchedConfig};
use gmi_drl::serve::{generate_trace, run_gateway, GatewayConfig, TrafficPattern};
use gmi_drl::vtime::CostModel;
use gmi_drl::workload::replay::run_replay;
use gmi_drl::workload::ReplayConfig;

fn bits(x: f64) -> u64 {
    x.to_bits()
}

/// Bit-exact equality over every RunMetrics field.
fn assert_metrics_identical(a: &RunMetrics, b: &RunMetrics, what: &str) {
    assert_eq!(bits(a.steps_per_sec), bits(b.steps_per_sec), "{what}: steps_per_sec");
    assert_eq!(bits(a.pps), bits(b.pps), "{what}: pps");
    assert_eq!(bits(a.ttop), bits(b.ttop), "{what}: ttop");
    assert_eq!(bits(a.span_s), bits(b.span_s), "{what}: span_s");
    assert_eq!(bits(a.utilization), bits(b.utilization), "{what}: utilization");
    assert_eq!(bits(a.final_reward), bits(b.final_reward), "{what}: final_reward");
    assert_eq!(bits(a.comm_s), bits(b.comm_s), "{what}: comm_s");
    assert_eq!(bits(a.peak_mem_gib), bits(b.peak_mem_gib), "{what}: peak_mem_gib");
    assert_eq!(a.reward_curve.len(), b.reward_curve.len(), "{what}: curve len");
    for (i, (x, y)) in a.reward_curve.iter().zip(&b.reward_curve).enumerate() {
        assert_eq!(bits(x.0), bits(y.0), "{what}: curve[{i}].t");
        assert_eq!(bits(x.1), bits(y.1), "{what}: curve[{i}].r");
    }
    assert_eq!(a.links.len(), b.links.len(), "{what}: link count");
    for (x, y) in a.links.iter().zip(&b.links) {
        assert_eq!(x.name, y.name, "{what}: link name");
        assert_eq!(x.bytes, y.bytes, "{what}: link bytes {}", x.name);
        assert_eq!(bits(x.busy_s), bits(y.busy_s), "{what}: link busy {}", x.name);
    }
    assert_eq!(a.latency, b.latency, "{what}: latency stats");
    assert_eq!(a.replay, b.replay, "{what}: replay stats");
}

/// A hand-built layout mirroring the scheduler's placement for `specs`:
/// (gpu, share, mem, role, num_env) per member, GMI ids in order.
fn mirror_layout(
    topo: &Topology,
    specs: &[(usize, f64, f64, Role, usize)],
) -> (GmiManager, Vec<usize>) {
    let mut manager = GmiManager::new(topo.clone());
    let mut ids = Vec::new();
    for (id, &(gpu, share, mem, role, num_env)) in specs.iter().enumerate() {
        manager
            .add_gmi(GmiSpec {
                id,
                gpu,
                sm_share: share,
                mem_gib: mem,
                backend: GmiBackend::Mps,
                role,
                num_env,
            })
            .unwrap();
        ids.push(id);
    }
    (manager, ids)
}

#[test]
fn sync_single_tenant_matches_standalone_bit_for_bit() {
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    // Scheduler placement for 2 x 0.5-share members on an empty 2-GPU
    // cluster: member 0 -> GPU 0, member 1 -> GPU 1 (most free share,
    // ties to the lowest index), roles Holistic, 4 GiB each.
    let (manager, ids) =
        mirror_layout(&topo, &[
            (0, 0.5, 4.0, Role::Holistic, 512),
            (1, 0.5, 4.0, Role::Holistic, 512),
        ]);
    let layout = Layout {
        manager,
        rollout_gmis: ids.clone(),
        trainer_gmis: ids,
        gmi_per_gpu: 1,
        num_env_per_gmi: 512,
        backend: GmiBackend::Mps,
    };
    // The exact program JobKind::Training builds: one PPO epoch of
    // sequential (non-overlapped) minibatch reductions per iteration.
    let cfg = SyncConfig {
        iterations: 4,
        ppo_epochs: 1,
        minibatches: gmi_drl::drl::DEFAULT_MINIBATCHES,
        overlap: false,
        ..SyncConfig::default()
    };
    let standalone = run_sync(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();

    let spec = JobSpec {
        id: 0,
        name: "solo".into(),
        priority: 1,
        arrival_s: 0.0,
        min_gmis: 2,
        initial_gmis: 2,
        max_gmis: 2,
        share: 0.5,
        min_share: 0.25,
        mem_gib: 4.0,
        pin_gpus: None,
        kind: JobKind::Training {
            iterations: 4,
            horizon: b.horizon,
            num_env: 512,
            minibatches: gmi_drl::drl::DEFAULT_MINIBATCHES,
        },
        tune: None,
    };
    let r = run_cluster(&topo, &b, &cost, &[spec], &SchedConfig::default()).unwrap();
    assert_metrics_identical(
        &standalone.metrics,
        &r.job(0).unwrap().metrics,
        "sync standalone vs single-tenant",
    );
}

#[test]
fn closed_serving_single_tenant_matches_standalone_bit_for_bit() {
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(1);
    // Scheduler placement for 2 x 0.5-share members on 1 GPU: both on
    // GPU 0, SimAgent role, 2 GiB each (JobSpec::closed's footprint).
    let (manager, ids) =
        mirror_layout(&topo, &[
            (0, 0.5, 2.0, Role::SimAgent, 1024),
            (0, 0.5, 2.0, Role::SimAgent, 1024),
        ]);
    let layout = Layout {
        manager,
        rollout_gmis: ids,
        trainer_gmis: vec![],
        gmi_per_gpu: 2,
        num_env_per_gmi: 1024,
        backend: GmiBackend::Mps,
    };
    let cfg = ServingConfig { rounds: 5, ..ServingConfig::default() };
    let standalone = run_serving(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();

    let spec = JobSpec::closed(0, "collect", 1, 0.0, 2, 0.5, 0.2, 1024, 5);
    let r = run_cluster(&topo, &b, &cost, &[spec], &SchedConfig::default()).unwrap();
    assert_metrics_identical(
        &standalone,
        &r.job(0).unwrap().metrics,
        "closed serving standalone vs single-tenant",
    );
}

#[test]
fn gateway_single_tenant_matches_standalone_bit_for_bit() {
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(1);
    // The standalone fleet builder's exact provisioning, mirrored by the
    // tenant spec: 2 initial members at floor(100/4)% share on GPU 0.
    let fleet = build_gateway_fleet(&topo, 2, 4, 16, &cost, None).unwrap();
    let member_mem = fleet.manager.gmi(0).unwrap().mem_gib;
    let member_share = fleet.manager.gmi(0).unwrap().sm_share;
    let trace = generate_trace(&TrafficPattern::Poisson { rate: 3000.0 }, 0.1, 9, 4);
    let cfg = GatewayConfig {
        max_batch: 16,
        max_wait_s: 1e-3,
        admission_cap: None,
        slo_s: 30e-3,
        autoscale: None,
    };
    let standalone = run_gateway(&fleet, &b, &cost, &trace, &cfg).unwrap();

    let mut spec = JobSpec::gateway(
        0,
        "gw",
        9,
        0.0,
        (2, 2, 2),
        member_share,
        cfg.clone(),
        trace.clone(),
    );
    spec.mem_gib = member_mem;
    let r = run_cluster(&topo, &b, &cost, &[spec], &SchedConfig::default()).unwrap();
    let job = r.job(0).unwrap();
    assert_metrics_identical(
        &standalone.metrics,
        &job.metrics,
        "gateway standalone vs single-tenant",
    );
    // Per-request distribution identical too (carried in the metrics).
    let (sl, cl) = (
        standalone.metrics.latency.as_ref().unwrap(),
        job.metrics.latency.as_ref().unwrap(),
    );
    assert_eq!(sl.served, cl.served);
    assert_eq!(sl.requests, cl.requests);
}

#[test]
fn a3c_single_tenant_matches_standalone_bit_for_bit() {
    let b = static_registry()["AY"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    // Scheduler placement for an (agents=1, trainers=1) tenant at 0.5
    // share: agent member 0 -> GPU 0, trainer member 1 -> GPU 1.
    let (manager, _) = mirror_layout(&topo, &[
        (0, 0.5, 4.0, Role::SimAgent, 2048),
        (1, 0.5, 4.0, Role::Trainer, 0),
    ]);
    let layout = Layout {
        manager,
        rollout_gmis: vec![0],
        trainer_gmis: vec![1],
        gmi_per_gpu: 1,
        num_env_per_gmi: 2048,
        backend: GmiBackend::Mps,
    };
    let cfg = AsyncConfig { rounds: 6, batch_samples: 4096, ..AsyncConfig::default() };
    let standalone = run_async(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();

    let spec = JobSpec::a3c(0, "a3c", 5, 0.0, (1, 1), 0.5, 0.25, 2048, cfg.clone());
    let r = run_cluster(&topo, &b, &cost, &[spec], &SchedConfig::default()).unwrap();
    assert_metrics_identical(
        &standalone.metrics,
        &r.job(0).unwrap().metrics,
        "a3c standalone vs single-tenant",
    );
}

#[test]
fn replay_single_tenant_matches_standalone_bit_for_bit() {
    let b = static_registry()["AY"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    // Scheduler placement for a (collectors=1) + learner tenant at 0.5
    // share: collector member 0 -> GPU 0, learner member 1 -> GPU 1
    // (JobSpec::replay's 4 GiB footprint).
    let (manager, _) = mirror_layout(&topo, &[
        (0, 0.5, 4.0, Role::SimAgent, 2048),
        (1, 0.5, 4.0, Role::Trainer, 0),
    ]);
    let layout = Layout {
        manager,
        rollout_gmis: vec![0],
        trainer_gmis: vec![1],
        gmi_per_gpu: 1,
        num_env_per_gmi: 2048,
        backend: GmiBackend::Mps,
    };
    let cfg = ReplayConfig { rounds: 6, push_samples: 4096, ..ReplayConfig::default() };
    let standalone = run_replay(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();

    let spec = JobSpec::replay(0, "replay", 5, 0.0, 1, 0.5, 0.25, 2048, cfg.clone());
    let r = run_cluster(&topo, &b, &cost, &[spec], &SchedConfig::default()).unwrap();
    let job = r.job(0).unwrap();
    assert_metrics_identical(
        &standalone.metrics,
        &job.metrics,
        "replay standalone vs single-tenant",
    );
    // The buffer ledger itself is part of the metrics — identical too
    // (covered by assert_metrics_identical, spot-checked here for sanity).
    let stats = job.metrics.replay.as_ref().unwrap();
    assert!(stats.transitions_in > 0 && stats.updates > 0);
}

#[test]
fn preempted_then_restored_program_never_recharges_completed_rounds() {
    // A trainer is shrunk mid-run by a high-priority burst and regrown
    // afterwards. The program resumes where it stopped: the env-step
    // conservation total comes out exactly once, and the job completes
    // exactly once at its full admitted share.
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(1);
    let iterations = 30usize;
    let num_env = 256usize;
    let trace = generate_trace(&TrafficPattern::Constant { rate: 4000.0 }, 0.2, 3, 4);
    let jobs = vec![
        JobSpec::training(0, "train", 1, 0.0, 1, 0.9, 0.2, num_env, iterations),
        JobSpec::serving(1, "burst", 9, 0.05, (1, 1, 1), 0.5, 16, 50e-3, trace),
    ];
    let cfg = SchedConfig { quantum_s: 0.05, ..Default::default() };
    let r = run_cluster(&topo, &b, &cost, &jobs, &cfg).unwrap();
    let train = r.job(0).unwrap();
    assert!(train.preemptions >= 1, "trainer was never preempted");
    assert!(train.restores >= 1, "trainer was never restored");
    // Env-step conservation: iterations x horizon x num_env x members,
    // charged exactly once across the preempt -> restore boundary.
    let expected = (iterations * 16 * num_env) as f64;
    let charged = train.metrics.steps_per_sec * train.metrics.span_s;
    assert!(
        ((charged - expected) / expected).abs() < 1e-9,
        "env steps {charged} vs expected {expected}: work re-charged or lost"
    );
    assert_eq!(
        r.events
            .iter()
            .filter(|e| e.job == 0 && e.action == SchedAction::Complete)
            .count(),
        1,
        "job completed more than once"
    );
    assert!((train.share_at_completion - 0.9).abs() < 1e-9);
    // The burst's requests were each served exactly once too.
    let serve = r.job(1).unwrap().metrics.latency.clone().unwrap();
    assert_eq!(serve.served, serve.requests);
}

#[test]
fn four_kind_corun_respects_cluster_invariants() {
    // Training + open-loop serving + A3C + closed-loop collection on one
    // shared 2-GPU cluster: everything completes, nothing oversubscribes,
    // every serving request is dispatched exactly once.
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    let trace = generate_trace(&TrafficPattern::Poisson { rate: 3000.0 }, 0.12, 17, 4);
    let jobs = vec![
        JobSpec::training(0, "train", 1, 0.0, 2, 0.4, 0.1, 512, 5),
        JobSpec::serving(1, "serve", 9, 0.0, (1, 2, 3), 0.25, 16, 20e-3, trace),
        JobSpec::a3c(
            2,
            "a3c",
            5,
            0.04,
            (1, 1),
            0.3,
            0.1,
            1024,
            AsyncConfig { rounds: 4, batch_samples: 4096, ..AsyncConfig::default() },
        ),
        JobSpec::closed(3, "collect", 2, 0.08, 1, 0.2, 0.1, 512, 4),
    ];
    let r = run_cluster(&topo, &b, &cost, &jobs, &SchedConfig::default()).unwrap();
    assert!(r.peak_gpu_share <= 1.0 + 1e-6, "peak share {}", r.peak_gpu_share);
    assert!(r.fairness > 0.0 && r.fairness <= 1.0 + 1e-12);
    for j in &r.jobs {
        assert!(j.completed_s > j.admitted_s - 1e-12, "job {} never completed", j.id);
        assert!(j.busy_s > 0.0, "job {} never computed", j.id);
    }
    let serve = r.job(1).unwrap().metrics.latency.clone().unwrap();
    assert_eq!(serve.served, serve.requests, "dropped or duplicated requests");
    assert_eq!(r.job(2).unwrap().kind, "async");
    assert_eq!(r.job(3).unwrap().kind, "closed");
    assert!(r.job(2).unwrap().metrics.ttop > 0.0, "a3c trainers never trained");
}
