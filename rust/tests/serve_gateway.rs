//! End-to-end serving-gateway integration: the SLO autoscaler against a
//! static fleet on identical seeded traces (the headline claim), and the
//! diurnal grow-then-shrink cycle the example prints.

use gmi_drl::cluster::Topology;
use gmi_drl::config::static_registry;
use gmi_drl::drl::Compute;
use gmi_drl::engine::Engine;
use gmi_drl::fabric::Fabric;
use gmi_drl::mapping::build_gateway_fleet;
use gmi_drl::serve::{
    batch_seconds, generate_trace, run_gateway, AutoscaleConfig, GatewayConfig, ScaleAction,
    TrafficPattern,
};
use gmi_drl::vtime::CostModel;
use gmi_drl::workload::{GatewayProgram, StepCtx, StepOutcome, Workload};

#[test]
fn autoscaled_fleet_beats_static_fleet_on_the_same_burst() {
    let bench = static_registry()["AT"].clone();
    let cost = CostModel::new(&bench);
    // Two GPUs so scale-up spreads over independent host links.
    let topo = Topology::dgx_a100(2);
    let batch = 32;
    let initial = 1; // per GPU
    let max_per = 4;
    let share = (100.0 / max_per as f64).floor() / 100.0;
    let serial = batch_seconds(&bench, &cost, &topo, share, batch);
    let static_cap = (2 * initial) as f64 * batch as f64 / serial;

    // Base load well under the static fleet, a burst at 2.5x its capacity.
    let pattern = TrafficPattern::Burst {
        base: 0.3 * static_cap,
        burst: 2.5 * static_cap,
        start_s: 0.1,
        len_s: 0.1,
    };
    let trace = generate_trace(&pattern, 0.35, 42, 8);
    assert!(trace.len() > 1000, "burst trace unexpectedly small");

    let slo_s = 8e-3;
    let cfg_static = GatewayConfig {
        max_batch: batch,
        max_wait_s: 1e-3,
        admission_cap: None,
        slo_s,
        autoscale: None,
        ..GatewayConfig::default()
    };
    let mut cfg_auto = cfg_static.clone();
    cfg_auto.autoscale = Some(AutoscaleConfig {
        window_s: 0.01,
        slo_p99_s: slo_s,
        // Floor at the initial fleet: the comparison isolates scale-UP.
        min_fleet: 2 * initial,
        max_per_gpu: max_per,
        ..Default::default()
    });

    let fleet_s = build_gateway_fleet(&topo, initial, max_per, batch, &cost, None).unwrap();
    let fleet_a = build_gateway_fleet(&topo, initial, max_per, batch, &cost, None).unwrap();
    let s = run_gateway(&fleet_s, &bench, &cost, &trace, &cfg_static).unwrap();
    let a = run_gateway(&fleet_a, &bench, &cost, &trace, &cfg_auto).unwrap();

    // Identical work: every request of the shared trace served, none
    // rejected, in both runs.
    assert_eq!(s.latency.served, trace.len());
    assert_eq!(a.latency.served, trace.len());
    assert_eq!(s.rejected, 0);
    assert_eq!(a.rejected, 0);

    // The scaler actually grew under the burst...
    let grows = a
        .scale_events
        .iter()
        .filter(|e| e.action == ScaleAction::Grow)
        .count();
    assert!(grows >= 1, "autoscaler never grew under a 2.5x burst");
    assert!(
        a.final_fleet.len() >= fleet_a.rollout_gmis.len(),
        "fleet shrank below its floor"
    );

    // ...and the grown fleet is strictly better on both SLO metrics.
    assert!(
        a.latency.p99_s < s.latency.p99_s,
        "autoscaled p99 {:.4}s !< static p99 {:.4}s",
        a.latency.p99_s,
        s.latency.p99_s
    );
    assert!(
        a.latency.attainment > s.latency.attainment,
        "autoscaled attainment {:.4} !> static {:.4}",
        a.latency.attainment,
        s.latency.attainment
    );
    // The static fleet really was in SLO trouble (the burst mattered).
    assert!(
        s.latency.p99_s > slo_s,
        "static fleet never violated: p99 {:.4}s",
        s.latency.p99_s
    );
}

#[test]
fn diurnal_day_produces_grow_and_shrink_events() {
    // The example's scenario: a diurnal swing whose peak overloads the
    // initial fleet and whose trough leaves it over-provisioned — the
    // scaling timeline must contain at least one grow AND one shrink.
    let bench = static_registry()["AT"].clone();
    let cost = CostModel::new(&bench);
    let topo = Topology::dgx_a100(2);
    let batch = 32;
    let max_per = 4;
    let share = (100.0 / max_per as f64).floor() / 100.0;
    let serial = batch_seconds(&bench, &cost, &topo, share, batch);
    let static_cap = 2.0 * batch as f64 / serial; // 1 GMI/GPU initially

    let pattern = TrafficPattern::Diurnal {
        base: 0.25 * static_cap,
        peak: 2.2 * static_cap,
        period_s: 0.5,
    };
    let trace = generate_trace(&pattern, 0.5, 7, 16);

    let slo_s = 10e-3;
    let cfg = GatewayConfig {
        max_batch: batch,
        max_wait_s: 1e-3,
        admission_cap: None,
        slo_s,
        autoscale: Some(AutoscaleConfig {
            window_s: 0.02,
            slo_p99_s: slo_s,
            min_fleet: 2,
            max_per_gpu: max_per,
            ..Default::default()
        }),
        ..GatewayConfig::default()
    };
    let fleet = build_gateway_fleet(&topo, 1, max_per, batch, &cost, None).unwrap();
    let r = run_gateway(&fleet, &bench, &cost, &trace, &cfg).unwrap();

    let grows = r
        .scale_events
        .iter()
        .filter(|e| e.action == ScaleAction::Grow)
        .count();
    let shrinks = r
        .scale_events
        .iter()
        .filter(|e| e.action == ScaleAction::Shrink)
        .count();
    assert!(grows >= 1, "no grow event over the diurnal peak");
    assert!(shrinks >= 1, "no shrink event over the diurnal trough");
    // Growth precedes the matching shrink (ramp up at the peak, give back
    // after it).
    let first_grow = r
        .scale_events
        .iter()
        .position(|e| e.action == ScaleAction::Grow)
        .unwrap();
    let last_shrink = r
        .scale_events
        .iter()
        .rposition(|e| e.action == ScaleAction::Shrink)
        .unwrap();
    assert!(last_shrink > first_grow, "no give-back after the peak");
    assert_eq!(r.latency.served, trace.len());
}

#[test]
fn pooled_hot_buffers_do_not_regrow_after_warmup() {
    // The gateway's per-round state (pending queue, completion heap,
    // latency scratch, pooled fabric plans) must reach steady-state
    // capacity during warmup and then stay put: a steady-load round
    // performs zero heap growth. Catches any future edit that reintroduces
    // a per-dispatch allocation (e.g. building a fresh `Plan` per batch).
    let bench = static_registry()["AT"].clone();
    let cost = CostModel::new(&bench);
    let topo = Topology::dgx_a100(1);
    let batch = 16;
    let max_per = 4;
    let share = (100.0 / max_per as f64).floor() / 100.0;
    let serial = batch_seconds(&bench, &cost, &topo, share, batch);
    // Two members on one GPU, loaded at half capacity: queues stay
    // bounded, and constant (evenly spaced) arrivals make every round
    // after warmup look like every other.
    let fleet_cap = 2.0 * batch as f64 / serial;
    let rate = 0.5 * fleet_cap;
    let quantum = 1e-3;
    let warmup = 300usize;
    let measured = 1000usize;
    // Arrivals must outlast the measured window so the program stays
    // Pending throughout (1500 rounds of trace vs 1300 stepped).
    let trace = generate_trace(&TrafficPattern::Constant { rate }, 1.5, 3, 4);
    assert!(trace.len() > 1000, "constant trace unexpectedly small");

    let cfg = GatewayConfig {
        max_batch: batch,
        max_wait_s: 1e-3,
        admission_cap: None,
        slo_s: 30e-3,
        autoscale: None,
        ..GatewayConfig::default()
    };
    let fleet = build_gateway_fleet(&topo, 2, max_per, batch, &cost, None).unwrap();
    let mut engine = Engine::new(&fleet.manager, &cost);
    let mut fabric = Fabric::single_node(fleet.manager.topology().clone());
    let active = engine.add_group(&fleet.rollout_gmis).unwrap();

    let mut program = GatewayProgram::new(cfg, trace);
    program.bind(&engine, &mut fabric, &bench, &active).unwrap();

    let compute = Compute::Null;
    for round in 0..warmup {
        let mut ctx = StepCtx {
            engine: &mut engine,
            fabric: &mut fabric,
            cost: &cost,
            bench: &bench,
            compute: &compute,
            horizon_s: (round + 1) as f64 * quantum,
        };
        let out = program.step(&mut ctx).unwrap();
        assert_eq!(out, StepOutcome::Pending, "trace drained during warmup");
    }

    let caps = program.hot_buffer_caps();
    // The pools are real: requests queued, batches dispatched, plans
    // materialized.
    assert!(caps[0] > 0, "pending queue never held a request");
    assert!(caps[2] > 0, "latency scratch never recorded a dispatch");
    assert!(caps[4] > 0 && caps[5] > 0, "pooled plans never materialized");

    for round in warmup..warmup + measured {
        let mut ctx = StepCtx {
            engine: &mut engine,
            fabric: &mut fabric,
            cost: &cost,
            bench: &bench,
            compute: &compute,
            horizon_s: (round + 1) as f64 * quantum,
        };
        let out = program.step(&mut ctx).unwrap();
        assert_eq!(
            out,
            StepOutcome::Pending,
            "trace drained inside the measured window at round {round}"
        );
        assert_eq!(
            program.hot_buffer_caps(),
            caps,
            "a pooled hot-path buffer regrew at round {round}"
        );
    }
}
