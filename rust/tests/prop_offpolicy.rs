//! Property tests for the off-policy workloads and the tenant-churn
//! scheduler paths they stress: the replay-buffer learner (collector ->
//! channel -> buffer -> learner pipeline) and the self-play league
//! coordinator (dynamic match tenants through the normal admission path).
//!
//! Same methodology as the other `prop_*` suites: deterministic scenarios
//! (the offline build has no proptest crate), each asserting an invariant
//! that must hold under churn:
//!
//!   1. transitions are conserved exactly across a fault kill and the
//!      checkpoint restore — lost in-flight samples are re-done, never
//!      dropped and never double-counted;
//!   2. sample staleness is finite and bounded by the run's span, and the
//!      empty-buffer learner path is NaN-free;
//!   3. the league matchmaker is fair (per-player match counts within one)
//!      and every match it spawns goes through real admission;
//!   4. a fault kill + re-admission preserves the first admission's queue
//!      wait — the outage is reported as recovery, not re-queueing
//!      (regression for the wait_s/recovery_s conflation).

use gmi_drl::cluster::Topology;
use gmi_drl::config::static_registry;
use gmi_drl::drl::Compute;
use gmi_drl::fault::{FaultPlan, FaultTrace};
use gmi_drl::mapping::build_async_layout;
use gmi_drl::sched::{run_cluster, JobSpec, SchedAction, SchedConfig};
use gmi_drl::vtime::CostModel;
use gmi_drl::workload::replay::run_replay;
use gmi_drl::workload::{LeagueConfig, ReplayConfig};

fn bench() -> gmi_drl::BenchInfo {
    static_registry()["AY"].clone()
}

/// A replay tenant whose three members (2 collectors + 1 learner) cannot
/// fit on one GPU (3 x 0.45 share > 1.0), so placement must spread it and
/// the GPU-1 failure is guaranteed to kill it.
fn spread_replay_spec(rounds: usize) -> JobSpec {
    JobSpec::replay(
        0,
        "replay",
        5,
        0.0,
        2,
        0.45,
        0.2,
        1024,
        ReplayConfig { rounds, ..ReplayConfig::default() },
    )
}

/// GPU 1 dies mid-run and is repaired shortly after; periodic checkpoints
/// let the killed tenant resume from stored state.
fn outage_cfg() -> SchedConfig {
    let trace = FaultTrace::parse("0.03 fail gpu 1\n0.05 repair gpu 1", 1).unwrap();
    SchedConfig {
        faults: Some(FaultPlan::new(trace).with_checkpoint_interval(0.02)),
        ..SchedConfig::default()
    }
}

#[test]
fn replay_transitions_are_conserved_across_kill_and_restore() {
    // The collection schedule is fixed by the config: every round, every
    // collector dispenses m whole env-steps of n_env transitions each.
    // A mid-run GPU loss kills the tenant; the restore re-does whatever
    // the checkpoint had not yet captured. The delivered-transition count
    // must come out EXACT — not "at least" (nothing dropped) and not
    // "more" (nothing double-counted by the redo).
    let b = bench();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    let spec = spread_replay_spec(20);
    let r = run_cluster(&topo, &b, &cost, &[spec], &outage_cfg()).unwrap();
    assert_eq!(r.fault_events, 2);
    let j = r.job(0).unwrap();
    assert!(j.kills >= 1, "the GPU loss must kill the spread tenant");
    assert!(r.events.iter().any(|e| e.action == SchedAction::Kill));
    assert!(j.completed_s > 0.0, "killed tenant never resumed to completion");
    assert!(j.checkpoint_s > 0.0, "no checkpoint cost was charged before the kill");

    let stats = j.metrics.replay.as_ref().expect("replay stats present");
    let cfg = ReplayConfig::default();
    let (rounds, collectors, n_env) = (20, 2, 1024);
    let m = (cfg.push_samples / n_env).max(1);
    assert_eq!(
        stats.transitions_in,
        rounds * collectors * m * n_env,
        "transitions not conserved across kill + restore"
    );
    assert!(stats.updates > 0, "learner never applied an update");
}

#[test]
fn replay_staleness_is_bounded_and_nan_free() {
    // Round 0 runs the learner pass before any collection, so the
    // empty-buffer path is exercised on every run — it must count empty
    // ticks and keep every staleness/pressure statistic finite (the
    // historical failure mode is 0/0 -> NaN on the empty buffer).
    let b = bench();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    let layout = build_async_layout(&topo, 1, 2, 1, 2048, &cost).unwrap();
    let cfg = ReplayConfig { rounds: 8, ..ReplayConfig::default() };
    let r = run_replay(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
    let stats = r.metrics.replay.as_ref().expect("replay stats present");
    assert!(stats.empty_ticks >= 1, "round-0 learner pass must hit the empty buffer");
    assert!(stats.updates > 0 && stats.transitions_sampled > 0, "learner never sampled");
    for (name, v) in [
        ("mean_staleness_s", stats.mean_staleness_s),
        ("max_staleness_s", stats.max_staleness_s),
        ("mean_pressure", stats.mean_pressure),
        ("peak_pressure", stats.peak_pressure),
    ] {
        assert!(v.is_finite(), "{name} is not finite: {v}");
    }
    assert!(stats.mean_staleness_s >= 0.0);
    assert!(stats.mean_staleness_s <= stats.max_staleness_s);
    assert!(
        stats.max_staleness_s <= r.metrics.span_s,
        "a sampled transition cannot be older than the run itself ({} > {})",
        stats.max_staleness_s,
        r.metrics.span_s
    );
    assert!(stats.mean_pressure >= 0.0 && stats.mean_pressure <= stats.peak_pressure);
    assert!(stats.peak_pressure <= 1.0, "buffer exceeded its memory budget");
    assert!(r.metrics.final_reward.is_finite());
}

#[test]
fn league_matchmaker_is_fair_and_spawns_through_admission() {
    let b = bench();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(1);
    let cfg = LeagueConfig {
        players: 4,
        total_matches: 8,
        max_concurrent: 2,
        match_rounds: 2,
        match_num_env: 256,
        match_share: 0.2,
        match_priority: 3,
        seed: 11,
    };
    let spec = JobSpec::league(0, "league", 5, 0.0, 0.2, cfg.clone());
    let r = run_cluster(&topo, &b, &cost, &[spec], &SchedConfig::default()).unwrap();

    // Every match exists as a real tenant: spawned, admitted, completed.
    assert_eq!(r.jobs.len(), 1 + cfg.total_matches);
    let spawns = r.events.iter().filter(|e| e.action == SchedAction::Spawn).count();
    assert_eq!(spawns, cfg.total_matches);
    let mut names: Vec<&str> = Vec::new();
    for j in &r.jobs {
        if j.id == 0 {
            assert_eq!(j.kind, "league");
            continue;
        }
        assert_eq!(j.kind, "closed", "match tenants are closed-loop jobs");
        assert!(j.completed_s > 0.0, "match {} never completed", j.id);
        assert!(
            r.events
                .iter()
                .any(|e| e.action == SchedAction::Admit && e.job == j.id),
            "match {} was never admitted through the normal path",
            j.id
        );
        names.push(&j.name);
    }
    names.sort_unstable();
    let mut expected: Vec<String> =
        (0..cfg.total_matches).map(|k| format!("match{k}")).collect();
    expected.sort_unstable();
    assert_eq!(names, expected, "spawned matches are not the scheduled set");

    // Fairness of the circle schedule: over any prefix, per-player match
    // counts stay within one of each other.
    for prefix in 1..=cfg.total_matches {
        let mut counts = vec![0usize; cfg.players];
        for k in 0..prefix {
            let (a, bb) = cfg.pairing(k as u64);
            counts[a] += 1;
            counts[bb] += 1;
        }
        let lo = *counts.iter().min().unwrap();
        let hi = *counts.iter().max().unwrap();
        assert!(
            hi - lo <= 1,
            "unfair matchmaking after {prefix} matches: counts {counts:?}"
        );
    }

    // The coordinator reported a win-rate table (one curve point per
    // player) built from real match outcomes.
    let coord = r.job(0).unwrap();
    assert_eq!(coord.metrics.reward_curve.len(), cfg.players);
    assert!(coord.metrics.final_reward > 0.0, "nobody ever won a match");
}

#[test]
fn kill_preserves_first_admission_wait_and_reports_recovery_separately() {
    // Regression: a tenant admitted at arrival (wait 0) that is killed by
    // a hardware failure and re-admitted after the repair must still
    // report zero queue wait — the time spent waiting out the outage is
    // recovery_s, not wait_s. Conflating the two made faulted days look
    // like admission-queue congestion.
    let b = bench();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    let spec = spread_replay_spec(20);
    let r = run_cluster(&topo, &b, &cost, &[spec], &outage_cfg()).unwrap();
    let j = r.job(0).unwrap();
    assert!(j.kills >= 1, "the GPU loss must kill the spread tenant");
    assert!(j.completed_s > 0.0);
    let readmit = r
        .events
        .iter()
        .find(|e| e.action == SchedAction::Admit && e.detail.contains("re-admitted"))
        .expect("no re-admission event after the repair");
    assert!(readmit.t_s > 0.0);
    assert_eq!(j.wait_s, 0.0, "outage time leaked into queue wait");
    assert_eq!(j.admitted_s, 0.0, "re-admission overwrote the first admission time");
    assert!(
        j.recovery_s > 0.0,
        "the kill-to-resume outage must be accounted as recovery"
    );
}
