//! Integration: compute/communication overlap through the fabric
//! (ISSUE 2 acceptance): on a multi-GPU layout, sync training with
//! overlapped allreduce finishes strictly faster than the sequential
//! (PR 1-style) schedule, with bit-identical reduced gradients — the
//! schedule moves, the arithmetic doesn't.

use gmi_drl::cluster::Topology;
use gmi_drl::comm::ReduceStrategy;
use gmi_drl::config::static_registry;
use gmi_drl::drl::sync::{run_sync, SyncConfig};
use gmi_drl::drl::Compute;
use gmi_drl::mapping::{build_sync_layout, MappingTemplate};
use gmi_drl::vtime::CostModel;

fn setup(gpus: usize, t: usize) -> (gmi_drl::mapping::Layout, gmi_drl::BenchInfo, CostModel) {
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(gpus);
    let layout =
        build_sync_layout(&topo, MappingTemplate::TaskColocated, t, 1024, &cost, None).unwrap();
    (layout, b, cost)
}

#[test]
fn overlapped_allreduce_beats_sequential_schedule() {
    let (layout, b, cost) = setup(4, 4);
    let mk = |overlap| SyncConfig { iterations: 6, overlap, ..Default::default() };
    let seq = run_sync(&layout, &b, &cost, &Compute::Null, &mk(false)).unwrap();
    let ovl = run_sync(&layout, &b, &cost, &Compute::Null, &mk(true)).unwrap();

    // Strictly faster: the reductions drain on the fabric links while the
    // trainers compute the next minibatch / the next rollout.
    assert!(
        ovl.metrics.span_s < seq.metrics.span_s,
        "overlap {} must beat sequential {}",
        ovl.metrics.span_s,
        seq.metrics.span_s
    );
    assert!(ovl.metrics.steps_per_sec > seq.metrics.steps_per_sec);

    // Bit-identical numerics: same strategy, same gradients, same final
    // parameters (the schedule does not touch the arithmetic).
    assert_eq!(ovl.strategy, seq.strategy);
    assert_eq!(ovl.final_params, seq.final_params);
    assert!(!ovl.final_params.is_empty());

    // Same traffic crossed the same links — only the timing changed.
    let bytes = |r: &gmi_drl::drl::sync::SyncRunResult| -> Vec<(String, u64)> {
        r.metrics.links.iter().map(|l| (l.name.clone(), l.bytes)).collect()
    };
    assert_eq!(bytes(&ovl), bytes(&seq));
}

#[test]
fn overlap_gains_across_strategies_and_layouts() {
    // The gain must hold for every pinned strategy that is valid on the
    // layout, not just the planner's pick.
    for (gpus, t, strategy) in [
        (2usize, 2usize, ReduceStrategy::MultiRing),
        (4, 4, ReduceStrategy::Hierarchical),
        (4, 4, ReduceStrategy::MultiProcess),
    ] {
        let (layout, b, cost) = setup(gpus, t);
        let mk = |overlap| SyncConfig {
            iterations: 4,
            strategy_override: Some(strategy),
            overlap,
            ..Default::default()
        };
        let seq = run_sync(&layout, &b, &cost, &Compute::Null, &mk(false)).unwrap();
        let ovl = run_sync(&layout, &b, &cost, &Compute::Null, &mk(true)).unwrap();
        assert!(
            ovl.metrics.span_s < seq.metrics.span_s,
            "{gpus}G{t}T {strategy}: overlap {} vs sequential {}",
            ovl.metrics.span_s,
            seq.metrics.span_s
        );
        assert_eq!(ovl.final_params, seq.final_params, "{gpus}G{t}T {strategy}");
    }
}

#[test]
fn overlap_preserves_learning_signal() {
    // The reward curve (what the run learned, when) is identical in reward
    // values; only the virtual timestamps shift earlier.
    let (layout, b, cost) = setup(2, 2);
    let mk = |overlap| SyncConfig { iterations: 5, overlap, ..Default::default() };
    let seq = run_sync(&layout, &b, &cost, &Compute::Null, &mk(false)).unwrap();
    let ovl = run_sync(&layout, &b, &cost, &Compute::Null, &mk(true)).unwrap();
    assert_eq!(seq.metrics.reward_curve.len(), ovl.metrics.reward_curve.len());
    for ((ts, rs), (to, ro)) in seq.metrics.reward_curve.iter().zip(&ovl.metrics.reward_curve) {
        assert_eq!(rs, ro, "reward values must not change");
        assert!(to <= ts + 1e-12, "overlapped timestamps must not be later");
    }
}
