//! Integration tests over REAL artifacts (skipped when `make artifacts`
//! hasn't run): the full three-layer stack — Pallas-kernel policies inside
//! JAX-lowered HLO, executed by the rust coordinator on PJRT-CPU.

use gmi_drl::cluster::Topology;
use gmi_drl::config::{artifacts_dir, Manifest};
use gmi_drl::drl::sync::{run_sync, SyncConfig};
use gmi_drl::drl::Compute;
use gmi_drl::mapping::{build_sync_layout, MappingTemplate};
use gmi_drl::runtime::ExecServer;
use gmi_drl::vtime::CostModel;

fn setup() -> Option<(Manifest, ExecServer)> {
    let dir = artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let server = ExecServer::start(dir).unwrap();
    Some((manifest, server))
}

#[test]
fn sync_training_replicas_stay_consistent() {
    // Data-parallel invariant: after every LGR allreduce + apply, every
    // replica holds bit-identical parameters.
    let Some((manifest, server)) = setup() else { return };
    let bench = manifest.bench("BB").unwrap().clone();
    let cost = CostModel::new(&bench);
    let topo = Topology::dgx_a100(2);
    let layout = build_sync_layout(
        &topo,
        MappingTemplate::TaskColocated,
        2,
        bench.num_env,
        &cost,
        None,
    )
    .unwrap();
    let compute = Compute::Real { handle: server.handle() };
    let cfg = SyncConfig {
        iterations: 3,
        real_replicas: 2, // two INDEPENDENT real workers
        ..Default::default()
    };
    let r = run_sync(&layout, &bench, &cost, &compute, &cfg).unwrap();
    assert!(r.metrics.steps_per_sec > 0.0);
    for s in &r.stats_per_iter {
        assert!(s.loss.is_finite(), "loss diverged: {}", s.loss);
    }
    // Determinism with independent replicas: the two-replica reduced-
    // gradient trajectory must replay exactly.
    let r2 = run_sync(&layout, &bench, &cost, &compute, &cfg).unwrap();
    assert_eq!(
        r.final_params, r2.final_params,
        "two-replica trajectory is not deterministic"
    );
    // And it must differ from the single-replica (mirrored) trajectory —
    // i.e. the second replica's gradient really entered the allreduce.
    let cfg1 = SyncConfig { real_replicas: 1, ..cfg.clone() };
    let r1 = run_sync(&layout, &bench, &cost, &compute, &cfg1).unwrap();
    assert_ne!(
        r.final_params, r1.final_params,
        "replica 1's gradient never reached the reduction"
    );
}

#[test]
fn sync_training_is_deterministic_in_seed() {
    let Some((manifest, server)) = setup() else { return };
    let bench = manifest.bench("BB").unwrap().clone();
    let cost = CostModel::new(&bench);
    let topo = Topology::dgx_a100(1);
    let layout =
        build_sync_layout(&topo, MappingTemplate::TaskColocated, 2, bench.num_env, &cost, None)
            .unwrap();
    let compute = Compute::Real { handle: server.handle() };
    let cfg = SyncConfig { iterations: 2, seed: 42, ..Default::default() };
    let a = run_sync(&layout, &bench, &cost, &compute, &cfg).unwrap();
    let b = run_sync(&layout, &bench, &cost, &compute, &cfg).unwrap();
    assert_eq!(a.final_params, b.final_params);
    let cfg2 = SyncConfig { seed: 43, ..cfg };
    let c = run_sync(&layout, &bench, &cost, &compute, &cfg2).unwrap();
    assert_ne!(a.final_params, c.final_params);
}

#[test]
fn training_reduces_loss_on_bb() {
    // Short real PPO run: value loss should drop as the critic fits.
    let Some((manifest, server)) = setup() else { return };
    let bench = manifest.bench("BB").unwrap().clone();
    let cost = CostModel::new(&bench);
    let topo = Topology::dgx_a100(1);
    let layout =
        build_sync_layout(&topo, MappingTemplate::TaskColocated, 1, bench.num_env, &cost, None)
            .unwrap();
    let compute = Compute::Real { handle: server.handle() };
    let cfg = SyncConfig { iterations: 12, lr: 1e-3, ..Default::default() };
    let r = run_sync(&layout, &bench, &cost, &compute, &cfg).unwrap();
    let first: f32 = r.stats_per_iter[..3].iter().map(|s| s.v_loss).sum::<f32>() / 3.0;
    let last: f32 = r.stats_per_iter[9..].iter().map(|s| s.v_loss).sum::<f32>() / 3.0;
    assert!(
        last < first,
        "critic did not learn: v_loss {first} -> {last}"
    );
}

#[test]
fn manifest_matches_rust_param_count() {
    // Guard: python model.num_params and rust config::param_count agree.
    let Some((manifest, _server)) = setup() else { return };
    for (abbr, b) in &manifest.benchmarks {
        let rust_count = gmi_drl::config::param_count(b.obs_dim, b.act_dim, &b.hidden);
        assert_eq!(
            rust_count, b.num_params,
            "{abbr}: rust {rust_count} vs manifest {}",
            b.num_params
        );
    }
}
