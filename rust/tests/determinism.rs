//! Determinism golden tests: the discrete-event engine is a simulator, so
//! the same seed + config must reproduce *bit-identical* `RunMetrics`
//! (span, rates, percentiles, per-link traffic) across runs — replayability
//! is what makes traces, regressions, and the autoscaler's decisions
//! debuggable. Any nondeterminism (map iteration order, uninitialized
//! accumulation order, wall-clock leakage) fails here first.

use gmi_drl::cluster::Topology;
use gmi_drl::config::static_registry;
use gmi_drl::drl::a3c::{run_async, AsyncConfig};
use gmi_drl::drl::serving::{run_serving, ServingConfig};
use gmi_drl::drl::sync::{run_sync, SyncConfig};
use gmi_drl::drl::Compute;
use gmi_drl::engine::ElasticConfig;
use gmi_drl::fault::{FaultPlan, FaultTrace};
use gmi_drl::mapping::{
    build_async_layout, build_gateway_fleet, build_serving_layout, build_sync_layout,
    MappingTemplate,
};
use gmi_drl::metrics::RunMetrics;
use gmi_drl::sched::{
    corun_scenario, offpolicy_corun_scenario, run_cluster, week_scenario, ClusterRunResult,
    FastForward, JobSpec, SchedConfig, WeekOpts,
};
use gmi_drl::workload::league::run_league;
use gmi_drl::workload::replay::run_replay;
use gmi_drl::workload::{Eviction, LeagueConfig, ReplayConfig};
use gmi_drl::gmi::GmiBackend;
use gmi_drl::serve::{generate_trace, run_gateway, AutoscaleConfig, GatewayConfig, TrafficPattern};
use gmi_drl::tune::{tune_gateway, tune_sync, GatewaySpace, SyncSpace, TuneConfig};
use gmi_drl::vtime::CostModel;

fn bits(x: f64) -> u64 {
    x.to_bits()
}

/// FNV-1a over a stream of u64s: the fingerprint the pinned golden stores.
struct Fingerprint(u64);

impl Fingerprint {
    fn new() -> Self {
        Fingerprint(0xcbf2_9ce4_8422_2325)
    }
    fn fold(&mut self, v: u64) {
        for byte in v.to_le_bytes() {
            self.0 ^= byte as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    fn fold_f64(&mut self, v: f64) {
        self.fold(v.to_bits());
    }
}

/// Bit-exact equality over every RunMetrics field.
fn assert_metrics_identical(a: &RunMetrics, b: &RunMetrics, what: &str) {
    assert_eq!(bits(a.steps_per_sec), bits(b.steps_per_sec), "{what}: steps_per_sec");
    assert_eq!(bits(a.pps), bits(b.pps), "{what}: pps");
    assert_eq!(bits(a.ttop), bits(b.ttop), "{what}: ttop");
    assert_eq!(bits(a.span_s), bits(b.span_s), "{what}: span_s");
    assert_eq!(bits(a.utilization), bits(b.utilization), "{what}: utilization");
    assert_eq!(bits(a.final_reward), bits(b.final_reward), "{what}: final_reward");
    assert_eq!(bits(a.comm_s), bits(b.comm_s), "{what}: comm_s");
    assert_eq!(bits(a.peak_mem_gib), bits(b.peak_mem_gib), "{what}: peak_mem_gib");
    assert_eq!(a.reward_curve.len(), b.reward_curve.len(), "{what}: curve len");
    for (i, (x, y)) in a.reward_curve.iter().zip(&b.reward_curve).enumerate() {
        assert_eq!(bits(x.0), bits(y.0), "{what}: curve[{i}].t");
        assert_eq!(bits(x.1), bits(y.1), "{what}: curve[{i}].r");
    }
    assert_eq!(a.links.len(), b.links.len(), "{what}: link count");
    for (x, y) in a.links.iter().zip(&b.links) {
        assert_eq!(x.name, y.name, "{what}: link name");
        assert_eq!(x.bytes, y.bytes, "{what}: link bytes {}", x.name);
        assert_eq!(bits(x.busy_s), bits(y.busy_s), "{what}: link busy {}", x.name);
    }
    // LatencyStats is PartialEq over plain fields; identical runs must
    // produce the identical distribution.
    assert_eq!(a.latency, b.latency, "{what}: latency stats");
    // ReplayStats likewise: buffer ledger, staleness, and pressure must
    // replay exactly.
    assert_eq!(a.replay, b.replay, "{what}: replay stats");
}

#[test]
fn sync_training_is_bit_identical_across_runs() {
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    let layout =
        build_sync_layout(&topo, MappingTemplate::TaskColocated, 2, 1024, &cost, None).unwrap();
    let cfg = SyncConfig { iterations: 4, ..Default::default() };
    let r1 = run_sync(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
    let r2 = run_sync(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
    assert_metrics_identical(&r1.metrics, &r2.metrics, "sync TCG");
    assert_eq!(r1.final_params, r2.final_params, "sync params drifted");

    // The elastic controller's decisions are part of the replay too.
    let tdg =
        build_sync_layout(&topo, MappingTemplate::TaskDedicated, 3, 1024, &cost, None).unwrap();
    let ecfg = SyncConfig {
        iterations: 4,
        elastic: Some(ElasticConfig::default()),
        ..Default::default()
    };
    let e1 = run_sync(&tdg, &b, &cost, &Compute::Null, &ecfg).unwrap();
    let e2 = run_sync(&tdg, &b, &cost, &Compute::Null, &ecfg).unwrap();
    assert_metrics_identical(&e1.metrics, &e2.metrics, "sync TDG elastic");
    assert_eq!(e1.elastic_shifts, e2.elastic_shifts);
}

#[test]
fn a3c_training_is_bit_identical_across_runs() {
    let b = static_registry()["AY"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    let layout = build_async_layout(&topo, 1, 3, 2, 2048, &cost).unwrap();
    let cfg = AsyncConfig { rounds: 6, ..Default::default() };
    let r1 = run_async(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
    let r2 = run_async(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
    assert_metrics_identical(&r1.metrics, &r2.metrics, "a3c");
    assert_eq!(r1.updates, r2.updates);
    assert_eq!(r1.channel_stats.packets_out, r2.channel_stats.packets_out);
}

#[test]
fn replay_training_is_bit_identical_across_runs() {
    // Reservoir eviction draws from the seeded stream on every full-buffer
    // insert, and the learner's minibatch draws interleave with it — the
    // whole off-policy pipeline must still replay exactly.
    let b = static_registry()["AY"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    let layout = build_async_layout(&topo, 1, 2, 1, 2048, &cost).unwrap();
    let cfg = ReplayConfig {
        rounds: 6,
        eviction: Eviction::Reservoir,
        buffer_gib: 0.002,
        ..ReplayConfig::default()
    };
    let r1 = run_replay(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
    let r2 = run_replay(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
    assert_metrics_identical(&r1.metrics, &r2.metrics, "replay");
    assert_eq!(r1.updates, r2.updates);
    assert_eq!(r1.channel_stats.packets_out, r2.channel_stats.packets_out);
    let stats = r1.metrics.replay.as_ref().unwrap();
    assert!(stats.evicted > 0, "tiny buffer never evicted: eviction path untested");
}

#[test]
fn serving_is_bit_identical_across_runs() {
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(1);
    let cfg = ServingConfig { rounds: 5, ..Default::default() };
    for template in [MappingTemplate::TaskColocated, MappingTemplate::TaskDedicated] {
        let layout = build_serving_layout(&topo, template, 3, 1024, &cost, None).unwrap();
        let r1 = run_serving(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        let r2 = run_serving(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        assert_metrics_identical(&r1, &r2, &format!("serving {template:?}"));
    }
}

#[test]
fn multi_job_corun_is_bit_identical_across_runs() {
    // The multi-tenant golden: a training + diurnal-serving co-run on one
    // shared cluster replays bit-identically — per-job RunMetrics, the
    // scheduling timeline (every preemption/grow/restore decision), and
    // the cluster-level aggregates.
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    let cfg = SchedConfig::default();
    let jobs1 = corun_scenario(&topo, &b, &cost, 0.4, 11, false);
    let jobs2 = corun_scenario(&topo, &b, &cost, 0.4, 11, false);
    let r1 = run_cluster(&topo, &b, &cost, &jobs1, &cfg).unwrap();
    let r2 = run_cluster(&topo, &b, &cost, &jobs2, &cfg).unwrap();
    assert_eq!(r1.jobs.len(), r2.jobs.len());
    for (a, c) in r1.jobs.iter().zip(&r2.jobs) {
        assert_eq!(a.id, c.id);
        assert_metrics_identical(&a.metrics, &c.metrics, &format!("corun job {}", a.id));
        assert_eq!(bits(a.admitted_s), bits(c.admitted_s), "job {} admitted_s", a.id);
        assert_eq!(bits(a.completed_s), bits(c.completed_s), "job {} completed_s", a.id);
        assert_eq!(bits(a.busy_s), bits(c.busy_s), "job {} busy_s", a.id);
        assert_eq!(
            bits(a.xjob_interference_s),
            bits(c.xjob_interference_s),
            "job {} xjob",
            a.id
        );
        assert_eq!(a.preemptions, c.preemptions, "job {} preemptions", a.id);
        assert_eq!(a.restores, c.restores, "job {} restores", a.id);
    }
    assert_eq!(r1.events, r2.events, "scheduling timeline drifted");
    assert_eq!(bits(r1.makespan_s), bits(r2.makespan_s));
    assert_eq!(bits(r1.cluster_utilization), bits(r2.cluster_utilization));
    assert_eq!(bits(r1.fairness), bits(r2.fairness));
}

#[test]
fn three_kind_corun_is_bit_identical_across_runs() {
    // The Workload-program golden: training + SLO serving + an A3C
    // channel-pipeline tenant co-run on one shared cluster and replay
    // bit-identically — per-job RunMetrics, the full scheduling timeline,
    // and the cluster aggregates.
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    let mk = || {
        let trace = generate_trace(
            &TrafficPattern::Diurnal { base: 2000.0, peak: 8000.0, period_s: 0.3 },
            0.3,
            5,
            4,
        );
        vec![
            JobSpec::training(0, "train", 1, 0.0, 2, 0.4, 0.1, 512, 6),
            JobSpec::serving(1, "serve", 9, 0.0, (1, 2, 3), 0.25, 16, 20e-3, trace),
            JobSpec::a3c(
                2,
                "a3c",
                5,
                0.04,
                (1, 1),
                0.3,
                0.1,
                1024,
                AsyncConfig { rounds: 5, batch_samples: 4096, ..AsyncConfig::default() },
            ),
        ]
    };
    let cfg = SchedConfig::default();
    let r1 = run_cluster(&topo, &b, &cost, &mk(), &cfg).unwrap();
    let r2 = run_cluster(&topo, &b, &cost, &mk(), &cfg).unwrap();
    assert_eq!(r1.jobs.len(), 3);
    for (a, c) in r1.jobs.iter().zip(&r2.jobs) {
        assert_eq!(a.id, c.id);
        assert_eq!(a.kind, c.kind);
        assert_metrics_identical(&a.metrics, &c.metrics, &format!("3-kind job {}", a.id));
        assert_eq!(bits(a.admitted_s), bits(c.admitted_s), "job {} admitted_s", a.id);
        assert_eq!(bits(a.completed_s), bits(c.completed_s), "job {} completed_s", a.id);
        assert_eq!(bits(a.busy_s), bits(c.busy_s), "job {} busy_s", a.id);
        assert_eq!(a.preemptions, c.preemptions, "job {} preemptions", a.id);
        assert_eq!(a.restores, c.restores, "job {} restores", a.id);
    }
    assert_eq!(r1.events, r2.events, "scheduling timeline drifted");
    assert_eq!(bits(r1.makespan_s), bits(r2.makespan_s));
    assert_eq!(bits(r1.fairness), bits(r2.fairness));
    // The async tenant actually ran its pipeline.
    let a3c = r1.job(2).unwrap();
    assert_eq!(a3c.kind, "async");
    assert!(a3c.metrics.ttop > 0.0, "a3c trainers never consumed a batch");
}

#[test]
fn gateway_is_bit_identical_across_runs() {
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(1);

    // Trace generation itself is seed-deterministic.
    let pattern = TrafficPattern::Burst { base: 3000.0, burst: 30000.0, start_s: 0.05, len_s: 0.05 };
    let t1 = generate_trace(&pattern, 0.15, 11, 4);
    let t2 = generate_trace(&pattern, 0.15, 11, 4);
    assert_eq!(t1, t2, "trace generation drifted");

    let cfg = GatewayConfig {
        max_batch: 16,
        max_wait_s: 1e-3,
        admission_cap: Some(4096),
        slo_s: 5e-3,
        autoscale: Some(AutoscaleConfig {
            window_s: 0.01,
            slo_p99_s: 5e-3,
            min_fleet: 2,
            max_per_gpu: 6,
            ..Default::default()
        }),
        ..GatewayConfig::default()
    };
    let l1 = build_gateway_fleet(&topo, 2, 6, 16, &cost, None).unwrap();
    let l2 = build_gateway_fleet(&topo, 2, 6, 16, &cost, None).unwrap();
    let r1 = run_gateway(&l1, &b, &cost, &t1, &cfg).unwrap();
    let r2 = run_gateway(&l2, &b, &cost, &t2, &cfg).unwrap();
    assert_metrics_identical(&r1.metrics, &r2.metrics, "gateway");
    assert_eq!(r1.served.len(), r2.served.len());
    assert_eq!(r1.rejected, r2.rejected);
    assert_eq!(r1.batch_sizes, r2.batch_sizes);
    assert_eq!(r1.scale_events.len(), r2.scale_events.len());
    for (x, y) in r1.scale_events.iter().zip(&r2.scale_events) {
        assert_eq!(x.action, y.action);
        assert_eq!(bits(x.t_s), bits(y.t_s));
        assert_eq!(x.fleet_after, y.fleet_after);
    }
    // Per-request outcomes replay exactly.
    for (x, y) in r1.served.iter().zip(&r2.served) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.batch, y.batch);
        assert_eq!(bits(x.completion_s), bits(y.completion_s));
    }
}

#[test]
fn tuned_sync_run_is_bit_identical_across_runs() {
    // The auto-tuned path end-to-end: tuner decision AND the long run it
    // hands the locked config to must both replay bit-for-bit.
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    let base = SyncConfig { iterations: 20_000, ..SyncConfig::default() };
    let tcfg = TuneConfig::default();
    let tune_once = || {
        tune_sync(
            &topo,
            MappingTemplate::TaskColocated,
            Some(GmiBackend::Mps),
            &b,
            &cost,
            &base,
            (2, 512),
            &SyncSpace::default(),
            &tcfg,
        )
        .unwrap()
    };
    let rep1 = tune_once();
    let rep2 = tune_once();
    assert_eq!(rep1.choice, rep2.choice, "tuner choice drifted");
    assert_eq!(rep1, rep2, "tuner report drifted");

    // Hand the locked config to a (short) long run, twice.
    let run_once = |rep: &gmi_drl::tune::SyncTuneReport| {
        let layout = build_sync_layout(
            &topo,
            MappingTemplate::TaskColocated,
            rep.choice.gmi_per_gpu,
            rep.choice.num_env,
            &cost,
            Some(GmiBackend::Mps),
        )
        .unwrap();
        let cfg = SyncConfig { iterations: 5, ..rep.choice.apply(&base) };
        run_sync(&layout, &b, &cost, &Compute::Null, &cfg).unwrap()
    };
    let r1 = run_once(&rep1);
    let r2 = run_once(&rep2);
    assert_metrics_identical(&r1.metrics, &r2.metrics, "tuned sync");
    assert_eq!(r1.strategy, r2.strategy);
    for (a, b) in r1.final_params.iter().zip(&r2.final_params) {
        assert_eq!(a.to_bits(), b.to_bits(), "tuned sync: final params");
    }
}

#[test]
fn tuned_gateway_run_is_bit_identical_across_runs() {
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(1);
    let trace =
        generate_trace(&TrafficPattern::Poisson { rate: 3000.0 }, 0.3, 11, 4);
    let layout = build_gateway_fleet(&topo, 2, 4, 64, &cost, None).unwrap();
    let base = GatewayConfig { slo_s: 20e-3, ..GatewayConfig::default() };
    let tcfg = TuneConfig { budget_frac: 0.5, ..TuneConfig::default() };
    let rep1 =
        tune_gateway(&layout, &b, &cost, &trace, &base, &GatewaySpace::default(), &tcfg).unwrap();
    let rep2 =
        tune_gateway(&layout, &b, &cost, &trace, &base, &GatewaySpace::default(), &tcfg).unwrap();
    assert_eq!(rep1.choice, rep2.choice, "gateway tuner choice drifted");
    assert_eq!(rep1, rep2, "gateway tuner report drifted");

    let run_once = || {
        let cfg = rep1.choice.apply(&base);
        run_gateway(&layout, &b, &cost, &trace, &cfg).unwrap()
    };
    let r1 = run_once();
    let r2 = run_once();
    assert_metrics_identical(&r1.metrics, &r2.metrics, "tuned gateway");
    assert_eq!(r1.served.len(), r2.served.len());
    for (x, y) in r1.served.iter().zip(&r2.served) {
        assert_eq!(x.id, y.id);
        assert_eq!(bits(x.completion_s), bits(y.completion_s));
    }
}

#[test]
fn pinned_fingerprint_golden_matches_committed_value() {
    // Run-vs-run goldens above catch nondeterminism WITHIN a build; this
    // one catches semantic drift ACROSS commits: a fixed gateway run and a
    // fixed two-tenant cluster day, and a fixed auto-tuned sync run are
    // hashed (every served request's completion bits, every scheduling
    // decision, every tuner choice field, every final metric) and
    // compared against a committed fingerprint. A hot-path "optimization"
    // that moves any virtual-time result by one ulp fails here.
    //
    // Blessing: delete `rust/tests/golden/hotpath_fingerprint.txt` and
    // re-run — the test writes the current fingerprint and passes. Only
    // bless after an INTENTIONAL semantic change, and say so in the commit.
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let mut fp = Fingerprint::new();

    // Scenario 1: burst-traffic gateway with admission control.
    let topo = Topology::dgx_a100(1);
    let pattern =
        TrafficPattern::Burst { base: 3000.0, burst: 30000.0, start_s: 0.05, len_s: 0.05 };
    let trace = generate_trace(&pattern, 0.15, 11, 4);
    let cfg = GatewayConfig {
        max_batch: 16,
        max_wait_s: 1e-3,
        admission_cap: Some(4096),
        slo_s: 5e-3,
        autoscale: None,
        ..GatewayConfig::default()
    };
    let layout = build_gateway_fleet(&topo, 2, 6, 16, &cost, None).unwrap();
    let r = run_gateway(&layout, &b, &cost, &trace, &cfg).unwrap();
    fp.fold(r.served.len() as u64);
    fp.fold(r.rejected as u64);
    for s in &r.served {
        fp.fold(s.id as u64);
        fp.fold(s.batch as u64);
        fp.fold_f64(s.dispatch_s);
        fp.fold_f64(s.completion_s);
    }
    for &n in &r.batch_sizes {
        fp.fold(n as u64);
    }
    fp.fold_f64(r.metrics.span_s);
    fp.fold_f64(r.metrics.comm_s);
    let l = r.metrics.latency.as_ref().unwrap();
    fp.fold_f64(l.p50_s);
    fp.fold_f64(l.p95_s);
    fp.fold_f64(l.p99_s);
    fp.fold_f64(l.mean_s);
    fp.fold_f64(l.attainment);

    // Scenario 2: the preemptive training + diurnal serving co-run.
    let topo2 = Topology::dgx_a100(2);
    let jobs = corun_scenario(&topo2, &b, &cost, 0.4, 11, false);
    let rc = run_cluster(&topo2, &b, &cost, &jobs, &SchedConfig::default()).unwrap();
    fp.fold(rc.events.len() as u64);
    for e in &rc.events {
        fp.fold_f64(e.t_s);
        fp.fold(e.job as u64);
        fp.fold(e.members as u64);
        fp.fold_f64(e.share);
    }
    for j in &rc.jobs {
        fp.fold_f64(j.metrics.span_s);
        fp.fold_f64(j.metrics.comm_s);
        fp.fold_f64(j.busy_s);
        fp.fold_f64(j.xjob_interference_s);
        fp.fold_f64(j.completed_s);
    }
    fp.fold_f64(rc.makespan_s);
    fp.fold_f64(rc.fairness);
    fp.fold_f64(rc.peak_gpu_share);

    // Scenario 3: the auto-tuner's decision plus the tuned run it locks.
    // Every probe measurement feeds the choice, so a one-ulp drift anywhere
    // in the probe path shows up either in the report fields or in the
    // tuned run's metrics.
    let base = SyncConfig { iterations: 20_000, ..SyncConfig::default() };
    let rep = tune_sync(
        &topo2,
        MappingTemplate::TaskColocated,
        Some(GmiBackend::Mps),
        &b,
        &cost,
        &base,
        (2, 512),
        &SyncSpace::default(),
        &TuneConfig::default(),
    )
    .unwrap();
    fp.fold(rep.choice.gmi_per_gpu as u64);
    fp.fold(rep.choice.num_env as u64);
    fp.fold(rep.choice.minibatches as u64);
    for byte in gmi_drl::tune::strategy_name(rep.choice.strategy).bytes() {
        fp.fold(byte as u64);
    }
    fp.fold(rep.choice.overlap as u64);
    fp.fold_f64(rep.objective);
    fp.fold_f64(rep.probe_cost_s);
    fp.fold(rep.probes.len() as u64);
    fp.fold(rep.pruned as u64);
    let tuned_layout = build_sync_layout(
        &topo2,
        MappingTemplate::TaskColocated,
        rep.choice.gmi_per_gpu,
        rep.choice.num_env,
        &cost,
        Some(GmiBackend::Mps),
    )
    .unwrap();
    let tuned_cfg = SyncConfig { iterations: 4, ..rep.choice.apply(&base) };
    let tr = run_sync(&tuned_layout, &b, &cost, &Compute::Null, &tuned_cfg).unwrap();
    fp.fold_f64(tr.metrics.steps_per_sec);
    fp.fold_f64(tr.metrics.span_s);
    fp.fold_f64(tr.metrics.comm_s);
    fp.fold_f64(tr.metrics.final_reward);

    let got = format!("{:016x}", fp.0);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/golden/hotpath_fingerprint.txt");
    check_golden(&got, path);
}

/// Compare against a committed pin, blessing on absence: first run on a
/// fresh checkout of a commit that intentionally changed semantics writes
/// the new pin.
fn check_golden(got: &str, path: &str) {
    match std::fs::read_to_string(path) {
        Ok(want) => {
            assert_eq!(
                got,
                want.trim(),
                "pinned golden fingerprint changed — virtual-time results \
                 drifted from the committed baseline (see {path} for how to \
                 bless an intentional change)"
            );
        }
        Err(_) => {
            std::fs::create_dir_all(
                std::path::Path::new(path).parent().expect("golden dir has a parent"),
            )
            .expect("create golden dir");
            std::fs::write(path, format!("{got}\n")).expect("write golden fingerprint");
        }
    }
}

#[test]
fn offpolicy_fingerprint_golden_matches_committed_value() {
    // The off-policy golden: a standalone replay run (buffer ledger,
    // staleness, pressure), a self-play league season (dynamic tenant
    // spawns through admission, Elo outcomes), and the three-tenant
    // off-policy co-run are hashed and pinned. Drift anywhere in the
    // replay sampling stream, the spawn/admission interleaving, or the
    // result-delivery order fails here.
    //
    // Blessing: delete `rust/tests/golden/offpolicy_fingerprint.txt`,
    // re-run, and say so in the commit.
    let b = static_registry()["AY"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    let mut fp = Fingerprint::new();

    // Scenario 1: standalone replay with reservoir turnover.
    let layout = build_async_layout(&topo, 1, 2, 1, 2048, &cost).unwrap();
    let rcfg = ReplayConfig {
        rounds: 6,
        eviction: Eviction::Reservoir,
        buffer_gib: 0.002,
        seed: 13,
        ..ReplayConfig::default()
    };
    let rr = run_replay(&layout, &b, &cost, &Compute::Null, &rcfg).unwrap();
    let stats = rr.metrics.replay.as_ref().unwrap();
    fp.fold(stats.capacity as u64);
    fp.fold(stats.transitions_in as u64);
    fp.fold(stats.transitions_sampled as u64);
    fp.fold(stats.evicted as u64);
    fp.fold(stats.updates as u64);
    fp.fold(stats.empty_ticks as u64);
    fp.fold_f64(stats.mean_staleness_s);
    fp.fold_f64(stats.max_staleness_s);
    fp.fold_f64(stats.mean_pressure);
    fp.fold_f64(stats.peak_pressure);
    fp.fold_f64(rr.metrics.span_s);
    fp.fold_f64(rr.metrics.steps_per_sec);
    fp.fold_f64(rr.metrics.ttop);
    fp.fold(rr.updates as u64);
    fp.fold(rr.channel_stats.packets_out as u64);

    // Scenario 2: a league season — every spawn/admit/complete decision
    // and the final table.
    let lcfg = LeagueConfig { total_matches: 6, seed: 13, ..LeagueConfig::default() };
    let lr = run_league(&topo, &b, &cost, &lcfg, 0.2, &SchedConfig::default()).unwrap();
    fp.fold(lr.jobs.len() as u64);
    fp.fold(lr.events.len() as u64);
    for e in &lr.events {
        fp.fold_f64(e.t_s);
        fp.fold(e.job as u64);
        for byte in e.action.to_string().bytes() {
            fp.fold(byte as u64);
        }
        fp.fold(e.members as u64);
    }
    let coord = lr.job(0).unwrap();
    for &(p, w) in &coord.metrics.reward_curve {
        fp.fold_f64(p);
        fp.fold_f64(w);
    }
    fp.fold_f64(coord.metrics.final_reward);
    fp.fold_f64(lr.makespan_s);

    // Scenario 3: the full off-policy co-run (training + replay + league
    // churning spawned matches through the shared cluster).
    let jobs = offpolicy_corun_scenario(&topo, &b, &cost, 13);
    let cr = run_cluster(&topo, &b, &cost, &jobs, &SchedConfig::default()).unwrap();
    fp.fold(cr.jobs.len() as u64);
    fp.fold(cr.events.len() as u64);
    for j in &cr.jobs {
        fp.fold(j.id as u64);
        fp.fold_f64(j.metrics.span_s);
        fp.fold_f64(j.metrics.steps_per_sec);
        fp.fold_f64(j.busy_s);
        fp.fold_f64(j.completed_s);
        if let Some(s) = &j.metrics.replay {
            fp.fold(s.transitions_in as u64);
            fp.fold(s.transitions_sampled as u64);
            fp.fold(s.evicted as u64);
        }
    }
    fp.fold_f64(cr.makespan_s);
    fp.fold_f64(cr.fairness);
    fp.fold_f64(cr.peak_gpu_share);

    let got = format!("{:016x}", fp.0);
    let path =
        concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/golden/offpolicy_fingerprint.txt");
    check_golden(&got, path);
}

#[test]
fn faulted_corun_fingerprint_golden_matches_committed_value() {
    // The fault-tolerance golden: a fixed two-tenant day under a fixed
    // declarative failure schedule (GPU loss + repair, an NVSwitch outage
    // forcing a mid-run replan) with periodic charged checkpoints. Every
    // scheduling decision — including every fail/repair/checkpoint/kill —
    // and every recovery metric is hashed and pinned, so a drift anywhere
    // in the kill/re-admit/replan path fails here.
    //
    // Blessing: delete `rust/tests/golden/fault_fingerprint.txt`, re-run,
    // and say so in the commit.
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    let trace = "\
        0.03 fail gpu 1\n\
        0.05 fail nvswitch\n\
        0.08 repair gpu 1\n\
        0.09 repair nvswitch\n";
    let jobs = corun_scenario(&topo, &b, &cost, 0.2, 7, false);
    let cfg = SchedConfig {
        faults: Some(
            FaultPlan::new(FaultTrace::parse(trace, 1).unwrap()).with_checkpoint_interval(0.02),
        ),
        ..SchedConfig::default()
    };
    let r = run_cluster(&topo, &b, &cost, &jobs, &cfg).unwrap();
    assert_eq!(r.fault_events, 4);

    let mut fp = Fingerprint::new();
    fp.fold(r.events.len() as u64);
    for e in &r.events {
        fp.fold_f64(e.t_s);
        fp.fold(e.job as u64);
        for byte in e.action.to_string().bytes() {
            fp.fold(byte as u64);
        }
        fp.fold(e.members as u64);
        fp.fold_f64(e.share);
        fp.fold(e.detail.len() as u64);
    }
    for j in &r.jobs {
        fp.fold_f64(j.metrics.span_s);
        fp.fold_f64(j.metrics.steps_per_sec);
        fp.fold_f64(j.busy_s);
        fp.fold_f64(j.completed_s);
        fp.fold(j.kills as u64);
        fp.fold_f64(j.goodput_lost_s);
        fp.fold_f64(j.recovery_s);
        fp.fold_f64(j.checkpoint_s);
    }
    fp.fold_f64(r.makespan_s);
    fp.fold_f64(r.goodput_lost_s);
    fp.fold(r.fault_events as u64);

    let got = format!("{:016x}", fp.0);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/golden/fault_fingerprint.txt");
    check_golden(&got, path);
}

/// Bit-exact equality over two whole cluster runs: the scheduling
/// timeline event-by-event plus every per-job report field. This is the
/// contract the idle-round fast-forward must honor — skipping quanta is
/// only legal if no observer could tell.
fn assert_cluster_identical(a: &ClusterRunResult, b: &ClusterRunResult, what: &str) {
    assert_eq!(a.events, b.events, "{what}: scheduling timeline diverged");
    assert_eq!(a.jobs.len(), b.jobs.len(), "{what}: job count");
    for (x, y) in a.jobs.iter().zip(&b.jobs) {
        let tag = format!("{what}: job {} ({})", x.id, x.name);
        assert_eq!(x.id, y.id, "{tag}: id");
        assert_eq!(x.kind, y.kind, "{tag}: kind");
        assert_metrics_identical(&x.metrics, &y.metrics, &tag);
        assert_eq!(bits(x.admitted_s), bits(y.admitted_s), "{tag}: admitted_s");
        assert_eq!(bits(x.completed_s), bits(y.completed_s), "{tag}: completed_s");
        assert_eq!(bits(x.wait_s), bits(y.wait_s), "{tag}: wait_s");
        assert_eq!(x.preemptions, y.preemptions, "{tag}: preemptions");
        assert_eq!(x.restores, y.restores, "{tag}: restores");
        assert_eq!(bits(x.busy_s), bits(y.busy_s), "{tag}: busy_s");
        assert_eq!(
            bits(x.xjob_interference_s),
            bits(y.xjob_interference_s),
            "{tag}: xjob_interference_s"
        );
        assert_eq!(x.kills, y.kills, "{tag}: kills");
        assert_eq!(bits(x.goodput_lost_s), bits(y.goodput_lost_s), "{tag}: goodput_lost_s");
        assert_eq!(bits(x.recovery_s), bits(y.recovery_s), "{tag}: recovery_s");
        assert_eq!(bits(x.checkpoint_s), bits(y.checkpoint_s), "{tag}: checkpoint_s");
    }
    assert_eq!(bits(a.makespan_s), bits(b.makespan_s), "{what}: makespan");
    assert_eq!(
        bits(a.cluster_utilization),
        bits(b.cluster_utilization),
        "{what}: cluster_utilization"
    );
    assert_eq!(bits(a.fairness), bits(b.fairness), "{what}: fairness");
    assert_eq!(bits(a.peak_gpu_share), bits(b.peak_gpu_share), "{what}: peak_gpu_share");
    assert_eq!(a.fault_events, b.fault_events, "{what}: fault_events");
    assert_eq!(bits(a.goodput_lost_s), bits(b.goodput_lost_s), "{what}: goodput_lost_s");
}

#[test]
fn fast_forward_is_bit_identical_to_the_naive_loop() {
    // The fast-forward contract on the hardest scenario we have: the
    // fault golden's two-tenant day (GPU loss + repair, an NVSwitch
    // outage, periodic charged checkpoints), where skips must stop short
    // of every fault event and checkpoint boundary. `Audit` additionally
    // re-steps every predicted-quiescent round naively and errors if one
    // did observable work, so it passing is the proof the hints are
    // conservative.
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    let trace = "\
        0.03 fail gpu 1\n\
        0.05 fail nvswitch\n\
        0.08 repair gpu 1\n\
        0.09 repair nvswitch\n";
    let jobs = corun_scenario(&topo, &b, &cost, 0.2, 7, false);
    let mk = |ff: FastForward| SchedConfig {
        faults: Some(
            FaultPlan::new(FaultTrace::parse(trace, 1).unwrap()).with_checkpoint_interval(0.02),
        ),
        fast_forward: ff,
        ..SchedConfig::default()
    };
    let off = run_cluster(&topo, &b, &cost, &jobs, &mk(FastForward::Off)).unwrap();
    let on = run_cluster(&topo, &b, &cost, &jobs, &mk(FastForward::On)).unwrap();
    let audit = run_cluster(&topo, &b, &cost, &jobs, &mk(FastForward::Audit)).unwrap();
    assert_eq!(off.fault_events, 4);
    assert_cluster_identical(&off, &on, "faulted day off-vs-on");
    assert_cluster_identical(&off, &audit, "faulted day off-vs-audit");
}

#[test]
fn fast_forward_on_a_sparse_week_slice_matches_the_naive_loop() {
    // A shortened week scenario: the diurnal troughs put thousands of
    // empty quanta between arrivals, so the fast-forward actually engages
    // (unlike the dense faulted day above, where skips are rare). A fault
    // plan in the middle of the slice checks that skips also stop short
    // of hardware events when the gaps are long. Trace representation is
    // pinned to the naive one (WeekOpts::disabled) so the ONLY varying
    // knob is the round loop; streaming/aggregation identities have their
    // own tests in prop_serve.
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    let jobs = week_scenario(&topo, 30.0, 11, &WeekOpts::disabled());
    let trace = "\
        3.0 fail gpu 1\n\
        5.5 fail nvswitch\n\
        8.0 repair gpu 1\n\
        9.0 repair nvswitch\n";
    let mk = |ff: FastForward| SchedConfig {
        faults: Some(
            FaultPlan::new(FaultTrace::parse(trace, 1).unwrap()).with_checkpoint_interval(1.0),
        ),
        fast_forward: ff,
        ..SchedConfig::default()
    };
    let off = run_cluster(&topo, &b, &cost, &jobs, &mk(FastForward::Off)).unwrap();
    let on = run_cluster(&topo, &b, &cost, &jobs, &mk(FastForward::On)).unwrap();
    let audit = run_cluster(&topo, &b, &cost, &jobs, &mk(FastForward::Audit)).unwrap();
    assert_eq!(off.fault_events, 4);
    assert_cluster_identical(&off, &on, "week slice off-vs-on");
    assert_cluster_identical(&off, &audit, "week slice off-vs-audit");
}

#[test]
fn scale_fingerprint_golden_matches_committed_value() {
    // The week-scale golden: a shortened week scenario under the FULL
    // fast path — streaming traces, macro-request aggregation, capped
    // latency reservoirs, and idle-round fast-forward all on at once.
    // Every scheduling decision and per-job outcome is hashed and pinned,
    // so a drift anywhere in the fast path (a skipped observable round, a
    // coalescing change, a reservoir reseed) fails here.
    //
    // Blessing: delete `rust/tests/golden/scale_fingerprint.txt`, re-run,
    // and say so in the commit.
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    let opts = WeekOpts { streaming: true, aggregation: 4, sample_cap: Some(512) };
    let jobs = week_scenario(&topo, 30.0, 11, &opts);
    let cfg = SchedConfig { fast_forward: FastForward::On, ..SchedConfig::default() };
    let r = run_cluster(&topo, &b, &cost, &jobs, &cfg).unwrap();

    let mut fp = Fingerprint::new();
    fp.fold(r.events.len() as u64);
    for e in &r.events {
        fp.fold_f64(e.t_s);
        fp.fold(e.job as u64);
        for byte in e.action.to_string().bytes() {
            fp.fold(byte as u64);
        }
        fp.fold(e.members as u64);
        fp.fold_f64(e.share);
        fp.fold(e.detail.len() as u64);
    }
    for j in &r.jobs {
        fp.fold(j.id as u64);
        fp.fold_f64(j.metrics.span_s);
        fp.fold_f64(j.metrics.steps_per_sec);
        fp.fold_f64(j.busy_s);
        fp.fold_f64(j.completed_s);
        if let Some(l) = &j.metrics.latency {
            fp.fold(l.served as u64);
            fp.fold_f64(l.mean_s);
            fp.fold_f64(l.p99_s);
        }
    }
    fp.fold_f64(r.makespan_s);
    fp.fold_f64(r.cluster_utilization);

    let got = format!("{:016x}", fp.0);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/golden/scale_fingerprint.txt");
    check_golden(&got, path);
}
