//! Property-based tests on the multi-tenant cluster scheduler, plus the
//! headline integration claim: preemptive co-scheduling strictly beats
//! static partitioning on BOTH training throughput and serving p99 over
//! the same seeded trace.
//!
//! Same methodology as the other property suites: no proptest crate
//! offline, so a seeded SplitMix64 generator drives many random cases
//! with universal assertions (deterministic on failure via the case
//! index).

use gmi_drl::cluster::Topology;
use gmi_drl::config::static_registry;
use gmi_drl::sched::{
    corun_scenario, run_cluster, FastForward, JobSpec, SchedAction, SchedConfig,
};
use gmi_drl::serve::{generate_trace, GatewayConfig, TrafficPattern};
use gmi_drl::vtime::CostModel;

/// Deterministic PRNG (SplitMix64).
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next() as usize) % (hi - lo + 1)
    }

    fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * ((self.next() >> 11) as f64 / (1u64 << 53) as f64)
    }
}

/// A random mixed tenant: shares stay <= 0.5 and counts <= 2, so every
/// job's guaranteed minimum fits even a 1-GPU cluster and admission is
/// always eventually possible.
fn random_job(rng: &mut Rng, id: usize, priority: u8, case: usize) -> JobSpec {
    let arrival = rng.f64(0.0, 0.12);
    let gmis = rng.range(1, 2);
    let share = (rng.range(20, 50) as f64) / 100.0;
    if rng.range(0, 1) == 0 {
        let num_env = 128 * rng.range(1, 4);
        JobSpec::training(id, "t", priority, arrival, gmis, share, 0.1, num_env, rng.range(1, 3))
    } else {
        let rate = rng.f64(1000.0, 8000.0);
        let dur = rng.f64(0.06, 0.15);
        let trace = generate_trace(
            &TrafficPattern::Poisson { rate },
            dur,
            (case * 31 + id) as u64,
            4,
        );
        let mut s = JobSpec::serving(
            id,
            "s",
            priority,
            arrival,
            (1, gmis, gmis + 1),
            share,
            8,
            30e-3,
            trace,
        );
        s.min_gmis = 1;
        s
    }
}

#[test]
fn prop_no_oversubscription_under_any_arrival_sequence() {
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let mut rng = Rng(0x5eed);
    for case in 0..8 {
        let gpus = rng.range(1, 2);
        let topo = Topology::dgx_a100(gpus);
        let n_jobs = rng.range(2, 4);
        // Distinct priorities, shuffled deterministically.
        let mut prios: Vec<u8> = (1..=n_jobs as u8).collect();
        for i in (1..prios.len()).rev() {
            prios.swap(i, rng.range(0, i));
        }
        let jobs: Vec<JobSpec> = (0..n_jobs)
            .map(|i| random_job(&mut rng, i, prios[i], case))
            .collect();
        let cfg = SchedConfig { quantum_s: 0.02, ..Default::default() };
        let r = run_cluster(&topo, &b, &cost, &jobs, &cfg)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        // The invariant: no arrival sequence may ever oversubscribe a
        // GPU's SMs or memory.
        assert!(
            r.peak_gpu_share <= 1.0 + 1e-6,
            "case {case}: peak GPU share {}",
            r.peak_gpu_share
        );
        assert!(
            r.peak_gpu_mem_gib <= 40.0 + 1e-6,
            "case {case}: peak GPU mem {}",
            r.peak_gpu_mem_gib
        );
        // Every job was admitted and ran to completion.
        for j in &r.jobs {
            assert!(j.admitted_s >= 0.0 && j.wait_s >= 0.0, "case {case} job {}", j.id);
            assert!(
                j.completed_s > j.admitted_s - 1e-12,
                "case {case} job {} never completed",
                j.id
            );
            assert!(j.busy_s > 0.0 || j.metrics.latency.is_some(), "case {case}: idle job");
        }
        // Fairness is a well-formed Jain index.
        assert!(r.fairness > 0.0 && r.fairness <= 1.0 + 1e-12, "case {case}");
        // Every serving request was dispatched exactly once.
        for j in r.jobs.iter().filter(|j| j.kind == "serving") {
            let l = j.metrics.latency.as_ref().unwrap();
            assert_eq!(l.served, l.requests, "case {case} job {}: dropped requests", j.id);
        }
    }
}

#[test]
fn prop_priority_inversion_never_persists_past_one_round() {
    // A top-priority arrival into a cluster packed by lower-priority
    // tenants must be admitted at its first scheduling round: the
    // admission path shrinks and evicts lower tenants in the same round,
    // so inversion never outlives one quantum.
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let mut rng = Rng(0xabcd);
    for case in 0..8 {
        let topo = Topology::dgx_a100(1);
        let hog_share = (rng.range(60, 90) as f64) / 100.0;
        // Demand always exceeds the free share left by the hog, so
        // admission is impossible without preemption — but always fits
        // once the hog is shrunk to its 0.1 floor.
        let want_share = ((1.0 - hog_share) + 0.2).min(0.8);
        let arrival = rng.f64(0.03, 0.1);
        let trace =
            generate_trace(&TrafficPattern::Poisson { rate: 2000.0 }, 0.08, case as u64, 4);
        let jobs = vec![
            JobSpec::training(0, "hog", 1, 0.0, 1, hog_share, 0.1, 256, 40),
            JobSpec::serving(1, "vip", 9, arrival, (1, 1, 1), want_share, 8, 30e-3, trace),
        ];
        let cfg = SchedConfig { quantum_s: 0.02, ..Default::default() };
        let r = run_cluster(&topo, &b, &cost, &jobs, &cfg).unwrap();
        let vip = r.job(1).unwrap();
        assert!(
            vip.wait_s <= cfg.quantum_s + 1e-9,
            "case {case}: priority inversion persisted {}s (> one {}s round)",
            vip.wait_s,
            cfg.quantum_s
        );
        // The hog was preempted to make room, never below its floor.
        let hog = r.job(0).unwrap();
        assert!(hog.preemptions >= 1, "case {case}: no preemption recorded");
        assert!(r.peak_gpu_share <= 1.0 + 1e-6, "case {case}");
    }
}

#[test]
fn prop_preempted_jobs_are_restored_when_capacity_frees() {
    // After the preempting tenant completes, the preempted trainer must
    // be regrown to its admitted provisioning — and finish there.
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let mut rng = Rng(0xfade);
    for case in 0..6 {
        let topo = Topology::dgx_a100(1);
        let share = (rng.range(70, 90) as f64) / 100.0;
        let trace =
            generate_trace(&TrafficPattern::Poisson { rate: 3000.0 }, 0.1, case as u64, 4);
        let jobs = vec![
            JobSpec::training(0, "train", 1, 0.0, 1, share, 0.15, 256, 40),
            JobSpec::serving(1, "burst", 9, 0.04, (1, 1, 1), 0.5, 8, 30e-3, trace),
        ];
        let cfg = SchedConfig { quantum_s: 0.02, ..Default::default() };
        let r = run_cluster(&topo, &b, &cost, &jobs, &cfg).unwrap();
        let train = r.job(0).unwrap();
        assert!(train.preemptions >= 1, "case {case}: never preempted");
        assert!(train.restores >= 1, "case {case}: never restored");
        assert!(
            (train.share_at_completion - share).abs() < 1e-9,
            "case {case}: trainer finished at {} share, admitted at {share}",
            train.share_at_completion
        );
        // The restore fires after the burst released its capacity.
        let burst_done = r
            .events
            .iter()
            .find(|e| e.job == 1 && e.action == SchedAction::Complete)
            .map(|e| e.t_s)
            .expect("burst completion event");
        assert!(
            r.events
                .iter()
                .any(|e| e.job == 0 && e.action == SchedAction::Restore && e.t_s >= burst_done),
            "case {case}: no restore after the burst completed"
        );
    }
}

#[test]
fn prop_placement_decisions_identical_across_two_runs() {
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let mk = || {
        let trace =
            generate_trace(&TrafficPattern::Poisson { rate: 4000.0 }, 0.12, 17, 4);
        vec![
            JobSpec::training(0, "t0", 2, 0.0, 2, 0.4, 0.1, 256, 3),
            JobSpec::serving(1, "s1", 9, 0.03, (1, 2, 3), 0.25, 8, 10e-3, trace),
            JobSpec::training(2, "t2", 1, 0.06, 1, 0.3, 0.1, 128, 2),
        ]
    };
    let topo = Topology::dgx_a100(2);
    let cfg = SchedConfig::default();
    let r1 = run_cluster(&topo, &b, &cost, &mk(), &cfg).unwrap();
    let r2 = run_cluster(&topo, &b, &cost, &mk(), &cfg).unwrap();
    // The full timeline — every placement, preemption, and restore — is
    // identical, and so is every per-job outcome, bit for bit.
    assert_eq!(r1.events, r2.events, "scheduling timeline drifted");
    assert_eq!(r1.jobs.len(), r2.jobs.len());
    for (a, c) in r1.jobs.iter().zip(&r2.jobs) {
        assert_eq!(a.metrics.steps_per_sec.to_bits(), c.metrics.steps_per_sec.to_bits());
        assert_eq!(a.metrics.span_s.to_bits(), c.metrics.span_s.to_bits());
        assert_eq!(a.busy_s.to_bits(), c.busy_s.to_bits());
        assert_eq!(a.xjob_interference_s.to_bits(), c.xjob_interference_s.to_bits());
        assert_eq!(a.preemptions, c.preemptions);
        assert_eq!(a.restores, c.restores);
    }
    assert_eq!(r1.fairness.to_bits(), r2.fairness.to_bits());
}

/// The acceptance claim (and the story `examples/shared_cluster.rs`
/// prints): over the same seeded diurnal day and the same total simulated
/// environments, the preemptive co-schedule strictly beats static
/// partitioning on BOTH training throughput and serving p99.
#[test]
fn preemptive_corun_beats_static_partitioning_on_both_axes() {
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    let day = 0.8;
    let static_jobs = corun_scenario(&topo, &b, &cost, day, 7, true);
    let elastic_jobs = corun_scenario(&topo, &b, &cost, day, 7, false);
    let stat = run_cluster(
        &topo,
        &b,
        &cost,
        &static_jobs,
        &SchedConfig { preemptive: false, ..Default::default() },
    )
    .unwrap();
    let elas = run_cluster(&topo, &b, &cost, &elastic_jobs, &SchedConfig::default()).unwrap();

    let s_train = stat.job(0).unwrap();
    let e_train = elas.job(0).unwrap();
    assert!(
        e_train.metrics.steps_per_sec > s_train.metrics.steps_per_sec,
        "training: preemptive {} !> static {}",
        e_train.metrics.steps_per_sec,
        s_train.metrics.steps_per_sec
    );

    let s_p99 = stat.job(1).unwrap().metrics.latency.as_ref().unwrap().p99_s;
    let e_p99 = elas.job(1).unwrap().metrics.latency.as_ref().unwrap().p99_s;
    assert!(e_p99 < s_p99, "serving p99: preemptive {e_p99} !< static {s_p99}");

    // The win came from actual preemptive elasticity, not sizing slack.
    assert!(elas.events.iter().any(|e| e.action == SchedAction::Preempt));
    assert!(elas.events.iter().any(|e| e.action == SchedAction::Grow));
    assert!(elas.events.iter().any(|e| e.action == SchedAction::Restore));
    assert!(stat.events.iter().all(|e| e.action != SchedAction::Preempt));
    // Neither schedule ever oversubscribed.
    assert!(stat.peak_gpu_share <= 1.0 + 1e-6);
    assert!(elas.peak_gpu_share <= 1.0 + 1e-6);
}

#[test]
fn derived_round_cap_admits_week_scale_horizons() {
    // The runaway guard used to be a flat 1,000,000-round cap, which
    // forbids exactly the workloads the fast path exists for (a week at
    // the 0.02s quantum is 30.2M quanta). The cap is now derived from the
    // tenants' trace horizons: a sparse gateway over 200 simulated
    // seconds at a 1e-4 quantum needs ~2M rounds — double the old flat
    // cap — and must now run to completion.
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    let pat = TrafficPattern::Constant { rate: 0.05 };
    let trace = generate_trace(&pat, 200.0, 7, 1);
    let jobs = vec![JobSpec::gateway(
        0,
        "sparse",
        5,
        0.0,
        (1, 1, 2),
        0.25,
        GatewayConfig { max_batch: 8, max_wait_s: 0.05, slo_s: 0.5, ..GatewayConfig::default() },
        trace,
    )];
    let cfg = SchedConfig {
        quantum_s: 1e-4,
        fast_forward: FastForward::On,
        ..SchedConfig::default()
    };
    let r = run_cluster(&topo, &b, &cost, &jobs, &cfg).unwrap();
    assert!(
        r.makespan_s / cfg.quantum_s > 1_000_000.0,
        "scenario too short to exercise the old flat cap: {} rounds",
        r.makespan_s / cfg.quantum_s
    );
    let served: usize =
        r.jobs.iter().filter_map(|j| j.metrics.latency.as_ref()).map(|l| l.served).sum();
    assert!(served > 0, "sparse gateway served nothing");

    // An explicit override still pins the cap — and still trips fast.
    let pinned = SchedConfig {
        quantum_s: 1e-4,
        max_rounds: Some(1_000),
        ..SchedConfig::default()
    };
    let err = run_cluster(&topo, &b, &cost, &jobs, &pinned).unwrap_err();
    assert!(
        format!("{err:#}").contains("runaway guard"),
        "expected the runaway-guard error, got: {err:#}"
    );
}
