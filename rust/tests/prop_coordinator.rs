//! Property-based tests on coordinator invariants.
//!
//! The offline build has no proptest crate; these use a seeded SplitMix64
//! generator over many random cases — same methodology (random inputs,
//! universal assertions, deterministic on failure via the printed seed).

use gmi_drl::cluster::Topology;
use gmi_drl::comm::{reduce_mean, select_strategy, LgrEngine, ReduceStrategy};
use gmi_drl::channels::{Batcher, ChannelKind, Chunk, Compressor, Packet, ShareMode};
use gmi_drl::config::static_registry;
use gmi_drl::gmi::{one_job_per_gpu, pack_jobs, GmiBackend, GmiManager, GmiSpec, Job, Role};
use gmi_drl::vtime::{Clock, CostModel, OpKind};

/// Deterministic PRNG (SplitMix64).
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next() as usize) % (hi - lo + 1)
    }

    fn f32(&mut self) -> f32 {
        (self.next() >> 40) as f32 / (1u64 << 24) as f32 - 0.5
    }
}

#[test]
fn prop_lgr_all_strategies_agree_with_naive_mean() {
    let mut rng = Rng(0xfeed);
    for case in 0..60 {
        let g = rng.range(1, 8);
        let t = rng.range(1, 4);
        let len = rng.range(1, 300);
        let mpl: Vec<Vec<usize>> =
            (0..g).map(|i| (0..t).map(|j| i * t + j).collect()).collect();
        let engine = LgrEngine::new(Topology::dgx_a100(g), mpl).unwrap();
        let grads: Vec<Vec<f32>> =
            (0..g * t).map(|_| (0..len).map(|_| rng.f32()).collect()).collect();
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let want = reduce_mean(&refs);

        for strat in [
            ReduceStrategy::MultiProcess,
            ReduceStrategy::MultiRing,
            ReduceStrategy::Hierarchical,
        ] {
            match engine.allreduce(&grads, strat) {
                Ok((got, secs)) => {
                    assert_eq!(got, want, "case {case} strat {strat} g={g} t={t}");
                    assert!(secs >= 0.0 && secs.is_finite());
                }
                Err(_) => {
                    // only MRR may reject, and only when t > g or t=1 cases
                    assert_eq!(strat, ReduceStrategy::MultiRing, "case {case}");
                    assert!(t > g, "MRR rejected valid layout g={g} t={t}");
                }
            }
        }
    }
}

#[test]
fn prop_algorithm1_selects_valid_strategy() {
    let mut rng = Rng(0xbeef);
    for case in 0..200 {
        let g = rng.range(1, 8);
        // possibly unequal GMIs per GPU
        let mpl: Vec<Vec<usize>> = {
            let mut id = 0;
            (0..g)
                .map(|_| {
                    let t = rng.range(1, 5);
                    (0..t)
                        .map(|_| {
                            id += 1;
                            id
                        })
                        .collect()
                })
                .collect()
        };
        let strat = select_strategy(&mpl);
        let sizes: Vec<usize> = mpl.iter().map(|v| v.len()).collect();
        let equal = sizes.windows(2).all(|w| w[0] == w[1]);
        match strat {
            ReduceStrategy::MultiProcess => assert_eq!(g, 1, "case {case}"),
            ReduceStrategy::MultiRing => {
                // MRR must be executable: equal counts, t <= g
                assert!(equal && sizes[0] <= g, "case {case}: invalid MRR for {sizes:?}");
            }
            ReduceStrategy::Hierarchical => {
                assert!(g > 1, "case {case}");
                assert!(!equal || sizes[0] > g, "case {case}: HAR chosen where MRR fits");
            }
        }
    }
}

#[test]
fn prop_batcher_conserves_samples() {
    let mut rng = Rng(0xcafe);
    for case in 0..50 {
        let batch = rng.range(4, 64);
        let mut bt = Batcher::new(0, ShareMode::MultiChannel, batch);
        let mut pushed = 0usize;
        let mut emitted = 0usize;
        for i in 0..rng.range(5, 30) {
            let envs = rng.range(1, 32);
            let steps = rng.range(1, 4);
            pushed += steps * envs;
            for &ch in &ChannelKind::ALL {
                let w = ch.width(6, 2);
                let pkt = Packet {
                    channel: ch,
                    chunks: vec![Chunk {
                        channel: ch,
                        agent: 0,
                        seq: i as u64,
                        steps,
                        envs,
                        data: vec![1.0; steps * envs * w],
                        ready: Clock(i as f64),
                    }],
                    ready: Clock(i as f64),
                };
                for b in bt.push(pkt, Clock(i as f64 + 0.5)) {
                    emitted += b.samples;
                    // every emitted batch is complete on all channels
                    assert_eq!(b.data.len(), ChannelKind::ALL.len(), "case {case}");
                    assert_eq!(b.data[&ChannelKind::State].len(), b.samples * 6);
                }
            }
        }
        let pending = bt.pending_samples(ChannelKind::State);
        assert_eq!(pushed, emitted + pending, "case {case}: sample leak");
        assert!(pending < batch, "case {case}: batcher under-emitted");
    }
}

#[test]
fn prop_compressor_conserves_bytes() {
    let mut rng = Rng(0xd00d);
    for _ in 0..40 {
        let threshold = rng.range(8, 256);
        let mut cp = Compressor::new(ShareMode::MultiChannel, threshold);
        let mut bytes_in = 0usize;
        let mut bytes_out = 0usize;
        for i in 0..rng.range(3, 40) {
            let envs = rng.range(1, 64);
            let chunk = Chunk {
                channel: ChannelKind::State,
                agent: rng.range(0, 5),
                seq: i as u64,
                steps: 1,
                envs,
                data: vec![0.5; envs * 10],
                ready: Clock(i as f64),
            };
            bytes_in += chunk.bytes();
            for p in cp.push(vec![chunk]) {
                bytes_out += p.bytes();
            }
        }
        for p in cp.flush() {
            bytes_out += p.bytes();
        }
        assert_eq!(bytes_in, bytes_out, "compressor must not drop/duplicate data");
        assert_eq!(cp.staged_bytes(), 0);
    }
}

#[test]
fn prop_manager_never_oversubscribes() {
    let mut rng = Rng(0xabad);
    for _ in 0..60 {
        let gpus = rng.range(1, 8);
        let mut mgr = GmiManager::new(Topology::dgx_a100(gpus));
        for id in 0..rng.range(1, 24) {
            let share = rng.range(5, 60) as f64 / 100.0;
            let _ = mgr.add_gmi(GmiSpec {
                id,
                gpu: rng.range(0, gpus - 1),
                sm_share: share,
                mem_gib: rng.range(1, 20) as f64,
                backend: GmiBackend::Mps,
                role: Role::Holistic,
                num_env: 256,
            });
        }
        // invariant: accepted shares and memory never exceed capacity
        for gpu in 0..gpus {
            let share: f64 =
                mgr.all().filter(|g| g.gpu == gpu).map(|g| g.sm_share).sum();
            let mem: f64 = mgr.all().filter(|g| g.gpu == gpu).map(|g| g.mem_gib).sum();
            assert!(share <= 1.0 + 1e-9, "GPU {gpu} share {share}");
            assert!(mem <= 40.0 + 1e-9, "GPU {gpu} mem {mem}");
        }
        // mapping list covers exactly the registered GMIs
        let mpl = mgr.mapping_list(|_| true);
        let count: usize = mpl.iter().map(|v| v.len()).sum();
        assert_eq!(count, mgr.len());
    }
}

#[test]
fn prop_pack_jobs_never_oversubscribes_any_gpu() {
    let mut rng = Rng(0x5eed);
    let mut packed = 0usize;
    for case in 0..150 {
        let gpus = rng.range(1, 8);
        let topo = Topology::dgx_a100(gpus);
        let backend = if rng.range(0, 1) == 0 { GmiBackend::Mps } else { GmiBackend::Mig };
        let jobs: Vec<Job> = (0..rng.range(1, 2 * gpus))
            .map(|id| Job {
                id,
                sm_demand: rng.range(5, 100) as f64 / 100.0,
                mem_gib: rng.range(1, 20) as f64,
            })
            .collect();
        // Over-full job sets may legitimately be rejected; accepted
        // schedules must satisfy every per-GPU invariant.
        let Ok(s) = pack_jobs(&topo, &jobs, backend) else { continue };
        packed += 1;
        assert_eq!(s.placements.len(), jobs.len(), "case {case}: job dropped");
        for gpu in 0..gpus {
            let on_gpu: Vec<_> = s.placements.iter().filter(|p| p.gpu == gpu).collect();
            let sm: f64 = on_gpu.iter().map(|p| p.sm_share).sum();
            assert!(sm <= 1.0 + 1e-9, "case {case}: GPU {gpu} SM {sm}");
            // Effective memory: MIG reserves at least the profile quota.
            let mem: f64 = on_gpu
                .iter()
                .map(|p| {
                    let want = jobs[p.job].mem_gib;
                    backend.mem_quota_gib(p.sm_share).map(|q| q.max(want)).unwrap_or(want)
                })
                .sum();
            assert!(mem <= 40.0 + 1e-9, "case {case}: GPU {gpu} mem {mem}");
        }
        // Quantization never under-provisions a job's demand.
        for p in &s.placements {
            assert!(p.sm_share + 1e-9 >= jobs[p.job].sm_demand, "case {case}");
        }
    }
    assert!(packed > 50, "generator produced too few packable cases: {packed}");
}

#[test]
fn prop_pack_jobs_never_uses_more_gpus_than_exclusive_baseline() {
    let mut rng = Rng(0xa110);
    for case in 0..150 {
        let gpus = rng.range(1, 8);
        let topo = Topology::dgx_a100(gpus);
        // At most one job per GPU so the exclusive baseline is feasible.
        let jobs: Vec<Job> = (0..rng.range(1, gpus))
            .map(|id| Job {
                id,
                sm_demand: rng.range(5, 100) as f64 / 100.0,
                mem_gib: rng.range(1, 20) as f64,
            })
            .collect();
        let base = one_job_per_gpu(&topo, &jobs).unwrap();
        for backend in [GmiBackend::Mps, GmiBackend::Mig, GmiBackend::DirectShare] {
            let s = pack_jobs(&topo, &jobs, backend)
                .unwrap_or_else(|e| panic!("case {case}: baseline-feasible set rejected: {e}"));
            assert!(
                s.gpus_used <= base.gpus_used,
                "case {case} {backend:?}: packed onto {} GPUs, baseline {}",
                s.gpus_used,
                base.gpus_used
            );
        }
    }
}

/// Per-GPU placement invariants that must hold after EVERY manager
/// operation: SM shares within capacity, memory within HBM, each share in
/// (0, 1], MIG memory within the covering profile's quota.
fn assert_layout_valid(mgr: &GmiManager, gpus: usize, ctx: &str) {
    for gpu in 0..gpus {
        let share: f64 = mgr.all().filter(|g| g.gpu == gpu).map(|g| g.sm_share).sum();
        let mem: f64 = mgr.all().filter(|g| g.gpu == gpu).map(|g| g.mem_gib).sum();
        assert!(share <= 1.0 + 1e-9, "{ctx}: GPU {gpu} SM oversubscribed at {share}");
        assert!(mem <= 40.0 + 1e-9, "{ctx}: GPU {gpu} memory oversubscribed at {mem}");
    }
    for g in mgr.all() {
        assert!(
            g.sm_share > 0.0 && g.sm_share <= 1.0 + 1e-9,
            "{ctx}: GMI {} invalid share {}",
            g.id,
            g.sm_share
        );
        if let Some(quota) = g.backend.mem_quota_gib(g.sm_share) {
            assert!(
                g.mem_gib <= quota + 1e-9,
                "{ctx}: GMI {} exceeds MIG quota ({} > {quota})",
                g.id,
                g.mem_gib
            );
        }
    }
}

#[test]
fn prop_resize_remove_sequences_never_invalidate_layouts() {
    // Arbitrary valid layouts + arbitrary resize_gmi / remove_gmi / re-add
    // sequences (many of which the manager must reject): after every
    // operation — accepted or not — the layout stays valid. This is the
    // contract the serving autoscaler and the elastic controller lean on.
    let mut rng = Rng(0xe1a571c);
    for case in 0..60 {
        let gpus = rng.range(1, 4);
        let mut mgr = GmiManager::new(Topology::dgx_a100(gpus));
        let backend = if rng.range(0, 1) == 0 { GmiBackend::Mps } else { GmiBackend::Mig };
        let mut ids: Vec<usize> = Vec::new();
        let mut next_id = 0usize;
        for _ in 0..rng.range(2, 10) {
            let share = if backend == GmiBackend::Mig {
                rng.range(1, 3) as f64 / 7.0
            } else {
                rng.range(5, 30) as f64 / 100.0
            };
            let ok = mgr
                .add_gmi(GmiSpec {
                    id: next_id,
                    gpu: rng.range(0, gpus - 1),
                    sm_share: share,
                    mem_gib: rng.range(1, 5) as f64,
                    backend,
                    role: Role::SimAgent,
                    num_env: 64,
                })
                .is_ok();
            if ok {
                ids.push(next_id);
            }
            next_id += 1;
        }
        assert_layout_valid(&mgr, gpus, &format!("case {case} setup"));
        for step in 0..40 {
            let ctx = format!("case {case} step {step}");
            match rng.range(0, 3) {
                // resize, including deliberately invalid shares (> 1, too
                // much memory) the manager must reject atomically.
                0 | 1 => {
                    if ids.is_empty() {
                        continue;
                    }
                    let pick = ids[rng.range(0, ids.len() - 1)];
                    let share = rng.range(1, 120) as f64 / 100.0;
                    let mem = rng.range(1, 50) as f64;
                    let _ = mgr.resize_gmi(pick, share, mem);
                }
                // remove: frees capacity and must drop group membership.
                2 => {
                    if ids.is_empty() {
                        continue;
                    }
                    let pick = ids[rng.range(0, ids.len() - 1)];
                    if mgr.remove_gmi(pick).is_ok() {
                        ids.retain(|&i| i != pick);
                    }
                }
                // re-add into whatever capacity the churn has freed.
                _ => {
                    let ok = mgr
                        .add_gmi(GmiSpec {
                            id: next_id,
                            gpu: rng.range(0, gpus - 1),
                            sm_share: if backend == GmiBackend::Mig {
                                rng.range(1, 3) as f64 / 7.0
                            } else {
                                rng.range(5, 40) as f64 / 100.0
                            },
                            mem_gib: rng.range(1, 5) as f64,
                            backend,
                            role: Role::SimAgent,
                            num_env: 64,
                        })
                        .is_ok();
                    if ok {
                        ids.push(next_id);
                    }
                    next_id += 1;
                }
            }
            assert_layout_valid(&mgr, gpus, &ctx);
        }
    }
}

#[test]
fn prop_engine_elastic_ops_keep_live_manager_valid() {
    // The same invariants through the engine's elastic surface
    // (resize_share / add_gmi / remove_gmi), which refreshes executors as
    // provisioning changes — the autoscaler's actual call path.
    use gmi_drl::engine::Engine;

    let mut rng = Rng(0x11a57);
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    for case in 0..25 {
        let gpus = rng.range(1, 3);
        let mut mgr = GmiManager::new(Topology::dgx_a100(gpus));
        let mut next_id = 0usize;
        for gpu in 0..gpus {
            for _ in 0..rng.range(1, 3) {
                mgr.add_gmi(GmiSpec {
                    id: next_id,
                    gpu,
                    sm_share: 0.2,
                    mem_gib: 3.0,
                    backend: GmiBackend::Mps,
                    role: Role::SimAgent,
                    num_env: 64,
                })
                .unwrap();
                next_id += 1;
            }
        }
        let all: Vec<usize> = mgr.all().map(|g| g.id).collect();
        let mut engine = Engine::new(&mgr, &cost);
        let mut live: Vec<usize> = Vec::new();
        for &g in &all {
            engine.add_executor(g).unwrap();
            live.push(g);
        }
        for step in 0..30 {
            let ctx = format!("case {case} step {step}");
            match rng.range(0, 2) {
                0 => {
                    if live.is_empty() {
                        continue;
                    }
                    let pick = live[rng.range(0, live.len() - 1)];
                    let _ = engine.resize_share(pick, rng.range(1, 110) as f64 / 100.0);
                }
                1 => {
                    if live.len() <= 1 {
                        continue;
                    }
                    let pick = live[rng.range(0, live.len() - 1)];
                    if engine.remove_gmi(pick).is_ok() {
                        live.retain(|&i| i != pick);
                    }
                }
                _ => {
                    let spec = GmiSpec {
                        id: next_id,
                        gpu: rng.range(0, gpus - 1),
                        sm_share: rng.range(5, 40) as f64 / 100.0,
                        mem_gib: 3.0,
                        backend: GmiBackend::Mps,
                        role: Role::SimAgent,
                        num_env: 64,
                    };
                    if engine.add_gmi(spec).is_ok() {
                        live.push(next_id);
                    }
                    next_id += 1;
                }
            }
            assert_layout_valid(engine.manager(), gpus, &ctx);
            // Live executors track their manager spec: effective share for
            // an MPS GMI is exactly the provisioned share.
            for &g in &live {
                let spec = engine.manager().gmi(g).expect("live GMI registered");
                assert!(spec.sm_share > 0.0, "{ctx}: GMI {g} zero share");
            }
        }
    }
}

#[test]
fn prop_cost_model_monotonicity() {
    let mut rng = Rng(0x1234);
    let reg = static_registry();
    let benches: Vec<_> = reg.values().collect();
    for _ in 0..100 {
        let b = benches[rng.range(0, benches.len() - 1)];
        let cost = CostModel::new(b);
        let n = rng.range(64, 8192);
        let s1 = rng.range(10, 99) as f64 / 100.0;
        let s2 = (s1 + 0.01).min(1.0);
        for op in [
            OpKind::SimStep { num_env: n },
            OpKind::PolicyFwd { num_env: n },
            OpKind::TrainGrad { samples: n },
        ] {
            // more share never hurts
            let t1 = cost.op_time(op, s1, 1.0);
            let t2 = cost.op_time(op, s2, 1.0);
            assert!(t2 <= t1 + 1e-12, "{op:?} share {s1}->{s2}: {t1} -> {t2}");
            // interference never helps
            assert!(cost.op_time(op, s1, 1.3) >= t1);
            // more work never takes less time
            let big = match op {
                OpKind::SimStep { .. } => OpKind::SimStep { num_env: n * 2 },
                OpKind::PolicyFwd { .. } => OpKind::PolicyFwd { num_env: n * 2 },
                OpKind::TrainGrad { .. } => OpKind::TrainGrad { samples: n * 2 },
                x => x,
            };
            assert!(cost.op_time(big, s1, 1.0) > t1);
        }
        // memory monotone in num_env
        assert!(cost.mem_gib(n * 2, 16, true, true) > cost.mem_gib(n, 16, true, true));
    }
}

#[test]
fn prop_clock_merges_are_monotone() {
    let mut rng = Rng(0x777);
    for _ in 0..100 {
        let mut c = Clock::zero();
        let mut last = 0.0f64;
        for _ in 0..rng.range(1, 50) {
            let before = c.seconds();
            if rng.range(0, 1) == 0 {
                c.advance(rng.range(0, 1000) as f64 / 1000.0);
            } else {
                let other = Clock(rng.range(0, 2000) as f64 / 1000.0);
                c.merge_then_advance(other, rng.range(0, 100) as f64 / 1000.0);
            }
            assert!(c.seconds() >= before, "clock went backwards");
            last = c.seconds();
        }
        assert!(last.is_finite());
    }
}
