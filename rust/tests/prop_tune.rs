//! Property tests for the online auto-tuner (ISSUE 7 acceptance):
//!
//! 1. The tuned configuration's MEASURED throughput is >= the Algorithm-2
//!    `explore()` pick and >= the hand-picked default on the same scenario
//!    — externally re-measured through the same drivers the tuner probed,
//!    not taken from the tuner's own report.
//! 2. Tuner decisions are bit-identical across repeated runs (the full
//!    report: choice, probe log, charges).
//! 3. Probe charging never exceeds the configured budget, at any budget —
//!    including a starved budget, which must degrade deterministically to
//!    the cost-model pick without running (or charging) anything.

use gmi_drl::cluster::Topology;
use gmi_drl::config::static_registry;
use gmi_drl::drl::sync::{run_sync, SyncConfig};
use gmi_drl::drl::Compute;
use gmi_drl::gmi::GmiBackend;
use gmi_drl::mapping::{build_gateway_fleet, build_sync_layout, MappingTemplate};
use gmi_drl::selection;
use gmi_drl::serve::{generate_trace, run_gateway, GatewayConfig, Request, TrafficPattern};
use gmi_drl::tune::{
    tune_gateway, tune_sync, GatewaySpace, SyncChoice, SyncSpace, TuneConfig,
};
use gmi_drl::vtime::CostModel;

fn setup() -> (Topology, gmi_drl::BenchInfo, CostModel) {
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    (Topology::dgx_a100(2), b, cost)
}

/// Re-measure a sync choice EXACTLY the way the tuner's full-fidelity
/// final lock does: the real `run_sync` driver, probe iteration count,
/// elasticity off, full rollout horizon.
fn measure_sync(
    topo: &Topology,
    bench: &gmi_drl::BenchInfo,
    cost: &CostModel,
    base: &SyncConfig,
    tcfg: &TuneConfig,
    c: &SyncChoice,
) -> f64 {
    let layout = build_sync_layout(
        topo,
        MappingTemplate::TaskColocated,
        c.gmi_per_gpu,
        c.num_env,
        cost,
        Some(GmiBackend::Mps),
    )
    .unwrap();
    let cfg = SyncConfig { iterations: tcfg.probe_iters, elastic: None, ..c.apply(base) };
    run_sync(&layout, bench, cost, &Compute::Null, &cfg).unwrap().metrics.steps_per_sec
}

#[test]
fn tuned_sync_beats_or_matches_explore_pick_and_hand_picked_default() {
    let (topo, b, cost) = setup();
    // A long projected run makes 1% a workable probe budget — the tuner
    // must still land under it.
    let base = SyncConfig { iterations: 40_000, ..SyncConfig::default() };
    let default_point = (2, 512); // a plausible hand-picked layout
    let tcfg = TuneConfig { probe_iters: 4, ..TuneConfig::default() };
    let rep = tune_sync(
        &topo,
        MappingTemplate::TaskColocated,
        Some(GmiBackend::Mps),
        &b,
        &cost,
        &base,
        default_point,
        &SyncSpace::default(),
        &tcfg,
    )
    .unwrap();
    assert!(!rep.fallback, "1% of a 40k-iteration run must fund probes");
    assert!(!rep.probes.is_empty());

    // Budget discipline: charged <= budget, and budget is 1% of horizon.
    assert!(rep.probe_cost_s <= rep.budget_s + 1e-9);
    assert!(
        rep.probe_cost_s < 0.01 * rep.run_horizon_s + 1e-9,
        "probe time {} must stay under 1% of the {}s run horizon",
        rep.probe_cost_s,
        rep.run_horizon_s
    );

    // External re-measurement: tuned vs the two protected references,
    // through the same driver the long run uses.
    let tuned = measure_sync(&topo, &b, &cost, &base, &tcfg, &rep.choice);
    assert_eq!(
        tuned.to_bits(),
        rep.objective.to_bits(),
        "the locked objective must be reproducible by an external run"
    );

    let explore_pick = selection::explore(&b, &cost, GmiBackend::Mps, 2, b.horizon)
        .0
        .expect("Algorithm 2 finds a configuration for AT");
    let base_knobs = |g: usize, e: usize| SyncChoice {
        gmi_per_gpu: g,
        num_env: e,
        minibatches: base.minibatches,
        strategy: base.strategy_override,
        overlap: base.overlap,
    };
    let explore_sps = measure_sync(
        &topo,
        &b,
        &cost,
        &base,
        &tcfg,
        &base_knobs(explore_pick.gmi_per_gpu, explore_pick.num_env),
    );
    let default_sps =
        measure_sync(&topo, &b, &cost, &base, &tcfg, &base_knobs(default_point.0, default_point.1));
    assert!(
        tuned >= explore_sps,
        "tuned {tuned} steps/s must match or beat the Algorithm-2 pick {explore_sps}"
    );
    assert!(
        tuned >= default_sps,
        "tuned {tuned} steps/s must match or beat the hand-picked default {default_sps}"
    );
}

#[test]
fn sync_tuner_decisions_are_bit_identical_across_runs() {
    let (topo, b, cost) = setup();
    let base = SyncConfig { iterations: 40_000, ..SyncConfig::default() };
    let tcfg = TuneConfig { probe_iters: 3, ..TuneConfig::default() };
    let run = || {
        tune_sync(
            &topo,
            MappingTemplate::TaskColocated,
            None,
            &b,
            &cost,
            &base,
            (2, 512),
            &SyncSpace::default(),
            &tcfg,
        )
        .unwrap()
    };
    let (r1, r2) = (run(), run());
    assert_eq!(r1.choice, r2.choice);
    assert_eq!(r1.objective.to_bits(), r2.objective.to_bits());
    assert_eq!(r1.probe_cost_s.to_bits(), r2.probe_cost_s.to_bits());
    assert_eq!(r1.probes.len(), r2.probes.len());
    for (a, b) in r1.probes.iter().zip(&r2.probes) {
        assert_eq!(a.label, b.label);
        assert_eq!(a.fidelity, b.fidelity);
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
        assert_eq!(a.cost_s.to_bits(), b.cost_s.to_bits());
    }
    assert_eq!(r1, r2, "the full reports must compare equal");
}

#[test]
fn starved_budget_degrades_to_the_cost_model_pick_without_charging() {
    let (topo, b, cost) = setup();
    // Two iterations project a tiny run; 1% of it funds no probe at all.
    let base = SyncConfig { iterations: 2, ..SyncConfig::default() };
    let rep = tune_sync(
        &topo,
        MappingTemplate::TaskColocated,
        Some(GmiBackend::Mps),
        &b,
        &cost,
        &base,
        (2, 512),
        &SyncSpace::default(),
        &TuneConfig::default(),
    )
    .unwrap();
    assert!(rep.fallback, "a starved budget must fall back");
    assert!(rep.probes.is_empty(), "fallback must not have probed");
    assert_eq!(rep.probe_cost_s, 0.0, "fallback must not have charged");
    // The fallback IS the Algorithm-2 pick with the base knobs.
    let explore_pick =
        selection::explore(&b, &cost, GmiBackend::Mps, 2, b.horizon).0.unwrap();
    assert_eq!(rep.choice.gmi_per_gpu, explore_pick.gmi_per_gpu);
    assert_eq!(rep.choice.num_env, explore_pick.num_env);
    assert_eq!(rep.choice.minibatches, base.minibatches);
    assert_eq!(rep.choice.strategy, base.strategy_override);
    assert_eq!(rep.choice.overlap, base.overlap);
    // Deterministic fallback too.
    let rep2 = tune_sync(
        &topo,
        MappingTemplate::TaskColocated,
        Some(GmiBackend::Mps),
        &b,
        &cost,
        &base,
        (2, 512),
        &SyncSpace::default(),
        &TuneConfig::default(),
    )
    .unwrap();
    assert_eq!(rep, rep2);
}

#[test]
fn probe_charging_never_exceeds_budget_at_any_budget() {
    let (topo, b, cost) = setup();
    for (iters, frac) in [(2usize, 0.01), (400, 0.01), (40_000, 0.01), (40_000, 0.0005)] {
        let base = SyncConfig { iterations: iters, ..SyncConfig::default() };
        let tcfg = TuneConfig { budget_frac: frac, ..TuneConfig::default() };
        let rep = tune_sync(
            &topo,
            MappingTemplate::TaskColocated,
            Some(GmiBackend::Mps),
            &b,
            &cost,
            &base,
            (2, 512),
            &SyncSpace::default(),
            &tcfg,
        )
        .unwrap();
        assert!(
            rep.probe_cost_s <= rep.budget_s + 1e-9,
            "iters={iters} frac={frac}: charged {} of {}",
            rep.probe_cost_s,
            rep.budget_s
        );
        assert!(
            rep.budget_s <= frac * rep.run_horizon_s + 1e-9,
            "iters={iters} frac={frac}: budget exceeds its fraction"
        );
    }
}

/// The gateway objective the tuner scores probes with: served/s when the
/// SLO held, `-p99` when it did not (any feasible policy dominates).
fn gateway_score(
    layout: &gmi_drl::mapping::Layout,
    bench: &gmi_drl::BenchInfo,
    cost: &CostModel,
    trace: &[Request],
    base: &GatewayConfig,
    max_batch: usize,
    max_wait_s: f64,
) -> f64 {
    let cfg = GatewayConfig { max_batch, max_wait_s, autoscale: None, ..*base };
    let r = run_gateway(layout, bench, cost, trace, &cfg).unwrap();
    if r.latency.p99_s <= base.slo_s {
        r.latency.served as f64 / r.metrics.span_s.max(1e-12)
    } else {
        -r.latency.p99_s
    }
}

#[test]
fn tuned_gateway_beats_or_matches_the_default_policy_on_the_full_trace() {
    let (topo, b, cost) = setup();
    let trace = generate_trace(&TrafficPattern::Poisson { rate: 3000.0 }, 0.4, 11, 4);
    // Fleet provisioned for the largest candidate batch, as the CLI does
    // under --autotune.
    let layout = build_gateway_fleet(&topo, 2, 4, 64, &cost, None).unwrap();
    let base = GatewayConfig { slo_s: 20e-3, ..GatewayConfig::default() };
    // A generous budget drives the final lock to the FULL trace, so the
    // external full-trace comparison below is exact, not sampled.
    let tcfg = TuneConfig { budget_frac: 8.0, ..TuneConfig::default() };
    let rep =
        tune_gateway(&layout, &b, &cost, &trace, &base, &GatewaySpace::default(), &tcfg).unwrap();
    assert!(!rep.fallback);
    assert!(rep.probe_cost_s <= rep.budget_s + 1e-9);
    // The top rung is the full trace.
    assert_eq!(rep.probes.last().unwrap().fidelity, trace.len());

    let tuned = gateway_score(
        &layout, &b, &cost, &trace, &base, rep.choice.max_batch, rep.choice.max_wait_s,
    );
    assert_eq!(
        tuned.to_bits(),
        rep.objective.to_bits(),
        "the locked objective must be reproducible externally"
    );
    let default =
        gateway_score(&layout, &b, &cost, &trace, &base, base.max_batch, base.max_wait_s);
    assert!(
        tuned >= default,
        "tuned policy score {tuned} must match or beat the hand-picked default {default}"
    );

    // And the decision is bit-identical run-to-run.
    let rep2 =
        tune_gateway(&layout, &b, &cost, &trace, &base, &GatewaySpace::default(), &tcfg).unwrap();
    assert_eq!(rep, rep2);
}

#[test]
fn gateway_probe_charging_respects_tight_budgets() {
    let (topo, b, cost) = setup();
    let trace = generate_trace(&TrafficPattern::Poisson { rate: 3000.0 }, 0.4, 11, 4);
    let layout = build_gateway_fleet(&topo, 2, 4, 64, &cost, None).unwrap();
    let base = GatewayConfig { slo_s: 20e-3, ..GatewayConfig::default() };
    for frac in [1e-6, 0.05, 0.5] {
        let tcfg = TuneConfig { budget_frac: frac, ..TuneConfig::default() };
        let rep =
            tune_gateway(&layout, &b, &cost, &trace, &base, &GatewaySpace::default(), &tcfg)
                .unwrap();
        assert!(
            rep.probe_cost_s <= rep.budget_s + 1e-9,
            "frac={frac}: charged {} of {}",
            rep.probe_cost_s,
            rep.budget_s
        );
        if rep.fallback {
            // A starved gateway tuner keeps the hand-picked policy.
            assert_eq!(rep.choice.max_batch, base.max_batch);
            assert_eq!(rep.choice.max_wait_s.to_bits(), base.max_wait_s.to_bits());
            assert!(rep.probes.is_empty());
        }
    }
}
