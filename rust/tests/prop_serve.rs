//! Queueing-theory property tests on the serving gateway.
//!
//! Same methodology as the other property suites (no proptest crate
//! offline): seeded SplitMix64 case generation, universal assertions,
//! deterministic on failure. Capacities are derived from the cost model
//! itself — one batch's request hop + batched forward + response hop —
//! so the properties stay valid if the calibrated constants move.

use gmi_drl::cluster::Topology;
use gmi_drl::config::static_registry;
use gmi_drl::mapping::{build_gateway_fleet, Layout};
use gmi_drl::serve::{
    batch_seconds, generate_trace, run_gateway, AutoscaleConfig, GatewayConfig, ScaleAction,
    TrafficPattern,
};
use gmi_drl::vtime::CostModel;
use gmi_drl::BenchInfo;

/// Deterministic PRNG (SplitMix64).
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next() as usize) % (hi - lo + 1)
    }
}

fn bench_and_cost() -> (BenchInfo, CostModel) {
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    (b, cost)
}

fn fleet(topo: &Topology, initial: usize, max: usize, batch: usize, cost: &CostModel) -> Layout {
    build_gateway_fleet(topo, initial, max, batch, cost, None).unwrap()
}

#[test]
fn prop_p99_monotone_nondecreasing_in_arrival_rate() {
    // Fixed capacity, no batching slack (max_batch = 1, so the dynamic
    // batching deadline cannot trade wait for service), constant arrivals:
    // a faster arrival rate can only queue more. p99 must be monotone
    // nondecreasing across the sweep, from well under to well past
    // capacity.
    let (b, cost) = bench_and_cost();
    let topo = Topology::dgx_a100(1);
    let layout = fleet(&topo, 2, 4, 1, &cost);
    let serial = batch_seconds(&b, &cost, &topo, 0.25, 1);
    let per_gmi = 1.0 / serial;
    let cfg = GatewayConfig {
        max_batch: 1,
        max_wait_s: 1e-3,
        admission_cap: None,
        slo_s: 10e-3,
        autoscale: None,
    };
    let mut last = 0.0f64;
    for frac in [0.2, 0.5, 0.8, 1.2, 1.6, 2.0] {
        let rate = frac * per_gmi;
        let trace = generate_trace(&TrafficPattern::Constant { rate }, 0.4, 0, 4);
        let r = run_gateway(&layout, &b, &cost, &trace, &cfg).unwrap();
        assert!(
            r.latency.p99_s >= last - 1e-9,
            "p99 decreased with load: {} -> {} at frac {frac}",
            last,
            r.latency.p99_s
        );
        last = r.latency.p99_s;
    }
    // And the sweep actually exercised queueing: overload p99 must far
    // exceed the unloaded service time.
    assert!(last > 10.0 * serial, "overload never queued: p99 {last}");
}

#[test]
fn prop_queue_stays_bounded_below_capacity() {
    // Offered load at half of one GMI's guaranteed serial rate (the fleet
    // has two): outstanding work must stay bounded — a few batches, not a
    // growing backlog — and the queue must drain right after the trace.
    let (b, cost) = bench_and_cost();
    let topo = Topology::dgx_a100(1);
    let batch = 16;
    let layout = fleet(&topo, 2, 4, batch, &cost);
    let serial = batch_seconds(&b, &cost, &topo, 0.25, batch);
    let rate = 0.5 * batch as f64 / serial;
    let cfg = GatewayConfig {
        max_batch: batch,
        max_wait_s: 1e-3,
        admission_cap: None,
        slo_s: 10e-3,
        autoscale: None,
    };
    for (seed, duration) in [(1u64, 0.3f64), (2, 0.6)] {
        let trace =
            generate_trace(&TrafficPattern::Poisson { rate }, duration, seed, 4);
        let r = run_gateway(&layout, &b, &cost, &trace, &cfg).unwrap();
        assert_eq!(r.served.len(), trace.len());
        assert!(
            r.latency.max_queue_depth <= 8 * batch,
            "backlog grew under sub-capacity load: depth {} (seed {seed})",
            r.latency.max_queue_depth
        );
        // Drain promptly: the last completion lands within a handful of
        // batch times of the last arrival (no hidden unbounded queue).
        let last_arrival = trace.last().unwrap().arrival_s;
        let last_done = r
            .served
            .iter()
            .map(|s| s.completion_s)
            .fold(0.0f64, f64::max);
        assert!(
            last_done - last_arrival <= 12.0 * serial + cfg.max_wait_s,
            "queue did not drain: {} past last arrival (seed {seed})",
            last_done - last_arrival
        );
        // Doubling the duration must not change the conclusion (stationary
        // backlog), which the loop's second iteration checks.
    }
}

#[test]
fn prop_batching_never_reorders_requests_from_one_source() {
    // Across random load levels and batching configs: requests of the same
    // source are dispatched in arrival order — batch indices nondecreasing
    // and ids increasing along the dispatch sequence.
    let (b, cost) = bench_and_cost();
    let topo = Topology::dgx_a100(1);
    let mut rng = Rng(0x5e8ef);
    for case in 0..6 {
        let batch = [1, 4, 16, 32][rng.range(0, 3)];
        let layout = fleet(&topo, rng.range(1, 3), 4, batch, &cost);
        let serial = batch_seconds(&b, &cost, &topo, 0.25, batch.max(1));
        let rate = (rng.range(20, 300) as f64 / 100.0) * batch as f64 / serial;
        let sources = rng.range(1, 6);
        let trace = generate_trace(
            &TrafficPattern::Poisson { rate },
            0.15,
            case as u64 + 77,
            sources,
        );
        let cfg = GatewayConfig {
            max_batch: batch,
            max_wait_s: rng.range(1, 20) as f64 * 1e-4,
            admission_cap: None,
            slo_s: 10e-3,
            autoscale: None,
        };
        let r = run_gateway(&layout, &b, &cost, &trace, &cfg).unwrap();
        assert_eq!(r.served.len(), trace.len(), "case {case}: request lost");
        let mut last: Vec<Option<(usize, usize)>> = vec![None; sources];
        for s in &r.served {
            if let Some((prev_batch, prev_id)) = last[s.source] {
                assert!(
                    s.batch >= prev_batch,
                    "case {case}: source {} batch order {prev_batch} -> {}",
                    s.source,
                    s.batch
                );
                assert!(
                    s.id > prev_id,
                    "case {case}: source {} id order {prev_id} -> {}",
                    s.source,
                    s.id
                );
            }
            last[s.source] = Some((s.batch, s.id));
        }
    }
}

#[test]
fn prop_autoscaler_never_oversubscribes_and_respects_floors() {
    // Random traffic (bursts and diurnal swings) through the autoscaled
    // gateway: whatever the scaler did, the final fleet must be a valid
    // placement — per-GPU SM shares sum to <= 1, memory within capacity,
    // every member at or above its validated share floor — and the fleet
    // size must have stayed within [min_fleet, gpus * max_per_gpu].
    let (b, cost) = bench_and_cost();
    let mut rng = Rng(0xa5ca1e);
    for case in 0..6 {
        let gpus = rng.range(1, 2);
        let topo = Topology::dgx_a100(gpus);
        let batch = 16;
        let initial = rng.range(1, 2);
        let max_per = rng.range(3, 5);
        let layout = fleet(&topo, initial, max_per, batch, &cost);
        let base_share = layout.manager.all().next().unwrap().sm_share;
        let serial = batch_seconds(&b, &cost, &topo, base_share, batch);
        let cap = (gpus * initial) as f64 * batch as f64 / serial;
        let pattern = if case % 2 == 0 {
            TrafficPattern::Burst {
                base: 0.4 * cap,
                burst: (rng.range(15, 30) as f64 / 10.0) * cap,
                start_s: 0.04,
                len_s: 0.05,
            }
        } else {
            TrafficPattern::Diurnal {
                base: 0.3 * cap,
                peak: (rng.range(15, 30) as f64 / 10.0) * cap,
                period_s: 0.1,
            }
        };
        let trace = generate_trace(&pattern, 0.15, case as u64 + 5, 4);
        let min_fleet = rng.range(1, gpus * initial);
        let auto = AutoscaleConfig {
            window_s: 0.01,
            slo_p99_s: 4e-3,
            min_fleet,
            max_per_gpu: max_per,
            min_share: 0.05,
            cooldown_windows: rng.range(0, 1),
            ..Default::default()
        };
        let cfg = GatewayConfig {
            max_batch: batch,
            max_wait_s: 1e-3,
            admission_cap: None,
            slo_s: 4e-3,
            autoscale: Some(auto.clone()),
        };
        let r = run_gateway(&layout, &b, &cost, &trace, &cfg).unwrap();
        // Placement validity of the final fleet.
        for gpu in 0..gpus {
            let share: f64 = r
                .final_fleet
                .iter()
                .filter(|g| g.gpu == gpu)
                .map(|g| g.sm_share)
                .sum();
            let mem: f64 = r
                .final_fleet
                .iter()
                .filter(|g| g.gpu == gpu)
                .map(|g| g.mem_gib)
                .sum();
            let members = r.final_fleet.iter().filter(|g| g.gpu == gpu).count();
            assert!(share <= 1.0 + 1e-9, "case {case}: GPU {gpu} share {share}");
            assert!(mem <= 40.0 + 1e-9, "case {case}: GPU {gpu} mem {mem}");
            assert!(
                members <= max_per,
                "case {case}: GPU {gpu} holds {members} > max {max_per}"
            );
        }
        // Every member at or above its validated floor.
        for g in &r.final_fleet {
            assert!(
                g.sm_share + 1e-9 >= base_share.min(auto.min_share),
                "case {case}: GMI {} below floor at {}",
                g.id,
                g.sm_share
            );
        }
        // Fleet size stayed within bounds at every scale step.
        for ev in &r.scale_events {
            assert!(
                ev.fleet_after >= min_fleet,
                "case {case}: shrank below min_fleet"
            );
            assert!(
                ev.fleet_after <= gpus * max_per,
                "case {case}: grew past the GPU caps"
            );
            match ev.action {
                ScaleAction::Grow => assert!(ev.fleet_after >= ev.fleet_before),
                ScaleAction::Shrink => assert!(ev.fleet_after <= ev.fleet_before),
            }
        }
        // Nothing was lost regardless of scaling.
        assert_eq!(r.served.len(), trace.len(), "case {case}");
    }
}
