//! Queueing-theory property tests on the serving gateway.
//!
//! Same methodology as the other property suites (no proptest crate
//! offline): seeded SplitMix64 case generation, universal assertions,
//! deterministic on failure. Capacities are derived from the cost model
//! itself — one batch's request hop + batched forward + response hop —
//! so the properties stay valid if the calibrated constants move.

use gmi_drl::cluster::Topology;
use gmi_drl::config::static_registry;
use gmi_drl::mapping::{build_gateway_fleet, Layout};
use gmi_drl::metrics::SampleReservoir;
use gmi_drl::serve::{
    batch_seconds, generate_trace, run_gateway, run_gateway_source, AutoscaleConfig,
    GatewayConfig, ScaleAction, TraceSource, TrafficPattern,
};
use gmi_drl::vtime::CostModel;
use gmi_drl::BenchInfo;

/// Deterministic PRNG (SplitMix64).
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next() as usize) % (hi - lo + 1)
    }
}

fn bench_and_cost() -> (BenchInfo, CostModel) {
    let b = static_registry()["AT"].clone();
    let cost = CostModel::new(&b);
    (b, cost)
}

fn fleet(topo: &Topology, initial: usize, max: usize, batch: usize, cost: &CostModel) -> Layout {
    build_gateway_fleet(topo, initial, max, batch, cost, None).unwrap()
}

#[test]
fn prop_p99_monotone_nondecreasing_in_arrival_rate() {
    // Fixed capacity, no batching slack (max_batch = 1, so the dynamic
    // batching deadline cannot trade wait for service), constant arrivals:
    // a faster arrival rate can only queue more. p99 must be monotone
    // nondecreasing across the sweep, from well under to well past
    // capacity.
    let (b, cost) = bench_and_cost();
    let topo = Topology::dgx_a100(1);
    let layout = fleet(&topo, 2, 4, 1, &cost);
    let serial = batch_seconds(&b, &cost, &topo, 0.25, 1);
    let per_gmi = 1.0 / serial;
    let cfg = GatewayConfig {
        max_batch: 1,
        max_wait_s: 1e-3,
        admission_cap: None,
        slo_s: 10e-3,
        autoscale: None,
        ..GatewayConfig::default()
    };
    let mut last = 0.0f64;
    for frac in [0.2, 0.5, 0.8, 1.2, 1.6, 2.0] {
        let rate = frac * per_gmi;
        let trace = generate_trace(&TrafficPattern::Constant { rate }, 0.4, 0, 4);
        let r = run_gateway(&layout, &b, &cost, &trace, &cfg).unwrap();
        assert!(
            r.latency.p99_s >= last - 1e-9,
            "p99 decreased with load: {} -> {} at frac {frac}",
            last,
            r.latency.p99_s
        );
        last = r.latency.p99_s;
    }
    // And the sweep actually exercised queueing: overload p99 must far
    // exceed the unloaded service time.
    assert!(last > 10.0 * serial, "overload never queued: p99 {last}");
}

#[test]
fn prop_queue_stays_bounded_below_capacity() {
    // Offered load at half of one GMI's guaranteed serial rate (the fleet
    // has two): outstanding work must stay bounded — a few batches, not a
    // growing backlog — and the queue must drain right after the trace.
    let (b, cost) = bench_and_cost();
    let topo = Topology::dgx_a100(1);
    let batch = 16;
    let layout = fleet(&topo, 2, 4, batch, &cost);
    let serial = batch_seconds(&b, &cost, &topo, 0.25, batch);
    let rate = 0.5 * batch as f64 / serial;
    let cfg = GatewayConfig {
        max_batch: batch,
        max_wait_s: 1e-3,
        admission_cap: None,
        slo_s: 10e-3,
        autoscale: None,
        ..GatewayConfig::default()
    };
    for (seed, duration) in [(1u64, 0.3f64), (2, 0.6)] {
        let trace =
            generate_trace(&TrafficPattern::Poisson { rate }, duration, seed, 4);
        let r = run_gateway(&layout, &b, &cost, &trace, &cfg).unwrap();
        assert_eq!(r.served.len(), trace.len());
        assert!(
            r.latency.max_queue_depth <= 8 * batch,
            "backlog grew under sub-capacity load: depth {} (seed {seed})",
            r.latency.max_queue_depth
        );
        // Drain promptly: the last completion lands within a handful of
        // batch times of the last arrival (no hidden unbounded queue).
        let last_arrival = trace.last().unwrap().arrival_s;
        let last_done = r
            .served
            .iter()
            .map(|s| s.completion_s)
            .fold(0.0f64, f64::max);
        assert!(
            last_done - last_arrival <= 12.0 * serial + cfg.max_wait_s,
            "queue did not drain: {} past last arrival (seed {seed})",
            last_done - last_arrival
        );
        // Doubling the duration must not change the conclusion (stationary
        // backlog), which the loop's second iteration checks.
    }
}

#[test]
fn prop_batching_never_reorders_requests_from_one_source() {
    // Across random load levels and batching configs: requests of the same
    // source are dispatched in arrival order — batch indices nondecreasing
    // and ids increasing along the dispatch sequence.
    let (b, cost) = bench_and_cost();
    let topo = Topology::dgx_a100(1);
    let mut rng = Rng(0x5e8ef);
    for case in 0..6 {
        let batch = [1, 4, 16, 32][rng.range(0, 3)];
        let layout = fleet(&topo, rng.range(1, 3), 4, batch, &cost);
        let serial = batch_seconds(&b, &cost, &topo, 0.25, batch.max(1));
        let rate = (rng.range(20, 300) as f64 / 100.0) * batch as f64 / serial;
        let sources = rng.range(1, 6);
        let trace = generate_trace(
            &TrafficPattern::Poisson { rate },
            0.15,
            case as u64 + 77,
            sources,
        );
        let cfg = GatewayConfig {
            max_batch: batch,
            max_wait_s: rng.range(1, 20) as f64 * 1e-4,
            admission_cap: None,
            slo_s: 10e-3,
            autoscale: None,
            ..GatewayConfig::default()
        };
        let r = run_gateway(&layout, &b, &cost, &trace, &cfg).unwrap();
        assert_eq!(r.served.len(), trace.len(), "case {case}: request lost");
        let mut last: Vec<Option<(usize, usize)>> = vec![None; sources];
        for s in &r.served {
            if let Some((prev_batch, prev_id)) = last[s.source] {
                assert!(
                    s.batch >= prev_batch,
                    "case {case}: source {} batch order {prev_batch} -> {}",
                    s.source,
                    s.batch
                );
                assert!(
                    s.id > prev_id,
                    "case {case}: source {} id order {prev_id} -> {}",
                    s.source,
                    s.id
                );
            }
            last[s.source] = Some((s.batch, s.id));
        }
    }
}

#[test]
fn prop_autoscaler_never_oversubscribes_and_respects_floors() {
    // Random traffic (bursts and diurnal swings) through the autoscaled
    // gateway: whatever the scaler did, the final fleet must be a valid
    // placement — per-GPU SM shares sum to <= 1, memory within capacity,
    // every member at or above its validated share floor — and the fleet
    // size must have stayed within [min_fleet, gpus * max_per_gpu].
    let (b, cost) = bench_and_cost();
    let mut rng = Rng(0xa5ca1e);
    for case in 0..6 {
        let gpus = rng.range(1, 2);
        let topo = Topology::dgx_a100(gpus);
        let batch = 16;
        let initial = rng.range(1, 2);
        let max_per = rng.range(3, 5);
        let layout = fleet(&topo, initial, max_per, batch, &cost);
        let base_share = layout.manager.all().next().unwrap().sm_share;
        let serial = batch_seconds(&b, &cost, &topo, base_share, batch);
        let cap = (gpus * initial) as f64 * batch as f64 / serial;
        let pattern = if case % 2 == 0 {
            TrafficPattern::Burst {
                base: 0.4 * cap,
                burst: (rng.range(15, 30) as f64 / 10.0) * cap,
                start_s: 0.04,
                len_s: 0.05,
            }
        } else {
            TrafficPattern::Diurnal {
                base: 0.3 * cap,
                peak: (rng.range(15, 30) as f64 / 10.0) * cap,
                period_s: 0.1,
            }
        };
        let trace = generate_trace(&pattern, 0.15, case as u64 + 5, 4);
        let min_fleet = rng.range(1, gpus * initial);
        let auto = AutoscaleConfig {
            window_s: 0.01,
            slo_p99_s: 4e-3,
            min_fleet,
            max_per_gpu: max_per,
            min_share: 0.05,
            cooldown_windows: rng.range(0, 1),
            ..Default::default()
        };
        let cfg = GatewayConfig {
            max_batch: batch,
            max_wait_s: 1e-3,
            admission_cap: None,
            slo_s: 4e-3,
            autoscale: Some(auto.clone()),
            ..GatewayConfig::default()
        };
        let r = run_gateway(&layout, &b, &cost, &trace, &cfg).unwrap();
        // Placement validity of the final fleet.
        for gpu in 0..gpus {
            let share: f64 = r
                .final_fleet
                .iter()
                .filter(|g| g.gpu == gpu)
                .map(|g| g.sm_share)
                .sum();
            let mem: f64 = r
                .final_fleet
                .iter()
                .filter(|g| g.gpu == gpu)
                .map(|g| g.mem_gib)
                .sum();
            let members = r.final_fleet.iter().filter(|g| g.gpu == gpu).count();
            assert!(share <= 1.0 + 1e-9, "case {case}: GPU {gpu} share {share}");
            assert!(mem <= 40.0 + 1e-9, "case {case}: GPU {gpu} mem {mem}");
            assert!(
                members <= max_per,
                "case {case}: GPU {gpu} holds {members} > max {max_per}"
            );
        }
        // Every member at or above its validated floor.
        for g in &r.final_fleet {
            assert!(
                g.sm_share + 1e-9 >= base_share.min(auto.min_share),
                "case {case}: GMI {} below floor at {}",
                g.id,
                g.sm_share
            );
        }
        // Fleet size stayed within bounds at every scale step.
        for ev in &r.scale_events {
            assert!(
                ev.fleet_after >= min_fleet,
                "case {case}: shrank below min_fleet"
            );
            assert!(
                ev.fleet_after <= gpus * max_per,
                "case {case}: grew past the GPU caps"
            );
            match ev.action {
                ScaleAction::Grow => assert!(ev.fleet_after >= ev.fleet_before),
                ScaleAction::Shrink => assert!(ev.fleet_after <= ev.fleet_before),
            }
        }
        // Nothing was lost regardless of scaling.
        assert_eq!(r.served.len(), trace.len(), "case {case}");
    }
}

// ---------------------------------------------------------------------------
// Week-scale fast path: streaming traces, macro aggregation, reservoirs
// ---------------------------------------------------------------------------

/// Bit-exact equality over everything a gateway run reports.
fn assert_runs_identical(
    a: &gmi_drl::serve::GatewayRunResult,
    b: &gmi_drl::serve::GatewayRunResult,
    what: &str,
) {
    assert_eq!(a.latency, b.latency, "{what}: latency stats");
    assert_eq!(a.served, b.served, "{what}: served ledger");
    assert_eq!(a.rejected, b.rejected, "{what}: rejected");
    assert_eq!(a.batch_sizes, b.batch_sizes, "{what}: batch sizes");
    assert_eq!(a.scale_events.len(), b.scale_events.len(), "{what}: scale events");
    assert_eq!(
        a.metrics.span_s.to_bits(),
        b.metrics.span_s.to_bits(),
        "{what}: span bits"
    );
    assert_eq!(
        a.metrics.steps_per_sec.to_bits(),
        b.metrics.steps_per_sec.to_bits(),
        "{what}: steps/s bits"
    );
    assert_eq!(
        a.metrics.utilization.to_bits(),
        b.metrics.utilization.to_bits(),
        "{what}: utilization bits"
    );
}

#[test]
fn prop_streaming_source_bit_identical_to_materialized() {
    // The tentpole identity: the lazy seeded stream must replay the eager
    // `generate_trace` sequence bit-for-bit (across its chunked refills),
    // and a gateway run fed the stream must report the bit-identical
    // result — latency distribution, served ledger, batch sizes, spans.
    let (b, cost) = bench_and_cost();
    let topo = Topology::dgx_a100(1);
    let batch = 16;
    let layout = fleet(&topo, 2, 4, batch, &cost);
    let serial = batch_seconds(&b, &cost, &topo, 0.25, batch);
    let rate = 0.7 * 2.0 * batch as f64 / serial;
    let mut rng = Rng(0x57e4_11);
    for case in 0..6 {
        let seed = rng.next();
        let sources = rng.range(1, 9);
        let duration = 0.2 + 0.1 * (case % 3) as f64;
        let pattern = match case % 3 {
            0 => TrafficPattern::Poisson { rate },
            1 => TrafficPattern::Diurnal { base: 0.2 * rate, peak: rate, period_s: duration },
            _ => TrafficPattern::Burst {
                base: 0.3 * rate,
                burst: 1.5 * rate,
                start_s: 0.3 * duration,
                len_s: 0.2 * duration,
            },
        };
        let eager = generate_trace(&pattern, duration, seed, sources);
        let streamed: Vec<_> =
            TraceSource::streaming(&pattern, duration, seed, sources).collect();
        assert_eq!(eager.len(), streamed.len(), "case {case}: stream length");
        for (i, (x, y)) in eager.iter().zip(&streamed).enumerate() {
            assert_eq!(x.id, y.id, "case {case}: id at {i}");
            assert_eq!(x.source, y.source, "case {case}: source at {i}");
            assert_eq!(
                x.arrival_s.to_bits(),
                y.arrival_s.to_bits(),
                "case {case}: arrival bits at {i}"
            );
        }
        if eager.is_empty() {
            continue;
        }
        let cfg = GatewayConfig {
            max_batch: batch,
            max_wait_s: 1e-3,
            slo_s: 10e-3,
            ..GatewayConfig::default()
        };
        let m = run_gateway(&layout, &b, &cost, &eager, &cfg).unwrap();
        let s = run_gateway_source(
            &layout,
            &b,
            &cost,
            TraceSource::streaming(&pattern, duration, seed, sources),
            &cfg,
        )
        .unwrap();
        assert_runs_identical(&m, &s, &format!("case {case}: streaming vs materialized"));
    }
}

#[test]
fn prop_aggregation_one_bit_identical_and_k_lossless() {
    // K = 1 macro-requests close on arrival, so the explicit setting must
    // be bit-identical to the default config. K > 1 coalesces: every
    // request is still served exactly once (no losses, no duplicates),
    // dispatched batches carry whole macros, and the dispatch count drops.
    let (b, cost) = bench_and_cost();
    let topo = Topology::dgx_a100(1);
    let batch = 16;
    let layout = fleet(&topo, 2, 4, batch, &cost);
    let serial = batch_seconds(&b, &cost, &topo, 0.25, batch);
    let rate = 0.6 * 2.0 * batch as f64 / serial;
    let trace = generate_trace(&TrafficPattern::Poisson { rate }, 0.4, 21, 4);
    assert!(trace.len() > 200, "aggregation trace unexpectedly small");
    let base = GatewayConfig {
        max_batch: batch,
        max_wait_s: 1e-3,
        slo_s: 10e-3,
        ..GatewayConfig::default()
    };

    let plain = run_gateway(&layout, &b, &cost, &trace, &base).unwrap();
    let k1 = run_gateway(
        &layout,
        &b,
        &cost,
        &trace,
        &GatewayConfig { aggregation: 1, ..base.clone() },
    )
    .unwrap();
    assert_runs_identical(&plain, &k1, "aggregation 1 vs default");

    let mut last_dispatches = plain.batch_sizes.len();
    for k in [2usize, 4, 8] {
        let r = run_gateway(
            &layout,
            &b,
            &cost,
            &trace,
            &GatewayConfig { aggregation: k, ..base.clone() },
        )
        .unwrap();
        assert_eq!(r.served.len(), trace.len(), "K={k}: request lost");
        assert_eq!(r.rejected, 0, "K={k}: spurious rejection");
        let mut ids: Vec<usize> = r.served.iter().map(|s| s.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), trace.len(), "K={k}: duplicate serve");
        assert_eq!(
            r.batch_sizes.iter().sum::<usize>(),
            trace.len(),
            "K={k}: batch ledger out of balance"
        );
        assert!(
            r.batch_sizes.len() <= last_dispatches,
            "K={k}: coalescing did not reduce dispatches ({} > {last_dispatches})",
            r.batch_sizes.len()
        );
        last_dispatches = r.batch_sizes.len();
    }
}

#[test]
fn prop_latency_reservoir_exact_below_cap_and_bounded_above() {
    // The reservoir satellite, unit level: below the cap every pushed
    // sample is retained in push order (any downstream statistic is
    // bit-identical to the unbounded log); above it the retained set stays
    // at the cap while the running sum remains exact — and the whole thing
    // replays bit-for-bit from its seed.
    let mut rng = Rng(0xca9);
    for case in 0..8 {
        let cap = rng.range(4, 64);
        let n = rng.range(1, 3 * cap);
        let seed = rng.next();
        let mut res = SampleReservoir::capped(cap, seed);
        let mut res2 = SampleReservoir::capped(cap, seed);
        let mut exact = Vec::new();
        let mut sum = 0.0f64;
        for i in 0..n {
            let v = ((i as f64) * 0.37).sin().abs() + 1e-3;
            res.push(v);
            res2.push(v);
            exact.push(v);
            sum += v;
        }
        assert_eq!(res.seen(), n, "case {case}: seen");
        assert_eq!(res.sum().to_bits(), sum.to_bits(), "case {case}: exact sum");
        assert_eq!(res.samples(), res2.samples(), "case {case}: seeded replay");
        if n <= cap {
            assert!(res.is_exact(), "case {case}: sub-cap must be exact");
            assert_eq!(res.samples(), &exact[..], "case {case}: push-order retention");
        } else {
            assert_eq!(res.samples().len(), cap, "case {case}: cap respected");
            for s in res.samples() {
                assert!(exact.contains(s), "case {case}: foreign sample");
            }
        }
    }

    // Gateway level: a cap at or above the served count must leave every
    // reported statistic bit-identical to the unbounded run.
    let (b, cost) = bench_and_cost();
    let topo = Topology::dgx_a100(1);
    let batch = 16;
    let layout = fleet(&topo, 2, 4, batch, &cost);
    let serial = batch_seconds(&b, &cost, &topo, 0.25, batch);
    let rate = 0.5 * 2.0 * batch as f64 / serial;
    let trace = generate_trace(&TrafficPattern::Poisson { rate }, 0.3, 5, 4);
    let base = GatewayConfig {
        max_batch: batch,
        max_wait_s: 1e-3,
        slo_s: 10e-3,
        ..GatewayConfig::default()
    };
    let unbounded = run_gateway(&layout, &b, &cost, &trace, &base).unwrap();
    let roomy = run_gateway(
        &layout,
        &b,
        &cost,
        &trace,
        &GatewayConfig { sample_cap: Some(trace.len() + 1), ..base.clone() },
    )
    .unwrap();
    assert_runs_identical(&unbounded, &roomy, "sub-cap reservoir vs unbounded");

    // A small cap still reports exact counts, exact mean (running sum),
    // and exact attainment (running SLO counter) — only the percentiles
    // come from the sampled reservoir.
    let capped = run_gateway(
        &layout,
        &b,
        &cost,
        &trace,
        &GatewayConfig { sample_cap: Some(32), ..base.clone() },
    )
    .unwrap();
    assert_eq!(capped.latency.served, unbounded.latency.served, "capped: served");
    assert_eq!(capped.latency.requests, unbounded.latency.requests, "capped: requests");
    assert_eq!(
        capped.latency.mean_s.to_bits(),
        unbounded.latency.mean_s.to_bits(),
        "capped: exact mean"
    );
    assert_eq!(
        capped.latency.attainment.to_bits(),
        unbounded.latency.attainment.to_bits(),
        "capped: exact attainment"
    );
    assert!(capped.latency.p99_s.is_finite() && capped.latency.p99_s > 0.0);
}
