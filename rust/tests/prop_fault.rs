//! Property tests for the fault-tolerance subsystem: seeded failure
//! injection, charged checkpoints, kill/re-admit recovery, and
//! degraded-fabric rerouting.
//!
//! Same methodology as the other `prop_*` suites: deterministic scenarios
//! (the offline build has no proptest crate), each asserting an invariant
//! the scheduler must hold under hardware failure:
//!
//!   1. a failed GPU is never a placement target;
//!   2. a killed tenant resumes from its last checkpoint, losing at most
//!      one checkpoint interval (plus a round of slack) of service;
//!   3. the collective planner reroutes around failed links or reports a
//!      partition — never a plan over a dead link;
//!   4. a faulted day is bit-reproducible;
//!   5. checkpoint capture cost is charged to the tenant's own clocks.

use gmi_drl::cluster::Topology;
use gmi_drl::config::static_registry;
use gmi_drl::fabric::{Fabric, ReduceStrategy};
use gmi_drl::fault::{
    FaultEvent, FaultKind, FaultPlan, FaultTarget, FaultTrace, FaultTraceConfig,
};
use gmi_drl::sched::{corun_scenario, run_cluster, ClusterRunResult, JobSpec, SchedAction, SchedConfig};
use gmi_drl::vtime::CostModel;

fn bench() -> gmi_drl::BenchInfo {
    static_registry()["AT"].clone()
}

/// Equal strings of `{:?}`-formatted floats mean equal bits (shortest
/// round-trip representation).
fn fingerprint(r: &ClusterRunResult) -> Vec<String> {
    let mut out: Vec<String> = r
        .events
        .iter()
        .map(|e| {
            format!("{:?} {} {} {} {:?} {}", e.t_s, e.job, e.action, e.members, e.share, e.detail)
        })
        .collect();
    for j in &r.jobs {
        out.push(format!(
            "job {}: rate {:?} busy {:?} kills {} lost {:?} ckpt {:?}",
            j.id, j.metrics.steps_per_sec, j.busy_s, j.kills, j.goodput_lost_s, j.checkpoint_s
        ));
    }
    out.push(format!("{:?} {:?} {}", r.makespan_s, r.goodput_lost_s, r.fault_events));
    out
}

#[test]
fn a_failed_gpu_is_never_a_placement_target() {
    // GPU 1 dies at t=0, before the tenant is admitted. Both members must
    // land on GPU 0 — if either were placed on the dead GPU, the fault
    // pass would kill the tenant in the next round.
    let b = bench();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    let trace = FaultTrace::parse("0.0 fail gpu 1", 1).unwrap();
    let cfg = SchedConfig { faults: Some(FaultPlan::new(trace)), ..SchedConfig::default() };
    let jobs = vec![JobSpec::training(0, "train", 1, 0.0, 2, 0.4, 0.2, 512, 6)];
    let r = run_cluster(&topo, &b, &cost, &jobs, &cfg).unwrap();
    assert_eq!(r.fault_events, 1);
    assert!(r.events.iter().any(|e| e.action == SchedAction::Fail));
    let j = r.job(0).unwrap();
    assert_eq!(j.kills, 0, "a member was placed on the dead GPU");
    assert!(r.events.iter().all(|e| e.action != SchedAction::Kill));
    assert!(j.completed_s > 0.0, "tenant never completed on the surviving GPU");
    assert_eq!(j.goodput_lost_s, 0.0);
}

#[test]
fn a_killed_tenant_resumes_from_checkpoint_with_bounded_loss() {
    // Two members spread over two GPUs; GPU 1 dies mid-run and never
    // recovers. The tenant is killed, re-admitted entirely onto GPU 0,
    // and resumes from its last periodic checkpoint — losing at most one
    // checkpoint interval (plus one scheduling round) of service.
    let b = bench();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    let ckpt = 0.02;
    let trace = FaultTrace::parse("0.05 fail gpu 1", 1).unwrap();
    let cfg = SchedConfig {
        faults: Some(FaultPlan::new(trace).with_checkpoint_interval(ckpt)),
        ..SchedConfig::default()
    };
    let jobs = vec![JobSpec::training(0, "train", 1, 0.0, 2, 0.4, 0.2, 1024, 40)];
    let r = run_cluster(&topo, &b, &cost, &jobs, &cfg).unwrap();
    let j = r.job(0).unwrap();
    assert!(j.kills >= 1, "the GPU loss must kill the spread tenant");
    assert!(j.completed_s > 0.0, "killed tenant was never re-admitted to completion");
    assert!(j.checkpoint_s > 0.0, "no checkpoint cost was charged before the kill");
    assert!(r.events.iter().any(|e| e.action == SchedAction::Checkpoint));
    assert!(r.events.iter().any(|e| e.action == SchedAction::Kill));
    // Resume came from a checkpoint, not from scratch.
    let readmit = r
        .events
        .iter()
        .find(|e| e.action == SchedAction::Admit && e.detail.contains("re-admitted"))
        .expect("no re-admission event");
    assert!(
        readmit.detail.contains("checkpoint"),
        "re-admission did not resume from the stored checkpoint: {}",
        readmit.detail
    );
    let bound = j.kills as f64 * (ckpt + cfg.quantum_s) * topo.num_gpus() as f64;
    assert!(
        j.goodput_lost_s <= bound + 1e-9,
        "lost {} GPU-s, checkpoint bound {}",
        j.goodput_lost_s,
        bound
    );
    assert_eq!(r.goodput_lost_s, j.goodput_lost_s);
}

#[test]
fn planner_reroutes_around_failed_links_or_reports_partition() {
    let topo = Topology::dgx_a100(4);
    let mut fabric = Fabric::single_node(topo);
    let mpl: Vec<Vec<usize>> = vec![vec![0], vec![1], vec![2], vec![3]];
    let bytes = 1 << 20;
    let (healthy_strategy, healthy_plan) =
        fabric.try_cheapest_allreduce(&mpl, bytes).expect("healthy fabric plans");
    assert!(fabric.plan_valid(&healthy_plan));

    // NVSwitch down: the ring strategies lose their only link; the planner
    // must fall to the host-staged multi-process reduce, and the plan it
    // returns must be valid on the degraded fabric.
    let nv_fail =
        FaultEvent { t_s: 0.0, kind: FaultKind::Fail, target: FaultTarget::NvSwitch };
    nv_fail.apply(&mut fabric, 1);
    let (deg_strategy, deg_plan) =
        fabric.try_cheapest_allreduce(&mpl, bytes).expect("host path still routes");
    assert_eq!(deg_strategy, ReduceStrategy::MultiProcess);
    assert!(fabric.plan_valid(&deg_plan));

    // A participant GPU dies too: its host path goes with it, so no
    // strategy has a valid route — the group is partitioned, an error,
    // never a silently-invalid plan.
    let gpu_fail =
        FaultEvent { t_s: 0.0, kind: FaultKind::Fail, target: FaultTarget::Gpu(1) };
    gpu_fail.apply(&mut fabric, 1);
    let err = fabric.try_cheapest_allreduce(&mpl, bytes).unwrap_err();
    assert!(err.to_string().contains("partitioned"), "unexpected error: {err}");

    // Full repair restores the healthy plan bit-for-bit.
    FaultEvent { t_s: 0.0, kind: FaultKind::Repair, target: FaultTarget::NvSwitch }
        .apply(&mut fabric, 1);
    FaultEvent { t_s: 0.0, kind: FaultKind::Repair, target: FaultTarget::Gpu(1) }
        .apply(&mut fabric, 1);
    assert!(!fabric.has_failures());
    let (repaired_strategy, repaired_plan) =
        fabric.try_cheapest_allreduce(&mpl, bytes).unwrap();
    assert_eq!(repaired_strategy, healthy_strategy);
    assert_eq!(repaired_plan.total_s().to_bits(), healthy_plan.total_s().to_bits());
}

#[test]
fn a_faulted_day_is_bit_reproducible() {
    // The canonical co-run day under a declarative failure schedule that
    // exercises every event class: GPU loss and repair, an NVSwitch
    // outage forcing a mid-run replan, and checkpoints throughout. Two
    // runs of the same inputs must agree down to the float bits.
    let b = bench();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    let trace = "\
        0.03 fail gpu 1\n\
        0.05 fail nvswitch\n\
        0.08 repair gpu 1\n\
        0.09 repair nvswitch\n";
    let jobs = corun_scenario(&topo, &b, &cost, 0.2, 7, false);
    let cfg = SchedConfig {
        faults: Some(
            FaultPlan::new(FaultTrace::parse(trace, 1).unwrap()).with_checkpoint_interval(0.02),
        ),
        ..SchedConfig::default()
    };
    let r1 = run_cluster(&topo, &b, &cost, &jobs, &cfg).unwrap();
    let r2 = run_cluster(&topo, &b, &cost, &jobs, &cfg).unwrap();
    assert_eq!(r1.fault_events, 4);
    assert_eq!(fingerprint(&r1), fingerprint(&r2), "faulted day diverged between runs");
    // Every tenant survived the outage to completion.
    assert!(r1.jobs.iter().all(|j| j.completed_s > 0.0));
}

#[test]
fn generated_traces_are_deterministic_and_respect_the_horizon() {
    let cfg = FaultTraceConfig {
        seed: 0xdead,
        duration_s: 2.0,
        num_gpus: 16,
        gpus_per_node: 2,
        gpu_mtbf_s: 0.2,
        node_mtbf_s: 0.7,
        link_mtbf_s: 0.9,
        repair_after_s: Some(0.1),
    };
    let a = FaultTrace::generate(&cfg);
    let b = FaultTrace::generate(&cfg);
    assert_eq!(a, b, "same config must generate the identical trace");
    assert!(!a.is_empty(), "MTBFs well under the horizon must yield events");
    assert!(a.events.iter().all(|e| e.t_s < cfg.duration_s));
    assert!(a.events.windows(2).all(|w| w[0].t_s <= w[1].t_s), "trace not time-sorted");
    // The declarative format round-trips.
    let reparsed = FaultTrace::parse(&a.to_text(), cfg.gpus_per_node).unwrap();
    assert_eq!(a, reparsed);
    // A different seed gives a different schedule.
    let other = FaultTrace::generate(&FaultTraceConfig { seed: 0xbeef, ..cfg });
    assert_ne!(a, other);
}

#[test]
fn checkpoint_cost_is_charged_to_the_tenants_own_clocks() {
    // Checkpointing with NO failures: pure insurance. The capture cost
    // lands on the tenant's member clocks (virtual time), so the
    // checkpointed day finishes no earlier than the plain one, the
    // overhead column is populated, and nothing is killed or lost.
    let b = bench();
    let cost = CostModel::new(&b);
    let topo = Topology::dgx_a100(2);
    let jobs = vec![JobSpec::training(0, "train", 1, 0.0, 2, 0.4, 0.2, 1024, 12)];
    let plain_cfg = SchedConfig::default();
    let ckpt_cfg = SchedConfig {
        faults: Some(
            FaultPlan::new(FaultTrace::new(Vec::new(), 1)).with_checkpoint_interval(0.01),
        ),
        ..SchedConfig::default()
    };
    let plain = run_cluster(&topo, &b, &cost, &jobs, &plain_cfg).unwrap();
    let ckpt = run_cluster(&topo, &b, &cost, &jobs, &ckpt_cfg).unwrap();
    let pj = plain.job(0).unwrap();
    let cj = ckpt.job(0).unwrap();
    assert_eq!(ckpt.fault_events, 0);
    assert_eq!(cj.kills, 0);
    assert_eq!(cj.goodput_lost_s, 0.0);
    assert!(cj.checkpoint_s > 0.0, "no capture cost charged");
    assert!(ckpt.events.iter().any(|e| e.action == SchedAction::Checkpoint));
    assert!(plain.events.iter().all(|e| e.action != SchedAction::Checkpoint));
    assert_eq!(pj.checkpoint_s, 0.0);
    assert!(
        cj.completed_s >= pj.completed_s,
        "charged checkpoints cannot make the job finish earlier ({} < {})",
        cj.completed_s,
        pj.completed_s
    );
}
