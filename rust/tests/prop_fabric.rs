//! Property-based tests on the communication fabric's collective planner.
//!
//! Same methodology as `prop_coordinator`: a seeded SplitMix64 generator
//! over many random cases (the offline build has no proptest crate).

use gmi_drl::cluster::Topology;
use gmi_drl::comm::select_strategy;
use gmi_drl::fabric::{Fabric, Plan, ReduceStrategy};
use gmi_drl::vtime::Clock;

/// Deterministic PRNG (SplitMix64).
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next() as usize) % (hi - lo + 1)
    }
}

/// Random GMI-to-GPU layout: `g` GPUs, possibly unequal GMIs per GPU.
fn random_mpl(rng: &mut Rng, equal: bool) -> Vec<Vec<usize>> {
    let g = rng.range(1, 8);
    let t_fixed = rng.range(1, 5);
    let mut id = 0usize;
    (0..g)
        .map(|_| {
            let t = if equal { t_fixed } else { rng.range(1, 5) };
            (0..t)
                .map(|_| {
                    id += 1;
                    id
                })
                .collect()
        })
        .collect()
}

#[test]
fn prop_planner_never_costlier_than_algorithm1() {
    let mut rng = Rng(0xfab1);
    for case in 0..300 {
        let mpl = random_mpl(&mut rng, rng.range(0, 1) == 0);
        let bytes = rng.range(1 << 10, 32 << 20);
        let fabric = Fabric::single_node(Topology::dgx_a100(mpl.len()));
        let (cheapest, plan) = fabric.cheapest_allreduce(&mpl, bytes);
        // Algorithm 1 always picks a valid strategy; the planner's pick
        // must never be costlier under the same cost model.
        let heuristic = select_strategy(&mpl);
        let h_plan = fabric
            .plan_allreduce(&mpl, bytes, heuristic)
            .unwrap_or_else(|e| panic!("case {case}: Alg 1 picked invalid {heuristic}: {e}"));
        assert!(
            plan.total_s() <= h_plan.total_s() + 1e-15,
            "case {case}: planner {cheapest} ({}) costlier than Alg 1 {heuristic} ({}) for {mpl:?}",
            plan.total_s(),
            h_plan.total_s()
        );
        // The chosen plan must itself be valid and re-derivable.
        let again = fabric.plan_allreduce(&mpl, bytes, cheapest).unwrap();
        assert!((again.total_s() - plan.total_s()).abs() < 1e-15, "case {case}");
    }
}

#[test]
fn prop_mrr_never_selected_when_invalid() {
    let mut rng = Rng(0x3a9);
    for case in 0..300 {
        let mpl = random_mpl(&mut rng, rng.range(0, 1) == 0);
        let bytes = rng.range(1 << 10, 32 << 20);
        let g = mpl.len();
        let sizes: Vec<usize> = mpl.iter().map(|v| v.len()).collect();
        let equal = sizes.windows(2).all(|w| w[0] == w[1]);
        let fabric = Fabric::single_node(Topology::dgx_a100(g));
        let (cheapest, _) = fabric.cheapest_allreduce(&mpl, bytes);
        if cheapest == ReduceStrategy::MultiRing {
            // MRR is only executable with equal per-GPU counts and t <= g.
            assert!(equal, "case {case}: MRR on unequal layout {sizes:?}");
            assert!(sizes[0] <= g, "case {case}: MRR with t {} > g {g}", sizes[0]);
        }
        // And asking for an invalid MRR directly must fail.
        if !equal || sizes[0] > g {
            assert!(
                fabric
                    .plan_allreduce(&mpl, bytes, ReduceStrategy::MultiRing)
                    .is_err(),
                "case {case}: invalid MRR plan accepted for {sizes:?}"
            );
        }
    }
}

#[test]
fn prop_plan_costs_positive_and_monotone_in_bytes() {
    let mut rng = Rng(0xbead);
    for case in 0..150 {
        let mpl = random_mpl(&mut rng, true);
        let total: usize = mpl.iter().map(|v| v.len()).sum();
        if total <= 1 {
            continue;
        }
        let bytes = rng.range(1 << 10, 8 << 20);
        let fabric = Fabric::single_node(Topology::dgx_a100(mpl.len()));
        for s in [
            ReduceStrategy::MultiProcess,
            ReduceStrategy::MultiRing,
            ReduceStrategy::Hierarchical,
        ] {
            let Ok(small) = fabric.plan_allreduce(&mpl, bytes, s) else { continue };
            let big = fabric.plan_allreduce(&mpl, bytes * 2, s).unwrap();
            assert!(small.total_s() > 0.0 && small.total_s().is_finite(), "case {case} {s}");
            assert!(
                big.total_s() > small.total_s(),
                "case {case} {s}: more bytes must cost more"
            );
        }
    }
}

#[test]
fn prop_execute_serializes_and_conserves_traffic() {
    let mut rng = Rng(0x5e1a);
    for case in 0..100 {
        let mpl = random_mpl(&mut rng, true);
        let total: usize = mpl.iter().map(|v| v.len()).sum();
        if total <= 1 {
            continue;
        }
        let bytes = rng.range(1 << 12, 4 << 20);
        let mut fabric = Fabric::single_node(Topology::dgx_a100(mpl.len()));
        let (_, plan) = fabric.cheapest_allreduce(&mpl, bytes);
        let reps = rng.range(2, 5);
        let mut last = Clock::zero();
        for k in 0..reps {
            let done = fabric.execute(&plan, Clock::zero());
            // Back-to-back executions of the same plan serialize on its
            // links: completion times strictly increase.
            assert!(done > last, "case {case} rep {k}: no serialization");
            last = done;
        }
        // The busiest link bounds the pipeline: it is held for its phases'
        // full duration on every repetition (phases on *other* links may
        // overlap across repetitions — that's the point of the fabric).
        let links: std::collections::BTreeSet<usize> = plan
            .steps
            .iter()
            .flat_map(|s| s.uses.iter().map(|u| u.link))
            .collect();
        let bottleneck = links
            .iter()
            .map(|&l| {
                plan.steps
                    .iter()
                    .filter(|s| s.uses.iter().any(|u| u.link == l))
                    .map(|s| s.dur)
                    .sum::<f64>()
            })
            .fold(0.0f64, f64::max);
        assert!(
            last.seconds() + 1e-12 >= bottleneck * reps as f64,
            "case {case}: {} reps of bottleneck {bottleneck} finished at {}",
            reps,
            last.seconds()
        );
        let moved: u64 = fabric.link_report().iter().map(|l| l.bytes).sum();
        let per_plan: u64 = plan
            .steps
            .iter()
            .flat_map(|s| s.uses.iter())
            .map(|u| u.bytes)
            .sum();
        assert_eq!(moved, per_plan * reps as u64, "case {case}: traffic not conserved");
    }
}

#[test]
fn prop_empty_plans_only_for_single_gmi() {
    let mut rng = Rng(0x0eff);
    for _ in 0..100 {
        let mpl = random_mpl(&mut rng, false);
        let total: usize = mpl.iter().map(|v| v.len()).sum();
        let fabric = Fabric::single_node(Topology::dgx_a100(mpl.len()));
        let (_, plan): (_, Plan) = fabric.cheapest_allreduce(&mpl, 1 << 20);
        assert_eq!(plan.is_empty(), total <= 1);
    }
}
