//! Integration: the python-AOT -> rust-load -> execute path, end to end.
//!
//! Requires `make artifacts` (or GMI_DRL_ARTIFACTS pointing at a manifest).
//! Runs the full init -> rollout -> grad -> apply cycle of one benchmark on
//! the PJRT CPU client and checks shapes and basic numerics.

use gmi_drl::config::artifacts_dir;
use gmi_drl::runtime::{ArtifactKind, ExecServer, HostTensor};
use gmi_drl::Manifest;

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

#[test]
fn full_training_cycle_roundtrip() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir).unwrap();
    // Use the cheapest benchmark present.
    let abbr = if manifest.benchmarks.contains_key("BB") {
        "BB".to_string()
    } else {
        manifest.benchmarks.keys().next().unwrap().clone()
    };
    let b = manifest.bench(&abbr).unwrap().clone();
    let (n, m, d, a, p) = (b.num_env, b.horizon, b.obs_dim, b.act_dim, b.num_params);

    let server = ExecServer::start(dir).unwrap();
    let h = server.handle();

    // init
    let out = h
        .execute(&abbr, ArtifactKind::Init, vec![HostTensor::scalar_i32(42)])
        .unwrap();
    assert_eq!(out.len(), 2);
    let params = out[0].clone();
    let state = out[1].clone();
    assert_eq!(params.len(), p);
    assert_eq!(state.shape(), &[n as i64, d as i64]);
    // init is deterministic in the seed
    let out2 = h
        .execute(&abbr, ArtifactKind::Init, vec![HostTensor::scalar_i32(42)])
        .unwrap();
    assert_eq!(out2[0], params);

    // rollout
    let out = h
        .execute(
            &abbr,
            ArtifactKind::Rollout,
            vec![params.clone(), state.clone(), HostTensor::scalar_i32(1)],
        )
        .unwrap();
    assert_eq!(out.len(), 8);
    let (obs, acts, logps, rews, vals, dones, _last_state, last_value) = (
        out[0].clone(),
        out[1].clone(),
        out[2].clone(),
        out[3].clone(),
        out[4].clone(),
        out[5].clone(),
        out[6].clone(),
        out[7].clone(),
    );
    assert_eq!(obs.shape(), &[m as i64, n as i64, d as i64]);
    assert_eq!(acts.shape(), &[m as i64, n as i64, a as i64]);
    assert_eq!(logps.shape(), &[m as i64, n as i64]);
    assert_eq!(last_value.shape(), &[n as i64]);
    assert!(rews.as_f32().unwrap().iter().all(|v| v.is_finite()));

    // grad
    let out = h
        .execute(
            &abbr,
            ArtifactKind::Grad,
            vec![params.clone(), obs, acts, logps, rews, vals, dones, last_value],
        )
        .unwrap();
    assert_eq!(out.len(), 7);
    let grads = out[0].clone();
    assert_eq!(grads.len(), p);
    let loss = out[1].scalar_value_f32().unwrap();
    assert!(loss.is_finite(), "loss {loss}");
    let gnorm: f32 = grads.as_f32().unwrap().iter().map(|g| g * g).sum::<f32>().sqrt();
    assert!(gnorm > 0.0 && gnorm.is_finite(), "grad norm {gnorm}");

    // apply (Adam step actually changes the parameters)
    let zeros = HostTensor::zeros_f32(&[p]);
    let out = h
        .execute(
            &abbr,
            ArtifactKind::Apply,
            vec![
                params.clone(),
                zeros.clone(),
                zeros,
                HostTensor::scalar_i32(0),
                grads,
                HostTensor::scalar_f32(3e-4),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 4);
    let new_params = &out[0];
    assert_eq!(new_params.len(), p);
    assert_ne!(new_params.as_f32().unwrap(), params.as_f32().unwrap());
    assert_eq!(out[3].scalar_value_i32().unwrap(), 1);

    let (execs, _, _, _, _) = h.stats().snapshot();
    assert!(execs >= 5);
}
