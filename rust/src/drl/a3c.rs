//! Asynchronized DRL training (A3C-style) with channel-based experience
//! sharing — paper §4.2, Fig 6b, Fig 11, Table 8.
//!
//! Serving GMIs (decoupled GPUs) continuously collect experience; the
//! dispenser/compressor/migrator/batcher pipeline moves it to trainer GMIs
//! on the training GPUs; trainers update asynchronously and periodically
//! push fresh parameters back to the agents.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use super::compute::Compute;
use crate::channels::{
    Batcher, ChannelStats, Compressor, Dispenser, Migrator, RolloutSegment, ShareMode,
    TrainerEndpoint,
};
use crate::config::BenchInfo;
use crate::mapping::Layout;
use crate::metrics::{RunMetrics, UtilizationTracker};
use crate::vtime::{Clock, CostModel, OpKind};

#[derive(Debug, Clone)]
pub struct AsyncConfig {
    /// Rollout rounds per serving GMI.
    pub rounds: usize,
    pub seed: i32,
    pub share_mode: ShareMode,
    /// Training batch size in samples (the BT slicing/stacking knob).
    pub batch_samples: usize,
    /// Push fresh params back to agents every k trainer updates.
    pub param_sync_every: usize,
    pub lr: f32,
    pub real_replicas: usize,
}

impl Default for AsyncConfig {
    fn default() -> Self {
        AsyncConfig {
            rounds: 10,
            seed: 1,
            share_mode: ShareMode::MultiChannel,
            batch_samples: 8192,
            param_sync_every: 4,
            lr: super::DEFAULT_LR,
            real_replicas: 1,
        }
    }
}

/// Result: run metrics + channel traffic statistics.
pub struct AsyncRunResult {
    pub metrics: RunMetrics,
    pub channel_stats: ChannelStats,
    /// trainer updates performed.
    pub updates: usize,
}

pub fn run_async(
    layout: &Layout,
    bench: &BenchInfo,
    cost: &CostModel,
    compute: &Compute,
    cfg: &AsyncConfig,
) -> Result<AsyncRunResult> {
    let agents = &layout.rollout_gmis;
    let trainers = &layout.trainer_gmis;
    anyhow::ensure!(!agents.is_empty() && !trainers.is_empty(), "async layout needs both");

    let topo = layout.manager.topology().clone();
    let endpoints: Vec<TrainerEndpoint> = trainers
        .iter()
        .map(|&g| TrainerEndpoint { gmi: g, gpu: layout.manager.gmi(g).unwrap().gpu })
        .collect();
    let mut migrator = Migrator::new(topo.clone(), endpoints);
    for &a in agents {
        migrator.register_agent(a, layout.manager.gmi(a).unwrap().gpu);
    }
    let mut dispensers: Vec<Dispenser> = agents
        .iter()
        .map(|&a| Dispenser::new(a, bench.obs_dim, bench.act_dim))
        .collect();
    // Per-channel transfer granularity: 256 KiB balances host-path
    // efficiency (HOST_MSG_HALF_BYTES) against staging latency on the
    // narrow channels.
    let mut compressor = Compressor::new(cfg.share_mode, 256 << 10);
    let mut batchers: BTreeMap<usize, Batcher> = trainers
        .iter()
        .map(|&t| (t, Batcher::new(t, cfg.share_mode, cfg.batch_samples)))
        .collect();

    // Real numerics on replica 0 only (agents mirror; trainers re-use the
    // last real rollout for real gradient calls — same bytes the pipeline
    // carries, see DESIGN.md §5).
    let real_n = cfg.real_replicas.min(agents.len()).max(1);
    let mut agent_workers = Vec::with_capacity(real_n);
    for _ in 0..real_n {
        agent_workers.push(compute.init(bench, cfg.seed)?);
    }
    let mut trainer_worker = compute.init(bench, cfg.seed)?;
    let mut last_real_rollout = None;

    let mut agent_clocks = vec![Clock::zero(); agents.len()];
    let mut trainer_clocks: BTreeMap<usize, Clock> =
        trainers.iter().map(|&t| (t, Clock::zero())).collect();
    let mut util = UtilizationTracker::new();
    let mut stats = ChannelStats::default();
    let m = bench.horizon;
    let mut updates = 0usize;
    let mut samples_trained = 0usize;
    let mut reward_sum = 0.0f64;
    let mut reward_n = 0usize;
    // (trainer batch queue handled inline: batches process on arrival.)

    for round in 0..cfg.rounds {
        for (i, &agid) in agents.iter().enumerate() {
            let spec = layout.manager.gmi(agid).context("agent gmi")?;
            let co = layout.manager.co_resident(agid);
            let share = spec.sm_share;
            let inter = spec.interference(co, cost);
            let n_env = spec.num_env;

            // rollout segment (sim + fwd per step)
            let t_sim = cost.op_time(OpKind::SimStep { num_env: n_env }, share, inter);
            let t_fwd = cost.op_time(OpKind::PolicyFwd { num_env: n_env }, share, inter);
            let dur = m as f64 * (t_sim + t_fwd);
            let now = agent_clocks[i].advance(dur);
            util.record(
                spec.gpu,
                cost.sm_occupancy(OpKind::SimStep { num_env: n_env }, share),
                m as f64 * t_sim,
                now.seconds(),
            );

            // experience: real on replicas, synthetic otherwise. In Null
            // mode everything is synthetic at the GMI's own env count (the
            // artifact batch size is irrelevant without real numerics).
            let seg = if compute.is_real() && i < real_n {
                let ro = compute.rollout(
                    bench,
                    &mut agent_workers[i],
                    cfg.seed + (round * 257 + i) as i32,
                )?;
                reward_sum += ro.mean_reward as f64;
                reward_n += 1;
                let seg = RolloutSegment {
                    steps: bench.horizon,
                    envs: bench.num_env,
                    obs: ro.obs.as_f32()?.to_vec(),
                    actions: ro.actions.as_f32()?.to_vec(),
                    logps: ro.logps.as_f32()?.to_vec(),
                    rewards: ro.rewards.as_f32()?.to_vec(),
                    values: ro.values.as_f32()?.to_vec(),
                    dones: ro.dones.as_f32()?.to_vec(),
                };
                last_real_rollout = Some(ro);
                seg
            } else {
                RolloutSegment::synthetic(m, n_env, bench.obs_dim, bench.act_dim)
            };

            // DP -> CP -> MG -> BT. Chunks are grouped along the step axis
            // at training-batch granularity; the migrator's sticky
            // per-agent routing keeps all channels of an agent aligned at
            // one trainer while agents balance across trainers.
            let steps_per_group = (cfg.batch_samples / n_env.max(1)).max(1);
            let groups =
                dispensers[i].dispense_groups(&seg, now, cfg.share_mode, steps_per_group);
            let mut packets = Vec::new();
            for group in groups {
                stats.chunks_in += group.len() as u64;
                packets.extend(compressor.push(group));
            }
            for pkt in packets {
                // The sender pays a per-message submission overhead on its
                // own timeline (IPC rendezvous + serialization) — the cost
                // that makes fine-grained UCC sharing slow on the agent
                // side (§4.2 / Table 8's PPS gap).
                agent_clocks[i].advance(crate::cluster::HOST_LAT);
                let decision = migrator.route(&pkt);
                stats.transfer_seconds += decision.transfer_s;
                stats.transfer_ops += 1;
                stats.packets_out += 1;
                stats.bytes_moved += pkt.bytes() as u64;
                let ready_batches = {
                    let batcher = batchers.get_mut(&decision.trainer).unwrap();
                    batcher.push(pkt, decision.arrival)
                };

                // trainer consumes ready batches immediately (async)
                for batch in ready_batches {
                    let tclock = trainer_clocks.get_mut(&decision.trainer).unwrap();
                    let tspec = layout.manager.gmi(decision.trainer).unwrap();
                    let tco = layout.manager.co_resident(decision.trainer);
                    let tshare = tspec.sm_share;
                    let tinter = tspec.interference(tco, cost);
                    let t_grad =
                        cost.op_time(OpKind::TrainGrad { samples: batch.samples }, tshare, tinter);
                    let t_apply = cost.op_time(OpKind::AdamApply, tshare, tinter);
                    tclock.merge_then_advance(batch.ready, t_grad + t_apply);
                    util.record(
                        tspec.gpu,
                        cost.sm_occupancy(
                            OpKind::TrainGrad { samples: batch.samples },
                            tshare,
                        ),
                        t_grad,
                        tclock.seconds(),
                    );
                    migrator.complete(decision.trainer, batch.samples);
                    samples_trained += batch.samples;
                    updates += 1;

                    // real gradient + update on the trainer worker
                    if compute.is_real() {
                        if let Some(ro) = &last_real_rollout {
                            let (g, _) = compute.grad(bench, &trainer_worker, ro)?;
                            compute.apply(bench, &mut trainer_worker, &g, cfg.lr)?;
                        }
                    }

                    // param push-back every k updates. A3C is asynchronous:
                    // agents never BLOCK on the trainer (they keep acting
                    // on stale parameters); they only pay the receive cost
                    // of the pushed tensor on their own timeline.
                    if updates % cfg.param_sync_every == 0 {
                        let t_push = topo.host_transfer_time(bench.param_bytes(), 1)
                            + bench.param_bytes() as f64 / topo.inter_gpu_bw();
                        for c in agent_clocks.iter_mut() {
                            c.advance(t_push);
                        }
                        for w in agent_workers.iter_mut() {
                            w.params = trainer_worker.params.clone();
                        }
                    }
                }
            }
        }
    }

    // flush stragglers through the pipeline (counted but not trained)
    let leftover = compressor.flush();
    for pkt in leftover {
        stats.packets_out += 1;
        stats.bytes_moved += pkt.bytes() as u64;
    }

    let agent_span = Clock::max_of(&agent_clocks).seconds();
    let trainer_span = trainer_clocks
        .values()
        .fold(0.0f64, |a, c| a.max(c.seconds()));
    let span = agent_span.max(trainer_span);
    let total_preds =
        (cfg.rounds * m) as f64 * agents.len() as f64 * layout.num_env_per_gmi as f64;
    let metrics = RunMetrics {
        steps_per_sec: total_preds / span,
        pps: total_preds / agent_span,
        ttop: samples_trained as f64 / span,
        span_s: span,
        utilization: util.mean_utilization(),
        final_reward: if reward_n > 0 { reward_sum / reward_n as f64 } else { 0.0 },
        reward_curve: vec![],
        comm_s: stats.transfer_seconds,
        peak_mem_gib: cost.mem_gib(layout.num_env_per_gmi, m, true, false),
    };
    Ok(AsyncRunResult { metrics, channel_stats: stats, updates })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::config::static_registry;
    use crate::mapping::build_async_layout;

    fn setup() -> (Layout, BenchInfo, CostModel) {
        let b = static_registry()["AY"].clone();
        let cost = CostModel::new(&b);
        let topo = Topology::dgx_a100(2);
        let layout = build_async_layout(&topo, 1, 3, 2, 2048, &cost).unwrap();
        (layout, b, cost)
    }

    #[test]
    fn async_runs_and_trains() {
        let (layout, b, cost) = setup();
        let cfg = AsyncConfig { rounds: 12, batch_samples: 4096, ..Default::default() };
        let r = run_async(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        assert!(r.metrics.pps > 0.0);
        assert!(r.updates > 0, "no trainer updates happened");
        assert!(r.metrics.ttop > 0.0);
        assert!(r.channel_stats.packets_out > 0);
    }

    #[test]
    fn mcc_fewer_bigger_packets_than_ucc() {
        // Table 8's mechanism: multi-channel moves the same bytes in fewer,
        // larger transfers.
        // Long enough that steady-state transfer efficiency dominates the
        // pipeline fill/drain tails.
        let (layout, b, cost) = setup();
        let mk = |mode| AsyncConfig {
            rounds: 40,
            batch_samples: 4096,
            share_mode: mode,
            ..Default::default()
        };
        let mcc =
            run_async(&layout, &b, &cost, &Compute::Null, &mk(ShareMode::MultiChannel)).unwrap();
        let ucc =
            run_async(&layout, &b, &cost, &Compute::Null, &mk(ShareMode::UniChannel)).unwrap();
        assert!(
            mcc.channel_stats.packets_out < ucc.channel_stats.packets_out,
            "mcc {} vs ucc {} packets",
            mcc.channel_stats.packets_out,
            ucc.channel_stats.packets_out
        );
        assert!(mcc.channel_stats.mean_packet_bytes() > ucc.channel_stats.mean_packet_bytes());
        // and higher training throughput
        assert!(
            mcc.metrics.ttop >= ucc.metrics.ttop,
            "mcc ttop {} vs ucc {}",
            mcc.metrics.ttop,
            ucc.metrics.ttop
        );
    }

    #[test]
    fn deterministic() {
        let (layout, b, cost) = setup();
        let cfg = AsyncConfig { rounds: 6, ..Default::default() };
        let a = run_async(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        let c = run_async(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        assert_eq!(a.metrics.pps, c.metrics.pps);
        assert_eq!(a.updates, c.updates);
    }
}
