//! Asynchronized DRL training (A3C-style) with channel-based experience
//! sharing — paper §4.2, Fig 6b, Fig 11, Table 8.
//!
//! Serving GMIs (decoupled GPUs) continuously collect experience; the
//! dispenser/compressor/migrator/batcher pipeline moves it to trainer GMIs
//! on the training GPUs; trainers update asynchronously and periodically
//! push fresh parameters back to the agents.
//!
//! Timing runs on the shared [`engine`](crate::engine): agents and trainers
//! are executors; batch consumption is a blocking-receive charge
//! (`charge_after`) against the batch's pipeline arrival time. All
//! experience and parameter movement flows over the communication
//! [`fabric`](crate::fabric): the migrator executes per-packet routes with
//! per-link occupancy (contended links serialize), and the periodic
//! parameter push-back is a fabric plan. The round loop lives in the
//! steppable workload program
//! ([`workload::AsyncProgram`](crate::workload::AsyncProgram)) shared with
//! the multi-tenant scheduler — which is what lets compressor-channel A3C
//! jobs co-run as cluster tenants; [`run_async`] is the thin standalone
//! driver. With [`AsyncConfig::elastic`] set, the engine's elastic
//! controller shifts SM share toward the bottleneck role group between
//! rounds, mirroring sync training's support.

use anyhow::Result;

use super::compute::Compute;
use crate::channels::ShareMode;
use crate::config::BenchInfo;
use crate::engine::{ElasticConfig, Engine};
use crate::fabric::Fabric;
use crate::mapping::Layout;
use crate::metrics::RunMetrics;
use crate::vtime::CostModel;
use crate::workload::{run_to_completion, AsyncProgram, Workload};

#[derive(Debug, Clone)]
pub struct AsyncConfig {
    /// Rollout rounds per serving GMI.
    pub rounds: usize,
    pub seed: i32,
    pub share_mode: ShareMode,
    /// Training batch size in samples (the BT slicing/stacking knob).
    pub batch_samples: usize,
    /// Push fresh params back to agents every k trainer updates.
    pub param_sync_every: usize,
    pub lr: f32,
    pub real_replicas: usize,
    /// Per-channel transfer granularity in bytes (the CP staging
    /// threshold). The default balances host-path efficiency
    /// (HOST_MSG_HALF_BYTES) against staging latency on the narrow
    /// channels; Table-8-style sweeps vary it.
    pub compressor_granularity: usize,
    /// Anti-starvation staging bound (virtual seconds): a partially filled
    /// channel queue older than this flushes below the size threshold, so
    /// low-traffic channels (e.g. `Done`) can't stall the batcher.
    pub staging_interval_s: f64,
    /// Elastic mid-run re-provisioning: between rounds, shift SM share
    /// toward the bottleneck role group on GPUs hosting both agents and
    /// trainers (None = static provisioning) — sync training's
    /// bottleneck-shifting support, mirrored for the async pipeline.
    pub elastic: Option<ElasticConfig>,
}

impl Default for AsyncConfig {
    fn default() -> Self {
        AsyncConfig {
            rounds: 10,
            seed: 1,
            share_mode: ShareMode::MultiChannel,
            batch_samples: 8192,
            param_sync_every: 4,
            lr: super::DEFAULT_LR,
            real_replicas: 1,
            compressor_granularity: 256 << 10,
            staging_interval_s: 1.0,
            elastic: None,
        }
    }
}

/// Result: run metrics + channel traffic statistics.
pub struct AsyncRunResult {
    pub metrics: RunMetrics,
    pub channel_stats: crate::channels::ChannelStats,
    /// trainer updates performed.
    pub updates: usize,
    /// Elastic re-provisioning adjustments applied (0 when disabled).
    pub elastic_shifts: usize,
}

pub fn run_async(
    layout: &Layout,
    bench: &BenchInfo,
    cost: &CostModel,
    compute: &Compute,
    cfg: &AsyncConfig,
) -> Result<AsyncRunResult> {
    anyhow::ensure!(
        !layout.rollout_gmis.is_empty() && !layout.trainer_gmis.is_empty(),
        "async layout needs both"
    );

    let mut engine = Engine::new(&layout.manager, cost);
    let mut fabric = Fabric::single_node(layout.manager.topology().clone());
    let agent_ids = engine.add_group(&layout.rollout_gmis)?;
    let trainer_ids = engine.add_group(&layout.trainer_gmis)?;
    let members = crate::workload::member_union(agent_ids, trainer_ids);

    let mut program = AsyncProgram::new(cfg.clone());
    program.bind(&engine, &mut fabric, bench, &members)?;
    run_to_completion(&mut program, &mut engine, &mut fabric, cost, bench, compute)?;

    let metrics = program.finish(&engine, &fabric);
    Ok(AsyncRunResult {
        metrics,
        channel_stats: program.take_channel_stats(),
        updates: program.updates(),
        elastic_shifts: program.elastic_shifts(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::config::static_registry;
    use crate::mapping::build_async_layout;

    fn setup() -> (Layout, BenchInfo, CostModel) {
        let b = static_registry()["AY"].clone();
        let cost = CostModel::new(&b);
        let topo = Topology::dgx_a100(2);
        let layout = build_async_layout(&topo, 1, 3, 2, 2048, &cost).unwrap();
        (layout, b, cost)
    }

    #[test]
    fn async_runs_and_trains() {
        let (layout, b, cost) = setup();
        let cfg = AsyncConfig { rounds: 12, batch_samples: 4096, ..Default::default() };
        let r = run_async(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        assert!(r.metrics.pps > 0.0);
        assert!(r.updates > 0, "no trainer updates happened");
        assert!(r.metrics.ttop > 0.0);
        assert!(r.channel_stats.packets_out > 0);
        // one cumulative learning-signal sample per round, monotone in
        // both virtual time and accumulated reward
        assert_eq!(r.metrics.reward_curve.len(), 12);
        assert!(r
            .metrics
            .reward_curve
            .windows(2)
            .all(|w| w[1].0 >= w[0].0 && w[1].1 >= w[0].1));
    }

    #[test]
    fn mcc_fewer_bigger_packets_than_ucc() {
        // Table 8's mechanism: multi-channel moves the same bytes in fewer,
        // larger transfers.
        // Long enough that steady-state transfer efficiency dominates the
        // pipeline fill/drain tails.
        let (layout, b, cost) = setup();
        let mk = |mode| AsyncConfig {
            rounds: 40,
            batch_samples: 4096,
            share_mode: mode,
            ..Default::default()
        };
        let mcc =
            run_async(&layout, &b, &cost, &Compute::Null, &mk(ShareMode::MultiChannel)).unwrap();
        let ucc =
            run_async(&layout, &b, &cost, &Compute::Null, &mk(ShareMode::UniChannel)).unwrap();
        assert!(
            mcc.channel_stats.packets_out < ucc.channel_stats.packets_out,
            "mcc {} vs ucc {} packets",
            mcc.channel_stats.packets_out,
            ucc.channel_stats.packets_out
        );
        assert!(mcc.channel_stats.mean_packet_bytes() > ucc.channel_stats.mean_packet_bytes());
        // and higher training throughput
        assert!(
            mcc.metrics.ttop >= ucc.metrics.ttop,
            "mcc ttop {} vs ucc {}",
            mcc.metrics.ttop,
            ucc.metrics.ttop
        );
    }

    #[test]
    fn granularity_knob_changes_packetization() {
        // Satellite of the Table 8 sweep: a finer CP staging threshold
        // moves the same bytes in more, smaller packets.
        let (layout, b, cost) = setup();
        let mk = |granularity| AsyncConfig {
            rounds: 12,
            batch_samples: 4096,
            compressor_granularity: granularity,
            ..Default::default()
        };
        let coarse = run_async(&layout, &b, &cost, &Compute::Null, &mk(256 << 10)).unwrap();
        let fine = run_async(&layout, &b, &cost, &Compute::Null, &mk(4 << 10)).unwrap();
        assert!(
            fine.channel_stats.packets_out > coarse.channel_stats.packets_out,
            "fine {} vs coarse {} packets",
            fine.channel_stats.packets_out,
            coarse.channel_stats.packets_out
        );
        assert_eq!(fine.channel_stats.bytes_moved, coarse.channel_stats.bytes_moved);
        assert!(
            fine.channel_stats.mean_packet_bytes() < coarse.channel_stats.mean_packet_bytes()
        );
    }

    #[test]
    fn fabric_links_surface_in_metrics() {
        let (layout, b, cost) = setup();
        let cfg = AsyncConfig { rounds: 6, ..Default::default() };
        let r = run_async(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        assert!(!r.metrics.links.is_empty(), "fabric traffic must be reported");
        // Every packet crossed at least one fabric link; cross-GPU packets
        // and parameter pushes cross more.
        let total: u64 = r.metrics.links.iter().map(|l| l.bytes).sum();
        assert!(
            total >= r.channel_stats.bytes_moved,
            "links {total} vs pipeline {}",
            r.channel_stats.bytes_moved
        );
        assert!(r.metrics.links.iter().all(|l| l.busy_s >= 0.0));
    }

    #[test]
    fn deterministic() {
        let (layout, b, cost) = setup();
        let cfg = AsyncConfig { rounds: 6, ..Default::default() };
        let a = run_async(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        let c = run_async(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        assert_eq!(a.metrics.pps, c.metrics.pps);
        assert_eq!(a.updates, c.updates);
        assert_eq!(a.metrics.reward_curve, c.metrics.reward_curve);
    }

    /// A deliberately imbalanced async layout: starved agent GMIs
    /// co-resident with an over-provisioned trainer on every GPU — the
    /// shape the elastic controller exists to fix (agents and trainers
    /// must share a GPU for share to move between them).
    fn imbalanced_async_layout(topo: &Topology) -> Layout {
        use crate::gmi::{GmiBackend, GmiManager, GmiSpec, Role};
        let mut manager = GmiManager::new(topo.clone());
        let mut rollout = Vec::new();
        let mut trainers = Vec::new();
        let mut id = 0usize;
        for gpu in 0..topo.num_gpus() {
            for _ in 0..2 {
                manager
                    .add_gmi(GmiSpec {
                        id,
                        gpu,
                        sm_share: 0.15,
                        mem_gib: 6.0,
                        backend: GmiBackend::Mps,
                        role: Role::SimAgent,
                        num_env: 2048,
                    })
                    .unwrap();
                rollout.push(id);
                id += 1;
            }
            manager
                .add_gmi(GmiSpec {
                    id,
                    gpu,
                    sm_share: 0.7,
                    mem_gib: 10.0,
                    backend: GmiBackend::Mps,
                    role: Role::Trainer,
                    num_env: 0,
                })
                .unwrap();
            trainers.push(id);
            id += 1;
        }
        Layout {
            manager,
            rollout_gmis: rollout,
            trainer_gmis: trainers,
            gmi_per_gpu: 3,
            num_env_per_gmi: 2048,
            backend: GmiBackend::Mps,
        }
    }

    #[test]
    fn elastic_reprovisioning_beats_static_on_imbalanced_async_layout() {
        // The A3C mirror of sync's bottleneck-shifting claim: a mostly
        // idle co-resident trainer donates SM share to the starved agents
        // between rounds, so agent predictions/s strictly improves.
        let b = static_registry()["AY"].clone();
        let cost = CostModel::new(&b);
        let topo = Topology::dgx_a100(1);
        let cfg_static = AsyncConfig { rounds: 8, batch_samples: 4096, ..Default::default() };
        let cfg_elastic = AsyncConfig {
            rounds: 8,
            batch_samples: 4096,
            elastic: Some(ElasticConfig::default()),
            ..Default::default()
        };
        let s = run_async(&imbalanced_async_layout(&topo), &b, &cost, &Compute::Null, &cfg_static)
            .unwrap();
        let e =
            run_async(&imbalanced_async_layout(&topo), &b, &cost, &Compute::Null, &cfg_elastic)
                .unwrap();
        assert_eq!(s.elastic_shifts, 0, "static run must not re-provision");
        assert!(e.elastic_shifts > 0, "controller never re-provisioned");
        assert!(
            e.metrics.pps > s.metrics.pps,
            "elastic {} vs static {}",
            e.metrics.pps,
            s.metrics.pps
        );
        // The caller's layout is a static description: elastic runs never
        // mutate it (the engine re-provisions its own live clone).
        let layout = imbalanced_async_layout(&topo);
        run_async(&layout, &b, &cost, &Compute::Null, &cfg_elastic).unwrap();
        assert_eq!(layout.manager.gmi(0).unwrap().sm_share, 0.15);
    }
}
