//! Asynchronized DRL training (A3C-style) with channel-based experience
//! sharing — paper §4.2, Fig 6b, Fig 11, Table 8.
//!
//! Serving GMIs (decoupled GPUs) continuously collect experience; the
//! dispenser/compressor/migrator/batcher pipeline moves it to trainer GMIs
//! on the training GPUs; trainers update asynchronously and periodically
//! push fresh parameters back to the agents.
//!
//! Timing runs on the shared [`engine`](crate::engine): agents and trainers
//! are executors; batch consumption is a blocking-receive charge
//! (`charge_after`) against the batch's pipeline arrival time. All
//! experience and parameter movement flows over the communication
//! [`fabric`](crate::fabric): the migrator executes per-packet routes with
//! per-link occupancy (contended links serialize), and the periodic
//! parameter push-back is a fabric plan.

use std::collections::BTreeMap;

use anyhow::Result;

use super::compute::Compute;
use crate::channels::{
    Batcher, ChannelStats, Compressor, Dispenser, Migrator, RolloutSegment, ShareMode,
    TrainerEndpoint,
};
use crate::config::BenchInfo;
use crate::engine::{Engine, ExecutorId, OpCharge};
use crate::fabric::Fabric;
use crate::mapping::Layout;
use crate::metrics::{RewardTracker, RunMetrics};
use crate::vtime::{CostModel, OpKind};

#[derive(Debug, Clone)]
pub struct AsyncConfig {
    /// Rollout rounds per serving GMI.
    pub rounds: usize,
    pub seed: i32,
    pub share_mode: ShareMode,
    /// Training batch size in samples (the BT slicing/stacking knob).
    pub batch_samples: usize,
    /// Push fresh params back to agents every k trainer updates.
    pub param_sync_every: usize,
    pub lr: f32,
    pub real_replicas: usize,
    /// Per-channel transfer granularity in bytes (the CP staging
    /// threshold). The default balances host-path efficiency
    /// (HOST_MSG_HALF_BYTES) against staging latency on the narrow
    /// channels; Table-8-style sweeps vary it.
    pub compressor_granularity: usize,
    /// Anti-starvation staging bound (virtual seconds): a partially filled
    /// channel queue older than this flushes below the size threshold, so
    /// low-traffic channels (e.g. `Done`) can't stall the batcher.
    pub staging_interval_s: f64,
}

impl Default for AsyncConfig {
    fn default() -> Self {
        AsyncConfig {
            rounds: 10,
            seed: 1,
            share_mode: ShareMode::MultiChannel,
            batch_samples: 8192,
            param_sync_every: 4,
            lr: super::DEFAULT_LR,
            real_replicas: 1,
            compressor_granularity: 256 << 10,
            staging_interval_s: 1.0,
        }
    }
}

/// Result: run metrics + channel traffic statistics.
pub struct AsyncRunResult {
    pub metrics: RunMetrics,
    pub channel_stats: ChannelStats,
    /// trainer updates performed.
    pub updates: usize,
}

pub fn run_async(
    layout: &Layout,
    bench: &BenchInfo,
    cost: &CostModel,
    compute: &Compute,
    cfg: &AsyncConfig,
) -> Result<AsyncRunResult> {
    let agents = &layout.rollout_gmis;
    let trainers = &layout.trainer_gmis;
    anyhow::ensure!(!agents.is_empty() && !trainers.is_empty(), "async layout needs both");

    let mut fabric = Fabric::single_node(layout.manager.topology().clone());
    let endpoints: Vec<TrainerEndpoint> = trainers
        .iter()
        .map(|&g| TrainerEndpoint { gmi: g, gpu: layout.manager.gmi(g).unwrap().gpu })
        .collect();
    let mut migrator = Migrator::new(endpoints);
    let mut agent_gpus: Vec<usize> = Vec::new();
    for &a in agents {
        let gpu = layout.manager.gmi(a).unwrap().gpu;
        migrator.register_agent(a, gpu);
        if !agent_gpus.contains(&gpu) {
            agent_gpus.push(gpu);
        }
    }
    let mut dispensers: Vec<Dispenser> = agents
        .iter()
        .map(|&a| Dispenser::new(a, bench.obs_dim, bench.act_dim))
        .collect();
    let mut compressor = Compressor::with_staging_interval(
        cfg.share_mode,
        cfg.compressor_granularity,
        cfg.staging_interval_s,
    );
    let mut batchers: BTreeMap<usize, Batcher> = trainers
        .iter()
        .map(|&t| (t, Batcher::new(t, cfg.share_mode, cfg.batch_samples)))
        .collect();

    // Real numerics on replica 0 only (agents mirror; trainers re-use the
    // last real rollout for real gradient calls — same bytes the pipeline
    // carries, see DESIGN.md §5).
    let real_n = cfg.real_replicas.min(agents.len()).max(1);
    let mut agent_workers = Vec::with_capacity(real_n);
    for _ in 0..real_n {
        agent_workers.push(compute.init(bench, cfg.seed)?);
    }
    let mut trainer_worker = compute.init(bench, cfg.seed)?;
    let mut last_real_rollout = None;

    let mut engine = Engine::new(&layout.manager, cost);
    let agent_ids = engine.add_group(agents)?;
    let trainer_ids: BTreeMap<usize, ExecutorId> = trainers
        .iter()
        .copied()
        .zip(engine.add_group(trainers)?)
        .collect();
    let mut stats = ChannelStats::default();
    let mut rewards = RewardTracker::default();
    let m = bench.horizon;
    let mut updates = 0usize;
    let mut samples_trained = 0usize;
    let mut reward_sum = 0.0f64;
    let mut reward_n = 0usize;
    // (trainer batch queue handled inline: batches process on arrival.)

    for round in 0..cfg.rounds {
        let mut round_reward = 0.0f64;
        let mut round_n = 0usize;
        for i in 0..agents.len() {
            let n_env = engine.num_env(agent_ids[i]);

            // rollout segment (sim + fwd per step); only the simulation
            // records occupancy — the agent forward overlaps the pipeline.
            let now = engine.charge_steps(
                cost,
                agent_ids[i],
                m as f64,
                &[
                    OpCharge::recorded(OpKind::SimStep { num_env: n_env }),
                    OpCharge::unrecorded(OpKind::PolicyFwd { num_env: n_env }),
                ],
                0.0,
            );

            // Rollout numerics on the real replicas. Under Null compute
            // only the deterministic pseudo reward is needed for the
            // Fig 9-style curve — no tensors are materialized.
            let seed = cfg.seed + (round * 257 + i) as i32;
            let ro = if compute.is_real() && i < real_n {
                Some(compute.rollout(bench, &mut agent_workers[i], seed)?)
            } else {
                None
            };
            if i < real_n {
                let r = ro
                    .as_ref()
                    .map(|ro| ro.mean_reward)
                    .unwrap_or_else(|| Compute::null_mean_reward(seed))
                    as f64;
                reward_sum += r;
                reward_n += 1;
                round_reward += r;
                round_n += 1;
            }

            // experience: real bytes on real replicas, synthetic otherwise.
            // In Null mode everything is synthetic at the GMI's own env
            // count (the artifact batch size is irrelevant without real
            // numerics).
            let seg = match &ro {
                Some(ro) => RolloutSegment {
                    steps: bench.horizon,
                    envs: bench.num_env,
                    obs: ro.obs.as_f32()?.to_vec(),
                    actions: ro.actions.as_f32()?.to_vec(),
                    logps: ro.logps.as_f32()?.to_vec(),
                    rewards: ro.rewards.as_f32()?.to_vec(),
                    values: ro.values.as_f32()?.to_vec(),
                    dones: ro.dones.as_f32()?.to_vec(),
                },
                None => RolloutSegment::synthetic(m, n_env, bench.obs_dim, bench.act_dim),
            };
            if let Some(ro) = ro {
                last_real_rollout = Some(ro);
            }

            // DP -> CP -> MG -> BT. Chunks are grouped along the step axis
            // at training-batch granularity; the migrator's sticky
            // per-agent routing keeps all channels of an agent aligned at
            // one trainer while agents balance across trainers.
            let steps_per_group = (cfg.batch_samples / n_env.max(1)).max(1);
            let groups =
                dispensers[i].dispense_groups(&seg, now, cfg.share_mode, steps_per_group);
            let mut packets = Vec::new();
            for group in groups {
                stats.chunks_in += group.len() as u64;
                packets.extend(compressor.push(group));
            }
            for pkt in packets {
                let decision = migrator.route(&mut fabric, &pkt);
                // The sender pays a per-message submission overhead on its
                // own timeline (IPC rendezvous + serialization) — the cost
                // that makes fine-grained UCC sharing slow on the agent
                // side (§4.2 / Table 8's PPS gap).
                engine.pay(agent_ids[i], decision.sender_s);
                stats.transfer_seconds += decision.transfer_s;
                stats.transfer_ops += 1;
                stats.packets_out += 1;
                stats.bytes_moved += pkt.bytes() as u64;
                let ready_batches = {
                    let batcher = batchers.get_mut(&decision.trainer).unwrap();
                    batcher.push(pkt, decision.arrival)
                };

                // trainer consumes ready batches immediately (async)
                for batch in ready_batches {
                    let tid = trainer_ids[&decision.trainer];
                    engine.charge_after(
                        cost,
                        tid,
                        batch.ready,
                        &[
                            OpCharge::recorded(OpKind::TrainGrad { samples: batch.samples }),
                            OpCharge::unrecorded(OpKind::AdamApply),
                        ],
                    );
                    migrator.complete(decision.trainer, batch.samples);
                    samples_trained += batch.samples;
                    updates += 1;

                    // real gradient + update on the trainer worker
                    if compute.is_real() {
                        if let Some(ro) = &last_real_rollout {
                            let (g, _) = compute.grad(bench, &trainer_worker, ro)?;
                            compute.apply(bench, &mut trainer_worker, &g, cfg.lr)?;
                        }
                    }

                    // param push-back every k updates. A3C is asynchronous:
                    // agents never BLOCK on the trainer (they keep acting
                    // on stale parameters); they only pay the receive cost
                    // of the pushed tensor on their own timeline. The push
                    // is a fabric plan (NVLink crossing + host delivery
                    // into each agent GMI).
                    if updates % cfg.param_sync_every == 0 {
                        let push = fabric.plan_param_push(bench.param_bytes(), &agent_gpus);
                        fabric.tally(&push, 1.0);
                        engine.pay_group(&agent_ids, push.total_s());
                        for w in agent_workers.iter_mut() {
                            w.params = trainer_worker.params.clone();
                        }
                    }
                }
            }
        }

        // Fig 9-style learning signal: accumulate this round's mean reward
        // into the cumulative curve at the agents' current virtual time
        // (same RewardTracker semantics as run_sync).
        if round_n > 0 {
            rewards.push(
                engine.max_time(&agent_ids).seconds(),
                round_reward / round_n as f64,
            );
        }
    }

    // flush stragglers through the pipeline (counted but not trained)
    let leftover = compressor.flush();
    for pkt in leftover {
        stats.packets_out += 1;
        stats.bytes_moved += pkt.bytes() as u64;
    }

    let agent_span = engine.max_time(&agent_ids).seconds();
    let span = engine.span();
    let total_preds =
        (cfg.rounds * m) as f64 * agents.len() as f64 * layout.num_env_per_gmi as f64;
    let metrics = RunMetrics {
        steps_per_sec: total_preds / span,
        pps: total_preds / agent_span,
        ttop: samples_trained as f64 / span,
        span_s: span,
        utilization: engine.mean_utilization(),
        final_reward: if reward_n > 0 { reward_sum / reward_n as f64 } else { 0.0 },
        reward_curve: rewards.curve.clone(),
        comm_s: stats.transfer_seconds,
        peak_mem_gib: cost.mem_gib(layout.num_env_per_gmi, m, true, false),
        links: fabric.link_report(),
        latency: None,
    };
    Ok(AsyncRunResult { metrics, channel_stats: stats, updates })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::config::static_registry;
    use crate::mapping::build_async_layout;

    fn setup() -> (Layout, BenchInfo, CostModel) {
        let b = static_registry()["AY"].clone();
        let cost = CostModel::new(&b);
        let topo = Topology::dgx_a100(2);
        let layout = build_async_layout(&topo, 1, 3, 2, 2048, &cost).unwrap();
        (layout, b, cost)
    }

    #[test]
    fn async_runs_and_trains() {
        let (layout, b, cost) = setup();
        let cfg = AsyncConfig { rounds: 12, batch_samples: 4096, ..Default::default() };
        let r = run_async(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        assert!(r.metrics.pps > 0.0);
        assert!(r.updates > 0, "no trainer updates happened");
        assert!(r.metrics.ttop > 0.0);
        assert!(r.channel_stats.packets_out > 0);
        // one cumulative learning-signal sample per round, monotone in
        // both virtual time and accumulated reward
        assert_eq!(r.metrics.reward_curve.len(), 12);
        assert!(r
            .metrics
            .reward_curve
            .windows(2)
            .all(|w| w[1].0 >= w[0].0 && w[1].1 >= w[0].1));
    }

    #[test]
    fn mcc_fewer_bigger_packets_than_ucc() {
        // Table 8's mechanism: multi-channel moves the same bytes in fewer,
        // larger transfers.
        // Long enough that steady-state transfer efficiency dominates the
        // pipeline fill/drain tails.
        let (layout, b, cost) = setup();
        let mk = |mode| AsyncConfig {
            rounds: 40,
            batch_samples: 4096,
            share_mode: mode,
            ..Default::default()
        };
        let mcc =
            run_async(&layout, &b, &cost, &Compute::Null, &mk(ShareMode::MultiChannel)).unwrap();
        let ucc =
            run_async(&layout, &b, &cost, &Compute::Null, &mk(ShareMode::UniChannel)).unwrap();
        assert!(
            mcc.channel_stats.packets_out < ucc.channel_stats.packets_out,
            "mcc {} vs ucc {} packets",
            mcc.channel_stats.packets_out,
            ucc.channel_stats.packets_out
        );
        assert!(mcc.channel_stats.mean_packet_bytes() > ucc.channel_stats.mean_packet_bytes());
        // and higher training throughput
        assert!(
            mcc.metrics.ttop >= ucc.metrics.ttop,
            "mcc ttop {} vs ucc {}",
            mcc.metrics.ttop,
            ucc.metrics.ttop
        );
    }

    #[test]
    fn granularity_knob_changes_packetization() {
        // Satellite of the Table 8 sweep: a finer CP staging threshold
        // moves the same bytes in more, smaller packets.
        let (layout, b, cost) = setup();
        let mk = |granularity| AsyncConfig {
            rounds: 12,
            batch_samples: 4096,
            compressor_granularity: granularity,
            ..Default::default()
        };
        let coarse = run_async(&layout, &b, &cost, &Compute::Null, &mk(256 << 10)).unwrap();
        let fine = run_async(&layout, &b, &cost, &Compute::Null, &mk(4 << 10)).unwrap();
        assert!(
            fine.channel_stats.packets_out > coarse.channel_stats.packets_out,
            "fine {} vs coarse {} packets",
            fine.channel_stats.packets_out,
            coarse.channel_stats.packets_out
        );
        assert_eq!(fine.channel_stats.bytes_moved, coarse.channel_stats.bytes_moved);
        assert!(
            fine.channel_stats.mean_packet_bytes() < coarse.channel_stats.mean_packet_bytes()
        );
    }

    #[test]
    fn fabric_links_surface_in_metrics() {
        let (layout, b, cost) = setup();
        let cfg = AsyncConfig { rounds: 6, ..Default::default() };
        let r = run_async(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        assert!(!r.metrics.links.is_empty(), "fabric traffic must be reported");
        // Every packet crossed at least one fabric link; cross-GPU packets
        // and parameter pushes cross more.
        let total: u64 = r.metrics.links.iter().map(|l| l.bytes).sum();
        assert!(
            total >= r.channel_stats.bytes_moved,
            "links {total} vs pipeline {}",
            r.channel_stats.bytes_moved
        );
        assert!(r.metrics.links.iter().all(|l| l.busy_s >= 0.0));
    }

    #[test]
    fn deterministic() {
        let (layout, b, cost) = setup();
        let cfg = AsyncConfig { rounds: 6, ..Default::default() };
        let a = run_async(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        let c = run_async(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        assert_eq!(a.metrics.pps, c.metrics.pps);
        assert_eq!(a.updates, c.updates);
        assert_eq!(a.metrics.reward_curve, c.metrics.reward_curve);
    }
}
