//! DRL serving: continuous experience collection (paper §5.1, Fig 7a).
//!
//! Every serving GMI loops environment-simulator + agent interaction. For
//! TCG layouts the state/action handoff is intra-GMI (free); for TDG
//! layouts each interaction round ships `2S + A + W` bytes across the GMI
//! boundary (Table 4's COM term) — the cost that motivates co-location.
//!
//! Timing runs on the shared [`engine`](crate::engine): each serving GMI is
//! one executor; the TDG boundary crossing is a [`fabric`](crate::fabric)
//! intra-GPU plan charged as unoccupied per-step time on the same timeline
//! (and tallied into the per-link traffic report). The round loop lives in
//! the steppable workload program
//! ([`workload::ClosedServingProgram`](crate::workload::ClosedServingProgram))
//! shared with the multi-tenant scheduler; [`run_serving`] is the thin
//! standalone driver.

use anyhow::Result;

use super::compute::Compute;
use crate::config::BenchInfo;
use crate::engine::{Engine, OpCharge};
use crate::fabric::Fabric;
use crate::gmi::Role;
use crate::mapping::Layout;
use crate::metrics::RunMetrics;
use crate::vtime::{CostModel, OpKind};
use crate::workload::{run_to_completion, ClosedServingProgram, Workload};

#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Interaction rounds (each = horizon env steps).
    pub rounds: usize,
    pub seed: i32,
    pub real_replicas: usize,
}

/// Does the layout use dedicated simulator/agent GMIs — the TDG serving
/// design the paper rejects? Such fleets pay [`tdg_agent_fwd`] plus the
/// per-step boundary crossing; shared by the closed-loop model here and
/// the open-loop gateway ([`serve`](crate::serve)).
pub fn is_dedicated(layout: &Layout) -> bool {
    layout
        .manager
        .all()
        .any(|g| matches!(g.role, Role::Simulator | Role::Agent))
}

/// The TDG dedicated-agent policy forward: charged at the batch size but
/// timed at the agent GMI's slice of the pair budget (alpha ~ 0.25 of
/// `pair_share`, floored at 2% of the GPU). The one place this model is
/// calibrated — both serving loops charge through it.
pub fn tdg_agent_fwd(num_env: usize, pair_share: f64) -> OpCharge {
    OpCharge::recorded(OpKind::PolicyFwd { num_env })
        .with_time_share((pair_share * 0.25).max(0.02))
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig { rounds: 10, seed: 1, real_replicas: 1 }
    }
}

pub fn run_serving(
    layout: &Layout,
    bench: &BenchInfo,
    cost: &CostModel,
    compute: &Compute,
    cfg: &ServingConfig,
) -> Result<RunMetrics> {
    anyhow::ensure!(!layout.rollout_gmis.is_empty(), "no serving GMIs");

    let mut engine = Engine::new(&layout.manager, cost);
    let mut fabric = Fabric::single_node(layout.manager.topology().clone());
    let ids = engine.add_group(&layout.rollout_gmis)?;

    let mut program = ClosedServingProgram::new(cfg.clone());
    program.bind(&engine, &mut fabric, bench, &ids)?;
    run_to_completion(&mut program, &mut engine, &mut fabric, cost, bench, compute)?;
    Ok(program.finish(&engine, &fabric))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::config::static_registry;
    use crate::mapping::{build_serving_layout, MappingTemplate};

    #[test]
    fn tcg_serving_beats_tdg() {
        // Table 4 / Eq 2: co-location ~2.5x over dedicated GMIs.
        let b = static_registry()["AT"].clone();
        let cost = CostModel::new(&b);
        let topo = Topology::dgx_a100(1);
        let cfg = ServingConfig { rounds: 5, ..Default::default() };
        let tcg =
            build_serving_layout(&topo, MappingTemplate::TaskColocated, 3, 1024, &cost, None)
                .unwrap();
        let tdg =
            build_serving_layout(&topo, MappingTemplate::TaskDedicated, 3, 1024, &cost, None)
                .unwrap();
        let r1 = run_serving(&tcg, &b, &cost, &Compute::Null, &cfg).unwrap();
        let r2 = run_serving(&tdg, &b, &cost, &Compute::Null, &cfg).unwrap();
        let gain = r1.steps_per_sec / r2.steps_per_sec;
        assert!(gain > 1.5, "TCG/TDG serving gain {gain}");
    }

    #[test]
    fn tdg_reports_fabric_comm_time() {
        // Regression: the TDG boundary crossings are tallied on the fabric
        // but used to be reported as comm_s = 0.
        let b = static_registry()["AT"].clone();
        let cost = CostModel::new(&b);
        let topo = Topology::dgx_a100(1);
        let cfg = ServingConfig { rounds: 5, ..Default::default() };
        let tcg =
            build_serving_layout(&topo, MappingTemplate::TaskColocated, 3, 1024, &cost, None)
                .unwrap();
        let tdg =
            build_serving_layout(&topo, MappingTemplate::TaskDedicated, 3, 1024, &cost, None)
                .unwrap();
        let r_tcg = run_serving(&tcg, &b, &cost, &Compute::Null, &cfg).unwrap();
        let r_tdg = run_serving(&tdg, &b, &cost, &Compute::Null, &cfg).unwrap();
        assert_eq!(r_tcg.comm_s, 0.0, "TCG crossings are intra-GMI (free)");
        assert!(r_tdg.comm_s > 0.0, "TDG crossings must be reported");
        // The reported figure is exactly the fabric's tallied busy time.
        let tallied: f64 = r_tdg.links.iter().map(|l| l.busy_s).sum();
        assert!(
            (r_tdg.comm_s - tallied).abs() < 1e-9,
            "comm_s {} vs fabric tally {tallied}",
            r_tdg.comm_s
        );
    }

    #[test]
    fn multi_gmi_serving_beats_single_process() {
        let b = static_registry()["AT"].clone();
        let cost = CostModel::new(&b);
        let topo = Topology::dgx_a100(1);
        let cfg = ServingConfig { rounds: 5, ..Default::default() };
        // 3 GMIs x 1024 envs vs 1 exclusive x 3072 envs: same total envs.
        let multi =
            build_serving_layout(&topo, MappingTemplate::TaskColocated, 3, 1024, &cost, None)
                .unwrap();
        let single =
            build_serving_layout(&topo, MappingTemplate::TaskColocated, 1, 3072, &cost, None)
                .unwrap();
        let rm = run_serving(&multi, &b, &cost, &Compute::Null, &cfg).unwrap();
        let rs = run_serving(&single, &b, &cost, &Compute::Null, &cfg).unwrap();
        let gain = rm.steps_per_sec / rs.steps_per_sec;
        assert!(gain > 1.5 && gain < 3.5, "multiplexing gain {gain}");
        // And utilization improves (Fig 1b -> fixed).
        assert!(rm.utilization > rs.utilization);
    }
}
