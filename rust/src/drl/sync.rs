//! Synchronized DRL training (PPO) over a GMI layout — the paper's main
//! workload (Fig 6a, Fig 7b/c, Table 7).
//!
//! Each iteration: (i) experience collection (rollout) on every
//! rollout-capable GMI, (ii) PPO gradient epochs with layout-aware gradient
//! reduction across trainer GMIs, (iii) Adam update everywhere. For TDG_EX
//! layouts the experience additionally crosses GMI boundaries (the cost the
//! paper's TCG_EX avoids).
//!
//! The iteration loop itself lives in the steppable workload program
//! ([`workload::SyncProgram`](crate::workload::SyncProgram)) — ONE
//! implementation shared with the multi-tenant scheduler — and
//! [`run_sync`] is the thin standalone driver: build the engine + fabric
//! from the layout, bind the program, and step it to completion. All
//! timing runs on the shared [`engine`](crate::engine); every transfer
//! (gradient reduction, TDG experience/parameter movement) is a
//! [`fabric`](crate::fabric) plan executed as an engine event. With
//! [`SyncConfig::elastic`] set, the engine's elastic controller
//! re-provisions SM shares between iterations toward the bottleneck role.
//!
//! ## Overlap semantics ([`SyncConfig::overlap`], on by default)
//!
//! With overlap, a minibatch's gradient reduction drains on the fabric
//! links while the trainers already compute the next minibatch (bucketed
//! DDP-style pipelining), and the *last* reduction of an iteration drains
//! while the next iteration's rollout starts. The true data dependency is
//! preserved where it lands: the first gradient of the next epoch (it
//! consumes the reduced parameters) blocks on the previous epoch's final
//! reduction via `charge_after`, and the run's span includes the final
//! drain. The reduction *arithmetic* is unaffected — both schedules call
//! the identical numerics, so reduced gradients are bit-identical; only
//! the virtual timeline changes. `overlap: false` reproduces the strictly
//! sequential per-minibatch barrier schedule.

use anyhow::Result;

use super::compute::Compute;
use crate::comm::ReduceStrategy;
use crate::config::BenchInfo;
use crate::engine::{ElasticConfig, Engine};
use crate::fabric::Fabric;
use crate::mapping::Layout;
use crate::metrics::RunMetrics;
use crate::vtime::CostModel;
use crate::workload::{run_to_completion, SyncProgram, Workload};

/// Sync-training run configuration.
#[derive(Debug, Clone)]
pub struct SyncConfig {
    pub iterations: usize,
    pub ppo_epochs: usize,
    /// PPO minibatches per epoch: each is a separate gradient + LGR
    /// reduction (Isaac PPO runs epochs x minibatches collective ops per
    /// iteration — the traffic pattern Table 7 measures).
    pub minibatches: usize,
    pub lr: f32,
    pub seed: i32,
    /// How many GMIs execute *real* numerics; the rest mirror replica 0's
    /// results (data-parallel replicas are statistically identical; the
    /// virtual timing is charged for every GMI regardless).
    pub real_replicas: usize,
    /// Force a reduction strategy (`--reduce`; None = the fabric planner's
    /// cheapest valid plan).
    pub strategy_override: Option<ReduceStrategy>,
    /// Elastic mid-run re-provisioning: between iterations, shift SM share
    /// toward the bottleneck role group (None = static provisioning).
    pub elastic: Option<ElasticConfig>,
    /// Overlap gradient reductions with trainer compute and the next
    /// rollout (paper §4.2 pipelined transfers); `false` reproduces the
    /// strictly sequential per-minibatch barrier schedule.
    pub overlap: bool,
}

impl Default for SyncConfig {
    fn default() -> Self {
        SyncConfig {
            iterations: 10,
            ppo_epochs: super::DEFAULT_PPO_EPOCHS,
            minibatches: super::DEFAULT_MINIBATCHES,
            lr: super::DEFAULT_LR,
            seed: 1,
            real_replicas: 1,
            strategy_override: None,
            elastic: None,
            overlap: true,
        }
    }
}

/// Result of a sync-training run.
pub struct SyncRunResult {
    pub metrics: RunMetrics,
    pub strategy: ReduceStrategy,
    /// Final parameters of GMI 0 (for checkpoint-style consumers).
    pub final_params: Vec<f32>,
    pub stats_per_iter: Vec<super::TrainStats>,
    /// Elastic re-provisioning adjustments applied (0 when disabled).
    pub elastic_shifts: usize,
}

pub fn run_sync(
    layout: &Layout,
    bench: &BenchInfo,
    cost: &CostModel,
    compute: &Compute,
    cfg: &SyncConfig,
) -> Result<SyncRunResult> {
    let n_roll = layout.rollout_gmis.len();
    let n_train = layout.trainer_gmis.len();
    anyhow::ensure!(n_roll > 0 && n_train > 0, "layout has no rollout/trainer GMIs");

    // The engine clones the layout's manager (the caller's static layout
    // is never mutated, even by elastic runs) and the run's one fabric
    // both plans and executes every transfer.
    let mut engine = Engine::new(&layout.manager, cost);
    let mut fabric = Fabric::single_node(layout.manager.topology().clone());
    let roll_ids = engine.add_group(&layout.rollout_gmis)?;
    let tr_ids = engine.add_group(&layout.trainer_gmis)?;
    let members = crate::workload::member_union(roll_ids, tr_ids);

    let mut program = SyncProgram::new(cfg.clone(), bench.horizon);
    program.bind(&engine, &mut fabric, bench, &members)?;
    run_to_completion(&mut program, &mut engine, &mut fabric, cost, bench, compute)?;

    let metrics = program.finish(&engine, &fabric);
    Ok(SyncRunResult {
        metrics,
        strategy: program.strategy(),
        final_params: program.take_final_params(),
        stats_per_iter: program.take_stats(),
        elastic_shifts: program.elastic_shifts(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::config::static_registry;
    use crate::gmi::{GmiBackend, GmiManager, GmiSpec, Role};
    use crate::mapping::{build_sync_layout, MappingTemplate};

    fn setup(gpus: usize, t: usize) -> (Layout, BenchInfo, CostModel) {
        let b = static_registry()["AT"].clone();
        let cost = CostModel::new(&b);
        let topo = Topology::dgx_a100(gpus);
        let layout =
            build_sync_layout(&topo, MappingTemplate::TaskColocated, t, 1024, &cost, None)
                .unwrap();
        (layout, b, cost)
    }

    #[test]
    fn runs_and_reports() {
        let (layout, b, cost) = setup(2, 2);
        let r = run_sync(&layout, &b, &cost, &Compute::Null, &SyncConfig::default()).unwrap();
        assert!(r.metrics.steps_per_sec > 0.0);
        assert!(r.metrics.span_s > 0.0);
        assert!(r.metrics.utilization > 0.0 && r.metrics.utilization <= 1.0);
        assert_eq!(r.metrics.reward_curve.len(), 10);
        // 2 GPUs x 2 GMIs -> MRR: the planner's cheapest plan (rings over
        // NVSwitch), agreeing with Algorithm 1 here.
        assert_eq!(r.strategy, ReduceStrategy::MultiRing);
        // static provisioning by default
        assert_eq!(r.elastic_shifts, 0);
        // fabric traffic surfaced
        assert!(!r.metrics.links.is_empty());
    }

    #[test]
    fn planner_drives_strategy() {
        // t > g: MRR is invalid; the cheapest valid plan is hierarchical —
        // the same verdict Algorithm 1 reaches.
        let (layout, b, cost) = setup(2, 3);
        let r = run_sync(&layout, &b, &cost, &Compute::Null, &SyncConfig::default()).unwrap();
        assert_eq!(r.strategy, ReduceStrategy::Hierarchical);
    }

    #[test]
    fn har_beats_mpr_in_throughput() {
        // Table 7's claim at the run level.
        let (layout, b, cost) = setup(4, 4);
        let mut cfg = SyncConfig { iterations: 5, ..Default::default() };
        cfg.strategy_override = Some(ReduceStrategy::MultiProcess);
        let mpr = run_sync(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        cfg.strategy_override = Some(ReduceStrategy::Hierarchical);
        let har = run_sync(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        assert!(
            har.metrics.steps_per_sec > mpr.metrics.steps_per_sec,
            "HAR {} vs MPR {}",
            har.metrics.steps_per_sec,
            mpr.metrics.steps_per_sec
        );
    }

    #[test]
    fn tcg_ex_beats_tdg_ex() {
        let b = static_registry()["AT"].clone();
        let cost = CostModel::new(&b);
        let topo = Topology::dgx_a100(2);
        let cfg = SyncConfig { iterations: 5, ..Default::default() };
        let tcg =
            build_sync_layout(&topo, MappingTemplate::TaskColocated, 3, 1024, &cost, None)
                .unwrap();
        let tdg =
            build_sync_layout(&topo, MappingTemplate::TaskDedicated, 3, 1024, &cost, None)
                .unwrap();
        let r_tcg = run_sync(&tcg, &b, &cost, &Compute::Null, &cfg).unwrap();
        let r_tdg = run_sync(&tdg, &b, &cost, &Compute::Null, &cfg).unwrap();
        assert!(
            r_tcg.metrics.steps_per_sec > r_tdg.metrics.steps_per_sec,
            "TCG {} vs TDG {}",
            r_tcg.metrics.steps_per_sec,
            r_tdg.metrics.steps_per_sec
        );
    }

    #[test]
    fn deterministic() {
        let (layout, b, cost) = setup(2, 2);
        let cfg = SyncConfig { iterations: 3, ..Default::default() };
        let a = run_sync(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        let c = run_sync(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        assert_eq!(a.metrics.steps_per_sec, c.metrics.steps_per_sec);
        assert_eq!(a.final_params, c.final_params);
    }

    // Overlap-vs-sequential behavior (strict speedup, bit-identical
    // parameters, identical per-link traffic) is covered end-to-end by
    // the integration suite in `rust/tests/fabric_overlap.rs`.

    /// A deliberately imbalanced TDG_EX layout: starved rollout GMIs next
    /// to an over-provisioned trainer on every GPU.
    fn imbalanced_layout(topo: &Topology) -> Layout {
        let mut manager = GmiManager::new(topo.clone());
        let mut rollout = Vec::new();
        let mut trainers = Vec::new();
        let mut id = 0usize;
        for gpu in 0..topo.num_gpus() {
            for _ in 0..2 {
                manager
                    .add_gmi(GmiSpec {
                        id,
                        gpu,
                        sm_share: 0.15,
                        mem_gib: 6.0,
                        backend: GmiBackend::Mps,
                        role: Role::SimAgent,
                        num_env: 1024,
                    })
                    .unwrap();
                rollout.push(id);
                id += 1;
            }
            manager
                .add_gmi(GmiSpec {
                    id,
                    gpu,
                    sm_share: 0.7,
                    mem_gib: 10.0,
                    backend: GmiBackend::Mps,
                    role: Role::Trainer,
                    num_env: 0,
                })
                .unwrap();
            trainers.push(id);
            id += 1;
        }
        Layout {
            manager,
            rollout_gmis: rollout,
            trainer_gmis: trainers,
            gmi_per_gpu: 3,
            num_env_per_gmi: 1024,
            backend: GmiBackend::Mps,
        }
    }

    #[test]
    fn elastic_reprovisioning_beats_static_on_imbalanced_layout() {
        let b = static_registry()["AT"].clone();
        let cost = CostModel::new(&b);
        let topo = Topology::dgx_a100(2);
        let cfg_static = SyncConfig { iterations: 8, ..Default::default() };
        let cfg_elastic = SyncConfig {
            iterations: 8,
            elastic: Some(ElasticConfig::default()),
            ..Default::default()
        };
        let s =
            run_sync(&imbalanced_layout(&topo), &b, &cost, &Compute::Null, &cfg_static).unwrap();
        let e =
            run_sync(&imbalanced_layout(&topo), &b, &cost, &Compute::Null, &cfg_elastic).unwrap();
        assert!(e.elastic_shifts > 0, "controller never re-provisioned");
        assert!(
            e.metrics.steps_per_sec > s.metrics.steps_per_sec,
            "elastic {} vs static {}",
            e.metrics.steps_per_sec,
            s.metrics.steps_per_sec
        );
        // The caller's layout is a static description: elastic runs never
        // mutate it (the engine re-provisions its own live clone).
        let layout = imbalanced_layout(&topo);
        run_sync(&layout, &b, &cost, &Compute::Null, &cfg_elastic).unwrap();
        assert_eq!(layout.manager.gmi(0).unwrap().sm_share, 0.15);
    }

    #[test]
    fn elastic_is_noop_on_colocated_layouts() {
        let (layout, b, cost) = setup(2, 2);
        let cfg = SyncConfig {
            iterations: 3,
            elastic: Some(ElasticConfig::default()),
            ..Default::default()
        };
        let e = run_sync(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        let s = run_sync(&layout, &b, &cost, &Compute::Null, &SyncConfig {
            iterations: 3,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(e.elastic_shifts, 0);
        assert_eq!(e.metrics.steps_per_sec, s.metrics.steps_per_sec);
    }
}
