//! Synchronized DRL training (PPO) over a GMI layout — the paper's main
//! workload (Fig 6a, Fig 7b/c, Table 7).
//!
//! Each iteration: (i) experience collection (rollout) on every
//! rollout-capable GMI, (ii) PPO gradient epochs with layout-aware gradient
//! reduction across trainer GMIs, (iii) Adam update everywhere. For TDG_EX
//! layouts the experience additionally crosses GMI boundaries (the cost the
//! paper's TCG_EX avoids).
//!
//! All timing runs on the shared [`engine`](crate::engine): this module
//! describes *what* executes where; clocks, share math, and utilization
//! accounting live in the engine, and every transfer (gradient reduction,
//! TDG experience/parameter movement) is a [`fabric`](crate::fabric) plan
//! executed as an engine event. With [`SyncConfig::elastic`] set, the
//! engine's elastic controller re-provisions SM shares between iterations
//! toward the bottleneck role.
//!
//! ## Overlap semantics ([`SyncConfig::overlap`], on by default)
//!
//! With overlap, a minibatch's gradient reduction drains on the fabric
//! links while the trainers already compute the next minibatch (bucketed
//! DDP-style pipelining), and the *last* reduction of an iteration drains
//! while the next iteration's rollout starts. The true data dependency is
//! preserved where it lands: the first gradient of the next epoch (it
//! consumes the reduced parameters) blocks on the previous epoch's final
//! reduction via `charge_after`, and the run's span includes the final
//! drain. The reduction *arithmetic* is unaffected — both schedules call
//! the identical numerics, so reduced gradients are bit-identical; only
//! the virtual timeline changes. `overlap: false` reproduces the strictly
//! sequential per-minibatch barrier schedule.

use anyhow::Result;

use super::compute::{Compute, WorkerState};
use crate::comm::ReduceStrategy;
use crate::config::BenchInfo;
use crate::engine::{ElasticConfig, ElasticController, Engine, OpCharge};
use crate::fabric::Fabric;
use crate::mapping::Layout;
use crate::metrics::{RewardTracker, RunMetrics};
use crate::vtime::{Clock, CostModel, OpKind};

/// Sync-training run configuration.
#[derive(Debug, Clone)]
pub struct SyncConfig {
    pub iterations: usize,
    pub ppo_epochs: usize,
    /// PPO minibatches per epoch: each is a separate gradient + LGR
    /// reduction (Isaac PPO runs epochs x minibatches collective ops per
    /// iteration — the traffic pattern Table 7 measures).
    pub minibatches: usize,
    pub lr: f32,
    pub seed: i32,
    /// How many GMIs execute *real* numerics; the rest mirror replica 0's
    /// results (data-parallel replicas are statistically identical; the
    /// virtual timing is charged for every GMI regardless).
    pub real_replicas: usize,
    /// Force a reduction strategy (`--reduce`; None = the fabric planner's
    /// cheapest valid plan).
    pub strategy_override: Option<ReduceStrategy>,
    /// Elastic mid-run re-provisioning: between iterations, shift SM share
    /// toward the bottleneck role group (None = static provisioning).
    pub elastic: Option<ElasticConfig>,
    /// Overlap gradient reductions with trainer compute and the next
    /// rollout (paper §4.2 pipelined transfers); `false` reproduces the
    /// strictly sequential per-minibatch barrier schedule.
    pub overlap: bool,
}

impl Default for SyncConfig {
    fn default() -> Self {
        SyncConfig {
            iterations: 10,
            ppo_epochs: super::DEFAULT_PPO_EPOCHS,
            minibatches: super::DEFAULT_MINIBATCHES,
            lr: super::DEFAULT_LR,
            seed: 1,
            real_replicas: 1,
            strategy_override: None,
            elastic: None,
            overlap: true,
        }
    }
}

/// Result of a sync-training run.
pub struct SyncRunResult {
    pub metrics: RunMetrics,
    pub strategy: ReduceStrategy,
    /// Final parameters of GMI 0 (for checkpoint-style consumers).
    pub final_params: Vec<f32>,
    pub stats_per_iter: Vec<super::TrainStats>,
    /// Elastic re-provisioning adjustments applied (0 when disabled).
    pub elastic_shifts: usize,
}

pub fn run_sync(
    layout: &Layout,
    bench: &BenchInfo,
    cost: &CostModel,
    compute: &Compute,
    cfg: &SyncConfig,
) -> Result<SyncRunResult> {
    let n_roll = layout.rollout_gmis.len();
    let n_train = layout.trainer_gmis.len();
    anyhow::ensure!(n_roll > 0 && n_train > 0, "layout has no rollout/trainer GMIs");
    let colocated = layout.rollout_gmis == layout.trainer_gmis;

    // LGR over the trainer GMIs: the run's one fabric both plans the
    // reduction (cheapest valid plan unless pinned via `--reduce`) and
    // executes it, so every plan's link ids refer to the fabric that
    // drains it. All transfer timing below runs through fabric plans
    // executed as engine events.
    let mpl = layout.manager.mapping_list(|r| r.has_trainer());
    let mut fabric = Fabric::single_node(layout.manager.topology().clone());
    let (strategy, reduce_plan) = match cfg.strategy_override {
        Some(s) => (s, fabric.plan_allreduce(&mpl, bench.param_bytes(), s)?),
        None => fabric.cheapest_allreduce(&mpl, bench.param_bytes()),
    };

    // The execution engine: one executor per role task. Colocated layouts
    // (TCG_EX holistic GMIs) alias rollout and trainer onto one timeline.
    let mut engine = Engine::new(&layout.manager, cost);
    let roll_ids = engine.add_group(&layout.rollout_gmis)?;
    let tr_ids = engine.add_group(&layout.trainer_gmis)?;
    let mut elastic = cfg.elastic.clone().map(ElasticController::new);
    // Completion of the last issued overlapped reduction: the next
    // parameter consumer blocks on it (None until the first reduction).
    let mut params_ready: Option<Clock> = None;

    // Worker state per rollout GMI (params/adam/env); trainers in TDG_EX
    // share the leader worker state of their GPU's serving GMIs.
    let real_n = cfg.real_replicas.min(n_roll).max(1);
    let mut workers: Vec<WorkerState> = Vec::with_capacity(n_roll);
    for (i, _) in layout.rollout_gmis.iter().enumerate() {
        if i < real_n {
            workers.push(compute.init(bench, cfg.seed)?);
        } else {
            workers.push(workers[0].clone());
        }
    }

    let mut rewards = RewardTracker::default();
    let mut stats_per_iter = Vec::new();
    let mut peak_mem: f64 = 0.0;

    let m = bench.horizon;
    let exp_bytes_per_gmi =
        layout.num_env_per_gmi * m * bench.experience_bytes_per_step();

    for iter in 0..cfg.iterations {
        // ---- (i) experience collection on every rollout GMI ----
        let mut rollouts: Vec<super::RolloutOut> = Vec::with_capacity(n_roll);
        for i in 0..n_roll {
            let n_env = engine.num_env(roll_ids[i]);
            engine.charge_steps(cost, roll_ids[i], m as f64, &super::rollout_charges(n_env), 0.0);
            peak_mem = peak_mem.max(cost.mem_gib(n_env, m, true, colocated));

            let ro = if i < real_n {
                compute.rollout(bench, &mut workers[i], cfg.seed + (iter * 131 + i) as i32)?
            } else {
                // mirror replica 0's experience (identical distribution)
                rollouts[0].clone()
            };
            rollouts.push(ro);
        }

        // TDG_EX: ship experience from serving GMIs to their GPU's trainer
        // and later ship parameters back (the Table 5 COM term). The gather
        // is a fabric plan: the k feeders contend and serialize on the
        // trainer GPU's host path.
        if !colocated {
            for (t_idx, _) in layout.trainer_gmis.iter().enumerate() {
                let tgpu = engine.gpu(tr_ids[t_idx]);
                // serving GMIs on the same GPU feed this trainer.
                let feeders: Vec<usize> = roll_ids
                    .iter()
                    .copied()
                    .filter(|&e| engine.gpu(e) == tgpu)
                    .collect();
                let k = feeders.len().max(1);
                let gather = fabric.plan_gather(k, exp_bytes_per_gmi, tgpu);
                // trainer waits for the slowest feeder, then the transfer.
                let feed_max = engine.max_time(&feeders);
                engine.recv_plan(&mut fabric, tr_ids[t_idx], feed_max, &gather);
            }
        }

        // ---- (ii) PPO epochs of minibatch updates ----
        // Virtual time: every (epoch, minibatch) is a gradient over
        // samples/minibatches plus one LGR reduction plus an Adam apply —
        // the collective traffic pattern Table 7 measures. Real numerics:
        // the grad artifact operates on the full batch, so the real
        // gradient/reduction/update runs once per epoch (the minibatch
        // partitioning changes traffic, not the per-epoch math).
        let mut iter_stats = super::TrainStats::default();
        let mb = cfg.minibatches.max(1);
        for _epoch in 0..cfg.ppo_epochs {
            // Real gradients, once per epoch. Only the real replicas are
            // materialized; the reduced gradient is their mean with
            // replica 0 weighted by the mirror count (mirrors hold exact
            // copies of replica 0's gradient, so this equals the full
            // n_train-way mean without n_train vector clones — §Perf L3
            // iteration 2).
            let mut real_grads: Vec<Vec<f32>> = Vec::with_capacity(real_n);
            for widx in 0..real_n.min(n_train) {
                let (g, st) = compute.grad(bench, &workers[widx], &rollouts[widx])?;
                if widx == 0 {
                    iter_stats = st;
                }
                real_grads.push(g);
            }
            let reduced = if real_grads.len() == 1 || n_train == 1 {
                real_grads.swap_remove(0)
            } else {
                let k = real_grads.len();
                let w0 = (n_train - k + 1) as f32;
                let mut acc = real_grads.swap_remove(0);
                for v in acc.iter_mut() {
                    *v *= w0;
                }
                for g in &real_grads {
                    for (a, v) in acc.iter_mut().zip(g.iter()) {
                        *a += v;
                    }
                }
                let inv = 1.0 / n_train as f32;
                for v in acc.iter_mut() {
                    *v *= inv;
                }
                acc
            };

            // virtual minibatch loop: grad/apply on the compute stream, one
            // LGR reduction per minibatch on the fabric. Sequential mode
            // blocks every trainer on every reduction (the PR 1 schedule);
            // overlap mode lets reduction k drain while minibatch k+1
            // computes, re-synchronizing at the next epoch's first gradient
            // (the point that consumes the reduced parameters).
            for mb_i in 0..mb {
                for t_idx in 0..n_train {
                    let total_samples = if colocated {
                        layout.num_env_per_gmi * m
                    } else {
                        layout.num_env_per_gmi * m * (n_roll / n_train).max(1)
                    };
                    let samples = (total_samples / mb).max(1);
                    let ops = [
                        OpCharge::recorded(OpKind::TrainGrad { samples }),
                        OpCharge::recorded(OpKind::AdamApply),
                    ];
                    match (mb_i, params_ready) {
                        // First gradient after an overlapped reduction:
                        // block on the reduced parameters landing.
                        (0, Some(ready)) => {
                            engine.charge_after(cost, tr_ids[t_idx], ready, &ops);
                        }
                        _ => {
                            engine.charge_steps(cost, tr_ids[t_idx], 1.0, &ops, 0.0);
                        }
                    }
                }
                if reduce_plan.is_empty() {
                    continue;
                }
                if cfg.overlap {
                    params_ready =
                        Some(engine.collective_overlapped(&mut fabric, &tr_ids, &reduce_plan));
                } else {
                    engine.collective(&mut fabric, &tr_ids, &reduce_plan);
                }
            }

            // real update, once per epoch
            for w in workers.iter_mut().take(real_n) {
                compute.apply(bench, w, &reduced, cfg.lr)?;
            }
            for i in real_n..n_roll {
                workers[i] = workers[0].clone();
            }
        }

        // TDG_EX: parameters flow back to the serving GMIs once the last
        // reduction has drained.
        if !colocated {
            let roll_gpus: Vec<usize> = {
                let mut g: Vec<usize> = roll_ids.iter().map(|&r| engine.gpu(r)).collect();
                g.sort_unstable();
                g.dedup();
                g
            };
            let fan = fabric.plan_fanout(
                bench.param_bytes(),
                n_roll / n_train.max(1),
                &roll_gpus,
            );
            let mut from = engine.max_time(&tr_ids);
            if let Some(ready) = params_ready {
                from = Clock(from.seconds().max(ready.seconds()));
            }
            engine.broadcast_plan(&mut fabric, &roll_ids, from, &fan);
        }

        let mean_r = rollouts.iter().map(|r| r.mean_reward as f64).sum::<f64>()
            / rollouts.len() as f64;
        rewards.push(engine.max_time(&roll_ids).seconds(), mean_r);
        stats_per_iter.push(iter_stats);

        // ---- (iii) elastic re-provisioning between iterations ----
        if let Some(ctl) = elastic.as_mut() {
            ctl.rebalance(&mut engine, &roll_ids, &tr_ids);
        }
    }

    // The final overlapped reduction drains past the last compute charge:
    // the run isn't over until its parameters landed.
    if let Some(ready) = params_ready {
        engine.wait_group(&tr_ids, ready);
    }

    // ---- metrics ----
    let span = engine.span();
    let total_env_steps = (cfg.iterations * m) as f64
        * layout.rollout_gmis.len() as f64
        * layout.num_env_per_gmi as f64;
    let total_samples = total_env_steps * cfg.ppo_epochs as f64;
    let metrics = RunMetrics {
        steps_per_sec: total_env_steps / span,
        pps: total_env_steps / span,
        ttop: total_samples / span,
        span_s: span,
        utilization: engine.mean_utilization(),
        final_reward: rewards.final_reward(),
        reward_curve: rewards.curve.clone(),
        comm_s: engine.comm_s(),
        peak_mem_gib: peak_mem,
        links: fabric.link_report(),
        latency: None,
    };
    Ok(SyncRunResult {
        metrics,
        strategy,
        final_params: workers.into_iter().next().map(|w| w.params).unwrap_or_default(),
        stats_per_iter,
        elastic_shifts: elastic.map(|c| c.shifts()).unwrap_or(0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::config::static_registry;
    use crate::gmi::{GmiBackend, GmiManager, GmiSpec, Role};
    use crate::mapping::{build_sync_layout, MappingTemplate};

    fn setup(gpus: usize, t: usize) -> (Layout, BenchInfo, CostModel) {
        let b = static_registry()["AT"].clone();
        let cost = CostModel::new(&b);
        let topo = Topology::dgx_a100(gpus);
        let layout =
            build_sync_layout(&topo, MappingTemplate::TaskColocated, t, 1024, &cost, None)
                .unwrap();
        (layout, b, cost)
    }

    #[test]
    fn runs_and_reports() {
        let (layout, b, cost) = setup(2, 2);
        let r = run_sync(&layout, &b, &cost, &Compute::Null, &SyncConfig::default()).unwrap();
        assert!(r.metrics.steps_per_sec > 0.0);
        assert!(r.metrics.span_s > 0.0);
        assert!(r.metrics.utilization > 0.0 && r.metrics.utilization <= 1.0);
        assert_eq!(r.metrics.reward_curve.len(), 10);
        // 2 GPUs x 2 GMIs -> MRR: the planner's cheapest plan (rings over
        // NVSwitch), agreeing with Algorithm 1 here.
        assert_eq!(r.strategy, ReduceStrategy::MultiRing);
        // static provisioning by default
        assert_eq!(r.elastic_shifts, 0);
        // fabric traffic surfaced
        assert!(!r.metrics.links.is_empty());
    }

    #[test]
    fn planner_drives_strategy() {
        // t > g: MRR is invalid; the cheapest valid plan is hierarchical —
        // the same verdict Algorithm 1 reaches.
        let (layout, b, cost) = setup(2, 3);
        let r = run_sync(&layout, &b, &cost, &Compute::Null, &SyncConfig::default()).unwrap();
        assert_eq!(r.strategy, ReduceStrategy::Hierarchical);
    }

    #[test]
    fn har_beats_mpr_in_throughput() {
        // Table 7's claim at the run level.
        let (layout, b, cost) = setup(4, 4);
        let mut cfg = SyncConfig { iterations: 5, ..Default::default() };
        cfg.strategy_override = Some(ReduceStrategy::MultiProcess);
        let mpr = run_sync(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        cfg.strategy_override = Some(ReduceStrategy::Hierarchical);
        let har = run_sync(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        assert!(
            har.metrics.steps_per_sec > mpr.metrics.steps_per_sec,
            "HAR {} vs MPR {}",
            har.metrics.steps_per_sec,
            mpr.metrics.steps_per_sec
        );
    }

    #[test]
    fn tcg_ex_beats_tdg_ex() {
        let b = static_registry()["AT"].clone();
        let cost = CostModel::new(&b);
        let topo = Topology::dgx_a100(2);
        let cfg = SyncConfig { iterations: 5, ..Default::default() };
        let tcg =
            build_sync_layout(&topo, MappingTemplate::TaskColocated, 3, 1024, &cost, None)
                .unwrap();
        let tdg =
            build_sync_layout(&topo, MappingTemplate::TaskDedicated, 3, 1024, &cost, None)
                .unwrap();
        let r_tcg = run_sync(&tcg, &b, &cost, &Compute::Null, &cfg).unwrap();
        let r_tdg = run_sync(&tdg, &b, &cost, &Compute::Null, &cfg).unwrap();
        assert!(
            r_tcg.metrics.steps_per_sec > r_tdg.metrics.steps_per_sec,
            "TCG {} vs TDG {}",
            r_tcg.metrics.steps_per_sec,
            r_tdg.metrics.steps_per_sec
        );
    }

    #[test]
    fn deterministic() {
        let (layout, b, cost) = setup(2, 2);
        let cfg = SyncConfig { iterations: 3, ..Default::default() };
        let a = run_sync(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        let c = run_sync(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        assert_eq!(a.metrics.steps_per_sec, c.metrics.steps_per_sec);
        assert_eq!(a.final_params, c.final_params);
    }

    // Overlap-vs-sequential behavior (strict speedup, bit-identical
    // parameters, identical per-link traffic) is covered end-to-end by
    // the integration suite in `rust/tests/fabric_overlap.rs`.

    /// A deliberately imbalanced TDG_EX layout: starved rollout GMIs next
    /// to an over-provisioned trainer on every GPU.
    fn imbalanced_layout(topo: &Topology) -> Layout {
        let mut manager = GmiManager::new(topo.clone());
        let mut rollout = Vec::new();
        let mut trainers = Vec::new();
        let mut id = 0usize;
        for gpu in 0..topo.num_gpus() {
            for _ in 0..2 {
                manager
                    .add_gmi(GmiSpec {
                        id,
                        gpu,
                        sm_share: 0.15,
                        mem_gib: 6.0,
                        backend: GmiBackend::Mps,
                        role: Role::SimAgent,
                        num_env: 1024,
                    })
                    .unwrap();
                rollout.push(id);
                id += 1;
            }
            manager
                .add_gmi(GmiSpec {
                    id,
                    gpu,
                    sm_share: 0.7,
                    mem_gib: 10.0,
                    backend: GmiBackend::Mps,
                    role: Role::Trainer,
                    num_env: 0,
                })
                .unwrap();
            trainers.push(id);
            id += 1;
        }
        Layout {
            manager,
            rollout_gmis: rollout,
            trainer_gmis: trainers,
            gmi_per_gpu: 3,
            num_env_per_gmi: 1024,
            backend: GmiBackend::Mps,
        }
    }

    #[test]
    fn elastic_reprovisioning_beats_static_on_imbalanced_layout() {
        let b = static_registry()["AT"].clone();
        let cost = CostModel::new(&b);
        let topo = Topology::dgx_a100(2);
        let cfg_static = SyncConfig { iterations: 8, ..Default::default() };
        let cfg_elastic = SyncConfig {
            iterations: 8,
            elastic: Some(ElasticConfig::default()),
            ..Default::default()
        };
        let s =
            run_sync(&imbalanced_layout(&topo), &b, &cost, &Compute::Null, &cfg_static).unwrap();
        let e =
            run_sync(&imbalanced_layout(&topo), &b, &cost, &Compute::Null, &cfg_elastic).unwrap();
        assert!(e.elastic_shifts > 0, "controller never re-provisioned");
        assert!(
            e.metrics.steps_per_sec > s.metrics.steps_per_sec,
            "elastic {} vs static {}",
            e.metrics.steps_per_sec,
            s.metrics.steps_per_sec
        );
        // The caller's layout is a static description: elastic runs never
        // mutate it (the engine re-provisions its own live clone).
        let layout = imbalanced_layout(&topo);
        run_sync(&layout, &b, &cost, &Compute::Null, &cfg_elastic).unwrap();
        assert_eq!(layout.manager.gmi(0).unwrap().sm_share, 0.15);
    }

    #[test]
    fn elastic_is_noop_on_colocated_layouts() {
        let (layout, b, cost) = setup(2, 2);
        let cfg = SyncConfig {
            iterations: 3,
            elastic: Some(ElasticConfig::default()),
            ..Default::default()
        };
        let e = run_sync(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        let s = run_sync(&layout, &b, &cost, &Compute::Null, &SyncConfig {
            iterations: 3,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(e.elastic_shifts, 0);
        assert_eq!(e.metrics.steps_per_sec, s.metrics.steps_per_sec);
    }
}
