//! The numerics backend for DRL roles.
//!
//! `Real` drives the AOT artifacts through the PJRT executor — genuine
//! policy forward/backward, physics, Adam. `Null` fabricates deterministic
//! pseudo-values with the same shapes so layout/throughput benches run
//! fast and without artifacts (virtual-time results are identical; only
//! the numerics differ — see DESIGN.md §5).

use anyhow::Result;

use crate::config::BenchInfo;
use crate::runtime::{ArtifactKind, ExecHandle, HostTensor};

/// Output of one rollout segment (shapes per the rollout artifact).
#[derive(Debug, Clone)]
pub struct RolloutOut {
    pub obs: HostTensor,
    pub actions: HostTensor,
    pub logps: HostTensor,
    pub rewards: HostTensor,
    pub values: HostTensor,
    pub dones: HostTensor,
    pub last_state: HostTensor,
    pub last_value: HostTensor,
    pub mean_reward: f32,
}

/// Scalar statistics of one PPO gradient step.
#[derive(Debug, Clone, Copy, Default)]
pub struct TrainStats {
    pub loss: f32,
    pub pi_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
    pub approx_kl: f32,
    pub mean_reward: f32,
}

/// Mutable per-worker learning state.
#[derive(Debug, Clone)]
pub struct WorkerState {
    pub params: Vec<f32>,
    pub adam_m: Vec<f32>,
    pub adam_v: Vec<f32>,
    pub adam_step: i32,
    pub env_state: HostTensor,
}

/// The numerics backend.
#[derive(Clone)]
pub enum Compute {
    Real { handle: ExecHandle },
    Null,
}

impl Compute {
    pub fn is_real(&self) -> bool {
        matches!(self, Compute::Real { .. })
    }

    /// Initialize params + env state for one worker.
    pub fn init(&self, b: &BenchInfo, seed: i32) -> Result<WorkerState> {
        let p = b.num_params;
        match self {
            Compute::Real { handle } => {
                let out = handle.execute(&b.abbr, ArtifactKind::Init, vec![
                    HostTensor::scalar_i32(seed),
                ])?;
                Ok(WorkerState {
                    params: out[0].clone().into_f32()?,
                    adam_m: vec![0.0; p],
                    adam_v: vec![0.0; p],
                    adam_step: 0,
                    env_state: out[1].clone(),
                })
            }
            Compute::Null => Ok(WorkerState {
                params: pseudo_vec(p, seed as u64, 0.01),
                adam_m: vec![0.0; p],
                adam_v: vec![0.0; p],
                adam_step: 0,
                env_state: HostTensor::zeros_f32(&[b.num_env, b.obs_dim]),
            }),
        }
    }

    /// The deterministic pseudo reward a Null-compute rollout reports for
    /// `seed` — exposed so reward-only consumers (the async learning
    /// curve) can skip materializing the full synthetic tensors.
    pub fn null_mean_reward(seed: i32) -> f32 {
        0.05 + 0.001 * (seed % 97) as f32
    }

    /// One rollout segment of `b.horizon` steps over `b.num_env` envs.
    pub fn rollout(&self, b: &BenchInfo, w: &mut WorkerState, seed: i32) -> Result<RolloutOut> {
        match self {
            Compute::Real { handle } => {
                let out = handle.execute(&b.abbr, ArtifactKind::Rollout, vec![
                    HostTensor::f32(w.params.clone(), &[b.num_params]),
                    w.env_state.clone(),
                    HostTensor::scalar_i32(seed),
                ])?;
                let mut it = out.into_iter();
                let (obs, actions, logps, rewards, values, dones, last_state, last_value) = (
                    it.next().unwrap(),
                    it.next().unwrap(),
                    it.next().unwrap(),
                    it.next().unwrap(),
                    it.next().unwrap(),
                    it.next().unwrap(),
                    it.next().unwrap(),
                    it.next().unwrap(),
                );
                let r = rewards.as_f32()?;
                let mean_reward = r.iter().sum::<f32>() / r.len().max(1) as f32;
                w.env_state = last_state.clone();
                Ok(RolloutOut {
                    obs,
                    actions,
                    logps,
                    rewards,
                    values,
                    dones,
                    last_state,
                    last_value,
                    mean_reward,
                })
            }
            Compute::Null => {
                let (m, n, d, a) = (b.horizon, b.num_env, b.obs_dim, b.act_dim);
                let mk = |shape: &[usize], scale: f32| {
                    HostTensor::f32(
                        pseudo_vec(shape.iter().product(), seed as u64 ^ 0x9e37, scale),
                        shape,
                    )
                };
                Ok(RolloutOut {
                    obs: mk(&[m, n, d], 0.1),
                    actions: mk(&[m, n, a], 0.2),
                    logps: mk(&[m, n], -1.0),
                    rewards: mk(&[m, n], 0.05),
                    values: mk(&[m, n], 0.0),
                    dones: HostTensor::zeros_f32(&[m, n]),
                    last_state: mk(&[n, d], 0.1),
                    last_value: mk(&[n], 0.0),
                    mean_reward: Self::null_mean_reward(seed),
                })
            }
        }
    }

    /// PPO gradient over a rollout. Returns (flat gradient, stats).
    pub fn grad(
        &self,
        b: &BenchInfo,
        w: &WorkerState,
        ro: &RolloutOut,
    ) -> Result<(Vec<f32>, TrainStats)> {
        match self {
            Compute::Real { handle } => {
                let out = handle.execute(&b.abbr, ArtifactKind::Grad, vec![
                    HostTensor::f32(w.params.clone(), &[b.num_params]),
                    ro.obs.clone(),
                    ro.actions.clone(),
                    ro.logps.clone(),
                    ro.rewards.clone(),
                    ro.values.clone(),
                    ro.dones.clone(),
                    ro.last_value.clone(),
                ])?;
                let grads = out[0].clone().into_f32()?;
                let stats = TrainStats {
                    loss: out[1].scalar_value_f32()?,
                    pi_loss: out[2].scalar_value_f32()?,
                    v_loss: out[3].scalar_value_f32()?,
                    entropy: out[4].scalar_value_f32()?,
                    approx_kl: out[5].scalar_value_f32()?,
                    mean_reward: out[6].scalar_value_f32()?,
                };
                Ok((grads, stats))
            }
            Compute::Null => Ok((
                pseudo_vec(b.num_params, 0xabcd, 1e-3),
                TrainStats { loss: 1.0, mean_reward: ro.mean_reward, ..Default::default() },
            )),
        }
    }

    /// Adam update with an (allreduced) flat gradient.
    pub fn apply(
        &self,
        b: &BenchInfo,
        w: &mut WorkerState,
        grads: &[f32],
        lr: f32,
    ) -> Result<()> {
        match self {
            Compute::Real { handle } => {
                let p = b.num_params;
                let out = handle.execute(&b.abbr, ArtifactKind::Apply, vec![
                    HostTensor::f32(std::mem::take(&mut w.params), &[p]),
                    HostTensor::f32(std::mem::take(&mut w.adam_m), &[p]),
                    HostTensor::f32(std::mem::take(&mut w.adam_v), &[p]),
                    HostTensor::scalar_i32(w.adam_step),
                    HostTensor::f32(grads.to_vec(), &[p]),
                    HostTensor::scalar_f32(lr),
                ])?;
                let mut it = out.into_iter();
                w.params = it.next().unwrap().into_f32()?;
                w.adam_m = it.next().unwrap().into_f32()?;
                w.adam_v = it.next().unwrap().into_f32()?;
                w.adam_step = it.next().unwrap().scalar_value_i32()?;
                Ok(())
            }
            Compute::Null => {
                // SGD stand-in keeps params moving deterministically.
                for (p, g) in w.params.iter_mut().zip(grads.iter()) {
                    *p -= lr * g;
                }
                w.adam_step += 1;
                Ok(())
            }
        }
    }
}

/// Deterministic pseudo-random vector (SplitMix64) for Null mode.
pub fn pseudo_vec(n: usize, seed: u64, scale: f32) -> Vec<f32> {
    let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
    (0..n)
        .map(|_| {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^= z >> 31;
            // map to [-1, 1) then scale
            ((z >> 11) as f32 / (1u64 << 52) as f32 - 1.0) * scale
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::static_registry;

    #[test]
    fn null_compute_full_cycle() {
        let b = static_registry()["AT"].clone();
        let c = Compute::Null;
        let mut w = c.init(&b, 7).unwrap();
        assert_eq!(w.params.len(), b.num_params);
        let ro = c.rollout(&b, &mut w, 1).unwrap();
        assert_eq!(ro.obs.shape(), &[b.horizon as i64, b.num_env as i64, b.obs_dim as i64]);
        let (g, stats) = c.grad(&b, &w, &ro).unwrap();
        assert_eq!(g.len(), b.num_params);
        assert!(stats.loss.is_finite());
        let before = w.params.clone();
        c.apply(&b, &mut w, &g, 3e-4).unwrap();
        assert_ne!(before, w.params);
        assert_eq!(w.adam_step, 1);
    }

    #[test]
    fn pseudo_vec_deterministic_and_bounded() {
        let a = pseudo_vec(100, 42, 0.5);
        let b = pseudo_vec(100, 42, 0.5);
        let c = pseudo_vec(100, 43, 0.5);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.iter().all(|v| v.abs() <= 0.5));
    }
}
