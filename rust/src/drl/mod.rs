//! DRL roles and training orchestrators (paper §3, §5).
//!
//! * [`compute`] — the numerics backend: `Real` executes the AOT HLO
//!   artifacts via PJRT, `Null` produces deterministic synthetic values so
//!   throughput benches can run without artifacts.
//! * [`serving`] — DRL serving (experience collection only, Fig 7a).
//! * [`sync`] — synchronized PPO training over a GMI layout (Fig 7b/c),
//!   with layout-aware gradient reduction.
//! * [`a3c`] — asynchronized training with channel-based experience
//!   sharing (Fig 11 / Table 8).

pub mod a3c;
pub mod compute;
pub mod serving;
pub mod sync;

pub use compute::{Compute, RolloutOut, TrainStats};

use crate::engine::OpCharge;
use crate::vtime::OpKind;

/// The per-step experience-collection charge every rollout-capable GMI
/// pays: one physics step plus one policy forward, both recorded. Shared
/// by the sync trainer and the multi-tenant scheduler's training stepper
/// so their rollouts cannot drift apart.
pub fn rollout_charges(num_env: usize) -> [OpCharge; 2] {
    [
        OpCharge::recorded(OpKind::SimStep { num_env }),
        OpCharge::recorded(OpKind::PolicyFwd { num_env }),
    ]
}

/// PPO hyperparameters mirrored from python/compile/model.py (fixed into
/// the artifacts; listed here for reporting only).
pub const GAMMA: f64 = 0.99;
pub const DEFAULT_LR: f32 = 3e-4;
/// PPO optimization epochs per collected batch (Isaac Gym PPO default).
/// The calibrated `T_t ~= T_s/3` (§5.1) is the whole per-iteration training
/// phase across all epochs — the cost model's per-pass rate accounts for
/// this (see vtime::cost GEMM_UTIL_TRAIN).
pub const DEFAULT_PPO_EPOCHS: usize = 5;
/// PPO minibatches per epoch: each triggers one gradient reduction.
pub const DEFAULT_MINIBATCHES: usize = 4;
