//! Host-side tensor values crossing the coordinator <-> executor boundary.

use anyhow::{bail, Context, Result};

/// A host tensor: the only data type that crosses between coordinator tasks
/// and the PJRT executor thread. Scalars use an empty shape.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { data: Vec<f32>, shape: Vec<i64> },
    I32 { data: Vec<i32>, shape: Vec<i64> },
}

impl HostTensor {
    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { data: vec![v], shape: vec![] }
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32 { data: vec![v], shape: vec![] }
    }

    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::F32 { data, shape: shape.iter().map(|&d| d as i64).collect() }
    }

    pub fn zeros_f32(shape: &[usize]) -> Self {
        Self::f32(vec![0.0; shape.iter().product()], shape)
    }

    pub fn shape(&self) -> &[i64] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn size_bytes(&self) -> usize {
        4 * self.len()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn scalar_value_f32(&self) -> Result<f32> {
        self.as_f32()?.first().copied().context("empty tensor")
    }

    pub fn scalar_value_i32(&self) -> Result<i32> {
        match self {
            HostTensor::I32 { data, .. } => data.first().copied().context("empty tensor"),
            HostTensor::F32 { .. } => bail!("expected i32 tensor, got f32"),
        }
    }

    /// Convert to an xla literal (executor thread only).
    pub(crate) fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            HostTensor::F32 { data, shape } => {
                if shape.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    xla::Literal::vec1(data).reshape(shape)?
                }
            }
            HostTensor::I32 { data, shape } => {
                if shape.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    xla::Literal::vec1(data).reshape(shape)?
                }
            }
        };
        Ok(lit)
    }

    /// Convert back from an xla literal (executor thread only).
    pub(crate) fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims = shape.dims().to_vec();
        match shape.element_type() {
            xla::ElementType::F32 => Ok(HostTensor::F32 { data: lit.to_vec::<f32>()?, shape: dims }),
            xla::ElementType::S32 => Ok(HostTensor::I32 { data: lit.to_vec::<i32>()?, shape: dims }),
            other => bail!("unsupported artifact output element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_sizes() {
        let t = HostTensor::f32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.size_bytes(), 24);
        assert!(!t.is_empty());
    }

    #[test]
    fn scalar_roundtrip() {
        assert_eq!(HostTensor::scalar_i32(7).scalar_value_i32().unwrap(), 7);
        assert_eq!(HostTensor::scalar_f32(0.5).scalar_value_f32().unwrap(), 0.5);
        assert!(HostTensor::scalar_f32(1.0).scalar_value_i32().is_err());
    }

    #[test]
    fn zeros_builder() {
        let z = HostTensor::zeros_f32(&[4, 5]);
        assert_eq!(z.len(), 20);
        assert!(z.as_f32().unwrap().iter().all(|&v| v == 0.0));
    }
}
