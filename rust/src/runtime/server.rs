//! The PJRT executor thread: owns the (non-`Send`) client and compiled
//! executables, serves execute requests from coordinator tasks.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::{ArtifactKind, HostTensor};
use crate::config::Manifest;

/// Aggregate executor statistics (for the perf pass / EXPERIMENTS.md §Perf).
#[derive(Debug, Default)]
pub struct ExecStats {
    pub executions: AtomicU64,
    pub compile_ns: AtomicU64,
    pub execute_ns: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
}

impl ExecStats {
    /// (executions, compile_s, execute_s, bytes_in, bytes_out)
    pub fn snapshot(&self) -> (u64, f64, f64, u64, u64) {
        (
            self.executions.load(Ordering::Relaxed),
            self.compile_ns.load(Ordering::Relaxed) as f64 / 1e9,
            self.execute_ns.load(Ordering::Relaxed) as f64 / 1e9,
            self.bytes_in.load(Ordering::Relaxed),
            self.bytes_out.load(Ordering::Relaxed),
        )
    }
}

enum Request {
    Execute {
        bench: String,
        kind: ArtifactKind,
        inputs: Vec<HostTensor>,
        reply: SyncSender<Result<Vec<HostTensor>>>,
    },
    Preload {
        bench: String,
        kind: ArtifactKind,
        reply: SyncSender<Result<()>>,
    },
    Shutdown,
}

/// Cloneable handle used by coordinator tasks; all methods are synchronous
/// RPCs to the executor thread.
#[derive(Clone)]
pub struct ExecHandle {
    tx: Sender<Request>,
    stats: Arc<ExecStats>,
}

// The handle only holds an mpsc Sender + Arc; safe to share across the
// coordinator's worker threads.
unsafe impl Sync for ExecHandle {}

impl ExecHandle {
    /// Run one artifact. The returned tensors are the flattened tuple
    /// elements of the jax function's output.
    pub fn execute(
        &self,
        bench: &str,
        kind: ArtifactKind,
        inputs: Vec<HostTensor>,
    ) -> Result<Vec<HostTensor>> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Request::Execute { bench: bench.to_string(), kind, inputs, reply })
            .map_err(|_| anyhow!("executor thread gone"))?;
        rx.recv().map_err(|_| anyhow!("executor thread dropped reply"))?
    }

    /// Compile an artifact ahead of time (otherwise compiled on first use).
    pub fn preload(&self, bench: &str, kind: ArtifactKind) -> Result<()> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Request::Preload { bench: bench.to_string(), kind, reply })
            .map_err(|_| anyhow!("executor thread gone"))?;
        rx.recv().map_err(|_| anyhow!("executor thread dropped reply"))?
    }

    pub fn stats(&self) -> &ExecStats {
        &self.stats
    }
}

/// Spawns the executor thread; dropping the server shuts it down.
pub struct ExecServer {
    handle: ExecHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ExecServer {
    /// `artifacts_dir` must contain `manifest.txt` (from `make artifacts`).
    pub fn start(artifacts_dir: PathBuf) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let (tx, rx) = channel::<Request>();
        let stats = Arc::new(ExecStats::default());
        let worker_stats = stats.clone();
        let join = std::thread::Builder::new()
            .name("pjrt-exec".into())
            .spawn(move || worker(artifacts_dir, manifest, rx, worker_stats))
            .context("spawning executor thread")?;
        Ok(ExecServer { handle: ExecHandle { tx, stats }, join: Some(join) })
    }

    /// Start against the default artifacts dir (honours GMI_DRL_ARTIFACTS).
    pub fn start_default() -> Result<Self> {
        Self::start(crate::config::artifacts_dir())
    }

    pub fn handle(&self) -> ExecHandle {
        self.handle.clone()
    }
}

impl Drop for ExecServer {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn worker(
    dir: PathBuf,
    manifest: Manifest,
    rx: Receiver<Request>,
    stats: Arc<ExecStats>,
) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            log::error!("PJRT CPU client failed: {e}");
            // Drain requests with errors so callers unblock.
            for req in rx.iter() {
                match req {
                    Request::Execute { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("PJRT client unavailable")));
                    }
                    Request::Preload { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("PJRT client unavailable")));
                    }
                    Request::Shutdown => break,
                }
            }
            return;
        }
    };
    let mut cache: HashMap<(String, ArtifactKind), xla::PjRtLoadedExecutable> = HashMap::new();

    for req in rx.iter() {
        match req {
            Request::Shutdown => break,
            Request::Preload { bench, kind, reply } => {
                let r =
                    ensure_compiled(&client, &dir, &manifest, &mut cache, &bench, kind, &stats)
                        .map(|_| ());
                let _ = reply.send(r);
            }
            Request::Execute { bench, kind, inputs, reply } => {
                let r = (|| -> Result<Vec<HostTensor>> {
                    ensure_compiled(&client, &dir, &manifest, &mut cache, &bench, kind, &stats)?;
                    let exe = cache.get(&(bench.clone(), kind)).unwrap();
                    for t in &inputs {
                        stats.bytes_in.fetch_add(t.size_bytes() as u64, Ordering::Relaxed);
                    }
                    let lits: Vec<xla::Literal> =
                        inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
                    let t0 = Instant::now();
                    let bufs = exe
                        .execute::<xla::Literal>(&lits)
                        .map_err(|e| anyhow!("execute {bench}/{kind}: {e}"))?;
                    let result = bufs[0][0]
                        .to_literal_sync()
                        .map_err(|e| anyhow!("to_literal {bench}/{kind}: {e}"))?;
                    stats
                        .execute_ns
                        .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    stats.executions.fetch_add(1, Ordering::Relaxed);
                    // aot.py lowers with return_tuple=True: always a tuple.
                    let parts = result.to_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
                    let outs: Vec<HostTensor> =
                        parts.iter().map(HostTensor::from_literal).collect::<Result<_>>()?;
                    for t in &outs {
                        stats.bytes_out.fetch_add(t.size_bytes() as u64, Ordering::Relaxed);
                    }
                    Ok(outs)
                })();
                let _ = reply.send(r);
            }
        }
    }
}

fn ensure_compiled(
    client: &xla::PjRtClient,
    dir: &Path,
    manifest: &Manifest,
    cache: &mut HashMap<(String, ArtifactKind), xla::PjRtLoadedExecutable>,
    bench: &str,
    kind: ArtifactKind,
    stats: &ExecStats,
) -> Result<()> {
    let key = (bench.to_string(), kind);
    if cache.contains_key(&key) {
        return Ok(());
    }
    let path = manifest.hlo_path(dir, bench, kind.as_str())?;
    let t0 = Instant::now();
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("non-utf8 artifact path")?,
    )
    .map_err(|e| anyhow!("parsing HLO text {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client
        .compile(&comp)
        .map_err(|e| anyhow!("compiling {bench}/{kind}: {e}"))?;
    stats
        .compile_ns
        .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    log::info!(
        "compiled {bench}/{kind} from {} in {:.2}s",
        path.display(),
        t0.elapsed().as_secs_f64()
    );
    cache.insert(key, exe);
    Ok(())
}
