//! PJRT runtime: load AOT-lowered HLO-text artifacts, compile them on the
//! CPU PJRT client, and execute them from the coordinator hot path.
//!
//! The `xla` crate's client/executable types wrap raw pointers and are not
//! `Send`, so all PJRT state is confined to a dedicated executor thread
//! ([`ExecServer`]); coordinator tasks talk to it through a cloneable
//! [`ExecHandle`] over crossbeam channels. One compiled executable per
//! (benchmark, artifact) pair, compiled lazily and cached.

mod server;
mod tensor;

pub use server::{ExecHandle, ExecServer, ExecStats};
pub use tensor::HostTensor;

/// The four artifacts each benchmark lowers to (see python/compile/aot.py).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// `(seed) -> (params_flat, state0)`
    Init,
    /// `(params_flat, state, seed) -> (obs, actions, logps, rewards, values,
    /// dones, last_state, last_value)`
    Rollout,
    /// `(params_flat, obs, actions, logps_old, rewards, values_old, dones,
    /// last_value) -> (grads_flat, loss, pi_loss, v_loss, entropy, kl,
    /// mean_reward)`
    Grad,
    /// `(params_flat, m, v, step, grads_flat, lr) -> (params', m', v', step')`
    Apply,
}

impl ArtifactKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ArtifactKind::Init => "init",
            ArtifactKind::Rollout => "rollout",
            ArtifactKind::Grad => "grad",
            ArtifactKind::Apply => "apply",
        }
    }
}

impl std::fmt::Display for ArtifactKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}
