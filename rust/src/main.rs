//! GMI-DRL launcher: the leader entrypoint.
//!
//! Subcommands:
//!   info                         show manifest + benchmark registry
//!   serve       [opts]           DRL serving on a GMI layout
//!   train-sync  [opts]           synchronized PPO training (LGR)
//!   train-async [opts]           asynchronized A3C training (channels)
//!   search      [opts]           Algorithm 2 configuration search
//!
//! Common options:
//!   --bench AT --gpus 4 --gmi-per-gpu 3 --num-env 1024 --rounds 20
//!   --real                       execute real numerics via PJRT artifacts
//!   --template tcg|tdg           mapping template (default tcg)
//!   --strategy mpr|mrr|har       force a reduction strategy
//!   --backend mps|mig|direct     force a GMI backend
//!   --mode ucc|mcc               experience sharing mode (async)

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use gmi_drl::baselines;
use gmi_drl::cluster::Topology;
use gmi_drl::comm::ReduceStrategy;
use gmi_drl::config::{artifacts_dir, static_registry, Manifest};
use gmi_drl::channels::ShareMode;
use gmi_drl::drl::a3c::{run_async, AsyncConfig};
use gmi_drl::drl::serving::{run_serving, ServingConfig};
use gmi_drl::drl::sync::{run_sync, SyncConfig};
use gmi_drl::drl::Compute;
use gmi_drl::fault::{FaultPlan, FaultTrace};
use gmi_drl::gmi::GmiBackend;
use gmi_drl::mapping::{
    build_async_layout, build_gateway_fleet, build_serving_layout, build_sync_layout,
    MappingTemplate,
};
use gmi_drl::metrics::{fmt_rate, latency_table, Table};
use gmi_drl::runtime::ExecServer;
use gmi_drl::sched::{
    corun_scenario, offpolicy_corun_scenario, run_cluster, sched_table, week_scenario, FastForward,
    SchedConfig, WeekOpts,
};
use gmi_drl::selection;
use gmi_drl::serve::{
    generate_trace, run_gateway_source, scale_table, AutoscaleConfig, GatewayConfig, TraceSource,
    TrafficPattern,
};
use gmi_drl::tune::{self, TuneConfig};
use gmi_drl::vtime::CostModel;
use gmi_drl::workload::league::run_league;
use gmi_drl::workload::replay::run_replay;
use gmi_drl::workload::{Eviction, LeagueConfig, ReplayConfig};

/// Minimal `--key value` / `--flag` parser (offline build: no clap).
struct Args {
    cmd: String,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    fn parse() -> Result<Self> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        let mut kv = BTreeMap::new();
        let mut flags = Vec::new();
        let rest: Vec<String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let a = &rest[i];
            let Some(name) = a.strip_prefix("--") else {
                bail!("unexpected argument {a}");
            };
            if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                kv.insert(name.to_string(), rest[i + 1].clone());
                i += 2;
            } else {
                flags.push(name.to_string());
                i += 1;
            }
        }
        Ok(Args { cmd, kv, flags })
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.kv.get(key) {
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("bad value for --{key}: {v}")),
            None => Ok(default),
        }
    }

    fn str(&self, key: &str, default: &str) -> String {
        self.kv.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

fn bench_info(abbr: &str, real: bool) -> Result<gmi_drl::BenchInfo> {
    if real {
        let m = Manifest::load(&artifacts_dir())?;
        Ok(m.bench(abbr)?.clone())
    } else {
        static_registry()
            .get(abbr)
            .cloned()
            .with_context(|| format!("unknown benchmark {abbr}"))
    }
}

fn compute(real: bool) -> Result<(Compute, Option<ExecServer>)> {
    if real {
        let server = ExecServer::start(artifacts_dir())?;
        Ok((Compute::Real { handle: server.handle() }, Some(server)))
    } else {
        Ok((Compute::Null, None))
    }
}

fn parse_strategy(s: &str) -> Result<Option<ReduceStrategy>> {
    Ok(match s {
        "" | "auto" => None,
        "mpr" => Some(ReduceStrategy::MultiProcess),
        "mrr" => Some(ReduceStrategy::MultiRing),
        "har" => Some(ReduceStrategy::Hierarchical),
        other => bail!("unknown strategy {other}"),
    })
}

fn parse_backend(s: &str) -> Result<Option<GmiBackend>> {
    Ok(match s {
        "" | "auto" => None,
        "mps" => Some(GmiBackend::Mps),
        "mig" => Some(GmiBackend::Mig),
        "direct" => Some(GmiBackend::DirectShare),
        other => bail!("unknown backend {other}"),
    })
}

fn parse_template(s: &str) -> Result<MappingTemplate> {
    Ok(match s {
        "" | "tcg" => MappingTemplate::TaskColocated,
        "tdg" => MappingTemplate::TaskDedicated,
        other => bail!("unknown template {other}"),
    })
}

fn main() -> Result<()> {
    let args = Args::parse()?;
    match args.cmd.as_str() {
        "info" => cmd_info(),
        "serve" => cmd_serve(&args),
        "train-sync" => cmd_train_sync(&args),
        "train-async" => cmd_train_async(&args),
        "train-replay" => cmd_train_replay(&args),
        "league" => cmd_league(&args),
        "multi" => cmd_multi(&args),
        "search" => cmd_search(&args),
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other}; try `gmi-drl help`"),
    }
}

const HELP: &str = "\
gmi-drl — GPU spatial multiplexing for multi-GPU DRL (paper reproduction)

USAGE: gmi-drl <COMMAND> [--key value] [--flag]

COMMANDS:
  info         show the artifact manifest and benchmark registry
  serve        DRL serving: closed-loop throughput by default, or the
               open-loop SLO gateway with --trace <pattern>
  train-sync   synchronized PPO training with layout-aware gradient reduction
  train-async  asynchronized A3C training with channel-based experience sharing
  train-replay off-policy training: collectors stream transitions into a
               memory-budgeted replay buffer; a learner samples at its own rate
  league       self-play league: a coordinator spawns match jobs as cluster
               tenants through the scheduler's admission path
  multi        multi-tenant co-run: training + a diurnal SLO serving fleet
               preemptively co-scheduled on one shared cluster
  search       workload-aware GMI selection (Algorithm 2)

COMMON OPTIONS:
  --bench AT|AY|BB|FC|HM|SH   benchmark (default AT)
  --gpus N                    GPUs of the DGX-A100 to use (default 4)
  --gmi-per-gpu K             GMIs per GPU (default: from Algorithm 2)
  --num-env N                 environments per GMI (default: from Algorithm 2)
  --rounds / --iters N        run length (default 20)
  --real                      real numerics via PJRT (needs `make artifacts`)
  --template tcg|tdg          mapping template
  --reduce auto|mpr|mrr|har   gradient-reduction strategy: auto = the fabric
                              planner's cheapest valid plan (alias --strategy)
  --backend mps|mig|direct    force a GMI backend
  --mode mcc|ucc              async experience sharing mode
  --elastic                   re-provision SM shares toward the bottleneck
                              role between sync iterations / async rounds
  --no-overlap                disable compute/communication overlap (sync):
                              strictly sequential per-minibatch reductions
  --granularity BYTES         per-channel compressor staging threshold
                              (async; default 256 KiB)
  --staging-interval SECS     flush partially filled channel queues older
                              than SECS (async anti-starvation; default 1.0)
  --links                     print the per-link fabric traffic table
  --autotune                  lock the configuration with the online
                              auto-tuner: measured probe runs through the
                              real programs on a scratch engine (sync /
                              async training and the gateway). Explicitly
                              given --gmi-per-gpu / --num-env /
                              --minibatches / --reduce / --no-overlap /
                              --max-batch / --max-wait-ms pin their axes
  --tune-budget FRAC          probe budget as a fraction of the projected
                              run horizon (default 0.01)

OPEN-LOOP SERVING (serve --trace ...):
  --trace constant|poisson|diurnal|burst   arrival pattern (enables the
                              gateway; omit for the closed-loop model)
  --rate R                    base arrival rate, req/s (default 50000)
  --peak R                    peak rate for diurnal/burst (default 4x rate)
  --duration S                trace length in virtual seconds (default 2)
  --sources N                 client streams (default 8)
  --max-batch N               dynamic batching: batch size cap (default 32)
  --max-wait-ms MS            dynamic batching: wait deadline (default 2)
  --admission-cap N           reject past N outstanding requests (0 = off)
  --slo-ms MS                 p99 latency target (default 30)
  --autoscale                 grow/shrink the fleet against the SLO
  --window-ms MS              autoscaler evaluation window (default 50)
  --max-per-gpu K             fleet headroom per GPU (default 3x initial)
  --period S                  diurnal period (default duration/2)
  --stream                    lazy seeded arrival stream (O(1) memory,
                              bit-identical to the materialized trace)
  --aggregation K             coalesce K arrivals into one macro-request
                              (fabric hops + forward charged once per
                              macro; default 1 = off, bit-identical)
  --sample-cap N              seeded-reservoir latency windows capped at N
                              samples (0 = exact/unbounded, the default)

OFF-POLICY REPLAY (train-replay):
  --buffer-gib G              replay-buffer memory budget, charged against
                              the learner GMI's memory (default 1.0)
  --eviction fifo|reservoir   full-buffer eviction policy (default fifo)
  --push-samples N            transitions each collector streams per round
                              (default 4096)
  --batch-samples N           learner minibatch size (default 1024)
  --learner-batches N         learner sampling ticks per round (default 2)

SELF-PLAY LEAGUE (league):
  --players N                 league size, even (default 4)
  --matches N                 season length in matches (default 12)
  --max-concurrent N          match jobs in flight at once (default 2)
  --match-rounds N            interaction rounds per match (default 3)
  --match-num-env N           environments per match member (default 256)
  --match-share S             SM share per match member (default 0.25)
  --share S                   coordinator SM share (default 0.25)
  --quantum-ms MS             scheduling round length (default 20)

MULTI-TENANT CO-RUN (multi):
  --offpolicy                 co-run PPO training + a replay learner + a
                              self-play league (dynamic tenants) instead of
                              the training + serving day
  --duration S                length of the serving day (default 1.0)
  --quantum-ms MS             scheduling round length (default 20)
  --static                    static partitioning baseline: tenants pinned
                              to disjoint GPU halves, no preemption
  --seed N                    trace seed (default 7)
  --fault-trace FILE          inject hardware failures from a declarative
                              trace file: one event per line,
                              \"<t_s> fail|repair gpu <i>|node <i>|nvswitch|ib\"
                              (# comments allowed). Killed tenants are
                              re-admitted onto surviving capacity
  --checkpoint-interval S     periodic Workload snapshots every S virtual
                              seconds, cost charged to the tenant's own
                              executors; killed tenants resume from the
                              last checkpoint (default off)
  --gpus-per-node N           node granularity for \"node <i>\" fault
                              targets (default 2)
  --week                      week-scale co-run: training + a diurnal fleet
                              + a bursty gateway over seven day/night
                              swings (pass --duration 604800 for the full
                              week; accepts --aggregation / --sample-cap /
                              --materialize)
  --materialize               with --week: materialize traces up front
                              instead of streaming (the memory baseline)
  --fast-forward              skip provably-quiescent scheduler rounds
                              (timeline and metrics stay bit-identical)
  --audit-ff                  step would-be-skipped rounds naively and
                              error if one does observable work
  --max-rounds N              pin the runaway guard (0 = derive from the
                              jobs' horizon and quantum, the default)
";

fn cmd_info() -> Result<()> {
    let mut t = Table::new(&["Abbr", "Benchmark", "Type", "#Dim", "Policy NN", "Params"]);
    for (abbr, b) in static_registry() {
        let nn = std::iter::once(b.obs_dim.to_string())
            .chain(b.hidden.iter().map(|h| h.to_string()))
            .chain(std::iter::once(b.act_dim.to_string()))
            .collect::<Vec<_>>()
            .join(":");
        t.row(vec![
            abbr,
            b.name.clone(),
            b.kind.clone(),
            b.obs_dim.to_string(),
            nn,
            fmt_rate(b.num_params as f64),
        ]);
    }
    t.print();
    match Manifest::load(&artifacts_dir()) {
        Ok(m) => println!(
            "\nartifacts: {} benchmarks lowered at {}",
            m.benchmarks.len(),
            artifacts_dir().display()
        ),
        Err(_) => println!("\nartifacts: none (run `make artifacts`)"),
    }
    Ok(())
}

fn select_config(
    args: &Args,
    bench: &gmi_drl::BenchInfo,
    cost: &CostModel,
    gpus: usize,
) -> Result<(usize, usize)> {
    let mut gmi_per_gpu: usize = args.get("gmi-per-gpu", 0)?;
    let mut num_env: usize = args.get("num-env", 0)?;
    if gmi_per_gpu == 0 || num_env == 0 {
        let (sel, _) = selection::explore(bench, cost, GmiBackend::Mps, gpus, bench.horizon);
        let sel = sel.context("Algorithm 2 found no runnable configuration")?;
        if gmi_per_gpu == 0 {
            gmi_per_gpu = sel.gmi_per_gpu;
        }
        if num_env == 0 {
            num_env = sel.num_env;
        }
        println!("[Algorithm 2] GMIperGPU={gmi_per_gpu} num_env={num_env}");
    }
    Ok((gmi_per_gpu, num_env))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let trace = args.str("trace", "");
    if !trace.is_empty() {
        return cmd_serve_open(args, &trace);
    }
    let real = args.flag("real");
    let bench = bench_info(&args.str("bench", "AT"), real)?;
    let cost = CostModel::new(&bench);
    let gpus: usize = args.get("gpus", 4)?;
    let topo = Topology::dgx_a100(gpus);
    let (gmi_per_gpu, num_env) = select_config(args, &bench, &cost, gpus)?;
    let template = parse_template(&args.str("template", "tcg"))?;
    let backend = parse_backend(&args.str("backend", "auto"))?;
    let rounds: usize = args.get("rounds", 20)?;

    let layout = build_serving_layout(&topo, template, gmi_per_gpu, num_env, &cost, backend)?;
    let (comp, _server) = compute(real)?;
    let m = run_serving(&layout, &bench, &cost, &comp, &ServingConfig {
        rounds,
        seed: args.get("seed", 1)?,
        real_replicas: if real { 1 } else { 0 },
    })?;
    m.print_summary(&format!(
        "serve {} {}x{} GMIs ({})",
        bench.abbr, gpus, gmi_per_gpu, layout.backend_name()
    ));
    if args.flag("links") {
        m.print_links();
    }
    // baseline comparison
    let base = baselines::isaac_serving(&topo, &bench, &cost, &comp, num_env * gmi_per_gpu, rounds)?;
    base.print_summary("baseline (Isaac Gym, 1 proc/GPU)");
    println!("speedup: {:.2}x", m.steps_per_sec / base.steps_per_sec);
    Ok(())
}

/// Open-loop gateway serving: `serve --trace <pattern>` replays a seeded
/// arrival trace through the admission/batching gateway, optionally with
/// the SLO-aware autoscaler.
fn cmd_serve_open(args: &Args, pattern: &str) -> Result<()> {
    let bench = bench_info(&args.str("bench", "AT"), false)?;
    let cost = CostModel::new(&bench);
    let gpus: usize = args.get("gpus", 4)?;
    let topo = Topology::dgx_a100(gpus);

    let rate: f64 = args.get("rate", 50_000.0)?;
    let peak: f64 = args.get("peak", rate * 4.0)?;
    let duration: f64 = args.get("duration", 2.0)?;
    let seed: u64 = args.get("seed", 1)?;
    let sources: usize = args.get("sources", 8)?;
    let pat = match pattern {
        "constant" => TrafficPattern::Constant { rate },
        "poisson" => TrafficPattern::Poisson { rate },
        "diurnal" => TrafficPattern::Diurnal {
            base: rate,
            peak,
            period_s: args.get("period", duration / 2.0)?,
        },
        "burst" => TrafficPattern::Burst {
            base: rate,
            burst: peak,
            start_s: duration * 0.25,
            len_s: duration * 0.25,
        },
        other => bail!("unknown trace pattern {other} (constant|poisson|diurnal|burst)"),
    };
    // --stream keeps the arrival trace lazy (O(1) memory, bit-identical
    // request sequence) — the week-scale default for long durations.
    let source = if args.flag("stream") {
        TraceSource::streaming(&pat, duration, seed, sources)
    } else {
        TraceSource::from(generate_trace(&pat, duration, seed, sources))
    };

    let max_batch: usize = args.get("max-batch", 32)?;
    let initial: usize = args.get("gmi-per-gpu", 2)?;
    let max_per: usize = args.get("max-per-gpu", (initial * 3).min(8).max(initial))?;
    let autotune = args.flag("autotune");
    let mut space = tune::GatewaySpace::default();
    if args.kv.contains_key("max-batch") {
        space.max_batch = vec![max_batch];
    }
    if args.kv.contains_key("max-wait-ms") {
        space.max_wait_ms = vec![args.get("max-wait-ms", 2.0)?];
    }
    // Under --autotune the fleet is provisioned for the largest batch the
    // search may lock, so every candidate policy fits the layout.
    let fleet_batch = if autotune {
        space.max_batch.iter().copied().max().unwrap_or(max_batch).max(max_batch)
    } else {
        max_batch
    };
    let layout = build_gateway_fleet(
        &topo,
        initial,
        max_per,
        fleet_batch,
        &cost,
        parse_backend(&args.str("backend", "auto"))?,
    )?;

    let slo_ms: f64 = args.get("slo-ms", 30.0)?;
    let window_ms: f64 = args.get("window-ms", 50.0)?;
    let cap: usize = args.get("admission-cap", 0)?;
    let aggregation: usize = args.get("aggregation", 1)?;
    let sample_cap: usize = args.get("sample-cap", 0)?;
    let mut cfg = GatewayConfig {
        max_batch,
        max_wait_s: args.get("max-wait-ms", 2.0)? / 1e3,
        admission_cap: if cap > 0 { Some(cap) } else { None },
        slo_s: slo_ms / 1e3,
        autoscale: args.flag("autoscale").then(|| AutoscaleConfig {
            window_s: window_ms / 1e3,
            slo_p99_s: slo_ms / 1e3,
            min_fleet: layout.rollout_gmis.len().min(gpus),
            max_per_gpu: max_per,
            ..AutoscaleConfig::default()
        }),
        aggregation: aggregation.max(1),
        sample_cap: if sample_cap > 0 { Some(sample_cap) } else { None },
    };

    if autotune {
        let tcfg = TuneConfig {
            budget_frac: args.get("tune-budget", TuneConfig::default().budget_frac)?,
            ..TuneConfig::default()
        };
        let rep = tune::tune_gateway_source(&layout, &bench, &cost, &source, &cfg, &space, &tcfg)?;
        print_tune_summary(&rep.choice.label(), &rep);
        cfg = rep.choice.apply(&cfg);
    }

    let shown = source
        .len_hint()
        .map(|n| format!("{} requests", fmt_rate(n as f64)))
        .unwrap_or_else(|| "streamed requests".into());
    println!(
        "serve-gateway {} [{pattern}] {shown} over {duration:.2}s, fleet {}x{initial} GMIs\n",
        bench.abbr, gpus
    );
    let r = run_gateway_source(&layout, &bench, &cost, source, &cfg)?;
    r.metrics
        .print_summary(&format!("serve-gateway {} ({pattern})", bench.abbr));
    latency_table(&r.latency).print();
    if !r.scale_events.is_empty() {
        println!("\nscaling timeline:");
        scale_table(&r.scale_events).print();
    }
    if args.flag("links") {
        r.metrics.print_links();
    }
    Ok(())
}

fn print_tune_summary<C>(label: &str, rep: &tune::TuneReport<C>) {
    println!(
        "[autotune] locked {label} | {} probes / {} candidates ({} pruned free) | \
         probe cost {:.4}s of {:.4}s budget ({:.3}% of the {:.2}s projected run){}",
        rep.probes.len(),
        rep.candidates,
        rep.pruned,
        rep.probe_cost_s,
        rep.budget_s,
        100.0 * rep.probe_cost_s / rep.run_horizon_s.max(1e-12),
        rep.run_horizon_s,
        if rep.fallback { " [fallback: cost-model pick, no probe afforded]" } else { "" },
    );
}

fn cmd_train_sync(args: &Args) -> Result<()> {
    let real = args.flag("real");
    let bench = bench_info(&args.str("bench", "AT"), real)?;
    let cost = CostModel::new(&bench);
    let gpus: usize = args.get("gpus", 4)?;
    let topo = Topology::dgx_a100(gpus);
    let (mut gmi_per_gpu, mut num_env) = select_config(args, &bench, &cost, gpus)?;
    let template = parse_template(&args.str("template", "tcg"))?;
    let backend = parse_backend(&args.str("backend", "auto"))?;
    // `--reduce` is the canonical strategy override; `--strategy` stays as
    // an alias for older scripts.
    let reduce = args.str("reduce", &args.str("strategy", "auto"));
    let mut cfg = SyncConfig {
        iterations: args.get("iters", 20)?,
        ppo_epochs: args.get("ppo-epochs", gmi_drl::drl::DEFAULT_PPO_EPOCHS)?,
        minibatches: args.get("minibatches", gmi_drl::drl::DEFAULT_MINIBATCHES)?,
        lr: args.get("lr", 3e-4)?,
        seed: args.get("seed", 1)?,
        real_replicas: if real { 1 } else { 0 },
        strategy_override: parse_strategy(&reduce)?,
        elastic: args
            .flag("elastic")
            .then(gmi_drl::engine::ElasticConfig::default),
        overlap: !args.flag("no-overlap"),
    };

    if args.flag("autotune") {
        let mut space = tune::SyncSpace::default();
        if args.kv.contains_key("gmi-per-gpu") {
            space.gmi_per_gpu = vec![gmi_per_gpu];
        }
        if args.kv.contains_key("num-env") {
            space.num_env = vec![num_env];
        }
        if args.kv.contains_key("minibatches") {
            space.minibatches = vec![cfg.minibatches];
        }
        if cfg.strategy_override.is_some() {
            space.strategies = vec![cfg.strategy_override];
        }
        if args.flag("no-overlap") {
            space.overlap = vec![false];
        }
        let tcfg = TuneConfig {
            budget_frac: args.get("tune-budget", TuneConfig::default().budget_frac)?,
            ..TuneConfig::default()
        };
        let rep = tune::tune_sync(
            &topo,
            template,
            backend,
            &bench,
            &cost,
            &cfg,
            (gmi_per_gpu, num_env),
            &space,
            &tcfg,
        )?;
        print_tune_summary(&rep.choice.label(), &rep);
        gmi_per_gpu = rep.choice.gmi_per_gpu;
        num_env = rep.choice.num_env;
        cfg = rep.choice.apply(&cfg);
    }

    let layout = build_sync_layout(&topo, template, gmi_per_gpu, num_env, &cost, backend)?;
    let (comp, _server) = compute(real)?;
    let r = run_sync(&layout, &bench, &cost, &comp, &cfg)?;
    r.metrics.print_summary(&format!(
        "train-sync {} {}x{} GMIs [{}]",
        bench.abbr, gpus, gmi_per_gpu, r.strategy
    ));
    if args.flag("links") {
        r.metrics.print_links();
    }
    let base = baselines::isaac_sync(
        &topo,
        &bench,
        &cost,
        &comp,
        baselines::CommBackend::Nccl,
        num_env * gmi_per_gpu,
        &cfg,
    )?;
    base.metrics.print_summary("baseline (Isaac Gym PPO + NCCL)");
    println!(
        "speedup: {:.2}x",
        r.metrics.steps_per_sec / base.metrics.steps_per_sec
    );
    Ok(())
}

fn cmd_train_async(args: &Args) -> Result<()> {
    let real = args.flag("real");
    let bench = bench_info(&args.str("bench", "AY"), real)?;
    let cost = CostModel::new(&bench);
    let gpus: usize = args.get("gpus", 4)?;
    let topo = Topology::dgx_a100(gpus);
    let serving_gpus: usize = args.get("serving-gpus", (gpus / 2).max(1))?;
    let (gmi_per_gpu, mut num_env) = select_config(args, &bench, &cost, gpus)?;
    let mode = match args.str("mode", "mcc").as_str() {
        "mcc" => ShareMode::MultiChannel,
        "ucc" => ShareMode::UniChannel,
        other => bail!("unknown mode {other}"),
    };
    let mut cfg = AsyncConfig {
        rounds: args.get("rounds", 20)?,
        seed: args.get("seed", 1)?,
        share_mode: mode,
        batch_samples: args.get("batch-samples", 8192)?,
        param_sync_every: args.get("param-sync-every", 4)?,
        lr: args.get("lr", 3e-4)?,
        real_replicas: if real { 1 } else { 0 },
        compressor_granularity: args
            .get("granularity", AsyncConfig::default().compressor_granularity)?,
        staging_interval_s: args
            .get("staging-interval", AsyncConfig::default().staging_interval_s)?,
        elastic: args
            .flag("elastic")
            .then(gmi_drl::engine::ElasticConfig::default),
    };
    let trainers_per_gpu: usize = args.get("trainers-per-gpu", 2)?;

    if args.flag("autotune") {
        let mut space = tune::AsyncSpace::default();
        if args.kv.contains_key("num-env") {
            space.num_env = vec![num_env];
        }
        if args.kv.contains_key("batch-samples") {
            space.batch_samples = vec![cfg.batch_samples];
        }
        if args.kv.contains_key("param-sync-every") {
            space.param_sync_every = vec![cfg.param_sync_every];
        }
        let tcfg = TuneConfig {
            budget_frac: args.get("tune-budget", TuneConfig::default().budget_frac)?,
            ..TuneConfig::default()
        };
        let rep = tune::tune_async(
            &topo,
            serving_gpus,
            gmi_per_gpu,
            trainers_per_gpu,
            &bench,
            &cost,
            &cfg,
            num_env,
            &space,
            &tcfg,
        )?;
        print_tune_summary(&rep.choice.label(), &rep);
        num_env = rep.choice.num_env;
        cfg = rep.choice.apply(&cfg);
    }

    let layout = build_async_layout(
        &topo,
        serving_gpus,
        gmi_per_gpu,
        trainers_per_gpu,
        num_env,
        &cost,
    )?;
    let (comp, _server) = compute(real)?;
    let r = run_async(&layout, &bench, &cost, &comp, &cfg)?;
    r.metrics.print_summary(&format!(
        "train-async {} ({} serving GPUs, {:?})",
        bench.abbr, serving_gpus, mode
    ));
    println!(
        "updates: {} | packets: {} | mean packet: {:.0} KiB",
        r.updates,
        r.channel_stats.packets_out,
        r.channel_stats.mean_packet_bytes() / 1024.0
    );
    if args.flag("links") {
        r.metrics.print_links();
    }
    Ok(())
}

/// Off-policy replay training: collectors stream transitions through the
/// channels layer into a memory-budgeted replay buffer; one learner
/// samples seeded minibatches at its own rate.
fn cmd_train_replay(args: &Args) -> Result<()> {
    let real = args.flag("real");
    let bench = bench_info(&args.str("bench", "AY"), real)?;
    let cost = CostModel::new(&bench);
    let gpus: usize = args.get("gpus", 2)?;
    anyhow::ensure!(gpus >= 2, "train-replay needs at least 2 GPUs");
    let topo = Topology::dgx_a100(gpus);
    // One learner GMI on the last GPU; the rest collect.
    let collector_gpus: usize = args.get("collector-gpus", gpus - 1)?;
    let (gmi_per_gpu, num_env) = select_config(args, &bench, &cost, gpus)?;
    let mode = match args.str("mode", "mcc").as_str() {
        "mcc" => ShareMode::MultiChannel,
        "ucc" => ShareMode::UniChannel,
        other => bail!("unknown mode {other}"),
    };
    let eviction = match args.str("eviction", "fifo").as_str() {
        "fifo" => Eviction::Fifo,
        "reservoir" => Eviction::Reservoir,
        other => bail!("unknown eviction policy {other}"),
    };
    let defaults = ReplayConfig::default();
    let cfg = ReplayConfig {
        rounds: args.get("rounds", 20)?,
        seed: args.get("seed", 1)?,
        share_mode: mode,
        push_samples: args.get("push-samples", defaults.push_samples)?,
        batch_samples: args.get("batch-samples", defaults.batch_samples)?,
        buffer_gib: args.get("buffer-gib", defaults.buffer_gib)?,
        eviction,
        learner_batches_per_round: args.get("learner-batches", defaults.learner_batches_per_round)?,
        param_sync_every: args.get("param-sync-every", defaults.param_sync_every)?,
        compressor_granularity: args.get("granularity", defaults.compressor_granularity)?,
        staging_interval_s: args.get("staging-interval", defaults.staging_interval_s)?,
    };
    let layout = build_async_layout(&topo, collector_gpus, gmi_per_gpu, 1, num_env, &cost)?;
    let (comp, _server) = compute(real)?;
    let r = run_replay(&layout, &bench, &cost, &comp, &cfg)?;
    r.metrics.print_summary(&format!(
        "train-replay {} ({} collector GPUs, {:?}, {:?})",
        bench.abbr, collector_gpus, mode, eviction
    ));
    r.metrics.print_replay();
    println!(
        "updates: {} | packets: {} | mean packet: {:.0} KiB",
        r.updates,
        r.channel_stats.packets_out,
        r.channel_stats.mean_packet_bytes() / 1024.0
    );
    if args.flag("links") {
        r.metrics.print_links();
    }
    Ok(())
}

/// Self-play league season: a coordinator tenant spawns every match as a
/// child cluster tenant through the scheduler's admission path and folds
/// the results into a win-rate table.
fn cmd_league(args: &Args) -> Result<()> {
    let bench = bench_info(&args.str("bench", "AY"), false)?;
    let cost = CostModel::new(&bench);
    let gpus: usize = args.get("gpus", 2)?;
    let topo = Topology::dgx_a100(gpus);
    let defaults = LeagueConfig::default();
    let cfg = LeagueConfig {
        players: args.get("players", defaults.players)?,
        total_matches: args.get("matches", defaults.total_matches)?,
        max_concurrent: args.get("max-concurrent", defaults.max_concurrent)?,
        match_rounds: args.get("match-rounds", defaults.match_rounds)?,
        match_num_env: args.get("match-num-env", defaults.match_num_env)?,
        match_share: args.get("match-share", defaults.match_share)?,
        match_priority: args.get("match-priority", defaults.match_priority)?,
        seed: args.get("seed", defaults.seed)?,
    };
    let share: f64 = args.get("share", 0.25)?;
    let sched = SchedConfig {
        quantum_s: args.get("quantum-ms", 20.0)? / 1e3,
        ..SchedConfig::default()
    };
    println!(
        "league {} on {gpus} GPUs: {} players, {} matches (<= {} in flight)\n",
        bench.abbr, cfg.players, cfg.total_matches, cfg.max_concurrent,
    );
    let r = run_league(&topo, &bench, &cost, &cfg, share, &sched)?;
    r.job_table().print();
    println!("\nscheduling timeline:");
    sched_table(&r.events).print();
    let coord = r.job(0).expect("coordinator report");
    let mut t = Table::new(&["player", "win rate"]);
    for &(player, rate) in &coord.metrics.reward_curve {
        t.row(vec![format!("{}", player as usize), format!("{rate:.3}")]);
    }
    println!("\nleague table ({} matches decided):", r.jobs.len() - 1);
    t.print();
    println!(
        "\nmakespan {:.2}s | cluster util {:.1}% | best win rate {:.3}",
        r.makespan_s,
        100.0 * r.cluster_utilization,
        coord.metrics.final_reward,
    );
    Ok(())
}

/// Multi-tenant co-run: preemptively co-schedule a training tenant and a
/// diurnal SLO serving fleet on one shared cluster (`--static` runs the
/// pinned static-partitioning baseline instead; `--offpolicy` swaps in
/// the training + replay + league scenario with dynamic tenants).
fn cmd_multi(args: &Args) -> Result<()> {
    let bench = bench_info(&args.str("bench", "AT"), false)?;
    let cost = CostModel::new(&bench);
    let gpus: usize = args.get("gpus", 2)?;
    anyhow::ensure!(gpus >= 2, "multi needs at least 2 GPUs");
    let topo = Topology::dgx_a100(gpus);
    let duration: f64 = args.get("duration", 1.0)?;
    let seed: u64 = args.get("seed", 7)?;
    let partitioned = args.flag("static");
    let ckpt_s: f64 = args.get("checkpoint-interval", 0.0)?;
    let fault_file = args.str("fault-trace", "");
    let faults = if fault_file.is_empty() && ckpt_s <= 0.0 {
        None
    } else {
        let gpus_per_node: usize = args.get("gpus-per-node", 2)?;
        let trace = if fault_file.is_empty() {
            // Checkpointing without injected failures is still meaningful:
            // the overhead column shows what the insurance costs.
            FaultTrace::new(Vec::new(), gpus_per_node)
        } else {
            let text = std::fs::read_to_string(&fault_file)
                .with_context(|| format!("reading fault trace {fault_file}"))?;
            FaultTrace::parse(&text, gpus_per_node)?
        };
        let mut plan = FaultPlan::new(trace);
        if ckpt_s > 0.0 {
            plan = plan.with_checkpoint_interval(ckpt_s);
        }
        Some(plan)
    };
    // --audit-ff cross-checks every span --fast-forward would skip by
    // stepping it naively and erroring on observable work.
    let fast_forward = if args.flag("audit-ff") {
        FastForward::Audit
    } else if args.flag("fast-forward") {
        FastForward::On
    } else {
        FastForward::Off
    };
    let max_rounds: usize = args.get("max-rounds", 0)?;
    let cfg = SchedConfig {
        quantum_s: args.get("quantum-ms", 20.0)? / 1e3,
        preemptive: !partitioned,
        faults,
        fast_forward,
        max_rounds: if max_rounds > 0 { Some(max_rounds) } else { None },
        ..SchedConfig::default()
    };
    let week = args.flag("week");
    let offpolicy = args.flag("offpolicy");
    let jobs = if week {
        let aggregation: usize = args.get("aggregation", WeekOpts::fast().aggregation)?;
        let sample_cap: usize = args.get("sample-cap", 8192)?;
        let opts = WeekOpts {
            streaming: !args.flag("materialize"),
            aggregation: aggregation.max(1),
            sample_cap: if sample_cap > 0 { Some(sample_cap) } else { None },
        };
        week_scenario(&topo, duration, seed, &opts)
    } else if offpolicy {
        offpolicy_corun_scenario(&topo, &bench, &cost, seed)
    } else {
        corun_scenario(&topo, &bench, &cost, duration, seed, partitioned)
    };
    if week {
        println!(
            "multi {} on {gpus} GPUs [week-scale]: {} tenants over {duration:.0}s ({:.2} days)\n",
            bench.abbr,
            jobs.len(),
            duration / 86_400.0,
        );
    } else if offpolicy {
        println!(
            "multi {} on {gpus} GPUs [off-policy]: {} tenants (+ league match spawns)\n",
            bench.abbr,
            jobs.len(),
        );
    } else {
        println!(
            "multi {} on {gpus} GPUs [{}]: {} tenants over a {duration:.2}s serving day\n",
            bench.abbr,
            if partitioned { "static partition" } else { "preemptive co-schedule" },
            jobs.len(),
        );
    }
    let r = run_cluster(&topo, &bench, &cost, &jobs, &cfg)?;
    r.job_table().print();
    println!("\nscheduling timeline:");
    sched_table(&r.events).print();
    println!(
        "\nmakespan {:.2}s | cluster util {:.1}% | fairness (Jain) {:.3} | peak GPU share {:.2}",
        r.makespan_s,
        100.0 * r.cluster_utilization,
        r.fairness,
        r.peak_gpu_share,
    );
    if cfg.faults.is_some() {
        println!(
            "faults: {} hardware events applied | goodput lost to kills {:.3} GPU-s",
            r.fault_events, r.goodput_lost_s,
        );
    }
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let bench = bench_info(&args.str("bench", "AT"), false)?;
    let cost = CostModel::new(&bench);
    let gpus: usize = args.get("gpus", 4)?;
    let (best, trace) = selection::explore(&bench, &cost, GmiBackend::Mps, gpus, bench.horizon);
    let mut t = Table::new(&["GMI/GPU", "num_env", "runnable", "steps/s (1 GMI)", "mem GiB"]);
    for p in &trace {
        t.row(vec![
            p.gmi_per_gpu.to_string(),
            p.num_env.to_string(),
            p.runnable.to_string(),
            fmt_rate(p.top),
            format!("{:.1}", p.mem_gib),
        ]);
    }
    t.print();
    match best {
        Some(b) => println!(
            "\nbest: GMIperGPU={} num_env={} projected {} steps/s on {gpus} GPUs",
            b.gmi_per_gpu,
            b.num_env,
            fmt_rate(b.projected_top)
        ),
        None => println!("\nno runnable configuration found"),
    }
    Ok(())
}
