//! Online throughput auto-tuner — measured probe runs over the joint
//! configuration space.
//!
//! Algorithm 2 (see [`crate::selection`]) searches (GMIperGPU, num_env)
//! against the calibrated cost model only. This module generalizes it into
//! an *online* tuner: short **measured probe runs** executed through the
//! exact same [`crate::workload::Workload`] programs the long run will use
//! (`run_sync` / `run_async` / `run_gateway` on a scratch Engine+Fabric),
//! searching the joint space
//!
//! - sync training: num_env per GMI x GMIs per GPU (which fixes the SM
//!   share via the backend's quantization) x minibatch count x reduce
//!   strategy (auto/mpr/mrr/har) x compute/comm overlap on/off,
//! - serving gateway: max_batch x max_wait against the SLO target,
//! - A3C: num_env x batch_samples x param_sync_every,
//! - scheduler admission: minibatch count for a Training tenant, probed on
//!   a scratch mirror of its placed members and charged to the tenant in
//!   virtual time ([`AdmissionTune`]).
//!
//! ## Probe protocol
//!
//! 1. **Saturation pruning (free).** The Algorithm-2 grid over the layout
//!    axes is evaluated on the cost model first; unrunnable points and the
//!    flat tail past `Sat = R_top/R_mem < alpha` are cut before any probe
//!    spends time, and the survivors are ranked by the projected system
//!    throughput ([`crate::selection::estimate`]) to seed the search
//!    deterministically.
//! 2. **Successive halving.** Survivors are probed at a short fidelity
//!    (reduced rollout length / trace prefix / round count), the better
//!    half advances, the fidelity doubles — so most probe time goes to the
//!    contenders. Layout axes are halved first, then the knob axes
//!    (minibatches x strategy x overlap) on the winning layout.
//! 3. **Final lock.** The composed winner is probed at full fidelity
//!    against two protected references — the hand-picked default and the
//!    Algorithm-2 `explore()` pick — and the measured best is locked. The
//!    tuned configuration therefore beats or matches both *by measurement*,
//!    not by projection.
//!
//! ## Budget accounting
//!
//! Probe time is virtual seconds on the scratch engine, charged against a
//! budget of `budget_frac` (default 1%, [`crate::config`]
//! `DEFAULT_TUNE_BUDGET_FRAC`) of the projected long-run horizon. Every
//! probe is admitted against a conservative (4x cost-model) bound *before*
//! it runs, so charging never exceeds the budget; when the budget cannot
//! fund even one probe the tuner degrades deterministically to the pure
//! Algorithm-2 pick (`fallback = true` in the report). The final-lock
//! probes are funded by a reservation carved out up front, so the
//! protected comparison happens whenever the budget allows any probing at
//! all. Everything — seeding, pruning, halving, tie-breaks (earlier seed
//! rank wins) — is deterministic, so tuner decisions are bit-identical
//! run-to-run (`rust/tests/prop_tune.rs`).
//!
//! ## How to add a knob
//!
//! Extend the relevant `*Space` (axis values) and `*Choice` (the locked
//! value + `apply()` onto the base config), include the axis when the knob
//! candidates are enumerated in `tune_*`, and make sure the probe's config
//! actually consumes it — nothing else changes: budgeting, halving, and
//! the protected final lock are shared machinery.

use anyhow::{Context, Result};

use crate::cluster::Topology;
use crate::comm::ReduceStrategy;
use crate::config::BenchInfo;
use crate::drl::a3c::{run_async, AsyncConfig};
use crate::drl::sync::{run_sync, SyncConfig};
use crate::drl::Compute;
use crate::engine::Engine;
use crate::fabric::Fabric;
use crate::gmi::{GmiBackend, GmiManager, GmiSpec};
use crate::mapping::{build_async_layout, build_sync_layout, Layout, MappingTemplate};
use crate::selection::{self, effective_share, SAT_ALPHA};
use crate::serve::{batch_seconds, run_gateway_source, GatewayConfig, Request, TraceSource};
use crate::vtime::{CostModel, OpKind};
use crate::workload::{run_to_completion, SyncProgram, Workload};

// ---------------------------------------------------------------------------
// Budget
// ---------------------------------------------------------------------------

/// Virtual-time probe budget: probes are admitted against a conservative
/// cost bound BEFORE running (so spending never overshoots), then charged
/// their actual measured span.
#[derive(Debug, Clone, Copy)]
pub struct TuneBudget {
    pub budget_s: f64,
    pub spent_s: f64,
}

impl TuneBudget {
    pub fn fraction_of(run_horizon_s: f64, frac: f64) -> TuneBudget {
        TuneBudget { budget_s: (run_horizon_s * frac).max(0.0), spent_s: 0.0 }
    }

    /// Can a probe with conservative cost bound `bound_s` still run?
    pub fn admits(&self, bound_s: f64) -> bool {
        self.spent_s + bound_s <= self.budget_s + 1e-12
    }

    pub fn charge(&mut self, actual_s: f64) {
        self.spent_s += actual_s.max(0.0);
    }

    pub fn remaining_s(&self) -> f64 {
        (self.budget_s - self.spent_s).max(0.0)
    }
}

/// Tuner-wide settings; the per-workload search spaces live in
/// [`SyncSpace`] / [`GatewaySpace`] / [`AsyncSpace`].
#[derive(Debug, Clone, Copy)]
pub struct TuneConfig {
    /// Probe budget as a fraction of the projected run horizon.
    pub budget_frac: f64,
    /// Rollout length of the cheapest sync probe rung (doubles per rung up
    /// to the benchmark's full horizon).
    pub probe_rollout: usize,
    /// Training iterations per probe run.
    pub probe_iters: usize,
    /// Layout candidates entering successive halving (knob candidates get
    /// twice this).
    pub max_candidates: usize,
}

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig {
            budget_frac: crate::config::DEFAULT_TUNE_BUDGET_FRAC,
            probe_rollout: 2,
            probe_iters: 2,
            max_candidates: 8,
        }
    }
}

// ---------------------------------------------------------------------------
// Probe records + report
// ---------------------------------------------------------------------------

/// One measured probe run.
#[derive(Debug, Clone, PartialEq)]
pub struct ProbeRecord {
    pub label: String,
    /// Probe fidelity: rollout length (sync), trace-prefix requests
    /// (gateway), rounds (A3C), or iterations (admission tuning).
    pub fidelity: usize,
    /// Measured objective: env-steps/s for training; for the gateway,
    /// served/s when the SLO held and `-p99` when it did not.
    pub objective: f64,
    /// Virtual seconds charged against the budget.
    pub cost_s: f64,
}

/// What the tuner decided and what it cost.
#[derive(Debug, Clone, PartialEq)]
pub struct TuneReport<C> {
    pub choice: C,
    /// Measured objective of the locked choice (cost-model projection when
    /// `fallback` is set).
    pub objective: f64,
    pub probes: Vec<ProbeRecord>,
    /// Total virtual seconds charged; never exceeds `budget_s`.
    pub probe_cost_s: f64,
    pub budget_s: f64,
    /// Projected horizon of the long run the budget was sized against.
    pub run_horizon_s: f64,
    /// Grid points cut by the runnable check + saturation pruning before
    /// any probe ran.
    pub pruned: usize,
    /// Candidates that entered successive halving (all phases).
    pub candidates: usize,
    /// True when the budget funded no probe and the decision degraded to
    /// the cost-model pick.
    pub fallback: bool,
}

pub type SyncTuneReport = TuneReport<SyncChoice>;
pub type GatewayTuneReport = TuneReport<GatewayChoice>;
pub type AsyncTuneReport = TuneReport<AsyncChoice>;

// ---------------------------------------------------------------------------
// Successive halving (shared by all tuners)
// ---------------------------------------------------------------------------

struct ProbeOutcome {
    objective: f64,
    cost_s: f64,
}

/// Geometric fidelity ladder: `r0, 2*r0, ... , full`.
fn rung_fidelities(r0: usize, full: usize) -> Vec<usize> {
    let full = full.max(1);
    let mut r = r0.clamp(1, full);
    let mut v = Vec::new();
    loop {
        v.push(r);
        if r >= full {
            break;
        }
        r = (r * 2).min(full);
    }
    v
}

/// Deterministic budget-gated successive halving.
///
/// Probes every surviving candidate at each rung's fidelity (in current
/// rank order, best-measured first, so if the budget runs dry mid-rung the
/// strongest contenders were measured); keeps the better half (ties to the
/// earlier seed rank). A probe whose conservative `bound` the budget
/// cannot admit ends the rung — whatever has been measured decides.
/// Returns `(winner index, winner's last measured objective)`, or `None`
/// if no candidate was ever successfully probed.
fn successive_halving<C>(
    cands: &[C],
    rungs: &[usize],
    budget: &mut TuneBudget,
    probes: &mut Vec<ProbeRecord>,
    label: impl Fn(&C) -> String,
    bound: impl Fn(&C, usize) -> f64,
    mut probe: impl FnMut(&C, usize) -> Result<Option<ProbeOutcome>>,
) -> Result<Option<(usize, f64)>> {
    let mut alive: Vec<usize> = (0..cands.len()).collect();
    let mut scores: Vec<f64> = vec![f64::NEG_INFINITY; cands.len()];
    for (ri, &fid) in rungs.iter().enumerate() {
        let mut measured: Vec<usize> = Vec::new();
        for &ci in &alive {
            if !budget.admits(bound(&cands[ci], fid)) {
                break;
            }
            match probe(&cands[ci], fid)? {
                Some(out) => {
                    budget.charge(out.cost_s);
                    probes.push(ProbeRecord {
                        label: label(&cands[ci]),
                        fidelity: fid,
                        objective: out.objective,
                        cost_s: out.cost_s,
                    });
                    scores[ci] = out.objective;
                    measured.push(ci);
                }
                // Invalid candidate (e.g. a reduce strategy the layout
                // cannot plan): drops out without charging the budget.
                None => scores[ci] = f64::NEG_INFINITY,
            }
        }
        if measured.is_empty() {
            break;
        }
        measured.sort_by(|&a, &b| {
            scores[b]
                .partial_cmp(&scores[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let keep = if ri + 1 == rungs.len() { 1 } else { measured.len().div_ceil(2) };
        measured.truncate(keep.max(1));
        alive = measured;
    }
    let winner = alive.first().copied().filter(|&i| scores[i].is_finite());
    Ok(winner.map(|i| (i, scores[i])))
}

// ---------------------------------------------------------------------------
// Cost-model estimates (probe admission bounds + horizon projection)
// ---------------------------------------------------------------------------

/// One modeled sync training iteration at an explicit share/interference
/// (the probe-admission bound core; a safety factor is applied on top).
#[allow(clippy::too_many_arguments)]
fn model_iter_core(
    cost: &CostModel,
    share: f64,
    inter: f64,
    num_env: usize,
    rollout: usize,
    epochs: usize,
    minibatches: usize,
) -> f64 {
    let t_sim = cost.op_time(OpKind::SimStep { num_env }, share, inter);
    let t_fwd = cost.op_time(OpKind::PolicyFwd { num_env }, share, inter);
    let mb = minibatches.max(1);
    let samples = (num_env * rollout).max(1);
    let t_train = cost.op_time(OpKind::TrainGrad { samples: samples.div_ceil(mb) }, share, inter);
    let t_adam = cost.op_time(OpKind::AdamApply, share, inter);
    rollout as f64 * (t_sim + t_fwd) + epochs.max(1) as f64 * mb as f64 * (t_train + t_adam)
}

#[allow(clippy::too_many_arguments)]
fn model_sync_iter_s(
    cost: &CostModel,
    backend: GmiBackend,
    gmi_per_gpu: usize,
    num_env: usize,
    rollout: usize,
    epochs: usize,
    minibatches: usize,
) -> f64 {
    let share = effective_share(backend, gmi_per_gpu);
    let inter = backend.interference(gmi_per_gpu.saturating_sub(1), cost.heaviness);
    model_iter_core(cost, share, inter, num_env, rollout, epochs, minibatches)
}

/// Safety factor on every probe-admission bound: the model omits
/// communication, experience shipping, and drain, so admission is gated at
/// 4x the modeled compute.
const BOUND_SAFETY: f64 = 4.0;

// ---------------------------------------------------------------------------
// Sync training tuner
// ---------------------------------------------------------------------------

/// Search space for sync training. Axis order is the deterministic
/// candidate enumeration order; pin an axis by shrinking it to one value.
#[derive(Debug, Clone)]
pub struct SyncSpace {
    pub gmi_per_gpu: Vec<usize>,
    pub num_env: Vec<usize>,
    pub minibatches: Vec<usize>,
    pub strategies: Vec<Option<ReduceStrategy>>,
    pub overlap: Vec<bool>,
}

impl Default for SyncSpace {
    fn default() -> Self {
        SyncSpace {
            gmi_per_gpu: vec![1, 2, 3, 4, 6, 8],
            num_env: vec![256, 512, 1024, 2048, 4096],
            minibatches: vec![2, 4, 8],
            strategies: vec![
                None,
                Some(ReduceStrategy::MultiProcess),
                Some(ReduceStrategy::MultiRing),
                Some(ReduceStrategy::Hierarchical),
            ],
            overlap: vec![true, false],
        }
    }
}

pub fn strategy_name(s: Option<ReduceStrategy>) -> &'static str {
    match s {
        None => "auto",
        Some(ReduceStrategy::MultiProcess) => "mpr",
        Some(ReduceStrategy::MultiRing) => "mrr",
        Some(ReduceStrategy::Hierarchical) => "har",
    }
}

/// A locked sync training configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SyncChoice {
    pub gmi_per_gpu: usize,
    pub num_env: usize,
    pub minibatches: usize,
    pub strategy: Option<ReduceStrategy>,
    pub overlap: bool,
}

impl SyncChoice {
    /// Overlay the tuned knobs on a base config (iterations, epochs, lr,
    /// seed, elasticity are the run's own business).
    pub fn apply(&self, base: &SyncConfig) -> SyncConfig {
        SyncConfig {
            minibatches: self.minibatches,
            strategy_override: self.strategy,
            overlap: self.overlap,
            ..base.clone()
        }
    }

    pub fn label(&self) -> String {
        format!(
            "g{}xe{} mb{} {} {}",
            self.gmi_per_gpu,
            self.num_env,
            self.minibatches,
            strategy_name(self.strategy),
            if self.overlap { "ov" } else { "seq" }
        )
    }
}

/// Algorithm-2-style saturation-pruned grid over the layout axes, ranked
/// by projected system throughput. Returns `(runnable points (g, e,
/// score) best-first, pruned count)`.
fn pruned_layout_grid(
    bench: &BenchInfo,
    cost: &CostModel,
    backend: GmiBackend,
    num_gpu: usize,
    space: &SyncSpace,
) -> (Vec<(usize, usize, f64)>, usize) {
    let mut envs = space.num_env.clone();
    envs.sort_unstable();
    envs.dedup();
    let mut gs = space.gmi_per_gpu.clone();
    gs.sort_unstable();
    gs.dedup();
    let mut points = Vec::new();
    let mut pruned = 0usize;
    for &g in gs.iter().rev() {
        let mut pre_top = 0.0f64;
        let mut pre_mem = 0.0f64;
        for (i, &e) in envs.iter().enumerate() {
            let p = selection::profile(bench, cost, backend, g, e, bench.horizon);
            if !p.runnable {
                pruned += 1;
                continue;
            }
            if pre_top > 0.0 && pre_mem > 0.0 {
                let r_top = (p.top - pre_top) / pre_top;
                let r_mem = (p.mem_gib - pre_mem) / pre_mem;
                let sat = if r_mem.abs() > 1e-12 { r_top / r_mem } else { f64::INFINITY };
                if sat < SAT_ALPHA {
                    // This point and the rest of the sweep are saturated.
                    pruned += envs.len() - i;
                    break;
                }
            }
            pre_top = p.top;
            pre_mem = p.mem_gib;
            points.push((g, e, selection::estimate(g, num_gpu, p.top)));
        }
    }
    points.sort_by(|a, b| {
        b.2.partial_cmp(&a.2)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.cmp(&b.0))
            .then(a.1.cmp(&b.1))
    });
    (points, pruned)
}

/// One measured sync probe: the real `run_sync` driver on a scratch
/// Engine+Fabric, with the benchmark's rollout shortened to `rollout` —
/// the exact code path of the long run at reduced fidelity. Returns
/// `None` for candidates the layout/planner rejects (e.g. an invalid
/// reduce strategy).
#[allow(clippy::too_many_arguments)]
fn sync_probe(
    topo: &Topology,
    template: MappingTemplate,
    backend: GmiBackend,
    bench: &BenchInfo,
    cost: &CostModel,
    base: &SyncConfig,
    choice: &SyncChoice,
    rollout: usize,
    probe_iters: usize,
) -> Result<Option<ProbeOutcome>> {
    let layout = match build_sync_layout(
        topo,
        template,
        choice.gmi_per_gpu,
        choice.num_env,
        cost,
        Some(backend),
    ) {
        Ok(l) => l,
        Err(_) => return Ok(None),
    };
    let mut pb = bench.clone();
    pb.horizon = rollout;
    // Probes measure the static configuration: elasticity would shift
    // shares mid-probe and add noise the short run cannot average out.
    let cfg = SyncConfig { iterations: probe_iters.max(1), elastic: None, ..choice.apply(base) };
    match run_sync(&layout, &pb, cost, &Compute::Null, &cfg) {
        Ok(r) => Ok(Some(ProbeOutcome {
            objective: r.metrics.steps_per_sec,
            cost_s: r.metrics.span_s,
        })),
        Err(_) => Ok(None),
    }
}

/// Tune sync training over the joint (layout x knob) space. `default_point`
/// is the hand-picked `(gmi_per_gpu, num_env)` the run would otherwise use
/// — it is probed as a protected reference in the final lock, as is the
/// Algorithm-2 `explore()` pick.
#[allow(clippy::too_many_arguments)]
pub fn tune_sync(
    topo: &Topology,
    template: MappingTemplate,
    backend: Option<GmiBackend>,
    bench: &BenchInfo,
    cost: &CostModel,
    base: &SyncConfig,
    default_point: (usize, usize),
    space: &SyncSpace,
    tcfg: &TuneConfig,
) -> Result<SyncTuneReport> {
    let be = backend.unwrap_or_else(|| GmiBackend::auto_select(true, topo.gpus[0].sm_arch));
    let (g_d, e_d) = default_point;
    anyhow::ensure!(g_d >= 1 && e_d >= 1, "auto-tuner: default point must be positive");

    // Phase 0 (free): saturation-pruned cost-model grid seeds the search.
    let (points, pruned) = pruned_layout_grid(bench, cost, be, topo.num_gpus(), space);
    anyhow::ensure!(
        !points.is_empty(),
        "auto-tuner: no runnable layout point in the search space"
    );

    // The budget is a fraction of the projected hand-picked long run.
    let run_horizon_s = base.iterations as f64
        * model_sync_iter_s(cost, be, g_d, e_d, bench.horizon, base.ppo_epochs, base.minibatches);
    let mut budget = TuneBudget::fraction_of(run_horizon_s, tcfg.budget_frac);

    let base_choice_at = |g: usize, e: usize| SyncChoice {
        gmi_per_gpu: g,
        num_env: e,
        minibatches: base.minibatches,
        strategy: base.strategy_override,
        overlap: base.overlap,
    };
    let explore_pick = selection::explore(bench, cost, be, topo.num_gpus(), bench.horizon).0;
    let probe_bound = |c: &SyncChoice, fid: usize| {
        BOUND_SAFETY
            * tcfg.probe_iters.max(1) as f64
            * model_sync_iter_s(cost, be, c.gmi_per_gpu, c.num_env, fid, base.ppo_epochs, c.minibatches)
    };

    // Reserve the final-lock probes (composed winner + explore pick +
    // hand-picked default, at full fidelity) up front, so the protected
    // comparison happens whenever the budget allows any probing at all.
    let full = bench.horizon;
    let mut reserve = probe_bound(&base_choice_at(g_d, e_d), full);
    if let Some(s) = explore_pick {
        reserve += probe_bound(&base_choice_at(s.gmi_per_gpu, s.num_env), full);
    }
    reserve += points
        .iter()
        .map(|&(g, e, _)| probe_bound(&base_choice_at(g, e), full))
        .fold(0.0, f64::max);
    let mut work =
        TuneBudget { budget_s: (budget.budget_s - reserve).max(0.0), spent_s: 0.0 };

    let mut probes = Vec::new();
    let rungs = rung_fidelities(tcfg.probe_rollout, full);

    // Phase 1: halve the layout axes under measured probes.
    let l_cands: Vec<SyncChoice> = points
        .iter()
        .take(tcfg.max_candidates.max(1))
        .map(|&(g, e, _)| base_choice_at(g, e))
        .collect();
    let w1 = successive_halving(
        &l_cands,
        &rungs,
        &mut work,
        &mut probes,
        SyncChoice::label,
        probe_bound,
        |c, fid| sync_probe(topo, template, be, bench, cost, base, c, fid, tcfg.probe_iters),
    )?;
    let mut candidates = l_cands.len();

    let layout_winner = w1.map(|(i, _)| l_cands[i]);

    // Phase 2: halve the knob axes on the winning layout.
    let phase2 = if let Some(inc) = layout_winner {
        let mut knob_cands = vec![inc];
        for &st in &space.strategies {
            for &ov in &space.overlap {
                for &mb in &space.minibatches {
                    let c = SyncChoice {
                        minibatches: mb.max(1),
                        strategy: st,
                        overlap: ov,
                        ..inc
                    };
                    if !knob_cands.contains(&c) {
                        knob_cands.push(c);
                    }
                }
            }
        }
        knob_cands.truncate((2 * tcfg.max_candidates).max(1));
        let w2 = successive_halving(
            &knob_cands,
            &rungs,
            &mut work,
            &mut probes,
            SyncChoice::label,
            probe_bound,
            |c, fid| sync_probe(topo, template, be, bench, cost, base, c, fid, tcfg.probe_iters),
        )?;
        candidates += knob_cands.len();
        Some(w2.map(|(i, obj)| (knob_cands[i], obj)).unwrap_or_else(|| {
            (inc, w1.map(|(_, o)| o).unwrap_or(f64::NEG_INFINITY))
        }))
    } else {
        None
    };
    budget.charge(work.spent_s);

    let (winner, winner_obj) = match phase2 {
        Some(w) => w,
        None => {
            // No probe ever ran: degrade deterministically to the
            // Algorithm-2 pick (or the best-ranked grid point).
            let (g, e) = explore_pick
                .map(|s| (s.gmi_per_gpu, s.num_env))
                .unwrap_or((points[0].0, points[0].1));
            let choice = base_choice_at(g, e);
            let p = selection::profile(bench, cost, be, g, e, full);
            return Ok(TuneReport {
                choice,
                objective: selection::estimate(g, topo.num_gpus(), p.top),
                probe_cost_s: budget.spent_s,
                budget_s: budget.budget_s,
                run_horizon_s,
                pruned,
                candidates,
                fallback: true,
                probes,
            });
        }
    };

    // Phase 3: final lock at full fidelity against the protected
    // references (dedup keeps the winner's seed rank 0 on ties).
    let mut finals = vec![winner];
    if let Some(s) = explore_pick {
        let c = base_choice_at(s.gmi_per_gpu, s.num_env);
        if !finals.contains(&c) {
            finals.push(c);
        }
    }
    let c = base_choice_at(g_d, e_d);
    if !finals.contains(&c) {
        finals.push(c);
    }
    let w3 = successive_halving(
        &finals,
        &[full],
        &mut budget,
        &mut probes,
        SyncChoice::label,
        probe_bound,
        |c, fid| sync_probe(topo, template, be, bench, cost, base, c, fid, tcfg.probe_iters),
    )?;
    candidates += finals.len();
    let (choice, objective) =
        w3.map(|(i, obj)| (finals[i], obj)).unwrap_or((winner, winner_obj));

    Ok(TuneReport {
        choice,
        objective,
        probe_cost_s: budget.spent_s,
        budget_s: budget.budget_s,
        run_horizon_s,
        pruned,
        candidates,
        fallback: false,
        probes,
    })
}

// ---------------------------------------------------------------------------
// Serving gateway tuner
// ---------------------------------------------------------------------------

/// Search space for the gateway's dynamic-batching policy.
#[derive(Debug, Clone)]
pub struct GatewaySpace {
    pub max_batch: Vec<usize>,
    pub max_wait_ms: Vec<f64>,
}

impl Default for GatewaySpace {
    fn default() -> Self {
        GatewaySpace {
            max_batch: vec![8, 16, 32, 64],
            max_wait_ms: vec![0.5, 1.0, 2.0, 4.0],
        }
    }
}

/// A locked gateway batching policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GatewayChoice {
    pub max_batch: usize,
    pub max_wait_s: f64,
}

impl GatewayChoice {
    pub fn apply(&self, base: &GatewayConfig) -> GatewayConfig {
        GatewayConfig { max_batch: self.max_batch, max_wait_s: self.max_wait_s, ..*base }
    }

    pub fn label(&self) -> String {
        format!("b{} w{:.2}ms", self.max_batch, self.max_wait_s * 1e3)
    }
}

/// Tune the gateway's `max_batch x max_wait` against the SLO target by
/// replaying prefixes of the real trace through `run_gateway` (autoscale
/// disabled in probes — the tuner locks the static batching policy).
/// Objective: among SLO-feasible policies the highest served/s, otherwise
/// the lowest p99 (encoded as `-p99`, so any feasible policy dominates).
pub fn tune_gateway(
    layout: &Layout,
    bench: &BenchInfo,
    cost: &CostModel,
    trace: &[Request],
    base: &GatewayConfig,
    space: &GatewaySpace,
    tcfg: &TuneConfig,
) -> Result<GatewayTuneReport> {
    // One Arc copy here; every probe prefix then shares the backing.
    tune_gateway_source(layout, bench, cost, &TraceSource::from(trace), base, space, tcfg)
}

/// [`tune_gateway`] over a [`TraceSource`] — probes replay seeded prefix
/// streams directly, so tuning against a week-long generated trace never
/// materializes it (three O(prefix) sizing scans at O(1) memory, then the
/// probes themselves). Bit-identical to the slice path on materialized
/// traces.
pub fn tune_gateway_source(
    layout: &Layout,
    bench: &BenchInfo,
    cost: &CostModel,
    trace: &TraceSource,
    base: &GatewayConfig,
    space: &GatewaySpace,
    tcfg: &TuneConfig,
) -> Result<GatewayTuneReport> {
    anyhow::ensure!(!layout.rollout_gmis.is_empty(), "auto-tuner: empty fleet");
    let (n, last_arrival) = trace.count_and_last();
    anyhow::ensure!(n > 0, "auto-tuner: empty trace");
    let run_horizon_s = last_arrival.max(1e-9);
    let mut budget = TuneBudget::fraction_of(run_horizon_s, tcfg.budget_frac);

    // Candidates: the hand-picked default first (protected), then the grid
    // in deterministic axis order.
    let default_choice =
        GatewayChoice { max_batch: base.max_batch, max_wait_s: base.max_wait_s };
    let mut cands = vec![default_choice];
    for &bsz in &space.max_batch {
        for &wms in &space.max_wait_ms {
            let c = GatewayChoice { max_batch: bsz.max(1), max_wait_s: wms.max(0.0) * 1e-3 };
            if !cands.contains(&c) {
                cands.push(c);
            }
        }
    }
    cands.truncate((4 * tcfg.max_candidates).max(1));

    let fleet = layout.rollout_gmis.len() as f64;
    let share = layout
        .manager
        .gmi(layout.rollout_gmis[0])
        .map(|s| s.sm_share)
        .unwrap_or(1.0);
    // Conservative per-request serial time: unbatched forward on one GMI.
    let serial_1 = batch_seconds(bench, cost, layout.manager.topology(), share, 1);

    // Fidelity = trace-prefix length, sized so the first rung's full scan
    // fits well inside the budget, then growing 4x per rung. The count of
    // arrivals inside the first budget slice comes from a lazy prefix walk
    // (== partition_point on the materialized backing, O(1) memory on the
    // streaming one).
    let prefix_for = |t: f64| -> usize {
        let mut k = 0usize;
        for req in trace.prefix(usize::MAX) {
            if req.arrival_s <= t {
                k += 1;
            } else {
                break;
            }
        }
        k
    };
    let target0 = budget.budget_s / (4.0 * (cands.len() as f64 + 2.0));
    let mut r = prefix_for(target0).clamp(8.min(n), n);
    let mut rungs = Vec::new();
    loop {
        rungs.push(r);
        if r >= n {
            break;
        }
        r = (r * 4).min(n);
    }
    let rung_last = *rungs.last().unwrap();

    // Arrival time at each rung boundary (probe_bound's inputs are always
    // rung fidelities), collected in one pass over the stream.
    let mut rung_arrivals: Vec<(usize, f64)> = rungs.iter().map(|&v| (v, run_horizon_s)).collect();
    {
        let mut k = 0usize;
        let mut i = 0usize;
        for req in trace.prefix(rung_last) {
            i += 1;
            while k < rung_arrivals.len() && rung_arrivals[k].0 == i {
                rung_arrivals[k].1 = req.arrival_s;
                k += 1;
            }
        }
    }
    let arrival_at = |fid: usize| -> f64 {
        rung_arrivals
            .iter()
            .find(|(v, _)| *v == fid.min(n))
            .map(|&(_, a)| a)
            .unwrap_or(run_horizon_s)
    };
    let probe_bound = |_c: &GatewayChoice, fid: usize| {
        2.0 * (arrival_at(fid) + fid as f64 * serial_1 / fleet.max(1.0))
    };

    // Reserve the final winner-vs-default comparison at the top fidelity.
    let reserve = 2.0 * probe_bound(&default_choice, rung_last);
    let mut work =
        TuneBudget { budget_s: (budget.budget_s - reserve).max(0.0), spent_s: 0.0 };

    let mut probes = Vec::new();
    let mut probe = |c: &GatewayChoice, fid: usize| -> Result<Option<ProbeOutcome>> {
        let pcfg = GatewayConfig {
            max_batch: c.max_batch,
            max_wait_s: c.max_wait_s,
            autoscale: None,
            ..*base
        };
        match run_gateway_source(layout, bench, cost, trace.prefix(fid.min(n)), &pcfg) {
            Ok(r) => {
                let span = r.metrics.span_s.max(1e-12);
                let feasible = r.latency.p99_s <= base.slo_s;
                let objective =
                    if feasible { r.latency.served as f64 / span } else { -r.latency.p99_s };
                Ok(Some(ProbeOutcome { objective, cost_s: r.metrics.span_s }))
            }
            Err(_) => Ok(None),
        }
    };

    let w1 = successive_halving(
        &cands,
        &rungs,
        &mut work,
        &mut probes,
        GatewayChoice::label,
        probe_bound,
        &mut probe,
    )?;
    budget.charge(work.spent_s);
    let mut candidates = cands.len();

    let (winner, winner_obj) = match w1 {
        Some((i, obj)) => (cands[i], obj),
        None => {
            // Budget funded nothing: keep the hand-picked policy.
            return Ok(TuneReport {
                choice: default_choice,
                objective: f64::NEG_INFINITY,
                probe_cost_s: budget.spent_s,
                budget_s: budget.budget_s,
                run_horizon_s,
                pruned: 0,
                candidates,
                fallback: true,
                probes,
            });
        }
    };

    // Final lock: winner vs the protected default at the top fidelity.
    let mut finals = vec![winner];
    if !finals.contains(&default_choice) {
        finals.push(default_choice);
    }
    let w2 = successive_halving(
        &finals,
        &[rung_last],
        &mut budget,
        &mut probes,
        GatewayChoice::label,
        probe_bound,
        &mut probe,
    )?;
    candidates += finals.len();
    let (choice, objective) =
        w2.map(|(i, obj)| (finals[i], obj)).unwrap_or((winner, winner_obj));

    Ok(TuneReport {
        choice,
        objective,
        probe_cost_s: budget.spent_s,
        budget_s: budget.budget_s,
        run_horizon_s,
        pruned: 0,
        candidates,
        fallback: false,
        probes,
    })
}

// ---------------------------------------------------------------------------
// A3C tuner
// ---------------------------------------------------------------------------

/// Search space for the async (A3C) pipeline.
#[derive(Debug, Clone)]
pub struct AsyncSpace {
    pub num_env: Vec<usize>,
    pub batch_samples: Vec<usize>,
    pub param_sync_every: Vec<usize>,
}

impl Default for AsyncSpace {
    fn default() -> Self {
        AsyncSpace {
            num_env: vec![1024, 2048, 4096],
            batch_samples: vec![4096, 8192, 16384],
            param_sync_every: vec![2, 4, 8],
        }
    }
}

/// A locked A3C configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AsyncChoice {
    pub num_env: usize,
    pub batch_samples: usize,
    pub param_sync_every: usize,
}

impl AsyncChoice {
    pub fn apply(&self, base: &AsyncConfig) -> AsyncConfig {
        AsyncConfig {
            batch_samples: self.batch_samples,
            param_sync_every: self.param_sync_every,
            ..base.clone()
        }
    }

    pub fn label(&self) -> String {
        format!("e{} bs{} ps{}", self.num_env, self.batch_samples, self.param_sync_every)
    }
}

/// Tune the A3C pipeline's `num_env x batch_samples x param_sync_every`
/// with short measured `run_async` probes (fidelity = round count).
#[allow(clippy::too_many_arguments)]
pub fn tune_async(
    topo: &Topology,
    serving_gpus: usize,
    serving_per_gpu: usize,
    trainers_per_gpu: usize,
    bench: &BenchInfo,
    cost: &CostModel,
    base: &AsyncConfig,
    default_num_env: usize,
    space: &AsyncSpace,
    tcfg: &TuneConfig,
) -> Result<AsyncTuneReport> {
    let be = GmiBackend::Mps; // async layouts are MPS (cross-GMI channels)
    let agents = (serving_gpus * serving_per_gpu).max(1);
    let trainers = ((topo.num_gpus() - serving_gpus) * trainers_per_gpu).max(1);

    // Saturation-prune the num_env axis on the cost model (agents' view).
    let mut envs = space.num_env.clone();
    envs.sort_unstable();
    envs.dedup();
    let mut kept = Vec::new();
    let mut pruned = 0usize;
    let mut pre_top = 0.0f64;
    let mut pre_mem = 0.0f64;
    for (i, &e) in envs.iter().enumerate() {
        let p = selection::profile(bench, cost, be, serving_per_gpu, e, bench.horizon);
        if !p.runnable {
            pruned += 1;
            continue;
        }
        if pre_top > 0.0 && pre_mem > 0.0 {
            let r_top = (p.top - pre_top) / pre_top;
            let r_mem = (p.mem_gib - pre_mem) / pre_mem;
            let sat = if r_mem.abs() > 1e-12 { r_top / r_mem } else { f64::INFINITY };
            if sat < SAT_ALPHA {
                pruned += envs.len() - i;
                break;
            }
        }
        pre_top = p.top;
        pre_mem = p.mem_gib;
        kept.push(e);
    }
    if kept.is_empty() {
        kept.push(default_num_env.max(1));
    }

    // Modeled seconds of one round: agents roll `horizon` steps, trainers
    // consume the produced samples in `batch_samples` slices.
    let share = effective_share(be, serving_per_gpu);
    let inter = be.interference(serving_per_gpu.saturating_sub(1), cost.heaviness);
    let round_s = |c: &AsyncChoice| {
        let t_sim = cost.op_time(OpKind::SimStep { num_env: c.num_env }, share, inter);
        let t_fwd = cost.op_time(OpKind::PolicyFwd { num_env: c.num_env }, share, inter);
        let produced = agents * c.num_env * bench.horizon;
        let batches = produced.div_ceil(c.batch_samples.max(1));
        let t_train =
            cost.op_time(OpKind::TrainGrad { samples: c.batch_samples.max(1) }, share, inter);
        bench.horizon as f64 * (t_sim + t_fwd)
            + batches as f64 * t_train / trainers as f64
    };

    let default_choice = AsyncChoice {
        num_env: default_num_env.max(1),
        batch_samples: base.batch_samples,
        param_sync_every: base.param_sync_every,
    };
    let run_horizon_s = base.rounds as f64 * round_s(&default_choice);
    let mut budget = TuneBudget::fraction_of(run_horizon_s, tcfg.budget_frac);

    let mut cands = vec![default_choice];
    for &e in &kept {
        for &bs in &space.batch_samples {
            for &ps in &space.param_sync_every {
                let c = AsyncChoice {
                    num_env: e,
                    batch_samples: bs.max(1),
                    param_sync_every: ps.max(1),
                };
                if !cands.contains(&c) {
                    cands.push(c);
                }
            }
        }
    }
    cands.truncate((2 * tcfg.max_candidates).max(1));

    let probe_bound = |c: &AsyncChoice, fid: usize| BOUND_SAFETY * fid as f64 * round_s(c);
    let rungs = rung_fidelities(1, base.rounds.clamp(1, 4));
    let rung_last = *rungs.last().unwrap();
    let reserve = 2.0 * probe_bound(&default_choice, rung_last);
    let mut work =
        TuneBudget { budget_s: (budget.budget_s - reserve).max(0.0), spent_s: 0.0 };

    let mut probes = Vec::new();
    let mut probe = |c: &AsyncChoice, fid: usize| -> Result<Option<ProbeOutcome>> {
        let layout = match build_async_layout(
            topo,
            serving_gpus,
            serving_per_gpu,
            trainers_per_gpu,
            c.num_env,
            cost,
        ) {
            Ok(l) => l,
            Err(_) => return Ok(None),
        };
        let cfg = AsyncConfig { rounds: fid.max(1), elastic: None, ..c.apply(base) };
        match run_async(&layout, bench, cost, &Compute::Null, &cfg) {
            Ok(r) => Ok(Some(ProbeOutcome {
                objective: r.metrics.steps_per_sec,
                cost_s: r.metrics.span_s,
            })),
            Err(_) => Ok(None),
        }
    };

    let w1 = successive_halving(
        &cands,
        &rungs,
        &mut work,
        &mut probes,
        AsyncChoice::label,
        probe_bound,
        &mut probe,
    )?;
    budget.charge(work.spent_s);
    let mut candidates = cands.len();

    let (winner, winner_obj) = match w1 {
        Some((i, obj)) => (cands[i], obj),
        None => {
            return Ok(TuneReport {
                choice: default_choice,
                objective: f64::NEG_INFINITY,
                probe_cost_s: budget.spent_s,
                budget_s: budget.budget_s,
                run_horizon_s,
                pruned,
                candidates,
                fallback: true,
                probes,
            });
        }
    };

    let mut finals = vec![winner];
    if !finals.contains(&default_choice) {
        finals.push(default_choice);
    }
    let w2 = successive_halving(
        &finals,
        &[rung_last],
        &mut budget,
        &mut probes,
        AsyncChoice::label,
        probe_bound,
        &mut probe,
    )?;
    candidates += finals.len();
    let (choice, objective) =
        w2.map(|(i, obj)| (finals[i], obj)).unwrap_or((winner, winner_obj));

    Ok(TuneReport {
        choice,
        objective,
        probe_cost_s: budget.spent_s,
        budget_s: budget.budget_s,
        run_horizon_s,
        pruned,
        candidates,
        fallback: false,
        probes,
    })
}

// ---------------------------------------------------------------------------
// Scheduler admission tuning
// ---------------------------------------------------------------------------

/// A Training tenant's request to tune its minibatch count at admission:
/// probes run on a scratch mirror of the placed members and the probe time
/// is charged to the tenant in virtual time (every member's clock pays).
#[derive(Debug, Clone, PartialEq)]
pub struct AdmissionTune {
    /// Minibatch candidates (the tenant's current count is always probed).
    pub minibatches: Vec<usize>,
    /// Budget as a fraction of the tenant's projected run horizon.
    pub budget_frac: f64,
    /// Training iterations per probe.
    pub probe_iters: usize,
}

impl Default for AdmissionTune {
    fn default() -> Self {
        AdmissionTune {
            minibatches: vec![1, 2, 4, 8],
            budget_frac: crate::config::DEFAULT_TUNE_BUDGET_FRAC,
            probe_iters: 2,
        }
    }
}

/// Probe minibatch candidates for a placed Training tenant on a scratch
/// mirror of its members (same GPUs, shares, roles — an empty manager, so
/// co-tenant interference is not modeled; the probe measures the tenant's
/// own pipeline). The probe config mirrors `JobKind::Training`'s program
/// (one PPO epoch, sequential reductions).
#[allow(clippy::too_many_arguments)]
pub fn tune_admission_minibatches(
    topo: &Topology,
    members: &[GmiSpec],
    bench: &BenchInfo,
    cost: &CostModel,
    iterations: usize,
    rollout_len: usize,
    current_mb: usize,
    tr: &AdmissionTune,
) -> Result<TuneReport<usize>> {
    anyhow::ensure!(!members.is_empty(), "admission tuning: no placed members");
    let mut manager = GmiManager::new(topo.clone());
    let mut ids = Vec::with_capacity(members.len());
    for (i, spec) in members.iter().enumerate() {
        let mut s = spec.clone();
        s.id = i;
        ids.push(manager.add_gmi(s)?);
    }

    let share = members[0].sm_share;
    let num_env = members.iter().map(|m| m.num_env).find(|&n| n > 0).unwrap_or(bench.num_env);
    let iter_s = |mb: usize| model_iter_core(cost, share, 1.0, num_env, rollout_len, 1, mb);
    let run_horizon_s = iterations.max(1) as f64 * iter_s(current_mb.max(1));
    let mut budget = TuneBudget::fraction_of(run_horizon_s, tr.budget_frac);

    let mut cands = vec![current_mb.max(1)];
    for &mb in &tr.minibatches {
        if !cands.contains(&mb.max(1)) {
            cands.push(mb.max(1));
        }
    }

    let rungs = rung_fidelities(1, tr.probe_iters.max(1));
    let mut probes = Vec::new();
    let w = successive_halving(
        &cands,
        &rungs,
        &mut budget,
        &mut probes,
        |mb| format!("mb{mb}"),
        |&mb, fid| BOUND_SAFETY * fid as f64 * iter_s(mb),
        |&mb, fid| {
            let cfg = SyncConfig {
                iterations: fid.max(1),
                ppo_epochs: 1,
                minibatches: mb,
                overlap: false,
                ..SyncConfig::default()
            };
            let mut engine = Engine::new(&manager, cost);
            let mut fabric = Fabric::single_node(topo.clone());
            let execs = engine.add_group(&ids)?;
            let mut program = SyncProgram::new(cfg, rollout_len);
            if program.bind(&engine, &mut fabric, bench, &execs).is_err() {
                return Ok(None);
            }
            if run_to_completion(&mut program, &mut engine, &mut fabric, cost, bench, &Compute::Null)
                .is_err()
            {
                return Ok(None);
            }
            let m = program.finish(&engine, &fabric);
            Ok(Some(ProbeOutcome { objective: m.steps_per_sec, cost_s: m.span_s }))
        },
    )
    .context("admission tuning probes")?;

    let candidates = cands.len();
    let (choice, objective, fallback) = match w {
        Some((i, obj)) => (cands[i], obj, false),
        None => (current_mb.max(1), f64::NEG_INFINITY, true),
    };
    Ok(TuneReport {
        choice,
        objective,
        probe_cost_s: budget.spent_s,
        budget_s: budget.budget_s,
        run_horizon_s,
        pruned: 0,
        candidates,
        fallback,
        probes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::static_registry;
    use crate::gmi::Role;

    fn at() -> (BenchInfo, CostModel) {
        let b = static_registry()["AT"].clone();
        let c = CostModel::new(&b);
        (b, c)
    }

    #[test]
    fn rung_ladder_doubles_to_full() {
        assert_eq!(rung_fidelities(2, 16), vec![2, 4, 8, 16]);
        assert_eq!(rung_fidelities(3, 16), vec![3, 6, 12, 16]);
        assert_eq!(rung_fidelities(16, 16), vec![16]);
        assert_eq!(rung_fidelities(32, 16), vec![16]);
        assert_eq!(rung_fidelities(0, 1), vec![1]);
    }

    #[test]
    fn budget_admission_is_conservative() {
        let mut b = TuneBudget::fraction_of(100.0, 0.01);
        assert!((b.budget_s - 1.0).abs() < 1e-12);
        assert!(b.admits(1.0));
        assert!(!b.admits(1.1));
        b.charge(0.6);
        assert!(b.admits(0.4));
        assert!(!b.admits(0.5));
        assert!((b.remaining_s() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn halving_picks_measured_best_and_respects_budget() {
        // Synthetic probes: objective = candidate value, cost 1s each.
        let cands = [3usize, 9, 5, 7];
        let mut budget = TuneBudget { budget_s: 100.0, spent_s: 0.0 };
        let mut probes = Vec::new();
        let w = successive_halving(
            &cands,
            &[1, 2],
            &mut budget,
            &mut probes,
            |c| format!("c{c}"),
            |_, _| 1.0,
            |&c, _| Ok(Some(ProbeOutcome { objective: c as f64, cost_s: 1.0 })),
        )
        .unwrap();
        let (i, obj) = w.expect("winner");
        assert_eq!(cands[i], 9);
        assert_eq!(obj, 9.0);
        // Rung 0 probes all 4, rung 1 the surviving 2.
        assert_eq!(probes.len(), 6);
        assert_eq!(budget.spent_s, 6.0);

        // Zero budget: nothing runs, no winner, nothing charged.
        let mut empty = TuneBudget { budget_s: 0.0, spent_s: 0.0 };
        let mut p2 = Vec::new();
        let w2 = successive_halving(
            &cands,
            &[1, 2],
            &mut empty,
            &mut p2,
            |c| format!("c{c}"),
            |_, _| 1.0,
            |&c, _| Ok(Some(ProbeOutcome { objective: c as f64, cost_s: 1.0 })),
        )
        .unwrap();
        assert!(w2.is_none());
        assert!(p2.is_empty());
        assert_eq!(empty.spent_s, 0.0);
    }

    #[test]
    fn halving_skips_invalid_candidates_without_charging() {
        let cands = [1usize, 2, 3];
        let mut budget = TuneBudget { budget_s: 100.0, spent_s: 0.0 };
        let mut probes = Vec::new();
        let w = successive_halving(
            &cands,
            &[1],
            &mut budget,
            &mut probes,
            |c| format!("c{c}"),
            |_, _| 1.0,
            |&c, _| {
                if c == 2 {
                    Ok(None) // invalid
                } else {
                    Ok(Some(ProbeOutcome { objective: c as f64, cost_s: 1.0 }))
                }
            },
        )
        .unwrap();
        assert_eq!(cands[w.unwrap().0], 3);
        assert_eq!(probes.len(), 2);
        assert_eq!(budget.spent_s, 2.0);
    }

    #[test]
    fn pruned_grid_is_ranked_and_prunes() {
        let (b, c) = at();
        let space = SyncSpace::default();
        let (points, pruned) = pruned_layout_grid(&b, &c, GmiBackend::Mps, 4, &space);
        assert!(!points.is_empty());
        // Best-first by projected throughput.
        for w in points.windows(2) {
            assert!(w[0].2 >= w[1].2);
        }
        // The full grid was NOT kept: saturation/runnable pruning bit.
        assert!(points.len() + pruned <= space.gmi_per_gpu.len() * space.num_env.len());
        assert!(pruned > 0, "expected some pruning on the default space");
    }

    #[test]
    fn admission_tuning_charges_within_budget_and_picks_candidate() {
        let (b, c) = at();
        let topo = Topology::dgx_a100(1);
        let members: Vec<GmiSpec> = (0..2)
            .map(|i| GmiSpec {
                id: 100 + i, // deliberately non-contiguous: the mirror re-ids
                gpu: 0,
                sm_share: 0.25,
                mem_gib: 4.0,
                backend: GmiBackend::Mps,
                role: Role::Holistic,
                num_env: 512,
            })
            .collect();
        let tr = AdmissionTune { minibatches: vec![1, 2, 4], budget_frac: 0.05, probe_iters: 2 };
        let r =
            tune_admission_minibatches(&topo, &members, &b, &c, 400, b.horizon, 4, &tr).unwrap();
        assert!(!r.fallback, "5% of 400 iterations funds probes");
        assert!([1, 2, 4].contains(&r.choice));
        assert!(r.probe_cost_s <= r.budget_s + 1e-9);
        assert!(!r.probes.is_empty());
        // Deterministic run-to-run.
        let r2 =
            tune_admission_minibatches(&topo, &members, &b, &c, 400, b.horizon, 4, &tr).unwrap();
        assert_eq!(r, r2);
    }
}
