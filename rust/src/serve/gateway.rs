//! The serving gateway: admission control + dynamic batching over an
//! open-loop arrival trace, executed on the engine/fabric substrate.
//!
//! One gateway fronts one serving fleet (the layout's rollout GMIs). For
//! every arrival it either admits the request into the batching queue or
//! rejects it (admission control bounds outstanding work); a batch
//! dispatches when it reaches `max_batch` requests or the oldest queued
//! request has waited `max_wait_s` — the classic dynamic-batching policy.
//! A dispatched batch becomes engine events on the least-loaded serving
//! executor:
//!
//! 1. the request payload hops onto the GMI through its GPU's host path
//!    (a [`fabric`](crate::fabric) plan — contended links serialize, so
//!    co-resident GMIs queue behind each other's transfers),
//! 2. [`OpKind::PolicyFwd`] is charged **at the batched size** (batching
//!    amortizes the per-op launch overhead, the §4.2 incentive), and
//! 3. the response payload hops back.
//!
//! Per-request latency is the gap between trace arrival and response
//! completion. With [`GatewayConfig::autoscale`] set, every
//! [`AutoscaleConfig::window_s`] of arrivals the window's p99 drives the
//! SLO-aware [`Autoscaler`] (grow on violation, shrink on comfortable
//! clearance) through the engine's validated `add_gmi` / `resize_share` /
//! `remove_gmi` paths.
//!
//! The whole pipeline is deterministic: the same layout, trace, and config
//! reproduce bit-identical metrics (locked in by `tests/determinism.rs`).
//! The event loop itself lives in the steppable workload program
//! ([`workload::GatewayProgram`](crate::workload::GatewayProgram)) shared
//! with the multi-tenant scheduler; [`run_gateway`] is the thin standalone
//! driver.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::cluster::Topology;
use crate::config::BenchInfo;
use crate::drl::serving::tdg_agent_fwd;
use crate::drl::Compute;
use crate::engine::{Engine, ExecutorId, OpCharge};
use crate::fabric::{Fabric, Plan};
use crate::gmi::GmiSpec;
use crate::mapping::Layout;
use crate::metrics::{LatencyStats, RunMetrics};
use crate::vtime::{Clock, CostModel, OpKind};
use crate::workload::{run_to_completion, GatewayProgram, Workload};

use super::autoscale::ScaleEvent;
use super::traffic::{Request, TraceSource};
use super::AutoscaleConfig;

/// Gateway policy: admission control, dynamic batching, SLO target, and
/// the optional autoscaler.
#[derive(Debug, Clone, Copy)]
pub struct GatewayConfig {
    /// Largest request batch one dispatch forms.
    pub max_batch: usize,
    /// Longest a queued request waits before its partial batch dispatches.
    pub max_wait_s: f64,
    /// Admission control: maximum outstanding requests (queued +
    /// in-flight); arrivals beyond it are rejected. `None` admits all.
    pub admission_cap: Option<usize>,
    /// End-to-end latency SLO per request (drives SLO attainment).
    pub slo_s: f64,
    /// SLO-aware elastic scaling between evaluation windows.
    pub autoscale: Option<AutoscaleConfig>,
    /// Macro-request aggregation factor `K`: the gateway coalesces up to
    /// `K` consecutive arrivals into one macro-request, so fabric hops and
    /// `PolicyFwd` are charged once at the aggregate batch size while
    /// per-request latencies are still recorded individually (a member's
    /// latency runs from its own arrival to the shared completion).
    /// `K = 1` (the default) is bit-identical to no aggregation — the
    /// week-scale fast path's opt-in coarsening knob.
    pub aggregation: usize,
    /// Bound on retained per-request samples (latency windows, the served
    /// ledger, batch-size log). `None` keeps every sample (today's exact
    /// behavior); `Some(cap)` switches latency percentiles to a seeded
    /// reservoir that is exact below the cap, while mean/attainment stay
    /// exact at any cap via running accumulators. A 10^7-request day then
    /// holds O(cap) f64s per fleet instead of O(requests).
    pub sample_cap: Option<usize>,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            max_batch: 32,
            max_wait_s: 2e-3,
            admission_cap: None,
            slo_s: 30e-3,
            autoscale: None,
            aggregation: 1,
            sample_cap: None,
        }
    }
}

/// Outcome of one admitted request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServedRequest {
    pub id: usize,
    pub source: usize,
    pub arrival_s: f64,
    /// Index of the dispatch batch that carried the request.
    pub batch: usize,
    pub dispatch_s: f64,
    pub completion_s: f64,
}

impl ServedRequest {
    pub fn latency_s(&self) -> f64 {
        self.completion_s - self.arrival_s
    }
}

/// Everything one gateway run produced.
pub struct GatewayRunResult {
    pub metrics: RunMetrics,
    pub latency: LatencyStats,
    /// Admitted requests in dispatch order (batch index ascending, FIFO
    /// within a batch).
    pub served: Vec<ServedRequest>,
    pub rejected: usize,
    /// Size of every dispatched batch, in dispatch order.
    pub batch_sizes: Vec<usize>,
    /// Applied scale steps (empty without an autoscaler).
    pub scale_events: Vec<ScaleEvent>,
    /// The live fleet provisioning at the end of the run (autoscaled runs
    /// may differ from the input layout).
    pub final_fleet: Vec<GmiSpec>,
}

impl GatewayRunResult {
    /// `(batch size, dispatch count)` pairs, ascending by size.
    pub fn batch_histogram(&self) -> Vec<(usize, usize)> {
        let mut hist: BTreeMap<usize, usize> = BTreeMap::new();
        for &b in &self.batch_sizes {
            *hist.entry(b).or_insert(0) += 1;
        }
        hist.into_iter().collect()
    }
}

/// Per-request gateway request payload: the observation in (Table 4's S).
fn request_bytes(bench: &BenchInfo) -> usize {
    4 * bench.obs_dim
}

/// Per-request gateway response payload: action + value out (A + W).
fn response_bytes(bench: &BenchInfo) -> usize {
    4 * (bench.act_dim + 1)
}

/// Serial end-to-end seconds of one `batch`-request dispatch on a
/// share-`share` GMI: request hop + batched forward + response hop, using
/// exactly the payload sizes and charging model [`run_gateway`] applies.
/// `batch / batch_seconds(..)` is a sustainable per-GMI request rate — the
/// capacity yardstick tests and examples derive offered-load levels from,
/// kept here so it cannot drift from the gateway's own cost model.
pub fn batch_seconds(
    bench: &BenchInfo,
    cost: &CostModel,
    topo: &Topology,
    share: f64,
    batch: usize,
) -> f64 {
    // An intra-GPU plan is a single host-path hop whose total time IS
    // `host_transfer_time`, so the hop costs are computed directly from
    // the topology — no Fabric construction (and no topology clone) per
    // capacity query. Bit-identical to executing the plans.
    let req = topo.host_transfer_time(batch * request_bytes(bench), 1);
    let resp = topo.host_transfer_time(batch * response_bytes(bench), 1);
    let fwd = cost.op_time(OpKind::PolicyFwd { num_env: batch }, share, 1.0);
    req + fwd + resp
}

/// Least-loaded executor of `active`: earliest clock, ties to the first.
/// The dispatch target rule shared by the gateway and the multi-tenant
/// scheduler's serving stepper.
pub fn least_loaded(engine: &Engine, active: &[ExecutorId]) -> ExecutorId {
    let mut ex = active[0];
    for &e in &active[1..] {
        if engine.clock(e).seconds() < engine.clock(ex).seconds() {
            ex = e;
        }
    }
    ex
}

/// Execute one `n`-request dispatch at virtual time `t` on executor `ex`
/// as engine events — request payload hop onto the GMI through its GPU's
/// host path, `PolicyFwd` charged at the batched size, response hop back —
/// and return the completion clock. The single place the serving dispatch
/// cost model lives: the gateway's batcher and the multi-tenant
/// scheduler's serving stepper both charge through it.
#[allow(clippy::too_many_arguments)]
pub fn execute_dispatch(
    engine: &mut Engine,
    fabric: &mut Fabric,
    cost: &CostModel,
    bench: &BenchInfo,
    ex: ExecutorId,
    t: f64,
    n: usize,
    dedicated: bool,
) -> Clock {
    let mut plans = DispatchPlans::default();
    execute_dispatch_pooled(engine, fabric, cost, bench, ex, t, n, dedicated, &mut plans)
}

/// Reusable request/response plan buffers for [`execute_dispatch_pooled`]:
/// one pair per gateway program, rewritten in place on every dispatch so
/// the steady-state dispatch path allocates nothing.
#[derive(Debug, Clone, Default)]
pub struct DispatchPlans {
    req: Plan,
    resp: Plan,
}

impl DispatchPlans {
    /// Step-buffer capacities of the two pooled plans (no-realloc
    /// introspection for the capacity regression test).
    #[doc(hidden)]
    pub fn step_caps(&self) -> (usize, usize) {
        (self.req.steps.capacity(), self.resp.steps.capacity())
    }

    /// Drop the buffered hops (keeping the allocations) — called whenever
    /// the fleet the plans were built against changes (autoscale shrink,
    /// GMI death, re-placement), so the in-place single-hop reuse path can
    /// never replay a hop over a link that no longer serves the fleet.
    pub fn clear(&mut self) {
        self.req.steps.clear();
        self.resp.steps.clear();
    }

    /// Whether both pooled plans still route over in-service links.
    pub fn valid_for(&self, fabric: &Fabric) -> bool {
        fabric.plan_valid(&self.req) && fabric.plan_valid(&self.resp)
    }
}

/// [`execute_dispatch`] writing its two transfer plans into caller-owned
/// buffers instead of allocating fresh ones per dispatch. The plans carry
/// identical durations and link uses, so every charged clock is
/// bit-identical to the allocating path.
#[allow(clippy::too_many_arguments)]
pub fn execute_dispatch_pooled(
    engine: &mut Engine,
    fabric: &mut Fabric,
    cost: &CostModel,
    bench: &BenchInfo,
    ex: ExecutorId,
    t: f64,
    n: usize,
    dedicated: bool,
    plans: &mut DispatchPlans,
) -> Clock {
    let gpu = engine.gpu(ex);
    let sharing = engine.co_resident(ex).max(1);
    fabric.plan_intra_gpu_into(n * request_bytes(bench), sharing, gpu, &mut plans.req);
    engine.recv_plan(fabric, ex, Clock(t), &plans.req);
    let fwd = if dedicated {
        tdg_agent_fwd(n, engine.share(ex))
    } else {
        OpCharge::recorded(OpKind::PolicyFwd { num_env: n })
    };
    engine.charge_steps(cost, ex, 1.0, &[fwd], 0.0);
    fabric.plan_intra_gpu_into(n * response_bytes(bench), sharing, gpu, &mut plans.resp);
    let after_fwd = engine.clock(ex);
    engine.recv_plan(fabric, ex, after_fwd, &plans.resp)
}

/// Run the gateway over an arrival trace (ascending `arrival_s`). The
/// layout's rollout GMIs form the initial serving fleet; the event loop
/// itself is the shared [`GatewayProgram`].
pub fn run_gateway(
    layout: &Layout,
    bench: &BenchInfo,
    cost: &CostModel,
    trace: &[Request],
    cfg: &GatewayConfig,
) -> Result<GatewayRunResult> {
    // The trace is copied ONCE here into the shared `Arc<[Request]>` the
    // program (and any scheduler job) borrows from.
    run_gateway_source(layout, bench, cost, TraceSource::from(trace), cfg)
}

/// [`run_gateway`] over a [`TraceSource`] — the week-scale entry point: a
/// streaming source never materializes the trace, so arrival memory stays
/// O(chunk) regardless of run length. Bit-identical to [`run_gateway`] on
/// the equivalent materialized trace.
pub fn run_gateway_source(
    layout: &Layout,
    bench: &BenchInfo,
    cost: &CostModel,
    trace: TraceSource,
    cfg: &GatewayConfig,
) -> Result<GatewayRunResult> {
    anyhow::ensure!(!layout.rollout_gmis.is_empty(), "no serving GMIs in layout");
    anyhow::ensure!(cfg.max_batch >= 1, "max_batch must be at least 1");
    anyhow::ensure!(cfg.aggregation >= 1, "aggregation must be at least 1");
    anyhow::ensure!(
        cfg.max_wait_s >= 0.0 && cfg.max_wait_s.is_finite(),
        "max_wait_s must be finite and non-negative"
    );

    let mut engine = Engine::new(&layout.manager, cost);
    let mut fabric = Fabric::single_node(layout.manager.topology().clone());
    let active = engine.add_group(&layout.rollout_gmis)?;

    let mut program = GatewayProgram::new(*cfg, trace);
    program.bind(&engine, &mut fabric, bench, &active)?;
    // The gateway charges no numerics, but the step contract carries a
    // backend; Null is the no-op choice.
    run_to_completion(&mut program, &mut engine, &mut fabric, cost, bench, &Compute::Null)?;

    let metrics = program.finish(&engine, &fabric);
    let latency = metrics.latency.clone().expect("gateway metrics carry latency");
    let final_fleet = engine.manager().all().cloned().collect();
    Ok(GatewayRunResult {
        metrics,
        latency,
        served: program.take_served(),
        rejected: program.rejected(),
        batch_sizes: program.take_batch_sizes(),
        scale_events: program.take_scale_events(),
        final_fleet,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::config::static_registry;
    use crate::mapping::build_gateway_fleet;
    use crate::serve::traffic::{generate_trace, TrafficPattern};

    fn setup() -> (Layout, BenchInfo, CostModel) {
        let b = static_registry()["AT"].clone();
        let cost = CostModel::new(&b);
        let topo = Topology::dgx_a100(1);
        let layout = build_gateway_fleet(&topo, 2, 4, 32, &cost, None).unwrap();
        (layout, b, cost)
    }

    #[test]
    fn serves_every_admitted_request_exactly_once() {
        let (layout, b, cost) = setup();
        let trace =
            generate_trace(&TrafficPattern::Poisson { rate: 5000.0 }, 0.2, 9, 4);
        let cfg = GatewayConfig { max_batch: 16, max_wait_s: 1e-3, ..Default::default() };
        let r = run_gateway(&layout, &b, &cost, &trace, &cfg).unwrap();
        assert_eq!(r.served.len() + r.rejected, trace.len());
        assert_eq!(r.rejected, 0, "no cap -> no rejections");
        let mut ids: Vec<usize> = r.served.iter().map(|s| s.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), trace.len(), "request served twice or dropped");
        // Every completion is after its arrival and batches respect the cap.
        for s in &r.served {
            assert!(s.completion_s > s.arrival_s);
        }
        assert!(r.batch_sizes.iter().all(|&b| b >= 1 && b <= 16));
        assert_eq!(
            r.batch_sizes.iter().sum::<usize>(),
            r.served.len(),
            "batch sizes must partition the served requests"
        );
        // The latency table is surfaced through RunMetrics.
        let l = r.metrics.latency.as_ref().unwrap();
        assert_eq!(l.served, r.served.len());
        assert!(l.p99_s >= l.p95_s && l.p95_s >= l.p50_s);
        assert!(l.p50_s > 0.0);
        // Gateway hops ride the fabric: comm time and link traffic exist.
        assert!(r.metrics.comm_s > 0.0);
        assert!(!r.metrics.links.is_empty());
    }

    #[test]
    fn admission_cap_rejects_overload() {
        let (layout, b, cost) = setup();
        // Far beyond fleet capacity: outstanding work piles up.
        let trace =
            generate_trace(&TrafficPattern::Constant { rate: 200_000.0 }, 0.05, 1, 4);
        let capped = GatewayConfig {
            max_batch: 16,
            max_wait_s: 1e-3,
            admission_cap: Some(64),
            ..Default::default()
        };
        let r = run_gateway(&layout, &b, &cost, &trace, &capped).unwrap();
        assert!(r.rejected > 0, "overload under a cap must reject");
        assert!(r.latency.max_queue_depth <= 64);
        assert_eq!(r.served.len() + r.rejected, trace.len());
        // Uncapped: everything is admitted, the queue grows past the cap.
        let open = GatewayConfig { admission_cap: None, ..capped };
        let r2 = run_gateway(&layout, &b, &cost, &trace, &open).unwrap();
        assert_eq!(r2.rejected, 0);
        assert!(r2.latency.max_queue_depth > 64);
    }

    /// Regression (zero-completions window): a run in which nothing is
    /// ever served — every arrival rejected by admission control — must
    /// still yield a fully defined, NaN-free latency report, and an
    /// autoscaler evaluating the resulting empty windows must treat them
    /// as no-signal instead of a perfect p99.
    #[test]
    fn zero_completion_window_reports_are_nan_free() {
        let (layout, b, cost) = setup();
        let trace =
            generate_trace(&TrafficPattern::Constant { rate: 5000.0 }, 0.05, 3, 4);
        let starved = GatewayConfig {
            max_batch: 16,
            max_wait_s: 1e-3,
            admission_cap: Some(0),
            autoscale: Some(crate::serve::AutoscaleConfig::default()),
            ..Default::default()
        };
        let r = run_gateway(&layout, &b, &cost, &trace, &starved).unwrap();
        assert_eq!(r.served.len(), 0, "cap 0 must starve the fleet");
        assert_eq!(r.rejected, trace.len());
        let l = &r.latency;
        assert_eq!(l.served, 0);
        assert_eq!((l.p50_s, l.p95_s, l.p99_s, l.mean_s), (0.0, 0.0, 0.0, 0.0));
        assert_eq!(l.attainment, 0.0, "every rejection is an SLO miss");
        assert_eq!(l.mean_batch, 0.0);
        for v in [
            l.p50_s,
            l.p95_s,
            l.p99_s,
            l.mean_s,
            l.attainment,
            l.mean_batch,
            r.metrics.steps_per_sec,
            r.metrics.span_s,
        ] {
            assert!(v.is_finite(), "zero-completion stat is not finite: {v}");
        }
        // Zero dispatches is no autoscale signal: the starved fleet must
        // not have scaled in either direction.
        assert!(r.scale_events.is_empty(), "empty windows must not drive scaling");
        // The rendered table carries no NaN artifacts.
        let rendered = crate::metrics::report::latency_table(l).render();
        assert!(!rendered.contains("NaN"), "{rendered}");
    }

    #[test]
    fn partial_batches_dispatch_at_the_wait_deadline() {
        let (layout, b, cost) = setup();
        // 10 req/s with a 1 ms wait: every batch times out at size 1.
        let trace = generate_trace(&TrafficPattern::Constant { rate: 10.0 }, 0.5, 1, 1);
        let cfg = GatewayConfig { max_batch: 32, max_wait_s: 1e-3, ..Default::default() };
        let r = run_gateway(&layout, &b, &cost, &trace, &cfg).unwrap();
        assert!(r.batch_sizes.iter().all(|&n| n == 1));
        for s in &r.served {
            assert!((s.dispatch_s - s.arrival_s - 1e-3).abs() < 1e-12);
        }
        // And the batch histogram reflects it.
        assert_eq!(r.batch_histogram(), vec![(1, trace.len())]);
    }

    /// Regression (stale pooled dispatch plans across a topology change):
    /// the pooled request/response `Plan` pair outlives membership
    /// changes, so after a fleet shrink its hops can reference a GPU the
    /// fleet no longer serves from — and on a degraded fabric, a dead
    /// GPU's host path. `valid_for` must flag such plans, re-`bind` with
    /// a changed fleet must clear them (keeping capacity), and a
    /// shrink-then-dispatch run must complete without ever charging the
    /// dead GPU's host link again.
    #[test]
    fn shrink_then_dispatch_never_replays_stale_pooled_hops() {
        let b = static_registry()["AT"].clone();
        let cost = CostModel::new(&b);
        let topo = Topology::dgx_a100(2);

        // Direct invariant: a plan pair pooled for GPU 1 goes invalid the
        // moment GPU 1 dies, and clearing restores validity without
        // shrinking the pooled step buffers.
        let mut fabric = Fabric::single_node(topo.clone());
        let mut plans = DispatchPlans::default();
        fabric.plan_intra_gpu_into(4096, 1, 1, &mut plans.req);
        fabric.plan_intra_gpu_into(4096, 1, 1, &mut plans.resp);
        assert!(plans.valid_for(&fabric));
        fabric.fail_gpu(1);
        assert!(
            !plans.valid_for(&fabric),
            "pooled hops over a dead GPU's host path must read invalid"
        );
        let caps = plans.step_caps();
        plans.clear();
        assert!(plans.valid_for(&fabric), "cleared plans are trivially valid");
        assert_eq!(plans.step_caps(), caps, "clear keeps pooled capacity");

        // End to end: dispatch on both GPUs, kill GPU 1 and shrink the
        // fleet to GPU 0's member, keep dispatching. The fabric's
        // failed-link execution guard panics on any stale replay, and GPU
        // 1's host link must see no traffic after the shrink.
        let fleet = build_gateway_fleet(&topo, 1, 4, 16, &cost, None).unwrap();
        let mut engine = crate::engine::Engine::new(&fleet.manager, &cost);
        let mut fabric = Fabric::single_node(fleet.manager.topology().clone());
        let active = engine.add_group(&fleet.rollout_gmis).unwrap();
        assert_eq!(active.len(), 2);
        let trace =
            generate_trace(&TrafficPattern::Constant { rate: 3000.0 }, 0.2, 5, 4);
        let cfg = GatewayConfig { max_batch: 16, max_wait_s: 1e-3, ..Default::default() };
        let mut program = crate::workload::GatewayProgram::new(cfg, &trace);
        use crate::workload::Workload as _;
        program.bind(&engine, &mut fabric, &b, &active).unwrap();
        let compute = crate::drl::Compute::Null;
        let quantum = 5e-3;
        let mut round = 0usize;
        let step = |program: &mut crate::workload::GatewayProgram,
                    engine: &mut crate::engine::Engine,
                    fabric: &mut Fabric,
                    round: usize| {
            let mut ctx = crate::workload::StepCtx {
                engine,
                fabric,
                cost: &cost,
                bench: &b,
                compute: &compute,
                horizon_s: (round + 1) as f64 * quantum,
            };
            program.step(&mut ctx).unwrap()
        };
        for _ in 0..10 {
            step(&mut program, &mut engine, &mut fabric, round);
            round += 1;
        }
        let gpu1_bytes = |fabric: &Fabric| {
            fabric
                .link_report()
                .iter()
                .find(|l| l.name == "host:gpu1")
                .map(|l| l.bytes)
                .unwrap_or(0)
        };
        let before = gpu1_bytes(&fabric);
        assert!(before > 0, "warmup never dispatched on GPU 1");
        fabric.fail_gpu(1);
        let survivors: Vec<_> =
            active.iter().copied().filter(|&ex| engine.gpu(ex) == 0).collect();
        assert_eq!(survivors.len(), 1);
        program.bind(&engine, &mut fabric, &b, &survivors).unwrap();
        loop {
            if step(&mut program, &mut engine, &mut fabric, round)
                == crate::workload::StepOutcome::Done
            {
                break;
            }
            round += 1;
            assert!(round < 10_000, "run never drained");
        }
        assert_eq!(
            gpu1_bytes(&fabric),
            before,
            "a pooled plan replayed a hop over the dead GPU's host path"
        );
    }

    #[test]
    fn batching_amortizes_latency_under_load() {
        // At a rate that keeps batches full, max_batch=16 must beat
        // max_batch=1 on p99: the launch overhead amortizes.
        let (layout, b, cost) = setup();
        let trace =
            generate_trace(&TrafficPattern::Constant { rate: 20_000.0 }, 0.1, 1, 4);
        let mk = |mb: usize| GatewayConfig {
            max_batch: mb,
            max_wait_s: 5e-4,
            ..Default::default()
        };
        let batched = run_gateway(&layout, &b, &cost, &trace, &mk(16)).unwrap();
        let single = run_gateway(&layout, &b, &cost, &trace, &mk(1)).unwrap();
        assert!(
            batched.latency.p99_s < single.latency.p99_s,
            "batched {} !< single {}",
            batched.latency.p99_s,
            single.latency.p99_s
        );
    }
}
