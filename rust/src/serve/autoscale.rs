//! SLO-aware elastic fleet scaling — JigsawRL-style resource re-assembly
//! on the paper's resource-adjustable GMIs.
//!
//! Between evaluation windows the [`Autoscaler`] looks at the p99 latency
//! of the requests the gateway dispatched during the window and drives the
//! engine's validated provisioning paths:
//!
//! * **grow** (window p99 violates the SLO): register a new fleet GMI on
//!   the GPU with the most free SM share ([`Engine::add_gmi`] →
//!   `GmiManager::add_gmi` validation), or — when every GPU is at its
//!   member cap — widen the smallest active GMI into the leftover share
//!   ([`Engine::resize_share`] → `GmiManager::resize_gmi`).
//! * **shrink** (window p99 comfortably clears the SLO): resize widened
//!   GMIs back to the fleet's base share first, then retire the most
//!   recently added member ([`Engine::remove_gmi`] →
//!   `GmiManager::remove_gmi`), never dropping the fleet below
//!   `min_fleet` and never resizing a GMI below its validated floor.
//!
//! Every step goes through the manager's placement validation, so an
//! autoscaled fleet can never oversubscribe a GPU's SMs or memory — the
//! property suite drives random traffic through this loop to check exactly
//! that.

use anyhow::Result;

use crate::engine::{Engine, ExecutorId};
use crate::gmi::GmiSpec;
use crate::metrics::percentile_select;

/// Tuning knobs of the SLO-aware autoscaler.
#[derive(Debug, Clone, Copy)]
pub struct AutoscaleConfig {
    /// Evaluation window length (virtual seconds of arrival time).
    pub window_s: f64,
    /// The p99 latency target the fleet scales against.
    pub slo_p99_s: f64,
    /// Shrink when the window p99 is below `shrink_frac * slo_p99_s`.
    pub shrink_frac: f64,
    /// Never shrink the fleet below this many serving GMIs.
    pub min_fleet: usize,
    /// Never grow a GPU past this many registered GMIs.
    pub max_per_gpu: usize,
    /// Validated share floor: resize steps never drop a GMI below it.
    pub min_share: f64,
    /// Evaluation windows to skip after a scale action (hysteresis).
    pub cooldown_windows: usize,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            window_s: 0.05,
            slo_p99_s: 30e-3,
            shrink_frac: 0.35,
            min_fleet: 1,
            max_per_gpu: 8,
            min_share: 0.05,
            cooldown_windows: 0,
        }
    }
}

/// Direction of one scale step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleAction {
    /// A GMI was added, or an existing one widened into free share.
    Grow,
    /// A GMI was removed, or a widened one resized back down.
    Shrink,
}

impl std::fmt::Display for ScaleAction {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ScaleAction::Grow => "grow",
            ScaleAction::Shrink => "shrink",
        })
    }
}

/// One applied scale step (the gateway's scaling timeline).
#[derive(Debug, Clone)]
pub struct ScaleEvent {
    /// Window boundary (virtual seconds) the decision fired at.
    pub t_s: f64,
    pub action: ScaleAction,
    pub fleet_before: usize,
    pub fleet_after: usize,
    /// The window p99 that triggered the decision.
    pub p99_s: f64,
    /// Human-readable description of the applied step.
    pub detail: String,
}

/// Render a scaling timeline as a table (`t`, action, fleet size, window
/// p99, detail) — shared by the CLI's `serve --trace` path and the
/// serving-fleet example.
pub fn scale_table(events: &[ScaleEvent]) -> crate::metrics::Table {
    let mut t = crate::metrics::Table::new(&[
        "t (s)",
        "action",
        "fleet",
        "window p99 (ms)",
        "detail",
    ]);
    for e in events {
        t.row(vec![
            format!("{:.3}", e.t_s),
            e.action.to_string(),
            format!("{} -> {}", e.fleet_before, e.fleet_after),
            format!("{:.2}", e.p99_s * 1e3),
            e.detail.clone(),
        ]);
    }
    t
}

/// Watches per-window p99 latency and re-provisions the serving fleet.
#[derive(Debug)]
pub struct Autoscaler {
    cfg: AutoscaleConfig,
    /// Prototype spec cloned for new fleet members (base share, memory,
    /// backend, role, env count) — the fleet's validated floor.
    template: GmiSpec,
    next_gmi_id: usize,
    /// Executors added by scale-up, most recent last (shrink retires these
    /// first, LIFO).
    grown: Vec<ExecutorId>,
    cooldown: usize,
    /// Reusable window-latency scratch for the per-window p99 selection —
    /// grows to the largest window once, then no per-window allocation.
    scratch: Vec<f64>,
}

impl Autoscaler {
    /// Build a scaler over an engine-managed fleet; the first active GMI's
    /// spec becomes the template for scale-up members.
    pub fn new(cfg: AutoscaleConfig, engine: &Engine, active: &[ExecutorId]) -> Result<Self> {
        anyhow::ensure!(cfg.window_s > 0.0, "autoscale window must be positive");
        anyhow::ensure!(!active.is_empty(), "autoscaler needs a non-empty fleet");
        anyhow::ensure!(
            cfg.min_fleet >= 1,
            "min_fleet must be at least 1 (an empty fleet cannot serve)"
        );
        let first = engine.gmi_of(active[0]);
        let template = engine
            .manager()
            .gmi(first)
            .ok_or_else(|| anyhow::anyhow!("fleet GMI {first} not registered"))?
            .clone();
        let next_gmi_id = engine.manager().all().map(|g| g.id).max().unwrap_or(0) + 1;
        Ok(Autoscaler {
            cfg,
            template,
            next_gmi_id,
            grown: Vec::new(),
            cooldown: 0,
            scratch: Vec::new(),
        })
    }

    pub fn window_s(&self) -> f64 {
        self.cfg.window_s
    }

    /// Evaluate one window: `window_lat` holds the latencies of every
    /// request dispatched during it (unsorted). Applies at most one scale
    /// step and returns it.
    pub fn evaluate(
        &mut self,
        t: f64,
        engine: &mut Engine,
        active: &mut Vec<ExecutorId>,
        window_lat: &[f64],
    ) -> Option<ScaleEvent> {
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return None;
        }
        if window_lat.is_empty() {
            // Zero dispatches is NO signal, not a great p99: an idle fleet
            // is indistinguishable here from one starved by admission
            // control under total overload (rejected arrivals never
            // dispatch), and shrinking in the latter case would scale down
            // exactly when the SLO is violated hardest.
            return None;
        }
        self.scratch.clear();
        self.scratch.extend_from_slice(window_lat);
        let p99 = percentile_select(&mut self.scratch, 0.99);
        let before = active.len();
        let ev = if p99 > self.cfg.slo_p99_s {
            self.grow(engine, active).map(|detail| ScaleEvent {
                t_s: t,
                action: ScaleAction::Grow,
                fleet_before: before,
                fleet_after: active.len(),
                p99_s: p99,
                detail,
            })
        } else if p99 < self.cfg.shrink_frac * self.cfg.slo_p99_s {
            // No fleet-size gate here: shrink() narrows widened members
            // first (count-neutral, legal even at min_fleet) and enforces
            // the min_fleet floor itself before removing anyone.
            self.shrink(engine, active).map(|detail| ScaleEvent {
                t_s: t,
                action: ScaleAction::Shrink,
                fleet_before: before,
                fleet_after: active.len(),
                p99_s: p99,
                detail,
            })
        } else {
            None
        };
        if ev.is_some() {
            self.cooldown = self.cfg.cooldown_windows;
        }
        ev
    }

    /// Free SM share and registered-GMI count of one GPU, per the engine's
    /// live manager.
    fn gpu_room(engine: &Engine, gpu: usize) -> (f64, usize) {
        let mut used = 0.0f64;
        let mut count = 0usize;
        for g in engine.manager().all() {
            if g.gpu == gpu {
                used += g.sm_share;
                count += 1;
            }
        }
        ((1.0 - used).max(0.0), count)
    }

    fn grow(&mut self, engine: &mut Engine, active: &mut Vec<ExecutorId>) -> Option<String> {
        let want = self.template.sm_share;
        // Prefer a whole new member on the GPU with the most free share.
        let mut best: Option<(usize, f64)> = None;
        for gpu in 0..engine.topology().num_gpus() {
            let (free, count) = Self::gpu_room(engine, gpu);
            if count < self.cfg.max_per_gpu && free + 1e-9 >= want {
                let better = match best {
                    None => true,
                    Some((_, f)) => free > f,
                };
                if better {
                    best = Some((gpu, free));
                }
            }
        }
        if let Some((gpu, _)) = best {
            let mut spec = self.template.clone();
            spec.id = self.next_gmi_id;
            spec.gpu = gpu;
            if let Ok(ex) = engine.add_gmi(spec) {
                self.next_gmi_id += 1;
                active.push(ex);
                self.grown.push(ex);
                return Some(format!("add GMI on gpu{gpu}"));
            }
        }
        // No room for a whole member: widen the smallest active GMI into
        // whatever share its GPU has left (validated resize).
        let mut target: Option<(ExecutorId, f64, f64)> = None;
        for &ex in active.iter() {
            let gmi = engine.gmi_of(ex);
            let Some(spec) = engine.manager().gmi(gmi) else { continue };
            let (free, _) = Self::gpu_room(engine, spec.gpu);
            if free < 0.01 {
                continue;
            }
            let better = match target {
                None => true,
                Some((_, share, _)) => spec.sm_share < share,
            };
            if better {
                target = Some((ex, spec.sm_share, free));
            }
        }
        let (ex, cur, free) = target?;
        let gmi = engine.gmi_of(ex);
        let new_share = (cur + free).min(1.0);
        match engine.resize_share(gmi, new_share) {
            Ok(()) => Some(format!("widen GMI {gmi} {cur:.2} -> {new_share:.2}")),
            Err(_) => None,
        }
    }

    fn shrink(&mut self, engine: &mut Engine, active: &mut Vec<ExecutorId>) -> Option<String> {
        // First undo any widening: resize back to the fleet's base share
        // (never below the validated floor).
        let base = self.template.sm_share.max(self.cfg.min_share);
        for &ex in active.iter() {
            let gmi = engine.gmi_of(ex);
            let Some(spec) = engine.manager().gmi(gmi) else { continue };
            if spec.sm_share > base + 1e-9 {
                let cur = spec.sm_share;
                if engine.resize_share(gmi, base).is_ok() {
                    return Some(format!("narrow GMI {gmi} {cur:.2} -> {base:.2}"));
                }
            }
        }
        // Then retire a member: most recently grown first, else the
        // highest-indexed active member.
        if active.len() <= self.cfg.min_fleet {
            return None;
        }
        let ex = match self.grown.pop() {
            Some(e) if active.contains(&e) => e,
            _ => *active.last()?,
        };
        self.grown.retain(|&e| e != ex);
        let gmi = engine.gmi_of(ex);
        match engine.remove_gmi(gmi) {
            Ok(_) => {
                active.retain(|&e| e != ex);
                Some(format!("remove GMI {gmi}"))
            }
            Err(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::config::static_registry;
    use crate::gmi::{GmiBackend, GmiManager, Role};
    use crate::vtime::CostModel;

    fn fleet(gpus: usize, members_per_gpu: usize, share: f64) -> (Engine, Vec<ExecutorId>) {
        let b = static_registry()["AT"].clone();
        let cost = CostModel::new(&b);
        let mut m = GmiManager::new(Topology::dgx_a100(gpus));
        let mut id = 0usize;
        for gpu in 0..gpus {
            for _ in 0..members_per_gpu {
                m.add_gmi(GmiSpec {
                    id,
                    gpu,
                    sm_share: share,
                    mem_gib: 2.0,
                    backend: GmiBackend::Mps,
                    role: Role::SimAgent,
                    num_env: 64,
                })
                .unwrap();
                id += 1;
            }
        }
        let mut e = Engine::new(&m, &cost);
        let ids = e.add_group(&(0..id).collect::<Vec<_>>()).unwrap();
        (e, ids)
    }

    #[test]
    fn violating_p99_grows_and_clearing_p99_shrinks() {
        let (mut e, ids) = fleet(1, 2, 0.25);
        let mut active = ids.clone();
        let cfg = AutoscaleConfig {
            window_s: 0.1,
            slo_p99_s: 10e-3,
            min_fleet: 2,
            max_per_gpu: 4,
            ..Default::default()
        };
        let mut s = Autoscaler::new(cfg, &e, &active).unwrap();
        // SLO violated: one member added.
        let ev = s.evaluate(0.1, &mut e, &mut active, &[0.05, 0.06, 0.07]).unwrap();
        assert_eq!(ev.action, ScaleAction::Grow);
        assert_eq!(ev.fleet_before, 2);
        assert_eq!(ev.fleet_after, 3);
        assert_eq!(e.manager().len(), 3);
        // Comfortably under: the grown member is retired again (LIFO).
        let ev = s.evaluate(0.2, &mut e, &mut active, &[1e-4, 2e-4]).unwrap();
        assert_eq!(ev.action, ScaleAction::Shrink);
        assert_eq!(active.len(), 2);
        assert_eq!(e.manager().len(), 2);
        // At the floor: no further shrink.
        assert!(s.evaluate(0.3, &mut e, &mut active, &[1e-4]).is_none());
        assert_eq!(active.len(), 2);
    }

    #[test]
    fn grow_widens_when_member_cap_is_reached() {
        // One GPU, cap 2, but only 0.6 of the GPU allocated: growth has to
        // come from widening, and a later shrink narrows back to base EVEN
        // at the min_fleet floor (narrowing is count-neutral).
        let (mut e, ids) = fleet(1, 2, 0.3);
        let mut active = ids.clone();
        let cfg = AutoscaleConfig {
            window_s: 0.1,
            slo_p99_s: 10e-3,
            min_fleet: 2,
            max_per_gpu: 2,
            ..Default::default()
        };
        let mut s = Autoscaler::new(cfg, &e, &active).unwrap();
        let ev = s.evaluate(0.1, &mut e, &mut active, &[0.05]).unwrap();
        assert_eq!(ev.action, ScaleAction::Grow);
        assert_eq!(active.len(), 2, "widening adds no member");
        let total: f64 = e.manager().all().map(|g| g.sm_share).sum();
        assert!(total <= 1.0 + 1e-9);
        assert!(total > 0.6 + 1e-9, "no share was actually grown");
        // Shrink narrows the widened member back before removing anything
        // (and despite the fleet sitting at min_fleet).
        let ev = s.evaluate(0.2, &mut e, &mut active, &[1e-4]).unwrap();
        assert_eq!(ev.action, ScaleAction::Shrink);
        assert_eq!(active.len(), 2);
        for g in e.manager().all() {
            assert!((g.sm_share - 0.3).abs() < 1e-9);
        }
        // Fully narrowed and at the floor: no further shrink events.
        assert!(s.evaluate(0.3, &mut e, &mut active, &[1e-4]).is_none());
        assert_eq!(active.len(), 2);
        // And a zero min_fleet is rejected outright.
        let bad = AutoscaleConfig { min_fleet: 0, ..Default::default() };
        assert!(Autoscaler::new(bad, &e, &active).is_err());
    }

    #[test]
    fn cooldown_suppresses_consecutive_actions() {
        let (mut e, ids) = fleet(1, 1, 0.2);
        let mut active = ids.clone();
        let cfg = AutoscaleConfig {
            window_s: 0.1,
            slo_p99_s: 10e-3,
            min_fleet: 1,
            max_per_gpu: 8,
            cooldown_windows: 2,
            ..Default::default()
        };
        let mut s = Autoscaler::new(cfg, &e, &active).unwrap();
        assert!(s.evaluate(0.1, &mut e, &mut active, &[0.05]).is_some());
        assert!(s.evaluate(0.2, &mut e, &mut active, &[0.05]).is_none());
        assert!(s.evaluate(0.3, &mut e, &mut active, &[0.05]).is_none());
        assert!(s.evaluate(0.4, &mut e, &mut active, &[0.05]).is_some());
        assert_eq!(active.len(), 3);
    }
}
