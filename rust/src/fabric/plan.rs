//! The collective planner: lowers AllReduce requests into per-link
//! transfer plans (paper §4.1 Figure 4, Table 2; §8 multi-node hierarchy).
//!
//! Every reduction strategy becomes the same shape of object — a [`Plan`]:
//! sequential phases, each occupying a set of links for a duration. One
//! cost model (the calibrated link constants in [`cluster`](crate::cluster)
//! plus the banned-elsewhere latency/CPU constants) prices every strategy,
//! so "select a strategy" is simply "pick the cheapest valid plan"
//! ([`Fabric::cheapest_allreduce`]) — validated against the paper's
//! Algorithm 1 heuristic by the fabric property tests.

use anyhow::{bail, Result};

use super::link::LinkId;
use super::Fabric;
use crate::cluster::{CPU_REDUCE_BW, HOST_LAT, IB_BW, NCCL_LAT};

/// The three single-node reduction strategies of Figure 4.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceStrategy {
    MultiProcess,
    MultiRing,
    Hierarchical,
}

impl std::fmt::Display for ReduceStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            ReduceStrategy::MultiProcess => "MPR",
            ReduceStrategy::MultiRing => "MRR",
            ReduceStrategy::Hierarchical => "HAR",
        };
        f.write_str(s)
    }
}

/// One link's share of a plan phase.
#[derive(Debug, Clone)]
pub struct LinkUse {
    pub link: LinkId,
    /// Seconds of busy time attributed to the link.
    pub busy_s: f64,
    /// Payload bytes attributed to the link.
    pub bytes: u64,
}

/// One sequential phase of a plan: the links it occupies and how long the
/// phase takes (links within a phase run in parallel; the phase ends when
/// the slowest finishes, which is what `dur` encodes).
#[derive(Debug, Clone)]
pub struct PlanStep {
    pub dur: f64,
    pub uses: Vec<LinkUse>,
}

/// A lowered transfer schedule: sequential [`PlanStep`]s. Pure data — the
/// fabric's `execute` turns it into virtual time and link occupancy.
#[derive(Debug, Clone, Default)]
pub struct Plan {
    pub steps: Vec<PlanStep>,
}

impl Plan {
    pub fn new() -> Self {
        Plan { steps: Vec::new() }
    }

    pub fn push_step(&mut self, step: PlanStep) {
        self.steps.push(step);
    }

    /// Rewrite this plan in place as a single-hop plan (one phase, one
    /// link), reusing the existing step/use storage when the shape already
    /// matches — the pooled hot path for per-dispatch gateway hops, which
    /// would otherwise allocate a fresh `Plan` per event.
    pub fn reuse_single_hop(&mut self, link: LinkId, dur: f64, bytes: u64) {
        if let [step] = self.steps.as_mut_slice() {
            if let [u] = step.uses.as_mut_slice() {
                step.dur = dur;
                *u = LinkUse { link, busy_s: dur, bytes };
                return;
            }
        }
        self.steps.clear();
        self.steps.push(PlanStep { dur, uses: vec![LinkUse { link, busy_s: dur, bytes }] });
    }

    /// Uncontended duration of the plan (sum of phase durations) — the
    /// planning-time cost used for strategy comparison.
    pub fn total_s(&self) -> f64 {
        self.steps.iter().map(|s| s.dur).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }
}

/// Extra span a baseline pays for unfused per-tensor collective launches:
/// `n_tensors - 1` additional ring launches of `2(g-1)` steps each (the
/// fused op's launches are already in the engine-charged ring time).
pub fn unfused_ring_launch_extra(g: usize, n_tensors: usize) -> f64 {
    if g <= 1 || n_tensors <= 1 {
        return 0.0;
    }
    (n_tensors as f64 - 1.0) * NCCL_LAT * 2.0 * (g as f64 - 1.0)
}

impl Fabric {
    /// Lower an allreduce over the GMI mapping list `mpl` (one inner vec of
    /// GMI ids per GPU) into a per-link plan under `strategy`. Fails for
    /// strategies the layout cannot execute (MRR with unequal per-GPU
    /// counts or `t > g` — the "multiple CUDA streams" constraint).
    pub fn plan_allreduce(
        &self,
        mpl: &[Vec<usize>],
        bytes: usize,
        strategy: ReduceStrategy,
    ) -> Result<Plan> {
        if mpl.is_empty() || mpl.iter().any(|v| v.is_empty()) {
            bail!("empty GMI mapping list");
        }
        let total: usize = mpl.iter().map(|v| v.len()).sum();
        if total <= 1 {
            return Ok(Plan::new());
        }
        let plan = match strategy {
            ReduceStrategy::MultiProcess => self.plan_mpr(mpl, bytes),
            ReduceStrategy::MultiRing => self.plan_mrr(mpl, bytes)?,
            ReduceStrategy::Hierarchical => self.plan_har(mpl, bytes),
        };
        if !self.plan_valid(&plan) {
            bail!("{strategy} routes over a failed link on the degraded fabric");
        }
        Ok(plan)
    }

    /// Pick the cheapest valid strategy for the layout under the one cost
    /// model — the planner's replacement for the Algorithm 1 heuristic
    /// (which it is validated against: never costlier, never an invalid
    /// MRR).
    pub fn cheapest_allreduce(&self, mpl: &[Vec<usize>], bytes: usize) -> (ReduceStrategy, Plan) {
        self.try_cheapest_allreduce(mpl, bytes)
            .expect("MPR is always a valid plan on a healthy fabric")
    }

    /// Fallible [`Fabric::cheapest_allreduce`] for degraded fabrics: when
    /// failed links leave NO strategy with a valid route between the
    /// participants, the group is partitioned and this returns the error a
    /// caller (the scheduler's rebind path) must handle by evicting or
    /// re-placing the tenant.
    pub fn try_cheapest_allreduce(
        &self,
        mpl: &[Vec<usize>],
        bytes: usize,
    ) -> Result<(ReduceStrategy, Plan)> {
        let mut best: Option<(ReduceStrategy, Plan)> = None;
        for s in [
            ReduceStrategy::MultiProcess,
            ReduceStrategy::MultiRing,
            ReduceStrategy::Hierarchical,
        ] {
            let Ok(p) = self.plan_allreduce(mpl, bytes, s) else { continue };
            let better = match &best {
                None => true,
                Some((_, b)) => p.total_s() < b.total_s(),
            };
            if better {
                best = Some((s, p));
            }
        }
        best.ok_or_else(|| {
            anyhow::anyhow!(
                "allreduce participants are partitioned: no reduction strategy has a \
                 valid route over the degraded fabric"
            )
        })
    }

    /// MPR: all `g*t` GMIs stage D2H (contending their GPU's host path),
    /// the CPU reduces `g*t` buffers, H2D broadcast back.
    fn plan_mpr(&self, mpl: &[Vec<usize>], bytes: usize) -> Plan {
        let t_max = mpl.iter().map(|v| v.len()).max().unwrap();
        let gt: usize = mpl.iter().map(|v| v.len()).sum();
        let topo = self.topology();
        let stage_dur = topo.host_transfer_time(bytes, t_max);
        let stage = |fab: &Fabric| PlanStep {
            dur: stage_dur,
            uses: mpl
                .iter()
                .enumerate()
                .map(|(gpu, v)| LinkUse {
                    link: fab.host_link(gpu),
                    busy_s: topo.host_transfer_time(bytes, v.len()),
                    bytes: (v.len() * bytes) as u64,
                })
                .collect(),
        };
        let mut plan = Plan::new();
        plan.push_step(stage(self));
        let cpu_dur = (gt * bytes) as f64 / CPU_REDUCE_BW + HOST_LAT;
        plan.push_step(PlanStep {
            dur: cpu_dur,
            uses: vec![LinkUse {
                link: self.cpu_link(),
                busy_s: cpu_dur,
                bytes: (gt * bytes) as u64,
            }],
        });
        plan.push_step(stage(self));
        plan
    }

    /// MRR: `t` non-intersecting rings across `g` GPUs (contending the
    /// NVSwitch fabric), a final ring over the `t` ring leaders, then the
    /// intra-ring broadcast back.
    fn plan_mrr(&self, mpl: &[Vec<usize>], bytes: usize) -> Result<Plan> {
        let g = mpl.len();
        let t = mpl[0].len();
        if mpl.iter().any(|v| v.len() != t) {
            bail!("MRR requires equal GMIs per GPU");
        }
        if t > g {
            bail!("MRR invalid: {t} GMIs/GPU > {g} GPUs (multiple CUDA streams error)");
        }
        let topo = self.topology();
        let nv = self.nvswitch_link();
        let ring_traffic = |k: usize, rings: usize| (rings * 2 * (k.max(1) - 1) * bytes) as u64;
        let mut plan = Plan::new();
        let phase1 = topo.ring_allreduce_time(g, bytes, t);
        plan.push_step(PlanStep {
            dur: phase1,
            uses: vec![LinkUse { link: nv, busy_s: phase1, bytes: ring_traffic(g, t) }],
        });
        let phase2 = topo.ring_allreduce_time(t, bytes, 1);
        plan.push_step(PlanStep {
            dur: phase2,
            uses: vec![LinkUse { link: nv, busy_s: phase2, bytes: ring_traffic(t, 1) }],
        });
        let bcast = topo.ring_allreduce_time(g, bytes, t) / 2.0;
        plan.push_step(PlanStep {
            dur: bcast,
            uses: vec![LinkUse { link: nv, busy_s: bcast, bytes: ring_traffic(g, t) / 2 }],
        });
        Ok(plan)
    }

    /// HAR: host-staged reduce to a leader within each GPU (all GPUs in
    /// parallel), NCCL ring across the `g` leaders, host-staged broadcast
    /// back down.
    fn plan_har(&self, mpl: &[Vec<usize>], bytes: usize) -> Plan {
        let g = mpl.len();
        let t_max = mpl.iter().map(|v| v.len()).max().unwrap();
        let topo = self.topology();
        let mut plan = Plan::new();
        let host_uses = |fab: &Fabric| -> Vec<LinkUse> {
            mpl.iter()
                .enumerate()
                .filter(|(_, v)| v.len() > 1)
                .map(|(gpu, v)| LinkUse {
                    link: fab.host_link(gpu),
                    busy_s: topo.host_transfer_time(bytes, v.len() - 1),
                    bytes: ((v.len() - 1) * bytes) as u64,
                })
                .collect()
        };
        if t_max > 1 {
            let dur = topo.host_transfer_time(bytes, t_max - 1)
                + (t_max * bytes) as f64 / CPU_REDUCE_BW;
            let mut uses = host_uses(self);
            uses.push(LinkUse {
                link: self.cpu_link(),
                busy_s: (t_max * bytes) as f64 / CPU_REDUCE_BW,
                bytes: (t_max * bytes) as u64,
            });
            plan.push_step(PlanStep { dur, uses });
        }
        let ring = topo.ring_allreduce_time(g, bytes, 1);
        if ring > 0.0 {
            plan.push_step(PlanStep {
                dur: ring,
                uses: vec![LinkUse {
                    link: self.nvswitch_link(),
                    busy_s: ring,
                    bytes: (2 * (g - 1) * bytes) as u64,
                }],
            });
        }
        if t_max > 1 {
            let dur = topo.host_transfer_time(bytes, t_max - 1);
            plan.push_step(PlanStep { dur, uses: host_uses(self) });
        }
        plan
    }

    /// The §8 three-level multi-node hierarchy: intra-GPU host-staged
    /// reduce, NVLink ring over per-GPU leaders, InfiniBand ring over node
    /// leaders, broadcast back down.
    pub fn plan_multinode_allreduce(&self, g: usize, t: usize, bytes: usize) -> Plan {
        let multi = self.multi_topology().expect("multi-node fabric required").clone();
        let ib = self.ib_link().expect("multi-node fabric has an IB link");
        let topo = self.topology();
        let mut plan = Plan::new();
        // Level 1: intra-GPU host-staged reduce (all GPUs/nodes parallel).
        if t > 1 {
            let dur = topo.host_transfer_time(bytes, t - 1) + (t * bytes) as f64 / CPU_REDUCE_BW;
            plan.push_step(PlanStep {
                dur,
                uses: vec![
                    LinkUse {
                        link: self.host_link(0),
                        busy_s: topo.host_transfer_time(bytes, t - 1),
                        bytes: ((t - 1) * bytes) as u64,
                    },
                    LinkUse {
                        link: self.cpu_link(),
                        busy_s: (t * bytes) as f64 / CPU_REDUCE_BW,
                        bytes: (t * bytes) as u64,
                    },
                ],
            });
        }
        // Level 2: NVLink ring over the g per-GPU leaders (per node).
        let l2 = topo.ring_allreduce_time(g, bytes, 1);
        if l2 > 0.0 {
            plan.push_step(PlanStep {
                dur: l2,
                uses: vec![LinkUse {
                    link: self.nvswitch_link(),
                    busy_s: l2,
                    bytes: (2 * (g - 1) * bytes) as u64,
                }],
            });
        }
        // Level 3: InfiniBand ring over node leaders.
        let l3 = multi.ib_ring_time(multi.num_nodes, bytes);
        if l3 > 0.0 {
            plan.push_step(PlanStep {
                dur: l3,
                uses: vec![LinkUse {
                    link: ib,
                    busy_s: l3,
                    bytes: (2 * (multi.num_nodes - 1) * bytes) as u64,
                }],
            });
        }
        // Broadcast back down: host fan-out (parallel per level) + the
        // NVLink launch of the downward ring.
        let down_host = if t > 1 { topo.host_transfer_time(bytes, t - 1) } else { 0.0 };
        let mut uses = vec![LinkUse {
            link: self.nvswitch_link(),
            busy_s: NCCL_LAT,
            bytes: ((g.max(1) - 1) * bytes) as u64,
        }];
        if t > 1 {
            uses.push(LinkUse {
                link: self.host_link(0),
                busy_s: down_host,
                bytes: ((t - 1) * bytes) as u64,
            });
        }
        plan.push_step(PlanStep { dur: down_host + NCCL_LAT, uses });
        plan
    }

    /// The layout-oblivious flat alternative at cluster scale: every GMI
    /// host-stages to a global CPU reduction, results cross IB once per
    /// extra node (used by the ablation showing the hierarchy is required).
    pub fn plan_flat_mpr(&self, g: usize, t: usize, bytes: usize) -> Plan {
        let multi = self.multi_topology().expect("multi-node fabric required").clone();
        let topo = self.topology();
        let k = multi.num_nodes * g * t;
        let mut plan = Plan::new();
        let stage = |fab: &Fabric| PlanStep {
            dur: topo.host_transfer_time(bytes, t),
            uses: vec![LinkUse {
                link: fab.host_link(0),
                busy_s: topo.host_transfer_time(bytes, t),
                bytes: (t * bytes) as u64,
            }],
        };
        plan.push_step(stage(self));
        let cpu = (k * bytes) as f64 / CPU_REDUCE_BW;
        plan.push_step(PlanStep {
            dur: cpu,
            uses: vec![LinkUse { link: self.cpu_link(), busy_s: cpu, bytes: (k * bytes) as u64 }],
        });
        if multi.num_nodes > 1 {
            let ib_dur = bytes as f64 * (multi.num_nodes - 1) as f64 / IB_BW;
            plan.push_step(PlanStep {
                dur: ib_dur,
                uses: vec![LinkUse {
                    link: self.ib_link().expect("multi-node fabric has an IB link"),
                    busy_s: ib_dur,
                    bytes: ((multi.num_nodes - 1) * bytes) as u64,
                }],
            });
        }
        plan.push_step(stage(self));
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{MultiNodeTopology, Topology};

    fn mpl(g: usize, t: usize) -> Vec<Vec<usize>> {
        (0..g).map(|i| (0..t).map(|j| i * t + j).collect()).collect()
    }

    #[test]
    fn mrr_validity_rules() {
        let f = Fabric::single_node(Topology::dgx_a100(2));
        assert!(f.plan_allreduce(&mpl(2, 3), 1 << 20, ReduceStrategy::MultiRing).is_err());
        assert!(f
            .plan_allreduce(&[vec![0, 1], vec![2]], 1 << 20, ReduceStrategy::MultiRing)
            .is_err());
        assert!(f.plan_allreduce(&mpl(2, 2), 1 << 20, ReduceStrategy::MultiRing).is_ok());
    }

    #[test]
    fn single_gmi_plans_are_empty() {
        let f = Fabric::single_node(Topology::dgx_a100(1));
        for s in [
            ReduceStrategy::MultiProcess,
            ReduceStrategy::MultiRing,
            ReduceStrategy::Hierarchical,
        ] {
            let p = f.plan_allreduce(&mpl(1, 1), 1 << 20, s).unwrap();
            assert!(p.is_empty());
        }
    }

    #[test]
    fn cheapest_is_min_over_valid_plans() {
        let f = Fabric::single_node(Topology::dgx_a100(4));
        let layout = mpl(4, 2);
        let bytes = 6 << 20;
        let (s, p) = f.cheapest_allreduce(&layout, bytes);
        for cand in [
            ReduceStrategy::MultiProcess,
            ReduceStrategy::MultiRing,
            ReduceStrategy::Hierarchical,
        ] {
            if let Ok(q) = f.plan_allreduce(&layout, bytes, cand) {
                assert!(p.total_s() <= q.total_s() + 1e-15, "{s} beaten by {cand}");
            }
        }
        // On NVLink boxes with t <= g, rings win clearly.
        assert_eq!(s, ReduceStrategy::MultiRing);
    }

    #[test]
    fn har_beats_mpr_on_multi_gpu_layouts() {
        let f = Fabric::single_node(Topology::dgx_a100(4));
        let bytes = 6 << 20;
        let har = f.plan_allreduce(&mpl(4, 4), bytes, ReduceStrategy::Hierarchical).unwrap();
        let mpr = f.plan_allreduce(&mpl(4, 4), bytes, ReduceStrategy::MultiProcess).unwrap();
        assert!(har.total_s() < mpr.total_s());
    }

    #[test]
    fn multinode_hierarchy_beats_flat() {
        let f = Fabric::multi_node(MultiNodeTopology::dgx_cluster(4, 8));
        let bytes = 6 * 1024 * 1024;
        let hier = f.plan_multinode_allreduce(8, 4, bytes).total_s();
        let flat = f.plan_flat_mpr(8, 4, bytes).total_s();
        assert!(flat / hier > 4.0, "hier {hier} flat {flat}");
    }

    #[test]
    fn unfused_launch_extra_shape() {
        assert_eq!(unfused_ring_launch_extra(1, 10), 0.0);
        assert_eq!(unfused_ring_launch_extra(4, 1), 0.0);
        let e2 = unfused_ring_launch_extra(2, 10);
        let e4 = unfused_ring_launch_extra(4, 10);
        assert!(e4 > e2 && e2 > 0.0);
    }
}
