//! Point-to-point routes over fabric links (paper §4.2's experience
//! movement): same-GPU transfers forward over the destination GPU's
//! host-staged path; cross-GPU transfers gather over the NVSwitch fabric
//! and then hand off through the destination's host path (the memory
//! barrier between GMIs makes the final hop host-staged under MPS/MIG).

use crate::cluster::NCCL_LAT;
use crate::vtime::Clock;

use super::link::LinkId;
use super::plan::{LinkUse, Plan, PlanStep};
use super::Fabric;

/// A resolved point-to-point route: the link hops a payload crosses.
#[derive(Debug, Clone)]
pub struct Route {
    pub hops: Vec<LinkId>,
    pub cross_gpu: bool,
}

impl Fabric {
    /// Resolve the route between two GPUs' GMIs. On a degraded fabric with
    /// the NVSwitch down, cross-GPU payloads bounce through host memory on
    /// both ends instead — slower, but it keeps surviving tenants
    /// connected.
    pub fn route(&self, src_gpu: usize, dst_gpu: usize) -> Route {
        if src_gpu == dst_gpu {
            Route { hops: vec![self.host_link(dst_gpu)], cross_gpu: false }
        } else if self.has_failures() && self.link_failed(self.nvswitch_link()) {
            Route {
                hops: vec![self.host_link(src_gpu), self.host_link(dst_gpu)],
                cross_gpu: true,
            }
        } else {
            Route {
                hops: vec![self.nvswitch_link(), self.host_link(dst_gpu)],
                cross_gpu: true,
            }
        }
    }

    /// Lower a point-to-point transfer of `bytes` along `route` into a
    /// plan: one phase per hop (NVLink gather, then host handoff).
    pub fn plan_route(&self, route: &Route, bytes: usize) -> Plan {
        let topo = self.topology();
        let mut plan = Plan::new();
        for &hop in &route.hops {
            let dur = if hop == self.nvswitch_link() {
                bytes as f64 / topo.inter_gpu_bw() + NCCL_LAT
            } else {
                topo.host_transfer_time(bytes, 1)
            };
            plan.push_step(PlanStep {
                dur,
                uses: vec![LinkUse { link: hop, busy_s: dur, bytes: bytes as u64 }],
            });
        }
        plan
    }

    /// Route + execute a point-to-point transfer: the payload leaves at
    /// `ready` (or later, if its links are busy — contended links
    /// serialize) and the returned clock is the arrival at the destination.
    pub fn transfer(
        &mut self,
        src_gpu: usize,
        dst_gpu: usize,
        bytes: usize,
        ready: Clock,
    ) -> (Clock, f64, bool) {
        let route = self.route(src_gpu, dst_gpu);
        let plan = self.plan_route(&route, bytes);
        let transfer_s = plan.total_s();
        let arrival = self.execute(&plan, ready);
        (arrival, transfer_s, route.cross_gpu)
    }

    /// Gather `sources` same-sized payloads into `dst_gpu` through its host
    /// path (the TDG_EX experience feed): the `k` feeders contend the path
    /// and their transfers serialize on it.
    pub fn plan_gather(&self, sources: usize, bytes_each: usize, dst_gpu: usize) -> Plan {
        let k = sources.max(1);
        let dur = k as f64 * self.topology().host_transfer_time(bytes_each, k);
        let mut plan = Plan::new();
        plan.push_step(PlanStep {
            dur,
            uses: vec![LinkUse {
                link: self.host_link(dst_gpu),
                busy_s: dur,
                bytes: (k * bytes_each) as u64,
            }],
        });
        plan
    }

    /// Fan one payload out to GMIs on `dst_gpus` through their host paths,
    /// `sharing` receivers contending each path (the TDG_EX parameter
    /// broadcast back to serving GMIs).
    pub fn plan_fanout(&self, bytes: usize, sharing: usize, dst_gpus: &[usize]) -> Plan {
        let dur = self.topology().host_transfer_time(bytes, sharing);
        let mut plan = Plan::new();
        plan.push_step(PlanStep {
            dur,
            uses: dst_gpus
                .iter()
                .map(|&gpu| LinkUse { link: self.host_link(gpu), busy_s: dur, bytes: bytes as u64 })
                .collect(),
        });
        plan
    }

    /// The A3C parameter push-back: one NVLink crossing from the training
    /// GPUs plus a host-staged delivery into each agent GMI.
    pub fn plan_param_push(&self, bytes: usize, dst_gpus: &[usize]) -> Plan {
        let topo = self.topology();
        let mut plan = Plan::new();
        // Degraded fabric: with the NVSwitch down the parameter payload
        // stages through pinned host memory (the CPU path) instead.
        let (cross_link, nv) = if self.link_failed(self.nvswitch_link()) {
            (self.cpu_link(), topo.host_transfer_time(bytes, 1))
        } else {
            (self.nvswitch_link(), bytes as f64 / topo.inter_gpu_bw())
        };
        plan.push_step(PlanStep {
            dur: nv,
            uses: vec![LinkUse { link: cross_link, busy_s: nv, bytes: bytes as u64 }],
        });
        let host = topo.host_transfer_time(bytes, 1);
        plan.push_step(PlanStep {
            dur: host,
            uses: dst_gpus
                .iter()
                .map(|&gpu| LinkUse { link: self.host_link(gpu), busy_s: host, bytes: bytes as u64 })
                .collect(),
        });
        plan
    }

    /// A within-GPU GMI boundary crossing (TDG serving's per-step
    /// state/action bounce): one host-path hop with `sharing` contenders.
    pub fn plan_intra_gpu(&self, bytes: usize, sharing: usize, gpu: usize) -> Plan {
        let dur = self.topology().host_transfer_time(bytes, sharing);
        let mut plan = Plan::new();
        plan.push_step(PlanStep {
            dur,
            uses: vec![LinkUse { link: self.host_link(gpu), busy_s: dur, bytes: bytes as u64 }],
        });
        plan
    }

    /// Pooled variant of [`Fabric::plan_intra_gpu`]: writes the hop into a
    /// caller-owned plan buffer instead of allocating one (identical
    /// durations/uses, so execution is bit-identical). The gateway reuses
    /// two such buffers across every dispatch of a run.
    pub fn plan_intra_gpu_into(&self, bytes: usize, sharing: usize, gpu: usize, plan: &mut Plan) {
        let dur = self.topology().host_transfer_time(bytes, sharing);
        plan.reuse_single_hop(self.host_link(gpu), dur, bytes as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;

    #[test]
    fn same_gpu_routes_host_only() {
        let f = Fabric::single_node(Topology::dgx_a100(4));
        let r = f.route(2, 2);
        assert!(!r.cross_gpu);
        assert_eq!(r.hops, vec![f.host_link(2)]);
        let c = f.route(0, 2);
        assert!(c.cross_gpu);
        assert_eq!(c.hops.len(), 2);
    }

    #[test]
    fn cross_gpu_costs_more() {
        let f = Fabric::single_node(Topology::dgx_a100(4));
        let bytes = 8 << 20;
        let same = f.plan_route(&f.route(1, 1), bytes).total_s();
        let cross = f.plan_route(&f.route(0, 1), bytes).total_s();
        assert!(cross > same);
    }

    #[test]
    fn contended_route_serializes() {
        let mut f = Fabric::single_node(Topology::dgx_a100(2));
        let (a1, t1, _) = f.transfer(0, 1, 4 << 20, Clock(1.0));
        assert!((a1.seconds() - (1.0 + t1)).abs() < 1e-12);
        // Same instant, same route: the second transfer queues behind.
        let (a2, t2, cross) = f.transfer(0, 1, 4 << 20, Clock(1.0));
        assert!(cross);
        assert!(a2.seconds() > 1.0 + t2);
        assert!(a2 > a1);
    }

    #[test]
    fn gather_and_fanout_scale_with_contention() {
        let f = Fabric::single_node(Topology::dgx_a100(2));
        let g1 = f.plan_gather(1, 1 << 20, 0).total_s();
        let g4 = f.plan_gather(4, 1 << 20, 0).total_s();
        assert!(g4 > g1 * 3.0);
        let f1 = f.plan_fanout(1 << 20, 1, &[0]).total_s();
        let f4 = f.plan_fanout(1 << 20, 4, &[0, 1]).total_s();
        assert!(f4 > f1);
    }
}
