//! Link-level primitives of the inter-GMI communication fabric.
//!
//! A [`Link`] is one contended transport resource derived from the cluster
//! topology: a GPU's host-staged PCIe path, the node-wide NVSwitch fabric,
//! the CPU reduction engine, or the inter-node InfiniBand ring. Transfer
//! plans ([`super::Plan`]) name links by [`LinkId`]; the [`Fabric`]
//! serializes concurrent plans on shared links and accumulates per-link
//! traffic totals ([`LinkStats`]) for the metrics report.
//!
//! [`Fabric`]: super::Fabric

/// Index of a link inside a [`Fabric`](super::Fabric) (stable for the
/// fabric's lifetime).
pub type LinkId = usize;

/// The transport classes of the fabric (paper §4: host-staged inter-process
/// paths, NVLink/NVSwitch NCCL rings, and — for the §8 multi-node
/// extension — InfiniBand between node leaders).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkKind {
    /// One GPU's host-staged path: D2H copy + shared-memory handoff + H2D.
    HostPath { gpu: usize },
    /// The node-wide NVSwitch fabric NCCL rings run over.
    NvSwitch,
    /// The CPU-side reduction engine (the MPR bottleneck).
    CpuReduce,
    /// The inter-node InfiniBand ring.
    InfiniBand,
}

/// One contended transport resource.
#[derive(Debug, Clone)]
pub struct Link {
    pub id: LinkId,
    pub kind: LinkKind,
}

impl Link {
    /// Human-readable name for the per-link metrics report.
    pub fn name(&self) -> String {
        match self.kind {
            LinkKind::HostPath { gpu } => format!("host:gpu{gpu}"),
            LinkKind::NvSwitch => "nvswitch".to_string(),
            LinkKind::CpuReduce => "cpu-reduce".to_string(),
            LinkKind::InfiniBand => "ib".to_string(),
        }
    }
}

/// Accumulated traffic totals of one link.
#[derive(Debug, Clone, Copy, Default)]
pub struct LinkStats {
    /// Payload bytes that crossed the link.
    pub bytes: u64,
    /// Virtual seconds the link spent busy.
    pub busy_s: f64,
}
