//! The topology-aware inter-GMI communication fabric (paper §4, Figs 4-5).
//!
//! Before this layer existed, gradient reduction (`comm::lgr`), multi-node
//! scaling (`comm::multinode`) and the channel pipeline (`channels`) each
//! hand-rolled their own link-cost arithmetic. The fabric is the one
//! substrate they all lower onto:
//!
//! * [`link`] — [`Link`]s: contended transport resources derived from
//!   [`cluster::Topology`] / [`cluster::MultiNodeTopology`] (per-GPU
//!   host-staged paths, the NVSwitch fabric, the CPU reduction engine, the
//!   inter-node InfiniBand ring).
//! * [`route`] — point-to-point [`Route`]s over those links (same-GPU host
//!   hop vs cross-GPU NVLink + host handoff) for the experience migrator.
//! * [`plan`] — the collective planner: lowers AllReduce requests into
//!   per-link transfer [`Plan`]s for every strategy (MPR / MRR / HAR and
//!   the 3-level multi-node hierarchy) under one cost model, and picks the
//!   cheapest valid plan ([`Fabric::cheapest_allreduce`]).
//!
//! A [`Plan`] is *pure data* (phases of per-link usage); [`Fabric::execute`]
//! turns it into virtual time, serializing plans that contend the same
//! links (`free_at` occupancy) and accumulating per-link bytes/busy totals
//! for [`metrics`](crate::metrics). The engine exposes plans as discrete
//! events on the participating executors
//! ([`Engine::collective`](crate::engine::Engine::collective) /
//! [`collective_overlapped`](crate::engine::Engine::collective_overlapped)),
//! which is what enables compute/communication overlap in `drl::sync`.
//!
//! [`cluster::Topology`]: crate::cluster::Topology
//! [`cluster::MultiNodeTopology`]: crate::cluster::MultiNodeTopology

pub mod link;
pub mod plan;
pub mod route;

pub use link::{Link, LinkId, LinkKind, LinkStats};
pub use plan::{unfused_ring_launch_extra, Plan, PlanStep, ReduceStrategy};
pub use route::Route;

use crate::cluster::{MultiNodeTopology, Topology, HOST_LAT};
use crate::metrics::LinkReport;
use crate::vtime::Clock;

/// The link-level communication substrate: the link table derived from the
/// topology plus the mutable per-link occupancy and traffic state.
#[derive(Debug, Clone)]
pub struct Fabric {
    topo: Topology,
    multi: Option<MultiNodeTopology>,
    links: Vec<Link>,
    /// Virtual time each link is busy until (plan serialization).
    free_at: Vec<f64>,
    stats: Vec<LinkStats>,
    host: Vec<LinkId>,
    nvswitch: LinkId,
    cpu: LinkId,
    ib: Option<LinkId>,
    /// Links taken down explicitly by [`Fabric::fail_link`].
    failed_links: Vec<bool>,
    /// GPUs taken down by [`Fabric::fail_gpu`] (a failed GPU also fails
    /// its host-staged path — nothing can stage through dead HBM).
    failed_gpus: Vec<bool>,
    /// One-branch hot-path gate: true iff any link or GPU is failed.
    has_failures: bool,
}

impl Fabric {
    /// Fabric of one multi-GPU node: a host-staged link per GPU, the
    /// NVSwitch fabric, and the CPU reduction engine.
    pub fn single_node(topo: Topology) -> Self {
        Self::build(topo, None)
    }

    /// Fabric of a multi-node cluster: the node links plus the InfiniBand
    /// ring between node leaders.
    pub fn multi_node(multi: MultiNodeTopology) -> Self {
        Self::build(multi.node.clone(), Some(multi))
    }

    fn build(topo: Topology, multi: Option<MultiNodeTopology>) -> Self {
        let mut links = Vec::new();
        let mut host = Vec::new();
        for gpu in 0..topo.num_gpus() {
            let id = links.len();
            links.push(Link { id, kind: LinkKind::HostPath { gpu } });
            host.push(id);
        }
        let nvswitch = links.len();
        links.push(Link { id: nvswitch, kind: LinkKind::NvSwitch });
        let cpu = links.len();
        links.push(Link { id: cpu, kind: LinkKind::CpuReduce });
        let ib = multi.as_ref().map(|_| {
            let id = links.len();
            links.push(Link { id, kind: LinkKind::InfiniBand });
            id
        });
        let n = links.len();
        let num_gpus = topo.num_gpus();
        Fabric {
            topo,
            multi,
            links,
            free_at: vec![0.0; n],
            stats: vec![LinkStats::default(); n],
            host,
            nvswitch,
            cpu,
            ib,
            failed_links: vec![false; n],
            failed_gpus: vec![false; num_gpus],
            has_failures: false,
        }
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    pub fn multi_topology(&self) -> Option<&MultiNodeTopology> {
        self.multi.as_ref()
    }

    pub(crate) fn host_link(&self, gpu: usize) -> LinkId {
        self.host[gpu.min(self.host.len() - 1)]
    }

    pub(crate) fn nvswitch_link(&self) -> LinkId {
        self.nvswitch
    }

    pub(crate) fn cpu_link(&self) -> LinkId {
        self.cpu
    }

    pub(crate) fn ib_link(&self) -> Option<LinkId> {
        self.ib
    }

    /// Take a link out of service: routes and collective plans that would
    /// use it become invalid, forcing the planner onto the next-cheapest
    /// valid plan (or a partition error when none remains).
    pub fn fail_link(&mut self, id: LinkId) {
        self.failed_links[id] = true;
        self.has_failures = true;
    }

    /// Bring an explicitly failed link back into service.
    pub fn repair_link(&mut self, id: LinkId) {
        self.failed_links[id] = false;
        self.refresh_failure_gate();
    }

    /// Take a GPU out of service. Its host-staged path fails with it;
    /// GMIs resident on the GPU must be drained by the scheduler before
    /// the next plan executes.
    pub fn fail_gpu(&mut self, gpu: usize) {
        self.failed_gpus[gpu] = true;
        self.has_failures = true;
    }

    /// Bring a failed GPU back into service (its host path recovers too,
    /// unless the link was also failed explicitly).
    pub fn repair_gpu(&mut self, gpu: usize) {
        self.failed_gpus[gpu] = false;
        self.refresh_failure_gate();
    }

    fn refresh_failure_gate(&mut self) {
        self.has_failures =
            self.failed_links.iter().any(|&f| f) || self.failed_gpus.iter().any(|&f| f);
    }

    pub fn gpu_failed(&self, gpu: usize) -> bool {
        self.failed_gpus.get(gpu).copied().unwrap_or(false)
    }

    /// Whether a link is out of service — either failed explicitly or the
    /// host path of a failed GPU.
    pub fn link_failed(&self, id: LinkId) -> bool {
        if self.failed_links[id] {
            return true;
        }
        match self.links[id].kind {
            LinkKind::HostPath { gpu } => self.failed_gpus[gpu],
            _ => false,
        }
    }

    pub fn has_failures(&self) -> bool {
        self.has_failures
    }

    /// GPUs currently out of service, ascending.
    pub fn failed_gpu_list(&self) -> Vec<usize> {
        (0..self.failed_gpus.len()).filter(|&g| self.failed_gpus[g]).collect()
    }

    /// A plan is valid iff no phase touches an out-of-service link. Always
    /// true on a healthy fabric.
    pub fn plan_valid(&self, plan: &Plan) -> bool {
        if !self.has_failures {
            return true;
        }
        plan.steps
            .iter()
            .all(|step| step.uses.iter().all(|u| !self.link_failed(u.link)))
    }

    /// Per-message sender-side submission overhead of a host-staged
    /// transfer (process wakeup + pickling + IPC rendezvous) — the cost a
    /// producer pays on its own timeline per packet it ships.
    pub fn submission_lat(&self) -> f64 {
        HOST_LAT
    }

    /// Execute a plan no earlier than `ready`: each phase starts when every
    /// link it uses is free (plans contending a link serialize), holds its
    /// links until the phase ends, and accumulates per-link traffic.
    /// Returns the completion time.
    pub fn execute(&mut self, plan: &Plan, ready: Clock) -> Clock {
        // Degraded-fabric guard: replaying a (possibly pooled) plan over a
        // failed link is a lifecycle bug upstream — the scheduler must
        // drain tenants off dead hardware before their next plan executes.
        // Costs one predictable branch on the healthy hot path.
        if self.has_failures {
            for step in &plan.steps {
                for u in &step.uses {
                    assert!(
                        !self.link_failed(u.link),
                        "plan executes over failed link {} — stale pooled plan or \
                         undrained tenant",
                        self.links[u.link].name()
                    );
                }
            }
        }
        // Fast lane for the dominant hot-path shape — one phase over one
        // link (gateway request/response hops): occupancy and traffic are
        // updated in a single batched touch. Same arithmetic as the
        // general loop (a fold over one element), so completion times are
        // bit-identical.
        if let [step] = plan.steps.as_slice() {
            if let [u] = step.uses.as_slice() {
                let start = ready.seconds().max(self.free_at[u.link]);
                let end = start + step.dur;
                self.free_at[u.link] = end;
                let s = &mut self.stats[u.link];
                s.busy_s += u.busy_s;
                s.bytes += u.bytes;
                return Clock(end);
            }
        }
        let mut t = ready.seconds();
        for step in &plan.steps {
            let start = step
                .uses
                .iter()
                .fold(t, |acc, u| acc.max(self.free_at[u.link]));
            let end = start + step.dur;
            for u in &step.uses {
                self.free_at[u.link] = end;
                let s = &mut self.stats[u.link];
                s.busy_s += u.busy_s;
                s.bytes += u.bytes;
            }
            t = end;
        }
        Clock(t)
    }

    /// Account a plan's traffic without occupying links or taking time —
    /// for per-step costs that are charged in aggregate on an executor's
    /// timeline (e.g. the serving TDG boundary crossing).
    pub fn tally(&mut self, plan: &Plan, reps: f64) {
        for step in &plan.steps {
            for u in &step.uses {
                self.stats[u.link].busy_s += u.busy_s * reps;
                self.stats[u.link].bytes += (u.bytes as f64 * reps) as u64;
            }
        }
    }

    /// Per-link traffic totals (links that saw no traffic are skipped).
    pub fn link_report(&self) -> Vec<LinkReport> {
        self.links
            .iter()
            .zip(&self.stats)
            .filter(|(_, s)| s.bytes > 0 || s.busy_s > 0.0)
            .map(|(l, s)| LinkReport { name: l.name(), bytes: s.bytes, busy_s: s.busy_s })
            .collect()
    }

    /// Raw stats of one link (test/diagnostic hook).
    pub fn link_stats(&self, id: LinkId) -> LinkStats {
        self.stats[id]
    }

    pub fn num_links(&self) -> usize {
        self.links.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::plan::LinkUse;

    fn one_step_plan(link: LinkId, dur: f64, bytes: u64) -> Plan {
        let mut p = Plan::new();
        p.push_step(PlanStep {
            dur,
            uses: vec![LinkUse { link, busy_s: dur, bytes }],
        });
        p
    }

    #[test]
    fn link_table_shape() {
        let f = Fabric::single_node(Topology::dgx_a100(4));
        // 4 host paths + nvswitch + cpu
        assert_eq!(f.num_links(), 6);
        assert!(f.ib_link().is_none());
        let fm = Fabric::multi_node(MultiNodeTopology::dgx_cluster(2, 4));
        assert_eq!(fm.num_links(), 7);
        assert!(fm.ib_link().is_some());
    }

    #[test]
    fn execute_serializes_contended_links() {
        let mut f = Fabric::single_node(Topology::dgx_a100(2));
        let l = f.host_link(0);
        let p = one_step_plan(l, 1.0, 100);
        let a = f.execute(&p, Clock(0.0));
        assert_eq!(a.seconds(), 1.0);
        // Same ready time, same link: the second plan queues behind.
        let b = f.execute(&p, Clock(0.0));
        assert_eq!(b.seconds(), 2.0);
        // A different link is free.
        let q = one_step_plan(f.host_link(1), 1.0, 100);
        let c = f.execute(&q, Clock(0.0));
        assert_eq!(c.seconds(), 1.0);
    }

    #[test]
    fn stats_accumulate_and_report() {
        let mut f = Fabric::single_node(Topology::dgx_a100(1));
        let l = f.host_link(0);
        f.execute(&one_step_plan(l, 0.5, 64), Clock(0.0));
        f.tally(&one_step_plan(l, 0.25, 32), 2.0);
        let s = f.link_stats(l);
        assert_eq!(s.bytes, 64 + 64);
        assert!((s.busy_s - 1.0).abs() < 1e-12);
        let rep = f.link_report();
        assert_eq!(rep.len(), 1);
        assert_eq!(rep[0].name, "host:gpu0");
    }

    #[test]
    fn empty_plan_is_free() {
        let mut f = Fabric::single_node(Topology::dgx_a100(1));
        let done = f.execute(&Plan::new(), Clock(3.0));
        assert_eq!(done.seconds(), 3.0);
    }
}
