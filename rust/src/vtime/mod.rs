//! Virtual timeline: the GPU timing model (DESIGN.md §5).
//!
//! Real numerics execute on the PJRT CPU client, but wall-clock CPU time is
//! meaningless as an A100 proxy. Every operation instead advances a per-GMI
//! **virtual clock** by a cost from the calibrated model in [`CostModel`];
//! synchronization points (allreduce, p2p receive) merge clocks Lamport
//! style. Virtual time is deterministic, so every bench is reproducible.

mod clock;
mod cost;

pub use clock::Clock;
pub use cost::{CostModel, OpKind, A100_F32_FLOPS, A100_SM_COUNT};
