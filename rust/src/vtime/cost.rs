//! The calibrated A100 cost model.
//!
//! Calibration anchors (DESIGN.md §5):
//!  * the paper's profiled phase ratios `T_s ≈ 6 T_a ≈ 3 T_t` (§5.1) — env
//!    simulation dominates one training iteration (~2/3), agent inference is
//!    small, policy training sits in between;
//!  * env simulation *saturates* at a modest SM share (`sim_sat`, Fig 1b's
//!    <50% utilization) — giving a simulator the whole GPU buys nothing past
//!    saturation, which is exactly the headroom GMI multiplexing harvests;
//!  * GEMM-shaped work (inference/training) partitions ~linearly in SM
//!    share;
//!  * absolute rates land in the paper's reported ranges (Table 7: AT 1e5
//!    steps/s scale on a few GPUs).

use crate::config::BenchInfo;

/// A100 peak f32 (TF32 tensor-core path) FLOP/s used for GEMM work.
pub const A100_F32_FLOPS: f64 = 156e12;
/// SMs per A100.
pub const A100_SM_COUNT: usize = 108;
/// A100 HBM capacity in GiB.
pub const A100_MEM_GIB: f64 = 40.0;

/// Effective "element rate" of physics simulation on a full A100
/// (flop-equivalents/s). Deliberately far below GEMM peak: physics is
/// element-wise, divergent and launch-bound — this constant is calibrated so
/// a full-GPU Ant simulation runs ~180k env-steps/s (Isaac Gym scale).
const K_SIM: f64 = 5.4e8;

/// Fixed per-sim-step launch/pipeline overhead (seconds): physics pipeline
/// sync + kernel launches; does not shrink with num_env or SM share.
const L_SIM: f64 = 1.0e-3;

/// Fixed per-GEMM-phase launch overhead (seconds).
const L_GEMM: f64 = 5.0e-5;

/// Effective GEMM utilization for small-batch MLP inference.
const GEMM_UTIL_INFER: f64 = 0.00156;
/// Effective GEMM utilization for training (bigger fused batches). The
/// T_t ~= T_s/3 anchor is the *total* training phase of one iteration,
/// which Isaac PPO spends in DEFAULT_PPO_EPOCHS passes over the batch —
/// so a single pass runs at epochs x the one-pass-calibrated rate.
const GEMM_UTIL_TRAIN: f64 = 0.00235 * crate::drl::DEFAULT_PPO_EPOCHS as f64;
// The two utilizations are calibrated so that at the reference config
// (AT, num_env=4096, horizon=16) the paper's T_s ≈ 6 T_a ≈ 3 T_t holds:
//   T_a = T_s/6  ->  util_infer such that fwd GEMM time = sim/6
//   T_t = T_s/3  ->  util_train such that train GEMM time = sim/3
// They look tiny because they also absorb framework overhead per op and the
// fact that these MLPs are far too small to fill an A100's MXUs.

/// One operation on the virtual timeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OpKind {
    /// One environment-simulation step for `num_env` environments.
    SimStep { num_env: usize },
    /// One policy forward (action prediction) for `num_env` environments.
    PolicyFwd { num_env: usize },
    /// One PPO gradient computation over `samples` experience samples.
    TrainGrad { samples: usize },
    /// Adam parameter update (flat vectors).
    AdamApply,
}

/// Per-benchmark cost model. `share` arguments are effective SM fractions in
/// (0, 1]; interference multipliers come from the GMI backend (gmi module).
#[derive(Debug, Clone)]
pub struct CostModel {
    pub abbr: String,
    /// flop-equivalents per env per sim step.
    pub sim_flops: f64,
    /// policy forward flops per env.
    pub fwd_flops: f64,
    /// SM share where env simulation saturates (Fig 1b).
    pub sim_sat: f64,
    /// relative "complexity" of the benchmark, drives interference penalties
    /// (Fig 8: HM/BB suffer more from weak isolation than AT).
    pub heaviness: f64,
    /// parameter count (for memory model).
    pub num_params: usize,
    pub obs_dim: usize,
    pub act_dim: usize,
}

impl CostModel {
    pub fn new(b: &BenchInfo) -> Self {
        // Saturation share: heavier physics keeps more SMs busy. Ranges
        // ~0.22 (BB) to ~0.41 (SH); mean ~0.3 matches Fig 1b's 32% average
        // utilization for sim-dominated execution.
        let sim_sat = (0.20 + b.obs_dim as f64 / 1100.0).min(0.45);
        // Complexity proxy for contention penalties. BB is flagged complex
        // in the paper (fast control loop); give control-heavy tasks a
        // floor via actuator count relative to obs size.
        let heaviness =
            (b.obs_dim as f64 / 211.0).max(0.35 + 2.0 * b.act_dim as f64 / b.obs_dim as f64 / 3.0);
        CostModel {
            abbr: b.abbr.clone(),
            sim_flops: b.sim_flops_per_env(),
            fwd_flops: b.fwd_flops_per_env(),
            sim_sat,
            heaviness: heaviness.min(1.0),
            num_params: b.num_params,
            obs_dim: b.obs_dim,
            act_dim: b.act_dim,
        }
    }

    /// Time (s) of one op on a GMI holding `share` of the GPU's SMs.
    /// `interference` is a >= 1.0 multiplier from the backend model.
    pub fn op_time(&self, op: OpKind, share: f64, interference: f64) -> f64 {
        assert!(share > 0.0 && share <= 1.0, "bad SM share {share}");
        let t = match op {
            OpKind::SimStep { num_env } => {
                // Physics saturates: shares above sim_sat buy nothing.
                let eff = (share / self.sim_sat).min(1.0);
                L_SIM + num_env as f64 * self.sim_flops / (K_SIM * eff)
            }
            OpKind::PolicyFwd { num_env } => {
                L_GEMM
                    + num_env as f64 * self.fwd_flops
                        / (A100_F32_FLOPS * GEMM_UTIL_INFER * share)
            }
            OpKind::TrainGrad { samples } => {
                // fwd + bwd ~= 3x forward flops.
                L_GEMM
                    + 3.0 * samples as f64 * self.fwd_flops
                        / (A100_F32_FLOPS * GEMM_UTIL_TRAIN * share)
            }
            OpKind::AdamApply => {
                // Bandwidth-bound elementwise over 4 flat vectors.
                L_GEMM + (4 * 4 * self.num_params) as f64 / (1.2e12 * share)
            }
        };
        t * interference
    }

    /// Fraction of the GPU's SMs an op actually occupies while running on a
    /// GMI with `share` (drives the utilization metric, Fig 1b). The MLPs
    /// of Table 6 are far too small to fill an A100, so even the GEMM
    /// phases occupy a modest fraction of an exclusive GPU — which is why
    /// the paper's baseline profiles at ~32%.
    pub fn sm_occupancy(&self, op: OpKind, share: f64) -> f64 {
        match op {
            OpKind::SimStep { .. } => share.min(self.sim_sat),
            OpKind::PolicyFwd { .. } => share * 0.35,
            OpKind::TrainGrad { .. } => share * 0.55,
            OpKind::AdamApply => share * 0.30,
        }
    }

    /// Device memory (GiB) needed by a role running `num_env` environments
    /// with rollout length `horizon`. Drives Alg 2's runnable check and the
    /// Fig 10 memory curve.
    pub fn mem_gib(&self, num_env: usize, horizon: usize, has_sim: bool, has_trainer: bool) -> f64 {
        let n = num_env as f64;
        let mut bytes = 0.8e9; // CUDA context + framework + workspace
        // Policy + optimizer state (params, adam m/v, grads).
        bytes += (5 * 4 * self.num_params) as f64;
        if has_sim {
            // Physics buffers: bodies, contacts, solver scratch per env;
            // mildly superlinear (contact broadphase) at large env counts.
            let per_env = 1.0e5 + 2000.0 * self.obs_dim as f64;
            bytes += n * per_env * (1.0 + n / 16384.0);
        }
        // Experience buffer (state/action/reward/logp/value/done).
        let exp = 4.0 * (self.obs_dim + self.act_dim + 4) as f64;
        bytes += n * horizon as f64 * exp;
        if has_trainer {
            // Activation storage for the training batch.
            let acts: f64 = 4.0 * (self.obs_dim + self.act_dim) as f64 * 8.0;
            bytes += n * horizon as f64 * acts;
        }
        bytes / 1.074e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::static_registry;

    fn at() -> CostModel {
        CostModel::new(&static_registry()["AT"])
    }

    #[test]
    fn sim_saturates_with_share() {
        let m = at();
        let full = m.op_time(OpKind::SimStep { num_env: 4096 }, 1.0, 1.0);
        let at_sat = m.op_time(OpKind::SimStep { num_env: 4096 }, m.sim_sat, 1.0);
        // Above saturation the share buys nothing.
        assert!((full - at_sat).abs() < 1e-12);
        // Below saturation time grows.
        let small = m.op_time(OpKind::SimStep { num_env: 4096 }, m.sim_sat / 2.0, 1.0);
        assert!(small > full * 1.5);
    }

    #[test]
    fn gemm_scales_linearly_in_share() {
        let m = at();
        let t1 = m.op_time(OpKind::TrainGrad { samples: 65536 }, 1.0, 1.0) - L_GEMM;
        let t4 = m.op_time(OpKind::TrainGrad { samples: 65536 }, 0.25, 1.0) - L_GEMM;
        assert!((t4 / t1 - 4.0).abs() < 0.05, "ratio {}", t4 / t1);
    }

    #[test]
    fn paper_phase_ratios_hold_at_reference_config() {
        // T_s ~= 6 T_a ~= 3 T_t for AT at num_env=4096, horizon=16 (§5.1).
        let m = at();
        let n = 4096;
        let h = 16;
        let ts = h as f64 * m.op_time(OpKind::SimStep { num_env: n }, 1.0, 1.0);
        let ta = h as f64 * m.op_time(OpKind::PolicyFwd { num_env: n }, 1.0, 1.0);
        // T_t is the whole training phase: PPO runs DEFAULT_PPO_EPOCHS
        // passes over the collected batch.
        let tt = crate::drl::DEFAULT_PPO_EPOCHS as f64
            * m.op_time(OpKind::TrainGrad { samples: n * h }, 1.0, 1.0);
        let r_a = ts / ta;
        let r_t = ts / tt;
        assert!((r_a - 6.0).abs() < 1.2, "T_s/T_a = {r_a}");
        assert!((r_t - 3.0).abs() < 0.6, "T_s/T_t = {r_t}");
    }

    #[test]
    fn full_gpu_ant_sim_rate_is_isaac_scale() {
        // ~180k env-steps/s for Ant on a full A100 (Isaac Gym magnitude).
        let m = at();
        let n = 4096;
        let t = m.op_time(OpKind::SimStep { num_env: n }, 1.0, 1.0);
        let rate = n as f64 / t;
        assert!(rate > 8e4 && rate < 5e5, "sim rate {rate}");
    }

    #[test]
    fn multiplexed_sim_beats_exclusive() {
        // 4 concurrent GMIs at 1/4 share each should aggregate ~3x the
        // exclusive sim rate (the paper's core mechanism).
        let m = at();
        let excl = 4096.0 / m.op_time(OpKind::SimStep { num_env: 4096 }, 1.0, 1.0);
        let per_gmi = 1024.0 / m.op_time(OpKind::SimStep { num_env: 1024 }, 0.25, 1.0);
        let agg = 4.0 * per_gmi;
        assert!(agg / excl > 2.0, "aggregate gain {}", agg / excl);
        assert!(agg / excl < 3.5, "aggregate gain {}", agg / excl);
    }

    #[test]
    fn memory_monotone_in_num_env() {
        let m = at();
        let a = m.mem_gib(512, 16, true, true);
        let b = m.mem_gib(4096, 16, true, true);
        let c = m.mem_gib(8192, 16, true, true);
        assert!(a < b && b < c);
        assert!(c < A100_MEM_GIB, "8k envs must fit one A100: {c}");
    }

    #[test]
    fn occupancy_bounded() {
        let m = at();
        for op in [
            OpKind::SimStep { num_env: 1024 },
            OpKind::PolicyFwd { num_env: 1024 },
            OpKind::TrainGrad { samples: 1024 },
            OpKind::AdamApply,
        ] {
            for share in [0.1, 0.25, 0.5, 1.0] {
                let o = m.sm_occupancy(op, share);
                assert!(o > 0.0 && o <= share + 1e-9);
            }
        }
    }
}
