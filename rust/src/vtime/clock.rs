//! Per-GMI virtual clocks with Lamport-style merging at sync points.

/// A virtual clock in seconds. One per GMI role task; advanced by the cost
/// model, merged (max) at communication points.
#[derive(Debug, Clone, Copy, Default, PartialEq, PartialOrd)]
pub struct Clock(pub f64);

impl Clock {
    pub fn zero() -> Self {
        Clock(0.0)
    }

    pub fn advance(&mut self, dt: f64) -> Self {
        debug_assert!(dt >= 0.0, "negative time advance {dt}");
        self.0 += dt;
        *self
    }

    /// Blocking receive / barrier: wait until `other` (the sender's send
    /// timestamp or the group's max), then advance by the op cost.
    pub fn merge_then_advance(&mut self, other: Clock, dt: f64) -> Self {
        self.0 = self.0.max(other.0) + dt;
        *self
    }

    pub fn seconds(&self) -> f64 {
        self.0
    }

    pub fn max_of(clocks: &[Clock]) -> Clock {
        Clock(clocks.iter().fold(0.0_f64, |a, c| a.max(c.0)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advance_accumulates() {
        let mut c = Clock::zero();
        c.advance(1.5);
        c.advance(0.5);
        assert!((c.seconds() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn merge_takes_max() {
        let mut c = Clock(1.0);
        c.merge_then_advance(Clock(3.0), 0.5);
        assert!((c.seconds() - 3.5).abs() < 1e-12);
        // merging with an older clock only adds the op cost
        let mut c2 = Clock(5.0);
        c2.merge_then_advance(Clock(1.0), 0.25);
        assert!((c2.seconds() - 5.25).abs() < 1e-12);
    }

    #[test]
    fn max_of_group() {
        let cs = [Clock(1.0), Clock(4.0), Clock(2.0)];
        assert_eq!(Clock::max_of(&cs).0, 4.0);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)] // debug_assert! is compiled out in release
    fn negative_advance_panics_in_debug() {
        let mut c = Clock::zero();
        c.advance(-1.0);
    }
}
