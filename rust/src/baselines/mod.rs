//! The paper's baselines (§6 Implementations): NVIDIA Isaac Gym scaled to
//! multiple GPUs — one exclusive process per GPU — with NCCL or Horovod as
//! the data-parallel communication backend, plus the non-GMI A3C setup and
//! the Direct-Share co-scheduling baseline of Fig 8.
//!
//! Baselines share the same compute artifacts, the same cost model, and
//! the same discrete-event [`engine`](crate::engine) (via the orchestrators
//! they delegate to) as GMI-DRL; the ONLY differences are the resource
//! layout (GPU-granularity processes) and the communication path —
//! isolating the system effect the paper measures.

use anyhow::Result;

use crate::cluster::Topology;
use crate::config::BenchInfo;
use crate::fabric::unfused_ring_launch_extra;
use crate::drl::compute::Compute;
use crate::drl::serving::{run_serving, ServingConfig};
use crate::drl::sync::{run_sync, SyncConfig, SyncRunResult};
use crate::gmi::GmiBackend;
use crate::mapping::{build_serving_layout, build_sync_layout, Layout, MappingTemplate};
use crate::metrics::RunMetrics;
use crate::vtime::CostModel;

/// Multi-GPU communication backend of the baseline trainer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommBackend {
    /// One fused ring allreduce per gradient tensor (NCCL).
    Nccl,
    /// Horovod: tensor-fusion buffer — one fused op per cycle, plus the
    /// background coordinator cycle latency.
    Horovod,
}

/// Isaac-Gym-style multi-GPU serving: one full-GPU process per GPU
/// (`gmi_per_gpu = 1`, exclusive; Fig 7a's baseline).
pub fn isaac_serving(
    topo: &Topology,
    bench: &BenchInfo,
    cost: &CostModel,
    compute: &Compute,
    num_env: usize,
    rounds: usize,
) -> Result<RunMetrics> {
    let layout = build_serving_layout(
        topo,
        MappingTemplate::TaskColocated,
        1,
        num_env,
        cost,
        Some(GmiBackend::Mps), // single process; backend is irrelevant at k=1
    )?;
    run_serving(&layout, bench, cost, compute, &ServingConfig {
        rounds,
        seed: 1,
        real_replicas: 1,
    })
}

/// Isaac Gym (PPO) + NCCL/Horovod: data-parallel sync training, one
/// exclusive process per GPU, GPU-granularity ring allreduce.
pub fn isaac_sync(
    topo: &Topology,
    bench: &BenchInfo,
    cost: &CostModel,
    compute: &Compute,
    backend: CommBackend,
    num_env: usize,
    cfg: &SyncConfig,
) -> Result<SyncRunResult> {
    let layout = build_sync_layout(
        topo,
        MappingTemplate::TaskColocated,
        1,
        num_env,
        cost,
        Some(GmiBackend::Mps),
    )?;
    let mut result = run_sync(&layout, bench, cost, compute, cfg)?;
    // Replace the LGR comm cost with the baseline's GPU-level collective:
    // the engine charged the single-GMI-per-GPU ring already (MRR over g
    // GPUs); stretch the span for the backend's per-tensor behaviour.
    let g = topo.num_gpus();
    if g > 1 {
        let n_tensors = 2 * (bench.hidden.len() + 1) * 2 + 1; // per-layer w+b, actor+critic, log_std
        let per_epoch_extra = match backend {
            // NCCL: one launch per tensor (unfused) — priced by the fabric.
            CommBackend::Nccl => unfused_ring_launch_extra(g, n_tensors),
            // Horovod: fused, but pays the coordinator cycle (~2.5 ms).
            CommBackend::Horovod => 2.5e-3,
        };
        let extra = per_epoch_extra * (cfg.ppo_epochs * cfg.iterations) as f64;
        result.metrics.stretch_span(extra);
    }
    Ok(result)
}

/// The Fig 8 backend study: k serving processes on ONE GPU under
/// Direct-Share / MPS / MIG.
pub fn backend_serving(
    bench: &BenchInfo,
    cost: &CostModel,
    compute: &Compute,
    backend: GmiBackend,
    k: usize,
    num_env: usize,
    rounds: usize,
) -> Result<RunMetrics> {
    let topo = Topology::dgx_a100(1);
    let layout =
        build_serving_layout(&topo, MappingTemplate::TaskColocated, k, num_env, cost, Some(backend))?;
    run_serving(&layout, bench, cost, compute, &ServingConfig {
        rounds,
        seed: 1,
        real_replicas: 1,
    })
}

/// Non-GMI asynchronized baseline (Fig 11): serving GPUs and training GPUs
/// each run ONE exclusive process; experience moves uni-channel.
pub fn non_gmi_async_layout(
    topo: &Topology,
    serving_gpus: usize,
    num_env: usize,
    cost: &CostModel,
) -> Result<Layout> {
    crate::mapping::build_async_layout(topo, serving_gpus, 1, 1, num_env, cost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::static_registry;

    fn at() -> (BenchInfo, CostModel) {
        let b = static_registry()["AT"].clone();
        let c = CostModel::new(&b);
        (b, c)
    }

    #[test]
    fn isaac_serving_runs() {
        let (b, c) = at();
        let topo = Topology::dgx_a100(2);
        let m = isaac_serving(&topo, &b, &c, &Compute::Null, 4096, 5).unwrap();
        assert!(m.steps_per_sec > 0.0);
        // exclusive sim-dominated execution -> low utilization (Fig 1b)
        assert!(m.utilization < 0.5, "baseline util {}", m.utilization);
    }

    #[test]
    fn horovod_vs_nccl_close_but_distinct() {
        let (b, c) = at();
        let topo = Topology::dgx_a100(4);
        let cfg = SyncConfig { iterations: 5, ..Default::default() };
        let n = isaac_sync(&topo, &b, &c, &Compute::Null, CommBackend::Nccl, 4096, &cfg).unwrap();
        let h =
            isaac_sync(&topo, &b, &c, &Compute::Null, CommBackend::Horovod, 4096, &cfg).unwrap();
        let ratio = n.metrics.steps_per_sec / h.metrics.steps_per_sec;
        assert!(ratio > 0.9 && ratio < 1.1, "NCCL/Horovod ratio {ratio}");
        assert_ne!(n.metrics.steps_per_sec, h.metrics.steps_per_sec);
    }

    #[test]
    fn backend_ordering_on_heavy_bench() {
        // Fig 8: MIG >= MPS > Direct-Share on HM.
        let b = static_registry()["HM"].clone();
        let c = CostModel::new(&b);
        let run = |be| {
            backend_serving(&b, &c, &Compute::Null, be, 3, 1024, 5)
                .unwrap()
                .steps_per_sec
        };
        let mig = run(GmiBackend::Mig);
        let mps = run(GmiBackend::Mps);
        let ds = run(GmiBackend::DirectShare);
        assert!(mig >= mps, "mig {mig} mps {mps}");
        assert!(mps > ds, "mps {mps} ds {ds}");
    }
}
