//! Simulated multi-GPU node: DGX-A100 topology (8x A100, NVSwitch fabric,
//! per-GPU PCIe host links), plus the multi-node cluster extension
//! ([`MultiNodeTopology`]: identical DGX nodes on an InfiniBand ring). The
//! interconnect bandwidth model feeds the link-level communication fabric
//! ([`fabric`](crate::fabric)), which is the only place link costs are
//! assembled into transfer plans.
//!
//! Substitution note (DESIGN.md §1): these are calibrated *effective*
//! bandwidths — what collective libraries achieve in practice, not link
//! peaks — so the LGR strategy crossovers match the paper's Table 7 shape.

use crate::vtime::A100_SM_COUNT;

/// A100 HBM capacity in GiB.
pub const A100_MEM_GIB: f64 = 40.0;

/// Effective NVLink/NVSwitch bandwidth per GPU pair for NCCL ring traffic
/// (bytes/s). DGX-A100: 600 GB/s aggregate per GPU; a single NCCL ring
/// sustains ~150 GB/s effective.
pub const NVLINK_BW: f64 = 150e9;

/// Per-operation latency of a NCCL collective launch (seconds).
pub const NCCL_LAT: f64 = 30e-6;

/// Effective host-staged inter-process bandwidth *per GPU's PCIe path*
/// (bytes/s). This is the paper's `B1`: D2H copy + shared-memory handoff +
/// H2D copy through a CPU-side collective (Gloo), far below PCIe peak.
pub const HOST_BW: f64 = 5e9;

/// Per-operation latency of a host-staged transfer (seconds): process
/// wakeup + pickling + IPC rendezvous.
pub const HOST_LAT: f64 = 150e-6;

/// CPU-side reduction throughput (bytes/s of summed output) — the paper's
/// MPR weakness (3): "relying on the slow CPU for reduction computation"
/// (a python-side gloo reduce, not a vectorized native loop).
pub const CPU_REDUCE_BW: f64 = 2e9;

/// Message size at which the host path reaches half its peak bandwidth.
/// Small transfers are dominated by per-message software overhead — the
/// §4.2 observation that fine-grained UCC sharing "largely underutilizes"
/// memory bandwidth, which the multi-channel compressor fixes by batching.
pub const HOST_MSG_HALF_BYTES: f64 = 2.0 * 1024.0 * 1024.0;

/// One physical GPU in the node.
#[derive(Debug, Clone)]
pub struct GpuDevice {
    pub id: usize,
    pub sm_count: usize,
    pub mem_gib: f64,
    /// Compute capability; sm_80 (A100) supports MIG, sm_70 (V100) does not.
    pub sm_arch: u32,
}

impl GpuDevice {
    pub fn a100(id: usize) -> Self {
        GpuDevice { id, sm_count: A100_SM_COUNT, mem_gib: A100_MEM_GIB, sm_arch: 80 }
    }

    pub fn v100(id: usize) -> Self {
        GpuDevice { id, sm_count: 80, mem_gib: 32.0, sm_arch: 70 }
    }

    pub fn supports_mig(&self) -> bool {
        self.sm_arch >= 80
    }
}

/// A multi-GPU node with an all-to-all NVSwitch fabric (DGX-A100) or a
/// PCIe-only box (no NVLink; NCCL falls back to host staging).
#[derive(Debug, Clone)]
pub struct Topology {
    pub gpus: Vec<GpuDevice>,
    pub has_nvlink: bool,
}

impl Topology {
    /// DGX-A100 with `n` of its 8 GPUs visible.
    pub fn dgx_a100(n: usize) -> Self {
        assert!(n >= 1 && n <= 8, "DGX-A100 has 8 GPUs, asked for {n}");
        Topology { gpus: (0..n).map(GpuDevice::a100).collect(), has_nvlink: true }
    }

    /// A cluster of `num_nodes` identical A100 nodes flattened into one
    /// GPU index space (`node * gpus_per_node + local`): the scheduler's
    /// view of a 16-node fleet, where node-granular failures take down a
    /// contiguous GPU range. Per-GPU host paths stay per-GPU; the shared
    /// NVSwitch link approximates the (never-saturated) inter-node fabric
    /// for the scheduler's co-run traffic.
    pub fn flat_cluster(num_nodes: usize, gpus_per_node: usize) -> Self {
        assert!(num_nodes >= 1 && gpus_per_node >= 1);
        Topology {
            gpus: (0..num_nodes * gpus_per_node).map(GpuDevice::a100).collect(),
            has_nvlink: true,
        }
    }

    /// A V100 box (sm_70): MPS only, no MIG (§3).
    pub fn v100_box(n: usize) -> Self {
        Topology { gpus: (0..n).map(GpuDevice::v100).collect(), has_nvlink: n > 1 }
    }

    pub fn num_gpus(&self) -> usize {
        self.gpus.len()
    }

    /// Effective inter-GPU bandwidth for one NCCL ring (bytes/s).
    pub fn inter_gpu_bw(&self) -> f64 {
        if self.has_nvlink {
            NVLINK_BW
        } else {
            HOST_BW
        }
    }

    /// Time for a NCCL ring allreduce over `k` endpoints of `bytes` each,
    /// with `rings_sharing` concurrent rings contending the fabric.
    pub fn ring_allreduce_time(&self, k: usize, bytes: usize, rings_sharing: usize) -> f64 {
        if k <= 1 {
            return 0.0;
        }
        let bw = self.inter_gpu_bw() / rings_sharing.max(1) as f64;
        let steps = 2 * (k - 1);
        NCCL_LAT * steps as f64 + steps as f64 * bytes as f64 / (k as f64 * bw)
    }

    /// Time to move `bytes` between two GMIs through host staging (D2H +
    /// handoff + H2D). `procs_sharing` processes contend the same GPU's
    /// PCIe path. Effective bandwidth degrades for small messages
    /// (HOST_MSG_HALF_BYTES) — the batching incentive of §4.2.
    pub fn host_transfer_time(&self, bytes: usize, procs_sharing: usize) -> f64 {
        let b = bytes as f64;
        let eff = (b / (b + HOST_MSG_HALF_BYTES)).max(0.02);
        HOST_LAT + b * procs_sharing.max(1) as f64 / (HOST_BW * eff)
    }
}

/// Effective per-node InfiniBand bandwidth (bytes/s): HDR 200 Gb/s link at
/// NCCL efficiency.
pub const IB_BW: f64 = 20e9;
/// Per-operation latency of an inter-node collective step.
pub const IB_LAT: f64 = 5e-6;

/// A cluster of identical DGX nodes joined by an InfiniBand ring (paper §8's
/// "intra- and inter-node GMI layout hierarchy").
#[derive(Debug, Clone)]
pub struct MultiNodeTopology {
    pub node: Topology,
    pub num_nodes: usize,
}

impl MultiNodeTopology {
    pub fn dgx_cluster(num_nodes: usize, gpus_per_node: usize) -> Self {
        assert!(num_nodes >= 1);
        MultiNodeTopology { node: Topology::dgx_a100(gpus_per_node), num_nodes }
    }

    /// Inter-node ring allreduce over `k` node leaders.
    pub fn ib_ring_time(&self, k: usize, bytes: usize) -> f64 {
        if k <= 1 {
            return 0.0;
        }
        let steps = 2 * (k - 1);
        steps as f64 * (IB_LAT + bytes as f64 / (k as f64 * IB_BW))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dgx_shape() {
        let t = Topology::dgx_a100(8);
        assert_eq!(t.num_gpus(), 8);
        assert!(t.gpus[0].supports_mig());
        assert_eq!(t.gpus[0].sm_count, 108);
    }

    #[test]
    #[should_panic]
    fn dgx_limit() {
        Topology::dgx_a100(9);
    }

    #[test]
    fn v100_has_no_mig() {
        let t = Topology::v100_box(2);
        assert!(!t.gpus[0].supports_mig());
    }

    #[test]
    fn ring_allreduce_scales() {
        let t = Topology::dgx_a100(4);
        let small = t.ring_allreduce_time(4, 1 << 20, 1);
        let big = t.ring_allreduce_time(4, 64 << 20, 1);
        // 64x the bytes: bandwidth term scales 64x, launch latency doesn't.
        assert!(big > small * 3.0, "big {big} small {small}");
        // contended rings are slower
        assert!(t.ring_allreduce_time(4, 1 << 20, 4) > small);
        // degenerate ring is free
        assert_eq!(t.ring_allreduce_time(1, 1 << 20, 1), 0.0);
    }

    #[test]
    fn host_transfer_contention() {
        let t = Topology::dgx_a100(1);
        let solo = t.host_transfer_time(8 << 20, 1);
        let shared = t.host_transfer_time(8 << 20, 4);
        assert!(shared > solo * 2.0);
    }

    #[test]
    fn nvlink_much_faster_than_host() {
        let t = Topology::dgx_a100(2);
        let nv = t.ring_allreduce_time(2, 16 << 20, 1);
        let host = t.host_transfer_time(16 << 20, 1) * 2.0;
        assert!(nv < host, "nvlink {nv} vs host {host}");
    }
}
