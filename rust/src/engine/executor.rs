//! The discrete-event GMI executor.
//!
//! An [`Engine`] owns one [`GmiExecutor`] per role task: the GMI's virtual
//! [`Clock`], its effective SM share (Direct-Share processes see the whole
//! GPU but time-slice it), its interference multiplier, and its busy-time
//! accounting. Orchestrators describe *work* ([`OpCharge`] sequences,
//! barriers, transfers); the engine turns it into clock advances and
//! utilization records, so no run loop touches `Clock`,
//! `UtilizationTracker`, or share math directly.

use anyhow::{Context, Result};

use crate::cluster::Topology;
use crate::fabric::{Fabric, Plan};
use crate::gmi::{GmiBackend, GmiId, GmiManager, GmiSpec};
use crate::metrics::UtilizationTracker;
use crate::vtime::{Clock, CostModel, OpKind};

/// Handle to one executor inside an [`Engine`] (stable for the engine's
/// lifetime; executors are never removed, only re-provisioned).
pub type ExecutorId = usize;

/// Longest op sequence one `charge` call accepts (rollout = sim + fwd,
/// training = grad + apply; nothing in the paper's loops needs more).
const MAX_OPS: usize = 8;

/// Effective SM share of a GMI for *timing*: Direct-Share processes all see
/// the whole GPU but time-slice it among `co_resident + 1` peers; MPS and
/// MIG provision the configured share.
pub fn eff_share(backend: GmiBackend, sm_share: f64, co_resident: usize) -> f64 {
    match backend {
        GmiBackend::DirectShare => 1.0 / (co_resident + 1) as f64,
        _ => sm_share,
    }
}

/// One operation inside a charge: what runs, at which timing share, and
/// whether its SM occupancy is recorded (pipeline-overlapped ops like the
/// A3C agent forward charge time but not utilization).
#[derive(Debug, Clone, Copy)]
pub struct OpCharge {
    pub op: OpKind,
    /// Override the share used for *timing* only (e.g. a TDG agent GMI
    /// running the forward at a fraction of the pair budget); occupancy is
    /// always recorded at the executor's own share.
    pub time_share: Option<f64>,
    pub record: bool,
}

impl OpCharge {
    pub fn recorded(op: OpKind) -> Self {
        OpCharge { op, time_share: None, record: true }
    }

    pub fn unrecorded(op: OpKind) -> Self {
        OpCharge { op, time_share: None, record: false }
    }

    pub fn with_time_share(mut self, share: f64) -> Self {
        self.time_share = Some(share);
        self
    }
}

/// Per-role-task execution state: the discrete-event unit of the engine.
#[derive(Debug, Clone)]
struct GmiExecutor {
    gmi: GmiId,
    gpu: usize,
    num_env: usize,
    co_resident: usize,
    /// Effective timing share (see [`eff_share`]).
    share: f64,
    /// Interference multiplier (>= 1) from the backend isolation model.
    interference: f64,
    clock: Clock,
    /// Virtual seconds spent computing (charges), as opposed to waiting at
    /// barriers/transfers — the elastic controller's bottleneck signal.
    busy_s: f64,
    /// Multi-tenant job tag ([`Engine::tag_job`]; None outside scheduler
    /// runs — untagged executors attribute no cross-job overhead).
    job: Option<usize>,
    /// Co-residents owned by OTHER jobs (0 when untagged or single-tenant).
    ext_co: usize,
    /// Interference multiplier with only same-job co-residents present —
    /// the counterfactual the cross-job attribution is measured against.
    solo_interference: f64,
    /// Accumulated compute seconds attributable to contention from other
    /// jobs' co-resident GMIs (the cluster scheduler's interference bill).
    xjob_s: f64,
}

/// The discrete-event execution engine one run loop drives.
///
/// The engine clones the layout's [`GmiManager`] at construction and owns
/// the *live* provisioning state: mid-run re-provisioning
/// ([`Engine::resize_share`]) validates against the cloned manager and
/// refreshes the affected executor, leaving the caller's static layout
/// untouched.
#[derive(Debug)]
pub struct Engine {
    manager: GmiManager,
    heaviness: f64,
    execs: Vec<GmiExecutor>,
    util: UtilizationTracker,
    comm_s: f64,
    /// Communication seconds attributed per job tag (multi-tenant runs).
    job_comm: std::collections::BTreeMap<usize, f64>,
    /// GMI id -> executor id. Executors are never removed, so entries are
    /// permanent; an O(log n) lookup replaces the historical O(n)
    /// `position()` scan that every charge-path caller paid.
    gmi_index: std::collections::BTreeMap<GmiId, ExecutorId>,
    /// Incrementally-maintained global clock frontier. Clocks only move
    /// forward (advance/merge are monotone), so a running max updated at
    /// every clock mutation is exactly the fold over all executors.
    span_max: f64,
    /// Per-GPU clock frontier (same running-max argument). Recomputed by
    /// scan only when an executor is re-pointed to a different GPU
    /// ([`Engine::add_gmi`] re-add) — the one event that can lower a GPU's
    /// frontier.
    gpu_frontier: Vec<f64>,
    /// Executor ids per GPU, ascending — refresh and frontier recompute
    /// walk these instead of scanning the whole fleet.
    gpu_execs: Vec<Vec<ExecutorId>>,
    /// Executor ids per job tag, ascending — per-job busy/interference
    /// totals sum over a job's own executors (same order as the historical
    /// whole-fleet filter scan, so totals are bit-identical).
    job_execs: std::collections::BTreeMap<usize, Vec<ExecutorId>>,
}

impl Engine {
    pub fn new(manager: &GmiManager, cost: &CostModel) -> Self {
        let gpus = manager.topology().num_gpus();
        Engine {
            manager: manager.clone(),
            heaviness: cost.heaviness,
            execs: Vec::new(),
            util: UtilizationTracker::new(),
            comm_s: 0.0,
            job_comm: std::collections::BTreeMap::new(),
            gmi_index: std::collections::BTreeMap::new(),
            span_max: 0.0,
            gpu_frontier: vec![0.0; gpus],
            gpu_execs: vec![Vec::new(); gpus],
            job_execs: std::collections::BTreeMap::new(),
        }
    }

    /// Grow the per-GPU structures to cover `gpu` (multi-node layouts can
    /// exceed the single-node topology's GPU count).
    #[inline]
    fn ensure_gpu(&mut self, gpu: usize) {
        if gpu >= self.gpu_frontier.len() {
            self.gpu_frontier.resize(gpu + 1, 0.0);
            self.gpu_execs.resize(gpu + 1, Vec::new());
        }
    }

    /// Fold a clock landing at `t` on `gpu` into the incremental frontiers.
    #[inline]
    fn note_time(&mut self, gpu: usize, t: f64) {
        if t > self.span_max {
            self.span_max = t;
        }
        if t > self.gpu_frontier[gpu] {
            self.gpu_frontier[gpu] = t;
        }
    }

    /// Rebuild one GPU's frontier by scan (only needed after a re-point
    /// moved an executor's history off this GPU).
    fn recompute_gpu_frontier(&mut self, gpu: usize) {
        self.ensure_gpu(gpu);
        let m = self.gpu_execs[gpu]
            .iter()
            .fold(0.0f64, |a, &i| a.max(self.execs[i].clock.seconds()));
        self.gpu_frontier[gpu] = m;
    }

    /// Register an executor for `gmi`. A GMI that already has an executor
    /// is not duplicated — the existing id is returned, so colocated roles
    /// (TCG_EX holistic GMIs running rollout *and* training) share one
    /// timeline.
    pub fn add_executor(&mut self, gmi: GmiId) -> Result<ExecutorId> {
        if let Some(&i) = self.gmi_index.get(&gmi) {
            // The index keeps entries for removed GMIs (their executors
            // are retired, never deleted). Handing such an executor out
            // here would let a caller silently charge work to a
            // deregistered GMI — the lifecycle bug behind dangling
            // post-`remove_gmi` references. Only a live registration may
            // resolve through the index; re-adding the id goes through
            // [`Engine::add_gmi`], which re-points the executor first.
            anyhow::ensure!(
                self.manager.gmi(gmi).is_some(),
                "GMI {gmi} was removed; its retired executor cannot be reused"
            );
            return Ok(i);
        }
        let spec = self.manager.gmi(gmi).with_context(|| format!("GMI {gmi} not registered"))?;
        let co = self.manager.co_resident(gmi);
        let interference = spec.backend.interference(co, self.heaviness);
        let gpu = spec.gpu;
        self.execs.push(GmiExecutor {
            gmi,
            gpu,
            num_env: spec.num_env,
            co_resident: co,
            share: eff_share(spec.backend, spec.sm_share, co),
            interference,
            clock: Clock::zero(),
            busy_s: 0.0,
            job: None,
            ext_co: 0,
            solo_interference: interference,
            xjob_s: 0.0,
        });
        let ex = self.execs.len() - 1;
        self.gmi_index.insert(gmi, ex);
        self.ensure_gpu(gpu);
        self.gpu_execs[gpu].push(ex);
        Ok(ex)
    }

    /// Register one executor per GMI id, in order (deduplicating shared
    /// GMIs — see [`Engine::add_executor`]).
    pub fn add_group(&mut self, gmis: &[GmiId]) -> Result<Vec<ExecutorId>> {
        gmis.iter().map(|&g| self.add_executor(g)).collect()
    }

    // ---- charging ----

    /// Charge `reps` repetitions of an op sequence: the executor's clock
    /// advances by `reps * (Σ op_time + extra_per_rep)` in one step (the
    /// ops pipeline within a repetition), SM occupancy is recorded for
    /// every op marked `record`, and the clock after the charge is
    /// returned. `extra_per_rep` models per-repetition time that occupies
    /// no SMs (e.g. a TDG boundary crossing per interaction step); it
    /// extends the clock but not the busy accounting.
    pub fn charge_steps(
        &mut self,
        cost: &CostModel,
        id: ExecutorId,
        reps: f64,
        ops: &[OpCharge],
        extra_per_rep: f64,
    ) -> Clock {
        self.charge_inner(cost, id, reps, ops, extra_per_rep, None)
    }

    /// Blocking-receive charge: wait until `ready`, then run the op
    /// sequence once (the A3C trainer consuming a batch the moment it
    /// arrives).
    pub fn charge_after(
        &mut self,
        cost: &CostModel,
        id: ExecutorId,
        ready: Clock,
        ops: &[OpCharge],
    ) -> Clock {
        self.charge_inner(cost, id, 1.0, ops, 0.0, Some(ready))
    }

    fn charge_inner(
        &mut self,
        cost: &CostModel,
        id: ExecutorId,
        reps: f64,
        ops: &[OpCharge],
        extra_per_rep: f64,
        after: Option<Clock>,
    ) -> Clock {
        assert!(ops.len() <= MAX_OPS, "charge of {} ops (max {MAX_OPS})", ops.len());
        let e = &mut self.execs[id];
        let mut times = [0.0f64; MAX_OPS];
        let mut op_sum = 0.0f64;
        for (k, c) in ops.iter().enumerate() {
            let t = cost.op_time(c.op, c.time_share.unwrap_or(e.share), e.interference);
            times[k] = t;
            op_sum += t;
        }
        let dur = reps * (op_sum + extra_per_rep);
        let end = match after {
            Some(ready) => e.clock.merge_then_advance(ready, dur),
            None => e.clock.advance(dur),
        };
        e.busy_s += reps * op_sum;
        // Cross-job interference bill: op_time scales linearly in the
        // interference multiplier, so the share of this charge owed to
        // other tenants' co-residents is exactly 1 - solo/actual.
        if e.ext_co > 0 && e.interference > 0.0 {
            e.xjob_s += reps * op_sum * (1.0 - e.solo_interference / e.interference);
        }
        let (gpu, share) = (e.gpu, e.share);
        self.note_time(gpu, end.seconds());
        for (k, c) in ops.iter().enumerate() {
            if c.record {
                let occ = cost.sm_occupancy(c.op, share);
                self.util.record(gpu, occ, reps * times[k], end.seconds());
            }
        }
        end
    }

    // ---- communication primitives ----

    /// Un-recorded time on one executor's own timeline (per-message IPC
    /// submission, a pushed-parameter receive): advances the clock without
    /// touching utilization, busy, or communication accounting.
    pub fn pay(&mut self, id: ExecutorId, dt: f64) -> Clock {
        let e = &mut self.execs[id];
        let end = e.clock.advance(dt);
        let gpu = e.gpu;
        self.note_time(gpu, end.seconds());
        end
    }

    /// [`Engine::pay`] on every member of a group.
    pub fn pay_group(&mut self, ids: &[ExecutorId], dt: f64) {
        for &i in ids {
            let e = &mut self.execs[i];
            let end = e.clock.advance(dt);
            let gpu = e.gpu;
            self.note_time(gpu, end.seconds());
        }
    }

    /// Count `dt` seconds of communication, attributing it to the job tag
    /// of `carrier` (the first participant) when tagged — per-job comm
    /// totals for multi-tenant runs, the global total always.
    fn charge_comm(&mut self, carrier: Option<ExecutorId>, dt: f64) {
        self.comm_s += dt;
        if let Some(i) = carrier {
            if let Some(j) = self.execs[i].job {
                *self.job_comm.entry(j).or_insert(0.0) += dt;
            }
        }
    }

    /// Barrier + collective: every member waits for the group maximum,
    /// then advances by `dt` (one LGR reduction). `dt` is counted once as
    /// communication time.
    pub fn barrier_advance(&mut self, ids: &[ExecutorId], dt: f64) {
        let barrier = self.max_time(ids);
        for &i in ids {
            let e = &mut self.execs[i];
            let end = e.clock.merge_then_advance(barrier, dt);
            let gpu = e.gpu;
            self.note_time(gpu, end.seconds());
        }
        self.charge_comm(ids.first().copied(), dt);
    }

    /// Point-to-point receive: `id` waits until `ready` (the sender's send
    /// timestamp or a feeder-group max), then pays `dt` of transfer time,
    /// counted as communication.
    pub fn recv(&mut self, id: ExecutorId, ready: Clock, dt: f64) -> Clock {
        self.charge_comm(Some(id), dt);
        let e = &mut self.execs[id];
        let end = e.clock.merge_then_advance(ready, dt);
        let gpu = e.gpu;
        self.note_time(gpu, end.seconds());
        end
    }

    /// One-to-many broadcast: every receiver waits for `from`, then pays
    /// `dt`; counted once as communication (a single fan-out transfer).
    pub fn broadcast(&mut self, ids: &[ExecutorId], from: Clock, dt: f64) {
        for &i in ids {
            let e = &mut self.execs[i];
            let end = e.clock.merge_then_advance(from, dt);
            let gpu = e.gpu;
            self.note_time(gpu, end.seconds());
        }
        self.charge_comm(ids.first().copied(), dt);
    }

    // ---- fabric collectives (transfer plans as engine events) ----

    /// Merge every member's clock forward to `ready` (no communication
    /// charge) — the drain point of an overlapped collective.
    pub fn wait_group(&mut self, ids: &[ExecutorId], ready: Clock) {
        for &i in ids {
            let e = &mut self.execs[i];
            let end = e.clock.merge_then_advance(ready, 0.0);
            let gpu = e.gpu;
            self.note_time(gpu, end.seconds());
        }
    }

    /// Issue a collective over `ids` *without blocking them*: the plan
    /// starts at the group's current max clock (every participant's input
    /// is ready), drains on the fabric — serializing against other plans on
    /// the same links — and the completion clock is returned. Participant
    /// clocks are untouched, so their compute overlaps the transfer; the
    /// caller re-synchronizes where the data dependency actually lands
    /// ([`Engine::wait_group`] or a `charge_after`). The plan's own link
    /// time is counted once as communication (queueing behind an earlier
    /// plan is that plan's already-counted drain, not new transfer time).
    pub fn collective_overlapped(
        &mut self,
        fabric: &mut Fabric,
        ids: &[ExecutorId],
        plan: &Plan,
    ) -> Clock {
        let start = self.max_time(ids);
        let done = fabric.execute(plan, start);
        self.charge_comm(ids.first().copied(), plan.total_s());
        done
    }

    /// Blocking collective: issue the plan at the group max and make every
    /// participant wait for its completion (the sequential schedule).
    pub fn collective(&mut self, fabric: &mut Fabric, ids: &[ExecutorId], plan: &Plan) -> Clock {
        let done = self.collective_overlapped(fabric, ids, plan);
        self.wait_group(ids, done);
        done
    }

    /// Point-to-point / gather plan as a blocking receive: the transfer
    /// starts when both the payload (`ready`) and the receiver are ready,
    /// drains on the fabric, and the receiver's clock lands at the arrival.
    pub fn recv_plan(
        &mut self,
        fabric: &mut Fabric,
        id: ExecutorId,
        ready: Clock,
        plan: &Plan,
    ) -> Clock {
        let start = Clock(self.execs[id].clock.seconds().max(ready.seconds()));
        let done = fabric.execute(plan, start);
        self.charge_comm(Some(id), plan.total_s());
        let e = &mut self.execs[id];
        let end = e.clock.merge_then_advance(done, 0.0);
        let gpu = e.gpu;
        self.note_time(gpu, end.seconds());
        done
    }

    /// Fan-out plan: the payload leaves at `from`, drains once on the
    /// fabric, and every receiver waits for the arrival.
    pub fn broadcast_plan(
        &mut self,
        fabric: &mut Fabric,
        ids: &[ExecutorId],
        from: Clock,
        plan: &Plan,
    ) -> Clock {
        let done = fabric.execute(plan, from);
        self.charge_comm(ids.first().copied(), plan.total_s());
        self.wait_group(ids, done);
        done
    }

    // ---- timeline / accounting queries ----

    pub fn clock(&self, id: ExecutorId) -> Clock {
        self.execs[id].clock
    }

    /// Latest clock of a group (barrier value; `Clock::zero()` when empty).
    pub fn max_time(&self, ids: &[ExecutorId]) -> Clock {
        Clock(ids.iter().fold(0.0f64, |a, &i| a.max(self.execs[i].clock.seconds())))
    }

    /// Latest clock over every executor — the run's virtual span. O(1):
    /// the frontier is maintained incrementally at every clock mutation
    /// (clocks are monotone, so a running max is exact).
    pub fn span(&self) -> f64 {
        self.span_max
    }

    /// Latest virtual time of any executor on `gpu` (per-GPU timeline).
    /// O(1) via the incrementally-maintained per-GPU frontier.
    pub fn gpu_time(&self, gpu: usize) -> f64 {
        self.gpu_frontier.get(gpu).copied().unwrap_or(0.0)
    }

    /// Reference fold-over-all-executors implementation of
    /// [`Engine::span`] — kept for the incremental-vs-scan equivalence
    /// goldens and benchmarks; not a public API.
    #[doc(hidden)]
    pub fn span_scan(&self) -> f64 {
        self.execs.iter().fold(0.0f64, |a, e| a.max(e.clock.seconds()))
    }

    /// Reference scan implementation of [`Engine::gpu_time`] (see
    /// [`Engine::span_scan`]).
    #[doc(hidden)]
    pub fn gpu_time_scan(&self, gpu: usize) -> f64 {
        self.execs
            .iter()
            .filter(|e| e.gpu == gpu)
            .fold(0.0f64, |a, e| a.max(e.clock.seconds()))
    }

    pub fn gpu_utilization(&self, gpu: usize) -> f64 {
        self.util.gpu_utilization(gpu)
    }

    pub fn mean_utilization(&self) -> f64 {
        self.util.mean_utilization()
    }

    /// Communication seconds charged through barrier/recv/broadcast.
    pub fn comm_s(&self) -> f64 {
        self.comm_s
    }

    /// Virtual seconds executor `id` spent computing (vs waiting).
    pub fn busy_seconds(&self, id: ExecutorId) -> f64 {
        self.execs[id].busy_s
    }

    // ---- multi-tenant job accounting ----

    /// Tag an executor (and its GMI in the live manager) as owned by
    /// `job`: subsequent charges attribute cross-job interference, comm
    /// primitives bill the job's comm total, and the manager's removal
    /// floor guard applies. Co-resident executors are refreshed so their
    /// external-tenant counts see the new ownership.
    pub fn tag_job(&mut self, id: ExecutorId, job: usize) -> Result<()> {
        let (gmi, gpu) = (self.execs[id].gmi, self.execs[id].gpu);
        // Manager first: a failure (retired executor, unknown GMI) must
        // leave engine- and manager-side ownership consistent.
        self.manager.tag_job(gmi, job)?;
        let prev = self.execs[id].job;
        if prev != Some(job) {
            if let Some(p) = prev {
                if let Some(v) = self.job_execs.get_mut(&p) {
                    if let Ok(k) = v.binary_search(&id) {
                        v.remove(k);
                    }
                }
            }
            let v = self.job_execs.entry(job).or_default();
            if let Err(k) = v.binary_search(&id) {
                v.insert(k, id);
            }
        }
        self.execs[id].job = Some(job);
        self.refresh_gpu(gpu);
        Ok(())
    }

    /// Pass-through to [`GmiManager::set_job_floor`] on the live manager.
    pub fn set_job_floor(&mut self, job: usize, min_total_share: f64) {
        self.manager.set_job_floor(job, min_total_share);
    }

    /// Release a completed job's claim in the live manager (floor + tags);
    /// executor tags stay for post-run accounting queries.
    pub fn clear_job(&mut self, job: usize) {
        self.manager.clear_job(job);
    }

    /// Job tag of an executor, if any.
    pub fn job_of_executor(&self, id: ExecutorId) -> Option<usize> {
        self.execs[id].job
    }

    /// Total busy seconds across every executor tagged to `job` (retired
    /// executors included — service already rendered stays counted). Sums
    /// over the job's own member list (ascending executor order — the same
    /// order as the historical whole-fleet filter scan, so the total is
    /// bit-identical) instead of scanning every executor.
    pub fn job_busy_s(&self, job: usize) -> f64 {
        self.job_execs
            .get(&job)
            .map(|v| v.iter().map(|&i| self.execs[i].busy_s).sum())
            .unwrap_or(0.0)
    }

    /// Reference whole-fleet filter-scan implementation of
    /// [`Engine::job_busy_s`] (equivalence goldens; not a public API).
    #[doc(hidden)]
    pub fn job_busy_s_scan(&self, job: usize) -> f64 {
        self.execs.iter().filter(|e| e.job == Some(job)).map(|e| e.busy_s).sum()
    }

    /// Communication seconds attributed to `job`'s executors.
    pub fn job_comm_s(&self, job: usize) -> f64 {
        self.job_comm.get(&job).copied().unwrap_or(0.0)
    }

    /// Compute seconds executor `id` lost to other tenants' co-resident
    /// GMIs (the cross-job interference bill; 0 when untagged).
    pub fn xjob_interference_s(&self, id: ExecutorId) -> f64 {
        self.execs[id].xjob_s
    }

    /// Total cross-job interference seconds billed to `job` (member-list
    /// sum, bit-identical to the historical filter scan — see
    /// [`Engine::job_busy_s`]).
    pub fn job_xjob_s(&self, job: usize) -> f64 {
        self.job_execs
            .get(&job)
            .map(|v| v.iter().map(|&i| self.execs[i].xjob_s).sum())
            .unwrap_or(0.0)
    }

    /// Reference filter-scan implementation of [`Engine::job_xjob_s`]
    /// (equivalence goldens; not a public API).
    #[doc(hidden)]
    pub fn job_xjob_s_scan(&self, job: usize) -> f64 {
        self.execs.iter().filter(|e| e.job == Some(job)).map(|e| e.xjob_s).sum()
    }

    pub fn gmi_of(&self, id: ExecutorId) -> GmiId {
        self.execs[id].gmi
    }

    pub fn gpu(&self, id: ExecutorId) -> usize {
        self.execs[id].gpu
    }

    pub fn num_env(&self, id: ExecutorId) -> usize {
        self.execs[id].num_env
    }

    pub fn co_resident(&self, id: ExecutorId) -> usize {
        self.execs[id].co_resident
    }

    /// Effective timing share currently provisioned for `id`.
    pub fn share(&self, id: ExecutorId) -> f64 {
        self.execs[id].share
    }

    /// The engine's live provisioning state (diverges from the layout's
    /// manager once elastic re-provisioning runs).
    pub fn manager(&self) -> &GmiManager {
        &self.manager
    }

    pub fn topology(&self) -> &Topology {
        self.manager.topology()
    }

    // ---- elastic re-provisioning ----

    /// Re-provision a GMI's SM share (memory unchanged), validated by the
    /// live manager, and refresh the executor's timing parameters. Charges
    /// already on the timeline keep their historical cost; only subsequent
    /// ops see the new share.
    pub fn resize_share(&mut self, gmi: GmiId, sm_share: f64) -> Result<()> {
        let mem = self
            .manager
            .gmi(gmi)
            .with_context(|| format!("GMI {gmi} not registered"))?
            .mem_gib;
        self.resize(gmi, sm_share, mem)
    }

    /// Re-provision a GMI's SM share and memory budget (see
    /// [`Engine::resize_share`]).
    pub fn resize(&mut self, gmi: GmiId, sm_share: f64, mem_gib: f64) -> Result<()> {
        self.manager.resize_gmi(gmi, sm_share, mem_gib)?;
        self.refresh(gmi);
        Ok(())
    }

    /// Register and provision a NEW GMI mid-run (the autoscaler's
    /// scale-up): the spec passes the live manager's full placement
    /// validation, an executor is provisioned for it, and every
    /// co-resident executor's timing parameters are refreshed for the
    /// changed contention. A brand-new GMI gets a fresh executor (clock at
    /// zero — immediately available); re-adding a previously removed GMI
    /// id re-points its retired executor at the new placement, keeping the
    /// clock monotone (available from its retirement time onward).
    pub fn add_gmi(&mut self, spec: GmiSpec) -> Result<ExecutorId> {
        let gpu = spec.gpu;
        let id = spec.id;
        self.manager.add_gmi(spec)?;
        let ex = match self.gmi_index.get(&id).copied() {
            // A retired executor with this GMI id still exists: re-point
            // it instead of aliasing its stale placement.
            Some(pos) => {
                let (new_gpu, new_env) = {
                    let s = self.manager.gmi(id).expect("GMI just registered");
                    (s.gpu, s.num_env)
                };
                let old_gpu = self.execs[pos].gpu;
                self.execs[pos].gpu = new_gpu;
                self.execs[pos].num_env = new_env;
                if old_gpu != new_gpu {
                    self.ensure_gpu(new_gpu);
                    if let Ok(k) = self.gpu_execs[old_gpu].binary_search(&pos) {
                        self.gpu_execs[old_gpu].remove(k);
                    }
                    if let Err(k) = self.gpu_execs[new_gpu].binary_search(&pos) {
                        self.gpu_execs[new_gpu].insert(k, pos);
                    }
                    // The executor's clock history left old_gpu: that
                    // frontier can shrink, so rebuild it by scan (rare —
                    // only on cross-GPU re-adds). The new GPU's frontier
                    // only grows, a running-max update.
                    self.recompute_gpu_frontier(old_gpu);
                    let t = self.execs[pos].clock.seconds();
                    self.note_time(new_gpu, t);
                    self.refresh_gpu(old_gpu);
                }
                pos
            }
            None => self.add_executor(id)?,
        };
        self.refresh_gpu(gpu);
        Ok(ex)
    }

    /// Deregister a GMI mid-run (the autoscaler's scale-down): its SM share
    /// and memory are freed for co-residents, whose executors are
    /// refreshed. The retired GMI's executor stays in place with a frozen
    /// clock (executor ids are stable for the engine's lifetime) — callers
    /// must simply stop charging it.
    pub fn remove_gmi(&mut self, gmi: GmiId) -> Result<GmiSpec> {
        let spec = self.manager.remove_gmi(gmi)?;
        self.refresh_gpu(spec.gpu);
        Ok(spec)
    }

    /// Recompute an executor's share/interference (and its external-tenant
    /// co-resident count) from the live manager.
    fn refresh(&mut self, gmi: GmiId) {
        let Some(&pos) = self.gmi_index.get(&gmi) else { return };
        let spec = self.manager.gmi(gmi).expect("refreshed GMI is registered");
        let co = self.manager.co_resident(gmi);
        // Co-residents tagged to a DIFFERENT job; untagged peers count as
        // same-tenant so single-tenant runs attribute nothing.
        let ext = match self.execs[pos].job {
            None => 0,
            Some(j) => self
                .manager
                .all()
                .filter(|o| o.gpu == spec.gpu && o.id != gmi)
                .filter(|o| self.manager.job_of(o.id).is_some_and(|oj| oj != j))
                .count(),
        };
        let backend = spec.backend;
        let sm_share = spec.sm_share;
        let e = &mut self.execs[pos];
        e.co_resident = co;
        e.share = eff_share(backend, sm_share, co);
        e.interference = backend.interference(co, self.heaviness);
        e.ext_co = ext;
        e.solo_interference = backend.interference(co - ext, self.heaviness);
    }

    /// Refresh every still-registered executor on `gpu` (after a GMI was
    /// added to or removed from it). Walks the GPU's own executor list
    /// (ascending, same order as the historical whole-fleet scan) with no
    /// temporary allocation.
    fn refresh_gpu(&mut self, gpu: usize) {
        if gpu >= self.gpu_execs.len() {
            return;
        }
        let mut k = 0;
        while k < self.gpu_execs[gpu].len() {
            let ex = self.gpu_execs[gpu][k];
            let g = self.execs[ex].gmi;
            if self.manager.gmi(g).is_some() {
                self.refresh(g);
            }
            k += 1;
        }
    }

    /// Assert every incrementally-maintained structure (id→index map,
    /// span/per-GPU frontiers, per-job member lists) agrees bit-for-bit
    /// with its reference fold/filter scan. Test and golden support; not a
    /// public API.
    #[doc(hidden)]
    pub fn audit_incremental_state(&self) {
        assert_eq!(
            self.span_scan().to_bits(),
            self.span().to_bits(),
            "span frontier diverged from scan"
        );
        let gpus = self.gpu_frontier.len().max(self.manager.topology().num_gpus());
        for g in 0..gpus {
            assert_eq!(
                self.gpu_time_scan(g).to_bits(),
                self.gpu_time(g).to_bits(),
                "gpu {g} frontier diverged from scan"
            );
        }
        for (i, e) in self.execs.iter().enumerate() {
            assert_eq!(
                self.gmi_index.get(&e.gmi).copied(),
                Some(i),
                "gmi {} index entry diverged",
                e.gmi
            );
        }
        let jobs: std::collections::BTreeSet<usize> =
            self.execs.iter().filter_map(|e| e.job).collect();
        for j in jobs {
            assert_eq!(
                self.job_busy_s_scan(j).to_bits(),
                self.job_busy_s(j).to_bits(),
                "job {j} busy total diverged from scan"
            );
            assert_eq!(
                self.job_xjob_s_scan(j).to_bits(),
                self.job_xjob_s(j).to_bits(),
                "job {j} xjob total diverged from scan"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::static_registry;
    use crate::gmi::{GmiSpec, Role};

    fn setup(shares: &[f64]) -> (Engine, Vec<ExecutorId>, CostModel) {
        let b = static_registry()["AT"].clone();
        let cost = CostModel::new(&b);
        let mut m = GmiManager::new(Topology::dgx_a100(1));
        for (id, &s) in shares.iter().enumerate() {
            m.add_gmi(GmiSpec {
                id,
                gpu: 0,
                sm_share: s,
                mem_gib: 5.0,
                backend: GmiBackend::Mps,
                role: Role::Holistic,
                num_env: 512,
            })
            .unwrap();
        }
        let mut e = Engine::new(&m, &cost);
        let ids = e.add_group(&(0..shares.len()).collect::<Vec<_>>()).unwrap();
        (e, ids, cost)
    }

    #[test]
    fn executors_dedup_per_gmi() {
        let (mut e, ids, _) = setup(&[0.4, 0.4]);
        assert_eq!(ids, vec![0, 1]);
        // A second group over the same GMIs aliases the same executors.
        let again = e.add_group(&[0, 1]).unwrap();
        assert_eq!(again, ids);
        assert!(e.add_executor(9).is_err());
    }

    #[test]
    fn charge_matches_manual_clock_arithmetic() {
        let (mut e, ids, cost) = setup(&[0.4, 0.4]);
        let op = OpKind::SimStep { num_env: 512 };
        let fwd = OpKind::PolicyFwd { num_env: 512 };
        let t_sim = cost.op_time(op, e.share(ids[0]), 1.0 + 0.03 * cost.heaviness);
        let t_fwd = cost.op_time(fwd, e.share(ids[0]), 1.0 + 0.03 * cost.heaviness);
        let end = e.charge_steps(
            &cost,
            ids[0],
            16.0,
            &[OpCharge::recorded(op), OpCharge::recorded(fwd)],
            0.0,
        );
        assert_eq!(end.seconds(), 16.0 * (t_sim + t_fwd));
        assert_eq!(e.clock(ids[0]).seconds(), end.seconds());
        assert_eq!(e.busy_seconds(ids[0]), end.seconds());
        assert!(e.mean_utilization() > 0.0);
        // The second executor never ran.
        assert_eq!(e.clock(ids[1]).seconds(), 0.0);
    }

    #[test]
    fn unrecorded_ops_charge_time_but_no_utilization() {
        let (mut e, ids, cost) = setup(&[0.4]);
        let end = e.charge_steps(
            &cost,
            ids[0],
            4.0,
            &[OpCharge::unrecorded(OpKind::AdamApply)],
            0.0,
        );
        assert!(end.seconds() > 0.0);
        assert_eq!(e.mean_utilization(), 0.0);
    }

    #[test]
    fn pay_is_idle_time() {
        let (mut e, ids, _) = setup(&[0.4, 0.4]);
        e.pay(ids[0], 1.5);
        e.pay_group(&ids, 0.5);
        assert_eq!(e.clock(ids[0]).seconds(), 2.0);
        assert_eq!(e.clock(ids[1]).seconds(), 0.5);
        assert_eq!(e.busy_seconds(ids[0]), 0.0);
        assert_eq!(e.comm_s(), 0.0);
    }

    #[test]
    fn barrier_merges_to_max_and_counts_comm_once() {
        let (mut e, ids, _) = setup(&[0.4, 0.4]);
        e.pay(ids[0], 3.0);
        e.pay(ids[1], 1.0);
        e.barrier_advance(&ids, 0.25);
        assert_eq!(e.clock(ids[0]).seconds(), 3.25);
        assert_eq!(e.clock(ids[1]).seconds(), 3.25);
        assert_eq!(e.comm_s(), 0.25);
        assert_eq!(e.max_time(&ids).seconds(), 3.25);
        assert_eq!(e.span(), 3.25);
        assert_eq!(e.gpu_time(0), 3.25);
        assert_eq!(e.gpu_time(3), 0.0);
    }

    #[test]
    fn recv_and_broadcast_account_transfers() {
        let (mut e, ids, cost) = setup(&[0.4, 0.4]);
        let sender_t = e.charge_after(
            &cost,
            ids[0],
            Clock(2.0),
            &[OpCharge::recorded(OpKind::AdamApply)],
        );
        assert!(sender_t.seconds() > 2.0);
        e.recv(ids[1], sender_t, 0.5);
        assert_eq!(e.clock(ids[1]).seconds(), sender_t.seconds() + 0.5);
        e.broadcast(&ids, e.max_time(&ids), 0.1);
        // comm counted once per primitive call.
        assert!((e.comm_s() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn resize_updates_timing_and_validates() {
        let (mut e, ids, cost) = setup(&[0.5, 0.4]);
        let slow = cost.op_time(OpKind::TrainGrad { samples: 1024 }, e.share(ids[1]), 1.0);
        // Growing past the peer's reservation fails and changes nothing.
        assert!(e.resize_share(0, 0.7).is_err());
        assert_eq!(e.share(ids[0]), 0.5);
        // Shrink the donor, then grow the receiver into the freed share.
        e.resize_share(0, 0.3).unwrap();
        e.resize_share(1, 0.6).unwrap();
        assert_eq!(e.share(ids[1]), 0.6);
        let fast = cost.op_time(OpKind::TrainGrad { samples: 1024 }, e.share(ids[1]), 1.0);
        assert!(fast < slow, "more share must speed GEMM work up");
        // The caller-visible manager reflects the live provisioning.
        assert_eq!(e.manager().gmi(0).unwrap().sm_share, 0.3);
    }

    #[test]
    fn fabric_collectives_overlap_and_serialize() {
        let (mut e, ids, _) = setup(&[0.4, 0.4]);
        let mut fabric = Fabric::single_node(Topology::dgx_a100(1));
        let plan = fabric.plan_intra_gpu(8 << 20, 1, 0);
        e.pay(ids[0], 1.0);
        let done = e.collective_overlapped(&mut fabric, &ids, &plan);
        assert!((done.seconds() - (1.0 + plan.total_s())).abs() < 1e-12);
        // Overlapped: participants did not block on the drain.
        assert_eq!(e.clock(ids[0]).seconds(), 1.0);
        assert_eq!(e.clock(ids[1]).seconds(), 0.0);
        assert!((e.comm_s() - plan.total_s()).abs() < 1e-12);
        // Blocking variant lands everyone at completion and serializes
        // against the first plan's link occupancy.
        let done2 = e.collective(&mut fabric, &ids, &plan);
        assert!(done2.seconds() >= done.seconds() + plan.total_s() - 1e-12);
        assert_eq!(e.clock(ids[0]).seconds(), done2.seconds());
        assert_eq!(e.clock(ids[1]).seconds(), done2.seconds());
        // wait_group never moves clocks backwards.
        e.wait_group(&ids, Clock(0.5));
        assert_eq!(e.clock(ids[0]).seconds(), done2.seconds());
    }

    #[test]
    fn recv_plan_merges_receiver_to_arrival() {
        let (mut e, ids, _) = setup(&[0.4, 0.4]);
        let mut fabric = Fabric::single_node(Topology::dgx_a100(1));
        let plan = fabric.plan_gather(2, 1 << 20, 0);
        let done = e.recv_plan(&mut fabric, ids[1], Clock(2.0), &plan);
        assert_eq!(e.clock(ids[1]).seconds(), done.seconds());
        assert!((done.seconds() - (2.0 + plan.total_s())).abs() < 1e-12);
        // The sender-side executor is untouched.
        assert_eq!(e.clock(ids[0]).seconds(), 0.0);
    }

    #[test]
    fn add_and_remove_gmis_mid_run() {
        let (mut e, ids, cost) = setup(&[0.4, 0.4]);
        assert_eq!(e.co_resident(ids[0]), 1);
        // A new GMI lands in the free 0.2 of GPU 0; incumbents see the
        // extra co-resident.
        let ex = e
            .add_gmi(GmiSpec {
                id: 7,
                gpu: 0,
                sm_share: 0.2,
                mem_gib: 5.0,
                backend: GmiBackend::Mps,
                role: Role::Holistic,
                num_env: 128,
            })
            .unwrap();
        assert_eq!(e.share(ex), 0.2);
        assert_eq!(e.co_resident(ids[0]), 2);
        assert_eq!(e.manager().len(), 3);
        // Oversubscription is rejected by the live manager's validation.
        assert!(e
            .add_gmi(GmiSpec {
                id: 8,
                gpu: 0,
                sm_share: 0.5,
                mem_gib: 5.0,
                backend: GmiBackend::Mps,
                role: Role::Holistic,
                num_env: 128,
            })
            .is_err());
        // The new executor charges like any other.
        let end = e.charge_steps(
            &cost,
            ex,
            2.0,
            &[OpCharge::recorded(OpKind::SimStep { num_env: 128 })],
            0.0,
        );
        assert!(end.seconds() > 0.0);
        // Removal frees the share for a peer to grow into.
        let freed = e.remove_gmi(7).unwrap();
        assert_eq!(freed.id, 7);
        assert_eq!(e.co_resident(ids[0]), 1);
        e.resize_share(0, 0.6).unwrap();
        assert!(e.remove_gmi(42).is_err());
        // Re-adding the same GMI id on ANOTHER GPU re-points the retired
        // executor: placement and timing parameters track the new spec.
        let ex2 = e
            .add_gmi(GmiSpec {
                id: 7,
                gpu: 1,
                sm_share: 0.5,
                mem_gib: 5.0,
                backend: GmiBackend::Mps,
                role: Role::Holistic,
                num_env: 256,
            })
            .unwrap();
        assert_eq!(ex2, ex, "executor ids are stable across re-adds");
        assert_eq!(e.gpu(ex2), 1);
        assert_eq!(e.num_env(ex2), 256);
        assert_eq!(e.share(ex2), 0.5);
        assert_eq!(e.co_resident(ex2), 0);
        // Its clock stayed monotone (frozen at the pre-removal charge).
        assert_eq!(e.clock(ex2).seconds(), end.seconds());
    }

    #[test]
    fn job_tags_attribute_comm_and_cross_job_interference() {
        let (mut e, ids, cost) = setup(&[0.4, 0.4]);
        e.tag_job(ids[0], 1).unwrap();
        e.tag_job(ids[1], 2).unwrap();
        // Both executors now see one external-tenant co-resident, so a
        // charge splits into solo time + a cross-job interference bill.
        let end = e.charge_steps(
            &cost,
            ids[0],
            4.0,
            &[OpCharge::recorded(OpKind::TrainGrad { samples: 1024 })],
            0.0,
        );
        let interf = 1.0 + 0.03 * cost.heaviness; // MPS, 1 co-resident
        let want_x = end.seconds() * (1.0 - 1.0 / interf);
        assert!(e.xjob_interference_s(ids[0]) > 0.0);
        assert!((e.xjob_interference_s(ids[0]) - want_x).abs() < 1e-12);
        assert!((e.job_xjob_s(1) - want_x).abs() < 1e-12);
        assert_eq!(e.xjob_interference_s(ids[1]), 0.0, "peer never charged");
        assert_eq!(e.job_of_executor(ids[0]), Some(1));
        assert!((e.job_busy_s(1) - end.seconds()).abs() < 1e-12);
        assert_eq!(e.job_busy_s(2), 0.0);
        // Comm primitives bill the carrier's job.
        e.recv(ids[0], Clock(1.0), 0.25);
        e.barrier_advance(&[ids[1]], 0.5);
        assert!((e.job_comm_s(1) - 0.25).abs() < 1e-12);
        assert!((e.job_comm_s(2) - 0.5).abs() < 1e-12);
        assert!((e.comm_s() - 0.75).abs() < 1e-12);
        // The live manager carries the ownership for the floor guard.
        assert_eq!(e.manager().job_of(0), Some(1));
        e.set_job_floor(1, 0.4);
        assert!(e.remove_gmi(0).is_err(), "floor must block the removal");
        e.clear_job(1);
        e.remove_gmi(0).unwrap();
    }

    #[test]
    fn same_job_co_residents_bill_no_cross_job_interference() {
        let (mut e, ids, cost) = setup(&[0.4, 0.4]);
        e.tag_job(ids[0], 1).unwrap();
        e.tag_job(ids[1], 1).unwrap();
        e.charge_steps(
            &cost,
            ids[0],
            4.0,
            &[OpCharge::recorded(OpKind::TrainGrad { samples: 1024 })],
            0.0,
        );
        assert_eq!(e.xjob_interference_s(ids[0]), 0.0);
        assert_eq!(e.job_xjob_s(1), 0.0);
        // Untagged runs (the single-tenant default) attribute nothing too.
        let (mut u, uids, cost2) = setup(&[0.4, 0.4]);
        u.charge_steps(
            &cost2,
            uids[0],
            4.0,
            &[OpCharge::recorded(OpKind::TrainGrad { samples: 1024 })],
            0.0,
        );
        assert_eq!(u.xjob_interference_s(uids[0]), 0.0);
        assert_eq!(u.job_comm_s(0), 0.0);
    }

    /// Equivalence golden for the incremental frontier structures: every
    /// clock-mutating primitive must leave span / per-GPU frontiers /
    /// id→index map / per-job totals bit-identical to the reference scans
    /// they replaced.
    #[test]
    fn incremental_frontiers_match_reference_scans() {
        let (mut e, ids, cost) = setup(&[0.4, 0.4]);
        e.audit_incremental_state();
        e.charge_steps(
            &cost,
            ids[0],
            16.0,
            &[OpCharge::recorded(OpKind::SimStep { num_env: 512 })],
            0.0,
        );
        e.audit_incremental_state();
        e.pay(ids[1], 0.5);
        e.pay_group(&ids, 0.25);
        e.audit_incremental_state();
        e.barrier_advance(&ids, 0.1);
        e.audit_incremental_state();
        e.recv(ids[0], Clock(9.0), 0.2);
        e.broadcast(&ids, e.max_time(&ids), 0.05);
        e.wait_group(&ids, Clock(20.0));
        e.audit_incremental_state();
        let mut fabric = Fabric::single_node(Topology::dgx_a100(1));
        let plan = fabric.plan_intra_gpu(8 << 20, 1, 0);
        e.collective(&mut fabric, &ids, &plan);
        e.recv_plan(&mut fabric, ids[0], Clock(25.0), &plan);
        e.audit_incremental_state();
        assert_eq!(e.span().to_bits(), e.span_scan().to_bits());
        assert_eq!(e.gpu_time(0).to_bits(), e.gpu_time_scan(0).to_bits());
    }

    /// Satellite regression for the id→index map: the autoscaler's
    /// interleaved add / remove / re-add / resize sequence must keep
    /// lookups, frontiers, and per-job totals consistent throughout —
    /// including the cross-GPU re-point that rebuilds a GPU frontier.
    #[test]
    fn interleaved_add_remove_resize_keeps_lookups_and_totals() {
        let (mut e, ids, cost) = setup(&[0.4, 0.4]);
        e.tag_job(ids[0], 1).unwrap();
        e.tag_job(ids[1], 2).unwrap();
        let grad = [OpCharge::recorded(OpKind::TrainGrad { samples: 1024 })];
        e.charge_steps(&cost, ids[0], 4.0, &grad, 0.0);
        e.audit_incremental_state();
        // Autoscaler grow: fresh GMI id in the free share.
        let ex = e
            .add_gmi(GmiSpec {
                id: 7,
                gpu: 0,
                sm_share: 0.2,
                mem_gib: 5.0,
                backend: GmiBackend::Mps,
                role: Role::Holistic,
                num_env: 128,
            })
            .unwrap();
        e.tag_job(ex, 1).unwrap();
        e.charge_steps(&cost, ex, 2.0, &grad, 0.0);
        e.audit_incremental_state();
        // Retire it, resize a survivor into the freed share, charge again.
        e.remove_gmi(7).unwrap();
        e.resize_share(0, 0.6).unwrap();
        e.charge_steps(&cost, ids[0], 1.0, &grad, 0.0);
        e.audit_incremental_state();
        // Retired executors keep their job's accumulated service.
        let busy_with_retired = e.job_busy_s(1);
        assert_eq!(busy_with_retired.to_bits(), e.job_busy_s_scan(1).to_bits());
        assert!(busy_with_retired > e.busy_seconds(ids[0]) - 1e-12);
        // Cross-GPU re-add re-points the retired executor; the old GPU's
        // frontier is rebuilt, the new one picks up the frozen clock.
        let ex2 = e
            .add_gmi(GmiSpec {
                id: 7,
                gpu: 1,
                sm_share: 0.5,
                mem_gib: 5.0,
                backend: GmiBackend::Mps,
                role: Role::Holistic,
                num_env: 256,
            })
            .unwrap();
        assert_eq!(ex2, ex, "executor ids stable across re-adds");
        e.audit_incremental_state();
        assert_eq!(e.gpu_time(1).to_bits(), e.gpu_time_scan(1).to_bits());
        e.charge_steps(&cost, ex2, 1.0, &grad, 0.0);
        // Re-tagging migrates the executor between job member lists.
        e.tag_job(ex2, 2).unwrap();
        e.audit_incremental_state();
        assert_eq!(e.job_busy_s(1).to_bits(), e.job_busy_s_scan(1).to_bits());
        assert_eq!(e.job_busy_s(2).to_bits(), e.job_busy_s_scan(2).to_bits());
        assert_eq!(e.job_xjob_s(2).to_bits(), e.job_xjob_s_scan(2).to_bits());
        // Lookups after the churn still dedup to the stable ids.
        assert_eq!(e.add_executor(7).unwrap(), ex2);
        assert_eq!(e.add_group(&[0, 1, 7]).unwrap(), vec![ids[0], ids[1], ex2]);
    }

    /// Regression: a removed GMI's id must not resolve to its retired
    /// executor. `gmi_index` keeps entries for retired executors (their
    /// service history stays attributable), and `add_executor` used to
    /// hand such an executor straight back out — so a caller holding a
    /// deregistered id could keep charging work against placement the
    /// manager no longer validates. Only `add_gmi` (which re-points the
    /// executor at freshly validated placement) may revive the id.
    #[test]
    fn removed_gmi_does_not_resolve_to_its_retired_executor() {
        let (mut e, ids, cost) = setup(&[0.4, 0.4]);
        let grad = [OpCharge::recorded(OpKind::TrainGrad { samples: 1024 })];
        e.charge_steps(&cost, ids[1], 2.0, &grad, 0.0);
        e.remove_gmi(1).unwrap();
        // The dangling id is rejected everywhere executors resolve from
        // GMI ids, not silently aliased to the retired executor.
        let err = e.add_executor(1).unwrap_err().to_string();
        assert!(err.contains("removed"), "unexpected error: {err}");
        assert!(e.add_group(&[0, 1]).is_err(), "group over a removed GMI must fail");
        // The live sibling still resolves, and a validated re-add revives
        // the id through the re-point path.
        assert_eq!(e.add_executor(0).unwrap(), ids[0]);
        let revived = e
            .add_gmi(GmiSpec {
                id: 1,
                gpu: 0,
                sm_share: 0.3,
                mem_gib: 5.0,
                backend: GmiBackend::Mps,
                role: Role::Holistic,
                num_env: 256,
            })
            .unwrap();
        assert_eq!(revived, ids[1], "re-add re-points the stable executor");
        assert_eq!(e.add_executor(1).unwrap(), revived);
        e.audit_incremental_state();
    }

    #[test]
    fn direct_share_time_slices() {
        let b = static_registry()["AT"].clone();
        let cost = CostModel::new(&b);
        let mut m = GmiManager::new(Topology::dgx_a100(1));
        for id in 0..3 {
            m.add_gmi(GmiSpec {
                id,
                gpu: 0,
                sm_share: 1.0,
                mem_gib: 5.0,
                backend: GmiBackend::DirectShare,
                role: Role::SimAgent,
                num_env: 512,
            })
            .unwrap();
        }
        let mut e = Engine::new(&m, &cost);
        let ids = e.add_group(&[0, 1, 2]).unwrap();
        assert!((e.share(ids[0]) - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(eff_share(GmiBackend::Mps, 0.4, 2), 0.4);
    }
}
