//! Elastic mid-run re-provisioning — the adaptive piece of the paper's
//! "resource-adjustable" GMI claim.
//!
//! Between sync iterations the controller inspects each role group's
//! busy/idle fractions on the engine's timelines. When one group idles
//! while the other saturates (a rollout-heavy or train-heavy imbalance),
//! it shifts SM share on every GPU from the idle group's GMIs to the
//! bottleneck group's GMIs, through the validated
//! [`GmiManager::resize_gmi`](crate::gmi::GmiManager::resize_gmi) path, so
//! the provisioning tracks what the stages actually need instead of the
//! layout builder's static guess.

use std::collections::BTreeMap;

use super::{Engine, ExecutorId};
use crate::gmi::GmiBackend;

/// Tuning knobs of the elastic controller.
#[derive(Debug, Clone)]
pub struct ElasticConfig {
    /// SM share taken from each donor GMI per adjustment (absolute
    /// fraction of its GPU).
    pub step: f64,
    /// No GMI is ever shrunk below this share.
    pub min_share: f64,
    /// Idle-fraction gap between the groups required before any shift
    /// (hysteresis against oscillation).
    pub threshold: f64,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig { step: 0.05, min_share: 0.05, threshold: 0.05 }
    }
}

/// Watches per-executor busy/clock deltas between rebalance calls and
/// re-provisions SM shares toward the bottleneck role group.
#[derive(Debug)]
pub struct ElasticController {
    cfg: ElasticConfig,
    /// Per executor id: (busy_s, clock_s) at the last rebalance.
    last: Vec<(f64, f64)>,
    shifts: usize,
}

impl ElasticController {
    pub fn new(cfg: ElasticConfig) -> Self {
        ElasticController { cfg, last: Vec::new(), shifts: 0 }
    }

    /// Adjustments applied so far.
    pub fn shifts(&self) -> usize {
        self.shifts
    }

    /// Inspect the window since the previous call and, if one group's idle
    /// fraction exceeds the other's by the configured threshold, shift SM
    /// share toward the busier group on every GPU hosting both. Returns
    /// whether any re-provisioning happened. Colocated layouts (the two
    /// groups alias the same executors) have nothing to shift.
    pub fn rebalance(
        &mut self,
        engine: &mut Engine,
        rollout: &[ExecutorId],
        trainers: &[ExecutorId],
    ) -> bool {
        if rollout.iter().any(|r| trainers.contains(r)) {
            return false;
        }
        let idle_r = self.group_idle(engine, rollout);
        let idle_t = self.group_idle(engine, trainers);
        for &i in rollout.iter().chain(trainers) {
            if self.last.len() <= i {
                self.last.resize(i + 1, (0.0, 0.0));
            }
            self.last[i] = (engine.busy_seconds(i), engine.clock(i).seconds());
        }
        let (donors, receivers) = if idle_t > idle_r + self.cfg.threshold {
            (trainers, rollout) // trainers wait on rollouts: rollout-bound
        } else if idle_r > idle_t + self.cfg.threshold {
            (rollout, trainers) // rollouts wait on trainers: train-bound
        } else {
            return false;
        };
        let moved = self.shift(engine, donors, receivers);
        if moved {
            self.shifts += 1;
        }
        moved
    }

    /// Idle fraction of a group over the window since the last rebalance.
    fn group_idle(&self, engine: &Engine, ids: &[ExecutorId]) -> f64 {
        let mut busy = 0.0f64;
        let mut span = 0.0f64;
        for &i in ids {
            let (b0, c0) = self.last.get(i).copied().unwrap_or((0.0, 0.0));
            busy += engine.busy_seconds(i) - b0;
            span += engine.clock(i).seconds() - c0;
        }
        if span <= 0.0 {
            return 0.0;
        }
        (1.0 - busy / span).clamp(0.0, 1.0)
    }

    /// Per GPU hosting both groups: shrink every donor by up to `step`
    /// (never below `min_share`), then grow the receivers evenly into the
    /// freed share. Shrink-before-grow keeps every intermediate state
    /// valid under the manager's oversubscription checks. A resize the
    /// manager rejects (e.g. a MIG donor whose smaller profile can't hold
    /// its memory) is skipped, not fatal: re-provisioning is best-effort
    /// and the layout stays valid either way.
    fn shift(
        &self,
        engine: &mut Engine,
        donors: &[ExecutorId],
        receivers: &[ExecutorId],
    ) -> bool {
        // Direct-Share GMIs time-slice the whole GPU regardless of their
        // nominal share — resizing them changes nothing, so they neither
        // donate nor receive.
        let adjustable = |engine: &Engine, id: ExecutorId| {
            engine
                .manager()
                .gmi(engine.gmi_of(id))
                .is_some_and(|s| s.backend != GmiBackend::DirectShare)
        };
        let mut by_gpu: BTreeMap<usize, (Vec<ExecutorId>, Vec<ExecutorId>)> = BTreeMap::new();
        for &d in donors.iter().filter(|&&d| adjustable(engine, d)) {
            by_gpu.entry(engine.gpu(d)).or_default().0.push(d);
        }
        for &r in receivers.iter().filter(|&&r| adjustable(engine, r)) {
            by_gpu.entry(engine.gpu(r)).or_default().1.push(r);
        }
        let mut moved = false;
        for (ds, rs) in by_gpu.values() {
            if ds.is_empty() || rs.is_empty() {
                continue;
            }
            let mut freed = 0.0f64;
            for &d in ds {
                let gmi = engine.gmi_of(d);
                let share = engine.manager().gmi(gmi).expect("donor registered").sm_share;
                let take = (share - self.cfg.min_share).min(self.cfg.step).max(0.0);
                if take <= 0.0 || engine.resize_share(gmi, share - take).is_err() {
                    continue;
                }
                freed += take;
            }
            if freed <= 0.0 {
                continue;
            }
            let gain = freed / rs.len() as f64;
            for &r in rs {
                let gmi = engine.gmi_of(r);
                let share = engine.manager().gmi(gmi).expect("receiver registered").sm_share;
                let _ = engine.resize_share(gmi, (share + gain).min(1.0));
            }
            moved = true;
        }
        moved
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::config::static_registry;
    use crate::engine::OpCharge;
    use crate::gmi::{GmiBackend, GmiManager, GmiSpec, Role};
    use crate::vtime::{CostModel, OpKind};

    /// One GPU: two starved rollout GMIs + one over-provisioned trainer.
    fn imbalanced() -> (Engine, Vec<ExecutorId>, Vec<ExecutorId>, CostModel) {
        let b = static_registry()["AT"].clone();
        let cost = CostModel::new(&b);
        let mut m = GmiManager::new(Topology::dgx_a100(1));
        for (id, (share, role, n_env)) in [
            (0.15, Role::SimAgent, 1024),
            (0.15, Role::SimAgent, 1024),
            (0.70, Role::Trainer, 0),
        ]
        .into_iter()
        .enumerate()
        {
            m.add_gmi(GmiSpec {
                id,
                gpu: 0,
                sm_share: share,
                mem_gib: 6.0,
                backend: GmiBackend::Mps,
                role,
                num_env: n_env,
            })
            .unwrap();
        }
        let mut e = Engine::new(&m, &cost);
        let roll = e.add_group(&[0, 1]).unwrap();
        let tr = e.add_group(&[2]).unwrap();
        (e, roll, tr, cost)
    }

    #[test]
    fn shifts_share_toward_the_busy_group() {
        let (mut e, roll, tr, cost) = imbalanced();
        // Rollouts compute the whole window; the trainer computes briefly
        // and then waits (merges forward) on the rollout timeline.
        for &r in &roll {
            let sim = OpCharge::recorded(OpKind::SimStep { num_env: 1024 });
            e.charge_steps(&cost, r, 16.0, &[sim], 0.0);
        }
        let feed = e.max_time(&roll);
        e.charge_after(&cost, tr[0], feed, &[OpCharge::recorded(OpKind::AdamApply)]);
        let mut ctl = ElasticController::new(ElasticConfig::default());
        assert!(ctl.rebalance(&mut e, &roll, &tr));
        assert_eq!(ctl.shifts(), 1);
        // Trainer donated one step; each rollout GMI gained half of it.
        assert!((e.manager().gmi(2).unwrap().sm_share - 0.65).abs() < 1e-9);
        assert!((e.manager().gmi(0).unwrap().sm_share - 0.175).abs() < 1e-9);
        assert!((e.manager().gmi(1).unwrap().sm_share - 0.175).abs() < 1e-9);
        // The layout stays valid: shares on the GPU still sum to 1.
        let total: f64 = e.manager().all().map(|g| g.sm_share).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn respects_min_share_floor() {
        let (mut e, roll, tr, cost) = imbalanced();
        let cfg = ElasticConfig { step: 1.0, min_share: 0.3, threshold: 0.05 };
        for &r in &roll {
            let sim = OpCharge::recorded(OpKind::SimStep { num_env: 1024 });
            e.charge_steps(&cost, r, 16.0, &[sim], 0.0);
        }
        e.charge_after(&cost, tr[0], e.max_time(&roll), &[OpCharge::recorded(OpKind::AdamApply)]);
        let mut ctl = ElasticController::new(cfg);
        assert!(ctl.rebalance(&mut e, &roll, &tr));
        // A full-step take is clamped to the floor: 0.7 -> 0.3.
        assert!((e.manager().gmi(2).unwrap().sm_share - 0.3).abs() < 1e-9);
    }

    #[test]
    fn infeasible_resizes_are_skipped_not_fatal() {
        // MIG donors whose shrunk profile can't hold their memory: the
        // manager rejects the resize and the controller moves on instead
        // of aborting the run.
        let b = static_registry()["AT"].clone();
        let cost = CostModel::new(&b);
        let mut m = GmiManager::new(Topology::dgx_a100(1));
        for (id, (role, n_env, mem)) in [
            (Role::SimAgent, 1024, 5.0),
            (Role::SimAgent, 1024, 5.0),
            (Role::Trainer, 0, 6.0),
        ]
        .into_iter()
        .enumerate()
        {
            m.add_gmi(GmiSpec {
                id,
                gpu: 0,
                sm_share: 2.0 / 7.0,
                mem_gib: mem,
                backend: GmiBackend::Mig,
                role,
                num_env: n_env,
            })
            .unwrap();
        }
        let mut e = Engine::new(&m, &cost);
        let roll = e.add_group(&[0, 1]).unwrap();
        let tr = e.add_group(&[2]).unwrap();
        for &r in &roll {
            let sim = OpCharge::recorded(OpKind::SimStep { num_env: 1024 });
            e.charge_steps(&cost, r, 16.0, &[sim], 0.0);
        }
        e.charge_after(&cost, tr[0], e.max_time(&roll), &[OpCharge::recorded(OpKind::AdamApply)]);
        // step large enough to drop the trainer below 1g.5gb's 5 GiB quota
        // for its 6 GiB of memory -> resize_gmi bails -> skipped.
        let cfg = ElasticConfig { step: 0.2, min_share: 0.05, threshold: 0.05 };
        let mut ctl = ElasticController::new(cfg);
        assert!(!ctl.rebalance(&mut e, &roll, &tr));
        assert_eq!(ctl.shifts(), 0);
        assert!((e.manager().gmi(2).unwrap().sm_share - 2.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn colocated_groups_and_balanced_windows_are_noops() {
        let (mut e, roll, tr, cost) = imbalanced();
        let mut ctl = ElasticController::new(ElasticConfig::default());
        // Shared executors: nothing to shift.
        assert!(!ctl.rebalance(&mut e, &roll, &roll));
        // Empty window: no signal, no shift.
        assert!(!ctl.rebalance(&mut e, &roll, &tr));
        // Both groups equally busy: inside the hysteresis band.
        for &i in roll.iter().chain(&tr) {
            e.charge_steps(&cost, i, 4.0, &[OpCharge::recorded(OpKind::AdamApply)], 0.0);
        }
        assert!(!ctl.rebalance(&mut e, &roll, &tr));
        assert_eq!(ctl.shifts(), 0);
    }
}
