//! The unified discrete-event GMI execution engine.
//!
//! Every orchestrator (serving, sync PPO, async A3C, and the Isaac-Gym
//! baselines built on them) used to hand-roll its own virtual-time loop:
//! per-GMI clock arrays, duplicated effective-share math, inline
//! utilization bookkeeping. This module is the shared substrate instead:
//!
//! * [`Engine`] — owns one executor per GMI role task (its [`Clock`],
//!   effective SM share, interference multiplier, busy accounting) plus the
//!   run-wide utilization and communication totals. Work is described as
//!   [`OpCharge`] sequences (`charge_steps` / `charge_after`) and
//!   communication primitives (`barrier_advance`, `recv`, `broadcast`,
//!   `pay`); timelines are queried per executor, per group, or per GPU.
//!   Fabric transfer plans execute as engine events (`collective`,
//!   `collective_overlapped`, `recv_plan`, `broadcast_plan`): the plan
//!   drains on the [`fabric`](crate::fabric)'s links (contended links
//!   serialize) while the participating executors either block on the
//!   completion or keep computing and re-synchronize at the true data
//!   dependency — the compute/communication overlap of paper §4.2.
//! * [`elastic`] — the adaptive controller the paper promises: between
//!   iterations it reads per-group busy/idle fractions off the engine and
//!   re-provisions SM shares toward the bottleneck role through the
//!   validated [`GmiManager::resize_gmi`](crate::gmi::GmiManager::resize_gmi)
//!   path. The engine also supports whole-GMI elasticity
//!   ([`Engine::add_gmi`] / [`Engine::remove_gmi`] with the same placement
//!   validation) — the substrate of the serving autoscaler
//!   ([`serve::autoscale`](crate::serve::autoscale)).
//!
//! The engine clones the layout's `GmiManager` at construction, so mid-run
//! re-provisioning never mutates the caller's static layout.
//!
//! [`Clock`]: crate::vtime::Clock

pub mod elastic;
mod executor;

pub use elastic::{ElasticConfig, ElasticController};
pub use executor::{eff_share, Engine, ExecutorId, OpCharge};
