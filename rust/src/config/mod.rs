//! Config system: the artifact manifest (written by `python -m compile.aot`),
//! the static benchmark registry (paper Table 6), and run configuration.
//!
//! The manifest interchange format is the line-based `manifest.txt` twin of
//! `manifest.json` (the offline build has no JSON crate; see DESIGN.md
//! §Dependencies).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One benchmark entry from the artifact manifest (Table 6 row + the shapes
/// baked into its HLO artifacts).
#[derive(Debug, Clone, PartialEq)]
pub struct BenchInfo {
    pub name: String,
    pub abbr: String,
    pub kind: String,
    pub obs_dim: usize,
    pub act_dim: usize,
    pub hidden: Vec<usize>,
    pub num_params: usize,
    pub num_env: usize,
    pub horizon: usize,
    pub files: BTreeMap<String, String>,
}

impl BenchInfo {
    /// FLOPs of one policy forward pass per environment (actor + critic,
    /// MACs x2). Drives the virtual-time cost model for GEMM-shaped work.
    pub fn fwd_flops_per_env(&self) -> f64 {
        let dims: Vec<usize> = std::iter::once(self.obs_dim)
            .chain(self.hidden.iter().copied())
            .collect();
        let mut macs = 0usize;
        for w in dims.windows(2) {
            macs += w[0] * w[1];
        }
        // actor head + critic head; x2 for the two identical trunks.
        let head = self.hidden.last().copied().unwrap_or(1);
        let total = 2 * macs + head * self.act_dim + head;
        2.0 * total as f64
    }

    /// FLOP-equivalents of one env simulation step per environment. Physics
    /// is element-wise (springs, damping, trig) — cheap in FLOPs but poorly
    /// parallelizable, which is exactly why it saturates at a small SM share.
    /// The superlinear factor models contact/solver cost growing with body
    /// complexity (ShadowHand physics is far heavier per state dim than
    /// Ant's) — anchored to 1.0 at Ant's 60 dims.
    pub fn sim_flops_per_env(&self) -> f64 {
        let base = 40.0 * self.obs_dim as f64 + (self.act_dim * self.obs_dim) as f64;
        base * (self.obs_dim as f64 / 60.0).powf(0.7)
    }

    /// Bytes of one experience record (state, action, reward, logp, value,
    /// done) for one env for one step.
    pub fn experience_bytes_per_step(&self) -> usize {
        4 * (self.obs_dim + self.act_dim + 4)
    }

    /// Bytes of the flat policy parameter / gradient vector (f32).
    pub fn param_bytes(&self) -> usize {
        4 * self.num_params
    }
}

/// The artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u32,
    pub benchmarks: BTreeMap<String, BenchInfo>,
}

impl Manifest {
    /// Parse `manifest.txt` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut version = 0u32;
        let mut benchmarks = BTreeMap::new();
        let mut cur: Option<BenchInfo> = None;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut it = line.splitn(2, ' ');
            let key = it.next().unwrap();
            let val = it.next().unwrap_or("").trim();
            let ctx = || format!("manifest line {}: {line}", lineno + 1);
            match key {
                "version" => version = val.parse().with_context(ctx)?,
                "bench" => {
                    if cur.is_some() {
                        bail!("manifest line {}: nested bench", lineno + 1);
                    }
                    cur = Some(BenchInfo {
                        name: String::new(),
                        abbr: val.to_string(),
                        kind: String::new(),
                        obs_dim: 0,
                        act_dim: 0,
                        hidden: vec![],
                        num_params: 0,
                        num_env: 0,
                        horizon: 0,
                        files: BTreeMap::new(),
                    });
                }
                "end" => {
                    let b = cur.take().context("end without bench")?;
                    benchmarks.insert(b.abbr.clone(), b);
                }
                _ => {
                    let b = cur.as_mut().with_context(ctx)?;
                    match key {
                        "name" => b.name = val.to_string(),
                        "kind" => b.kind = val.to_string(),
                        "obs_dim" => b.obs_dim = val.parse().with_context(ctx)?,
                        "act_dim" => b.act_dim = val.parse().with_context(ctx)?,
                        "hidden" => {
                            b.hidden = val
                                .split(',')
                                .map(|s| s.trim().parse::<usize>())
                                .collect::<std::result::Result<_, _>>()
                                .with_context(ctx)?
                        }
                        "num_params" => b.num_params = val.parse().with_context(ctx)?,
                        "num_env" => b.num_env = val.parse().with_context(ctx)?,
                        "horizon" => b.horizon = val.parse().with_context(ctx)?,
                        "file" => {
                            let mut fit = val.splitn(2, ' ');
                            let k = fit.next().unwrap_or("").to_string();
                            let v = fit.next().unwrap_or("").trim().to_string();
                            if k.is_empty() || v.is_empty() {
                                bail!("manifest line {}: bad file entry", lineno + 1);
                            }
                            b.files.insert(k, v);
                        }
                        _ => bail!("manifest line {}: unknown key {key}", lineno + 1),
                    }
                }
            }
        }
        if cur.is_some() {
            bail!("manifest: unterminated bench block");
        }
        Ok(Manifest { version, benchmarks })
    }

    pub fn bench(&self, abbr: &str) -> Result<&BenchInfo> {
        self.benchmarks
            .get(abbr)
            .with_context(|| format!("benchmark {abbr} not in manifest"))
    }

    pub fn hlo_path(&self, dir: &Path, abbr: &str, artifact: &str) -> Result<PathBuf> {
        let b = self.bench(abbr)?;
        let file = b
            .files
            .get(artifact)
            .with_context(|| format!("artifact {artifact} missing for {abbr}"))?;
        Ok(dir.join(abbr).join(file))
    }
}

/// Static registry of the paper's Table 6 benchmarks. Used by cost-model-only
/// code paths (unit tests, pure virtual benches) that must not require
/// `make artifacts` to have run.
pub fn static_registry() -> BTreeMap<String, BenchInfo> {
    let rows: Vec<(&str, &str, &str, usize, usize, Vec<usize>)> = vec![
        ("Ant", "AT", "L", 60, 8, vec![256, 128, 64]),
        ("Anymal", "AY", "L", 48, 12, vec![256, 128, 64]),
        ("BallBalance", "BB", "L", 24, 3, vec![256, 128, 64]),
        ("FrankaCabinet", "FC", "F", 23, 9, vec![256, 128, 64]),
        ("Humanoid", "HM", "L", 108, 21, vec![200, 400, 100]),
        ("ShadowHand", "SH", "R", 211, 20, vec![512, 512, 512, 256]),
    ];
    rows.into_iter()
        .map(|(name, abbr, kind, obs, act, hidden)| {
            let num_params = param_count(obs, act, &hidden);
            (
                abbr.to_string(),
                BenchInfo {
                    name: name.to_string(),
                    abbr: abbr.to_string(),
                    kind: kind.to_string(),
                    obs_dim: obs,
                    act_dim: act,
                    hidden,
                    num_params,
                    num_env: 256,
                    horizon: 16,
                    files: BTreeMap::new(),
                },
            )
        })
        .collect()
}

/// All six paper benchmark abbreviations in Table 6 order.
pub const PAPER_BENCHMARKS: [&str; 6] = ["AT", "AY", "BB", "FC", "HM", "SH"];

/// Default auto-tuner probe budget as a fraction of the projected run
/// horizon (see `tune`): probe virtual-time is bounded to 1% of the run.
pub const DEFAULT_TUNE_BUDGET_FRAC: f64 = 0.01;

/// Mirror of python `model.num_params` (separate actor + critic trunks,
/// heads, log_std). Kept in sync by an integration test against the
/// manifest.
pub fn param_count(obs: usize, act: usize, hidden: &[usize]) -> usize {
    let dims: Vec<usize> = std::iter::once(obs).chain(hidden.iter().copied()).collect();
    let mut trunk = 0usize;
    for w in dims.windows(2) {
        trunk += w[0] * w[1] + w[1];
    }
    let last = *hidden.last().unwrap();
    // actor trunk + actor head + critic trunk + critic head + log_std
    trunk + (last * act + act) + trunk + (last + 1) + act
}

/// Where the artifacts live; honours `GMI_DRL_ARTIFACTS` for tests.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("GMI_DRL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_matches_paper_table6() {
        let reg = static_registry();
        assert_eq!(reg.len(), 6);
        assert_eq!(reg["AT"].obs_dim, 60);
        assert_eq!(reg["AT"].act_dim, 8);
        assert_eq!(reg["HM"].hidden, vec![200, 400, 100]);
        assert_eq!(reg["SH"].hidden, vec![512, 512, 512, 256]);
    }

    #[test]
    fn param_counts_match_paper_table7() {
        // Paper Table 7: AT 1.1e5, HM 2.9e5, SH 1.5e6.
        let reg = static_registry();
        let at = reg["AT"].num_params as f64;
        let hm = reg["HM"].num_params as f64;
        let sh = reg["SH"].num_params as f64;
        assert!((at - 1.1e5).abs() / 1.1e5 < 0.1, "AT {at}");
        assert!((hm - 2.9e5).abs() / 2.9e5 < 0.05, "HM {hm}");
        assert!((sh - 1.5e6).abs() / 1.5e6 < 0.05, "SH {sh}");
    }

    #[test]
    fn fwd_flops_positive_and_ordered() {
        let reg = static_registry();
        assert!(reg["SH"].fwd_flops_per_env() > reg["AT"].fwd_flops_per_env());
        assert!(reg["AT"].fwd_flops_per_env() > 0.0);
    }

    #[test]
    fn manifest_parse_roundtrip() {
        let text = "\
version 1
bench AT
name Ant
kind L
obs_dim 60
act_dim 8
hidden 256,128,64
num_params 114129
num_env 256
horizon 16
file init init.hlo.txt
file rollout rollout.hlo.txt
end
";
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.version, 1);
        let b = m.bench("AT").unwrap();
        assert_eq!(b.hidden, vec![256, 128, 64]);
        assert_eq!(b.files["rollout"], "rollout.hlo.txt");
        assert!(m.bench("ZZ").is_err());
    }

    #[test]
    fn manifest_parse_rejects_garbage() {
        assert!(Manifest::parse("bench AT\nbench AY\n").is_err());
        assert!(Manifest::parse("bench AT\nbogus 1\nend\n").is_err());
        assert!(Manifest::parse("bench AT\n").is_err());
        assert!(Manifest::parse("end\n").is_err());
    }
}
