//! The experience compressor (CP): system-wide service that concatenates
//! per-channel chunks into transfer-sized packets (paper §4.2).
//!
//! The threshold is per-channel in *bytes*: a wide channel (states) crosses
//! it every few steps while a narrow one (rewards) accumulates many more
//! steps per transfer — "handling data collection and transferring at
//! different levels of granularity and transmission rate" (§4.2). Channel
//! alignment at the trainer is guaranteed by the migrator's sticky
//! per-agent routing, not by synchronized flushing.
//!
//! Staging is additionally bounded in *age*: a partially filled queue whose
//! oldest chunk is more than one staging interval old flushes even below
//! the size threshold. Without this, a low-traffic channel (`Done` is one
//! float per sample) can starve — its samples sit staged for the whole run
//! while every other channel of the group reaches the trainer, and the
//! batcher never completes a batch.

use std::collections::BTreeMap;

use crate::vtime::Clock;

use super::{ChannelKind, Chunk, Packet, ShareMode};

/// System-wide compressor. Multi-channel mode stages chunks per channel and
/// emits one packet each time `threshold_bytes` accumulate — or when the
/// queue's oldest chunk turns one staging interval old; uni-channel mode
/// forwards every chunk immediately (no batching — the Table 8 baseline).
#[derive(Debug)]
pub struct Compressor {
    mode: ShareMode,
    threshold_bytes: usize,
    /// Max age (virtual seconds) a staged chunk may wait below the size
    /// threshold before its queue flushes; `INFINITY` disables age flushes.
    staging_interval_s: f64,
    staged: BTreeMap<(usize, ChannelKind), Vec<Chunk>>,
}

impl Compressor {
    pub fn new(mode: ShareMode, threshold_bytes: usize) -> Self {
        Self::with_staging_interval(mode, threshold_bytes, f64::INFINITY)
    }

    /// Compressor with an anti-starvation staging interval: any queue whose
    /// oldest chunk is `staging_interval_s` or more behind the newest
    /// observed timestamp flushes regardless of accumulated size.
    pub fn with_staging_interval(
        mode: ShareMode,
        threshold_bytes: usize,
        staging_interval_s: f64,
    ) -> Self {
        assert!(staging_interval_s > 0.0, "staging interval must be positive");
        Compressor { mode, threshold_bytes, staging_interval_s, staged: BTreeMap::new() }
    }

    /// Default transfer granularity: 1 MiB per channel — large enough to
    /// amortize the host-path per-message overhead (HOST_MSG_HALF_BYTES),
    /// small enough to bound trainer staleness.
    pub fn with_default_threshold(mode: ShareMode) -> Self {
        Self::new(mode, 1 << 20)
    }

    /// Stage chunks; returns any packets that became ready (by size, or by
    /// the anti-starvation age bound). Staging is per (agent, channel) so
    /// one agent's slow channel can't delay another's.
    pub fn push(&mut self, chunks: Vec<Chunk>) -> Vec<Packet> {
        let mut out = Vec::new();
        let mut now = Clock::zero();
        for chunk in chunks {
            if chunk.ready > now {
                now = chunk.ready;
            }
            match self.mode {
                ShareMode::UniChannel => {
                    // Ship every record as-is: maximal op count.
                    out.push(Packet {
                        channel: chunk.channel,
                        ready: chunk.ready,
                        chunks: vec![chunk],
                    });
                }
                ShareMode::MultiChannel => {
                    let key = (chunk.agent, chunk.channel);
                    let q = self.staged.entry(key).or_default();
                    q.push(chunk);
                    let bytes: usize = q.iter().map(Chunk::bytes).sum();
                    if bytes >= self.threshold_bytes {
                        let chunks = std::mem::take(q);
                        let ready = Clock::max_of(
                            &chunks.iter().map(|c| c.ready).collect::<Vec<_>>(),
                        );
                        out.push(Packet { channel: chunks[0].channel, chunks, ready });
                    }
                }
            }
        }
        out.extend(self.flush_stale(now));
        out
    }

    /// Flush every staging queue whose oldest chunk is at least one staging
    /// interval behind `now` — the anti-starvation bound for low-traffic
    /// channels. No-op when the interval is infinite.
    pub fn flush_stale(&mut self, now: Clock) -> Vec<Packet> {
        if !self.staging_interval_s.is_finite() {
            return Vec::new();
        }
        let mut out = Vec::new();
        // A queue's first chunk is its oldest: chunks arrive in the
        // producing agent's clock order and queues are per (agent,
        // channel), so no full scan is needed.
        let stale: Vec<(usize, ChannelKind)> = self
            .staged
            .iter()
            .filter(|(_, q)| {
                q.first()
                    .is_some_and(|c| c.ready.seconds() + self.staging_interval_s <= now.seconds())
            })
            .map(|(k, _)| *k)
            .collect();
        for key in stale {
            let chunks = self.staged.remove(&key).unwrap_or_default();
            if chunks.is_empty() {
                continue;
            }
            let ready = Clock::max_of(&chunks.iter().map(|c| c.ready).collect::<Vec<_>>());
            out.push(Packet { channel: chunks[0].channel, chunks, ready });
        }
        out
    }

    /// Flush all staging queues (end of segment batch or run).
    pub fn flush(&mut self) -> Vec<Packet> {
        let mut out = Vec::new();
        for (_, chunks) in std::mem::take(&mut self.staged) {
            if chunks.is_empty() {
                continue;
            }
            let ready =
                Clock::max_of(&chunks.iter().map(|c| c.ready).collect::<Vec<_>>());
            out.push(Packet { channel: chunks[0].channel, chunks, ready });
        }
        out
    }

    pub fn staged_bytes(&self) -> usize {
        self.staged.values().flatten().map(Chunk::bytes).sum()
    }

    pub fn staged_samples(&self, ch: ChannelKind) -> usize {
        self.staged
            .iter()
            .filter(|((_, c), _)| *c == ch)
            .flat_map(|(_, q)| q.iter())
            .map(|c| c.steps * c.envs)
            .sum()
    }

    /// Samples of one channel staged for one producing agent. Snapshots
    /// read this per agent: staged-but-unflushed work is dropped by a
    /// restore, so the owning program must re-charge and re-dispense it
    /// (the `Workload::snapshot` lost-and-redone contract).
    pub fn staged_samples_for(&self, agent: usize, ch: ChannelKind) -> usize {
        self.staged
            .get(&(agent, ch))
            .map(|q| q.iter().map(|c| c.steps * c.envs).sum())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chunk(ch: ChannelKind, envs: usize, width: usize, t: f64) -> Chunk {
        Chunk {
            channel: ch,
            agent: 0,
            seq: 0,
            steps: 1,
            envs,
            data: vec![0.0; envs * width],
            ready: Clock(t),
        }
    }

    #[test]
    fn multichannel_batches_to_byte_threshold() {
        let mut cp = Compressor::new(ShareMode::MultiChannel, 4 * 120); // 120 floats
        assert!(cp.push(vec![chunk(ChannelKind::State, 40, 1, 1.0)]).is_empty());
        assert!(cp.push(vec![chunk(ChannelKind::State, 40, 1, 2.0)]).is_empty());
        let out = cp.push(vec![chunk(ChannelKind::State, 40, 1, 3.0)]);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].chunks.len(), 3);
        // packet ready = latest member chunk
        assert_eq!(out[0].ready, Clock(3.0));
        assert_eq!(cp.staged_samples(ChannelKind::State), 0);
    }

    #[test]
    fn narrow_channels_accumulate_more_steps() {
        // The §4.2 point: rewards (1 float/sample) batch ~60x more steps
        // per transfer than states (60 floats/sample).
        let mut cp = Compressor::new(ShareMode::MultiChannel, 4 * 600);
        let mut state_pkts = 0;
        let mut reward_pkts = 0;
        for t in 0..60 {
            for p in cp.push(vec![
                chunk(ChannelKind::State, 10, 60, t as f64),
                chunk(ChannelKind::Reward, 10, 1, t as f64),
            ]) {
                match p.channel {
                    ChannelKind::State => state_pkts += 1,
                    ChannelKind::Reward => reward_pkts += 1,
                    _ => {}
                }
            }
        }
        assert!(state_pkts >= 50, "state {state_pkts}");
        assert_eq!(reward_pkts, 1, "reward should batch ~60 steps");
    }

    #[test]
    fn agents_stage_independently() {
        let mut cp = Compressor::new(ShareMode::MultiChannel, 4 * 100);
        let mut a = chunk(ChannelKind::State, 60, 1, 1.0);
        let mut b = chunk(ChannelKind::State, 60, 1, 1.0);
        a.agent = 0;
        b.agent = 1;
        // neither crosses alone
        assert!(cp.push(vec![a.clone()]).is_empty());
        assert!(cp.push(vec![b]).is_empty());
        // agent 0's second chunk flushes only agent 0's queue
        let out = cp.push(vec![a]);
        assert_eq!(out.len(), 1);
        assert!(out[0].chunks.iter().all(|c| c.agent == 0));
        assert_eq!(cp.staged_bytes(), 4 * 60);
    }

    #[test]
    fn unichannel_never_batches() {
        let mut cp = Compressor::new(ShareMode::UniChannel, usize::MAX);
        let out = cp.push(vec![
            chunk(ChannelKind::State, 10, 12, 1.0),
            chunk(ChannelKind::State, 10, 12, 1.5),
        ]);
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|p| p.chunks.len() == 1));
        assert!(cp.flush().is_empty());
    }

    #[test]
    fn stale_partial_chunks_flush_by_age() {
        // Regression: a partially filled low-traffic queue (Done is one
        // float per sample) used to wait for the size threshold forever;
        // it must flush once its oldest chunk is one staging interval old.
        let mut cp = Compressor::with_staging_interval(ShareMode::MultiChannel, usize::MAX, 1.0);
        assert!(cp.push(vec![chunk(ChannelKind::Done, 4, 1, 0.0)]).is_empty());
        // Still young at t=0.5: stays staged.
        assert!(cp.push(vec![chunk(ChannelKind::Done, 4, 1, 0.5)]).is_empty());
        // Traffic on ANY channel advancing past the age bound flushes the
        // stale Done queue (and only it — the fresh State chunk stays).
        let out = cp.push(vec![chunk(ChannelKind::State, 4, 60, 1.25)]);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].channel, ChannelKind::Done);
        assert_eq!(out[0].chunks.len(), 2);
        assert_eq!(out[0].ready, Clock(0.5));
        assert_eq!(cp.staged_samples(ChannelKind::Done), 0);
        assert_eq!(cp.staged_samples(ChannelKind::State), 4);
        // Explicit sweep hook: nothing stale yet at t=1.5, State stale by
        // t=3.
        assert!(cp.flush_stale(Clock(1.5)).is_empty());
        let late = cp.flush_stale(Clock(3.0));
        assert_eq!(late.len(), 1);
        assert_eq!(late[0].channel, ChannelKind::State);
        // Default construction keeps the pure size-threshold behavior.
        let mut plain = Compressor::new(ShareMode::MultiChannel, usize::MAX);
        plain.push(vec![chunk(ChannelKind::Done, 4, 1, 0.0)]);
        assert!(plain.push(vec![chunk(ChannelKind::Done, 4, 1, 1e9)]).is_empty());
        assert_eq!(plain.staged_samples(ChannelKind::Done), 8);
    }

    #[test]
    fn flush_drains_everything() {
        let mut cp = Compressor::new(ShareMode::MultiChannel, usize::MAX);
        cp.push(vec![chunk(ChannelKind::State, 5, 2, 1.0)]);
        cp.push(vec![chunk(ChannelKind::Reward, 5, 1, 2.0)]);
        let out = cp.flush();
        assert_eq!(out.len(), 2);
        assert_eq!(cp.staged_bytes(), 0);
    }
}
