//! The experience migrator (MG): system-wide routing of experience packets
//! from agent GMIs to trainer GMIs (paper §4.2).
//!
//! Routing is *sticky per agent*: all of an agent's channels flow to the
//! same trainer so the batcher always sees aligned channel data, while
//! load balance happens at agent granularity — a new agent is assigned to
//! the least-loaded trainer, and an agent is re-assigned at a segment
//! boundary (its State-channel packet) when its trainer's backlog runs
//! more than 2x the lightest one.
//!
//! Transfer geometry and timing come from the communication
//! [`fabric`](crate::fabric): the migrator resolves a [`Route`] (same-GPU
//! host hop vs cross-GPU NVLink + host handoff) and executes it with
//! per-link occupancy, so packets contending a link serialize instead of
//! magically sharing it — the migrator holds no link math of its own.
//!
//! [`Route`]: crate::fabric::Route

use std::collections::BTreeMap;

use crate::fabric::Fabric;
use crate::vtime::Clock;

use super::{ChannelKind, Packet};

/// Where a packet went and what it cost.
#[derive(Debug, Clone)]
pub struct RouteDecision {
    pub trainer: usize,
    /// Virtual time the packet arrives at the trainer (includes queueing
    /// behind earlier packets on contended links).
    pub arrival: Clock,
    /// Link seconds charged for the move (uncontended route time).
    pub transfer_s: f64,
    pub cross_gpu: bool,
    /// Sender-side per-message submission overhead (IPC rendezvous +
    /// serialization), paid on the producing agent's own timeline.
    pub sender_s: f64,
}

/// Trainer endpoint registered with the migrator.
#[derive(Debug, Clone)]
pub struct TrainerEndpoint {
    pub gmi: usize,
    pub gpu: usize,
}

#[derive(Debug)]
pub struct Migrator {
    trainers: Vec<TrainerEndpoint>,
    /// Outstanding queued samples per trainer (the load-balance signal).
    outstanding: BTreeMap<usize, usize>,
    /// GPU of each agent GMI (same- vs cross-GPU routing).
    agent_gpu: BTreeMap<usize, usize>,
    /// Sticky agent -> trainer assignment (channel alignment).
    assignment: BTreeMap<usize, usize>,
}

impl Migrator {
    pub fn new(trainers: Vec<TrainerEndpoint>) -> Self {
        let outstanding = trainers.iter().map(|t| (t.gmi, 0)).collect();
        Migrator {
            trainers,
            outstanding,
            agent_gpu: BTreeMap::new(),
            assignment: BTreeMap::new(),
        }
    }

    pub fn register_agent(&mut self, gmi: usize, gpu: usize) {
        self.agent_gpu.insert(gmi, gpu);
    }

    /// Trainer finished `samples` of work: shrink its backlog.
    pub fn complete(&mut self, trainer: usize, samples: usize) {
        if let Some(v) = self.outstanding.get_mut(&trainer) {
            *v = v.saturating_sub(samples);
        }
    }

    pub fn outstanding(&self, trainer: usize) -> usize {
        self.outstanding.get(&trainer).copied().unwrap_or(0)
    }

    pub fn assignment_of(&self, agent: usize) -> Option<usize> {
        self.assignment.get(&agent).copied()
    }

    fn least_loaded(&self, prefer_gpu: usize) -> usize {
        self.trainers
            .iter()
            .min_by_key(|t| {
                (
                    self.outstanding.get(&t.gmi).copied().unwrap_or(0),
                    t.gpu != prefer_gpu,
                    t.gmi,
                )
            })
            .map(|t| t.gmi)
            .expect("no trainer endpoints")
    }

    /// Route one packet to the source agent's sticky trainer; (re)assign at
    /// State-channel packets (segment/group boundaries) so channels of one
    /// group never split across trainers. The move executes on the fabric:
    /// its links serialize contended packets and accumulate traffic stats.
    pub fn route(&mut self, fabric: &mut Fabric, pkt: &Packet) -> RouteDecision {
        assert!(!self.trainers.is_empty(), "no trainer endpoints");
        let agent = pkt.chunks.first().map(|c| c.agent).unwrap_or(0);
        let src_gpu = self.agent_gpu.get(&agent).copied().unwrap_or(0);

        let trainer = match self.assignment.get(&agent).copied() {
            None => {
                let t = self.least_loaded(src_gpu);
                self.assignment.insert(agent, t);
                t
            }
            Some(t) => {
                // Rebalance opportunity at group boundaries only.
                if pkt.channel == ChannelKind::State {
                    let cur = self.outstanding.get(&t).copied().unwrap_or(0);
                    let best = self.least_loaded(src_gpu);
                    let best_load = self.outstanding.get(&best).copied().unwrap_or(0);
                    if cur > 2 * best_load.max(1) {
                        self.assignment.insert(agent, best);
                        best
                    } else {
                        t
                    }
                } else {
                    t
                }
            }
        };

        let chosen_gpu = self
            .trainers
            .iter()
            .find(|t| t.gmi == trainer)
            .map(|t| t.gpu)
            .unwrap_or(0);
        let (arrival, transfer_s, cross_gpu) =
            fabric.transfer(src_gpu, chosen_gpu, pkt.bytes(), pkt.ready);
        *self.outstanding.entry(trainer).or_insert(0) += pkt.samples();
        RouteDecision {
            trainer,
            arrival,
            transfer_s,
            cross_gpu,
            sender_s: fabric.submission_lat(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channels::Chunk;
    use crate::cluster::Topology;

    fn packet(agent: usize, ch: ChannelKind, floats: usize, t: f64) -> Packet {
        Packet {
            channel: ch,
            chunks: vec![Chunk {
                channel: ch,
                agent,
                seq: 0,
                steps: 1,
                envs: floats,
                data: vec![0.0; floats],
                ready: Clock(t),
            }],
            ready: Clock(t),
        }
    }

    fn setup() -> (Migrator, Fabric) {
        let fabric = Fabric::single_node(Topology::dgx_a100(4));
        let trainers = vec![
            TrainerEndpoint { gmi: 10, gpu: 2 },
            TrainerEndpoint { gmi: 11, gpu: 3 },
        ];
        let mut m = Migrator::new(trainers);
        m.register_agent(0, 0);
        m.register_agent(1, 2); // same GPU as trainer 10
        m.register_agent(2, 0);
        (m, fabric)
    }

    #[test]
    fn sticky_per_agent_alignment() {
        let (mut m, mut f) = setup();
        let d1 = m.route(&mut f, &packet(0, ChannelKind::State, 100, 1.0));
        // every other channel of agent 0 follows the same trainer
        for ch in [ChannelKind::Action, ChannelKind::Reward, ChannelKind::Done] {
            let d = m.route(&mut f, &packet(0, ch, 10, 1.1));
            assert_eq!(d.trainer, d1.trainer, "channel {ch:?} split from its group");
        }
    }

    #[test]
    fn new_agents_balance_across_trainers() {
        let (mut m, mut f) = setup();
        let d0 = m.route(&mut f, &packet(0, ChannelKind::State, 100, 1.0));
        let d2 = m.route(&mut f, &packet(2, ChannelKind::State, 100, 1.0));
        assert_ne!(d0.trainer, d2.trainer, "second agent should take the idle trainer");
    }

    #[test]
    fn prefers_same_gpu_when_balanced() {
        let (mut m, mut f) = setup();
        let d = m.route(&mut f, &packet(1, ChannelKind::State, 100, 1.0));
        assert_eq!(d.trainer, 10);
        assert!(!d.cross_gpu);
    }

    #[test]
    fn rebalances_at_group_boundary_when_skewed() {
        let (mut m, mut f) = setup();
        let d0 = m.route(&mut f, &packet(0, ChannelKind::State, 4000, 1.0));
        // trainer d0 now has a big backlog; agent 0's next group boundary
        // should move it to the other trainer (backlog > 2x other).
        let d1 = m.route(&mut f, &packet(0, ChannelKind::State, 100, 2.0));
        assert_ne!(d1.trainer, d0.trainer);
        // non-boundary packets never migrate mid-group
        let d2 = m.route(&mut f, &packet(0, ChannelKind::Reward, 10, 2.1));
        assert_eq!(d2.trainer, d1.trainer);
    }

    #[test]
    fn completion_drains_backlog() {
        let (mut m, mut f) = setup();
        let d = m.route(&mut f, &packet(0, ChannelKind::State, 500, 1.0));
        assert_eq!(m.outstanding(d.trainer), 500);
        m.complete(d.trainer, 400);
        assert_eq!(m.outstanding(d.trainer), 100);
        m.complete(d.trainer, 200);
        assert_eq!(m.outstanding(d.trainer), 0);
    }

    #[test]
    fn cross_gpu_costs_more_and_arrival_after_ready() {
        let (mut m, mut f) = setup();
        let same = m.route(&mut f, &packet(1, ChannelKind::State, 40960, 5.0));
        assert!(!same.cross_gpu);
        assert!(same.arrival.0 > 5.0);
        assert!(same.sender_s > 0.0);
        let cross = m.route(&mut f, &packet(0, ChannelKind::State, 40960, 5.0));
        assert!(cross.cross_gpu);
        assert!(cross.transfer_s > same.transfer_s);
    }

    #[test]
    fn contended_links_serialize_packets() {
        let (mut m, mut f) = setup();
        // Two packets from agent 1 to its same-GPU trainer, both ready at
        // t=1: the second queues behind the first on the host link.
        let a = m.route(&mut f, &packet(1, ChannelKind::State, 40960, 1.0));
        let b = m.route(&mut f, &packet(1, ChannelKind::Action, 40960, 1.0));
        assert_eq!(a.trainer, b.trainer);
        assert!(b.arrival > a.arrival, "contended link must serialize");
        assert!(b.arrival.seconds() >= a.arrival.seconds() + b.transfer_s - 1e-12);
        // Fabric accounted the traffic.
        let total: u64 = f.link_report().iter().map(|l| l.bytes).sum();
        assert_eq!(total, 2 * 40960 * 4);
    }
}
