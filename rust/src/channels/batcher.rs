//! The experience batcher (BT): per-trainer data preparation — slicing and
//! stacking channel packets back into training batches (paper §4.2).

use std::collections::BTreeMap;

use crate::vtime::Clock;

use super::{ChannelKind, Packet, ShareMode};

/// A training batch ready for the PPO/A3C update.
#[derive(Debug, Clone)]
pub struct TrainBatch {
    pub samples: usize,
    /// Per-channel stacked data (multi-channel) or the interleaved blob
    /// under the State key (uni-channel).
    pub data: BTreeMap<ChannelKind, Vec<f32>>,
    /// When the last contributing packet arrived at the trainer.
    pub ready: Clock,
}

/// Per-trainer batcher: accumulates per-channel samples and emits a batch
/// once `batch_samples` are available on every required channel. Supports
/// the paper's two preparation modes: *slicing* (small batches for high
/// update frequency) and *stacking* (large batches for noise reduction) —
/// both fall out of the `batch_samples` knob.
#[derive(Debug)]
pub struct Batcher {
    pub trainer: usize,
    mode: ShareMode,
    batch_samples: usize,
    acc: BTreeMap<ChannelKind, Vec<f32>>,
    samples: BTreeMap<ChannelKind, usize>,
    latest: Clock,
}

impl Batcher {
    pub fn new(trainer: usize, mode: ShareMode, batch_samples: usize) -> Self {
        Batcher {
            trainer,
            mode,
            batch_samples,
            acc: BTreeMap::new(),
            samples: BTreeMap::new(),
            latest: Clock::zero(),
        }
    }

    fn required_channels(&self) -> &'static [ChannelKind] {
        // Both modes deliver per-component data (UCC just unbatched); a
        // training batch needs every component.
        let _ = self.mode;
        &ChannelKind::ALL
    }

    /// Accept a routed packet (arrival time from the migrator's decision);
    /// returns completed batches.
    pub fn push(&mut self, pkt: Packet, arrival: Clock) -> Vec<TrainBatch> {
        if arrival > self.latest {
            self.latest = arrival;
        }
        let n = pkt.samples();
        *self.samples.entry(pkt.channel).or_insert(0) += n;
        let acc = self.acc.entry(pkt.channel).or_default();
        for c in &pkt.chunks {
            acc.extend_from_slice(&c.data);
        }

        let mut out = Vec::new();
        while self.batch_ready() {
            out.push(self.cut_batch());
        }
        out
    }

    fn batch_ready(&self) -> bool {
        self.required_channels()
            .iter()
            .all(|ch| self.samples.get(ch).copied().unwrap_or(0) >= self.batch_samples)
    }

    /// Slice exactly `batch_samples` off the front of every channel.
    fn cut_batch(&mut self) -> TrainBatch {
        let mut data = BTreeMap::new();
        for &ch in self.required_channels() {
            let have = self.samples.get(&ch).copied().unwrap_or(0);
            let buf = self.acc.get_mut(&ch).unwrap();
            let per_sample = buf.len() / have.max(1);
            let take = self.batch_samples * per_sample;
            let rest = buf.split_off(take.min(buf.len()));
            let head = std::mem::replace(buf, rest);
            data.insert(ch, head);
            *self.samples.get_mut(&ch).unwrap() -= self.batch_samples;
        }
        TrainBatch { samples: self.batch_samples, data, ready: self.latest }
    }

    pub fn pending_samples(&self, ch: ChannelKind) -> usize {
        self.samples.get(&ch).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channels::Chunk;

    fn pkt(ch: ChannelKind, steps: usize, envs: usize, width: usize, t: f64) -> Packet {
        Packet {
            channel: ch,
            chunks: vec![Chunk {
                channel: ch,
                agent: 0,
                seq: 0,
                steps,
                envs,
                data: vec![1.0; steps * envs * width],
                ready: Clock(t),
            }],
            ready: Clock(t),
        }
    }

    #[test]
    fn emits_when_all_channels_ready() {
        let mut bt = Batcher::new(0, ShareMode::MultiChannel, 8);
        // push 8 samples on every channel except Done: no batch yet
        for &ch in &ChannelKind::ALL[..5] {
            let w = ch.width(6, 2);
            assert!(bt.push(pkt(ch, 2, 4, w, 1.0), Clock(1.1)).is_empty());
        }
        let out = bt.push(pkt(ChannelKind::Done, 2, 4, 1, 2.0), Clock(2.5));
        assert_eq!(out.len(), 1);
        let b = &out[0];
        assert_eq!(b.samples, 8);
        assert_eq!(b.data[&ChannelKind::State].len(), 8 * 6);
        assert_eq!(b.data[&ChannelKind::Reward].len(), 8);
        // batch readiness = last arrival
        assert_eq!(b.ready, Clock(2.5));
    }

    #[test]
    fn slicing_excess_into_multiple_batches() {
        let mut bt = Batcher::new(0, ShareMode::MultiChannel, 4);
        let mut batches = Vec::new();
        for &ch in &ChannelKind::ALL {
            let w = ch.width(6, 2);
            batches.extend(bt.push(pkt(ch, 4, 2, w, 1.0), Clock(1.0)));
        }
        // 8 samples per channel, batch=4 -> two batches after the last push
        assert_eq!(batches.len(), 2);
        assert_eq!(bt.pending_samples(ChannelKind::State), 0);
    }

    #[test]
    fn unichannel_needs_all_components_too() {
        let mut bt = Batcher::new(0, ShareMode::UniChannel, 4);
        assert!(bt.push(pkt(ChannelKind::State, 1, 4, 6, 1.0), Clock(1.0)).is_empty());
        let mut out = Vec::new();
        for &ch in &ChannelKind::ALL[1..] {
            let w = ch.width(6, 2);
            out.extend(bt.push(pkt(ch, 1, 4, w, 1.0), Clock(1.2)));
        }
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].samples, 4);
    }
}
