//! Channel-based experience sharing (paper §4.2, Figure 5).
//!
//! Connects agent GMIs to trainer GMIs in asynchronized training. The
//! experience record is heterogeneous (states are wide, rewards are one
//! float), so a single monolithic stream ("uni-channel", UCC) wastes
//! bandwidth on small ragged transfers. The multi-channel design (MCC)
//! splits experience into typed channels and re-batches per channel:
//!
//! * [`Dispenser`] (per agent) categorizes experience into channels;
//! * [`Compressor`] (system-wide) concatenates per-channel chunks until a
//!   transfer-size threshold is met (the paper's "increase the size of
//!   each data movement"), with an age bound so low-traffic channels
//!   can't starve below the threshold;
//! * [`Migrator`] (system-wide) routes packets to the least-loaded trainer
//!   over [`fabric`](crate::fabric) routes (same-GPU host hop vs cross-GPU
//!   NVLink + handoff) with per-link occupancy, so contended links
//!   serialize;
//! * [`Batcher`] (per trainer) slices/stacks channel data back into
//!   training batches.
//!
//! All components are deterministic queue machines driven by the async
//! orchestrator (`drl::a3c`); items carry virtual timestamps.

mod batcher;
mod compressor;
mod dispenser;
mod migrator;

pub use batcher::{Batcher, TrainBatch};
pub use compressor::Compressor;
pub use dispenser::{Dispenser, RolloutSegment};
pub use migrator::{Migrator, RouteDecision, TrainerEndpoint};

use crate::vtime::Clock;

/// The typed experience channels (paper Fig 5(a): Exp_S, Exp_A, ...).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ChannelKind {
    State,
    Action,
    Logp,
    Reward,
    Value,
    Done,
}

impl ChannelKind {
    pub const ALL: [ChannelKind; 6] = [
        ChannelKind::State,
        ChannelKind::Action,
        ChannelKind::Logp,
        ChannelKind::Reward,
        ChannelKind::Value,
        ChannelKind::Done,
    ];

    /// Floats per (env, step) element in this channel for a benchmark with
    /// `obs_dim` observations and `act_dim` actions.
    pub fn width(&self, obs_dim: usize, act_dim: usize) -> usize {
        match self {
            ChannelKind::State => obs_dim,
            ChannelKind::Action => act_dim,
            ChannelKind::Logp | ChannelKind::Reward | ChannelKind::Value | ChannelKind::Done => 1,
        }
    }
}

/// Sharing mode: the paper's multi-channel design vs the uni-channel
/// baseline (Table 8's UCC vs MCC).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShareMode {
    UniChannel,
    MultiChannel,
}

/// One typed slice of experience from one agent rollout segment.
#[derive(Debug, Clone)]
pub struct Chunk {
    pub channel: ChannelKind,
    pub agent: usize,
    /// Rollout segment sequence number at the producing agent.
    pub seq: u64,
    /// (steps, envs) this chunk covers.
    pub steps: usize,
    pub envs: usize,
    pub data: Vec<f32>,
    /// Producer's virtual clock when the chunk became available.
    pub ready: Clock,
}

impl Chunk {
    pub fn bytes(&self) -> usize {
        4 * self.data.len()
    }
}

/// A transfer unit: one or more concatenated chunks of the same channel.
#[derive(Debug, Clone)]
pub struct Packet {
    pub channel: ChannelKind,
    pub chunks: Vec<Chunk>,
    /// max over member chunk ready times (can't ship before produced).
    pub ready: Clock,
}

impl Packet {
    pub fn bytes(&self) -> usize {
        self.chunks.iter().map(Chunk::bytes).sum()
    }

    pub fn samples(&self) -> usize {
        self.chunks.iter().map(|c| c.steps * c.envs).sum()
    }
}

/// Pipeline traffic statistics (drives Table 8's analysis).
#[derive(Debug, Default, Clone)]
pub struct ChannelStats {
    pub chunks_in: u64,
    pub packets_out: u64,
    pub bytes_moved: u64,
    pub transfer_ops: u64,
    pub transfer_seconds: f64,
}

impl ChannelStats {
    pub fn mean_packet_bytes(&self) -> f64 {
        if self.packets_out == 0 {
            0.0
        } else {
            self.bytes_moved as f64 / self.packets_out as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_widths() {
        assert_eq!(ChannelKind::State.width(60, 8), 60);
        assert_eq!(ChannelKind::Action.width(60, 8), 8);
        assert_eq!(ChannelKind::Reward.width(60, 8), 1);
        assert_eq!(ChannelKind::ALL.len(), 6);
    }

    #[test]
    fn packet_accounting() {
        let c = |n: usize| Chunk {
            channel: ChannelKind::State,
            agent: 0,
            seq: 0,
            steps: 1,
            envs: n,
            data: vec![0.0; n * 60],
            ready: Clock(1.0),
        };
        let p = Packet {
            channel: ChannelKind::State,
            chunks: vec![c(4), c(8)],
            ready: Clock(2.0),
        };
        assert_eq!(p.samples(), 12);
        assert_eq!(p.bytes(), 4 * 12 * 60);
    }
}
