//! The experience dispenser (DP): per-agent service that categorizes a
//! rollout's experience into typed channel chunks (paper §4.2).

use crate::vtime::Clock;

use super::{ChannelKind, Chunk, ShareMode};

/// Per-agent dispenser. In multi-channel mode one rollout segment becomes
//  one chunk per channel; in uni-channel mode it becomes per-step
/// interleaved chunks on the State channel only (the monolithic baseline:
/// every step's full record ships as its own small message).
#[derive(Debug)]
pub struct Dispenser {
    pub agent: usize,
    obs_dim: usize,
    act_dim: usize,
    seq: u64,
}

/// One rollout segment's experience as produced by the rollout artifact
/// (row-major [steps, envs, width] buffers).
#[derive(Debug, Clone)]
pub struct RolloutSegment {
    pub steps: usize,
    pub envs: usize,
    pub obs: Vec<f32>,
    pub actions: Vec<f32>,
    pub logps: Vec<f32>,
    pub rewards: Vec<f32>,
    pub values: Vec<f32>,
    pub dones: Vec<f32>,
}

impl RolloutSegment {
    /// Synthetic segment for cost-model-only runs (NullCompute).
    pub fn synthetic(steps: usize, envs: usize, obs_dim: usize, act_dim: usize) -> Self {
        let sn = steps * envs;
        RolloutSegment {
            steps,
            envs,
            obs: vec![0.1; sn * obs_dim],
            actions: vec![0.2; sn * act_dim],
            logps: vec![-1.0; sn],
            rewards: vec![0.05; sn],
            values: vec![0.0; sn],
            dones: vec![0.0; sn],
        }
    }

    pub fn channel_data(&self, ch: ChannelKind) -> &[f32] {
        match ch {
            ChannelKind::State => &self.obs,
            ChannelKind::Action => &self.actions,
            ChannelKind::Logp => &self.logps,
            ChannelKind::Reward => &self.rewards,
            ChannelKind::Value => &self.values,
            ChannelKind::Done => &self.dones,
        }
    }
}

impl Dispenser {
    pub fn new(agent: usize, obs_dim: usize, act_dim: usize) -> Self {
        Dispenser { agent, obs_dim, act_dim, seq: 0 }
    }

    /// Resume a dispenser whose stream already issued `seq` chunk groups.
    /// Restored programs carry the counter through [`Workload::snapshot`]
    /// (`crate::workload::Workload::snapshot`) so a post-restore chunk can
    /// never collide with a seq id the consumer saw before the kill.
    pub fn with_seq(agent: usize, obs_dim: usize, act_dim: usize, seq: u64) -> Self {
        Dispenser { agent, obs_dim, act_dim, seq }
    }

    /// The next chunk-group sequence id this dispenser will issue (the
    /// value a snapshot must carry to keep the stream collision-free).
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Categorize one rollout segment into chunks. `ready` is the agent's
    /// virtual clock after producing the segment.
    pub fn dispense(&mut self, seg: &RolloutSegment, ready: Clock, mode: ShareMode) -> Vec<Chunk> {
        self.dispense_groups(seg, ready, mode, seg.steps)
            .into_iter()
            .flatten()
            .collect()
    }

    /// Like [`dispense`], but splits the segment along the step axis into
    /// groups of at most `steps_per_group` steps, each group carrying every
    /// channel for that step range. Groups are the routing granularity: the
    /// migrator balances them across trainers (a whole segment routed as
    /// one unit would serialize on a single trainer).
    pub fn dispense_groups(
        &mut self,
        seg: &RolloutSegment,
        ready: Clock,
        mode: ShareMode,
        steps_per_group: usize,
    ) -> Vec<Vec<Chunk>> {
        let seq = self.seq;
        self.seq += 1;
        match mode {
            ShareMode::MultiChannel => {
                let spg = steps_per_group.clamp(1, seg.steps);
                let n = seg.envs;
                (0..seg.steps)
                    .step_by(spg)
                    .map(|s0| {
                        let s1 = (s0 + spg).min(seg.steps);
                        ChannelKind::ALL
                            .iter()
                            .map(|&ch| {
                                let w = match ch {
                                    ChannelKind::State => self.obs_dim,
                                    ChannelKind::Action => self.act_dim,
                                    _ => 1,
                                };
                                Chunk {
                                    channel: ch,
                                    agent: self.agent,
                                    seq,
                                    steps: s1 - s0,
                                    envs: n,
                                    data: seg.channel_data(ch)[s0 * n * w..s1 * n * w]
                                        .to_vec(),
                                    ready,
                                }
                            })
                            .collect()
                    })
                    .collect()
            }
            ShareMode::UniChannel => {
                // Baseline: every experience component of every step ships
                // as its own message through the single connection — the
                // fine-grained pattern of Fig 5(b)'s uni-channel design.
                let n = seg.envs;
                (0..seg.steps)
                    .map(|s| {
                        ChannelKind::ALL
                            .iter()
                            .map(|&ch| {
                                let w = ch.width(self.obs_dim, self.act_dim);
                                Chunk {
                                    channel: ch,
                                    agent: self.agent,
                                    seq,
                                    steps: 1,
                                    envs: n,
                                    data: seg.channel_data(ch)[s * n * w..(s + 1) * n * w]
                                        .to_vec(),
                                    ready,
                                }
                            })
                            .collect()
                    })
                    .collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multichannel_splits_by_type() {
        let mut dp = Dispenser::new(3, 6, 2);
        let seg = RolloutSegment::synthetic(4, 8, 6, 2);
        let chunks = dp.dispense(&seg, Clock(1.0), ShareMode::MultiChannel);
        assert_eq!(chunks.len(), 6);
        let state = chunks.iter().find(|c| c.channel == ChannelKind::State).unwrap();
        assert_eq!(state.data.len(), 4 * 8 * 6);
        let rew = chunks.iter().find(|c| c.channel == ChannelKind::Reward).unwrap();
        assert_eq!(rew.data.len(), 4 * 8);
        assert!(chunks.iter().all(|c| c.agent == 3 && c.seq == 0));
    }

    #[test]
    fn unichannel_is_per_step_per_component() {
        let mut dp = Dispenser::new(0, 6, 2);
        let seg = RolloutSegment::synthetic(4, 8, 6, 2);
        let chunks = dp.dispense(&seg, Clock(0.5), ShareMode::UniChannel);
        // one message per (step, component): maximally fine-grained
        assert_eq!(chunks.len(), 4 * 6);
        assert!(chunks.iter().all(|c| c.steps == 1));
        // total bytes identical between modes (same information moves)
        let mut dp2 = Dispenser::new(0, 6, 2);
        let mc = dp2.dispense(&seg, Clock(0.5), ShareMode::MultiChannel);
        let ub: usize = chunks.iter().map(Chunk::bytes).sum();
        let mb: usize = mc.iter().map(Chunk::bytes).sum();
        assert_eq!(ub, mb);
        // but in far more messages
        assert!(chunks.len() > mc.len());
    }

    #[test]
    fn group_split_preserves_data() {
        let mut dp = Dispenser::new(0, 6, 2);
        let seg = RolloutSegment::synthetic(8, 4, 6, 2);
        let groups = dp.dispense_groups(&seg, Clock(1.0), ShareMode::MultiChannel, 3);
        // ceil(8/3) = 3 groups, each with all 6 channels
        assert_eq!(groups.len(), 3);
        assert!(groups.iter().all(|g| g.len() == 6));
        let total_state: usize = groups
            .iter()
            .flatten()
            .filter(|c| c.channel == ChannelKind::State)
            .map(|c| c.data.len())
            .sum();
        assert_eq!(total_state, 8 * 4 * 6);
        let steps: Vec<usize> = groups
            .iter()
            .map(|g| g.iter().find(|c| c.channel == ChannelKind::State).unwrap().steps)
            .collect();
        assert_eq!(steps, vec![3, 3, 2]);
    }

    #[test]
    fn seq_increments() {
        let mut dp = Dispenser::new(0, 4, 2);
        let seg = RolloutSegment::synthetic(1, 2, 4, 2);
        let a = dp.dispense(&seg, Clock(0.0), ShareMode::MultiChannel);
        let b = dp.dispense(&seg, Clock(0.1), ShareMode::MultiChannel);
        assert_eq!(a[0].seq, 0);
        assert_eq!(b[0].seq, 1);
    }

    #[test]
    fn restored_dispenser_continues_the_seq_stream_without_collisions() {
        // A dispenser that issued two groups is snapshotted (seq carried)
        // and rebuilt; the resumed stream must continue at seq 2 — the
        // pre-fix `new()` rebuild restarted at 0 and collided with ids the
        // consumer already saw.
        let seg = RolloutSegment::synthetic(1, 2, 4, 2);
        let mut dp = Dispenser::new(7, 4, 2);
        let mut seen: Vec<u64> = Vec::new();
        seen.push(dp.dispense(&seg, Clock(0.0), ShareMode::MultiChannel)[0].seq);
        seen.push(dp.dispense(&seg, Clock(0.1), ShareMode::MultiChannel)[0].seq);
        let carried = dp.seq();
        assert_eq!(carried, 2);
        let mut restored = Dispenser::with_seq(7, 4, 2, carried);
        let after = restored.dispense(&seg, Clock(0.2), ShareMode::MultiChannel)[0].seq;
        assert!(!seen.contains(&after), "post-restore seq {after} collides with {seen:?}");
        assert_eq!(after, 2);
    }
}
