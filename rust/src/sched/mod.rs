//! Multi-tenant cluster scheduling: preemptive co-scheduling of
//! heterogeneous DRL jobs on one shared cluster — the GMI answer to the
//! paper's §8 "cluster scheduling" direction, grown from the single-job
//! bin-packer ([`gmi::scheduler`](crate::gmi::scheduler)) into a running
//! system.
//!
//! Every standalone driver in this crate assumes exclusive ownership of
//! the whole cluster; this module drops that assumption. A queue of
//! [`JobSpec`]s — sync training runs, A3C pipelines, closed-loop
//! collectors, serving fleets with SLO classes — is admitted onto one
//! shared [`Topology`](crate::cluster::Topology), placed through the
//! [`GmiManager`](crate::gmi::GmiManager)'s validation (no
//! oversubscription ever, enforced at every placement/resize), and
//! co-executed on a single shared [`Engine`](crate::engine::Engine) with
//! per-job event tagging and cross-job interference accounting in the
//! executors. Each tenant runs as a steppable
//! [`Workload`](crate::workload::Workload) program — the SAME
//! implementation its standalone run loop drives — so the scheduler holds
//! no per-kind execution logic and a single-tenant cluster run is
//! bit-identical to the standalone run. The scheduler is *preemptive*: a high-priority arrival or a
//! serving tenant missing its SLO window shrinks and, if needed, evicts
//! lower-priority tenants' GMIs through the validated
//! `resize_share`/`remove_gmi` paths — never below the tenant's
//! guaranteed floor, which the manager's typed
//! [`RemoveGmiError`](crate::gmi::RemoveGmiError) guard enforces — and
//! restores them once pressure drops.
//!
//! [`run_cluster`] returns per-job [`RunMetrics`](crate::metrics::RunMetrics)
//! plus cluster-level fairness (Jain's index over per-job busy
//! GPU-seconds) and utilization, and the full scheduling timeline
//! ([`SchedEvent`]) — the preemption story `examples/shared_cluster.rs`
//! prints.

mod cluster;
mod job;

pub use cluster::{
    run_cluster, sched_table, ClusterRunResult, FastForward, JobReport, SchedAction, SchedConfig,
    SchedEvent, CLUSTER_EVENT,
};
pub use job::{JobId, JobKind, JobSpec};

use crate::cluster::Topology;
use crate::config::BenchInfo;
use crate::serve::{batch_seconds, generate_trace, GatewayConfig, TraceSource, TrafficPattern};
use crate::vtime::CostModel;

/// The canonical two-tenant co-run: a low-priority sync-training job plus
/// a high-priority diurnal serving fleet sharing `topo`, sized off the
/// gateway's own capacity yardstick ([`serve::batch_seconds`](crate::serve::batch_seconds))
/// so the diurnal peak (1.2x the static fleet's capacity) forces the
/// preemptive schedule to reclaim training share while the trough lets it
/// give the share back.
///
/// `partitioned` selects the static-partitioning baseline: each tenant is
/// pinned to its own side of the cluster at fixed provisioning (training
/// gets `g/2` whole exclusive GPUs, serving the remaining `g - g/2`),
/// the classic one-job-per-GPU-slice arrangement the scheduler is
/// measured against. Both variants simulate the same total environments
/// and replay the identical seeded trace, so their per-job metrics are
/// directly comparable. `topo` needs any GPU count >= 2 (odd counts give
/// serving the larger side).
pub fn corun_scenario(
    topo: &Topology,
    bench: &BenchInfo,
    cost: &CostModel,
    duration_s: f64,
    seed: u64,
    partitioned: bool,
) -> Vec<JobSpec> {
    let g = topo.num_gpus();
    assert!(g >= 2, "corun_scenario needs at least 2 GPUs, got {g}");
    let train_gpus = g / 2;
    let serve_gpus = g - train_gpus;
    let serve_share = 0.25;
    let max_batch = 32;
    let member_rate = max_batch as f64 / batch_seconds(bench, cost, topo, serve_share, max_batch);
    // The static baseline packs 4 serving members on each of its GPUs.
    let static_members = 4 * serve_gpus;
    let static_capacity = member_rate * static_members as f64;
    let pattern = TrafficPattern::Diurnal {
        base: 0.25 * static_capacity,
        peak: 1.2 * static_capacity,
        period_s: duration_s,
    };
    // Streamed lazily: bit-identical to `generate_trace` on the same
    // seeds (the traffic property suite locks this in), so the pinned
    // scheduler goldens are unchanged while the trace itself never
    // materializes.
    let trace = TraceSource::streaming(&pattern, duration_s, seed, 8);
    let slo = 20e-3;
    // Enough training iterations to outlast the serving day.
    let iters = ((duration_s * 12.0).ceil() as usize).max(4);
    if partitioned {
        let mut train =
            JobSpec::training(0, "train-ppo", 1, 0.0, train_gpus, 1.0, 1.0, 2048, iters);
        train.pin_gpus = Some((0..train_gpus).collect());
        let mut serve = JobSpec::serving(
            1,
            "serve-slo",
            9,
            0.0,
            (static_members, static_members, static_members),
            serve_share,
            max_batch,
            slo,
            trace,
        );
        serve.pin_gpus = Some((train_gpus..g).collect());
        vec![train, serve]
    } else {
        // Same total envs (2 x train_gpus x 1024 vs train_gpus x 2048),
        // whole cluster shared: training spreads multiplexed GMIs across
        // GPUs, the serving fleet starts at one member per GPU and may
        // grow to three under load.
        let train =
            JobSpec::training(0, "train-ppo", 1, 0.0, 2 * train_gpus, 0.5, 0.25, 1024, iters);
        let serve = JobSpec::serving(
            1,
            "serve-slo",
            9,
            0.0,
            (g, g, 3 * g),
            serve_share,
            max_batch,
            slo,
            trace,
        );
        vec![train, serve]
    }
}

/// The off-policy co-run: an on-policy PPO trainer, an off-policy
/// replay-buffer learner, and a self-play league coordinator sharing
/// `topo`. The three stress different scheduler paths at once — steady
/// batch tenancy (training), memory-budgeted buffer tenancy with a
/// collector/learner split (replay), and dynamic tenant churn (the league
/// spawns and retires match jobs through the admission path for the whole
/// run). Deterministic in `seed`; `topo` needs >= 2 GPUs.
pub fn offpolicy_corun_scenario(
    topo: &Topology,
    bench: &BenchInfo,
    cost: &CostModel,
    seed: u64,
) -> Vec<JobSpec> {
    let g = topo.num_gpus();
    assert!(g >= 2, "offpolicy_corun_scenario needs at least 2 GPUs, got {g}");
    let train = JobSpec::training(0, "train-ppo", 1, 0.0, g, 0.3, 0.15, 1024, 12);
    let replay = JobSpec::replay(
        1,
        "replay-learner",
        4,
        0.0,
        g,
        0.25,
        0.1,
        1024,
        crate::workload::ReplayConfig { rounds: 6, seed, ..Default::default() },
    );
    let league = JobSpec::league(
        2,
        "league",
        6,
        0.0,
        0.1,
        crate::workload::LeagueConfig {
            players: 4,
            total_matches: 8,
            max_concurrent: 2,
            match_rounds: 2,
            match_num_env: 256,
            match_share: 0.15,
            match_priority: 3,
            seed,
        },
    );
    vec![train, replay, league]
}

/// Knobs of the week-scale scenario ([`week_scenario`]): which of the
/// three cooperating fast-path mechanisms are engaged. `disabled()` is
/// the exact-baseline configuration the week benchmark measures against.
#[derive(Debug, Clone, Copy)]
pub struct WeekOpts {
    /// Stream the arrival traces lazily (O(1) memory) instead of
    /// materializing them up front. Either way the request sequence is
    /// bit-identical.
    pub streaming: bool,
    /// Macro-request aggregation factor for the serving tenants
    /// ([`GatewayConfig::aggregation`]); 1 disables coalescing.
    pub aggregation: usize,
    /// Latency sample cap for the serving tenants
    /// ([`GatewayConfig::sample_cap`]); `None` keeps every sample.
    pub sample_cap: Option<usize>,
}

impl WeekOpts {
    /// All three mechanisms on, sized for a simulated week.
    pub fn fast() -> WeekOpts {
        WeekOpts { streaming: true, aggregation: 8, sample_cap: Some(8192) }
    }

    /// The exact baseline: materialized traces, no coalescing, every
    /// sample retained.
    pub fn disabled() -> WeekOpts {
        WeekOpts { streaming: false, aggregation: 1, sample_cap: None }
    }
}

/// The week-scale co-run: an early-finishing training job plus two
/// open-loop serving tenants — a diurnal fleet cycling through seven deep
/// day/night swings and a bursty low-rate gateway with a mid-week spike —
/// sharing `topo` for `duration_s` simulated seconds (a week at the
/// default 604 800). Absolute request rates are fixed (mean ~1.5 req/s on
/// the diurnal tenant, ~0.02 req/s plus the spike on the bursty one), so
/// the trough stretches between arrivals span thousands of scheduler
/// quanta — the workload the idle-round fast-forward and streaming traces
/// exist for. Deterministic in `seed`; `topo` needs >= 2 GPUs.
pub fn week_scenario(
    topo: &Topology,
    duration_s: f64,
    seed: u64,
    opts: &WeekOpts,
) -> Vec<JobSpec> {
    let g = topo.num_gpus();
    assert!(g >= 2, "week_scenario needs at least 2 GPUs, got {g}");
    // Seven diurnal periods regardless of the horizon, so shortened runs
    // (tests, the bench's quick mode) keep the week's shape.
    let day_s = duration_s / 7.0;
    let diurnal = TrafficPattern::Diurnal { base: 0.05, peak: 3.0, period_s: day_s };
    let burst = TrafficPattern::Burst {
        base: 0.02,
        burst: 50.0,
        start_s: duration_s * 0.5,
        len_s: day_s * 0.01,
    };
    let mk_trace = |pattern: &TrafficPattern, seed: u64, sources: usize| {
        if opts.streaming {
            TraceSource::streaming(pattern, duration_s, seed, sources)
        } else {
            TraceSource::from(generate_trace(pattern, duration_s, seed, sources))
        }
    };
    // A training tenant that finishes early in the week: once it drains,
    // the cluster is serving-only and the trough rounds become provably
    // quiescent.
    let train = JobSpec::training(0, "train-ppo", 1, 0.0, 2, 0.5, 0.25, 1024, 64);
    let serve_cfg = GatewayConfig {
        max_batch: 32,
        max_wait_s: 0.05,
        slo_s: 0.2,
        aggregation: opts.aggregation.max(1),
        sample_cap: opts.sample_cap,
        ..GatewayConfig::default()
    };
    let serve = JobSpec::gateway(
        1,
        "serve-diurnal",
        9,
        0.0,
        (1, 2, 4),
        0.25,
        serve_cfg,
        mk_trace(&diurnal, seed, 8),
    );
    let spike_cfg = GatewayConfig {
        max_batch: 64,
        max_wait_s: 0.1,
        slo_s: 0.5,
        aggregation: opts.aggregation.max(1),
        sample_cap: opts.sample_cap,
        ..GatewayConfig::default()
    };
    let spike = JobSpec::gateway(
        2,
        "serve-burst",
        8,
        0.0,
        (1, 1, 2),
        0.25,
        spike_cfg,
        mk_trace(&burst, seed.wrapping_add(1), 4),
    );
    vec![train, serve, spike]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::static_registry;

    #[test]
    fn corun_scenario_variants_are_comparable() {
        let b = static_registry()["AT"].clone();
        let cost = CostModel::new(&b);
        let topo = Topology::dgx_a100(2);
        let stat = corun_scenario(&topo, &b, &cost, 0.5, 7, true);
        let elas = corun_scenario(&topo, &b, &cost, 0.5, 7, false);
        assert_eq!(stat.len(), 2);
        assert_eq!(elas.len(), 2);
        for s in stat.iter().chain(&elas) {
            s.validate(&topo).unwrap();
        }
        // Identical seeded trace in both variants.
        let trace_of = |j: &JobSpec| match &j.kind {
            JobKind::Serving { trace, .. } => trace.clone(),
            _ => panic!("expected serving"),
        };
        assert_eq!(trace_of(&stat[1]), trace_of(&elas[1]));
        // Same total simulated environments.
        let envs = |j: &JobSpec| match &j.kind {
            JobKind::Training { num_env, .. } => num_env * j.initial_gmis,
            _ => panic!("expected training"),
        };
        assert_eq!(envs(&stat[0]), envs(&elas[0]));
        // Static pins split the cluster; elastic shares it.
        assert_eq!(stat[0].pin_gpus, Some(vec![0]));
        assert_eq!(stat[1].pin_gpus, Some(vec![1]));
        assert!(elas[0].pin_gpus.is_none() && elas[1].pin_gpus.is_none());
        assert!(elas[1].max_gmis > elas[1].initial_gmis, "elastic fleet must have headroom");
    }

    #[test]
    fn offpolicy_corun_scenario_validates_and_runs() {
        let b = static_registry()["AY"].clone();
        let cost = CostModel::new(&b);
        let topo = Topology::dgx_a100(2);
        let jobs = offpolicy_corun_scenario(&topo, &b, &cost, 7);
        assert_eq!(jobs.len(), 3);
        for j in &jobs {
            j.validate(&topo).unwrap();
        }
        let r = run_cluster(&topo, &b, &cost, &jobs, &SchedConfig::default()).unwrap();
        // All three tenants plus every spawned match completed.
        assert!(r.jobs.len() > 3, "the league never spawned a match");
        assert!(r.jobs.iter().all(|j| j.completed_s > 0.0), "a tenant never completed");
        assert!(r.job(1).unwrap().metrics.replay.is_some());
        assert!(r.peak_gpu_share <= 1.0 + 1e-6);
    }

    #[test]
    fn corun_scenario_supports_odd_gpu_counts() {
        // Regression for the arbitrary "even GPU count" restriction: odd
        // clusters build valid layouts (serving takes the larger side) and
        // a short preemptive day runs to completion.
        let b = static_registry()["AT"].clone();
        let cost = CostModel::new(&b);
        let topo = Topology::dgx_a100(3);
        for partitioned in [true, false] {
            let jobs = corun_scenario(&topo, &b, &cost, 0.2, 7, partitioned);
            for j in &jobs {
                j.validate(&topo).unwrap();
            }
        }
        // Static pins split 1 + 2; total envs match across variants.
        let stat = corun_scenario(&topo, &b, &cost, 0.2, 7, true);
        let elas = corun_scenario(&topo, &b, &cost, 0.2, 7, false);
        assert_eq!(stat[0].pin_gpus, Some(vec![0]));
        assert_eq!(stat[1].pin_gpus, Some(vec![1, 2]));
        let envs = |j: &JobSpec| match &j.kind {
            JobKind::Training { num_env, .. } => num_env * j.initial_gmis,
            _ => panic!("expected training"),
        };
        assert_eq!(envs(&stat[0]), envs(&elas[0]));

        let r = crate::sched::run_cluster(&topo, &b, &cost, &elas, &SchedConfig::default())
            .unwrap();
        assert!(r.peak_gpu_share <= 1.0 + 1e-6);
        assert!(r.jobs.iter().all(|j| j.completed_s > 0.0), "a tenant never completed");
    }

    #[test]
    fn week_scenario_validates_and_runs_at_a_short_horizon() {
        // Smoke over both WeekOpts presets at a shrunken horizon: jobs
        // pass cluster validation, the three tenants complete, and the
        // serving jobs actually see traffic. The bit-identity of fast vs
        // disabled is covered by the determinism suite.
        let b = static_registry()["AT"].clone();
        let cost = CostModel::new(&b);
        let topo = Topology::dgx_a100(2);
        for opts in [WeekOpts::fast(), WeekOpts::disabled()] {
            let jobs = week_scenario(&topo, 20.0, 11, &opts);
            assert_eq!(jobs.len(), 3);
            for j in &jobs {
                j.validate(&topo).unwrap();
            }
            let cfg = SchedConfig { fast_forward: FastForward::On, ..SchedConfig::default() };
            let r = run_cluster(&topo, &b, &cost, &jobs, &cfg).unwrap();
            assert!(r.jobs.iter().all(|j| j.completed_s > 0.0), "a tenant never completed");
            let served: usize = r
                .jobs
                .iter()
                .filter_map(|j| j.metrics.latency.as_ref())
                .map(|l| l.served)
                .sum();
            assert!(served > 0, "the week's serving tenants saw no traffic");
            assert!(r.peak_gpu_share <= 1.0 + 1e-6);
        }
    }
}
