//! Job descriptions for the multi-tenant cluster scheduler.
//!
//! A [`JobSpec`] is the tenancy contract one workload signs with the
//! cluster: what kind of work it runs ([`JobKind`]), when it arrives, how
//! important it is, and the GMI envelope it may occupy — between
//! `min_gmis x min_share` (the guaranteed floor preemption can shrink it
//! to but never past, enforced by the manager's removal guard) and
//! `max_gmis x share` (the ceiling elasticity may grow it to).

use anyhow::Result;

use crate::cluster::Topology;
use crate::gmi::Role;
use crate::serve::Request;

/// Cluster-unique job identifier.
pub type JobId = usize;

/// What a tenant actually runs.
#[derive(Debug, Clone)]
pub enum JobKind {
    /// Synchronized PPO-style training: `iterations` of (rollout of
    /// `horizon` steps over `num_env` envs per GMI, then `minibatches`
    /// gradient + allreduce rounds). Charges the same rollout ops as
    /// [`drl::sync`](crate::drl::sync) and reduces over the job's own
    /// fabric allreduce plan.
    Training {
        iterations: usize,
        horizon: usize,
        /// Environments per member GMI.
        num_env: usize,
        minibatches: usize,
    },
    /// Open-loop serving fleet with an SLO class: the trace's requests are
    /// batched (up to `max_batch`, flushed every scheduling round) onto the
    /// job's least-loaded GMI through the shared dispatch cost model
    /// ([`serve::execute_dispatch`](crate::serve::execute_dispatch)). A
    /// scheduling round whose dispatched p99 violates `slo_p99_s` raises
    /// pressure: the scheduler grows the fleet, preempting lower-priority
    /// tenants if it must.
    Serving {
        trace: Vec<Request>,
        slo_p99_s: f64,
        max_batch: usize,
    },
}

/// The tenancy contract of one job.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub id: JobId,
    pub name: String,
    /// Higher admits first and may preempt lower (never equal or higher).
    pub priority: u8,
    /// Cluster time the job joins the queue.
    pub arrival_s: f64,
    /// Guaranteed member floor: eviction never drops the job below it.
    pub min_gmis: usize,
    /// Members placed at admission (and the restore target).
    pub initial_gmis: usize,
    /// Elasticity ceiling (serving growth under SLO pressure).
    pub max_gmis: usize,
    /// SM share each member is provisioned at (and restored to).
    pub share: f64,
    /// Preemption may shrink a member to this share, never below.
    pub min_share: f64,
    /// Device memory per member GMI (GiB).
    pub mem_gib: f64,
    /// Restrict placement to these GPUs (None = whole cluster) — the
    /// static-partitioning baseline pins each tenant to its own slice.
    pub pin_gpus: Option<Vec<usize>>,
    pub kind: JobKind,
}

impl JobSpec {
    /// A fixed-size training tenant: `gmis` members at `share`, shrinkable
    /// to `min_share` but never evicted below `gmis` members.
    #[allow(clippy::too_many_arguments)]
    pub fn training(
        id: JobId,
        name: &str,
        priority: u8,
        arrival_s: f64,
        gmis: usize,
        share: f64,
        min_share: f64,
        num_env: usize,
        iterations: usize,
    ) -> JobSpec {
        JobSpec {
            id,
            name: name.to_string(),
            priority,
            arrival_s,
            min_gmis: gmis,
            initial_gmis: gmis,
            max_gmis: gmis,
            share,
            min_share,
            mem_gib: 4.0,
            pin_gpus: None,
            kind: JobKind::Training {
                iterations,
                horizon: 16,
                num_env,
                minibatches: crate::drl::DEFAULT_MINIBATCHES,
            },
        }
    }

    /// An elastic serving tenant: admitted at `initial` members, growable
    /// to `max` under SLO pressure, never evicted below `min`.
    #[allow(clippy::too_many_arguments)]
    pub fn serving(
        id: JobId,
        name: &str,
        priority: u8,
        arrival_s: f64,
        (min, initial, max): (usize, usize, usize),
        share: f64,
        max_batch: usize,
        slo_p99_s: f64,
        trace: Vec<Request>,
    ) -> JobSpec {
        JobSpec {
            id,
            name: name.to_string(),
            priority,
            arrival_s,
            min_gmis: min,
            initial_gmis: initial,
            max_gmis: max,
            share,
            min_share: share,
            mem_gib: 2.0,
            pin_gpus: None,
            kind: JobKind::Serving { trace, slo_p99_s, max_batch },
        }
    }

    /// Sanity-check the envelope (counts ordered, shares in range, and the
    /// admitted `initial_gmis` set placeable on an EMPTY allowed slice of
    /// `topo` — a job that cannot ever start is a config error, not a
    /// queue entry).
    pub fn validate(&self, topo: &Topology) -> Result<()> {
        anyhow::ensure!(
            self.min_gmis >= 1
                && self.min_gmis <= self.initial_gmis
                && self.initial_gmis <= self.max_gmis,
            "job {} ({}): GMI counts must satisfy 1 <= min <= initial <= max",
            self.id,
            self.name
        );
        anyhow::ensure!(
            self.share > 0.0 && self.share <= 1.0 && self.min_share > 0.0,
            "job {} ({}): shares must lie in (0, 1]",
            self.id,
            self.name
        );
        anyhow::ensure!(
            self.min_share <= self.share + 1e-9,
            "job {} ({}): min_share {} exceeds share {}",
            self.id,
            self.name,
            self.min_share,
            self.share
        );
        anyhow::ensure!(self.arrival_s >= 0.0, "job {}: negative arrival", self.id);
        if let JobKind::Serving { trace, slo_p99_s, max_batch } = &self.kind {
            anyhow::ensure!(*max_batch >= 1, "job {}: max_batch must be >= 1", self.id);
            anyhow::ensure!(*slo_p99_s > 0.0, "job {}: SLO must be positive", self.id);
            anyhow::ensure!(
                trace.windows(2).all(|w| w[1].arrival_s >= w[0].arrival_s),
                "job {}: trace must be sorted by arrival",
                self.id
            );
        }
        let allowed = self.allowed_gpus(topo);
        anyhow::ensure!(!allowed.is_empty(), "job {}: no allowed GPUs", self.id);
        for &g in &allowed {
            anyhow::ensure!(g < topo.num_gpus(), "job {}: pinned GPU {g} not in topology", self.id);
        }
        // The ADMITTED set must fit the empty allowed slice: admission
        // places `initial_gmis` members (>= min_gmis), so a job whose
        // initial set can never be placed would queue forever — a config
        // error, not a queue entry.
        let by_sm = ((1.0 + 1e-9) / self.share) as usize;
        let by_mem = allowed
            .iter()
            .map(|&g| ((topo.gpus[g].mem_gib + 1e-9) / self.mem_gib) as usize)
            .min()
            .unwrap_or(0);
        let cap = allowed.len() * by_sm.min(by_mem);
        anyhow::ensure!(
            cap >= self.initial_gmis,
            "job {} ({}): admitted set of {} x {:.2}-share GMIs cannot fit \
             its allowed slice of {} GPU(s)",
            self.id,
            self.name,
            self.initial_gmis,
            self.share,
            allowed.len()
        );
        Ok(())
    }

    /// GPUs this job may place on, ascending.
    pub fn allowed_gpus(&self, topo: &Topology) -> Vec<usize> {
        match &self.pin_gpus {
            Some(p) => {
                let mut v = p.clone();
                v.sort_unstable();
                v.dedup();
                v
            }
            None => (0..topo.num_gpus()).collect(),
        }
    }

    /// The aggregate SM-share floor registered with the manager's removal
    /// guard: preemption may never strand the job below it.
    pub fn floor_share(&self) -> f64 {
        self.min_gmis as f64 * self.min_share
    }

    /// DRL role of this job's member GMIs.
    pub fn role(&self) -> Role {
        match self.kind {
            JobKind::Training { .. } => Role::Holistic,
            JobKind::Serving { .. } => Role::SimAgent,
        }
    }

    /// `num_env` a member GMI is registered with (sizes rollout charges for
    /// training, the inference slot for serving).
    pub fn member_num_env(&self) -> usize {
        match &self.kind {
            JobKind::Training { num_env, .. } => *num_env,
            JobKind::Serving { max_batch, .. } => *max_batch,
        }
    }

    pub fn is_serving(&self) -> bool {
        matches!(self.kind, JobKind::Serving { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_catches_bad_envelopes() {
        let topo = Topology::dgx_a100(2);
        let ok = JobSpec::training(0, "t", 1, 0.0, 2, 0.5, 0.1, 256, 3);
        ok.validate(&topo).unwrap();

        let mut bad = ok.clone();
        bad.min_gmis = 0;
        assert!(bad.validate(&topo).is_err());

        let mut bad = ok.clone();
        bad.max_gmis = 1; // initial 2 > max 1
        assert!(bad.validate(&topo).is_err());

        let mut bad = ok.clone();
        bad.min_share = 0.9; // above share
        assert!(bad.validate(&topo).is_err());

        let mut bad = ok.clone();
        bad.share = 0.8;
        bad.min_gmis = 3; // three 0.8-share members never fit 2 GPUs
        bad.initial_gmis = 3;
        bad.max_gmis = 3;
        assert!(bad.validate(&topo).is_err());

        let mut bad = ok.clone();
        bad.pin_gpus = Some(vec![5]);
        assert!(bad.validate(&topo).is_err());

        // Pins restrict the feasibility check to the pinned slice: two
        // 0.5-share members fit one GPU, three do not.
        let mut pinned = ok.clone();
        pinned.pin_gpus = Some(vec![0]);
        pinned.validate(&topo).unwrap();
        pinned.min_gmis = 3;
        pinned.initial_gmis = 3;
        pinned.max_gmis = 3;
        assert!(pinned.validate(&topo).is_err());
    }

    #[test]
    fn floors_and_roles() {
        let t = JobSpec::training(0, "t", 1, 0.0, 2, 0.5, 0.15, 256, 3);
        assert!((t.floor_share() - 0.3).abs() < 1e-12);
        assert_eq!(t.role(), Role::Holistic);
        assert_eq!(t.member_num_env(), 256);
        assert!(!t.is_serving());

        let s = JobSpec::serving(1, "s", 9, 0.0, (1, 2, 4), 0.25, 16, 10e-3, vec![]);
        assert_eq!(s.role(), Role::SimAgent);
        assert_eq!(s.member_num_env(), 16);
        assert!(s.is_serving());
        assert!((s.floor_share() - 0.25).abs() < 1e-12);
        s.validate(&Topology::dgx_a100(1)).unwrap();
    }
}
