//! Job descriptions for the multi-tenant cluster scheduler.
//!
//! A [`JobSpec`] is the tenancy contract one workload signs with the
//! cluster: what kind of work it runs ([`JobKind`]), when it arrives, how
//! important it is, and the GMI envelope it may occupy — between
//! `min_gmis x min_share` (the guaranteed floor preemption can shrink it
//! to but never past, enforced by the manager's removal guard) and
//! `max_gmis x share` (the ceiling elasticity may grow it to).
//!
//! A [`JobKind`] is purely a *constructor*: [`JobSpec::build_program`]
//! turns it into the same steppable [`Workload`] program the standalone
//! run loops drive, so the scheduler contains no per-kind execution logic
//! at all — one implementation per workload, shared everywhere.

use anyhow::Result;

use crate::cluster::Topology;
use crate::drl::a3c::AsyncConfig;
use crate::drl::serving::ServingConfig;
use crate::drl::sync::SyncConfig;
use crate::gmi::Role;
use crate::serve::{GatewayConfig, TraceSource};
use crate::tune::AdmissionTune;
use crate::workload::{
    AsyncProgram, ClosedServingProgram, GatewayProgram, LeagueConfig, LeagueProgram,
    ReplayConfig, ReplayProgram, SyncProgram, Workload,
};

/// Cluster-unique job identifier.
pub type JobId = usize;

/// What a tenant actually runs — each variant constructs the matching
/// [`Workload`] program (see [`JobSpec::build_program`]).
#[derive(Debug, Clone)]
pub enum JobKind {
    /// Synchronized PPO-style training: `iterations` of (rollout of
    /// `horizon` steps over `num_env` envs per member, then `minibatches`
    /// gradient + allreduce rounds) — the
    /// [`SyncProgram`](crate::workload::SyncProgram) over holistic
    /// members, reducing over the job's own fabric allreduce plan.
    Training {
        iterations: usize,
        horizon: usize,
        /// Environments per member GMI.
        num_env: usize,
        minibatches: usize,
    },
    /// Open-loop serving fleet with an SLO class: the trace's requests are
    /// batched (up to `max_batch`, partial batches flushed every
    /// scheduling round) onto the job's least-loaded member — the
    /// [`GatewayProgram`](crate::workload::GatewayProgram) in round-flush
    /// mode. A scheduling round whose dispatched p99 violates `slo_p99_s`
    /// raises pressure: the scheduler grows the fleet, preempting
    /// lower-priority tenants if it must.
    Serving {
        /// Arrival stream: either a shared materialized trace (`Arc`
        /// backing — building the tenant's program clones a pointer, not
        /// the request log) or a lazily generated seeded stream
        /// ([`TraceSource::streaming`] — a week-long trace at O(1)
        /// memory).
        trace: TraceSource,
        slo_p99_s: f64,
        max_batch: usize,
    },
    /// Open-loop gateway tenant with the standalone gateway's full
    /// dynamic-batching policy (max-batch x max-wait, optional admission
    /// cap): the identical [`GatewayProgram`](crate::workload::GatewayProgram)
    /// `serve::run_gateway` drives. The scheduler owns fleet elasticity,
    /// so `cfg.autoscale` must be `None`.
    Gateway { trace: TraceSource, cfg: GatewayConfig },
    /// Closed-loop DRL serving (continuous experience collection, no
    /// arrival process) — the
    /// [`ClosedServingProgram`](crate::workload::ClosedServingProgram).
    Closed {
        rounds: usize,
        /// Environments per member GMI.
        num_env: usize,
    },
    /// Asynchronized A3C training with channel-based experience sharing —
    /// the [`AsyncProgram`](crate::workload::AsyncProgram). The first
    /// `agents` members place as serving agents, the remaining `trainers`
    /// as dedicated trainers; membership is fixed for the run (the channel
    /// pipeline's routing is keyed by it), so preemption is resize-only.
    Async {
        agents: usize,
        trainers: usize,
        /// Environments per agent member GMI.
        num_env: usize,
        cfg: AsyncConfig,
    },
    /// Off-policy replay-buffer training — the
    /// [`ReplayProgram`](crate::workload::ReplayProgram). The first
    /// `collectors` members place as experience collectors, the last as
    /// the learner owning the memory-budgeted replay buffer; membership is
    /// fixed for the run (the channel pipeline and buffer provenance are
    /// keyed by it), so preemption is resize-only.
    Replay {
        collectors: usize,
        /// Environments per collector member GMI.
        num_env: usize,
        cfg: ReplayConfig,
    },
    /// Self-play league coordinator — the
    /// [`LeagueProgram`](crate::workload::LeagueProgram): a single
    /// matchmaker member that spawns match jobs as child tenants through
    /// the scheduler's normal admission path and folds their results into
    /// a win-rate table. The first workload kind to exercise dynamic
    /// tenant creation ([`Workload::take_spawn_requests`]).
    League { cfg: LeagueConfig },
}

/// The tenancy contract of one job.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub id: JobId,
    pub name: String,
    /// Higher admits first and may preempt lower (never equal or higher).
    pub priority: u8,
    /// Cluster time the job joins the queue.
    pub arrival_s: f64,
    /// Guaranteed member floor: eviction never drops the job below it.
    pub min_gmis: usize,
    /// Members placed at admission (and the restore target).
    pub initial_gmis: usize,
    /// Elasticity ceiling (serving growth under SLO pressure).
    pub max_gmis: usize,
    /// SM share each member is provisioned at (and restored to).
    pub share: f64,
    /// Preemption may shrink a member to this share, never below.
    pub min_share: f64,
    /// Device memory per member GMI (GiB).
    pub mem_gib: f64,
    /// Restrict placement to these GPUs (None = whole cluster) — the
    /// static-partitioning baseline pins each tenant to its own slice.
    pub pin_gpus: Option<Vec<usize>>,
    pub kind: JobKind,
    /// Training tenants may request minibatch auto-tuning at admission:
    /// probe runs execute on a scratch mirror of the placed members and
    /// their virtual time is charged to the tenant's own clocks
    /// ([`crate::tune::tune_admission_minibatches`]).
    pub tune: Option<AdmissionTune>,
}

impl JobSpec {
    /// A fixed-size training tenant: `gmis` members at `share`, shrinkable
    /// to `min_share` but never evicted below `gmis` members.
    #[allow(clippy::too_many_arguments)]
    pub fn training(
        id: JobId,
        name: &str,
        priority: u8,
        arrival_s: f64,
        gmis: usize,
        share: f64,
        min_share: f64,
        num_env: usize,
        iterations: usize,
    ) -> JobSpec {
        JobSpec {
            id,
            name: name.to_string(),
            priority,
            arrival_s,
            min_gmis: gmis,
            initial_gmis: gmis,
            max_gmis: gmis,
            share,
            min_share,
            mem_gib: 4.0,
            pin_gpus: None,
            kind: JobKind::Training {
                iterations,
                horizon: 16,
                num_env,
                minibatches: crate::drl::DEFAULT_MINIBATCHES,
            },
            tune: None,
        }
    }

    /// An elastic serving tenant: admitted at `initial` members, growable
    /// to `max` under SLO pressure, never evicted below `min`.
    #[allow(clippy::too_many_arguments)]
    pub fn serving(
        id: JobId,
        name: &str,
        priority: u8,
        arrival_s: f64,
        (min, initial, max): (usize, usize, usize),
        share: f64,
        max_batch: usize,
        slo_p99_s: f64,
        trace: impl Into<TraceSource>,
    ) -> JobSpec {
        JobSpec {
            id,
            name: name.to_string(),
            priority,
            arrival_s,
            min_gmis: min,
            initial_gmis: initial,
            max_gmis: max,
            share,
            min_share: share,
            mem_gib: 2.0,
            pin_gpus: None,
            kind: JobKind::Serving { trace: trace.into(), slo_p99_s, max_batch },
            tune: None,
        }
    }

    /// An elastic gateway tenant running the standalone gateway's full
    /// dynamic-batching policy under the scheduler's fleet elasticity.
    #[allow(clippy::too_many_arguments)]
    pub fn gateway(
        id: JobId,
        name: &str,
        priority: u8,
        arrival_s: f64,
        (min, initial, max): (usize, usize, usize),
        share: f64,
        cfg: GatewayConfig,
        trace: impl Into<TraceSource>,
    ) -> JobSpec {
        JobSpec {
            id,
            name: name.to_string(),
            priority,
            arrival_s,
            min_gmis: min,
            initial_gmis: initial,
            max_gmis: max,
            share,
            min_share: share,
            mem_gib: 2.0,
            pin_gpus: None,
            kind: JobKind::Gateway { trace: trace.into(), cfg },
            tune: None,
        }
    }

    /// A fixed-size closed-loop serving tenant (`rounds` interaction
    /// rounds of continuous experience collection).
    #[allow(clippy::too_many_arguments)]
    pub fn closed(
        id: JobId,
        name: &str,
        priority: u8,
        arrival_s: f64,
        gmis: usize,
        share: f64,
        min_share: f64,
        num_env: usize,
        rounds: usize,
    ) -> JobSpec {
        JobSpec {
            id,
            name: name.to_string(),
            priority,
            arrival_s,
            min_gmis: gmis,
            initial_gmis: gmis,
            max_gmis: gmis,
            share,
            min_share,
            mem_gib: 2.0,
            pin_gpus: None,
            kind: JobKind::Closed { rounds, num_env },
            tune: None,
        }
    }

    /// An A3C tenant: `agents` serving members feeding `trainers` trainer
    /// members over the compressor-channel pipeline. Membership is fixed
    /// (min = initial = max = agents + trainers); preemption is
    /// resize-only down to `min_share`.
    #[allow(clippy::too_many_arguments)]
    pub fn a3c(
        id: JobId,
        name: &str,
        priority: u8,
        arrival_s: f64,
        (agents, trainers): (usize, usize),
        share: f64,
        min_share: f64,
        num_env: usize,
        cfg: AsyncConfig,
    ) -> JobSpec {
        let members = agents + trainers;
        JobSpec {
            id,
            name: name.to_string(),
            priority,
            arrival_s,
            min_gmis: members,
            initial_gmis: members,
            max_gmis: members,
            share,
            min_share,
            mem_gib: 4.0,
            pin_gpus: None,
            kind: JobKind::Async { agents, trainers, num_env, cfg },
            tune: None,
        }
    }

    /// An off-policy replay tenant: `collectors` collector members feeding
    /// one learner member's replay buffer over the compressor-channel
    /// pipeline. Membership is fixed (min = initial = max = collectors +
    /// 1); preemption is resize-only down to `min_share`.
    #[allow(clippy::too_many_arguments)]
    pub fn replay(
        id: JobId,
        name: &str,
        priority: u8,
        arrival_s: f64,
        collectors: usize,
        share: f64,
        min_share: f64,
        num_env: usize,
        cfg: ReplayConfig,
    ) -> JobSpec {
        let members = collectors + 1;
        JobSpec {
            id,
            name: name.to_string(),
            priority,
            arrival_s,
            min_gmis: members,
            initial_gmis: members,
            max_gmis: members,
            share,
            min_share,
            mem_gib: 4.0,
            pin_gpus: None,
            kind: JobKind::Replay { collectors, num_env, cfg },
            tune: None,
        }
    }

    /// A self-play league coordinator tenant: one lightweight matchmaker
    /// member; the matches it runs are spawned as child tenants through
    /// the normal admission path, so the coordinator's own envelope stays
    /// a single small GMI.
    pub fn league(
        id: JobId,
        name: &str,
        priority: u8,
        arrival_s: f64,
        share: f64,
        cfg: LeagueConfig,
    ) -> JobSpec {
        JobSpec {
            id,
            name: name.to_string(),
            priority,
            arrival_s,
            min_gmis: 1,
            initial_gmis: 1,
            max_gmis: 1,
            share,
            min_share: share,
            mem_gib: 2.0,
            pin_gpus: None,
            kind: JobKind::League { cfg },
            tune: None,
        }
    }

    /// Request minibatch auto-tuning at admission (Training tenants only —
    /// `validate` rejects it elsewhere): short probe runs on a scratch
    /// mirror of the placed members pick the minibatch count, and the
    /// probe virtual-time is charged to the tenant's own member clocks.
    pub fn with_admission_tuning(mut self, tune: AdmissionTune) -> JobSpec {
        self.tune = Some(tune);
        self
    }

    /// Build the steppable [`Workload`] program this tenancy contract
    /// runs — the SAME program the standalone driver of the kind would
    /// build, which is what makes a single-tenant cluster run
    /// bit-identical to the standalone run (`rust/tests/prop_workload.rs`).
    pub fn build_program(&self) -> Box<dyn Workload> {
        match &self.kind {
            JobKind::Training { iterations, horizon, num_env: _, minibatches } => {
                // The scheduler's historical training model: one PPO epoch
                // of `minibatches` sequential (non-overlapped) reductions
                // per iteration, Null-compute numerics.
                Box::new(SyncProgram::new(
                    SyncConfig {
                        iterations: *iterations,
                        ppo_epochs: 1,
                        minibatches: *minibatches,
                        overlap: false,
                        ..SyncConfig::default()
                    },
                    *horizon,
                ))
            }
            JobKind::Serving { trace, slo_p99_s, max_batch } => Box::new(
                GatewayProgram::round_flush(
                    GatewayConfig {
                        max_batch: *max_batch,
                        max_wait_s: f64::INFINITY,
                        slo_s: *slo_p99_s,
                        ..GatewayConfig::default()
                    },
                    // A cursor clone: a materialized backing shares the one
                    // trace allocation, a streaming one rewinds its seeds.
                    trace.clone(),
                ),
            ),
            JobKind::Gateway { trace, cfg } => {
                Box::new(GatewayProgram::new(*cfg, trace.clone()))
            }
            JobKind::Closed { rounds, num_env: _ } => Box::new(ClosedServingProgram::new(
                ServingConfig { rounds: *rounds, ..ServingConfig::default() },
            )),
            JobKind::Async { cfg, .. } => Box::new(AsyncProgram::new(cfg.clone())),
            JobKind::Replay { cfg, .. } => Box::new(ReplayProgram::new(cfg.clone())),
            JobKind::League { cfg } => Box::new(LeagueProgram::new(cfg.clone())),
        }
    }

    /// Sanity-check the envelope (counts ordered, shares in range, and the
    /// admitted `initial_gmis` set placeable on an EMPTY allowed slice of
    /// `topo` — a job that cannot ever start is a config error, not a
    /// queue entry).
    pub fn validate(&self, topo: &Topology) -> Result<()> {
        anyhow::ensure!(
            self.min_gmis >= 1
                && self.min_gmis <= self.initial_gmis
                && self.initial_gmis <= self.max_gmis,
            "job {} ({}): GMI counts must satisfy 1 <= min <= initial <= max",
            self.id,
            self.name
        );
        anyhow::ensure!(
            self.share > 0.0 && self.share <= 1.0 && self.min_share > 0.0,
            "job {} ({}): shares must lie in (0, 1]",
            self.id,
            self.name
        );
        anyhow::ensure!(
            self.min_share <= self.share + 1e-9,
            "job {} ({}): min_share {} exceeds share {}",
            self.id,
            self.name,
            self.min_share,
            self.share
        );
        anyhow::ensure!(self.arrival_s >= 0.0, "job {}: negative arrival", self.id);
        match &self.kind {
            JobKind::Serving { trace, slo_p99_s, max_batch } => {
                anyhow::ensure!(*max_batch >= 1, "job {}: max_batch must be >= 1", self.id);
                anyhow::ensure!(*slo_p99_s > 0.0, "job {}: SLO must be positive", self.id);
                anyhow::ensure!(
                    trace.is_sorted(),
                    "job {}: trace must be sorted by arrival",
                    self.id
                );
            }
            JobKind::Gateway { trace, cfg } => {
                anyhow::ensure!(cfg.max_batch >= 1, "job {}: max_batch must be >= 1", self.id);
                anyhow::ensure!(cfg.slo_s > 0.0, "job {}: SLO must be positive", self.id);
                anyhow::ensure!(
                    cfg.max_wait_s >= 0.0 && cfg.max_wait_s.is_finite(),
                    "job {}: max_wait must be finite and non-negative \
                     (use JobKind::Serving for round-boundary flushing)",
                    self.id
                );
                anyhow::ensure!(
                    cfg.autoscale.is_none(),
                    "job {}: the scheduler owns fleet elasticity; gateway tenants \
                     must not carry their own autoscaler",
                    self.id
                );
                anyhow::ensure!(
                    trace.is_sorted(),
                    "job {}: trace must be sorted by arrival",
                    self.id
                );
                anyhow::ensure!(
                    cfg.aggregation >= 1,
                    "job {}: aggregation must be >= 1 (1 disables coalescing)",
                    self.id
                );
            }
            JobKind::Closed { rounds, num_env } => {
                anyhow::ensure!(*rounds >= 1, "job {}: rounds must be >= 1", self.id);
                anyhow::ensure!(*num_env >= 1, "job {}: num_env must be >= 1", self.id);
            }
            JobKind::Async { agents, trainers, cfg, .. } => {
                anyhow::ensure!(
                    *agents >= 1 && *trainers >= 1,
                    "job {}: async tenants need agents and trainers",
                    self.id
                );
                anyhow::ensure!(
                    agents + trainers == self.initial_gmis
                        && self.min_gmis == self.initial_gmis
                        && self.max_gmis == self.initial_gmis,
                    "job {}: async membership is fixed \
                     (min = initial = max = agents + trainers)",
                    self.id
                );
                anyhow::ensure!(cfg.rounds >= 1, "job {}: rounds must be >= 1", self.id);
                anyhow::ensure!(
                    cfg.elastic.is_none(),
                    "job {}: the scheduler owns re-provisioning; async tenants \
                     must not carry their own elastic controller",
                    self.id
                );
            }
            JobKind::Replay { collectors, cfg, num_env } => {
                anyhow::ensure!(
                    *collectors >= 1,
                    "job {}: replay tenants need at least one collector",
                    self.id
                );
                anyhow::ensure!(*num_env >= 1, "job {}: num_env must be >= 1", self.id);
                anyhow::ensure!(
                    collectors + 1 == self.initial_gmis
                        && self.min_gmis == self.initial_gmis
                        && self.max_gmis == self.initial_gmis,
                    "job {}: replay membership is fixed \
                     (min = initial = max = collectors + 1)",
                    self.id
                );
                anyhow::ensure!(cfg.rounds >= 1, "job {}: rounds must be >= 1", self.id);
                anyhow::ensure!(
                    cfg.buffer_gib > 0.0 && cfg.buffer_gib <= self.mem_gib,
                    "job {}: replay buffer budget must be positive and fit the \
                     learner member's {} GiB memory grant",
                    self.id,
                    self.mem_gib
                );
                anyhow::ensure!(
                    cfg.batch_samples >= 1 && cfg.push_samples >= 1,
                    "job {}: replay batch/push sizes must be >= 1",
                    self.id
                );
            }
            JobKind::League { cfg } => {
                anyhow::ensure!(
                    cfg.players >= 2 && cfg.players % 2 == 0,
                    "job {}: a league needs an even number of players >= 2",
                    self.id
                );
                anyhow::ensure!(
                    cfg.total_matches >= 1 && cfg.max_concurrent >= 1,
                    "job {}: league match counts must be >= 1",
                    self.id
                );
                anyhow::ensure!(
                    cfg.match_rounds >= 1 && cfg.match_num_env >= 1,
                    "job {}: match rounds and env counts must be >= 1",
                    self.id
                );
                // The children must themselves be admissible: probe a
                // representative match spec against the same topology.
                let probe = cfg.match_spec(JobId::MAX - 1, 0, 0.0);
                probe.validate(topo).map_err(|e| {
                    anyhow::anyhow!("job {}: league match spec is invalid: {e}", self.id)
                })?;
            }
            JobKind::Training { .. } => {}
        }
        if let Some(t) = &self.tune {
            anyhow::ensure!(
                matches!(self.kind, JobKind::Training { .. }),
                "job {} ({}): admission tuning is only defined for Training tenants",
                self.id,
                self.name
            );
            anyhow::ensure!(
                t.budget_frac > 0.0 && t.probe_iters >= 1 && !t.minibatches.is_empty(),
                "job {} ({}): admission tuning needs a positive budget, probe \
                 iterations, and at least one minibatch candidate",
                self.id,
                self.name
            );
        }
        let allowed = self.allowed_gpus(topo);
        anyhow::ensure!(!allowed.is_empty(), "job {}: no allowed GPUs", self.id);
        for &g in &allowed {
            anyhow::ensure!(g < topo.num_gpus(), "job {}: pinned GPU {g} not in topology", self.id);
        }
        // The ADMITTED set must fit the empty allowed slice: admission
        // places `initial_gmis` members (>= min_gmis), so a job whose
        // initial set can never be placed would queue forever — a config
        // error, not a queue entry.
        let by_sm = ((1.0 + 1e-9) / self.share) as usize;
        let by_mem = allowed
            .iter()
            .map(|&g| ((topo.gpus[g].mem_gib + 1e-9) / self.mem_gib) as usize)
            .min()
            .unwrap_or(0);
        let cap = allowed.len() * by_sm.min(by_mem);
        anyhow::ensure!(
            cap >= self.initial_gmis,
            "job {} ({}): admitted set of {} x {:.2}-share GMIs cannot fit \
             its allowed slice of {} GPU(s)",
            self.id,
            self.name,
            self.initial_gmis,
            self.share,
            allowed.len()
        );
        Ok(())
    }

    /// GPUs this job may place on, ascending.
    pub fn allowed_gpus(&self, topo: &Topology) -> Vec<usize> {
        match &self.pin_gpus {
            Some(p) => {
                let mut v = p.clone();
                v.sort_unstable();
                v.dedup();
                v
            }
            None => (0..topo.num_gpus()).collect(),
        }
    }

    /// The aggregate SM-share floor registered with the manager's removal
    /// guard: preemption may never strand the job below it.
    pub fn floor_share(&self) -> f64 {
        self.min_gmis as f64 * self.min_share
    }

    /// DRL role of the `idx`-th member GMI (async tenants mix agent and
    /// trainer members; every other kind is homogeneous).
    pub fn member_role(&self, idx: usize) -> Role {
        match &self.kind {
            JobKind::Training { .. } => Role::Holistic,
            JobKind::Serving { .. } | JobKind::Gateway { .. } | JobKind::Closed { .. } => {
                Role::SimAgent
            }
            JobKind::Async { agents, .. } => {
                if idx < *agents {
                    Role::SimAgent
                } else {
                    Role::Trainer
                }
            }
            JobKind::Replay { collectors, .. } => {
                if idx < *collectors {
                    Role::SimAgent
                } else {
                    Role::Trainer
                }
            }
            // The matchmaker both evaluates policies (inference) and owns
            // the league state — a holistic single-member tenant.
            JobKind::League { .. } => Role::Holistic,
        }
    }

    /// `num_env` the `idx`-th member GMI is registered with (sizes rollout
    /// charges for training, the inference slot for serving; trainer
    /// members of async tenants simulate nothing).
    pub fn member_num_env(&self, idx: usize) -> usize {
        match &self.kind {
            JobKind::Training { num_env, .. } => *num_env,
            JobKind::Serving { max_batch, .. } => *max_batch,
            JobKind::Gateway { cfg, .. } => cfg.max_batch,
            JobKind::Closed { num_env, .. } => *num_env,
            JobKind::Async { agents, num_env, .. } => {
                if idx < *agents {
                    *num_env
                } else {
                    0
                }
            }
            JobKind::Replay { collectors, num_env, .. } => {
                if idx < *collectors {
                    *num_env
                } else {
                    0
                }
            }
            // One matchmaker inference slot per league player.
            JobKind::League { cfg } => cfg.players,
        }
    }

    /// The p99 latency target this tenant is scheduled against (None for
    /// throughput-oriented kinds): what makes a tenant eligible for SLO
    /// pressure growth and what the restore hysteresis reads.
    pub fn slo_p99_s(&self) -> Option<f64> {
        match &self.kind {
            JobKind::Serving { slo_p99_s, .. } => Some(*slo_p99_s),
            JobKind::Gateway { cfg, .. } => Some(cfg.slo_s),
            _ => None,
        }
    }

    /// Latency-sensitive open-loop tenants step first each round and
    /// complete at round boundaries.
    pub fn is_serving(&self) -> bool {
        matches!(self.kind, JobKind::Serving { .. } | JobKind::Gateway { .. })
    }

    /// Human-readable kind tag for reports.
    pub fn kind_label(&self) -> &'static str {
        match &self.kind {
            JobKind::Training { .. } => "training",
            JobKind::Serving { .. } => "serving",
            JobKind::Gateway { .. } => "gateway",
            JobKind::Closed { .. } => "closed",
            JobKind::Async { .. } => "async",
            JobKind::Replay { .. } => "replay",
            JobKind::League { .. } => "league",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_catches_bad_envelopes() {
        let topo = Topology::dgx_a100(2);
        let ok = JobSpec::training(0, "t", 1, 0.0, 2, 0.5, 0.1, 256, 3);
        ok.validate(&topo).unwrap();

        let mut bad = ok.clone();
        bad.min_gmis = 0;
        assert!(bad.validate(&topo).is_err());

        let mut bad = ok.clone();
        bad.max_gmis = 1; // initial 2 > max 1
        assert!(bad.validate(&topo).is_err());

        let mut bad = ok.clone();
        bad.min_share = 0.9; // above share
        assert!(bad.validate(&topo).is_err());

        let mut bad = ok.clone();
        bad.share = 0.8;
        bad.min_gmis = 3; // three 0.8-share members never fit 2 GPUs
        bad.initial_gmis = 3;
        bad.max_gmis = 3;
        assert!(bad.validate(&topo).is_err());

        let mut bad = ok.clone();
        bad.pin_gpus = Some(vec![5]);
        assert!(bad.validate(&topo).is_err());

        // Pins restrict the feasibility check to the pinned slice: two
        // 0.5-share members fit one GPU, three do not.
        let mut pinned = ok.clone();
        pinned.pin_gpus = Some(vec![0]);
        pinned.validate(&topo).unwrap();
        pinned.min_gmis = 3;
        pinned.initial_gmis = 3;
        pinned.max_gmis = 3;
        assert!(pinned.validate(&topo).is_err());
    }

    #[test]
    fn validate_catches_bad_new_kinds() {
        let topo = Topology::dgx_a100(2);

        // Async: membership must be fixed and both roles present.
        let a = JobSpec::a3c(0, "a", 1, 0.0, (1, 1), 0.4, 0.1, 256, AsyncConfig::default());
        a.validate(&topo).unwrap();
        let mut bad = a.clone();
        bad.max_gmis = 3; // elastic membership is not allowed for async
        assert!(bad.validate(&topo).is_err());
        let mut bad = a.clone();
        bad.kind = JobKind::Async {
            agents: 0,
            trainers: 2,
            num_env: 256,
            cfg: AsyncConfig::default(),
        };
        assert!(bad.validate(&topo).is_err());
        let mut bad = a.clone();
        if let JobKind::Async { cfg, .. } = &mut bad.kind {
            cfg.elastic = Some(crate::engine::ElasticConfig::default());
        }
        assert!(bad.validate(&topo).is_err(), "tenant-owned elastic must be rejected");

        // Gateway: no tenant-owned autoscaler, sane policy knobs.
        let g = JobSpec::gateway(
            1,
            "g",
            9,
            0.0,
            (1, 2, 4),
            0.25,
            GatewayConfig::default(),
            vec![],
        );
        g.validate(&topo).unwrap();
        let mut bad = g.clone();
        if let JobKind::Gateway { cfg, .. } = &mut bad.kind {
            cfg.autoscale = Some(crate::serve::AutoscaleConfig::default());
        }
        assert!(bad.validate(&topo).is_err(), "tenant-owned autoscaler must be rejected");

        // Closed: rounds and env counts must be positive.
        let c = JobSpec::closed(2, "c", 1, 0.0, 1, 0.5, 0.1, 512, 5);
        c.validate(&topo).unwrap();
        let mut bad = c.clone();
        bad.kind = JobKind::Closed { rounds: 0, num_env: 512 };
        assert!(bad.validate(&topo).is_err());

        // Replay: fixed membership, buffer within the memory grant.
        let r = JobSpec::replay(3, "r", 1, 0.0, 2, 0.4, 0.1, 1024, ReplayConfig::default());
        r.validate(&topo).unwrap();
        let mut bad = r.clone();
        bad.max_gmis = 5; // elastic membership is not allowed for replay
        assert!(bad.validate(&topo).is_err());
        let mut bad = r.clone();
        if let JobKind::Replay { cfg, .. } = &mut bad.kind {
            cfg.buffer_gib = 100.0; // exceeds the member memory grant
        }
        assert!(bad.validate(&topo).is_err(), "oversized buffer must be rejected");

        // League: even player count, valid child match spec.
        let l = JobSpec::league(4, "l", 2, 0.0, 0.2, LeagueConfig::default());
        l.validate(&topo).unwrap();
        let mut bad = l.clone();
        if let JobKind::League { cfg } = &mut bad.kind {
            cfg.players = 3;
        }
        assert!(bad.validate(&topo).is_err(), "odd player count must be rejected");
        let mut bad = l.clone();
        if let JobKind::League { cfg } = &mut bad.kind {
            cfg.match_share = 2.0; // child spec share out of range
        }
        assert!(bad.validate(&topo).is_err(), "invalid match spec must be rejected");
    }

    #[test]
    fn admission_tuning_only_for_training() {
        let topo = Topology::dgx_a100(2);
        let t = JobSpec::training(0, "t", 1, 0.0, 2, 0.5, 0.1, 256, 3)
            .with_admission_tuning(AdmissionTune::default());
        t.validate(&topo).unwrap();

        let s = JobSpec::serving(1, "s", 9, 0.0, (1, 2, 4), 0.25, 16, 10e-3, vec![])
            .with_admission_tuning(AdmissionTune::default());
        assert!(s.validate(&topo).is_err(), "non-training tuning must be rejected");

        let mut bad = t.clone();
        bad.tune = Some(AdmissionTune { minibatches: vec![], ..AdmissionTune::default() });
        assert!(bad.validate(&topo).is_err(), "empty candidate list must be rejected");
    }

    #[test]
    fn floors_roles_and_labels() {
        let t = JobSpec::training(0, "t", 1, 0.0, 2, 0.5, 0.15, 256, 3);
        assert!((t.floor_share() - 0.3).abs() < 1e-12);
        assert_eq!(t.member_role(0), Role::Holistic);
        assert_eq!(t.member_num_env(0), 256);
        assert!(!t.is_serving());
        assert_eq!(t.kind_label(), "training");
        assert!(t.slo_p99_s().is_none());

        let s = JobSpec::serving(1, "s", 9, 0.0, (1, 2, 4), 0.25, 16, 10e-3, vec![]);
        assert_eq!(s.member_role(0), Role::SimAgent);
        assert_eq!(s.member_num_env(0), 16);
        assert!(s.is_serving());
        assert_eq!(s.kind_label(), "serving");
        assert_eq!(s.slo_p99_s(), Some(10e-3));
        assert!((s.floor_share() - 0.25).abs() < 1e-12);
        s.validate(&Topology::dgx_a100(1)).unwrap();

        // Async tenants mix member roles: agents first, then trainers.
        let a = JobSpec::a3c(2, "a", 5, 0.0, (2, 1), 0.3, 0.1, 1024, AsyncConfig::default());
        assert_eq!(a.initial_gmis, 3);
        assert_eq!(a.member_role(0), Role::SimAgent);
        assert_eq!(a.member_role(1), Role::SimAgent);
        assert_eq!(a.member_role(2), Role::Trainer);
        assert_eq!(a.member_num_env(0), 1024);
        assert_eq!(a.member_num_env(2), 0);
        assert!(!a.is_serving());
        assert_eq!(a.kind_label(), "async");

        let g = JobSpec::gateway(
            3,
            "g",
            9,
            0.0,
            (1, 1, 2),
            0.25,
            GatewayConfig { slo_s: 5e-3, ..GatewayConfig::default() },
            vec![],
        );
        assert!(g.is_serving());
        assert_eq!(g.slo_p99_s(), Some(5e-3));
        assert_eq!(g.kind_label(), "gateway");

        // Replay tenants mirror async member mixing: collectors first,
        // then the learner.
        let r = JobSpec::replay(4, "r", 3, 0.0, 2, 0.3, 0.1, 1024, ReplayConfig::default());
        assert_eq!(r.initial_gmis, 3);
        assert_eq!(r.member_role(0), Role::SimAgent);
        assert_eq!(r.member_role(2), Role::Trainer);
        assert_eq!(r.member_num_env(0), 1024);
        assert_eq!(r.member_num_env(2), 0);
        assert!(!r.is_serving());
        assert_eq!(r.kind_label(), "replay");

        let l = JobSpec::league(5, "l", 2, 0.0, 0.2, LeagueConfig::default());
        assert_eq!(l.initial_gmis, 1);
        assert_eq!(l.member_role(0), Role::Holistic);
        assert_eq!(l.member_num_env(0), LeagueConfig::default().players);
        assert_eq!(l.kind_label(), "league");
    }
}
