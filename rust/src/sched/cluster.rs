//! The multi-tenant cluster scheduler: preemptive co-scheduling of
//! heterogeneous DRL jobs on one shared [`Topology`].
//!
//! One [`run_cluster`] call owns a single shared [`Engine`] + [`Fabric`]
//! pair and advances cluster time in fixed scheduling rounds
//! ([`SchedConfig::quantum_s`]). Each tenant IS a steppable
//! [`Workload`](crate::workload::Workload) program — the identical
//! implementation its standalone run loop drives
//! ([`JobSpec::build_program`]) — so the scheduler contains no per-kind
//! execution logic: it only places, preempts, restores, and steps. Each
//! round, in order:
//!
//! 1. **SLO decisions** — a latency-sensitive tenant whose previous
//!    round's dispatched p99 ([`Workload::slo_signal`]) violated its SLO
//!    grows (a new member GMI, preempting lower-priority tenants if
//!    placement needs room); one comfortably under `restore_frac x SLO`
//!    retires its most recently grown member.
//! 2. **Admissions** — arrived queued jobs admit in priority order; when
//!    placement fails, lower-priority tenants are first *shrunk* to their
//!    per-member `min_share` (validated resizes) and then *evicted* one
//!    member at a time down to their `min_gmis` floor — the manager's
//!    [`RemoveGmiError::BelowJobFloor`](crate::gmi::RemoveGmiError) guard
//!    makes over-eviction impossible by construction. An admitted tenant
//!    gets its program built and bound to the placed members.
//! 3. **Restores** — when no tenant is under SLO pressure, preempted
//!    tenants get one action per round back toward their admitted
//!    provisioning: re-add an evicted member, else regrow shrunken
//!    members into free share.
//! 4. **Steps** — every running program is stepped to the round boundary
//!    (`Workload::step` with the boundary as horizon). Programs own every
//!    piece of run state, so preempt → restore resumes mid-program
//!    without re-charging completed work; a program reporting
//!    [`StepOutcome::Done`] completes and releases its GMIs.
//!
//! After any membership or provisioning change the affected tenant's
//! program is re-bound ([`Workload::bind`]) so placement-derived caches
//! (e.g. a training tenant's allreduce plan) track the live fleet.
//!
//! Tenants are not only the submitted jobs: a coordinator program (the
//! self-play league) may spawn *child tenants* at runtime. After the step
//! pass each round, [`Workload::take_spawn_requests`] is drained; every
//! request becomes a fresh tenant (cluster-assigned id, arrival stamped at
//! the round boundary) that goes through the identical admission path.
//! Completed children hand their metrics back to the coordinator via
//! [`Workload::child_result`] before its next step; deliveries are kept in
//! a per-coordinator history so a coordinator kill + restore replays them
//! (programs deduplicate by tag).
//!
//! Every placement, resize, and removal goes through the engine's live
//! [`GmiManager`](crate::gmi::GmiManager) validation, so no arrival
//! sequence can oversubscribe a GPU's SMs or memory — `run_cluster`
//! additionally tracks the worst per-GPU share/memory it ever observed
//! ([`ClusterRunResult::peak_gpu_share`]) so the property suite can check
//! exactly that. Per-job service (busy seconds, communication seconds,
//! cross-job interference seconds) comes from the engine's job tagging;
//! cluster fairness is Jain's index over per-job busy GPU-seconds.
//!
//! A single-tenant cluster run is bit-identical to the standalone run of
//! the same workload program (asserted in `rust/tests/prop_workload.rs`).

use std::cmp::Reverse;
use std::collections::BTreeSet;

use anyhow::Result;

use super::job::{JobId, JobKind, JobSpec};
use crate::cluster::Topology;
use crate::config::BenchInfo;
use crate::drl::Compute;
use crate::engine::{Engine, ExecutorId};
use crate::fabric::Fabric;
use crate::fault::{FaultKind, FaultPlan};
use crate::gmi::{GmiBackend, GmiId, GmiSpec};
use crate::metrics::{jain_index, RunMetrics, Table};
use crate::vtime::CostModel;
use crate::workload::{StepCtx, StepOutcome, Workload};

/// Scheduler policy knobs.
#[derive(Debug, Clone)]
pub struct SchedConfig {
    /// Scheduling round length (virtual seconds): the cadence of
    /// admission, preemption, SLO evaluation, and restore decisions.
    pub quantum_s: f64,
    /// Preemptive elasticity on (the scheduler) vs off (the static
    /// baseline: jobs keep whatever they were admitted with).
    pub preemptive: bool,
    /// A serving round's p99 below `restore_frac x SLO` counts as
    /// pressure-off: grown members retire and preempted tenants restore.
    /// Between the two thresholds nothing moves (hysteresis).
    pub restore_frac: f64,
    /// Hard cap on scheduling rounds (runaway guard). `None` (the
    /// default) derives the cap from the jobs' own horizon: four times
    /// the rounds their serving traces span at `quantum_s`, floored at
    /// the historical 1,000,000 so short runs keep the old guard. A flat
    /// cap would silently forbid long runs — a simulated week at the
    /// default 0.02 s quantum is ~30.2 M rounds — so only set `Some(n)`
    /// to pin an explicit budget.
    pub max_rounds: Option<usize>,
    /// Failure injection + checkpoint cadence ([`FaultPlan`]); `None`
    /// runs the cluster failure-free (the historical behavior,
    /// bit-identical timelines).
    pub faults: Option<FaultPlan>,
    /// Idle-round fast-forward: skip provably-quiescent quanta (see
    /// [`FastForward`]). `Off` preserves the historical naive cadence.
    pub fast_forward: FastForward,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            quantum_s: 0.02,
            preemptive: true,
            restore_frac: 0.5,
            max_rounds: None,
            faults: None,
            fast_forward: FastForward::Off,
        }
    }
}

/// Idle-round fast-forward policy: whether the round loop may jump the
/// clock over quanta in which provably nothing observable can happen
/// (every running tenant's [`Workload::next_event_hint`] lies beyond the
/// span, no queued arrival is due, no restore is pending, and no fault or
/// checkpoint boundary falls inside it). Skipping whole integer rounds
/// preserves `now = round * quantum` bit-for-bit, so the produced
/// timeline and metrics are identical to the naive loop's.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FastForward {
    /// Never skip: step every round (the historical behavior).
    #[default]
    Off,
    /// Jump directly from each active round to the next round that can
    /// observe an event.
    On,
    /// Compute the same skip spans as [`FastForward::On`] but step them
    /// naively, erroring if a "quiescent" round did observable work.
    /// The cross-check mode for validating hint implementations.
    Audit,
}

/// Sentinel [`JobId`] on cluster-scoped timeline entries (hardware
/// fail/repair events, which belong to no tenant).
pub const CLUSTER_EVENT: JobId = JobId::MAX;

/// What one timeline entry records.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedAction {
    /// Job placed and started.
    Admit,
    /// A tenant's admission-time auto-tuning locked a configuration
    /// (probe virtual-time charged to the tenant's own member clocks).
    Tune,
    /// Job arrived but could not be placed (logged once; retried every
    /// round).
    Queue,
    /// A lower-priority tenant's members were shrunk to their share floor.
    Preempt,
    /// A lower-priority tenant lost a member GMI (down to its count floor).
    Evict,
    /// A serving tenant under SLO pressure gained a member.
    Grow,
    /// A serving tenant retired a grown member (pressure off).
    Shrink,
    /// A preempted tenant got provisioning back (re-add or regrow).
    Restore,
    /// Job finished and released its GMIs.
    Complete,
    /// Hardware failed (cluster-scoped entry: `job` is [`CLUSTER_EVENT`]).
    Fail,
    /// Hardware recovered (cluster-scoped entry).
    Repair,
    /// A running tenant's program state was captured; the capture cost was
    /// charged to the tenant's own member clocks.
    Checkpoint,
    /// A tenant lost members to a hardware failure (or was partitioned by
    /// one): its live program was discarded and it re-queued to resume
    /// from its last checkpoint.
    Kill,
    /// A coordinator tenant created this job at runtime (it enters the
    /// admission queue like any arrival).
    Spawn,
}

impl std::fmt::Display for SchedAction {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SchedAction::Admit => "admit",
            SchedAction::Tune => "tune",
            SchedAction::Queue => "queue",
            SchedAction::Preempt => "preempt",
            SchedAction::Evict => "evict",
            SchedAction::Grow => "grow",
            SchedAction::Shrink => "shrink",
            SchedAction::Restore => "restore",
            SchedAction::Complete => "complete",
            SchedAction::Fail => "fail",
            SchedAction::Repair => "repair",
            SchedAction::Checkpoint => "checkpoint",
            SchedAction::Kill => "kill",
            SchedAction::Spawn => "spawn",
        })
    }
}

/// One entry of the scheduling timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct SchedEvent {
    /// Cluster time the decision fired at (a round boundary).
    pub t_s: f64,
    pub job: JobId,
    pub action: SchedAction,
    /// The job's member count after the action.
    pub members: usize,
    /// The job's aggregate SM share after the action.
    pub share: f64,
    pub detail: String,
}

/// Render a scheduling timeline (the preemption timeline the shared
/// cluster example prints).
pub fn sched_table(events: &[SchedEvent]) -> Table {
    let mut t = Table::new(&["t (s)", "job", "action", "members", "share", "detail"]);
    for e in events {
        t.row(vec![
            format!("{:.3}", e.t_s),
            if e.job == CLUSTER_EVENT { "-".into() } else { e.job.to_string() },
            e.action.to_string(),
            e.members.to_string(),
            format!("{:.2}", e.share),
            e.detail.clone(),
        ]);
    }
    t
}

/// Per-job outcome of a cluster run.
#[derive(Debug, Clone)]
pub struct JobReport {
    pub id: JobId,
    pub name: String,
    pub priority: u8,
    /// "training", "serving", "gateway", "closed", or "async".
    pub kind: &'static str,
    /// The workload program's own metrics ([`Workload::finish`]) — for a
    /// single-tenant cluster, bit-identical to the standalone run's.
    /// Span, rates, and `comm_s` are scoped to the job (comm via the
    /// engine's job tags); engine-wide aggregates (utilization, links)
    /// reflect the shared cluster at the job's completion; per-job service
    /// attribution is in `busy_s` / `xjob_interference_s` below.
    pub metrics: RunMetrics,
    pub admitted_s: f64,
    pub completed_s: f64,
    /// Queue wait: admission minus arrival.
    pub wait_s: f64,
    /// Preemption actions suffered (shrinks + evictions).
    pub preemptions: usize,
    /// Restore actions received.
    pub restores: usize,
    /// Busy GPU-seconds across the job's executors (its service total).
    pub busy_s: f64,
    /// Compute seconds lost to other tenants' co-resident GMIs.
    pub xjob_interference_s: f64,
    /// Aggregate SM share held at completion (restored jobs end at their
    /// admitted provisioning).
    pub share_at_completion: f64,
    pub gmis_at_completion: usize,
    /// Hardware-failure kills suffered (each discarded the live program
    /// and re-queued the tenant).
    pub kills: usize,
    /// Busy GPU-seconds of un-checkpointed service discarded by kills —
    /// the goodput the failures cost this job.
    pub goodput_lost_s: f64,
    /// Total virtual seconds between each kill and the re-admission that
    /// resumed the job.
    pub recovery_s: f64,
    /// Total checkpoint capture cost charged to this job's member clocks
    /// (GPU-seconds).
    pub checkpoint_s: f64,
}

/// Everything one [`run_cluster`] call produced.
#[derive(Debug, Clone)]
pub struct ClusterRunResult {
    /// One report per job: input jobs in input order, then tenants a
    /// coordinator spawned at runtime, in spawn order.
    pub jobs: Vec<JobReport>,
    /// The scheduling timeline, in decision order.
    pub events: Vec<SchedEvent>,
    /// Latest virtual time any executor reached.
    pub makespan_s: f64,
    /// Engine-wide mean GPU utilization.
    pub cluster_utilization: f64,
    /// Jain's index over per-job busy GPU-seconds.
    pub fairness: f64,
    /// Worst per-GPU SM-share sum ever observed at a round boundary
    /// (must stay <= 1: the no-oversubscription invariant).
    pub peak_gpu_share: f64,
    /// Worst per-GPU memory sum ever observed (GiB).
    pub peak_gpu_mem_gib: f64,
    /// Hardware fail/repair events applied from the fault trace.
    pub fault_events: usize,
    /// Cluster-wide busy GPU-seconds discarded by failure kills.
    pub goodput_lost_s: f64,
}

impl ClusterRunResult {
    pub fn job(&self, id: JobId) -> Option<&JobReport> {
        self.jobs.iter().find(|j| j.id == id)
    }

    /// Render the per-job outcome table.
    pub fn job_table(&self) -> Table {
        let mut t = Table::new(&[
            "job",
            "kind",
            "prio",
            "wait (ms)",
            "span (s)",
            "rate (/s)",
            "p99 (ms)",
            "preempt",
            "restore",
            "xjob (ms)",
            "kills",
            "lost (s)",
            "recov (s)",
            "ckpt (s)",
        ]);
        for j in &self.jobs {
            t.row(vec![
                format!("{} ({})", j.id, j.name),
                j.kind.to_string(),
                j.priority.to_string(),
                format!("{:.1}", j.wait_s * 1e3),
                format!("{:.3}", j.metrics.span_s),
                format!("{:.0}", j.metrics.steps_per_sec),
                j.metrics
                    .latency
                    .as_ref()
                    .map(|l| format!("{:.2}", l.p99_s * 1e3))
                    .unwrap_or_else(|| "-".into()),
                j.preemptions.to_string(),
                j.restores.to_string(),
                format!("{:.1}", j.xjob_interference_s * 1e3),
                j.kills.to_string(),
                format!("{:.3}", j.goodput_lost_s),
                format!("{:.3}", j.recovery_s),
                format!("{:.3}", j.checkpoint_s),
            ]);
        }
        t
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    Queued,
    Running,
    Done,
}

/// Per-tenant runtime bookkeeping. Everything workload-specific lives in
/// the program; the scheduler only tracks placement and timeline facts.
struct Tenant {
    spec: JobSpec,
    state: State,
    /// Active member GMIs and their executors (parallel vectors).
    gmis: Vec<GmiId>,
    execs: Vec<ExecutorId>,
    /// The steppable workload program (built at admission).
    program: Option<Box<dyn Workload>>,
    /// Program reported [`StepOutcome::Done`]; completes this round.
    done: bool,
    /// The program's final metrics, captured at completion.
    final_metrics: Option<RunMetrics>,
    admitted_s: f64,
    completed_s: f64,
    queued_logged: bool,
    preemptions: usize,
    restores: usize,
    share_at_completion: f64,
    gmis_at_completion: usize,
    /// Members gained under SLO pressure, most recent last (shrink
    /// retires these first, LIFO).
    grown: Vec<GmiId>,
    /// Below admitted provisioning (shrunk or evicted): the restore pass
    /// only scans flagged tenants, so a steady-state round touches no
    /// tenant state at all.
    needs_restore: bool,
    /// Last periodic [`Workload::snapshot`] capture. A kill resumes from
    /// this (via a fresh re-snapshot, so one checkpoint survives repeated
    /// kills); `None` means a kill restarts the job from scratch.
    ckpt: Option<Box<dyn Workload>>,
    kills: usize,
    /// Set at kill, cleared (into `recovery_s`) at re-admission.
    killed_at: Option<f64>,
    recovery_s: f64,
    checkpoint_s: f64,
    goodput_lost_s: f64,
    /// `engine.job_busy_s` at the last checkpoint (or [re-]admission):
    /// the baseline for goodput-lost accounting at a kill.
    busy_at_ckpt: f64,
    /// Set once the admission-time auto-tuner has locked a configuration:
    /// a bind-failure backout re-queues a tenant without a kill, and the
    /// retried admission must not probe (and charge) again.
    tuned: bool,
    /// The coordinator that spawned this tenant at runtime, with the tag
    /// it chose (`None` for submitted jobs).
    parent: Option<(JobId, u64)>,
    /// Tags of children this coordinator already spawned: a restored
    /// coordinator's replayed requests are deduplicated here (the live
    /// children kept running through the coordinator's outage).
    spawned_tags: BTreeSet<u64>,
    /// Completed child results awaiting delivery to this coordinator's
    /// program (drained before its next step).
    pending: Vec<(u64, RunMetrics)>,
    /// Every completed child result, in completion order — replayed into
    /// `pending` when this coordinator resumes from a checkpoint that
    /// predates some completions.
    history: Vec<(u64, RunMetrics)>,
}

impl Tenant {
    fn new(spec: JobSpec) -> Self {
        Tenant {
            spec,
            state: State::Queued,
            gmis: Vec::new(),
            execs: Vec::new(),
            program: None,
            done: false,
            final_metrics: None,
            admitted_s: 0.0,
            completed_s: 0.0,
            queued_logged: false,
            preemptions: 0,
            restores: 0,
            share_at_completion: 0.0,
            gmis_at_completion: 0,
            grown: Vec::new(),
            needs_restore: false,
            ckpt: None,
            kills: 0,
            killed_at: None,
            recovery_s: 0.0,
            checkpoint_s: 0.0,
            goodput_lost_s: 0.0,
            busy_at_ckpt: 0.0,
            tuned: false,
            parent: None,
            spawned_tags: BTreeSet::new(),
            pending: Vec::new(),
            history: Vec::new(),
        }
    }
}

struct Cluster<'a> {
    bench: &'a BenchInfo,
    cost: &'a CostModel,
    cfg: &'a SchedConfig,
    engine: Engine,
    fabric: Fabric,
    /// Cluster tenants run Null numerics (virtual timing is identical).
    compute: Compute,
    tenants: Vec<Tenant>,
    events: Vec<SchedEvent>,
    next_gmi: GmiId,
    peak_gpu_share: f64,
    peak_gpu_mem: f64,
    /// Placement changed since the last peak sample: `track_peaks` only
    /// rescans the manager after an add/resize/remove (peaks are running
    /// maxes, so unchanged rounds cannot move them).
    placement_dirty: bool,
    /// Reusable tenant-ordering buffer for the per-round passes.
    order_scratch: Vec<usize>,
    /// Next unapplied event of `cfg.faults` (the trace is time-sorted).
    fault_cursor: usize,
    /// Next periodic checkpoint boundary (INFINITY when disabled).
    next_checkpoint_s: f64,
    /// Id assigned to the next runtime-spawned child tenant (starts past
    /// every submitted job's id).
    next_job_id: JobId,
}

/// Admit, co-schedule, and run `jobs` to completion on one shared
/// cluster. Deterministic: the same inputs reproduce the identical
/// timeline and bit-identical per-job metrics.
pub fn run_cluster(
    topo: &Topology,
    bench: &BenchInfo,
    cost: &CostModel,
    jobs: &[JobSpec],
    cfg: &SchedConfig,
) -> Result<ClusterRunResult> {
    anyhow::ensure!(cfg.quantum_s > 0.0, "scheduling quantum must be positive");
    anyhow::ensure!(!jobs.is_empty(), "no jobs submitted");
    if let Some(p) = &cfg.faults {
        anyhow::ensure!(
            p.checkpoint_interval_s > 0.0,
            "checkpoint interval must be positive (f64::INFINITY disables checkpointing)"
        );
    }
    let mut seen = BTreeSet::new();
    for j in jobs {
        j.validate(topo)?;
        anyhow::ensure!(seen.insert(j.id), "duplicate job id {}", j.id);
    }

    let manager = crate::gmi::GmiManager::new(topo.clone());
    let mut cluster = Cluster {
        bench,
        cost,
        cfg,
        engine: Engine::new(&manager, cost),
        fabric: Fabric::single_node(topo.clone()),
        compute: Compute::Null,
        tenants: jobs.iter().cloned().map(Tenant::new).collect(),
        events: Vec::new(),
        next_gmi: 0,
        peak_gpu_share: 0.0,
        peak_gpu_mem: 0.0,
        placement_dirty: true,
        order_scratch: Vec::new(),
        fault_cursor: 0,
        next_checkpoint_s: cfg
            .faults
            .as_ref()
            .map(|p| p.checkpoint_interval_s)
            .unwrap_or(f64::INFINITY),
        next_job_id: jobs.iter().map(|j| j.id).max().unwrap_or(0).saturating_add(1),
    };
    cluster.run()?;
    Ok(cluster.into_result())
}

impl Cluster<'_> {
    // ---- the round loop ----

    fn run(&mut self) -> Result<()> {
        let q = self.cfg.quantum_s;
        let max_rounds = self.cfg.max_rounds.unwrap_or_else(|| self.derived_max_rounds(q));
        let mut round = 0usize;
        // Audit mode: rounds below this index were predicted quiescent by
        // an earlier `next_active_round` and must not do observable work.
        let mut audit_until = 0usize;
        while self.tenants.iter().any(|t| t.state != State::Done) {
            anyhow::ensure!(
                round < max_rounds,
                "scheduler exceeded {} rounds (runaway guard; set \
                 SchedConfig::max_rounds = Some(n) to raise the derived cap)",
                max_rounds
            );
            let audited = round < audit_until;
            let pre_events = self.events.len();
            let pre_fault_cursor = self.fault_cursor;
            let now = round as f64 * q;
            // Computed the same way the next round's `now` will be, so
            // round boundaries are bit-identical across rounds.
            let round_end = (round + 1) as f64 * q;
            // Hardware events land first (pessimistic: a failure at the
            // checkpoint boundary loses the full interval), then the
            // checkpoint pass captures the survivors.
            self.fault_pass(now);
            self.checkpoint_pass(now);
            if self.cfg.preemptive {
                self.slo_decisions(now);
            }
            self.admissions(now)?;
            if self.cfg.preemptive {
                self.restore_pass(now);
            }
            // Serving tenants step first, then batch tenants, both through
            // the one reusable ordering buffer (no per-round allocation).
            let mut order = std::mem::take(&mut self.order_scratch);
            self.order_running_into(true, &mut order);
            for k in 0..order.len() {
                self.step_tenant(order[k], round_end)?;
            }
            self.order_running_into(false, &mut order);
            for k in 0..order.len() {
                self.step_tenant(order[k], round_end)?;
            }
            self.order_scratch = order;
            // Coordinator programs may have requested child tenants while
            // stepping; they join the queue and admit from the next round.
            self.drain_spawn_requests(now, round_end)?;
            if audited {
                // `placement_dirty` was cleared by the previous round's
                // track_peaks, so it is set here iff THIS round moved
                // placement; checked before track_peaks clears it again.
                let quiet = self.events.len() == pre_events
                    && self.fault_cursor == pre_fault_cursor
                    && !self.placement_dirty
                    && self.tenants.iter().all(|t| !t.done);
                anyhow::ensure!(
                    quiet,
                    "fast-forward audit: round {round} (t = {now:.4}s) was \
                     predicted quiescent but did observable work"
                );
            }
            // Sample occupancy peaks BEFORE completions release GMIs, so a
            // tenant admitted and finished within one round is observed.
            self.track_peaks();
            self.completions(now, round_end);
            round = match self.cfg.fast_forward {
                FastForward::Off => round + 1,
                FastForward::On => self.next_active_round(round, q, max_rounds),
                FastForward::Audit => {
                    let target = self.next_active_round(round, q, max_rounds);
                    audit_until = audit_until.max(target);
                    round + 1
                }
            };
        }
        Ok(())
    }

    /// Runaway cap when `SchedConfig::max_rounds` is `None`: four times
    /// the rounds the jobs' own horizons imply (serving-trace end plus
    /// arrival offset), floored at the historical 1,000,000. Training
    /// tenants have no intrinsic horizon; the 4x slack over the serving
    /// span (plus the floor) covers their drain time.
    fn derived_max_rounds(&self, q: f64) -> usize {
        let mut horizon = 0.0f64;
        for t in &self.tenants {
            let end = match &t.spec.kind {
                JobKind::Serving { trace, .. } | JobKind::Gateway { trace, .. } => trace.end_s(),
                _ => 0.0,
            };
            horizon = horizon.max(t.spec.arrival_s + end);
        }
        let derived = (4.0 * (horizon / q).ceil()).min(1e18);
        (derived as usize).max(1_000_000)
    }

    /// Fast-forward: the next round index that could observe an event.
    /// Returns `round + 1` (the naive cadence) unless EVERY per-round
    /// pass is provably a no-op over the skipped span:
    ///
    /// - every Running tenant's program gives a [`Workload::next_event_hint`]
    ///   (a `None` hint means "step me every round"), and has no pending
    ///   child results and no restore flag (restore_pass acts each round);
    /// - no Queued tenant is already due (admission retries each round);
    /// - the span contains no fault event, no checkpoint boundary, no
    ///   queued arrival, and no tenant hint.
    ///
    /// The target is computed conservatively LOW — an early stop just
    /// steps one naive (idle) round; a late one would skip observable
    /// work. Jumping whole integer rounds keeps `now = round * q`
    /// bit-identical with the naive loop at every processed round.
    fn next_active_round(&mut self, round: usize, q: f64, max_rounds: usize) -> usize {
        let next = round + 1;
        let now_next = next as f64 * q;
        let mut bound = f64::INFINITY;
        for t in &self.tenants {
            match t.state {
                State::Queued => {
                    if t.spec.arrival_s <= now_next + 1e-12 {
                        return next;
                    }
                    bound = bound.min(t.spec.arrival_s);
                }
                State::Running => {
                    if t.needs_restore || !t.pending.is_empty() {
                        return next;
                    }
                }
                State::Done => {}
            }
        }
        for i in 0..self.tenants.len() {
            if self.tenants[i].state != State::Running {
                continue;
            }
            let Some(p) = self.tenants[i].program.as_mut() else {
                return next;
            };
            match p.next_event_hint() {
                Some(t_ev) => bound = bound.min(t_ev),
                None => return next,
            }
        }
        if let Some(plan) = self.cfg.faults.as_ref() {
            if let Some(ev) = plan.trace.events.get(self.fault_cursor) {
                bound = bound.min(ev.t_s);
            }
        }
        bound = bound.min(self.next_checkpoint_s);
        if !bound.is_finite() {
            // No future event yet tenants aren't Done — unreachable (a
            // drained program hints None), but step naively over spinning.
            return next;
        }
        // First round whose quantum can interact with an event at `bound`,
        // biased low so float rounding can only cost extra naive rounds.
        let cap = (max_rounds.saturating_sub(1)) as f64;
        let lo = (((bound - 1e-12) / q).floor().max(0.0)).min(cap) as usize;
        lo.max(next)
    }

    /// Running tenants of one kind, priority-descending then id-ascending,
    /// written into a caller-owned buffer (the round loop reuses one).
    fn order_running_into(&self, serving: bool, out: &mut Vec<usize>) {
        out.clear();
        out.extend((0..self.tenants.len()).filter(|&i| {
            self.tenants[i].state == State::Running
                && self.tenants[i].spec.is_serving() == serving
        }));
        out.sort_by_key(|&i| (Reverse(self.tenants[i].spec.priority), self.tenants[i].spec.id));
    }

    fn push_event(&mut self, t_s: f64, idx: usize, action: SchedAction, detail: String) {
        let job = self.tenants[idx].spec.id;
        self.events.push(SchedEvent {
            t_s,
            job,
            action,
            members: self.tenants[idx].gmis.len(),
            share: self.engine.manager().job_share(job),
            detail,
        });
    }

    /// Step one running tenant's program to the round boundary.
    fn step_tenant(&mut self, idx: usize, round_end: f64) -> Result<()> {
        if self.tenants[idx].state != State::Running || self.tenants[idx].done {
            return Ok(());
        }
        let mut program =
            self.tenants[idx].program.take().expect("running tenant has a program");
        // Completed child results land before the coordinator's next
        // charges — a post-restore replay re-delivers the full history and
        // the program deduplicates by tag.
        for (tag, m) in std::mem::take(&mut self.tenants[idx].pending) {
            program.child_result(tag, &m);
        }
        let outcome = {
            let mut ctx = StepCtx {
                engine: &mut self.engine,
                fabric: &mut self.fabric,
                cost: self.cost,
                bench: self.bench,
                compute: &self.compute,
                horizon_s: round_end,
            };
            program.step(&mut ctx)
        };
        self.tenants[idx].program = Some(program);
        if outcome? == StepOutcome::Done {
            self.tenants[idx].done = true;
        }
        Ok(())
    }

    /// Turn every running coordinator's pending [`SpawnRequest`]s into
    /// queued tenants. The scheduler owns child identity: each request
    /// gets a fresh cluster-unique job id and an arrival at this round's
    /// boundary, then competes for admission like any submitted job. A
    /// restored coordinator may replay requests for children that already
    /// exist (and kept running through its outage) — `spawned_tags`
    /// deduplicates those.
    ///
    /// [`SpawnRequest`]: crate::workload::SpawnRequest
    fn drain_spawn_requests(&mut self, now: f64, round_end: f64) -> Result<()> {
        for idx in 0..self.tenants.len() {
            if self.tenants[idx].state != State::Running {
                continue;
            }
            let Some(program) = self.tenants[idx].program.as_mut() else { continue };
            let requests = program.take_spawn_requests();
            if requests.is_empty() {
                continue;
            }
            let parent_job = self.tenants[idx].spec.id;
            for req in requests {
                if !self.tenants[idx].spawned_tags.insert(req.tag) {
                    continue;
                }
                let mut spec = req.spec;
                spec.id = self.next_job_id;
                spec.arrival_s = round_end;
                spec.validate(self.engine.topology()).map_err(|e| {
                    e.context(format!(
                        "job {parent_job} spawned an invalid child (tag {})",
                        req.tag
                    ))
                })?;
                self.next_job_id += 1;
                let mut child = Tenant::new(spec);
                child.parent = Some((parent_job, req.tag));
                self.tenants.push(child);
                let child_idx = self.tenants.len() - 1;
                self.push_event(
                    now,
                    child_idx,
                    SchedAction::Spawn,
                    format!("spawned by job {parent_job} (tag {})", req.tag),
                );
            }
        }
        Ok(())
    }

    /// Re-bind a running tenant's program after a membership or
    /// provisioning change (the preempt/resize/restore hook). On a
    /// healthy fabric a re-bind of placed members cannot fail; on a
    /// degraded one it can (the planner finds no valid route), which
    /// kills the tenant back to its last checkpoint.
    fn rebind(&mut self, idx: usize, now: f64) {
        if self.tenants[idx].state != State::Running {
            return;
        }
        let Some(mut program) = self.tenants[idx].program.take() else { return };
        let execs = self.tenants[idx].execs.clone();
        match program.bind(&self.engine, &mut self.fabric, self.bench, &execs) {
            Ok(()) => self.tenants[idx].program = Some(program),
            Err(e) => {
                assert!(
                    self.fabric.has_failures(),
                    "re-bind of a placed tenant failed on a healthy fabric: {e}"
                );
                drop(program);
                self.kill_tenant(idx, now, format!("re-bind failed on degraded fabric ({e})"));
            }
        }
    }

    // ---- failure injection / checkpoint / recovery ----

    /// Timeline entry that belongs to the cluster, not a tenant.
    fn push_cluster_event(&mut self, t_s: f64, action: SchedAction, detail: String) {
        self.events.push(SchedEvent {
            t_s,
            job: CLUSTER_EVENT,
            action,
            members: 0,
            share: 0.0,
            detail,
        });
    }

    /// Apply every fault-trace event due by `now` to the fabric, kill
    /// tenants left with members on dead GPUs, and re-plan the survivors
    /// against the changed fabric (next-cheapest valid routes; a tenant
    /// the planner cannot route at all — partitioned — is killed too).
    fn fault_pass(&mut self, now: f64) {
        let cfg = self.cfg;
        let Some(plan) = cfg.faults.as_ref() else { return };
        let events = &plan.trace.events;
        let mut changed = false;
        while self.fault_cursor < events.len() && events[self.fault_cursor].t_s <= now + 1e-12 {
            let ev = events[self.fault_cursor];
            self.fault_cursor += 1;
            ev.apply(&mut self.fabric, plan.trace.gpus_per_node);
            changed = true;
            let action = match ev.kind {
                FaultKind::Fail => SchedAction::Fail,
                FaultKind::Repair => SchedAction::Repair,
            };
            self.push_cluster_event(now, action, format!("{} (trace t={:.4})", ev.target, ev.t_s));
        }
        if !changed {
            return;
        }
        if self.fabric.has_failures() {
            for idx in 0..self.tenants.len() {
                if self.tenants[idx].state != State::Running {
                    continue;
                }
                let on_dead_gpu = self.tenants[idx].gmis.iter().any(|&g| {
                    self.engine
                        .manager()
                        .gmi(g)
                        .map_or(false, |s| self.fabric.gpu_failed(s.gpu))
                });
                if on_dead_gpu {
                    self.kill_tenant(idx, now, "member GPU failed".into());
                }
            }
        }
        self.replan_running(now);
    }

    /// Swap every running tenant's program for an unbound snapshot and
    /// re-bind it, so placement-derived plans (collective routes, pooled
    /// dispatch plans) are recomputed against the fabric as it now is —
    /// both after failures (reroute or die) and after repairs (take the
    /// cheap routes back). Run state carries over; a program without
    /// snapshot support falls back to a plain re-bind.
    fn replan_running(&mut self, now: f64) {
        for idx in 0..self.tenants.len() {
            if self.tenants[idx].state != State::Running {
                continue;
            }
            let Some(program) = self.tenants[idx].program.take() else { continue };
            let mut fresh = program.snapshot().unwrap_or(program);
            let execs = self.tenants[idx].execs.clone();
            match fresh.bind(&self.engine, &mut self.fabric, self.bench, &execs) {
                Ok(()) => self.tenants[idx].program = Some(fresh),
                Err(e) => {
                    drop(fresh);
                    self.kill_tenant(idx, now, format!("partitioned by fabric failure ({e})"));
                }
            }
        }
    }

    /// Periodic program-state capture: snapshot every running tenant and
    /// charge the capture (one host-staged parameter dump per member) to
    /// the tenant's own executors — co-tenants never pay for another
    /// job's checkpoints.
    fn checkpoint_pass(&mut self, now: f64) {
        if now + 1e-12 < self.next_checkpoint_s {
            return;
        }
        let interval = self
            .cfg
            .faults
            .as_ref()
            .map(|p| p.checkpoint_interval_s)
            .expect("finite next_checkpoint_s implies a fault plan");
        while self.next_checkpoint_s <= now + 1e-12 {
            self.next_checkpoint_s += interval;
        }
        let cost_s =
            self.engine.topology().host_transfer_time(self.bench.num_params * 4, 1);
        for idx in 0..self.tenants.len() {
            if self.tenants[idx].state != State::Running || self.tenants[idx].done {
                continue;
            }
            let Some(snap) = self.tenants[idx].program.as_ref().and_then(|p| p.snapshot())
            else {
                continue;
            };
            for k in 0..self.tenants[idx].execs.len() {
                let ex = self.tenants[idx].execs[k];
                self.engine.pay(ex, cost_s);
            }
            let members = self.tenants[idx].execs.len();
            let job = self.tenants[idx].spec.id;
            let busy = self.engine.job_busy_s(job);
            let t = &mut self.tenants[idx];
            t.ckpt = Some(snap);
            t.checkpoint_s += cost_s * members as f64;
            t.busy_at_ckpt = busy;
            self.push_event(
                now,
                idx,
                SchedAction::Checkpoint,
                format!("captured; {cost_s:.5}s charged to each of {members} member(s)"),
            );
        }
    }

    /// Release a tenant's members back to the cluster (the shared tail of
    /// completion and kill).
    fn release_members(&mut self, idx: usize) {
        let job = self.tenants[idx].spec.id;
        self.engine.clear_job(job);
        let gmis: Vec<GmiId> = self.tenants[idx].gmis.drain(..).collect();
        self.tenants[idx].execs.clear();
        for g in gmis {
            let _ = self.engine.remove_gmi(g);
        }
        self.placement_dirty = true;
    }

    /// A hardware failure took this tenant down: discard the live program
    /// (its un-checkpointed service is the goodput lost), release every
    /// member, and re-queue. The admissions pass re-admits it onto
    /// surviving capacity, resuming from `ckpt` when one exists.
    fn kill_tenant(&mut self, idx: usize, now: f64, detail: String) {
        if self.tenants[idx].state != State::Running {
            return;
        }
        let job = self.tenants[idx].spec.id;
        let lost = (self.engine.job_busy_s(job) - self.tenants[idx].busy_at_ckpt).max(0.0);
        drop(self.tenants[idx].program.take());
        self.release_members(idx);
        let t = &mut self.tenants[idx];
        t.state = State::Queued;
        t.done = false;
        t.kills += 1;
        t.killed_at = Some(now);
        t.goodput_lost_s += lost;
        t.grown.clear();
        t.needs_restore = false;
        t.queued_logged = false;
        let from = if t.ckpt.is_some() { "last checkpoint" } else { "scratch" };
        self.push_event(
            now,
            idx,
            SchedAction::Kill,
            format!("{detail}; {lost:.4}s service lost, will resume from {from}"),
        );
    }

    // ---- capacity / placement ----

    /// Used (SM share, memory GiB) of one GPU per the engine's live
    /// manager — the one occupancy aggregation placement and peak
    /// tracking both read.
    fn gpu_used(&self, gpu: usize) -> (f64, f64) {
        let mut sm = 0.0f64;
        let mut mem = 0.0f64;
        for g in self.engine.manager().all().filter(|g| g.gpu == gpu) {
            sm += g.sm_share;
            mem += g.mem_gib;
        }
        (sm, mem)
    }

    /// Free (SM share, memory GiB) of one GPU.
    fn gpu_free(&self, gpu: usize) -> (f64, f64) {
        let (sm, mem) = self.gpu_used(gpu);
        let cap_mem = self.engine.topology().gpus[gpu].mem_gib;
        ((1.0 - sm).max(0.0), (cap_mem - mem).max(0.0))
    }

    /// Place ONE member for tenant `idx` at its spec share on the allowed
    /// GPU with the most free share (ties to the lowest index), register
    /// its executor, tag its job, and advance its clock to `now`. The
    /// member's role and env count come from its index in the member list
    /// (async tenants mix agent and trainer members).
    fn place_one(&mut self, idx: usize, now: f64) -> Option<GmiId> {
        let member_idx = self.tenants[idx].gmis.len();
        let (share, mem, role, num_env, job, allowed) = {
            let s = &self.tenants[idx].spec;
            (
                s.share,
                s.mem_gib,
                s.member_role(member_idx),
                s.member_num_env(member_idx),
                s.id,
                s.allowed_gpus(self.engine.topology()),
            )
        };
        let mut best: Option<(usize, f64)> = None;
        for &g in &allowed {
            // A dead GPU is never a placement target, no matter how free.
            if self.fabric.gpu_failed(g) {
                continue;
            }
            let (free_sm, free_mem) = self.gpu_free(g);
            if free_sm + 1e-9 >= share && free_mem + 1e-9 >= mem {
                if best.map_or(true, |(_, f)| free_sm > f + 1e-12) {
                    best = Some((g, free_sm));
                }
            }
        }
        let (gpu, _) = best?;
        let id = self.next_gmi;
        let spec = GmiSpec {
            id,
            gpu,
            sm_share: share,
            mem_gib: mem,
            backend: GmiBackend::Mps,
            role,
            num_env,
        };
        let ex = self.engine.add_gmi(spec).ok()?;
        self.placement_dirty = true;
        self.next_gmi += 1;
        self.engine.tag_job(ex, job).expect("member registered above");
        let lag = now - self.engine.clock(ex).seconds();
        if lag > 0.0 {
            self.engine.pay(ex, lag);
        }
        let t = &mut self.tenants[idx];
        t.gmis.push(id);
        t.execs.push(ex);
        Some(id)
    }

    /// Place tenant `idx`'s full initial member set, or roll back and
    /// report failure.
    fn try_place_initial(&mut self, idx: usize, now: f64) -> bool {
        let want = self.tenants[idx].spec.initial_gmis;
        let mut placed = Vec::new();
        for _ in 0..want {
            match self.place_one(idx, now) {
                Some(g) => placed.push(g),
                None => {
                    for g in placed.into_iter().rev() {
                        let t = &mut self.tenants[idx];
                        t.gmis.pop();
                        t.execs.pop();
                        let _ = self.engine.remove_gmi(g);
                        self.placement_dirty = true;
                    }
                    return false;
                }
            }
        }
        true
    }

    // ---- preemption ----

    /// Shrink every running tenant of lower priority to its per-member
    /// share floor (validated resizes). Returns whether anything moved.
    fn shrink_lower(&mut self, priority: u8, now: f64) -> bool {
        let mut order: Vec<usize> = (0..self.tenants.len())
            .filter(|&i| {
                self.tenants[i].state == State::Running
                    && self.tenants[i].spec.priority < priority
            })
            .collect();
        order.sort_by_key(|&i| (self.tenants[i].spec.priority, self.tenants[i].spec.id));
        let mut any = false;
        for i in order {
            let floor = self.tenants[i].spec.min_share;
            let mut changed = 0usize;
            // Index walk: `resize_share` never edits the member list, so
            // no defensive clone of it is needed.
            for k in 0..self.tenants[i].gmis.len() {
                let gmi = self.tenants[i].gmis[k];
                let cur = match self.engine.manager().gmi(gmi) {
                    Some(s) => s.sm_share,
                    None => continue,
                };
                if cur > floor + 1e-9 && self.engine.resize_share(gmi, floor).is_ok() {
                    changed += 1;
                }
            }
            if changed > 0 {
                self.placement_dirty = true;
                self.tenants[i].needs_restore = true;
                self.tenants[i].preemptions += 1;
                self.rebind(i, now);
                self.push_event(
                    now,
                    i,
                    SchedAction::Preempt,
                    format!("shrunk {changed} member(s) to {floor:.2}"),
                );
                any = true;
            }
        }
        any
    }

    /// Evict one member GMI from the lowest-priority tenant below
    /// `priority` that still sits above its member-count floor. Returns
    /// whether an eviction happened.
    fn evict_one_lower(&mut self, priority: u8, now: f64) -> bool {
        let mut cand: Option<usize> = None;
        for i in 0..self.tenants.len() {
            let t = &self.tenants[i];
            if t.state != State::Running
                || t.spec.priority >= priority
                || t.gmis.len() <= t.spec.min_gmis
            {
                continue;
            }
            let better = match cand {
                None => true,
                Some(c) => {
                    (t.spec.priority, t.spec.id)
                        < (self.tenants[c].spec.priority, self.tenants[c].spec.id)
                }
            };
            if better {
                cand = Some(i);
            }
        }
        let Some(i) = cand else { return false };
        let gmi = *self.tenants[i].gmis.last().expect("above count floor");
        if self.engine.remove_gmi(gmi).is_err() {
            return false;
        }
        let t = &mut self.tenants[i];
        t.gmis.pop();
        t.execs.pop();
        t.grown.retain(|&g| g != gmi);
        t.preemptions += 1;
        t.needs_restore = true;
        self.placement_dirty = true;
        self.rebind(i, now);
        self.push_event(now, i, SchedAction::Evict, format!("evicted member GMI {gmi}"));
        true
    }

    // ---- admission ----

    fn admissions(&mut self, now: f64) -> Result<()> {
        let mut order: Vec<usize> = (0..self.tenants.len())
            .filter(|&i| {
                self.tenants[i].state == State::Queued
                    && self.tenants[i].spec.arrival_s <= now + 1e-12
            })
            .collect();
        order.sort_by(|&a, &b| {
            let (ta, tb) = (&self.tenants[a].spec, &self.tenants[b].spec);
            tb.priority
                .cmp(&ta.priority)
                .then(ta.arrival_s.total_cmp(&tb.arrival_s))
                .then(ta.id.cmp(&tb.id))
        });
        for idx in order {
            self.try_admit(idx, now)?;
        }
        Ok(())
    }

    fn try_admit(&mut self, idx: usize, now: f64) -> Result<()> {
        let prio = self.tenants[idx].spec.priority;
        let mut ok = self.try_place_initial(idx, now);
        if !ok && self.cfg.preemptive {
            self.shrink_lower(prio, now);
            ok = self.try_place_initial(idx, now);
            while !ok && self.evict_one_lower(prio, now) {
                ok = self.try_place_initial(idx, now);
            }
        }
        if ok {
            let resuming = self.tenants[idx].kills > 0;
            let (job, floor) = {
                let t = &mut self.tenants[idx];
                t.state = State::Running;
                // Re-admissions after a kill keep the original admission
                // time (wait_s stays queue wait; the outage is recovery_s).
                if !resuming {
                    t.admitted_s = now;
                }
                (t.spec.id, t.spec.floor_share())
            };
            self.engine.set_job_floor(job, floor);
            // Admission-time auto-tuning (Training tenants that requested
            // it) — BEFORE the program is built, so the tuned minibatch
            // count is what the tenant runs with. A resumed tenant keeps
            // its first admission's locked choice instead of re-probing.
            if !resuming {
                self.tune_at_admission(idx, now)?;
            }
            // Build the workload program and bind it to the placed
            // members: a killed tenant resumes from a re-snapshot of its
            // last checkpoint (the stored one survives further kills),
            // anything else starts fresh. From here on the tenant is just
            // stepped.
            let mut program = match self.tenants[idx].ckpt.as_ref() {
                Some(c) => c.snapshot().expect("a stored checkpoint can re-snapshot"),
                None => self.tenants[idx].spec.build_program(),
            };
            let execs = self.tenants[idx].execs.clone();
            if let Err(e) = program.bind(&self.engine, &mut self.fabric, self.bench, &execs) {
                // Only a degraded fabric can make freshly validated
                // placement unbindable (partitioned members): back the
                // admission out and retry on a later round.
                anyhow::ensure!(
                    self.fabric.has_failures(),
                    "bind of a freshly placed tenant failed on a healthy fabric: {e}"
                );
                drop(program);
                self.release_members(idx);
                let t = &mut self.tenants[idx];
                t.state = State::Queued;
                if !t.queued_logged {
                    t.queued_logged = true;
                    self.push_event(now, idx, SchedAction::Queue, format!("unbindable: {e}"));
                }
                return Ok(());
            }
            self.tenants[idx].program = Some(program);
            self.tenants[idx].busy_at_ckpt = self.engine.job_busy_s(job);
            if resuming {
                // The restored program is a checkpoint that may predate
                // some child completions: replay the full result history
                // (programs deduplicate deliveries by tag).
                self.tenants[idx].pending = self.tenants[idx].history.clone();
            }
            if let Some(killed) = self.tenants[idx].killed_at.take() {
                self.tenants[idx].recovery_s += now - killed;
            }
            let n = self.tenants[idx].gmis.len();
            let detail = if resuming {
                let src = if self.tenants[idx].ckpt.is_some() { "last checkpoint" } else { "scratch" };
                format!("re-admitted {n} member(s) on surviving capacity, resumed from {src}")
            } else {
                format!("placed {n} member(s)")
            };
            self.push_event(now, idx, SchedAction::Admit, detail);
        } else if !self.tenants[idx].queued_logged {
            self.tenants[idx].queued_logged = true;
            self.push_event(now, idx, SchedAction::Queue, "insufficient capacity".into());
        }
        Ok(())
    }

    /// Admission-time minibatch tuning: probe candidates on a scratch
    /// mirror of the tenant's just-placed members
    /// ([`crate::tune::tune_admission_minibatches`]), lock the measured
    /// best into the job's `Training` kind, and charge the probe
    /// virtual-time to the tenant's own member clocks — co-tenants never
    /// pay for another job's tuning.
    fn tune_at_admission(&mut self, idx: usize, now: f64) -> Result<()> {
        // Once per tenant, ever: the `!resuming` gate at the call site only
        // covers kill + re-admission, not a bind-failure backout (which
        // re-queues without a kill) — without this flag the retried
        // admission would probe and charge a second time.
        if self.tenants[idx].tuned {
            return Ok(());
        }
        let Some(tr) = self.tenants[idx].spec.tune.clone() else { return Ok(()) };
        let (iterations, horizon, current_mb) = match &self.tenants[idx].spec.kind {
            JobKind::Training { iterations, horizon, minibatches, .. } => {
                (*iterations, *horizon, *minibatches)
            }
            // validate() rejects tuning on other kinds; unreachable in a
            // validated run, harmless otherwise.
            _ => return Ok(()),
        };
        let members: Vec<GmiSpec> = self.tenants[idx]
            .gmis
            .iter()
            .filter_map(|&g| self.engine.manager().gmi(g).cloned())
            .collect();
        let topo = self.engine.manager().topology().clone();
        let rep = crate::tune::tune_admission_minibatches(
            &topo, &members, self.bench, self.cost, iterations, horizon, current_mb, &tr,
        )?;
        if let JobKind::Training { minibatches, .. } = &mut self.tenants[idx].spec.kind {
            *minibatches = rep.choice;
        }
        self.tenants[idx].tuned = true;
        if rep.probe_cost_s > 0.0 {
            for k in 0..self.tenants[idx].execs.len() {
                let ex = self.tenants[idx].execs[k];
                self.engine.pay(ex, rep.probe_cost_s);
            }
        }
        self.push_event(
            now,
            idx,
            SchedAction::Tune,
            format!(
                "minibatches {current_mb} -> {} ({} probes, {:.4}s charged{})",
                rep.choice,
                rep.probes.len(),
                rep.probe_cost_s,
                if rep.fallback { ", fallback" } else { "" }
            ),
        );
        Ok(())
    }

    // ---- SLO pressure / elasticity ----

    fn slo_decisions(&mut self, now: f64) {
        let mut order = std::mem::take(&mut self.order_scratch);
        self.order_running_into(true, &mut order);
        for k in 0..order.len() {
            let idx = order[k];
            let Some(slo) = self.tenants[idx].spec.slo_p99_s() else { continue };
            let signal = self.tenants[idx].program.as_ref().and_then(|p| p.slo_signal());
            let Some(p99) = signal else { continue };
            if p99 > slo {
                self.grow_serving(idx, now, p99);
            } else if p99 < self.cfg.restore_frac * slo {
                self.shrink_grown(idx, now, p99);
            }
        }
        self.order_scratch = order;
    }

    fn grow_serving(&mut self, idx: usize, now: f64, p99: f64) {
        let (prio, max_gmis) =
            (self.tenants[idx].spec.priority, self.tenants[idx].spec.max_gmis);
        if self.tenants[idx].gmis.len() >= max_gmis {
            return;
        }
        let mut placed = self.place_one(idx, now);
        if placed.is_none() {
            self.shrink_lower(prio, now);
            placed = self.place_one(idx, now);
            while placed.is_none() && self.evict_one_lower(prio, now) {
                placed = self.place_one(idx, now);
            }
        }
        if let Some(g) = placed {
            self.tenants[idx].grown.push(g);
            self.rebind(idx, now);
            self.push_event(
                now,
                idx,
                SchedAction::Grow,
                format!("p99 {:.1}ms over SLO: added member GMI {g}", p99 * 1e3),
            );
        }
    }

    fn shrink_grown(&mut self, idx: usize, now: f64, p99: f64) {
        let Some(gmi) = self.tenants[idx].grown.pop() else { return };
        if self.engine.remove_gmi(gmi).is_err() {
            self.tenants[idx].grown.push(gmi);
            return;
        }
        let t = &mut self.tenants[idx];
        if let Some(pos) = t.gmis.iter().position(|&g| g == gmi) {
            t.gmis.remove(pos);
            t.execs.remove(pos);
        }
        // Retiring a grown member can leave the tenant below its admitted
        // provisioning when evictions interleaved with growth.
        t.needs_restore = true;
        self.placement_dirty = true;
        self.rebind(idx, now);
        self.push_event(
            now,
            idx,
            SchedAction::Shrink,
            format!("p99 {:.1}ms comfortable: retired grown GMI {gmi}", p99 * 1e3),
        );
    }

    /// When no serving tenant is under SLO pressure, give each
    /// below-target tenant one step back toward its admitted
    /// provisioning: re-add an evicted member, else regrow shrunken
    /// members into free share.
    fn restore_pass(&mut self, now: f64) {
        let pressure = self.tenants.iter().any(|t| {
            t.state == State::Running
                && match (t.spec.slo_p99_s(), t.program.as_ref().and_then(|p| p.slo_signal())) {
                    (Some(slo), Some(p)) => p > slo,
                    _ => false,
                }
        });
        if pressure {
            return;
        }
        // Only tenants flagged by a preemption/eviction/shrink are scanned:
        // a fully provisioned steady-state round walks an empty order.
        let mut order = std::mem::take(&mut self.order_scratch);
        order.clear();
        order.extend((0..self.tenants.len()).filter(|&i| {
            self.tenants[i].state == State::Running && self.tenants[i].needs_restore
        }));
        order.sort_by_key(|&i| (Reverse(self.tenants[i].spec.priority), self.tenants[i].spec.id));
        for k in 0..order.len() {
            let idx = order[k];
            let (initial, share) =
                (self.tenants[idx].spec.initial_gmis, self.tenants[idx].spec.share);
            if self.tenants[idx].gmis.len() < initial {
                if let Some(g) = self.place_one(idx, now) {
                    self.tenants[idx].restores += 1;
                    self.rebind(idx, now);
                    self.push_event(
                        now,
                        idx,
                        SchedAction::Restore,
                        format!("re-added evicted member as GMI {g}"),
                    );
                    continue;
                }
            }
            let mut grew = 0usize;
            let mut still_below = 0usize;
            for m in 0..self.tenants[idx].gmis.len() {
                let gmi = self.tenants[idx].gmis[m];
                let (cur, gpu) = match self.engine.manager().gmi(gmi) {
                    Some(s) => (s.sm_share, s.gpu),
                    None => continue,
                };
                if cur + 1e-9 >= share {
                    continue;
                }
                let (free, _) = self.gpu_free(gpu);
                let target = (cur + free).min(share);
                if target > cur + 0.009 && self.engine.resize_share(gmi, target).is_ok() {
                    grew += 1;
                    if target + 1e-9 < share {
                        still_below += 1;
                    }
                } else {
                    still_below += 1;
                }
            }
            if grew > 0 {
                self.placement_dirty = true;
                self.tenants[idx].restores += 1;
                self.rebind(idx, now);
                self.push_event(
                    now,
                    idx,
                    SchedAction::Restore,
                    format!("regrew {grew} member(s) toward {share:.2}"),
                );
            }
            if still_below == 0 && self.tenants[idx].gmis.len() >= initial {
                self.tenants[idx].needs_restore = false;
            }
        }
        self.order_scratch = order;
    }

    // ---- completion / release ----

    fn completions(&mut self, now: f64, round_end: f64) {
        for idx in 0..self.tenants.len() {
            if self.tenants[idx].state != State::Running || !self.tenants[idx].done {
                continue;
            }
            // Open-loop serving tenants complete at the round boundary
            // their trace drained in; batch tenants at their executor
            // frontier.
            let at = if self.tenants[idx].spec.is_serving() {
                round_end
            } else {
                self.engine.max_time(&self.tenants[idx].execs).seconds().max(now)
            };
            self.finish(idx, at);
        }
    }

    fn finish(&mut self, idx: usize, at: f64) {
        // Capture the program's metrics BEFORE releasing its GMIs: the
        // finish fold reads live member provisioning.
        let mut program =
            self.tenants[idx].program.take().expect("completing tenant has a program");
        let metrics = program.finish(&self.engine, &self.fabric);
        // A spawned child's result flows back to its coordinator: queued
        // for delivery before the coordinator's next step, and kept in its
        // history so a later coordinator restore can replay it.
        if let Some((pjob, tag)) = self.tenants[idx].parent {
            if let Some(p) = self.tenants.iter().position(|t| t.spec.id == pjob) {
                self.tenants[p].pending.push((tag, metrics.clone()));
                self.tenants[p].history.push((tag, metrics.clone()));
            }
        }
        self.tenants[idx].final_metrics = Some(metrics);
        drop(program);

        let job = self.tenants[idx].spec.id;
        let share = self.engine.manager().job_share(job);
        let members = self.tenants[idx].gmis.len();
        self.release_members(idx);
        let t = &mut self.tenants[idx];
        t.state = State::Done;
        t.completed_s = at;
        t.share_at_completion = share;
        t.gmis_at_completion = members;
        self.push_event(at, idx, SchedAction::Complete, format!("released {members} GMI(s)"));
    }

    fn track_peaks(&mut self) {
        // Peaks are running maxes over manager placement, which only moves
        // on an add/resize/remove — rounds without one cannot change them.
        if !self.placement_dirty {
            return;
        }
        self.placement_dirty = false;
        for gpu in 0..self.engine.topology().num_gpus() {
            let (sm, mem) = self.gpu_used(gpu);
            self.peak_gpu_share = self.peak_gpu_share.max(sm);
            self.peak_gpu_mem = self.peak_gpu_mem.max(mem);
        }
    }

    // ---- reporting ----

    fn into_result(self) -> ClusterRunResult {
        let mut reports = Vec::with_capacity(self.tenants.len());
        let mut busies = Vec::with_capacity(self.tenants.len());
        for t in &self.tenants {
            let job = t.spec.id;
            let busy = self.engine.job_busy_s(job);
            let xjob = self.engine.job_xjob_s(job);
            busies.push(busy);
            let metrics = t
                .final_metrics
                .clone()
                .expect("every tenant completed before into_result");
            reports.push(JobReport {
                id: job,
                name: t.spec.name.clone(),
                priority: t.spec.priority,
                kind: t.spec.kind_label(),
                metrics,
                admitted_s: t.admitted_s,
                completed_s: t.completed_s,
                wait_s: (t.admitted_s - t.spec.arrival_s).max(0.0),
                preemptions: t.preemptions,
                restores: t.restores,
                busy_s: busy,
                xjob_interference_s: xjob,
                share_at_completion: t.share_at_completion,
                gmis_at_completion: t.gmis_at_completion,
                kills: t.kills,
                goodput_lost_s: t.goodput_lost_s,
                recovery_s: t.recovery_s,
                checkpoint_s: t.checkpoint_s,
            });
        }
        let goodput_lost_s = reports.iter().map(|j| j.goodput_lost_s).sum();
        ClusterRunResult {
            jobs: reports,
            events: self.events,
            makespan_s: self.engine.span(),
            cluster_utilization: self.engine.mean_utilization(),
            fairness: jain_index(&busies),
            peak_gpu_share: self.peak_gpu_share,
            peak_gpu_mem_gib: self.peak_gpu_mem,
            fault_events: self.fault_cursor,
            goodput_lost_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::static_registry;
    use crate::drl::a3c::AsyncConfig;
    use crate::serve::{generate_trace, TrafficPattern};

    fn setup() -> (Topology, BenchInfo, CostModel) {
        let b = static_registry()["AT"].clone();
        let cost = CostModel::new(&b);
        (Topology::dgx_a100(1), b, cost)
    }

    #[test]
    fn single_training_job_runs_to_completion() {
        let (topo, b, cost) = setup();
        let jobs = vec![JobSpec::training(0, "solo", 1, 0.0, 2, 0.5, 0.2, 512, 3)];
        let r = run_cluster(&topo, &b, &cost, &jobs, &SchedConfig::default()).unwrap();
        let j = r.job(0).unwrap();
        assert_eq!(j.kind, "training");
        assert!(j.metrics.steps_per_sec > 0.0);
        assert_eq!(j.wait_s, 0.0);
        assert_eq!(j.preemptions, 0);
        assert_eq!(j.gmis_at_completion, 2);
        assert!((j.share_at_completion - 1.0).abs() < 1e-9);
        assert!(r.peak_gpu_share <= 1.0 + 1e-6);
        assert!((r.fairness - 1.0).abs() < 1e-9, "one tenant is trivially fair");
        assert!(matches!(r.events.first().unwrap().action, SchedAction::Admit));
        assert!(matches!(r.events.last().unwrap().action, SchedAction::Complete));
    }

    #[test]
    fn admission_tuning_fires_once_charges_tenant_and_is_deterministic() {
        let (topo, b, cost) = setup();
        let tuned = vec![JobSpec::training(0, "solo", 1, 0.0, 2, 0.5, 0.2, 512, 40)
            .with_admission_tuning(crate::tune::AdmissionTune {
                budget_frac: 0.05,
                ..Default::default()
            })];
        let r = run_cluster(&topo, &b, &cost, &tuned, &SchedConfig::default()).unwrap();
        let tune_events: Vec<_> =
            r.events.iter().filter(|e| e.action == SchedAction::Tune).collect();
        assert_eq!(tune_events.len(), 1, "tuning fires exactly once, at admission");
        assert!(tune_events[0].detail.contains("charged"));
        assert!(r.job(0).unwrap().metrics.steps_per_sec > 0.0);
        // Bit-identical decision and timeline run-to-run.
        let r2 = run_cluster(&topo, &b, &cost, &tuned, &SchedConfig::default()).unwrap();
        assert_eq!(r.events, r2.events);
        assert_eq!(
            r.job(0).unwrap().metrics.steps_per_sec.to_bits(),
            r2.job(0).unwrap().metrics.steps_per_sec.to_bits()
        );
        // An untuned run of the same spec emits no Tune event: existing
        // tenants' timelines are untouched by the feature.
        let plain = vec![JobSpec::training(0, "solo", 1, 0.0, 2, 0.5, 0.2, 512, 40)];
        let rp = run_cluster(&topo, &b, &cost, &plain, &SchedConfig::default()).unwrap();
        assert!(rp.events.iter().all(|e| e.action != SchedAction::Tune));
    }

    #[test]
    fn high_priority_arrival_preempts_and_training_is_restored() {
        let (topo, b, cost) = setup();
        // Training owns 0.9 of the single GPU; a high-priority serving
        // burst arrives and needs 0.5 — admission must shrink the trainer,
        // and after the burst completes the trainer must be regrown.
        let trace = generate_trace(&TrafficPattern::Constant { rate: 4000.0 }, 0.2, 3, 4);
        let jobs = vec![
            JobSpec::training(0, "train", 1, 0.0, 1, 0.9, 0.2, 512, 30),
            JobSpec::serving(1, "serve", 9, 0.05, (1, 1, 1), 0.5, 16, 50e-3, trace),
        ];
        let cfg = SchedConfig { quantum_s: 0.05, ..Default::default() };
        let r = run_cluster(&topo, &b, &cost, &jobs, &cfg).unwrap();
        let train = r.job(0).unwrap();
        let serve = r.job(1).unwrap();
        assert!(serve.wait_s <= cfg.quantum_s + 1e-9, "serving waited {}", serve.wait_s);
        assert!(train.preemptions >= 1, "trainer was never preempted");
        assert!(train.restores >= 1, "trainer was never restored");
        assert!(
            (train.share_at_completion - 0.9).abs() < 1e-9,
            "trainer ended at {} share",
            train.share_at_completion
        );
        assert!(r.events.iter().any(|e| e.action == SchedAction::Preempt && e.job == 0));
        let served = serve.metrics.latency.as_ref().unwrap();
        assert_eq!(served.served, served.requests);
        assert!(r.peak_gpu_share <= 1.0 + 1e-6);
        // The co-resident window billed cross-job interference to someone.
        assert!(train.xjob_interference_s + serve.xjob_interference_s > 0.0);
    }

    #[test]
    fn non_preemptive_mode_queues_instead_of_preempting() {
        let (topo, b, cost) = setup();
        let trace = generate_trace(&TrafficPattern::Constant { rate: 2000.0 }, 0.1, 3, 4);
        let jobs = vec![
            JobSpec::training(0, "train", 1, 0.0, 1, 0.9, 0.2, 512, 4),
            JobSpec::serving(1, "serve", 9, 0.0, (1, 1, 1), 0.5, 16, 50e-3, trace),
        ];
        let cfg = SchedConfig { preemptive: false, quantum_s: 0.05, ..Default::default() };
        let r = run_cluster(&topo, &b, &cost, &jobs, &cfg).unwrap();
        // Serving outranks training and admits first; the trainer queues
        // behind it until the fleet releases its share.
        let train = r.job(0).unwrap();
        assert!(train.wait_s > 0.0, "low-priority trainer should have queued");
        assert_eq!(train.preemptions, 0);
        assert!(r.events.iter().any(|e| e.action == SchedAction::Queue && e.job == 0));
        assert!(r.events.iter().all(|e| e.action != SchedAction::Preempt));
        assert!(r.peak_gpu_share <= 1.0 + 1e-6);
    }

    #[test]
    fn async_and_closed_tenants_run_to_completion() {
        // The new workload kinds the Workload refactor unlocked: an A3C
        // tenant (agents + trainers over the channel pipeline) and a
        // closed-loop serving tenant co-run with nothing special-cased in
        // the scheduler.
        let b = static_registry()["AY"].clone();
        let cost = CostModel::new(&b);
        let topo = Topology::dgx_a100(2);
        let jobs = vec![
            JobSpec::a3c(
                0,
                "a3c",
                5,
                0.0,
                (1, 1),
                0.4,
                0.1,
                1024,
                AsyncConfig { rounds: 4, batch_samples: 4096, ..Default::default() },
            ),
            JobSpec::closed(1, "collect", 1, 0.0, 2, 0.3, 0.1, 512, 4),
        ];
        let r = run_cluster(&topo, &b, &cost, &jobs, &SchedConfig::default()).unwrap();
        let a = r.job(0).unwrap();
        assert_eq!(a.kind, "async");
        assert!(a.metrics.pps > 0.0, "agents never predicted");
        assert!(a.metrics.ttop > 0.0, "trainers never consumed a batch");
        assert_eq!(a.gmis_at_completion, 2);
        let c = r.job(1).unwrap();
        assert_eq!(c.kind, "closed");
        assert!(c.metrics.steps_per_sec > 0.0);
        assert!(r.peak_gpu_share <= 1.0 + 1e-6);
        assert!(r.fairness > 0.0 && r.fairness <= 1.0 + 1e-12);
    }

    #[test]
    fn replay_tenant_runs_to_completion_in_the_cluster() {
        let b = static_registry()["AY"].clone();
        let cost = CostModel::new(&b);
        let topo = Topology::dgx_a100(2);
        let cfg = crate::workload::ReplayConfig {
            rounds: 3,
            push_samples: 2048,
            batch_samples: 1024,
            buffer_gib: 0.5,
            ..Default::default()
        };
        let jobs = vec![JobSpec::replay(0, "replay", 5, 0.0, 2, 0.4, 0.1, 1024, cfg)];
        let r = run_cluster(&topo, &b, &cost, &jobs, &SchedConfig::default()).unwrap();
        let j = r.job(0).unwrap();
        assert_eq!(j.kind, "replay");
        assert_eq!(j.gmis_at_completion, 3, "2 collectors + 1 learner");
        let stats = j.metrics.replay.as_ref().expect("replay tenant reports buffer stats");
        assert!(stats.transitions_in > 0, "collectors never filled the buffer");
        assert!(stats.updates > 0, "learner never consumed a batch");
        assert!(r.peak_gpu_share <= 1.0 + 1e-6);
    }

    #[test]
    fn league_tenant_spawns_matches_through_admission() {
        let b = static_registry()["AY"].clone();
        let cost = CostModel::new(&b);
        let topo = Topology::dgx_a100(1);
        let cfg = crate::workload::LeagueConfig {
            players: 4,
            total_matches: 6,
            max_concurrent: 2,
            match_rounds: 2,
            match_num_env: 256,
            match_share: 0.2,
            match_priority: 3,
            seed: 7,
        };
        let jobs = vec![JobSpec::league(0, "league", 5, 0.0, 0.2, cfg)];
        let run = || run_cluster(&topo, &b, &cost, &jobs, &SchedConfig::default()).unwrap();
        let r = run();
        // Coordinator first (input order), then one report per match.
        assert_eq!(r.jobs.len(), 7, "coordinator + 6 spawned matches");
        let coord = r.job(0).unwrap();
        assert_eq!(coord.kind, "league");
        assert!(coord.metrics.final_reward > 0.0, "no player ever won a match");
        assert_eq!(
            r.events.iter().filter(|e| e.action == SchedAction::Spawn).count(),
            6,
            "every match spawns exactly once"
        );
        for j in r.jobs.iter().skip(1) {
            // Children are ordinary closed-loop tenants that went through
            // the normal admission path and ran to completion.
            assert_eq!(j.kind, "closed");
            assert!(j.metrics.steps_per_sec > 0.0);
            assert!(j.id > 0, "children get fresh cluster-assigned ids");
            assert!(r
                .events
                .iter()
                .any(|e| e.job == j.id && e.action == SchedAction::Admit));
        }
        // The dynamic-spawn timeline is bit-identical run to run.
        let r2 = run();
        assert_eq!(r.events, r2.events);
        assert_eq!(
            r.job(0).unwrap().metrics.final_reward.to_bits(),
            r2.job(0).unwrap().metrics.final_reward.to_bits()
        );
    }

    #[test]
    fn rejects_bad_configs() {
        let (topo, b, cost) = setup();
        let ok = JobSpec::training(0, "t", 1, 0.0, 1, 0.5, 0.2, 256, 2);
        assert!(run_cluster(&topo, &b, &cost, &[], &SchedConfig::default()).is_err());
        let dup = vec![ok.clone(), ok.clone()];
        assert!(run_cluster(&topo, &b, &cost, &dup, &SchedConfig::default()).is_err());
        let bad_q = SchedConfig { quantum_s: 0.0, ..Default::default() };
        assert!(run_cluster(&topo, &b, &cost, &[ok], &bad_q).is_err());
    }
}
