//! GMI backends: MPS, MIG, Direct-Share — Table 1 of the paper.

/// A MIG profile on A100 (paper Fig 3): `Ng.Mgb` = N of 7 usable compute
/// slices, M GiB of memory.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MigProfile {
    pub name: &'static str,
    pub compute_slices: usize,
    pub mem_gib: f64,
}

/// The A100 MIG profile table. The 8th compute slice is reserved by the
/// hardware (grey boxes in the paper's Fig 3), so shares are out of 7.
pub const MIG_PROFILES: [MigProfile; 5] = [
    MigProfile { name: "1g.5gb", compute_slices: 1, mem_gib: 5.0 },
    MigProfile { name: "2g.10gb", compute_slices: 2, mem_gib: 10.0 },
    MigProfile { name: "3g.20gb", compute_slices: 3, mem_gib: 20.0 },
    MigProfile { name: "4g.20gb", compute_slices: 4, mem_gib: 20.0 },
    MigProfile { name: "7g.40gb", compute_slices: 7, mem_gib: 40.0 },
];

impl MigProfile {
    pub fn sm_share(&self) -> f64 {
        self.compute_slices as f64 / 7.0
    }

    /// Smallest profile whose compute share covers `share`, if any.
    pub fn covering(share: f64) -> Option<MigProfile> {
        MIG_PROFILES.iter().copied().find(|p| p.sm_share() + 1e-9 >= share)
    }
}

/// How a GMI is realized on the physical GPU (paper Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GmiBackend {
    /// CUDA Multi-Process Service: logical partition, SM % isolation, no
    /// memory QoS, intra-GPU inter-process communication possible.
    Mps,
    /// Multi-Instance GPU: physical partition, full isolation, memory QoS,
    /// NO communication between instances on the same GPU.
    Mig,
    /// No partitioning: processes time-share the whole GPU (Fig 8 baseline).
    DirectShare,
}

impl GmiBackend {
    /// Table 1, "Com." column: can two GMIs on the SAME GPU exchange data
    /// without bouncing through the host?
    pub fn intra_gpu_comm(&self) -> bool {
        match self {
            GmiBackend::Mps => true,
            GmiBackend::Mig => false,
            GmiBackend::DirectShare => true,
        }
    }

    /// Does the backend guarantee memory QoS (Table 1)?
    pub fn mem_qos(&self) -> bool {
        matches!(self, GmiBackend::Mig)
    }

    /// Quantize a requested SM share to what the backend can provision.
    /// MPS provisions by percentage (1% granularity), MIG snaps UP to the
    /// covering profile, Direct-Share has no notion of shares at all (every
    /// process sees the whole GPU and contends).
    pub fn quantize_share(&self, requested: f64) -> f64 {
        match self {
            GmiBackend::Mps => (requested * 100.0).ceil() / 100.0,
            GmiBackend::Mig => MigProfile::covering(requested)
                .map(|p| p.sm_share())
                .unwrap_or(1.0),
            GmiBackend::DirectShare => requested,
        }
    }

    /// Smallest SM share the backend can actually provision: MPS allocates
    /// whole percentage points, MIG's finest profile is 1g.5gb (1 of 7
    /// slices), and Direct-Share has no quantization floor at all (any
    /// positive request is "provisioned" as whole-GPU contention).
    pub fn min_quantized_share(&self) -> f64 {
        match self {
            GmiBackend::Mps => 0.01,
            GmiBackend::Mig => MIG_PROFILES[0].sm_share(),
            GmiBackend::DirectShare => f64::MIN_POSITIVE,
        }
    }

    /// Memory quota the backend enforces for a share-`s` GMI on a 40 GiB
    /// GPU; `None` = no quota (MPS / Direct-Share can oversubscribe and
    /// crash, which Alg 2's runnable check models).
    pub fn mem_quota_gib(&self, share: f64) -> Option<f64> {
        match self {
            GmiBackend::Mig => MigProfile::covering(share).map(|p| p.mem_gib),
            _ => None,
        }
    }

    /// Compute-interference multiplier (>= 1) when `co_resident` *other*
    /// GMIs share the GPU. `heaviness` in [0,1] is the workload's contention
    /// pressure (CostModel). Calibrated to Fig 8: Direct-Share loses
    /// 15-45%, MPS a few %, MIG nothing.
    pub fn interference(&self, co_resident: usize, heaviness: f64) -> f64 {
        if co_resident == 0 {
            return 1.0;
        }
        let k = co_resident as f64;
        match self {
            GmiBackend::Mig => 1.0,
            GmiBackend::Mps => 1.0 + 0.03 * heaviness * k.min(4.0),
            GmiBackend::DirectShare => 1.0 + (0.12 + 0.18 * heaviness) * k,
        }
    }

    /// The paper's backend-selection rule (§3): training needs inter-GMI
    /// communication -> MPS; serving is computation-only -> MIG; pre-Ampere
    /// GPUs (sm < 80) only have MPS.
    pub fn auto_select(for_training: bool, sm_arch: u32) -> GmiBackend {
        if sm_arch < 80 || for_training {
            GmiBackend::Mps
        } else {
            GmiBackend::Mig
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mig_profiles_match_a100_table() {
        assert_eq!(MIG_PROFILES.len(), 5);
        let p = MigProfile::covering(2.0 / 8.0).unwrap();
        assert_eq!(p.name, "2g.10gb");
        assert_eq!(MigProfile::covering(1.0).unwrap().name, "7g.40gb");
        assert_eq!(MigProfile::covering(0.1).unwrap().name, "1g.5gb");
        assert!(MigProfile::covering(1.1).is_none());
    }

    #[test]
    fn quantization() {
        assert!((GmiBackend::Mps.quantize_share(0.333) - 0.34).abs() < 1e-9);
        assert!((GmiBackend::Mig.quantize_share(0.25) - 2.0 / 7.0).abs() < 1e-9);
        assert_eq!(GmiBackend::DirectShare.quantize_share(0.4), 0.4);
    }

    #[test]
    fn min_quantized_share_is_the_provisioning_floor() {
        assert!((GmiBackend::Mps.min_quantized_share() - 0.01).abs() < 1e-12);
        assert!((GmiBackend::Mig.min_quantized_share() - 1.0 / 7.0).abs() < 1e-12);
        // Direct-Share never quantizes: the floor is effectively zero but
        // still positive, so clamping to it cannot zero a share out.
        let ds = GmiBackend::DirectShare.min_quantized_share();
        assert!(ds > 0.0 && ds < 1e-100);
        // The floor is a fixed point of quantization for every backend.
        for be in [GmiBackend::Mps, GmiBackend::Mig, GmiBackend::DirectShare] {
            let f = be.min_quantized_share();
            assert!((be.quantize_share(f) - f).abs() < 1e-12, "{be:?}");
        }
    }

    #[test]
    fn comm_capability_table1() {
        assert!(GmiBackend::Mps.intra_gpu_comm());
        assert!(!GmiBackend::Mig.intra_gpu_comm());
        assert!(GmiBackend::Mig.mem_qos());
        assert!(!GmiBackend::Mps.mem_qos());
    }

    #[test]
    fn auto_selection_rule() {
        assert_eq!(GmiBackend::auto_select(true, 80), GmiBackend::Mps);
        assert_eq!(GmiBackend::auto_select(false, 80), GmiBackend::Mig);
        // V100: MPS regardless
        assert_eq!(GmiBackend::auto_select(false, 70), GmiBackend::Mps);
    }

    #[test]
    fn mig_mem_quota() {
        assert_eq!(GmiBackend::Mig.mem_quota_gib(0.25), Some(10.0));
        assert_eq!(GmiBackend::Mps.mem_quota_gib(0.25), None);
    }
}
