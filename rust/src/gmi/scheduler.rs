//! GMI-aware cluster scheduling (paper §8, "For cluster scheduling"):
//! condensing fragmented GPU jobs into fewer GPUs via spatial multiplexing.
//!
//! Existing schedulers (Gandiva/AntMan-style) place one job per GPU even
//! when jobs underutilize it. With GMIs, a job's profiled (SM, memory)
//! demand becomes a packing item; best-fit-decreasing packing recycles the
//! spare capacity and frees whole GPUs for jobs with GPU-affinity demands.

use anyhow::{bail, Result};

use super::GmiBackend;
use crate::cluster::Topology;

/// One GPU job with its profiled resource demand (fractions of one GPU).
#[derive(Debug, Clone)]
pub struct Job {
    pub id: usize,
    /// SM demand in (0, 1] — e.g. from Algorithm 2's saturation profile.
    pub sm_demand: f64,
    /// Memory demand in GiB.
    pub mem_gib: f64,
}

/// Placement of one job as a GMI on a GPU.
#[derive(Debug, Clone, PartialEq)]
pub struct Placement {
    pub job: usize,
    pub gpu: usize,
    /// Provisioned SM share after backend quantization.
    pub sm_share: f64,
}

/// Result of a scheduling round.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub placements: Vec<Placement>,
    pub gpus_used: usize,
    /// Mean provisioned SM share across used GPUs (packing quality).
    pub mean_gpu_load: f64,
}

/// Pack jobs onto the topology with best-fit-decreasing on SM demand.
/// `backend` controls share quantization (MIG snaps to profiles).
pub fn pack_jobs(topo: &Topology, jobs: &[Job], backend: GmiBackend) -> Result<Schedule> {
    let n_gpus = topo.num_gpus();
    let mut order: Vec<&Job> = jobs.iter().collect();
    order.sort_by(|a, b| b.sm_demand.partial_cmp(&a.sm_demand).unwrap());

    let mut sm_left = vec![1.0f64; n_gpus];
    let mut mem_left: Vec<f64> = topo.gpus.iter().map(|g| g.mem_gib).collect();
    let mut placements = Vec::with_capacity(jobs.len());

    for job in order {
        if job.sm_demand <= 0.0 || job.sm_demand > 1.0 {
            bail!("job {}: invalid SM demand {}", job.id, job.sm_demand);
        }
        let share = backend.quantize_share(job.sm_demand).min(1.0);
        let mem = backend
            .mem_quota_gib(share)
            .map(|q| q.max(job.mem_gib))
            .unwrap_or(job.mem_gib);
        // Best fit: the used GPU with the least leftover that still fits;
        // fall back to a fresh GPU.
        let mut best: Option<(usize, f64)> = None;
        for gpu in 0..n_gpus {
            if sm_left[gpu] + 1e-9 >= share && mem_left[gpu] + 1e-9 >= mem {
                let leftover = sm_left[gpu] - share;
                if best.map(|(_, l)| leftover < l).unwrap_or(true) {
                    best = Some((gpu, leftover));
                }
            }
        }
        let Some((gpu, _)) = best else {
            bail!("job {} ({}x SM, {} GiB) does not fit the cluster", job.id, share, mem);
        };
        sm_left[gpu] -= share;
        mem_left[gpu] -= mem;
        placements.push(Placement { job: job.id, gpu, sm_share: share });
    }

    let gpus_used = {
        let mut used: Vec<usize> = placements.iter().map(|p| p.gpu).collect();
        used.sort_unstable();
        used.dedup();
        used.len()
    };
    let mean_gpu_load = if gpus_used == 0 {
        0.0
    } else {
        placements.iter().map(|p| p.sm_share).sum::<f64>() / gpus_used as f64
    };
    Ok(Schedule { placements, gpus_used, mean_gpu_load })
}

/// The incumbent baseline: one exclusive GPU per job.
pub fn one_job_per_gpu(topo: &Topology, jobs: &[Job]) -> Result<Schedule> {
    if jobs.len() > topo.num_gpus() {
        bail!("{} jobs need {} exclusive GPUs, have {}", jobs.len(), jobs.len(), topo.num_gpus());
    }
    let placements: Vec<Placement> = jobs
        .iter()
        .enumerate()
        .map(|(gpu, j)| Placement { job: j.id, gpu, sm_share: 1.0 })
        .collect();
    Ok(Schedule {
        gpus_used: placements.len(),
        mean_gpu_load: jobs.iter().map(|j| j.sm_demand).sum::<f64>() / jobs.len().max(1) as f64,
        placements,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jobs(demands: &[(f64, f64)]) -> Vec<Job> {
        demands
            .iter()
            .enumerate()
            .map(|(id, &(sm, mem))| Job { id, sm_demand: sm, mem_gib: mem })
            .collect()
    }

    #[test]
    fn condenses_fragmented_jobs() {
        // Six 30%-jobs: baseline burns 6 GPUs; GMI packing needs 2.
        let topo = Topology::dgx_a100(8);
        let js = jobs(&[(0.3, 8.0); 6]);
        let base = one_job_per_gpu(&topo, &js).unwrap();
        let packed = pack_jobs(&topo, &js, GmiBackend::Mps).unwrap();
        assert_eq!(base.gpus_used, 6);
        assert!(packed.gpus_used <= 2, "packed onto {} GPUs", packed.gpus_used);
        assert!(packed.mean_gpu_load > base.mean_gpu_load);
    }

    #[test]
    fn respects_memory_limits() {
        // SM would fit 4 per GPU, but memory only 2 (18 GiB each on 40).
        let topo = Topology::dgx_a100(8);
        let js = jobs(&[(0.2, 18.0); 4]);
        let s = pack_jobs(&topo, &js, GmiBackend::Mps).unwrap();
        assert_eq!(s.gpus_used, 2);
        for gpu in 0..2 {
            let mem: f64 = s
                .placements
                .iter()
                .filter(|p| p.gpu == gpu)
                .map(|_| 18.0)
                .sum();
            assert!(mem <= 40.0);
        }
    }

    #[test]
    fn mig_quantization_changes_packing() {
        // 0.3 SM snaps to 3/7 under MIG -> only 2 fit per GPU (6/7).
        let topo = Topology::dgx_a100(8);
        let js = jobs(&[(0.3, 4.0); 6]);
        let mps = pack_jobs(&topo, &js, GmiBackend::Mps).unwrap();
        let mig = pack_jobs(&topo, &js, GmiBackend::Mig).unwrap();
        assert!(mig.gpus_used >= mps.gpus_used);
        assert!(mig.placements.iter().all(|p| (p.sm_share - 3.0 / 7.0).abs() < 1e-9));
    }

    #[test]
    fn rejects_unsatisfiable() {
        let topo = Topology::dgx_a100(1);
        // 2 full-GPU jobs on 1 GPU
        assert!(pack_jobs(&topo, &jobs(&[(1.0, 10.0), (1.0, 10.0)]), GmiBackend::Mps).is_err());
        // baseline can't host 3 jobs on 2 GPUs
        let topo2 = Topology::dgx_a100(2);
        assert!(one_job_per_gpu(&topo2, &jobs(&[(0.1, 1.0); 3])).is_err());
        // invalid demand
        assert!(pack_jobs(&topo, &jobs(&[(1.5, 1.0)]), GmiBackend::Mps).is_err());
    }

    #[test]
    fn best_fit_prefers_tightest_gpu() {
        let topo = Topology::dgx_a100(3);
        // Seed: 0.7 on gpu A, 0.5 on gpu B (descending order packs these
        // first onto separate GPUs), then a 0.3 job must choose the 0.7 GPU
        // (leftover 0.0) over the 0.5 GPU (leftover 0.2).
        let js = jobs(&[(0.7, 4.0), (0.5, 4.0), (0.3, 4.0)]);
        let s = pack_jobs(&topo, &js, GmiBackend::Mps).unwrap();
        let p07 = s.placements.iter().find(|p| p.job == 0).unwrap().gpu;
        let p03 = s.placements.iter().find(|p| p.job == 2).unwrap().gpu;
        assert_eq!(p07, p03, "0.3 job should co-locate with the 0.7 job");
    }
}
