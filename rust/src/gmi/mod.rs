//! The GPU Multiplexing Instance (GMI) abstraction — the paper's §3.
//!
//! A GMI is a resource-adjustable sub-GPU: a slice of one physical GPU's
//! SMs and memory, realized by one of three backends:
//!
//! * **MPS** — logical partition by SM percentage; no memory QoS, weak SM
//!   isolation (interference under load), but inter-GMI communication is
//!   possible (the paper picks MPS for *training*).
//! * **MIG** — physical partition following the A100 profile table
//!   (1g.5gb … 7g.40gb, one slice reserved); full isolation, memory QoS,
//!   but **no** inter-instance communication on the same GPU (picked for
//!   *serving*).
//! * **DirectShare** — plain process co-scheduling with no partitioning at
//!   all; the Fig 8 baseline.

mod backend;
mod manager;
pub mod scheduler;

pub use backend::{GmiBackend, MigProfile, MIG_PROFILES};
pub use manager::{GmiGroup, GmiManager, RemoveGmiError};
pub use scheduler::{one_job_per_gpu, pack_jobs, Job, Placement, Schedule};

use crate::vtime::CostModel;

/// Globally unique GMI identifier (the paper's `GMI_id`).
pub type GmiId = usize;

/// The DRL role(s) hosted by a GMI (paper §3: `DRL_role`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Role {
    /// Environment simulator + agent co-located (serving block, TCG).
    SimAgent,
    /// Dedicated trainer (TDG_EX / async training GMIs).
    Trainer,
    /// Simulator + agent + trainer (holistic training GMI, TCG_EX).
    Holistic,
    /// Dedicated simulator (TDG exploration only; the paper rejects this).
    Simulator,
    /// Dedicated agent (TDG exploration only).
    Agent,
}

impl Role {
    pub fn has_sim(&self) -> bool {
        matches!(self, Role::SimAgent | Role::Holistic | Role::Simulator)
    }

    pub fn has_agent(&self) -> bool {
        matches!(self, Role::SimAgent | Role::Holistic | Role::Agent)
    }

    pub fn has_trainer(&self) -> bool {
        matches!(self, Role::Trainer | Role::Holistic)
    }
}

/// Static description of one GMI: where it lives and what it gets.
#[derive(Debug, Clone)]
pub struct GmiSpec {
    pub id: GmiId,
    pub gpu: usize,
    /// SM share in (0, 1]; for MIG this is quantized to a profile.
    pub sm_share: f64,
    /// Memory budget in GiB.
    pub mem_gib: f64,
    pub backend: GmiBackend,
    pub role: Role,
    /// Environments simulated by this GMI (0 for pure trainers).
    pub num_env: usize,
}

impl GmiSpec {
    /// Interference multiplier (>= 1) applied to compute on this GMI when
    /// `co_resident` other GMIs share the GPU. Backend isolation quality is
    /// the Fig 8 mechanism: MIG (hardware) < MPS (logical) < DirectShare.
    pub fn interference(&self, co_resident: usize, cost: &CostModel) -> f64 {
        self.backend.interference(co_resident, cost.heaviness)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::static_registry;

    #[test]
    fn role_capabilities() {
        assert!(Role::SimAgent.has_sim() && Role::SimAgent.has_agent());
        assert!(!Role::SimAgent.has_trainer());
        assert!(Role::Holistic.has_sim() && Role::Holistic.has_trainer());
        assert!(Role::Trainer.has_trainer() && !Role::Trainer.has_sim());
    }

    #[test]
    fn interference_ordering_matches_fig8() {
        let cost = CostModel::new(&static_registry()["HM"]);
        let spec = |backend| GmiSpec {
            id: 0,
            gpu: 0,
            sm_share: 0.5,
            mem_gib: 20.0,
            backend,
            role: Role::SimAgent,
            num_env: 1024,
        };
        let mig = spec(GmiBackend::Mig).interference(1, &cost);
        let mps = spec(GmiBackend::Mps).interference(1, &cost);
        let ds = spec(GmiBackend::DirectShare).interference(1, &cost);
        assert!(mig <= mps && mps < ds, "mig {mig} mps {mps} ds {ds}");
        assert_eq!(spec(GmiBackend::Mig).interference(0, &cost), 1.0);
    }
}
