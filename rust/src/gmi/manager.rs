//! The global GMI manager (paper §3, Listing 1): registration, GPU
//! attachment, communication groups, and resource validation.
//!
//! For multi-tenant clusters ([`sched`](crate::sched)) the manager also
//! tracks which *job* owns each GMI ([`GmiManager::tag_job`]) and a
//! per-job aggregate SM-share floor ([`GmiManager::set_job_floor`]):
//! [`GmiManager::remove_gmi`] rejects a removal that would strand a job
//! below its floor with a typed [`RemoveGmiError`], so preemption can
//! never evict a tenant past its guaranteed minimum.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{bail, Result};

use super::{GmiBackend, GmiId, GmiSpec, Role};
use crate::cluster::Topology;

/// Why a [`GmiManager::remove_gmi`] call was rejected.
#[derive(Debug, Clone, PartialEq)]
pub enum RemoveGmiError {
    /// The GMI id is not registered.
    NotRegistered(GmiId),
    /// Removing the GMI would drop its job's aggregate SM share below the
    /// floor registered via [`GmiManager::set_job_floor`].
    BelowJobFloor {
        gmi: GmiId,
        job: usize,
        /// The job's aggregate SM share after the removal would apply.
        share_after: f64,
        /// The registered minimum aggregate share.
        floor: f64,
    },
}

impl fmt::Display for RemoveGmiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RemoveGmiError::NotRegistered(id) => write!(f, "GMI {id} not registered"),
            RemoveGmiError::BelowJobFloor { gmi, job, share_after, floor } => write!(
                f,
                "removing GMI {gmi} would drop job {job} to {share_after:.2} \
                 aggregate SM share, below its {floor:.2} floor"
            ),
        }
    }
}

impl std::error::Error for RemoveGmiError {}

/// A communication group of GMIs (the paper's `get_group`): the unit over
/// which collectives (gradient reduction) run.
#[derive(Debug, Clone, Default)]
pub struct GmiGroup {
    pub members: Vec<GmiId>,
}

/// The global registry every `DRL_role.__init__` registers with
/// (`GMI_DRL.GMI_manager.add_GMI`). GMIs are *resource-adjustable*: besides
/// registration, the manager supports mid-run [`resize_gmi`] and
/// [`remove_gmi`] with the same placement validation — the substrate the
/// engine's elastic re-provisioning builds on.
///
/// [`resize_gmi`]: GmiManager::resize_gmi
/// [`remove_gmi`]: GmiManager::remove_gmi
#[derive(Debug, Clone)]
pub struct GmiManager {
    topology: Topology,
    gmis: BTreeMap<GmiId, GmiSpec>,
    groups: BTreeMap<String, GmiGroup>,
    /// Multi-tenant ownership: GMI -> job id (empty for single-tenant runs).
    job_tags: BTreeMap<GmiId, usize>,
    /// Per-job minimum aggregate SM share guarded by [`Self::remove_gmi`].
    job_floors: BTreeMap<usize, f64>,
}

impl GmiManager {
    pub fn new(topology: Topology) -> Self {
        GmiManager {
            topology,
            gmis: BTreeMap::new(),
            groups: BTreeMap::new(),
            job_tags: BTreeMap::new(),
            job_floors: BTreeMap::new(),
        }
    }

    pub fn topology(&self) -> &Topology {
        &self.topology
    }

    /// Validate a placement against everything else on its GPU: GPU exists,
    /// backend supported by the architecture, SM shares on the GPU don't
    /// exceed capacity, MIG memory quota respected. `exclude` names a GMI
    /// whose current provisioning is ignored (resize re-validates a GMI
    /// against its *peers*, not its own old shape).
    fn validate_placement(&self, spec: &GmiSpec, exclude: Option<GmiId>) -> Result<()> {
        let Some(gpu) = self.topology.gpus.get(spec.gpu) else {
            bail!("GMI {}: GPU {} not in topology", spec.id, spec.gpu);
        };
        if spec.backend == GmiBackend::Mig && !gpu.supports_mig() {
            bail!("GMI {}: MIG unsupported on sm_{} GPU {}", spec.id, gpu.sm_arch, spec.gpu);
        }
        if spec.sm_share <= 0.0 || spec.sm_share > 1.0 {
            bail!("GMI {}: invalid SM share {}", spec.id, spec.sm_share);
        }
        let peers = || {
            self.gmis
                .values()
                .filter(|g| g.gpu == spec.gpu && exclude != Some(g.id))
        };
        // Direct-Share doesn't partition, so shares don't sum-constrain.
        if spec.backend != GmiBackend::DirectShare {
            let used: f64 = peers().map(|g| g.sm_share).sum();
            if used + spec.sm_share > 1.0 + 1e-9 {
                bail!(
                    "GMI {}: GPU {} SM oversubscribed ({:.2} + {:.2} > 1)",
                    spec.id,
                    spec.gpu,
                    used,
                    spec.sm_share
                );
            }
        }
        if let Some(quota) = spec.backend.mem_quota_gib(spec.sm_share) {
            if spec.mem_gib > quota + 1e-9 {
                bail!(
                    "GMI {}: MIG profile allows {quota} GiB, asked {}",
                    spec.id,
                    spec.mem_gib
                );
            }
        }
        let mem_used: f64 = peers().map(|g| g.mem_gib).sum();
        if mem_used + spec.mem_gib > gpu.mem_gib + 1e-9 {
            bail!(
                "GMI {}: GPU {} memory oversubscribed ({:.1} + {:.1} > {} GiB)",
                spec.id,
                spec.gpu,
                mem_used,
                spec.mem_gib,
                gpu.mem_gib
            );
        }
        Ok(())
    }

    /// Register a GMI and attach it to its GPU (`set_GPU`), after full
    /// placement validation ([`Self::validate_placement`]).
    pub fn add_gmi(&mut self, spec: GmiSpec) -> Result<GmiId> {
        if self.gmis.contains_key(&spec.id) {
            bail!("GMI {} already registered", spec.id);
        }
        self.validate_placement(&spec, None)?;
        let id = spec.id;
        self.gmis.insert(id, spec);
        Ok(id)
    }

    /// Re-provision an existing GMI to `(sm_share, mem_gib)`, re-running
    /// the same placement validation as registration — the paper's
    /// "resource-adjustable instance" property. On error the GMI keeps its
    /// current provisioning.
    pub fn resize_gmi(&mut self, id: GmiId, sm_share: f64, mem_gib: f64) -> Result<()> {
        let Some(cur) = self.gmis.get(&id) else {
            bail!("GMI {id} not registered");
        };
        let mut cand = cur.clone();
        cand.sm_share = sm_share;
        cand.mem_gib = mem_gib;
        self.validate_placement(&cand, Some(id))?;
        self.gmis.insert(id, cand);
        Ok(())
    }

    /// Deregister a GMI, freeing its SM share and memory for co-residents
    /// and dropping it from every communication group and its job tag.
    /// Returns the removed spec.
    ///
    /// When the GMI belongs to a job with a registered floor
    /// ([`Self::set_job_floor`]), a removal that would drop the job's
    /// aggregate SM share below that floor is rejected with
    /// [`RemoveGmiError::BelowJobFloor`] — preemption can shrink a tenant
    /// to its guaranteed minimum but never past it.
    pub fn remove_gmi(&mut self, id: GmiId) -> Result<GmiSpec, RemoveGmiError> {
        let Some(spec) = self.gmis.get(&id) else {
            return Err(RemoveGmiError::NotRegistered(id));
        };
        if let Some(&job) = self.job_tags.get(&id) {
            if let Some(&floor) = self.job_floors.get(&job) {
                let share_after = self.job_share(job) - spec.sm_share;
                if share_after + 1e-9 < floor {
                    return Err(RemoveGmiError::BelowJobFloor { gmi: id, job, share_after, floor });
                }
            }
        }
        let spec = self.gmis.remove(&id).expect("presence checked above");
        self.job_tags.remove(&id);
        for group in self.groups.values_mut() {
            group.members.retain(|&m| m != id);
        }
        Ok(spec)
    }

    // ---- multi-tenant job ownership ----

    /// Tag a registered GMI as owned by `job` (multi-tenant bookkeeping;
    /// feeds [`Self::remove_gmi`]'s floor guard and the engine's cross-job
    /// interference attribution).
    pub fn tag_job(&mut self, id: GmiId, job: usize) -> Result<()> {
        if !self.gmis.contains_key(&id) {
            bail!("GMI {id} not registered");
        }
        self.job_tags.insert(id, job);
        Ok(())
    }

    /// Register (or update) a job's minimum aggregate SM share. Removals
    /// that would drop the job's tagged GMIs below it are rejected.
    pub fn set_job_floor(&mut self, job: usize, min_total_share: f64) {
        self.job_floors.insert(job, min_total_share);
    }

    /// Drop a job's floor and every tag pointing at it (its GMIs stay
    /// registered) — the release path when a tenant completes.
    pub fn clear_job(&mut self, job: usize) {
        self.job_floors.remove(&job);
        self.job_tags.retain(|_, &mut j| j != job);
    }

    /// The job a GMI is tagged to, if any.
    pub fn job_of(&self, id: GmiId) -> Option<usize> {
        self.job_tags.get(&id).copied()
    }

    /// Aggregate SM share currently held by `job`'s tagged GMIs.
    pub fn job_share(&self, job: usize) -> f64 {
        self.job_tags
            .iter()
            .filter(|&(_, &j)| j == job)
            .filter_map(|(&id, _)| self.gmis.get(&id))
            .map(|g| g.sm_share)
            .sum()
    }

    /// Registered GMIs tagged to `job`, ascending by id.
    pub fn job_members(&self, job: usize) -> Vec<GmiId> {
        self.job_tags
            .iter()
            .filter(|&(_, &j)| j == job)
            .map(|(&id, _)| id)
            .filter(|id| self.gmis.contains_key(id))
            .collect()
    }

    pub fn gmi(&self, id: GmiId) -> Option<&GmiSpec> {
        self.gmis.get(&id)
    }

    pub fn all(&self) -> impl Iterator<Item = &GmiSpec> {
        self.gmis.values()
    }

    pub fn len(&self) -> usize {
        self.gmis.len()
    }

    pub fn is_empty(&self) -> bool {
        self.gmis.is_empty()
    }

    /// GMIs co-resident on the same GPU as `id` (excluding itself).
    pub fn co_resident(&self, id: GmiId) -> usize {
        let Some(g) = self.gmis.get(&id) else { return 0 };
        self.gmis.values().filter(|o| o.gpu == g.gpu && o.id != id).count()
    }

    /// The GMI-to-GPU mapping list `MPL` of Algorithm 1: one inner list of
    /// GMI ids per GPU, only for GMIs matching `role_filter`.
    pub fn mapping_list(&self, role_filter: impl Fn(Role) -> bool) -> Vec<Vec<GmiId>> {
        let mut per_gpu: BTreeMap<usize, Vec<GmiId>> = BTreeMap::new();
        for g in self.gmis.values() {
            if role_filter(g.role) {
                per_gpu.entry(g.gpu).or_default().push(g.id);
            }
        }
        per_gpu.into_values().collect()
    }

    /// Create or extend a named communication group.
    pub fn join_group(&mut self, name: &str, id: GmiId) -> Result<()> {
        if !self.gmis.contains_key(&id) {
            bail!("GMI {id} not registered");
        }
        let group = self.groups.entry(name.to_string()).or_default();
        if !group.members.contains(&id) {
            group.members.push(id);
        }
        Ok(())
    }

    pub fn group(&self, name: &str) -> Option<&GmiGroup> {
        self.groups.get(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;

    fn spec(id: GmiId, gpu: usize, share: f64, backend: GmiBackend) -> GmiSpec {
        GmiSpec {
            id,
            gpu,
            sm_share: share,
            mem_gib: 5.0,
            backend,
            role: Role::Holistic,
            num_env: 512,
        }
    }

    #[test]
    fn register_and_group() {
        let mut m = GmiManager::new(Topology::dgx_a100(2));
        m.add_gmi(spec(0, 0, 0.5, GmiBackend::Mps)).unwrap();
        m.add_gmi(spec(1, 0, 0.5, GmiBackend::Mps)).unwrap();
        m.add_gmi(spec(2, 1, 0.5, GmiBackend::Mps)).unwrap();
        assert_eq!(m.len(), 3);
        assert_eq!(m.co_resident(0), 1);
        assert_eq!(m.co_resident(2), 0);
        m.join_group("trainers", 0).unwrap();
        m.join_group("trainers", 2).unwrap();
        m.join_group("trainers", 0).unwrap(); // idempotent
        assert_eq!(m.group("trainers").unwrap().members, vec![0, 2]);
        assert!(m.join_group("x", 99).is_err());
    }

    #[test]
    fn rejects_oversubscription() {
        let mut m = GmiManager::new(Topology::dgx_a100(1));
        m.add_gmi(spec(0, 0, 0.6, GmiBackend::Mps)).unwrap();
        assert!(m.add_gmi(spec(1, 0, 0.6, GmiBackend::Mps)).is_err());
        // Direct-Share is exempt from share sums.
        let mut m = GmiManager::new(Topology::dgx_a100(1));
        m.add_gmi(spec(0, 0, 1.0, GmiBackend::DirectShare)).unwrap();
        m.add_gmi(spec(1, 0, 1.0, GmiBackend::DirectShare)).unwrap();
    }

    #[test]
    fn rejects_duplicate_and_bad_gpu() {
        let mut m = GmiManager::new(Topology::dgx_a100(1));
        m.add_gmi(spec(0, 0, 0.3, GmiBackend::Mps)).unwrap();
        assert!(m.add_gmi(spec(0, 0, 0.3, GmiBackend::Mps)).is_err());
        assert!(m.add_gmi(spec(1, 5, 0.3, GmiBackend::Mps)).is_err());
    }

    #[test]
    fn rejects_mig_on_v100() {
        let mut m = GmiManager::new(Topology::v100_box(1));
        assert!(m.add_gmi(spec(0, 0, 0.3, GmiBackend::Mig)).is_err());
        m.add_gmi(spec(1, 0, 0.3, GmiBackend::Mps)).unwrap();
    }

    #[test]
    fn mapping_list_shape() {
        let mut m = GmiManager::new(Topology::dgx_a100(2));
        for (i, gpu) in [(0, 0), (1, 0), (2, 1), (3, 1)] {
            m.add_gmi(spec(i, gpu, 0.4, GmiBackend::Mps)).unwrap();
        }
        let mpl = m.mapping_list(|r| r.has_trainer());
        assert_eq!(mpl, vec![vec![0, 1], vec![2, 3]]);
        let none = m.mapping_list(|r| matches!(r, Role::Agent));
        assert!(none.is_empty());
    }

    #[test]
    fn resize_revalidates_against_peers() {
        let mut m = GmiManager::new(Topology::dgx_a100(1));
        m.add_gmi(spec(0, 0, 0.5, GmiBackend::Mps)).unwrap();
        m.add_gmi(spec(1, 0, 0.4, GmiBackend::Mps)).unwrap();
        // Growing into free capacity is fine; the spec is updated.
        m.resize_gmi(0, 0.6, 5.0).unwrap();
        assert_eq!(m.gmi(0).unwrap().sm_share, 0.6);
        // Growing past the peer's reservation is rejected and leaves the
        // current provisioning untouched.
        assert!(m.resize_gmi(0, 0.7, 5.0).is_err());
        assert_eq!(m.gmi(0).unwrap().sm_share, 0.6);
        // Invalid shares and unknown GMIs are rejected.
        assert!(m.resize_gmi(0, 0.0, 5.0).is_err());
        assert!(m.resize_gmi(0, 1.5, 5.0).is_err());
        assert!(m.resize_gmi(7, 0.1, 1.0).is_err());
    }

    #[test]
    fn resize_respects_mig_quota_and_memory() {
        let mut m = GmiManager::new(Topology::dgx_a100(1));
        m.add_gmi(spec(0, 0, 2.0 / 7.0, GmiBackend::Mig)).unwrap();
        // 2g.10gb allows 10 GiB; asking for 12 without more slices fails.
        assert!(m.resize_gmi(0, 2.0 / 7.0, 12.0).is_err());
        // Growing to 3g.20gb makes the same memory legal.
        m.resize_gmi(0, 3.0 / 7.0, 12.0).unwrap();
        assert_eq!(m.gmi(0).unwrap().mem_gib, 12.0);

        // GPU-level memory oversubscription via resize is rejected too.
        let mut m2 = GmiManager::new(Topology::dgx_a100(1));
        let mut a = spec(0, 0, 0.4, GmiBackend::Mps);
        a.mem_gib = 30.0;
        m2.add_gmi(a).unwrap();
        m2.add_gmi(spec(1, 0, 0.4, GmiBackend::Mps)).unwrap();
        assert!(m2.resize_gmi(1, 0.4, 15.0).is_err());
        m2.resize_gmi(1, 0.4, 9.0).unwrap();
    }

    #[test]
    fn remove_frees_capacity_and_groups() {
        let mut m = GmiManager::new(Topology::dgx_a100(1));
        m.add_gmi(spec(0, 0, 0.6, GmiBackend::Mps)).unwrap();
        m.join_group("trainers", 0).unwrap();
        assert!(m.add_gmi(spec(1, 0, 0.6, GmiBackend::Mps)).is_err());
        let freed = m.remove_gmi(0).unwrap();
        assert_eq!(freed.sm_share, 0.6);
        assert!(m.group("trainers").unwrap().members.is_empty());
        // The freed capacity is immediately reusable.
        m.add_gmi(spec(1, 0, 0.6, GmiBackend::Mps)).unwrap();
        assert!(m.remove_gmi(42).is_err());
    }

    #[test]
    fn remove_below_job_floor_is_rejected_with_typed_error() {
        // Regression: removal used to succeed silently regardless of the
        // owning job's minimum; it must now return a typed error.
        let mut m = GmiManager::new(Topology::dgx_a100(1));
        m.add_gmi(spec(0, 0, 0.4, GmiBackend::Mps)).unwrap();
        m.add_gmi(spec(1, 0, 0.4, GmiBackend::Mps)).unwrap();
        m.tag_job(0, 7).unwrap();
        m.tag_job(1, 7).unwrap();
        m.set_job_floor(7, 0.6);
        assert!((m.job_share(7) - 0.8).abs() < 1e-9);
        assert_eq!(m.job_members(7), vec![0, 1]);
        assert_eq!(m.job_of(1), Some(7));
        // 0.8 - 0.4 = 0.4 < 0.6 floor: rejected, nothing removed.
        match m.remove_gmi(1) {
            Err(RemoveGmiError::BelowJobFloor { gmi, job, share_after, floor }) => {
                assert_eq!((gmi, job), (1, 7));
                assert!((share_after - 0.4).abs() < 1e-9);
                assert!((floor - 0.6).abs() < 1e-9);
            }
            other => panic!("expected BelowJobFloor, got {other:?}"),
        }
        assert_eq!(m.len(), 2);
        // Unknown ids keep their own typed error.
        assert!(matches!(m.remove_gmi(42), Err(RemoveGmiError::NotRegistered(42))));
        // Relaxing the floor (or clearing the job) makes removal legal,
        // and removal drops the tag.
        m.set_job_floor(7, 0.4);
        m.remove_gmi(1).unwrap();
        assert_eq!(m.job_of(1), None);
        assert!((m.job_share(7) - 0.4).abs() < 1e-9);
        // Now 0.4 - 0.4 = 0 < 0.4: the last member is protected...
        assert!(m.remove_gmi(0).is_err());
        // ...until the job releases its claim.
        m.clear_job(7);
        m.remove_gmi(0).unwrap();
        assert!(m.is_empty());
    }

    #[test]
    fn untagged_gmis_remove_freely() {
        // Floors only guard tagged members: single-tenant behavior intact.
        let mut m = GmiManager::new(Topology::dgx_a100(1));
        m.add_gmi(spec(0, 0, 0.4, GmiBackend::Mps)).unwrap();
        m.set_job_floor(7, 1.0);
        m.remove_gmi(0).unwrap();
        assert!(m.is_empty());
        assert!(m.tag_job(3, 7).is_err(), "tagging unknown GMIs is rejected");
    }

    #[test]
    fn rejects_memory_oversubscription() {
        let mut m = GmiManager::new(Topology::dgx_a100(1));
        let mut s = spec(0, 0, 0.5, GmiBackend::Mps);
        s.mem_gib = 30.0;
        m.add_gmi(s).unwrap();
        let mut s2 = spec(1, 0, 0.4, GmiBackend::Mps);
        s2.mem_gib = 15.0;
        assert!(m.add_gmi(s2).is_err());
    }
}
