//! Task-aware GMI mapping (paper §5.1): design templates mapping DRL tasks
//! onto GMIs, plus the analytical cost model of Tables 3-5 / Eqs. (1)-(3)
//! that justifies them.
//!
//! * serving: **TCG** (task-colocated: simulator+agent per GMI) vs **TDG**
//!   (task-dedicated GMIs); TCG wins ~2.5x (Table 4 / Eq. 2);
//! * sync training: **TCG_EX** (holistic training GMIs) vs **TDG_EX**;
//!   TCG_EX wins ~5x (Table 5 / Eq. 3);
//! * async training: decoupled serving GPUs + training GPUs (Fig 6b).

pub mod cost;
mod layout;

pub use cost::{MappingCost, TaskProfile};
pub use layout::{
    build_async_layout, build_gateway_fleet, build_serving_layout, build_sync_layout, Layout,
};

/// Template choice for serving / sync training (paper §5.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MappingTemplate {
    /// Task-colocated GMIs (the paper's choice).
    TaskColocated,
    /// Task-dedicated GMIs (the rejected alternative, kept as a baseline).
    TaskDedicated,
}

impl std::fmt::Display for MappingTemplate {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MappingTemplate::TaskColocated => f.write_str("TCG"),
            MappingTemplate::TaskDedicated => f.write_str("TDG"),
        }
    }
}
