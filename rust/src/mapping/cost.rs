//! The analytical mapping cost model: Tables 3-5 and Eqs. (1)-(3).
//!
//! Terms follow Table 3: `R_s/R_a/R_t` dominant-resource sizes, `T_s/T_a/
//! T_t` phase times, `S/A/W` vector sizes, `BW` inter-GMI bandwidth, `M_p`
//! model size, `m` sim steps per training, `n` total GMIs, `alpha/beta`
//! sharing ratios. The paper's measured constants: alpha ~= 0.2, beta ~=
//! 0.3, R_s ~= 10 R_a ~= 5 R_t, T_s ~= 6 T_a ~= 3 T_t.

use super::MappingTemplate;

/// Dominant resource type of Eq. (1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DominantResource {
    Sm,
    Memory,
}

/// Per-task profile feeding the Tables 4/5 formulas. Defaults implement the
/// paper's measured constants; the selection module can override from
/// profiled numbers.
#[derive(Debug, Clone)]
pub struct TaskProfile {
    /// Dominant resource sizes (arbitrary units; ratios matter).
    pub r_s: f64,
    pub r_a: f64,
    pub r_t: f64,
    /// Phase times per iteration (seconds or ratios).
    pub t_s: f64,
    pub t_a: f64,
    pub t_t: f64,
    /// size ratios when simulators share agents / trainers.
    pub alpha: f64,
    pub beta: f64,
    /// single state/action/reward vector bytes.
    pub s_bytes: f64,
    pub a_bytes: f64,
    pub w_bytes: f64,
    /// policy model bytes.
    pub mp_bytes: f64,
    /// sim steps per training iteration.
    pub m: usize,
    /// total GMIs.
    pub n: usize,
    /// inter-GMI bandwidth bytes/s.
    pub bw: f64,
    /// SM and memory consumption of one exclusive process, relative to one
    /// GPU (Eq. 1 inputs).
    pub sm_frac: f64,
    pub mem_frac: f64,
}

impl TaskProfile {
    /// Paper defaults for a benchmark with `obs/act` dims and `mp` bytes.
    pub fn paper_defaults(obs_dim: usize, act_dim: usize, mp_bytes: f64, m: usize, n: usize) -> Self {
        let t_s = 6.0;
        TaskProfile {
            r_s: 10.0,
            r_a: 1.0,
            r_t: 2.0,
            t_s,
            t_a: t_s / 6.0,
            t_t: t_s / 3.0,
            alpha: 0.2,
            beta: 0.3,
            s_bytes: 4.0 * obs_dim as f64,
            a_bytes: 4.0 * act_dim as f64,
            w_bytes: 4.0,
            mp_bytes,
            m,
            n,
            bw: crate::cluster::HOST_BW,
            sm_frac: 0.9,
            mem_frac: 0.3,
        }
    }

    /// Eq. (1): the dominant resource.
    pub fn dominant(&self) -> DominantResource {
        if self.sm_frac >= self.mem_frac {
            DominantResource::Sm
        } else {
            DominantResource::Memory
        }
    }
}

/// Output of the Table 4 / Table 5 comparison for one template.
#[derive(Debug, Clone)]
pub struct MappingCost {
    pub template: MappingTemplate,
    /// Time-weighted dominant-resource size R^I (Tables 4/5).
    pub resource_size: f64,
    /// Communication bytes per iteration COM (Tables 4/5).
    pub comm_bytes: f64,
    /// Projected throughput TOP (Eqs. 2/3) in iterations/s-equivalents.
    pub throughput: f64,
}

/// Table 4 + Eq. (2): DRL serving (simulator + agent only).
pub fn serving_cost(p: &TaskProfile, tpl: MappingTemplate) -> MappingCost {
    let (resource, com) = match tpl {
        MappingTemplate::TaskDedicated => (
            (p.t_s * p.r_s + p.t_a * p.alpha * p.r_a) / (p.t_s + p.t_a),
            2.0 * p.s_bytes + p.a_bytes + p.w_bytes,
        ),
        MappingTemplate::TaskColocated => (
            (p.t_s + p.t_a) * p.r_s.max(p.r_a) / (p.t_s + p.t_a),
            0.0,
        ),
    };
    // Eq. (2): TOP = (R_all / R) * 1 / (T_s + T_a + COM/BW). The paper's
    // profiling says COM/BW ~= 2 (T_s + T_a) for per-interaction sharing.
    let comm_time = if com > 0.0 { 2.0 * (p.t_s + p.t_a) } else { 0.0 };
    let r_all = p.r_s.max(p.r_a).max(p.r_t) * 10.0; // whole-system budget
    let top = (r_all / resource) / (p.t_s + p.t_a + comm_time);
    MappingCost { template: tpl, resource_size: resource, comm_bytes: com, throughput: top }
}

/// Table 5 + Eq. (3): synchronized DRL training.
pub fn sync_cost(p: &TaskProfile, tpl: MappingTemplate) -> MappingCost {
    let n = p.n as f64;
    let (resource, com, comm_time) = match tpl {
        MappingTemplate::TaskDedicated => {
            let r = (p.t_s * p.r_s + p.t_a * p.alpha * p.r_a + p.t_t * p.beta * p.r_t)
                / (p.t_s + p.t_a + p.t_t);
            let com = p.m as f64 * (p.s_bytes + p.a_bytes + p.w_bytes)
                + p.mp_bytes
                + 2.0 * (n - 1.0) * p.mp_bytes / n;
            // paper profiling: COM/BW ~= 7 (T_s + T_a + T_t) for TDG_EX.
            (r, com, 7.0 * (p.t_s + p.t_a + p.t_t))
        }
        MappingTemplate::TaskColocated => {
            let r = (p.t_s + p.t_a + p.t_t) * p.r_s.max(p.r_a).max(p.r_t)
                / (p.t_s + p.t_a + p.t_t);
            let com = 2.0 * (n - 1.0) * p.mp_bytes / n;
            (r, com, com / p.bw)
        }
    };
    let r_all = p.r_s.max(p.r_a).max(p.r_t) * 10.0;
    let top = (r_all / resource) / (p.t_s + p.t_a + p.t_t + comm_time);
    MappingCost { template: tpl, resource_size: resource, comm_bytes: com, throughput: top }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile() -> TaskProfile {
        TaskProfile::paper_defaults(60, 8, 4.0 * 1.1e5, 32, 8)
    }

    #[test]
    fn dominant_resource_rule() {
        let mut p = profile();
        assert_eq!(p.dominant(), DominantResource::Sm);
        p.mem_frac = 0.95;
        assert_eq!(p.dominant(), DominantResource::Memory);
    }

    #[test]
    fn tcg_beats_tdg_serving_by_about_2_5x() {
        // §5.1: "the overall serving throughput of our TCG solution would
        // be higher (about 2.5x) compared with TDG".
        let p = profile();
        let tcg = serving_cost(&p, MappingTemplate::TaskColocated);
        let tdg = serving_cost(&p, MappingTemplate::TaskDedicated);
        let gain = tcg.throughput / tdg.throughput;
        assert!(gain > 2.0 && gain < 3.2, "serving TCG/TDG = {gain}");
        assert_eq!(tcg.comm_bytes, 0.0);
        assert!(tdg.comm_bytes > 0.0);
    }

    #[test]
    fn tcg_ex_beats_tdg_ex_by_about_5x() {
        // §5.1: "the overall system throughput of our TCG_EX would increase
        // evidently (about 5x) compared with TDG_EX".
        let p = profile();
        let tcg = sync_cost(&p, MappingTemplate::TaskColocated);
        let tdg = sync_cost(&p, MappingTemplate::TaskDedicated);
        let gain = tcg.throughput / tdg.throughput;
        assert!(gain > 3.5 && gain < 7.0, "sync TCG_EX/TDG_EX = {gain}");
    }

    #[test]
    fn resource_penalty_of_colocation_is_modest() {
        // §5.1: colocation's resource penalty ~0.16x for serving, ~0.5x for
        // training — small against the 3x/8x communication savings.
        let p = profile();
        let tcg = serving_cost(&p, MappingTemplate::TaskColocated);
        let tdg = serving_cost(&p, MappingTemplate::TaskDedicated);
        let penalty = tcg.resource_size / tdg.resource_size - 1.0;
        assert!(penalty > 0.0 && penalty < 0.35, "serving penalty {penalty}");

        let tcgx = sync_cost(&p, MappingTemplate::TaskColocated);
        let tdgx = sync_cost(&p, MappingTemplate::TaskDedicated);
        let penalty = tcgx.resource_size / tdgx.resource_size - 1.0;
        assert!(penalty > 0.2 && penalty < 0.8, "sync penalty {penalty}");
    }

    #[test]
    fn tcg_ex_comm_is_gradient_only() {
        let p = profile();
        let tcg = sync_cost(&p, MappingTemplate::TaskColocated);
        // 2 (n-1)/n * Mp
        let want = 2.0 * 7.0 / 8.0 * p.mp_bytes;
        assert!((tcg.comm_bytes - want).abs() < 1e-6);
    }

    #[test]
    fn sync_throughput_monotone_decreasing_in_payload() {
        // Eq. (3): growing the model payload can only slow TCG_EX down —
        // comm bytes rise with Mp, and throughput falls accordingly.
        let mut prev_top = f64::INFINITY;
        let mut prev_com = 0.0;
        for mp in [1e5, 1e6, 1e7, 1e8] {
            let mut p = profile();
            p.mp_bytes = mp;
            let c = sync_cost(&p, MappingTemplate::TaskColocated);
            assert!(c.throughput < prev_top, "payload {mp}: top {} rose", c.throughput);
            assert!(c.comm_bytes > prev_com, "payload {mp}: comm did not grow");
            prev_top = c.throughput;
            prev_com = c.comm_bytes;
        }
    }

    #[test]
    fn sync_throughput_monotone_decreasing_in_gmi_count() {
        // More reducing GMIs = more gradient traffic (2 (n-1)/n Mp) and
        // never a higher per-iteration rate for the same profile.
        let mut prev = f64::INFINITY;
        for n in [2usize, 4, 8, 32, 128] {
            let mut p = profile();
            p.n = n;
            let c = sync_cost(&p, MappingTemplate::TaskColocated);
            assert!(c.throughput <= prev + 1e-12, "n={n}: throughput rose");
            prev = c.throughput;
        }
    }

    #[test]
    fn sync_throughput_monotone_increasing_in_bandwidth() {
        let mut prev = 0.0;
        for bw in [1e8, 1e9, 1e10, 1e11] {
            let mut p = profile();
            p.bw = bw;
            let c = sync_cost(&p, MappingTemplate::TaskColocated);
            assert!(c.throughput > prev, "bw {bw}: throughput did not improve");
            prev = c.throughput;
        }
    }

    #[test]
    fn dedicated_resource_size_monotone_in_sharing_ratios() {
        // Tables 4/5: alpha (agents shared per simulator) and beta
        // (trainers shared) scale the dedicated templates' time-weighted
        // resource size; colocated templates are flat in both.
        let mut prev_serving = 0.0;
        let mut prev_sync = 0.0;
        for scale in [0.1, 0.3, 0.6, 1.0] {
            let mut p = profile();
            p.alpha = 0.2 * scale / 0.1;
            p.beta = 0.3 * scale / 0.1;
            let serving = serving_cost(&p, MappingTemplate::TaskDedicated);
            let sync = sync_cost(&p, MappingTemplate::TaskDedicated);
            assert!(serving.resource_size > prev_serving, "alpha scale {scale}");
            assert!(sync.resource_size > prev_sync, "beta scale {scale}");
            prev_serving = serving.resource_size;
            prev_sync = sync.resource_size;

            let tcg = serving_cost(&p, MappingTemplate::TaskColocated);
            let flat = serving_cost(&profile(), MappingTemplate::TaskColocated);
            assert!((tcg.resource_size - flat.resource_size).abs() < 1e-12);
        }
    }

    #[test]
    fn serving_tdg_comm_scales_with_vector_sizes() {
        // Table 4's COM term is 2S + A + W: doubling the observation
        // vector doubles the dominant term; the colocated template stays
        // at zero no matter the sizes.
        let mut p = profile();
        let base = serving_cost(&p, MappingTemplate::TaskDedicated).comm_bytes;
        p.s_bytes *= 2.0;
        let doubled = serving_cost(&p, MappingTemplate::TaskDedicated).comm_bytes;
        assert!((doubled - base - p.s_bytes).abs() < 1e-9, "COM must grow by 2*dS = S'");
        assert_eq!(serving_cost(&p, MappingTemplate::TaskColocated).comm_bytes, 0.0);
    }
}
