//! Layout builders: turn (benchmark, topology, template, GMIperGPU,
//! num_env) into a concrete set of registered GMIs.

use anyhow::Result;

use super::MappingTemplate;
use crate::cluster::Topology;
use crate::gmi::{GmiBackend, GmiManager, GmiSpec, Role};
use crate::vtime::CostModel;

/// A fully-specified placement: the manager with every GMI registered.
pub struct Layout {
    pub manager: GmiManager,
    /// GMIs that run rollouts (serving or holistic).
    pub rollout_gmis: Vec<usize>,
    /// GMIs that run training.
    pub trainer_gmis: Vec<usize>,
    pub gmi_per_gpu: usize,
    pub num_env_per_gmi: usize,
    pub backend: GmiBackend,
}

impl Layout {
    pub fn backend_name(&self) -> &'static str {
        match self.backend {
            GmiBackend::Mps => "MPS",
            GmiBackend::Mig => "MIG",
            GmiBackend::DirectShare => "Direct-Share",
        }
    }

    /// Total environments simulated per step across the whole layout.
    pub fn total_envs(&self) -> usize {
        self.rollout_gmis.len() * self.num_env_per_gmi
    }
}

/// DRL serving (Fig 6 context, §5.1): `gmi_per_gpu` TCG serving blocks per
/// GPU (simulator+agent co-located), or TDG pairs (dedicated simulator and
/// agent GMIs) for the rejected-baseline comparison.
pub fn build_serving_layout(
    topo: &Topology,
    template: MappingTemplate,
    gmi_per_gpu: usize,
    num_env_per_gmi: usize,
    cost: &CostModel,
    backend_override: Option<GmiBackend>,
) -> Result<Layout> {
    let backend = backend_override
        .unwrap_or_else(|| GmiBackend::auto_select(false, topo.gpus[0].sm_arch));
    let mut manager = GmiManager::new(topo.clone());
    let mut rollout = Vec::new();
    let mut id = 0usize;
    for gpu in 0..topo.num_gpus() {
        match template {
            MappingTemplate::TaskColocated => {
                let share = backend.quantize_share(1.0 / gmi_per_gpu as f64);
                for _ in 0..gmi_per_gpu {
                    let mem = cost.mem_gib(num_env_per_gmi, 16, true, false);
                    manager.add_gmi(GmiSpec {
                        id,
                        gpu,
                        sm_share: share.min(1.0 / gmi_per_gpu as f64),
                        mem_gib: mem.min(topo.gpus[gpu].mem_gib / gmi_per_gpu as f64),
                        backend,
                        role: Role::SimAgent,
                        num_env: num_env_per_gmi,
                    })?;
                    rollout.push(id);
                    id += 1;
                }
            }
            MappingTemplate::TaskDedicated => {
                // alpha ~ 0.2: one agent GMI serves ~2 simulator GMIs; the
                // simulator keeps the big share.
                let pairs = gmi_per_gpu.max(2) / 2;
                for _ in 0..pairs {
                    let sim_share = 0.8 / pairs as f64;
                    let agent_share = 0.2 / pairs as f64;
                    manager.add_gmi(GmiSpec {
                        id,
                        gpu,
                        sm_share: sim_share,
                        mem_gib: cost.mem_gib(num_env_per_gmi, 16, true, false),
                        backend,
                        role: Role::Simulator,
                        num_env: num_env_per_gmi,
                    })?;
                    rollout.push(id);
                    id += 1;
                    manager.add_gmi(GmiSpec {
                        id,
                        gpu,
                        sm_share: agent_share,
                        mem_gib: 2.0,
                        backend,
                        role: Role::Agent,
                        num_env: 0,
                    })?;
                    id += 1;
                }
            }
        }
    }
    Ok(Layout {
        manager,
        rollout_gmis: rollout,
        trainer_gmis: vec![],
        gmi_per_gpu,
        num_env_per_gmi,
        backend,
    })
}

/// Synchronized training (Fig 6a): TCG_EX holistic GMIs (every GMI runs
/// sim+agent+trainer and joins the gradient group) or TDG_EX (serving GMIs
/// plus dedicated trainer GMIs; beta ~ 0.3 of a GPU per trainer).
pub fn build_sync_layout(
    topo: &Topology,
    template: MappingTemplate,
    gmi_per_gpu: usize,
    num_env_per_gmi: usize,
    cost: &CostModel,
    backend_override: Option<GmiBackend>,
) -> Result<Layout> {
    // Training needs inter-GMI communication -> MPS by the §3 rule.
    let backend = backend_override
        .unwrap_or_else(|| GmiBackend::auto_select(true, topo.gpus[0].sm_arch));
    let mut manager = GmiManager::new(topo.clone());
    let mut rollout = Vec::new();
    let mut trainers = Vec::new();
    let mut id = 0usize;
    for gpu in 0..topo.num_gpus() {
        match template {
            MappingTemplate::TaskColocated => {
                for _ in 0..gmi_per_gpu {
                    let mem = cost.mem_gib(num_env_per_gmi, 16, true, true);
                    manager.add_gmi(GmiSpec {
                        id,
                        gpu,
                        sm_share: 1.0 / gmi_per_gpu as f64,
                        mem_gib: mem.min(topo.gpus[gpu].mem_gib / gmi_per_gpu as f64),
                        backend,
                        role: Role::Holistic,
                        num_env: num_env_per_gmi,
                    })?;
                    rollout.push(id);
                    trainers.push(id);
                    id += 1;
                }
            }
            MappingTemplate::TaskDedicated => {
                // serving GMIs + one dedicated trainer GMI per GPU.
                let serving = gmi_per_gpu.max(2) - 1;
                let trainer_share = 0.3;
                let serve_share = (1.0 - trainer_share) / serving as f64;
                for _ in 0..serving {
                    manager.add_gmi(GmiSpec {
                        id,
                        gpu,
                        sm_share: serve_share,
                        mem_gib: cost.mem_gib(num_env_per_gmi, 16, true, false),
                        backend,
                        role: Role::SimAgent,
                        num_env: num_env_per_gmi,
                    })?;
                    rollout.push(id);
                    id += 1;
                }
                manager.add_gmi(GmiSpec {
                    id,
                    gpu,
                    sm_share: trainer_share,
                    mem_gib: cost.mem_gib(num_env_per_gmi * serving, 16, false, true),
                    backend,
                    role: Role::Trainer,
                    num_env: 0,
                })?;
                trainers.push(id);
                id += 1;
            }
        }
    }
    Ok(Layout {
        manager,
        rollout_gmis: rollout,
        trainer_gmis: trainers,
        gmi_per_gpu,
        num_env_per_gmi,
        backend,
    })
}

/// Open-loop serving-gateway fleet ([`serve`](crate::serve)):
/// `initial_per_gpu` inference GMIs per GPU, each provisioned at
/// `1/max_per_gpu` of the GPU's SMs, so every GPU keeps validated headroom
/// the SLO autoscaler can grow into (up to `max_per_gpu` members). Gateway
/// request/response traffic crosses the GMI boundary through host IPC, so
/// the §3 backend rule picks MPS unless overridden. `num_env` sizes the
/// per-GMI inference slot (typically the gateway's max batch).
pub fn build_gateway_fleet(
    topo: &Topology,
    initial_per_gpu: usize,
    max_per_gpu: usize,
    num_env: usize,
    cost: &CostModel,
    backend_override: Option<GmiBackend>,
) -> Result<Layout> {
    anyhow::ensure!(
        initial_per_gpu >= 1 && initial_per_gpu <= max_per_gpu,
        "initial fleet ({initial_per_gpu}/GPU) must fit under max_per_gpu ({max_per_gpu})"
    );
    let backend = backend_override.unwrap_or(GmiBackend::Mps);
    // Floor to the MPS 1% granularity so max_per_gpu members always pack.
    let share = ((100.0 / max_per_gpu as f64).floor() / 100.0).max(0.01);
    let mut manager = GmiManager::new(topo.clone());
    let mut rollout = Vec::new();
    let mut id = 0usize;
    for gpu in 0..topo.num_gpus() {
        for _ in 0..initial_per_gpu {
            // Inference-only footprint: context + parameters, no physics
            // buffers, no optimizer batch.
            let mem = cost
                .mem_gib(num_env, 1, false, false)
                .min(topo.gpus[gpu].mem_gib / max_per_gpu as f64);
            manager.add_gmi(GmiSpec {
                id,
                gpu,
                sm_share: share,
                mem_gib: mem,
                backend,
                role: Role::SimAgent,
                num_env,
            })?;
            rollout.push(id);
            id += 1;
        }
    }
    Ok(Layout {
        manager,
        rollout_gmis: rollout,
        trainer_gmis: vec![],
        gmi_per_gpu: initial_per_gpu,
        num_env_per_gmi: num_env,
        backend,
    })
}

/// Asynchronized training (Fig 6b): serving GMIs packed on one subset of
/// GPUs, trainer GMIs on the rest — the decoupled scheme.
pub fn build_async_layout(
    topo: &Topology,
    serving_gpus: usize,
    serving_per_gpu: usize,
    trainers_per_gpu: usize,
    num_env_per_gmi: usize,
    cost: &CostModel,
) -> Result<Layout> {
    assert!(serving_gpus < topo.num_gpus(), "need at least one training GPU");
    let backend = GmiBackend::Mps; // cross-GMI experience traffic -> MPS
    let mut manager = GmiManager::new(topo.clone());
    let mut rollout = Vec::new();
    let mut trainers = Vec::new();
    let mut id = 0usize;
    for gpu in 0..serving_gpus {
        for _ in 0..serving_per_gpu {
            manager.add_gmi(GmiSpec {
                id,
                gpu,
                sm_share: 1.0 / serving_per_gpu as f64,
                mem_gib: cost
                    .mem_gib(num_env_per_gmi, 16, true, false)
                    .min(topo.gpus[gpu].mem_gib / serving_per_gpu as f64),
                backend,
                role: Role::SimAgent,
                num_env: num_env_per_gmi,
            })?;
            rollout.push(id);
            id += 1;
        }
    }
    for gpu in serving_gpus..topo.num_gpus() {
        for _ in 0..trainers_per_gpu {
            manager.add_gmi(GmiSpec {
                id,
                gpu,
                sm_share: 1.0 / trainers_per_gpu as f64,
                mem_gib: cost
                    .mem_gib(num_env_per_gmi, 16, false, true)
                    .min(topo.gpus[gpu].mem_gib / trainers_per_gpu as f64),
                backend,
                role: Role::Trainer,
                num_env: 0,
            })?;
            trainers.push(id);
            id += 1;
        }
    }
    Ok(Layout {
        manager,
        rollout_gmis: rollout,
        trainer_gmis: trainers,
        gmi_per_gpu: serving_per_gpu,
        num_env_per_gmi,
        backend,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::static_registry;

    fn cost() -> CostModel {
        CostModel::new(&static_registry()["AT"])
    }

    #[test]
    fn tcg_sync_layout_is_holistic() {
        let topo = Topology::dgx_a100(2);
        let l = build_sync_layout(&topo, MappingTemplate::TaskColocated, 3, 1024, &cost(), None)
            .unwrap();
        assert_eq!(l.manager.len(), 6);
        assert_eq!(l.rollout_gmis, l.trainer_gmis);
        assert_eq!(l.backend, GmiBackend::Mps);
        let mpl = l.manager.mapping_list(|r| r.has_trainer());
        assert_eq!(mpl.len(), 2);
        assert_eq!(mpl[0].len(), 3);
    }

    #[test]
    fn tdg_sync_layout_separates_trainers() {
        let topo = Topology::dgx_a100(2);
        let l = build_sync_layout(&topo, MappingTemplate::TaskDedicated, 3, 1024, &cost(), None)
            .unwrap();
        // 2 serving + 1 trainer per GPU
        assert_eq!(l.manager.len(), 6);
        assert_eq!(l.trainer_gmis.len(), 2);
        assert_eq!(l.rollout_gmis.len(), 4);
        assert!(l
            .trainer_gmis
            .iter()
            .all(|&t| l.manager.gmi(t).unwrap().role == Role::Trainer));
    }

    #[test]
    fn serving_layout_uses_mig_on_a100() {
        let topo = Topology::dgx_a100(1);
        let l = build_serving_layout(&topo, MappingTemplate::TaskColocated, 3, 512, &cost(), None)
            .unwrap();
        assert_eq!(l.backend, GmiBackend::Mig);
        assert_eq!(l.rollout_gmis.len(), 3);
    }

    #[test]
    fn serving_layout_uses_mps_on_v100() {
        let topo = Topology::v100_box(1);
        let l = build_serving_layout(&topo, MappingTemplate::TaskColocated, 2, 512, &cost(), None)
            .unwrap();
        assert_eq!(l.backend, GmiBackend::Mps);
    }

    #[test]
    fn gateway_fleet_leaves_validated_headroom() {
        let topo = Topology::dgx_a100(2);
        let l = build_gateway_fleet(&topo, 2, 6, 32, &cost(), None).unwrap();
        assert_eq!(l.manager.len(), 4);
        assert_eq!(l.rollout_gmis.len(), 4);
        assert!(l.trainer_gmis.is_empty());
        assert_eq!(l.backend, GmiBackend::Mps);
        // Every GPU can still host (max - initial) more members.
        for gpu in 0..2 {
            let used: f64 = l
                .manager
                .all()
                .filter(|g| g.gpu == gpu)
                .map(|g| g.sm_share)
                .sum();
            let share = l.manager.all().next().unwrap().sm_share;
            assert!(used + 4.0 * share <= 1.0 + 1e-9, "no headroom: used {used}");
        }
        // Degenerate configs are rejected.
        assert!(build_gateway_fleet(&topo, 3, 2, 32, &cost(), None).is_err());
        assert!(build_gateway_fleet(&topo, 0, 2, 32, &cost(), None).is_err());
    }

    #[test]
    fn async_layout_decouples() {
        let topo = Topology::dgx_a100(4);
        let l = build_async_layout(&topo, 2, 3, 2, 1024, &cost()).unwrap();
        assert_eq!(l.rollout_gmis.len(), 6);
        assert_eq!(l.trainer_gmis.len(), 4);
        // serving on GPUs 0-1, trainers on 2-3
        assert!(l.rollout_gmis.iter().all(|&g| l.manager.gmi(g).unwrap().gpu < 2));
        assert!(l.trainer_gmis.iter().all(|&g| l.manager.gmi(g).unwrap().gpu >= 2));
    }
}
