//! The closed-loop DRL serving workload program:
//! `drl::serving::run_serving`'s round loop as a steppable [`Workload`].
//!
//! Every round each serving member charges one `horizon`-step
//! simulator+agent interaction segment; TDG fleets (dedicated
//! simulator/agent GMIs — the design the paper rejects) additionally pay
//! the per-step boundary crossing as a fabric intra-GPU plan and run the
//! forward at the agent GMI's slice of the pair budget.

use anyhow::Result;

use super::{StepCtx, StepOutcome, Workload};
use crate::config::BenchInfo;
use crate::drl::compute::WorkerState;
use crate::drl::serving::{tdg_agent_fwd, ServingConfig};
use crate::engine::{Engine, ExecutorId, OpCharge};
use crate::fabric::Fabric;
use crate::gmi::Role;
use crate::metrics::RunMetrics;
use crate::vtime::OpKind;

/// Steppable closed-loop serving program (see module docs).
pub struct ClosedServingProgram {
    cfg: ServingConfig,
    // ---- bound membership ----
    members: Vec<ExecutorId>,
    ids: Vec<ExecutorId>,
    dedicated: bool,
    num_env0: usize,
    bound: bool,
    // ---- run state ----
    started: bool,
    start_s: f64,
    round: usize,
    rollout_len: usize,
    /// Environment steps actually charged (exact integer accumulation):
    /// robust to mid-run membership changes, bit-identical to the
    /// closed-form `rounds x members x horizon x num_env` under fixed
    /// membership.
    env_steps: usize,
    workers: Vec<WorkerState>,
    reward_sum: f64,
    reward_count: usize,
    /// Fabric seconds of the TDG boundary crossings (tallied here for the
    /// per-job comm report; TCG crossings are intra-GMI and free).
    comm_s: f64,
    peak_mem: f64,
}

impl ClosedServingProgram {
    pub fn new(cfg: ServingConfig) -> Self {
        ClosedServingProgram {
            cfg,
            members: Vec::new(),
            ids: Vec::new(),
            dedicated: false,
            num_env0: 0,
            bound: false,
            started: false,
            start_s: 0.0,
            round: 0,
            rollout_len: 0,
            env_steps: 0,
            workers: Vec::new(),
            reward_sum: 0.0,
            reward_count: 0,
            comm_s: 0.0,
            peak_mem: 0.0,
        }
    }

    /// Rounds fully charged so far.
    pub fn rounds_done(&self) -> usize {
        self.round
    }

    fn run_round(&mut self, ctx: &mut StepCtx<'_>) -> Result<()> {
        let m = self.rollout_len;
        let real_n = self.cfg.real_replicas.min(self.ids.len()).max(1);
        for i in 0..self.ids.len() {
            let id = self.ids[i];
            let n_env = ctx.engine.num_env(id);
            let share = ctx.engine.share(id);

            let sim = OpCharge::recorded(OpKind::SimStep { num_env: n_env });
            // In TDG the agent runs on its own small GMI; model its
            // forward at the agent GMI's slice of the pair budget.
            let fwd = if self.dedicated {
                tdg_agent_fwd(n_env, share)
            } else {
                OpCharge::recorded(OpKind::PolicyFwd { num_env: n_env })
            };
            // TDG: per interaction step, 2S + A + W bytes cross the GMI
            // boundary through the host (Table 4) — a fabric intra-GPU
            // plan, tallied once per step.
            let t_comm = if self.dedicated {
                let bytes = n_env * 4 * (2 * ctx.bench.obs_dim + ctx.bench.act_dim + 1);
                let hop = ctx.fabric.plan_intra_gpu(
                    bytes,
                    ctx.engine.co_resident(id).max(1),
                    ctx.engine.gpu(id),
                );
                ctx.fabric.tally(&hop, m as f64);
                self.comm_s += hop.total_s() * m as f64;
                hop.total_s()
            } else {
                0.0
            };
            ctx.engine.charge_steps(ctx.cost, id, m as f64, &[sim, fwd], t_comm);
            self.env_steps += m * n_env;

            if i < real_n {
                let ro = ctx.compute.rollout(
                    ctx.bench,
                    &mut self.workers[i],
                    self.cfg.seed + (self.round * 37 + i) as i32,
                )?;
                self.reward_sum += ro.mean_reward as f64;
                self.reward_count += 1;
            }
        }
        self.round += 1;
        Ok(())
    }
}

impl Workload for ClosedServingProgram {
    fn bind(
        &mut self,
        engine: &Engine,
        _fabric: &mut Fabric,
        _bench: &BenchInfo,
        members: &[ExecutorId],
    ) -> Result<()> {
        if self.bound && self.members == members {
            return Ok(());
        }
        let mut ids = Vec::new();
        let mut dedicated = false;
        for &ex in members {
            let gmi = engine.gmi_of(ex);
            let role = engine
                .manager()
                .gmi(gmi)
                .ok_or_else(|| anyhow::anyhow!("member GMI {gmi} not registered"))?
                .role;
            if matches!(role, Role::Simulator | Role::Agent) {
                dedicated = true;
            }
            if role.has_sim() {
                ids.push(ex);
            }
        }
        anyhow::ensure!(!ids.is_empty(), "no serving members");
        self.num_env0 = engine.num_env(ids[0]);
        self.ids = ids;
        self.dedicated = dedicated;
        self.members = members.to_vec();
        self.bound = true;
        Ok(())
    }

    /// Closed-loop serving has an always-full queue: every round issues
    /// real dispatch work, so no round is ever quiescent. Keep the trait
    /// default (None = never fast-forward over this tenant) explicit so
    /// the contrast with the open-loop gateway is visible.
    fn next_event_hint(&mut self) -> Option<f64> {
        None
    }

    fn step(&mut self, ctx: &mut StepCtx) -> Result<StepOutcome> {
        anyhow::ensure!(self.bound, "serving program stepped before bind");
        if !self.started {
            self.started = true;
            self.start_s = ctx.engine.max_time(&self.ids).seconds();
            self.rollout_len = ctx.bench.horizon;
            self.peak_mem = ctx.cost.mem_gib(self.num_env0, self.rollout_len, true, false);
            let real_n = self.cfg.real_replicas.min(self.ids.len()).max(1);
            for _ in 0..real_n {
                self.workers.push(ctx.compute.init(ctx.bench, self.cfg.seed)?);
            }
        }
        while self.round < self.cfg.rounds
            && ctx.engine.max_time(&self.ids).seconds() < ctx.horizon_s
        {
            self.run_round(ctx)?;
        }
        if self.round >= self.cfg.rounds {
            return Ok(StepOutcome::Done);
        }
        Ok(StepOutcome::Pending)
    }

    fn snapshot(&self) -> Option<Box<dyn Workload>> {
        Some(Box::new(ClosedServingProgram {
            cfg: self.cfg.clone(),
            members: Vec::new(),
            ids: Vec::new(),
            dedicated: false,
            num_env0: 0,
            bound: false,
            started: self.started,
            start_s: self.start_s,
            round: self.round,
            rollout_len: self.rollout_len,
            env_steps: self.env_steps,
            workers: self.workers.clone(),
            reward_sum: self.reward_sum,
            reward_count: self.reward_count,
            comm_s: self.comm_s,
            peak_mem: self.peak_mem,
        }))
    }

    fn finish(&mut self, engine: &Engine, fabric: &Fabric) -> RunMetrics {
        let span = engine.max_time(&self.ids).seconds() - self.start_s;
        // What was actually charged — robust to mid-run membership changes.
        let total_steps = self.env_steps as f64;
        RunMetrics {
            steps_per_sec: total_steps / span,
            pps: total_steps / span,
            ttop: 0.0,
            span_s: span,
            utilization: engine.mean_utilization(),
            final_reward: if self.reward_count > 0 {
                self.reward_sum / self.reward_count as f64
            } else {
                0.0
            },
            reward_curve: vec![],
            comm_s: self.comm_s,
            peak_mem_gib: self.peak_mem,
            links: fabric.link_report(),
            latency: None,
            replay: None,
        }
    }
}
