//! The synchronized-PPO workload program: `drl::sync::run_sync`'s
//! iteration loop as a steppable [`Workload`].
//!
//! One iteration = (i) rollout on every rollout-capable member, (ii)
//! `ppo_epochs x minibatches` gradient + LGR-reduction + Adam rounds over
//! the trainer members (overlapped or sequential per
//! [`SyncConfig::overlap`]), (iii) optional elastic re-provisioning. The
//! program owns every piece of mutable run state (iteration counter,
//! worker numerics, reward curve, the in-flight overlapped reduction), so
//! the scheduler can step it one round at a time and a preempted program
//! resumes exactly where it stopped. The allreduce plan is derived from
//! the live member placement at [`Workload::bind`] time and re-derived on
//! membership changes.

use anyhow::Result;

use super::{StepCtx, StepOutcome, Workload};
use crate::comm::ReduceStrategy;
use crate::config::BenchInfo;
use crate::drl::compute::WorkerState;
use crate::drl::sync::SyncConfig;
use crate::drl::{rollout_charges, RolloutOut, TrainStats};
use crate::engine::{ElasticController, Engine, ExecutorId, OpCharge};
use crate::fabric::{Fabric, Plan};
use crate::metrics::{RewardTracker, RunMetrics};
use crate::vtime::{Clock, OpKind};

/// Steppable sync-PPO program (see module docs).
pub struct SyncProgram {
    cfg: SyncConfig,
    /// Environment steps per rollout segment (`bench.horizon` for
    /// standalone runs; the tenancy contract's `horizon` in the cluster).
    rollout_len: usize,
    // ---- bound membership (refreshed by `bind`) ----
    members: Vec<ExecutorId>,
    roll_ids: Vec<ExecutorId>,
    tr_ids: Vec<ExecutorId>,
    colocated: bool,
    num_env0: usize,
    strategy: ReduceStrategy,
    plan: Plan,
    bound: bool,
    // ---- run state (never reset by re-binds) ----
    started: bool,
    start_s: f64,
    iter: usize,
    /// Environment steps actually charged (exact integer accumulation):
    /// robust to mid-run membership changes, and bit-identical to the
    /// closed-form `iterations x members x num_env` under fixed
    /// membership (all values are far below 2^53).
    env_steps: usize,
    drained: bool,
    workers: Vec<WorkerState>,
    rewards: RewardTracker,
    stats_per_iter: Vec<TrainStats>,
    peak_mem: f64,
    /// Completion of the last issued overlapped reduction (None until the
    /// first reduction, or always with `overlap: false`).
    params_ready: Option<Clock>,
    elastic: Option<ElasticController>,
}

impl SyncProgram {
    pub fn new(cfg: SyncConfig, rollout_len: usize) -> Self {
        let elastic = cfg.elastic.clone().map(ElasticController::new);
        SyncProgram {
            cfg,
            rollout_len,
            members: Vec::new(),
            roll_ids: Vec::new(),
            tr_ids: Vec::new(),
            colocated: false,
            num_env0: 0,
            strategy: ReduceStrategy::MultiProcess,
            plan: Plan::new(),
            bound: false,
            started: false,
            start_s: 0.0,
            iter: 0,
            env_steps: 0,
            drained: false,
            workers: Vec::new(),
            rewards: RewardTracker::default(),
            stats_per_iter: Vec::new(),
            peak_mem: 0.0,
            params_ready: None,
            elastic,
        }
    }

    /// Reduction strategy the bound plan uses.
    pub fn strategy(&self) -> ReduceStrategy {
        self.strategy
    }

    /// Iterations fully charged so far.
    pub fn iterations_done(&self) -> usize {
        self.iter
    }

    /// Elastic re-provisioning adjustments applied (0 when disabled).
    pub fn elastic_shifts(&self) -> usize {
        self.elastic.as_ref().map(|c| c.shifts()).unwrap_or(0)
    }

    /// Final parameters of worker 0 (checkpoint-style consumers); consumes
    /// the workers.
    pub fn take_final_params(&mut self) -> Vec<f32> {
        std::mem::take(&mut self.workers)
            .into_iter()
            .next()
            .map(|w| w.params)
            .unwrap_or_default()
    }

    /// Per-iteration training statistics; consumes the log.
    pub fn take_stats(&mut self) -> Vec<TrainStats> {
        std::mem::take(&mut self.stats_per_iter)
    }

    /// One full sync iteration — a verbatim port of the historical
    /// `run_sync` loop body, so standalone and cluster runs cannot drift.
    fn run_iteration(&mut self, ctx: &mut StepCtx<'_>) -> Result<()> {
        let m = self.rollout_len;
        let n_roll = self.roll_ids.len();
        let n_train = self.tr_ids.len();
        let colocated = self.colocated;
        let real_n = self.cfg.real_replicas.min(n_roll).max(1);

        // ---- (i) experience collection on every rollout GMI ----
        let mut rollouts: Vec<RolloutOut> = Vec::with_capacity(n_roll);
        for i in 0..n_roll {
            let n_env = ctx.engine.num_env(self.roll_ids[i]);
            ctx.engine.charge_steps(
                ctx.cost,
                self.roll_ids[i],
                m as f64,
                &rollout_charges(n_env),
                0.0,
            );
            self.env_steps += m * n_env;
            self.peak_mem = self.peak_mem.max(ctx.cost.mem_gib(n_env, m, true, colocated));

            let ro = if i < real_n {
                ctx.compute.rollout(
                    ctx.bench,
                    &mut self.workers[i],
                    self.cfg.seed + (self.iter * 131 + i) as i32,
                )?
            } else {
                // mirror replica 0's experience (identical distribution)
                rollouts[0].clone()
            };
            rollouts.push(ro);
        }

        // TDG_EX: ship experience from serving GMIs to their GPU's trainer
        // (the Table 5 COM term); the k feeders contend and serialize on
        // the trainer GPU's host path.
        if !colocated {
            let exp_bytes_per_gmi = self.num_env0 * m * ctx.bench.experience_bytes_per_step();
            for t_idx in 0..n_train {
                let tgpu = ctx.engine.gpu(self.tr_ids[t_idx]);
                let feeders: Vec<ExecutorId> = self
                    .roll_ids
                    .iter()
                    .copied()
                    .filter(|&e| ctx.engine.gpu(e) == tgpu)
                    .collect();
                let k = feeders.len().max(1);
                let gather = ctx.fabric.plan_gather(k, exp_bytes_per_gmi, tgpu);
                let feed_max = ctx.engine.max_time(&feeders);
                ctx.engine.recv_plan(ctx.fabric, self.tr_ids[t_idx], feed_max, &gather);
            }
        }

        // ---- (ii) PPO epochs of minibatch updates ----
        let mut iter_stats = TrainStats::default();
        let mb = self.cfg.minibatches.max(1);
        for _epoch in 0..self.cfg.ppo_epochs {
            // Real gradients, once per epoch: the reduced gradient is the
            // real replicas' mean with replica 0 weighted by the mirror
            // count (mirrors hold exact copies of replica 0's gradient).
            let mut real_grads: Vec<Vec<f32>> = Vec::with_capacity(real_n);
            for widx in 0..real_n.min(n_train) {
                let (g, st) = ctx.compute.grad(ctx.bench, &self.workers[widx], &rollouts[widx])?;
                if widx == 0 {
                    iter_stats = st;
                }
                real_grads.push(g);
            }
            let reduced = if real_grads.len() == 1 || n_train == 1 {
                real_grads.swap_remove(0)
            } else {
                let k = real_grads.len();
                let w0 = (n_train - k + 1) as f32;
                let mut acc = real_grads.swap_remove(0);
                for v in acc.iter_mut() {
                    *v *= w0;
                }
                for g in &real_grads {
                    for (a, v) in acc.iter_mut().zip(g.iter()) {
                        *a += v;
                    }
                }
                let inv = 1.0 / n_train as f32;
                for v in acc.iter_mut() {
                    *v *= inv;
                }
                acc
            };

            // Virtual minibatch loop: grad/apply on the compute stream,
            // one LGR reduction per minibatch on the fabric. Overlap mode
            // lets reduction k drain while minibatch k+1 computes,
            // re-synchronizing at the next epoch's first gradient.
            for mb_i in 0..mb {
                for t_idx in 0..n_train {
                    let total_samples = if colocated {
                        self.num_env0 * m
                    } else {
                        self.num_env0 * m * (n_roll / n_train).max(1)
                    };
                    let samples = (total_samples / mb).max(1);
                    let ops = [
                        OpCharge::recorded(OpKind::TrainGrad { samples }),
                        OpCharge::recorded(OpKind::AdamApply),
                    ];
                    match (mb_i, self.params_ready) {
                        // First gradient after an overlapped reduction:
                        // block on the reduced parameters landing.
                        (0, Some(ready)) => {
                            ctx.engine.charge_after(ctx.cost, self.tr_ids[t_idx], ready, &ops);
                        }
                        _ => {
                            ctx.engine.charge_steps(ctx.cost, self.tr_ids[t_idx], 1.0, &ops, 0.0);
                        }
                    }
                }
                if self.plan.is_empty() {
                    continue;
                }
                if self.cfg.overlap {
                    self.params_ready = Some(ctx.engine.collective_overlapped(
                        ctx.fabric,
                        &self.tr_ids,
                        &self.plan,
                    ));
                } else {
                    ctx.engine.collective(ctx.fabric, &self.tr_ids, &self.plan);
                }
            }

            // real update, once per epoch
            for w in self.workers.iter_mut().take(real_n) {
                ctx.compute.apply(ctx.bench, w, &reduced, self.cfg.lr)?;
            }
            for i in real_n..n_roll {
                self.workers[i] = self.workers[0].clone();
            }
        }

        // TDG_EX: parameters flow back to the serving GMIs once the last
        // reduction has drained.
        if !colocated {
            let roll_gpus: Vec<usize> = {
                let mut g: Vec<usize> =
                    self.roll_ids.iter().map(|&r| ctx.engine.gpu(r)).collect();
                g.sort_unstable();
                g.dedup();
                g
            };
            let fan = ctx.fabric.plan_fanout(
                ctx.bench.param_bytes(),
                n_roll / n_train.max(1),
                &roll_gpus,
            );
            let mut from = ctx.engine.max_time(&self.tr_ids);
            if let Some(ready) = self.params_ready {
                from = Clock(from.seconds().max(ready.seconds()));
            }
            ctx.engine.broadcast_plan(ctx.fabric, &self.roll_ids, from, &fan);
        }

        let mean_r = rollouts.iter().map(|r| r.mean_reward as f64).sum::<f64>()
            / rollouts.len() as f64;
        self.rewards.push(ctx.engine.max_time(&self.roll_ids).seconds(), mean_r);
        self.stats_per_iter.push(iter_stats);

        // ---- (iii) elastic re-provisioning between iterations ----
        if let Some(ctl) = self.elastic.as_mut() {
            ctl.rebalance(ctx.engine, &self.roll_ids, &self.tr_ids);
        }
        self.iter += 1;
        Ok(())
    }
}

impl Workload for SyncProgram {
    fn bind(
        &mut self,
        engine: &Engine,
        fabric: &mut Fabric,
        bench: &BenchInfo,
        members: &[ExecutorId],
    ) -> Result<()> {
        if self.bound && self.members == members {
            // Resize-only changes: nothing cached depends on SM shares
            // (charges read live shares; the plan depends on placement).
            return Ok(());
        }
        let (roll, tr) = super::partition_roles(engine, members)?;
        anyhow::ensure!(
            !roll.is_empty() && !tr.is_empty(),
            "sync program needs rollout and trainer members"
        );
        // LGR over the trainer members: the mapping list groups them per
        // GPU (ascending member order within a GPU), and the fabric lowers
        // the cheapest valid plan unless a strategy is pinned.
        let mut per_gpu: std::collections::BTreeMap<usize, Vec<usize>> =
            std::collections::BTreeMap::new();
        for &ex in &tr {
            per_gpu.entry(engine.gpu(ex)).or_default().push(engine.gmi_of(ex));
        }
        let mpl: Vec<Vec<usize>> = per_gpu.into_values().collect();
        let (strategy, plan) = match self.cfg.strategy_override {
            Some(s) => (s, fabric.plan_allreduce(&mpl, bench.param_bytes(), s)?),
            None => fabric.cheapest_allreduce(&mpl, bench.param_bytes()),
        };
        self.colocated = roll == tr;
        self.num_env0 = engine.num_env(roll[0]);
        self.roll_ids = roll;
        self.tr_ids = tr;
        self.members = members.to_vec();
        self.strategy = strategy;
        self.plan = plan;
        self.bound = true;
        Ok(())
    }

    fn step(&mut self, ctx: &mut StepCtx) -> Result<StepOutcome> {
        anyhow::ensure!(self.bound, "sync program stepped before bind");
        if !self.started {
            self.started = true;
            self.start_s = ctx.engine.max_time(&self.members).seconds();
            let real_n = self.cfg.real_replicas.min(self.roll_ids.len()).max(1);
            for i in 0..self.roll_ids.len() {
                if i < real_n {
                    self.workers.push(ctx.compute.init(ctx.bench, self.cfg.seed)?);
                } else {
                    self.workers.push(self.workers[0].clone());
                }
            }
        }
        while self.iter < self.cfg.iterations
            && ctx.engine.max_time(&self.members).seconds() < ctx.horizon_s
        {
            self.run_iteration(ctx)?;
        }
        if self.iter >= self.cfg.iterations {
            if !self.drained {
                self.drained = true;
                // The final overlapped reduction drains past the last
                // compute charge: the run isn't over until its parameters
                // landed.
                if let Some(ready) = self.params_ready {
                    ctx.engine.wait_group(&self.tr_ids, ready);
                }
            }
            return Ok(StepOutcome::Done);
        }
        Ok(StepOutcome::Pending)
    }

    fn snapshot(&self) -> Option<Box<dyn Workload>> {
        // Progress (iterations, worker params, reward curve, charged
        // env-steps) survives; binding-derived caches (role partition,
        // allreduce plan) and the in-flight overlapped reduction do not —
        // the restore placement re-derives them at bind.
        Some(Box::new(SyncProgram {
            cfg: self.cfg.clone(),
            rollout_len: self.rollout_len,
            members: Vec::new(),
            roll_ids: Vec::new(),
            tr_ids: Vec::new(),
            colocated: false,
            num_env0: 0,
            strategy: ReduceStrategy::MultiProcess,
            plan: Plan::new(),
            bound: false,
            started: self.started,
            start_s: self.start_s,
            iter: self.iter,
            env_steps: self.env_steps,
            drained: self.drained,
            workers: self.workers.clone(),
            rewards: self.rewards.clone(),
            stats_per_iter: self.stats_per_iter.clone(),
            peak_mem: self.peak_mem,
            params_ready: None,
            elastic: self.cfg.elastic.clone().map(ElasticController::new),
        }))
    }

    fn finish(&mut self, engine: &Engine, fabric: &Fabric) -> RunMetrics {
        let span = engine.max_time(&self.members).seconds() - self.start_s;
        // What was actually charged — NOT a closed-form formula, so a
        // tenant whose membership shrank mid-run reports true throughput.
        let total_env_steps = self.env_steps as f64;
        let total_samples = total_env_steps * self.cfg.ppo_epochs as f64;
        RunMetrics {
            steps_per_sec: total_env_steps / span,
            pps: total_env_steps / span,
            ttop: total_samples / span,
            span_s: span,
            utilization: engine.mean_utilization(),
            final_reward: self.rewards.final_reward(),
            reward_curve: self.rewards.curve.clone(),
            comm_s: super::scoped_comm_s(engine, &self.members),
            peak_mem_gib: self.peak_mem,
            links: fabric.link_report(),
            latency: None,
            replay: None,
        }
    }
}
