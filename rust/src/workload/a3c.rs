//! The asynchronized (A3C-style) training workload program:
//! `drl::a3c::run_async`'s round loop as a steppable [`Workload`].
//!
//! Serving members continuously collect experience; the
//! dispenser/compressor/migrator/batcher pipeline moves it to trainer
//! members over the fabric; trainers update asynchronously and
//! periodically push fresh parameters back. The whole pipeline (staged
//! channel queues, sticky routing, partially filled batches) lives in the
//! program, so a preempted tenant resumes mid-pipeline without
//! re-charging completed rounds. With [`AsyncConfig::elastic`] set, the
//! engine's elastic controller re-provisions SM share toward the
//! bottleneck role group between rounds — the same bottleneck-shifting
//! support sync training has had since PR 1.

use std::collections::BTreeMap;

use anyhow::Result;

use super::{StepCtx, StepOutcome, Workload};
use crate::channels::{
    Batcher, ChannelKind, ChannelStats, Compressor, Dispenser, Migrator, RolloutSegment,
    TrainerEndpoint,
};
use crate::config::BenchInfo;
use crate::drl::a3c::AsyncConfig;
use crate::drl::compute::{Compute, WorkerState};
use crate::drl::RolloutOut;
use crate::engine::{ElasticController, Engine, ExecutorId, OpCharge};
use crate::fabric::Fabric;
use crate::metrics::{RewardTracker, RunMetrics};
use crate::vtime::OpKind;

/// Steppable A3C program (see module docs).
pub struct AsyncProgram {
    cfg: AsyncConfig,
    // ---- bound membership ----
    members: Vec<ExecutorId>,
    agent_ids: Vec<ExecutorId>,
    trainer_exec_list: Vec<ExecutorId>,
    /// trainer GMI id -> executor (the migrator routes by GMI id).
    trainer_ids: BTreeMap<usize, ExecutorId>,
    agent_gpus: Vec<usize>,
    num_env0: usize,
    bound: bool,
    // ---- channel pipeline ----
    migrator: Option<Migrator>,
    dispensers: Vec<Dispenser>,
    compressor: Option<Compressor>,
    batchers: BTreeMap<usize, Batcher>,
    /// Per-agent chunk-group sequence counters carried across
    /// snapshot/restore: a restored dispenser resumes the stream where the
    /// killed one left off, so post-restore seq ids never collide with ids
    /// the trainer-side consumer already saw.
    dispenser_seqs: Vec<u64>,
    /// Per-agent sample counts that were staged in the compressor (charged
    /// but never flushed) at snapshot time. The lost-and-redone contract:
    /// the first post-restore round re-charges and re-dispenses them.
    redo_samples: Vec<usize>,
    // ---- run state ----
    started: bool,
    start_s: f64,
    rollout_len: usize,
    round: usize,
    flushed: bool,
    agent_workers: Vec<WorkerState>,
    trainer_worker: Option<WorkerState>,
    last_real_rollout: Option<RolloutOut>,
    stats: ChannelStats,
    rewards: RewardTracker,
    updates: usize,
    samples_trained: usize,
    reward_sum: f64,
    reward_n: usize,
    peak_mem: f64,
    elastic: Option<ElasticController>,
}

impl AsyncProgram {
    pub fn new(cfg: AsyncConfig) -> Self {
        let elastic = cfg.elastic.clone().map(ElasticController::new);
        AsyncProgram {
            cfg,
            members: Vec::new(),
            agent_ids: Vec::new(),
            trainer_exec_list: Vec::new(),
            trainer_ids: BTreeMap::new(),
            agent_gpus: Vec::new(),
            num_env0: 0,
            bound: false,
            migrator: None,
            dispensers: Vec::new(),
            compressor: None,
            batchers: BTreeMap::new(),
            dispenser_seqs: Vec::new(),
            redo_samples: Vec::new(),
            started: false,
            start_s: 0.0,
            rollout_len: 0,
            round: 0,
            flushed: false,
            agent_workers: Vec::new(),
            trainer_worker: None,
            last_real_rollout: None,
            stats: ChannelStats::default(),
            rewards: RewardTracker::default(),
            updates: 0,
            samples_trained: 0,
            reward_sum: 0.0,
            reward_n: 0,
            peak_mem: 0.0,
            elastic,
        }
    }

    /// Trainer updates performed so far.
    pub fn updates(&self) -> usize {
        self.updates
    }

    /// Rounds fully charged so far.
    pub fn rounds_done(&self) -> usize {
        self.round
    }

    /// Elastic re-provisioning adjustments applied (0 when disabled).
    pub fn elastic_shifts(&self) -> usize {
        self.elastic.as_ref().map(|c| c.shifts()).unwrap_or(0)
    }

    /// Channel traffic statistics; consumes the log.
    pub fn take_channel_stats(&mut self) -> ChannelStats {
        std::mem::take(&mut self.stats)
    }

    /// Per-agent chunk-group sequence counters as the pipeline would
    /// snapshot them: live dispenser counters when bound, the carried
    /// restore state otherwise. Exposed for the seq-continuity regression
    /// tests.
    pub fn dispenser_seqs(&self) -> Vec<u64> {
        if self.dispensers.is_empty() {
            self.dispenser_seqs.clone()
        } else {
            self.dispensers.iter().map(Dispenser::seq).collect()
        }
    }

    /// Per-agent staged-but-unflushed samples a snapshot would mark for
    /// redo (plus any carried redo debt not yet repaid). Exposed for the
    /// transition-conservation regression tests.
    pub fn redo_samples(&self) -> Vec<usize> {
        self.snapshot_redo()
    }

    /// Per-agent redo debt at snapshot time: samples staged in the
    /// compressor for that agent's State channel (charged on the agent's
    /// timeline but dropped with the pipeline at restore) plus carried
    /// debt from an earlier kill that this incarnation has not repaid yet.
    fn snapshot_redo(&self) -> Vec<usize> {
        let n = if self.dispensers.is_empty() {
            self.redo_samples.len().max(self.dispenser_seqs.len())
        } else {
            self.dispensers.len()
        };
        (0..n)
            .map(|i| {
                let staged = match (&self.compressor, self.dispensers.get(i)) {
                    (Some(cp), Some(d)) => cp.staged_samples_for(d.agent, ChannelKind::State),
                    _ => 0,
                };
                staged + self.redo_samples.get(i).copied().unwrap_or(0)
            })
            .collect()
    }

    /// Repay the redo debt carried through a snapshot: re-charge the
    /// rollout work whose staged experience died with the old pipeline and
    /// re-dispense equivalent synthetic segments through the fresh one.
    /// Runs once, on the first step after a restore bind.
    fn redo_lost_samples(&mut self, ctx: &mut StepCtx<'_>) -> Result<()> {
        let debts = std::mem::take(&mut self.redo_samples);
        for (i, &lost) in debts.iter().enumerate() {
            if lost == 0 || i >= self.agent_ids.len() {
                continue;
            }
            let n_env = ctx.engine.num_env(self.agent_ids[i]);
            let steps = lost.div_ceil(n_env.max(1)).max(1);
            let now = ctx.engine.charge_steps(
                ctx.cost,
                self.agent_ids[i],
                steps as f64,
                &[
                    OpCharge::recorded(OpKind::SimStep { num_env: n_env }),
                    OpCharge::unrecorded(OpKind::PolicyFwd { num_env: n_env }),
                ],
                0.0,
            );
            let seg = RolloutSegment::synthetic(steps, n_env, ctx.bench.obs_dim, ctx.bench.act_dim);
            let steps_per_group = (self.cfg.batch_samples / n_env.max(1)).max(1);
            let groups =
                self.dispensers[i].dispense_groups(&seg, now, self.cfg.share_mode, steps_per_group);
            let compressor = self.compressor.as_mut().expect("bound program");
            let mut packets = Vec::new();
            for group in groups {
                self.stats.chunks_in += group.len() as u64;
                packets.extend(compressor.push(group));
            }
            // Re-staged chunks that crossed the threshold flow on to a
            // trainer exactly as first-run traffic would.
            self.drain_packets(ctx, i, packets)?;
        }
        Ok(())
    }

    /// Pipeline tail shared by the round loop and the redo path: route
    /// ready packets to trainers, charge the async updates they complete,
    /// and push parameters back on schedule.
    fn drain_packets(
        &mut self,
        ctx: &mut StepCtx<'_>,
        i: usize,
        packets: Vec<crate::channels::Packet>,
    ) -> Result<()> {
        for pkt in packets {
            let decision = self.migrator.as_mut().expect("bound program").route(ctx.fabric, &pkt);
            // The sender pays a per-message submission overhead on its
            // own timeline (IPC rendezvous + serialization).
            ctx.engine.pay(self.agent_ids[i], decision.sender_s);
            self.stats.transfer_seconds += decision.transfer_s;
            self.stats.transfer_ops += 1;
            self.stats.packets_out += 1;
            self.stats.bytes_moved += pkt.bytes() as u64;
            let ready_batches = {
                let batcher = self.batchers.get_mut(&decision.trainer).unwrap();
                batcher.push(pkt, decision.arrival)
            };

            // trainer consumes ready batches immediately (async)
            for batch in ready_batches {
                let tid = self.trainer_ids[&decision.trainer];
                ctx.engine.charge_after(
                    ctx.cost,
                    tid,
                    batch.ready,
                    &[
                        OpCharge::recorded(OpKind::TrainGrad { samples: batch.samples }),
                        OpCharge::unrecorded(OpKind::AdamApply),
                    ],
                );
                self.migrator
                    .as_mut()
                    .expect("bound program")
                    .complete(decision.trainer, batch.samples);
                self.samples_trained += batch.samples;
                self.updates += 1;

                // real gradient + update on the trainer worker
                if ctx.compute.is_real() {
                    if let Some(ro) = &self.last_real_rollout {
                        let tw = self.trainer_worker.as_mut().expect("bound program");
                        let (g, _) = ctx.compute.grad(ctx.bench, tw, ro)?;
                        ctx.compute.apply(ctx.bench, tw, &g, self.cfg.lr)?;
                    }
                }

                // param push-back every k updates: agents never BLOCK
                // on the trainer; they only pay the receive cost of
                // the pushed tensor on their own timeline.
                if self.updates % self.cfg.param_sync_every == 0 {
                    let push =
                        ctx.fabric.plan_param_push(ctx.bench.param_bytes(), &self.agent_gpus);
                    ctx.fabric.tally(&push, 1.0);
                    ctx.engine.pay_group(&self.agent_ids, push.total_s());
                    let params =
                        self.trainer_worker.as_ref().expect("bound program").params.clone();
                    for w in self.agent_workers.iter_mut() {
                        w.params = params.clone();
                    }
                }
            }
        }
        Ok(())
    }

    /// One A3C round over every agent — a verbatim port of the historical
    /// `run_async` loop body.
    fn run_round(&mut self, ctx: &mut StepCtx<'_>) -> Result<()> {
        let m = self.rollout_len;
        let real_n = self.cfg.real_replicas.min(self.agent_ids.len()).max(1);
        let mut round_reward = 0.0f64;
        let mut round_n = 0usize;
        for i in 0..self.agent_ids.len() {
            let n_env = ctx.engine.num_env(self.agent_ids[i]);

            // rollout segment (sim + fwd per step); only the simulation
            // records occupancy — the agent forward overlaps the pipeline.
            let now = ctx.engine.charge_steps(
                ctx.cost,
                self.agent_ids[i],
                m as f64,
                &[
                    OpCharge::recorded(OpKind::SimStep { num_env: n_env }),
                    OpCharge::unrecorded(OpKind::PolicyFwd { num_env: n_env }),
                ],
                0.0,
            );

            // Rollout numerics on the real replicas; under Null compute
            // only the deterministic pseudo reward is needed.
            let seed = self.cfg.seed + (self.round * 257 + i) as i32;
            let ro = if ctx.compute.is_real() && i < real_n {
                Some(ctx.compute.rollout(ctx.bench, &mut self.agent_workers[i], seed)?)
            } else {
                None
            };
            if i < real_n {
                let r = ro
                    .as_ref()
                    .map(|ro| ro.mean_reward)
                    .unwrap_or_else(|| Compute::null_mean_reward(seed))
                    as f64;
                self.reward_sum += r;
                self.reward_n += 1;
                round_reward += r;
                round_n += 1;
            }

            // experience: real bytes on real replicas, synthetic otherwise.
            let seg = match &ro {
                Some(ro) => RolloutSegment {
                    steps: ctx.bench.horizon,
                    envs: ctx.bench.num_env,
                    obs: ro.obs.as_f32()?.to_vec(),
                    actions: ro.actions.as_f32()?.to_vec(),
                    logps: ro.logps.as_f32()?.to_vec(),
                    rewards: ro.rewards.as_f32()?.to_vec(),
                    values: ro.values.as_f32()?.to_vec(),
                    dones: ro.dones.as_f32()?.to_vec(),
                },
                None => {
                    RolloutSegment::synthetic(m, n_env, ctx.bench.obs_dim, ctx.bench.act_dim)
                }
            };
            if let Some(ro) = ro {
                self.last_real_rollout = Some(ro);
            }

            // DP -> CP -> MG -> BT, grouped along the step axis at
            // training-batch granularity.
            let steps_per_group = (self.cfg.batch_samples / n_env.max(1)).max(1);
            let groups = self.dispensers[i].dispense_groups(
                &seg,
                now,
                self.cfg.share_mode,
                steps_per_group,
            );
            let compressor = self.compressor.as_mut().expect("bound program");
            let mut packets = Vec::new();
            for group in groups {
                self.stats.chunks_in += group.len() as u64;
                packets.extend(compressor.push(group));
            }
            self.drain_packets(ctx, i, packets)?;
        }

        // Fig 9-style learning signal: this round's mean reward at the
        // agents' current virtual time.
        if round_n > 0 {
            self.rewards.push(
                ctx.engine.max_time(&self.agent_ids).seconds(),
                round_reward / round_n as f64,
            );
        }
        self.round += 1;
        Ok(())
    }
}

impl Workload for AsyncProgram {
    fn bind(
        &mut self,
        engine: &Engine,
        _fabric: &mut Fabric,
        bench: &BenchInfo,
        members: &[ExecutorId],
    ) -> Result<()> {
        if self.bound {
            // The channel pipeline's routing and staged queues are keyed
            // by the member set; A3C tenancy contracts therefore fix their
            // membership (min = initial = max), and only share resizes —
            // which nothing cached depends on — occur mid-run.
            anyhow::ensure!(
                self.members == members,
                "A3C membership is fixed for the run (resize-only elasticity)"
            );
            return Ok(());
        }
        // Holistic members land in both groups, aliasing agent and trainer
        // onto one executor — the shape the historical inline loop ran.
        let (agents, trainers) = super::partition_roles(engine, members)?;
        anyhow::ensure!(
            !agents.is_empty() && !trainers.is_empty(),
            "async layout needs both agents and trainers"
        );
        let endpoints: Vec<TrainerEndpoint> = trainers
            .iter()
            .map(|&ex| TrainerEndpoint { gmi: engine.gmi_of(ex), gpu: engine.gpu(ex) })
            .collect();
        let mut migrator = Migrator::new(endpoints);
        let mut agent_gpus: Vec<usize> = Vec::new();
        let mut agent_gmis: Vec<usize> = Vec::new();
        for &ex in &agents {
            let gmi = engine.gmi_of(ex);
            let gpu = engine.gpu(ex);
            migrator.register_agent(gmi, gpu);
            agent_gmis.push(gmi);
            if !agent_gpus.contains(&gpu) {
                agent_gpus.push(gpu);
            }
        }
        // A restore bind resumes each agent's chunk-group stream at the
        // carried sequence counter: membership is fixed for the run, so
        // agent i of the restored program IS agent i of the killed one,
        // and reusing already-issued seq ids would collide at the
        // trainer-side consumer.
        let carried = std::mem::take(&mut self.dispenser_seqs);
        self.dispensers = agent_gmis
            .iter()
            .enumerate()
            .map(|(i, &g)| {
                if carried.len() == agent_gmis.len() {
                    Dispenser::with_seq(g, bench.obs_dim, bench.act_dim, carried[i])
                } else {
                    Dispenser::new(g, bench.obs_dim, bench.act_dim)
                }
            })
            .collect();
        self.compressor = Some(Compressor::with_staging_interval(
            self.cfg.share_mode,
            self.cfg.compressor_granularity,
            self.cfg.staging_interval_s,
        ));
        self.batchers = trainers
            .iter()
            .map(|&ex| {
                let gmi = engine.gmi_of(ex);
                (gmi, Batcher::new(gmi, self.cfg.share_mode, self.cfg.batch_samples))
            })
            .collect();
        self.trainer_ids =
            trainers.iter().map(|&ex| (engine.gmi_of(ex), ex)).collect();
        self.num_env0 = engine.num_env(agents[0]);
        self.migrator = Some(migrator);
        self.agent_ids = agents;
        self.trainer_exec_list = trainers;
        self.agent_gpus = agent_gpus;
        self.members = members.to_vec();
        self.bound = true;
        Ok(())
    }

    fn step(&mut self, ctx: &mut StepCtx) -> Result<StepOutcome> {
        anyhow::ensure!(self.bound, "async program stepped before bind");
        if !self.started {
            self.started = true;
            self.start_s = ctx.engine.max_time(&self.members).seconds();
            self.rollout_len = ctx.bench.horizon;
            self.peak_mem = ctx.cost.mem_gib(self.num_env0, self.rollout_len, true, false);
            let real_n = self.cfg.real_replicas.min(self.agent_ids.len()).max(1);
            for _ in 0..real_n {
                self.agent_workers.push(ctx.compute.init(ctx.bench, self.cfg.seed)?);
            }
            self.trainer_worker = Some(ctx.compute.init(ctx.bench, self.cfg.seed)?);
        }
        // Lost-and-redone: repay the staged-experience debt carried through
        // a snapshot before charging any new rounds.
        self.redo_lost_samples(ctx)?;
        while self.round < self.cfg.rounds
            && ctx.engine.max_time(&self.agent_ids).seconds() < ctx.horizon_s
        {
            self.run_round(ctx)?;
            // ---- elastic re-provisioning between rounds ----
            if let Some(ctl) = self.elastic.as_mut() {
                ctl.rebalance(ctx.engine, &self.agent_ids, &self.trainer_exec_list);
            }
        }
        if self.round >= self.cfg.rounds {
            if !self.flushed {
                self.flushed = true;
                // flush stragglers through the pipeline (counted but not
                // trained)
                let leftover = self.compressor.as_mut().expect("bound program").flush();
                for pkt in leftover {
                    self.stats.packets_out += 1;
                    self.stats.bytes_moved += pkt.bytes() as u64;
                }
            }
            return Ok(StepOutcome::Done);
        }
        Ok(StepOutcome::Pending)
    }

    fn snapshot(&self) -> Option<Box<dyn Workload>> {
        // Rounds, worker params, reward/channel logs survive; the staged
        // channel pipeline (compressor queue, batchers, migrator routing)
        // is membership-keyed and is rebuilt fresh at the restore bind.
        // Two things are carried ACROSS the rebuild: each dispenser's
        // chunk-group sequence counter (so the resumed stream never
        // reissues a seq id the consumer already saw) and the per-agent
        // count of samples staged-but-unflushed in the compressor (charged
        // work whose experience dies with the pipeline — the restored
        // program re-charges and re-dispenses it, the lost-and-redone
        // contract).
        Some(Box::new(AsyncProgram {
            cfg: self.cfg.clone(),
            members: Vec::new(),
            agent_ids: Vec::new(),
            trainer_exec_list: Vec::new(),
            trainer_ids: BTreeMap::new(),
            agent_gpus: Vec::new(),
            num_env0: 0,
            bound: false,
            migrator: None,
            dispensers: Vec::new(),
            compressor: None,
            batchers: BTreeMap::new(),
            dispenser_seqs: self.dispenser_seqs(),
            redo_samples: self.snapshot_redo(),
            started: self.started,
            start_s: self.start_s,
            rollout_len: self.rollout_len,
            round: self.round,
            flushed: self.flushed,
            agent_workers: self.agent_workers.clone(),
            trainer_worker: self.trainer_worker.clone(),
            last_real_rollout: self.last_real_rollout.clone(),
            stats: self.stats.clone(),
            rewards: self.rewards.clone(),
            updates: self.updates,
            samples_trained: self.samples_trained,
            reward_sum: self.reward_sum,
            reward_n: self.reward_n,
            peak_mem: self.peak_mem,
            elastic: self.cfg.elastic.clone().map(ElasticController::new),
        }))
    }

    fn finish(&mut self, engine: &Engine, fabric: &Fabric) -> RunMetrics {
        let agent_span = engine.max_time(&self.agent_ids).seconds() - self.start_s;
        let span = engine.max_time(&self.members).seconds() - self.start_s;
        let total_preds = (self.cfg.rounds * self.rollout_len) as f64
            * self.agent_ids.len() as f64
            * self.num_env0 as f64;
        RunMetrics {
            steps_per_sec: total_preds / span,
            pps: total_preds / agent_span,
            ttop: self.samples_trained as f64 / span,
            span_s: span,
            utilization: engine.mean_utilization(),
            final_reward: if self.reward_n > 0 {
                self.reward_sum / self.reward_n as f64
            } else {
                0.0
            },
            reward_curve: self.rewards.curve.clone(),
            comm_s: self.stats.transfer_seconds,
            peak_mem_gib: self.peak_mem,
            links: fabric.link_report(),
            latency: None,
            replay: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::static_registry;
    use crate::drl::a3c::AsyncConfig;
    use crate::engine::Engine;
    use crate::fabric::Fabric;
    use crate::gmi::{GmiBackend, GmiManager, GmiSpec, Role};
    use crate::topo::Topology;
    use crate::vtime::CostModel;

    fn two_gpu_async() -> (Engine, Fabric, crate::config::BenchInfo, CostModel) {
        let topo = Topology::dgx_a100(1);
        let bench = static_registry()["AY"].clone();
        let cost = CostModel::new(&bench);
        let mut manager = GmiManager::new(topo.clone());
        manager
            .add_gmi(GmiSpec {
                id: 0,
                gpu: 0,
                sm_share: 0.5,
                mem_gib: 4.0,
                backend: GmiBackend::Mps,
                role: Role::SimAgent,
                num_env: 512,
            })
            .unwrap();
        manager
            .add_gmi(GmiSpec {
                id: 1,
                gpu: 1,
                sm_share: 0.5,
                mem_gib: 4.0,
                backend: GmiBackend::Mps,
                role: Role::Trainer,
                num_env: 0,
            })
            .unwrap();
        let mut engine = Engine::new(&manager, &cost);
        engine.add_group(&[0, 1]).unwrap();
        let fabric = Fabric::single_node(topo);
        (engine, fabric, bench, cost)
    }

    fn small_cfg() -> AsyncConfig {
        AsyncConfig {
            rounds: 4,
            batch_samples: 4096,
            // Big granularity + long staging interval: chunks stay staged
            // in the compressor across rounds, the churn the satellite
            // fixes target.
            compressor_granularity: 64 << 20,
            staging_interval_s: 1e9,
            ..AsyncConfig::default()
        }
    }

    fn run_partially(program: &mut AsyncProgram, horizon_s: f64) {
        let (mut engine, mut fabric, bench, cost) = two_gpu_async();
        let compute = Compute::Null;
        let members: Vec<ExecutorId> = vec![0, 1];
        program.bind(&engine, &mut fabric, &bench, &members).unwrap();
        let mut ctx = StepCtx {
            engine: &mut engine,
            fabric: &mut fabric,
            cost: &cost,
            bench: &bench,
            compute: &compute,
            horizon_s,
        };
        let _ = program.step(&mut ctx).unwrap();
    }

    /// Satellite regression: pre-PR snapshots rebuilt dispensers from
    /// constructor state, so a restored stream re-issued seq ids 0..n that
    /// the trainer-side consumer had already seen.
    #[test]
    fn snapshot_carries_dispenser_seq_counters() {
        let mut program = AsyncProgram::new(small_cfg());
        run_partially(&mut program, 0.05);
        let seqs_before = program.dispenser_seqs();
        assert!(
            seqs_before.iter().any(|&s| s > 0),
            "partial run should have dispensed chunk groups, got {seqs_before:?}"
        );
        // Rebuild from the same carried state the snapshot records (tests
        // live in this module, so the carried fields are reachable without
        // downcasting the Box<dyn Workload>).
        let mut restored = AsyncProgram::new(small_cfg());
        restored.dispenser_seqs = seqs_before.clone();
        run_partially(&mut restored, 0.05);
        let seqs_after = restored.dispenser_seqs();
        for (b, a) in seqs_before.iter().zip(&seqs_after) {
            assert!(
                a > b,
                "restored dispenser must continue past the carried counter \
                 (before {b}, after {a}) — a fresh counter would collide"
            );
        }
    }

    /// Satellite regression: samples staged in the compressor at snapshot
    /// time died silently pre-PR — neither flushed nor re-charged. The
    /// snapshot must mark them for redo and the restored program must
    /// repay the debt on its first step.
    #[test]
    fn staged_compressor_samples_are_redone_after_restore() {
        let mut program = AsyncProgram::new(small_cfg());
        run_partially(&mut program, 0.05);
        let redo = program.redo_samples();
        assert!(
            redo.iter().any(|&s| s > 0),
            "huge granularity should leave staged samples, got {redo:?}"
        );
        // The snapshot carries the debt even though the pipeline dies.
        let mut restored = AsyncProgram::new(small_cfg());
        restored.dispenser_seqs = program.dispenser_seqs();
        restored.redo_samples = redo.clone();
        restored.started = true;
        restored.rollout_len = 64;
        run_partially(&mut restored, f64::INFINITY);
        // Control: identical restore but with no carried debt — exactly
        // what the pre-PR snapshot produced. The debt-carrying restore
        // must dispense strictly more chunk groups (the redone samples).
        let mut control = AsyncProgram::new(small_cfg());
        control.dispenser_seqs = program.dispenser_seqs();
        control.started = true;
        control.rollout_len = 64;
        run_partially(&mut control, f64::INFINITY);
        assert!(
            restored.stats.chunks_in > control.stats.chunks_in,
            "redo must re-dispense the staged samples (restored {} vs control {})",
            restored.stats.chunks_in,
            control.stats.chunks_in
        );
        assert!(
            restored.redo_samples.iter().all(|&s| s == 0),
            "carried redo debt must be consumed on the first restored step"
        );
    }
}
