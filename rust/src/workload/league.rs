//! The self-play league coordinator workload program.
//!
//! A single matchmaker member runs a league season over
//! [`LeagueConfig::players`] policies: it pairs players with a
//! round-robin circle schedule, spawns each match as a *child tenant*
//! through the scheduler's normal admission path
//! ([`Workload::take_spawn_requests`] / [`SpawnRequest`]), and folds each
//! completed match back into an Elo-rated win-rate table via
//! [`Workload::child_result`]. Matches are ordinary [`JobKind::Closed`]
//! tenants — they queue, place, preempt, checkpoint, and fail exactly
//! like input jobs, which is the point: the league exercises the
//! scheduler's dynamic tenant-churn paths end to end.
//!
//! Determinism: the pairing schedule is closed-form in the match index,
//! match outcomes draw from a SplitMix64 stream seeded by
//! [`LeagueConfig::seed`] in result-delivery order (which the scheduler's
//! round loop makes deterministic), and re-delivered results after a
//! coordinator kill + restore are deduplicated by tag — so a faulted
//! season reproduces bit-identically run to run.
//!
//! [`JobKind::Closed`]: crate::sched::JobKind::Closed

use std::collections::BTreeMap;

use anyhow::Result;

use super::{SpawnRequest, StepCtx, StepOutcome, Workload};
use crate::config::BenchInfo;
use crate::engine::{Engine, ExecutorId, OpCharge};
use crate::fabric::Fabric;
use crate::metrics::RunMetrics;
use crate::sched::JobSpec;
use crate::vtime::OpKind;

/// Self-play league configuration.
#[derive(Debug, Clone)]
pub struct LeagueConfig {
    /// League size (even, >= 2): the circle schedule pairs everyone.
    pub players: usize,
    /// Matches in the season.
    pub total_matches: usize,
    /// Matches allowed in flight at once (spawned, result not yet seen).
    pub max_concurrent: usize,
    /// Interaction rounds each match job runs.
    pub match_rounds: usize,
    /// Environments per match member GMI.
    pub match_num_env: usize,
    /// SM share each match member is provisioned at.
    pub match_share: f64,
    /// Priority match jobs are admitted at.
    pub match_priority: u8,
    /// Seed for the outcome SplitMix64 stream.
    pub seed: u64,
}

impl Default for LeagueConfig {
    fn default() -> Self {
        LeagueConfig {
            players: 4,
            total_matches: 12,
            max_concurrent: 2,
            match_rounds: 3,
            match_num_env: 256,
            match_share: 0.25,
            match_priority: 3,
            seed: 1,
        }
    }
}

impl LeagueConfig {
    /// The child tenancy contract for match `tag`: a one-member
    /// closed-loop serving job (the match simulation). `id` and
    /// `arrival_s` are the scheduler's to overwrite — `validate` probes
    /// this spec to reject leagues whose children could never admit.
    pub fn match_spec(&self, id: usize, tag: u64, arrival_s: f64) -> JobSpec {
        JobSpec::closed(
            id,
            &format!("match{tag}"),
            self.match_priority,
            arrival_s,
            1,
            self.match_share,
            self.match_share,
            self.match_num_env,
            self.match_rounds,
        )
    }

    /// Round-robin circle pairing for match index `k`: schedule rounds of
    /// `players/2` simultaneous pairs; within a full cycle of `players-1`
    /// schedule rounds every player meets every other exactly once, and
    /// any prefix of the schedule keeps per-player match counts within
    /// one of each other (the fairness invariant the property tests lock).
    pub fn pairing(&self, k: u64) -> (usize, usize) {
        let p = self.players;
        let half = p / 2;
        let sr = (k as usize / half) % (p - 1).max(1);
        let j = k as usize % half;
        // Circle method: player p-1 stays fixed; the rest rotate by `sr`.
        let a = if j == 0 { p - 1 } else { (sr + j) % (p - 1) };
        let b = (sr + p - 1 - j) % (p - 1);
        (a.min(b), a.max(b))
    }
}

/// SplitMix64 (same local copy the replay workload carries; the fault
/// layer's is module-private).
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Steppable league coordinator program (see module docs).
pub struct LeagueProgram {
    cfg: LeagueConfig,
    // ---- bound membership ----
    member: Option<ExecutorId>,
    members: Vec<ExecutorId>,
    bound: bool,
    // ---- league ledger (all of it survives snapshot/restore) ----
    /// Next match index to spawn.
    next_match: u64,
    /// Spawned matches awaiting a result: tag -> (player a, player b).
    outstanding: BTreeMap<u64, (usize, usize)>,
    /// Requests created but not yet drained by the scheduler (normally
    /// empty at snapshot time; carried defensively so a kill between
    /// creation and drain cannot strand a match).
    pending_spawns: Vec<SpawnRequest>,
    /// Decided matches: tag -> winning player.
    results: BTreeMap<u64, usize>,
    wins: Vec<usize>,
    played: Vec<usize>,
    /// Elo-style ratings driving the seeded outcome draws.
    ratings: Vec<f64>,
    rng: u64,
    // ---- run state ----
    started: bool,
    start_s: f64,
    ticks: usize,
    peak_mem: f64,
}

impl LeagueProgram {
    pub fn new(cfg: LeagueConfig) -> Self {
        let players = cfg.players;
        let rng = cfg.seed;
        LeagueProgram {
            cfg,
            member: None,
            members: Vec::new(),
            bound: false,
            next_match: 0,
            outstanding: BTreeMap::new(),
            pending_spawns: Vec::new(),
            results: BTreeMap::new(),
            wins: vec![0; players],
            played: vec![0; players],
            ratings: vec![1000.0; players],
            rng,
            started: false,
            start_s: 0.0,
            ticks: 0,
            peak_mem: 0.0,
        }
    }

    /// Matches decided so far.
    pub fn matches_done(&self) -> usize {
        self.results.len()
    }

    /// Per-player (wins, matches played) — the league table.
    pub fn table(&self) -> Vec<(usize, usize)> {
        self.wins.iter().copied().zip(self.played.iter().copied()).collect()
    }

    fn season_over(&self) -> bool {
        self.results.len() >= self.cfg.total_matches
    }

    /// One matchmaker tick: charge the pairing/evaluation inference and
    /// top outstanding matches up to the concurrency cap.
    fn run_tick(&mut self, ctx: &mut StepCtx<'_>) {
        let member = self.member.expect("bound program");
        let n_env = ctx.engine.num_env(member);
        ctx.engine.charge_steps(
            ctx.cost,
            member,
            1.0,
            &[OpCharge::recorded(OpKind::PolicyFwd { num_env: n_env })],
            0.0,
        );
        while self.outstanding.len() + self.pending_spawns.len() < self.cfg.max_concurrent
            && (self.next_match as usize) < self.cfg.total_matches
        {
            let tag = self.next_match;
            let pair = self.cfg.pairing(tag);
            self.outstanding.insert(tag, pair);
            // id/arrival are placeholders the scheduler overwrites.
            self.pending_spawns.push(SpawnRequest { tag, spec: self.cfg.match_spec(0, tag, 0.0) });
            self.next_match += 1;
        }
        self.ticks += 1;
    }
}

impl Workload for LeagueProgram {
    fn bind(
        &mut self,
        _engine: &Engine,
        _fabric: &mut Fabric,
        _bench: &BenchInfo,
        members: &[ExecutorId],
    ) -> Result<()> {
        anyhow::ensure!(members.len() == 1, "a league coordinator is a single member");
        self.member = Some(members[0]);
        self.members = members.to_vec();
        self.bound = true;
        Ok(())
    }

    fn step(&mut self, ctx: &mut StepCtx) -> Result<StepOutcome> {
        anyhow::ensure!(self.bound, "league program stepped before bind");
        // Progress comes from child tenants the scheduler admits between
        // rounds; an infinite-horizon (standalone) step would spin forever
        // waiting for results that can never arrive.
        anyhow::ensure!(
            ctx.horizon_s.is_finite() || self.season_over(),
            "the league coordinator cannot run standalone — drive it through the \
             cluster scheduler (its matches are spawned tenants)"
        );
        if !self.started {
            self.started = true;
            self.start_s = ctx.engine.max_time(&self.members).seconds();
            let n_env = ctx.engine.num_env(self.member.expect("bound program"));
            self.peak_mem = ctx.cost.mem_gib(n_env, 1, true, false);
        }
        while !self.season_over()
            && ctx.engine.max_time(&self.members).seconds() < ctx.horizon_s
        {
            self.run_tick(ctx);
        }
        if self.season_over() {
            return Ok(StepOutcome::Done);
        }
        Ok(StepOutcome::Pending)
    }

    fn take_spawn_requests(&mut self) -> Vec<SpawnRequest> {
        std::mem::take(&mut self.pending_spawns)
    }

    fn child_result(&mut self, tag: u64, metrics: &RunMetrics) {
        // Re-delivery after a restore replays every completed child —
        // results are keyed by tag, so a decided match never re-draws.
        if self.results.contains_key(&tag) {
            return;
        }
        let Some((a, b)) = self.outstanding.remove(&tag) else {
            return;
        };
        // The match ran to completion under the scheduler; its metrics
        // prove the work happened. The OUTCOME draws from the seeded
        // stream against the Elo expectation, so season timelines stay
        // bit-reproducible while stronger players keep winning more.
        let _ = metrics;
        let e_a = 1.0 / (1.0 + 10f64.powf((self.ratings[b] - self.ratings[a]) / 400.0));
        let u = (splitmix64(&mut self.rng) >> 11) as f64 / (1u64 << 53) as f64;
        let (winner, loser) = if u < e_a { (a, b) } else { (b, a) };
        let k = 32.0;
        let e_w = if winner == a { e_a } else { 1.0 - e_a };
        self.ratings[winner] += k * (1.0 - e_w);
        self.ratings[loser] -= k * (1.0 - e_w);
        self.wins[winner] += 1;
        self.played[a] += 1;
        self.played[b] += 1;
        self.results.insert(tag, winner);
    }

    fn snapshot(&self) -> Option<Box<dyn Workload>> {
        // The whole league ledger survives: schedule cursor, outstanding
        // matches (their child tenants keep running independently of the
        // coordinator's kill), decided results, ratings, and the RNG
        // cursor. Undrained spawn requests are carried defensively.
        Some(Box::new(LeagueProgram {
            cfg: self.cfg.clone(),
            member: None,
            members: Vec::new(),
            bound: false,
            next_match: self.next_match,
            outstanding: self.outstanding.clone(),
            pending_spawns: self.pending_spawns.clone(),
            results: self.results.clone(),
            wins: self.wins.clone(),
            played: self.played.clone(),
            ratings: self.ratings.clone(),
            rng: self.rng,
            started: self.started,
            start_s: self.start_s,
            ticks: self.ticks,
            peak_mem: self.peak_mem,
        }))
    }

    fn finish(&mut self, engine: &Engine, fabric: &Fabric) -> RunMetrics {
        let span = engine.max_time(&self.members).seconds() - self.start_s;
        let rate = |num: f64, den: f64| if den > 0.0 { num / den } else { 0.0 };
        // The learning signal is the league table: one curve point per
        // player, (player index, win rate). The final reward is the top
        // win rate — the strongest policy the season produced.
        let curve: Vec<(f64, f64)> = self
            .wins
            .iter()
            .zip(&self.played)
            .enumerate()
            .map(|(i, (&w, &p))| (i as f64, rate(w as f64, p as f64)))
            .collect();
        let best = curve.iter().map(|&(_, r)| r).fold(0.0f64, f64::max);
        RunMetrics {
            // Matches decided per coordinator second — the season's
            // throughput figure.
            steps_per_sec: rate(self.results.len() as f64, span),
            pps: rate(self.ticks as f64, span),
            ttop: 0.0,
            span_s: span,
            utilization: engine.mean_utilization(),
            final_reward: best,
            reward_curve: curve,
            comm_s: 0.0,
            peak_mem_gib: self.peak_mem,
            links: fabric.link_report(),
            latency: None,
            replay: None,
        }
    }
}

/// Standalone league driver: one coordinator tenant on an otherwise empty
/// cluster — "standalone" still means the scheduler, because the matches
/// ARE tenants. Returns the full cluster result: the coordinator's report
/// first (input order), then one report per spawned match.
pub fn run_league(
    topo: &crate::cluster::Topology,
    bench: &BenchInfo,
    cost: &crate::vtime::CostModel,
    cfg: &LeagueConfig,
    share: f64,
    sched: &crate::sched::SchedConfig,
) -> Result<crate::sched::ClusterRunResult> {
    let spec = JobSpec::league(0, "league", 5, 0.0, share, cfg.clone());
    crate::sched::run_cluster(topo, bench, cost, &[spec], sched)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn circle_pairing_is_fair_and_complete() {
        for players in [2usize, 4, 6, 8] {
            let cfg = LeagueConfig { players, ..LeagueConfig::default() };
            let half = players / 2;
            let cycle = half * (players - 1).max(1);
            // One full cycle: every unordered pair exactly once.
            let mut seen = std::collections::BTreeSet::new();
            for k in 0..cycle as u64 {
                let (a, b) = cfg.pairing(k);
                assert!(a < b && b < players, "bad pair ({a},{b})");
                assert!(seen.insert((a, b)), "pair ({a},{b}) repeated in a cycle");
            }
            assert_eq!(seen.len(), players * (players - 1) / 2);
            // Any prefix: per-player counts within 1 of each other.
            for prefix in 1..=cycle as u64 {
                let mut counts = vec![0usize; players];
                for k in 0..prefix {
                    let (a, b) = cfg.pairing(k);
                    counts[a] += 1;
                    counts[b] += 1;
                }
                let max = *counts.iter().max().unwrap();
                let min = *counts.iter().min().unwrap();
                assert!(
                    max - min <= 1,
                    "prefix {prefix} of {players}-league unfair: {counts:?}"
                );
            }
        }
    }

    #[test]
    fn results_dedup_by_tag_and_update_the_table() {
        let cfg = LeagueConfig::default();
        let mut prog = LeagueProgram::new(cfg.clone());
        let pair = cfg.pairing(0);
        prog.outstanding.insert(0, pair);
        let m = RunMetrics::default();
        prog.child_result(0, &m);
        assert_eq!(prog.matches_done(), 1);
        let table = prog.table();
        let rng_after = prog.rng;
        // Redelivery (the post-restore replay) must be a no-op.
        prog.child_result(0, &m);
        assert_eq!(prog.matches_done(), 1);
        assert_eq!(prog.table(), table);
        assert_eq!(prog.rng, rng_after, "redelivery must not consume the RNG");
        assert_eq!(prog.played[pair.0] + prog.played[pair.1], 2);
        assert_eq!(prog.wins.iter().sum::<usize>(), 1);
    }
}
