//! Steppable workload programs — ONE implementation per workload, shared
//! by the standalone run loops and the multi-tenant scheduler.
//!
//! Before this layer existed, every workload's inner loop lived twice:
//! once in its standalone orchestrator (`drl::sync::run_sync`,
//! `drl::a3c::run_async`, `drl::serving::run_serving`,
//! `serve::gateway::run_gateway`) and once re-implemented inline in the
//! cluster scheduler's `JobKind` match — so every cost-model change had to
//! land in two places, and workloads without an inline re-implementation
//! (A3C) could not be cluster tenants at all. A [`Workload`] is the single
//! implementation: a round-based coroutine over the shared
//! [`Engine`](crate::engine::Engine) + [`Fabric`](crate::fabric::Fabric)
//! substrate that charges its work in resumable steps.
//!
//! ## The contract
//!
//! * [`Workload::bind`] — (re)attach the program to its member executors.
//!   Called once before the first step and again by the scheduler after
//!   every membership or provisioning change (preemptive shrink, eviction,
//!   SLO growth, restore), so cached placement-derived state (e.g. the
//!   sync allreduce plan over the member GPUs) tracks the live fleet.
//!   Re-binding an unchanged member set is a no-op: program progress
//!   (completed iterations, queued requests, pipeline state) is never
//!   reset, which is what makes preempt → restore resume instead of
//!   re-charging completed work.
//! * [`Workload::step`] — advance the program, charging engine/fabric
//!   events until its executor frontier reaches `StepCtx::horizon_s` (one
//!   scheduling round) or the program completes. A standalone driver
//!   passes `f64::INFINITY` and the whole run happens in one step; the
//!   scheduler passes each round's boundary. Crucially, the charge
//!   sequence depends only on program state — never on where the horizon
//!   falls — so a single-tenant cluster run is bit-identical to the
//!   standalone run of the same program (locked in by
//!   `rust/tests/prop_workload.rs`).
//! * [`Workload::slo_signal`] — the last step's observed p99 latency
//!   (serving programs only): the pressure signal the scheduler's SLO
//!   grow/shrink/restore decisions consume.
//! * [`Workload::finish`] — fold the program's bookkeeping into
//!   [`RunMetrics`], exactly as its standalone loop reported them. Span,
//!   rates, and communication seconds are scoped to the program's own
//!   members (comm via the engine's job tags when present); engine-wide
//!   aggregates (utilization, link traffic) reflect the whole engine,
//!   which for a standalone run *is* the program — multi-tenant runs
//!   additionally get per-job busy/interference attribution from the
//!   engine's job tags.
//!
//! ## Adding a new workload kind
//!
//! 1. Implement [`Workload`] here: hold all mutable run state in the
//!    program struct, partition members by [`Role`](crate::gmi::Role) in
//!    `bind`, and gate the work loop on
//!    `engine.max_time(&members) < ctx.horizon_s`.
//! 2. Give it a standalone driver (build engine + fabric from a
//!    [`Layout`](crate::mapping::Layout), bind, step to completion).
//! 3. Add a [`JobKind`](crate::sched::JobKind) variant whose
//!    `build_program` constructs it — the scheduler needs nothing else:
//!    admission, preemption, SLO elasticity, and restore are
//!    workload-agnostic.
//!
//! ## Dynamic tenants
//!
//! A program may also act as a *coordinator* that creates cluster tenants
//! at runtime: [`Workload::take_spawn_requests`] is drained by the
//! scheduler after every round, and each returned [`SpawnRequest`] becomes
//! a child [`JobSpec`](crate::sched::JobSpec) that goes through the normal
//! admission path — queueing, placement, preemption, and fault handling
//! apply to children exactly as to input jobs. When a child completes, the
//! scheduler hands its [`RunMetrics`] back through
//! [`Workload::child_result`], keyed by the coordinator-chosen tag. The
//! self-play league ([`league::LeagueProgram`]) is the reference user:
//! it spawns match jobs, collects their results into a win-rate table,
//! and keeps spawning until its season completes.

pub mod a3c;
pub mod gateway;
pub mod league;
pub mod replay;
pub mod serving;
pub mod sync;

pub use a3c::AsyncProgram;
pub use gateway::GatewayProgram;
pub use league::{LeagueConfig, LeagueProgram};
pub use replay::{Eviction, ReplayConfig, ReplayProgram};
pub use serving::ClosedServingProgram;
pub use sync::SyncProgram;

use anyhow::Result;

use crate::config::BenchInfo;
use crate::drl::Compute;
use crate::engine::{Engine, ExecutorId};
use crate::fabric::Fabric;
use crate::metrics::RunMetrics;
use crate::sched::JobSpec;
use crate::vtime::CostModel;

/// A coordinator program's request to create a cluster tenant at runtime
/// (drained by the scheduler via [`Workload::take_spawn_requests`]).
///
/// The `spec.id` and `spec.arrival_s` the coordinator fills in are
/// placeholders: the scheduler assigns a fresh cluster-unique job id and
/// stamps the arrival at the round boundary the request was drained on, so
/// the child enters the same admission queue as any input job. The `tag`
/// is the coordinator's own stable key for the child — it survives
/// checkpoint/restore (the scheduler re-delivers completed child results
/// after a coordinator kill, deduplicated by tag).
#[derive(Debug, Clone)]
pub struct SpawnRequest {
    /// Coordinator-chosen stable identifier for this child (unique per
    /// coordinator; used for result delivery and re-spawn deduplication).
    pub tag: u64,
    /// The child job to admit. `id` and `arrival_s` are overwritten by the
    /// scheduler.
    pub spec: JobSpec,
}

/// Everything one [`Workload::step`] call may touch: the shared
/// discrete-event substrate plus the charge horizon for this step.
pub struct StepCtx<'a> {
    pub engine: &'a mut Engine,
    pub fabric: &'a mut Fabric,
    pub cost: &'a CostModel,
    pub bench: &'a BenchInfo,
    /// Numerics backend (real PJRT artifacts or the deterministic Null
    /// stand-in). Cluster tenants run Null numerics.
    pub compute: &'a Compute,
    /// Virtual-time horizon this step may charge up to: the program stops
    /// issuing work once its executor frontier passes it.
    /// `f64::INFINITY` runs the program to completion in one step.
    pub horizon_s: f64,
}

/// What one [`Workload::step`] call reports back.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOutcome {
    /// Work remains beyond the horizon — step again next round.
    Pending,
    /// Every charge the program will ever issue has been issued.
    Done,
}

/// First-occurrence-ordered union of two executor groups — the standalone
/// drivers' member list (colocated layouts alias rollout and trainer onto
/// one executor, which must appear once).
pub fn member_union(a: Vec<ExecutorId>, b: Vec<ExecutorId>) -> Vec<ExecutorId> {
    let mut members = a;
    for id in b {
        if !members.contains(&id) {
            members.push(id);
        }
    }
    members
}

/// Partition members by DRL role capability into (rollout-capable,
/// trainer-capable), preserving member order. Holistic members appear in
/// BOTH lists — colocated layouts alias the two role groups onto one
/// executor/timeline.
pub(crate) fn partition_roles(
    engine: &Engine,
    members: &[ExecutorId],
) -> Result<(Vec<ExecutorId>, Vec<ExecutorId>)> {
    let mut rollout = Vec::new();
    let mut trainers = Vec::new();
    for &ex in members {
        let gmi = engine.gmi_of(ex);
        let role = engine
            .manager()
            .gmi(gmi)
            .ok_or_else(|| anyhow::anyhow!("member GMI {gmi} not registered"))?
            .role;
        if role.has_sim() {
            rollout.push(ex);
        }
        if role.has_trainer() {
            trainers.push(ex);
        }
    }
    Ok((rollout, trainers))
}

/// Communication seconds attributable to this program: the job-tagged
/// total when the members carry a job tag (multi-tenant runs attribute
/// comm per tenant), the engine-wide total otherwise (standalone, where
/// the engine IS the program). In a single-tenant cluster the two sums
/// receive identical additions in identical order, so this stays
/// bit-identical to the standalone figure.
pub(crate) fn scoped_comm_s(engine: &Engine, members: &[ExecutorId]) -> f64 {
    members
        .first()
        .and_then(|&ex| engine.job_of_executor(ex))
        .map(|job| engine.job_comm_s(job))
        .unwrap_or_else(|| engine.comm_s())
}

/// Drive a bound program to completion — the standalone driver loop: one
/// infinite-horizon step sequence over the program's own engine + fabric.
/// (The scheduler instead steps programs one round at a time.)
pub fn run_to_completion(
    program: &mut dyn Workload,
    engine: &mut Engine,
    fabric: &mut Fabric,
    cost: &CostModel,
    bench: &BenchInfo,
    compute: &Compute,
) -> Result<()> {
    let mut ctx = StepCtx { engine, fabric, cost, bench, compute, horizon_s: f64::INFINITY };
    while program.step(&mut ctx)? != StepOutcome::Done {}
    Ok(())
}

/// A resource-adjustable, schedulable workload program (see the module
/// docs for the step/membership lifecycle).
pub trait Workload {
    /// (Re)attach the program to its member executors. Idempotent for an
    /// unchanged member set; programs with placement-derived caches (the
    /// sync allreduce plan, the gateway's active fleet) refresh them here.
    fn bind(
        &mut self,
        engine: &Engine,
        fabric: &mut Fabric,
        bench: &BenchInfo,
        members: &[ExecutorId],
    ) -> Result<()>;

    /// Advance the program up to `ctx.horizon_s` (see [`StepCtx`]).
    fn step(&mut self, ctx: &mut StepCtx) -> Result<StepOutcome>;

    /// p99 latency of the requests dispatched during the last step (None
    /// for non-serving programs or steps that dispatched nothing) — the
    /// scheduler's SLO pressure signal.
    fn slo_signal(&self) -> Option<f64> {
        None
    }

    /// Virtual time of this program's next observable event, for the
    /// scheduler's idle-round fast-forward
    /// ([`crate::sched::FastForward`]): the earliest instant at which a
    /// future `step` would do ANY work or change ANY externally observable
    /// signal (including `slo_signal` decaying back to None). A round
    /// whose whole quantum lies strictly before every tenant's hint is
    /// provably quiescent and may be skipped. `None` (the default) means
    /// "unknown — never skip over me"; it is always safe, merely slow.
    /// Only programs whose step is a pure function of virtual-time events
    /// (the gateway's arrival/deadline/window loop) should override this;
    /// per-step programs (training loops, closed-loop serving) do work
    /// every round and must keep the default.
    fn next_event_hint(&mut self) -> Option<f64> {
        None
    }

    /// Fold the completed (or preempted-final) program state into the
    /// metrics its standalone run loop would have reported.
    fn finish(&mut self, engine: &Engine, fabric: &Fabric) -> RunMetrics;

    /// Capture a restartable copy of the program's progress — the
    /// checkpoint the fault-tolerant scheduler resumes a killed tenant
    /// from ([`crate::fault::FaultPlan::checkpoint_interval_s`]). The
    /// snapshot is UNBOUND: placement-derived caches (member lists, the
    /// allreduce plan, pooled dispatch plans, channel pipelines) are
    /// dropped, and the next `bind` rebuilds them against whatever
    /// surviving GPUs the tenant lands on. In-flight, un-checkpointed
    /// work (the current round's partial charges, queued pipeline
    /// packets) is lost — that is the at-most-one-interval guarantee,
    /// not a bug. `None` (the default) marks a program that cannot
    /// checkpoint; a kill then restarts it from scratch.
    fn snapshot(&self) -> Option<Box<dyn Workload>> {
        None
    }

    /// Drain this program's pending requests to create cluster tenants
    /// (see [`SpawnRequest`]). The scheduler calls this after stepping the
    /// program each round; non-coordinator programs use the default (no
    /// requests). Requests must be idempotent under re-delivery of child
    /// results: after a coordinator kill + restore, the scheduler replays
    /// every completed child result, and the coordinator must not re-spawn
    /// a tag it has already seen a result for.
    fn take_spawn_requests(&mut self) -> Vec<SpawnRequest> {
        Vec::new()
    }

    /// Deliver a completed child tenant's metrics back to the coordinator
    /// that spawned it (keyed by the [`SpawnRequest::tag`]). May be called
    /// more than once per tag across kill/restore cycles — implementations
    /// deduplicate by tag.
    fn child_result(&mut self, tag: u64, metrics: &RunMetrics) {
        let _ = (tag, metrics);
    }
}
