//! The off-policy replay-buffer training workload program.
//!
//! Collector members stream experience through the
//! dispenser/compressor/migrator channel pipeline to a single learner
//! member that owns a memory-budgeted replay buffer; the learner samples
//! minibatches from the buffer at its own rate, decoupled from the
//! collection rate — the off-policy counterpart of the A3C pipeline
//! ([`super::a3c::AsyncProgram`]).
//!
//! The buffer is charged against the learner GMI's memory budget:
//! [`ReplayConfig::buffer_gib`] converts to a transition capacity at bind
//! time, and insertions beyond it evict — FIFO (oldest experience first)
//! or seeded random-victim ([`Eviction::Reservoir`]). Per-run staleness
//! (learner virtual time minus each sampled transition's arrival time)
//! and buffer pressure (occupancy / capacity) are reported in
//! [`ReplayStats`] via [`RunMetrics::replay`].
//!
//! Determinism: sampling and eviction draw from a SplitMix64 stream
//! seeded by [`ReplayConfig::seed`], and every charge depends only on
//! program state — a single-tenant cluster run is bit-identical to the
//! standalone [`run_replay`] driver (locked by `prop_workload.rs`), and
//! the full state (buffer ledger, RNG cursor, staleness accumulators,
//! dispenser seq counters, staged-sample redo debt) travels through
//! [`Workload::snapshot`] so a fault kill + restore loses no transitions.

use std::collections::VecDeque;

use anyhow::Result;

use super::{StepCtx, StepOutcome, Workload};
use crate::channels::{
    ChannelKind, ChannelStats, Compressor, Dispenser, Migrator, RolloutSegment, ShareMode,
    TrainerEndpoint,
};
use crate::config::BenchInfo;
use crate::drl::compute::Compute;
use crate::engine::{Engine, ExecutorId, OpCharge};
use crate::fabric::Fabric;
use crate::mapping::Layout;
use crate::metrics::{ReplayStats, RewardTracker, RunMetrics};
use crate::vtime::{CostModel, OpKind};

/// Replay-buffer eviction policy once the memory budget is exceeded.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Eviction {
    /// Drop the oldest buffered experience first.
    Fifo,
    /// Drop a seeded-uniform random victim (reservoir-style turnover:
    /// surviving experience is an unbiased sample of everything inserted).
    Reservoir,
}

/// Off-policy replay training configuration.
#[derive(Debug, Clone)]
pub struct ReplayConfig {
    /// Collection rounds per collector.
    pub rounds: usize,
    /// Seed for the sampling/eviction SplitMix64 stream.
    pub seed: u64,
    pub share_mode: ShareMode,
    /// Transitions each collector pushes per round (rounded up to whole
    /// environment steps).
    pub push_samples: usize,
    /// Learner minibatch size in transitions.
    pub batch_samples: usize,
    /// Replay-buffer memory budget in GiB, charged against the learner
    /// GMI; converts to a transition capacity from the benchmark's
    /// transition width.
    pub buffer_gib: f64,
    pub eviction: Eviction,
    /// Learner sampling passes per collection round (the off-policy
    /// replay ratio knob).
    pub learner_batches_per_round: usize,
    /// Push fresh params back to collectors every k learner updates.
    pub param_sync_every: usize,
    /// Per-channel transfer granularity in bytes (the CP staging
    /// threshold).
    pub compressor_granularity: usize,
    /// Anti-starvation staging bound (virtual seconds).
    pub staging_interval_s: f64,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        ReplayConfig {
            rounds: 10,
            seed: 1,
            share_mode: ShareMode::MultiChannel,
            push_samples: 4096,
            batch_samples: 1024,
            buffer_gib: 1.0,
            eviction: Eviction::Fifo,
            learner_batches_per_round: 2,
            param_sync_every: 4,
            compressor_granularity: 256 << 10,
            staging_interval_s: 1.0,
        }
    }
}

/// SplitMix64: the same tiny seeded generator the fault layer uses (its
/// copy is module-private); one u64 of state, full-period, deterministic.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// One buffered insertion: a chunk group's worth of transitions from one
/// collector. The buffer holds the ledger, not the f32 payloads — the
/// learner's compute is charged synthetically per sampled batch, so only
/// counts, provenance, and birth times matter.
#[derive(Debug, Clone)]
struct BufferEntry {
    /// Producing collector's GMI id (provenance; keeps entries distinct).
    #[allow(dead_code)]
    agent: usize,
    /// Dispenser sequence id of the originating chunk group.
    #[allow(dead_code)]
    seq: u64,
    /// Transitions in this entry.
    samples: usize,
    /// Learner-side arrival time (virtual seconds) — staleness baseline.
    born_s: f64,
}

/// Steppable off-policy replay program (see module docs).
pub struct ReplayProgram {
    cfg: ReplayConfig,
    // ---- bound membership ----
    members: Vec<ExecutorId>,
    collector_ids: Vec<ExecutorId>,
    learner_id: Option<ExecutorId>,
    collector_gpus: Vec<usize>,
    bound: bool,
    // ---- channel pipeline ----
    migrator: Option<Migrator>,
    dispensers: Vec<Dispenser>,
    compressor: Option<Compressor>,
    /// Carried across snapshot/restore (same churn contract as A3C): seq
    /// counters resume the stream, redo debt re-charges staged-but-lost
    /// samples.
    dispenser_seqs: Vec<u64>,
    redo_samples: Vec<usize>,
    // ---- replay buffer ----
    capacity: usize,
    buffer: VecDeque<BufferEntry>,
    buffer_samples: usize,
    rng: u64,
    // ---- run state ----
    started: bool,
    start_s: f64,
    round: usize,
    flushed: bool,
    env_steps: usize,
    transitions_in: usize,
    transitions_sampled: usize,
    evicted: usize,
    updates: usize,
    empty_ticks: usize,
    staleness_sum: f64,
    staleness_n: usize,
    max_staleness_s: f64,
    pressure_sum: f64,
    pressure_n: usize,
    peak_pressure: f64,
    stats: ChannelStats,
    rewards: RewardTracker,
    reward_sum: f64,
    reward_n: usize,
    peak_mem: f64,
}

impl ReplayProgram {
    pub fn new(cfg: ReplayConfig) -> Self {
        let rng = cfg.seed;
        ReplayProgram {
            cfg,
            members: Vec::new(),
            collector_ids: Vec::new(),
            learner_id: None,
            collector_gpus: Vec::new(),
            bound: false,
            migrator: None,
            dispensers: Vec::new(),
            compressor: None,
            dispenser_seqs: Vec::new(),
            redo_samples: Vec::new(),
            capacity: 0,
            buffer: VecDeque::new(),
            buffer_samples: 0,
            rng,
            started: false,
            start_s: 0.0,
            round: 0,
            flushed: false,
            env_steps: 0,
            transitions_in: 0,
            transitions_sampled: 0,
            evicted: 0,
            updates: 0,
            empty_ticks: 0,
            staleness_sum: 0.0,
            staleness_n: 0,
            max_staleness_s: 0.0,
            pressure_sum: 0.0,
            pressure_n: 0,
            peak_pressure: 0.0,
            stats: ChannelStats::default(),
            rewards: RewardTracker::default(),
            reward_sum: 0.0,
            reward_n: 0,
            peak_mem: 0.0,
        }
    }

    /// Learner updates performed so far.
    pub fn updates(&self) -> usize {
        self.updates
    }

    /// Rounds fully charged so far.
    pub fn rounds_done(&self) -> usize {
        self.round
    }

    /// Transitions currently buffered.
    pub fn buffered(&self) -> usize {
        self.buffer_samples
    }

    /// Transition capacity derived from the memory budget.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Channel traffic statistics; consumes the log.
    pub fn take_channel_stats(&mut self) -> ChannelStats {
        std::mem::take(&mut self.stats)
    }

    /// Bytes one buffered transition occupies: obs + action + logp +
    /// reward + value + done, all f32 (the channel set's full width).
    fn transition_bytes(bench: &BenchInfo) -> usize {
        4 * (bench.obs_dim + bench.act_dim + 4)
    }

    /// Record one buffer-pressure observation (occupancy over capacity,
    /// clamped to [0, 1]; 0 when capacity is degenerate). Every learner
    /// tick samples pressure — including empty-buffer ticks, so the mean
    /// reflects the whole run, never a 0/0.
    fn pressure_tick(&mut self) {
        let p = if self.capacity > 0 {
            (self.buffer_samples as f64 / self.capacity as f64).clamp(0.0, 1.0)
        } else {
            0.0
        };
        self.pressure_sum += p;
        self.pressure_n += 1;
        if p > self.peak_pressure {
            self.peak_pressure = p;
        }
    }

    /// Evict down to capacity after an insertion, per the configured
    /// policy. Counts evicted transitions; never touches the RNG unless a
    /// random victim is actually needed (keeps FIFO and under-budget runs
    /// on the same RNG stream as their no-eviction twins).
    fn evict_to_capacity(&mut self) {
        while self.buffer_samples > self.capacity && !self.buffer.is_empty() {
            let victim = match self.cfg.eviction {
                Eviction::Fifo => 0,
                Eviction::Reservoir => {
                    (splitmix64(&mut self.rng) % self.buffer.len() as u64) as usize
                }
            };
            let e = self.buffer.remove(victim).expect("victim index in range");
            self.buffer_samples -= e.samples;
            self.evicted += e.samples;
        }
    }

    /// Insert a packet's State-channel chunks into the buffer (the other
    /// five channels ride the same packets; counting one canonical channel
    /// counts each transition exactly once).
    fn insert_packet(&mut self, pkt: &crate::channels::Packet, arrival_s: f64) {
        for c in pkt.chunks.iter().filter(|c| c.channel == ChannelKind::State) {
            let samples = c.steps * c.envs;
            if samples == 0 {
                continue;
            }
            self.buffer.push_back(BufferEntry {
                agent: c.agent,
                seq: c.seq,
                samples,
                born_s: arrival_s,
            });
            self.buffer_samples += samples;
            self.transitions_in += samples;
        }
        self.evict_to_capacity();
    }

    /// Route ready packets to the learner over the fabric and insert them.
    fn drain_packets(
        &mut self,
        ctx: &mut StepCtx<'_>,
        collector: ExecutorId,
        packets: Vec<crate::channels::Packet>,
    ) {
        for pkt in packets {
            let decision = self.migrator.as_mut().expect("bound program").route(ctx.fabric, &pkt);
            // The sender pays the per-message submission overhead on its
            // own timeline (IPC rendezvous + serialization).
            ctx.engine.pay(collector, decision.sender_s);
            self.stats.transfer_seconds += decision.transfer_s;
            self.stats.transfer_ops += 1;
            self.stats.packets_out += 1;
            self.stats.bytes_moved += pkt.bytes() as u64;
            // The buffer absorbs the packet the moment it lands; there is
            // no batcher — the learner samples on its own schedule.
            self.migrator
                .as_mut()
                .expect("bound program")
                .complete(decision.trainer, pkt.samples());
            self.insert_packet(&pkt, decision.arrival.seconds());
        }
    }

    /// Repay the staged-experience debt carried through a snapshot:
    /// re-charge the collection work whose staged samples died with the
    /// old pipeline and re-dispense equivalent synthetic segments, so the
    /// transition count over the whole run is conserved exactly.
    fn redo_lost_samples(&mut self, ctx: &mut StepCtx<'_>) {
        let debts = std::mem::take(&mut self.redo_samples);
        for (i, &lost) in debts.iter().enumerate() {
            if lost == 0 || i >= self.collector_ids.len() {
                continue;
            }
            let id = self.collector_ids[i];
            let n_env = ctx.engine.num_env(id);
            let steps = lost.div_ceil(n_env.max(1)).max(1);
            let now = ctx.engine.charge_steps(
                ctx.cost,
                id,
                steps as f64,
                &[
                    OpCharge::recorded(OpKind::SimStep { num_env: n_env }),
                    OpCharge::unrecorded(OpKind::PolicyFwd { num_env: n_env }),
                ],
                0.0,
            );
            let seg = RolloutSegment::synthetic(steps, n_env, ctx.bench.obs_dim, ctx.bench.act_dim);
            let steps_per_group = (self.cfg.batch_samples / n_env.max(1)).max(1);
            let groups =
                self.dispensers[i].dispense_groups(&seg, now, self.cfg.share_mode, steps_per_group);
            let compressor = self.compressor.as_mut().expect("bound program");
            let mut packets = Vec::new();
            for group in groups {
                self.stats.chunks_in += group.len() as u64;
                packets.extend(compressor.push(group));
            }
            self.drain_packets(ctx, id, packets);
        }
    }

    /// Per-agent redo debt a snapshot must carry: State-channel samples
    /// staged in the compressor (charged but unflushed) plus any carried
    /// debt this incarnation has not repaid yet.
    fn snapshot_redo(&self) -> Vec<usize> {
        let n = if self.dispensers.is_empty() {
            self.redo_samples.len().max(self.dispenser_seqs.len())
        } else {
            self.dispensers.len()
        };
        (0..n)
            .map(|i| {
                let staged = match (&self.compressor, self.dispensers.get(i)) {
                    (Some(cp), Some(d)) => cp.staged_samples_for(d.agent, ChannelKind::State),
                    _ => 0,
                };
                staged + self.redo_samples.get(i).copied().unwrap_or(0)
            })
            .collect()
    }

    fn snapshot_seqs(&self) -> Vec<u64> {
        if self.dispensers.is_empty() {
            self.dispenser_seqs.clone()
        } else {
            self.dispensers.iter().map(Dispenser::seq).collect()
        }
    }

    /// The learner's sampling passes for this round. Sampling runs BEFORE
    /// this round's collection lands (sample-then-insert), so round 0
    /// naturally exercises the empty-buffer path: an empty tick records
    /// zero pressure and no staleness instead of dividing by zero.
    fn learner_pass(&mut self, ctx: &mut StepCtx<'_>) {
        let learner = self.learner_id.expect("bound program");
        for _ in 0..self.cfg.learner_batches_per_round {
            self.pressure_tick();
            if self.buffer_samples == 0 {
                self.empty_ticks += 1;
                continue;
            }
            // Staleness baseline: the learner's clock as this batch is
            // assembled (before the update's own compute is charged).
            let t_l = ctx.engine.max_time(&[learner]).seconds();
            let mut remaining = self.cfg.batch_samples.min(self.buffer_samples);
            let batch = remaining;
            while remaining > 0 {
                let idx = (splitmix64(&mut self.rng) % self.buffer.len() as u64) as usize;
                let e = &self.buffer[idx];
                let take = e.samples.min(remaining);
                remaining -= take;
                let stale = (t_l - e.born_s).max(0.0);
                self.staleness_sum += stale * take as f64;
                self.staleness_n += take;
                if stale > self.max_staleness_s {
                    self.max_staleness_s = stale;
                }
            }
            ctx.engine.charge_steps(
                ctx.cost,
                learner,
                1.0,
                &[
                    OpCharge::recorded(OpKind::TrainGrad { samples: batch }),
                    OpCharge::unrecorded(OpKind::AdamApply),
                ],
                0.0,
            );
            self.transitions_sampled += batch;
            self.updates += 1;

            // Param push-back every k updates: collectors never block on
            // the learner; they only pay the receive cost.
            if self.updates % self.cfg.param_sync_every == 0 {
                let push =
                    ctx.fabric.plan_param_push(ctx.bench.param_bytes(), &self.collector_gpus);
                ctx.fabric.tally(&push, 1.0);
                ctx.engine.pay_group(&self.collector_ids, push.total_s());
            }
        }
    }

    /// One replay round: learner sampling passes, then every collector's
    /// collection segment streamed through the channel pipeline into the
    /// buffer.
    fn run_round(&mut self, ctx: &mut StepCtx<'_>) {
        self.learner_pass(ctx);

        let mut round_reward = 0.0f64;
        let mut round_n = 0usize;
        for i in 0..self.collector_ids.len() {
            let id = self.collector_ids[i];
            let n_env = ctx.engine.num_env(id);
            let m = (self.cfg.push_samples / n_env.max(1)).max(1);
            let now = ctx.engine.charge_steps(
                ctx.cost,
                id,
                m as f64,
                &[
                    OpCharge::recorded(OpKind::SimStep { num_env: n_env }),
                    OpCharge::unrecorded(OpKind::PolicyFwd { num_env: n_env }),
                ],
                0.0,
            );
            self.env_steps += m * n_env;

            let seed = (self.cfg.seed as i32).wrapping_add((self.round * 257 + i) as i32);
            let r = Compute::null_mean_reward(seed) as f64;
            self.reward_sum += r;
            self.reward_n += 1;
            round_reward += r;
            round_n += 1;

            let seg = RolloutSegment::synthetic(m, n_env, ctx.bench.obs_dim, ctx.bench.act_dim);
            let steps_per_group = (self.cfg.batch_samples / n_env.max(1)).max(1);
            let groups =
                self.dispensers[i].dispense_groups(&seg, now, self.cfg.share_mode, steps_per_group);
            let compressor = self.compressor.as_mut().expect("bound program");
            let mut packets = Vec::new();
            for group in groups {
                self.stats.chunks_in += group.len() as u64;
                packets.extend(compressor.push(group));
            }
            self.drain_packets(ctx, id, packets);
        }

        if round_n > 0 {
            self.rewards.push(
                ctx.engine.max_time(&self.collector_ids).seconds(),
                round_reward / round_n as f64,
            );
        }
        self.round += 1;
    }
}

impl Workload for ReplayProgram {
    fn bind(
        &mut self,
        engine: &Engine,
        _fabric: &mut Fabric,
        bench: &BenchInfo,
        members: &[ExecutorId],
    ) -> Result<()> {
        if self.bound {
            // Like A3C, the channel pipeline and buffer provenance are
            // keyed by the member set: replay tenancy contracts fix their
            // membership and only share resizes occur mid-run.
            anyhow::ensure!(
                self.members == members,
                "replay membership is fixed for the run (resize-only elasticity)"
            );
            return Ok(());
        }
        let (collectors, learners) = super::partition_roles(engine, members)?;
        anyhow::ensure!(
            !collectors.is_empty(),
            "replay layout needs at least one collector"
        );
        anyhow::ensure!(
            learners.len() == 1,
            "replay layout needs exactly one learner (got {})",
            learners.len()
        );
        let learner = learners[0];
        let mut migrator = Migrator::new(vec![TrainerEndpoint {
            gmi: engine.gmi_of(learner),
            gpu: engine.gpu(learner),
        }]);
        let mut collector_gpus: Vec<usize> = Vec::new();
        let mut collector_gmis: Vec<usize> = Vec::new();
        for &ex in &collectors {
            let gmi = engine.gmi_of(ex);
            let gpu = engine.gpu(ex);
            migrator.register_agent(gmi, gpu);
            collector_gmis.push(gmi);
            if !collector_gpus.contains(&gpu) {
                collector_gpus.push(gpu);
            }
        }
        // Restore binds resume each collector's chunk-group stream at the
        // carried counter (membership is fixed, so collector i of the
        // restored program IS collector i of the killed one).
        let carried = std::mem::take(&mut self.dispenser_seqs);
        self.dispensers = collector_gmis
            .iter()
            .enumerate()
            .map(|(i, &g)| {
                if carried.len() == collector_gmis.len() {
                    Dispenser::with_seq(g, bench.obs_dim, bench.act_dim, carried[i])
                } else {
                    Dispenser::new(g, bench.obs_dim, bench.act_dim)
                }
            })
            .collect();
        self.compressor = Some(Compressor::with_staging_interval(
            self.cfg.share_mode,
            self.cfg.compressor_granularity,
            self.cfg.staging_interval_s,
        ));
        self.capacity = ((self.cfg.buffer_gib * (1u64 << 30) as f64)
            / Self::transition_bytes(bench) as f64)
            .floor() as usize;
        anyhow::ensure!(self.capacity > 0, "replay buffer budget below one transition");
        self.migrator = Some(migrator);
        self.collector_ids = collectors;
        self.learner_id = Some(learner);
        self.collector_gpus = collector_gpus;
        self.members = members.to_vec();
        self.bound = true;
        Ok(())
    }

    fn step(&mut self, ctx: &mut StepCtx) -> Result<StepOutcome> {
        anyhow::ensure!(self.bound, "replay program stepped before bind");
        if !self.started {
            self.started = true;
            self.start_s = ctx.engine.max_time(&self.members).seconds();
            let n_env0 = ctx.engine.num_env(self.collector_ids[0]);
            // Collector-side rollout memory plus the learner-side buffer
            // budget: the footprint the tenant's GMI memory grant covers.
            self.peak_mem =
                ctx.cost.mem_gib(n_env0, ctx.bench.horizon, true, false) + self.cfg.buffer_gib;
        }
        // Lost-and-redone: repay the staged-experience debt carried
        // through a snapshot before charging any new rounds.
        self.redo_lost_samples(ctx);
        while self.round < self.cfg.rounds
            && ctx.engine.max_time(&self.members).seconds() < ctx.horizon_s
        {
            self.run_round(ctx);
        }
        if self.round >= self.cfg.rounds {
            if !self.flushed {
                self.flushed = true;
                // Final drain: staged stragglers enter the buffer so every
                // dispensed transition is accounted for exactly once.
                let leftover = self.compressor.as_mut().expect("bound program").flush();
                for pkt in leftover {
                    // Flush routes like regular traffic — the first
                    // collector pays the submission overhead (the flush is
                    // a single end-of-run sweep).
                    let sender = self.collector_ids[0];
                    self.drain_packets(ctx, sender, vec![pkt]);
                }
            }
            return Ok(StepOutcome::Done);
        }
        Ok(StepOutcome::Pending)
    }

    fn snapshot(&self) -> Option<Box<dyn Workload>> {
        // The buffer ledger, RNG cursor, and every accumulator survive;
        // the channel pipeline is rebuilt at the restore bind. Carried
        // ACROSS the rebuild: dispenser seq counters (stream continuity)
        // and the per-collector staged-sample redo debt (transition
        // conservation) — the same contract as the A3C snapshot.
        Some(Box::new(ReplayProgram {
            cfg: self.cfg.clone(),
            members: Vec::new(),
            collector_ids: Vec::new(),
            learner_id: None,
            collector_gpus: Vec::new(),
            bound: false,
            migrator: None,
            dispensers: Vec::new(),
            compressor: None,
            dispenser_seqs: self.snapshot_seqs(),
            redo_samples: self.snapshot_redo(),
            capacity: self.capacity,
            buffer: self.buffer.clone(),
            buffer_samples: self.buffer_samples,
            rng: self.rng,
            started: self.started,
            start_s: self.start_s,
            round: self.round,
            flushed: self.flushed,
            env_steps: self.env_steps,
            transitions_in: self.transitions_in,
            transitions_sampled: self.transitions_sampled,
            evicted: self.evicted,
            updates: self.updates,
            empty_ticks: self.empty_ticks,
            staleness_sum: self.staleness_sum,
            staleness_n: self.staleness_n,
            max_staleness_s: self.max_staleness_s,
            pressure_sum: self.pressure_sum,
            pressure_n: self.pressure_n,
            peak_pressure: self.peak_pressure,
            stats: self.stats.clone(),
            rewards: self.rewards.clone(),
            reward_sum: self.reward_sum,
            reward_n: self.reward_n,
            peak_mem: self.peak_mem,
        }))
    }

    fn finish(&mut self, engine: &Engine, fabric: &Fabric) -> RunMetrics {
        let collector_span = engine.max_time(&self.collector_ids).seconds() - self.start_s;
        let span = engine.max_time(&self.members).seconds() - self.start_s;
        let total_steps = self.env_steps as f64;
        // Every ratio is guarded: a zero-round or zero-sample run reports
        // zeros, never NaN (locked by prop_offpolicy.rs).
        let rate = |num: f64, den: f64| if den > 0.0 { num / den } else { 0.0 };
        let replay = ReplayStats {
            capacity: self.capacity,
            transitions_in: self.transitions_in,
            transitions_sampled: self.transitions_sampled,
            evicted: self.evicted,
            updates: self.updates,
            empty_ticks: self.empty_ticks,
            mean_staleness_s: rate(self.staleness_sum, self.staleness_n as f64),
            max_staleness_s: self.max_staleness_s,
            mean_pressure: rate(self.pressure_sum, self.pressure_n as f64),
            peak_pressure: self.peak_pressure,
        };
        RunMetrics {
            steps_per_sec: rate(total_steps, span),
            pps: rate(total_steps, collector_span),
            ttop: rate(self.transitions_sampled as f64, span),
            span_s: span,
            utilization: engine.mean_utilization(),
            final_reward: rate(self.reward_sum, self.reward_n as f64),
            reward_curve: self.rewards.curve.clone(),
            comm_s: self.stats.transfer_seconds,
            peak_mem_gib: self.peak_mem,
            links: fabric.link_report(),
            latency: None,
            replay: Some(replay),
        }
    }
}

/// Result of a standalone replay run.
pub struct ReplayRunResult {
    pub metrics: RunMetrics,
    pub channel_stats: ChannelStats,
    /// Learner updates performed.
    pub updates: usize,
}

/// Standalone off-policy driver: collectors + one learner from an async
/// layout, run to completion on a private engine + fabric (the same
/// program the scheduler steps round-by-round — `prop_workload.rs` locks
/// the two paths bit-identical).
pub fn run_replay(
    layout: &Layout,
    bench: &BenchInfo,
    cost: &CostModel,
    compute: &Compute,
    cfg: &ReplayConfig,
) -> Result<ReplayRunResult> {
    anyhow::ensure!(
        !layout.rollout_gmis.is_empty() && !layout.trainer_gmis.is_empty(),
        "replay layout needs collectors and a learner"
    );
    let mut engine = Engine::new(&layout.manager, cost);
    let mut fabric = Fabric::single_node(layout.manager.topology().clone());
    let collector_ids = engine.add_group(&layout.rollout_gmis)?;
    let learner_ids = engine.add_group(&layout.trainer_gmis)?;
    let members = super::member_union(collector_ids, learner_ids);

    let mut program = ReplayProgram::new(cfg.clone());
    program.bind(&engine, &mut fabric, bench, &members)?;
    super::run_to_completion(&mut program, &mut engine, &mut fabric, cost, bench, compute)?;

    let metrics = program.finish(&engine, &fabric);
    Ok(ReplayRunResult { metrics, channel_stats: program.take_channel_stats(), updates: program.updates() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::config::static_registry;
    use crate::mapping::build_async_layout;

    fn setup() -> (Layout, BenchInfo, CostModel) {
        let b = static_registry()["AY"].clone();
        let cost = CostModel::new(&b);
        let topo = Topology::dgx_a100(2);
        // 1 serving GPU x 2 collectors, 1 trainer GPU x 1 learner.
        let layout = build_async_layout(&topo, 1, 2, 1, 2048, &cost).unwrap();
        (layout, b, cost)
    }

    #[test]
    fn replay_runs_samples_and_reports_stats() {
        let (layout, b, cost) = setup();
        let cfg = ReplayConfig { rounds: 8, ..ReplayConfig::default() };
        let r = run_replay(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        let stats = r.metrics.replay.as_ref().expect("replay stats present");
        assert!(stats.capacity > 0);
        // Exact conservation: every dispensed transition lands exactly
        // once (collection is in whole env-steps).
        let n_env = 2048;
        let m = (cfg.push_samples / n_env).max(1);
        assert_eq!(stats.transitions_in, cfg.rounds * 2 * m * n_env);
        assert!(r.updates > 0, "learner never sampled");
        assert!(stats.transitions_sampled > 0);
        // Round 0 samples before any insertion: the empty path is hit.
        assert!(stats.empty_ticks >= 1);
        assert!(stats.mean_staleness_s.is_finite() && stats.mean_staleness_s >= 0.0);
        assert!(stats.max_staleness_s >= stats.mean_staleness_s);
        assert!((0.0..=1.0).contains(&stats.mean_pressure));
        assert!((0.0..=1.0).contains(&stats.peak_pressure));
        assert!(r.metrics.pps > 0.0 && r.metrics.ttop > 0.0);
    }

    #[test]
    fn eviction_keeps_buffer_at_capacity() {
        let (layout, b, cost) = setup();
        // Tiny budget: capacity of a few thousand transitions forces
        // steady eviction under both policies.
        let bytes = ReplayProgram::transition_bytes(&b);
        let tiny_gib = (4096 * bytes) as f64 / (1u64 << 30) as f64;
        for eviction in [Eviction::Fifo, Eviction::Reservoir] {
            let cfg = ReplayConfig {
                rounds: 6,
                buffer_gib: tiny_gib,
                eviction,
                ..ReplayConfig::default()
            };
            let r = run_replay(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
            let stats = r.metrics.replay.unwrap();
            assert!(stats.evicted > 0, "{eviction:?} never evicted");
            assert!(
                stats.transitions_in - stats.evicted <= stats.capacity,
                "{eviction:?} buffer exceeded capacity"
            );
            assert!(stats.peak_pressure <= 1.0);
        }
    }

    #[test]
    fn replay_is_deterministic_run_to_run() {
        let (layout, b, cost) = setup();
        let cfg = ReplayConfig { rounds: 6, eviction: Eviction::Reservoir, ..Default::default() };
        let a = run_replay(&layout, &b, &cost, &Compute::Null, &cfg).unwrap();
        let (layout2, b2, cost2) = setup();
        let c = run_replay(&layout2, &b2, &cost2, &Compute::Null, &cfg).unwrap();
        assert_eq!(a.metrics.replay, c.metrics.replay);
        assert_eq!(a.metrics.span_s.to_bits(), c.metrics.span_s.to_bits());
        assert_eq!(a.updates, c.updates);
    }
}
